//! Precision & op-family sweep: cross the numeric-format axis (FP16 vs
//! the two FP8 storage grids, whose cast units carry their own fault
//! sites) and the GEMM op family against the protection ladder.
//!
//! ```text
//! cargo run --release --example precision_sweep [injections]
//! ```
//!
//! The equivalent CLI invocation is
//! `redmule-ft sweep --configs baseline,full --format fp16,fp8-e4m3 \
//!  --op mul,addmax --shapes 6x8x8 --faults 1 --injections 200`.

use redmule_ft::campaign::{Sweep, SweepConfig};
use redmule_ft::fp::{Fp8Format, GemmFormat, GemmOp};
use redmule_ft::golden::GemmSpec;
use redmule_ft::redmule::Protection;

fn main() -> redmule_ft::Result<()> {
    let injections: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let mut cfg = SweepConfig::new(injections, 17);
    cfg.protections = vec![Protection::Baseline, Protection::Full];
    cfg.formats = vec![GemmFormat::Fp16, GemmFormat::Fp8(Fp8Format::E4M3)];
    cfg.ops = vec![GemmOp::Mul, GemmOp::AddMax];
    cfg.shapes = vec![GemmSpec::new(6, 8, 8)];
    cfg.fault_counts = vec![1];
    eprintln!(
        "precision_sweep: {} cells x {injections} injections...",
        cfg.n_cells()
    );

    let r = Sweep::run(&cfg)?;
    println!("{}", r.to_json_v2());

    // Replication catches faults regardless of the numeric format or the
    // reduction op: the fully protected build never does worse than
    // baseline in any (format, op) cell pair.
    for fmt in [GemmFormat::Fp16, GemmFormat::Fp8(Fp8Format::E4M3)] {
        for op in [GemmOp::Mul, GemmOp::AddMax] {
            let fe = |prot: Protection| {
                r.cells
                    .iter()
                    .filter(|c| c.protection == prot && c.format == fmt && c.op == op)
                    .map(|c| c.result.functional_errors())
                    .min()
                    .expect("cell present")
            };
            let (base, full) = (fe(Protection::Baseline), fe(Protection::Full));
            assert!(
                full <= base,
                "{}/{}: full protection must not exceed baseline errors",
                fmt.name(),
                op.name()
            );
        }
    }
    eprintln!(
        "precision_sweep OK: {} runs in {:.1} s ({:.0} runs/s)",
        r.total_runs(),
        r.wall_seconds,
        r.runs_per_sec()
    );
    Ok(())
}
