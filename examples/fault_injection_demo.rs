//! Targeted fault-injection walkthrough: one fault per protection
//! mechanism of Figure 1, showing exactly which checker catches it.
//!
//! ```text
//! cargo run --release --example fault_injection_demo
//! ```

use redmule_ft::cluster::{HostOutcome, System};
use redmule_ft::fault::site::{
    checker_unit, fault_unit as fu, regfile_unit, sched_unit, streamer_unit, wbuf_unit, Module,
    SiteId,
};
use redmule_ft::fault::{FaultKind, FaultPlan};
use redmule_ft::prelude::*;
use redmule_ft::redmule::fault_unit::cause;

fn inject(
    sys: &mut System,
    problem: &GemmProblem,
    mode: ExecMode,
    plan: FaultPlan,
) -> redmule_ft::Result<(HostOutcome, u32, bool, bool)> {
    let golden = problem.golden_z();
    let r = sys.run_gemm_with_fault(problem, mode, Some(plan))?;
    Ok((r.outcome, r.fault_causes, r.irq_seen, r.z_matches(&golden)))
}

fn main() -> redmule_ft::Result<()> {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, 2025);
    let mut sys = System::new(cfg, Protection::Full);
    let ft = ExecMode::FaultTolerant;

    // Mid-compute cycle for transient targets.
    let mid = sys.run_gemm(&problem, ft)?.cycles / 2;

    println!("== Figure-1 protection mechanisms, one targeted fault each ==\n");

    // (3)+(B) broadcast weight corrupted *after* parity generation: the
    // per-CE parity check fires. The column is only live on cycles where
    // a wave sits at its entry slot, so probe a few cycles.
    let mut w_hit = None;
    for c in mid..mid + 16 {
        let r = inject(
            &mut sys,
            &problem,
            ft,
            FaultPlan {
                cycle: c,
                site: SiteId::new(Module::WBuf, wbuf_unit::VALUE_REG, 1),
                bit: 9,
                kind: FaultKind::Transient,
            },
        )?;
        if r.1 & cause::W_PARITY != 0 {
            w_hit = Some(r);
            break;
        }
    }
    let (o, c, irq, ok) = w_hit.expect("a live cycle must trip the W parity check");
    println!(
        "W broadcast register flip  -> {:?}, causes [{}], irq {}, correct {}",
        o,
        cause::names(c).join("+"),
        irq,
        ok
    );
    assert!(c & cause::W_PARITY != 0 && ok);

    // (2)+(4) one FMA result of one row of a redundant pair: the output
    // checker sees the pair disagree (probe until the CE is live).
    let mut fma_hit = None;
    for cyc in mid..mid + 24 {
        let r = inject(
            &mut sys,
            &problem,
            ft,
            FaultPlan {
                cycle: cyc,
                site: SiteId::new(Module::CeArray, redmule_ft::fault::site::ce_unit::FMA_NET, 5),
                bit: 3,
                kind: FaultKind::Transient,
            },
        )?;
        assert!(r.3, "full protection must stay correct");
        if r.1 & cause::Z_MISMATCH != 0 {
            fma_hit = Some(r);
            break;
        }
    }
    let (o, c, _, ok) = fma_hit.expect("a live FMA transient must trip the Z checker");
    println!(
        "FMA result transient       -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(c & cause::Z_MISMATCH != 0 && ok);

    // (1) corrupted accumulator of one row in the pair: detected when the
    // tile is stored (or masked if the slot is overwritten first — probe).
    let mut acc_hit = None;
    for cyc in (mid..mid + 40).rev() {
        let r = inject(
            &mut sys,
            &problem,
            ft,
            FaultPlan {
                cycle: cyc,
                site: SiteId::with_wide_index(Module::Accumulator, 0, 17),
                bit: 14,
                kind: FaultKind::StateUpset,
            },
        )?;
        assert!(r.3, "full protection must stay correct");
        if r.1 & cause::Z_MISMATCH != 0 {
            acc_hit = Some(r);
            break;
        }
    }
    let (o, c, _, ok) = acc_hit.expect("a late accumulator SEU must trip the Z checker");
    println!(
        "accumulator SEU            -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(c & cause::Z_MISMATCH != 0 && ok);

    // (A) streamer address generator upset: the reduced-width replica
    // disagrees on the issued address.
    let (o, c, _, ok) = inject(
        &mut sys,
        &problem,
        ft,
        FaultPlan {
            cycle: 2, // before the first fetches
            site: SiteId::new(Module::StreamerX, streamer_unit::ADDR_REG, 0),
            bit: 6,
            kind: FaultKind::StateUpset,
        },
    )?;
    println!(
        "streamer addr-gen SEU      -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(c & cause::STREAMER_MISMATCH != 0 && ok);

    // (B) scheduler counter upset: lockstep FSM comparison.
    let (o, c, _, ok) = inject(
        &mut sys,
        &problem,
        ft,
        FaultPlan {
            cycle: mid,
            site: SiteId::with_wide_index(Module::SchedFsm, sched_unit::COUNT_REG, 2),
            bit: 1,
            kind: FaultKind::StateUpset,
        },
    )?;
    println!(
        "scheduler counter SEU      -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(c & cause::FSM_MISMATCH != 0 && ok);

    // (B) configuration word upset: continuous regfile parity check.
    // After host_program+commit the *active* context is 1, so the live
    // K word sits at index 1*WORDS + 6 (a flip in the shadow context is
    // correctly ignored — see regfile unit tests).
    let active_k = (redmule_ft::redmule::regfile::WORDS + 6) as u16;
    let (o, c, _, ok) = inject(
        &mut sys,
        &problem,
        ft,
        FaultPlan {
            cycle: mid,
            site: SiteId::new(Module::RegFile, regfile_unit::WORD, active_k),
            bit: 2,
            kind: FaultKind::StateUpset,
        },
    )?;
    println!(
        "regfile config-word SEU    -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(c & cause::REGFILE_PARITY != 0 && ok);

    // §3.3: transient on the interrupt wire during the 2-cycle assert —
    // the host must still see the IRQ on the other cycle. Find an abort
    // first, then hit the IRQ net on its first assert cycle.
    let probe = FaultPlan {
        cycle: 2,
        site: SiteId::new(Module::StreamerX, streamer_unit::ADDR_REG, 0),
        bit: 5,
        kind: FaultKind::StateUpset,
    };
    let r = sys.run_gemm_with_fault(&problem, ft, Some(probe))?;
    assert!(r.irq_seen && r.retries > 0);
    println!(
        "\nIRQ double-assert: detection raises the wire for 2 cycles; a 1-cycle\ntransient on the wire cannot hide it (see integration_fault.rs for the\nexhaustive per-cycle check). retries={}, correct={}",
        r.retries,
        r.z_matches(&problem.golden_z())
    );

    // Checker nets themselves are fault sites too (WFILTER / Z_CMP).
    let store_cycle = sys.run_gemm(&problem, ft)?.cycles - 3; // during StoreZ
    let (o, c, _, ok) = inject(
        &mut sys,
        &problem,
        ft,
        FaultPlan {
            cycle: store_cycle,
            site: SiteId::new(Module::Checker, checker_unit::WFILTER_NET, 4),
            bit: 0,
            kind: FaultKind::Transient,
        },
    )?;
    println!(
        "write-filter net transient -> {:?}, causes [{}], correct {}",
        o,
        cause::names(c).join("+"),
        ok
    );
    assert!(ok);

    // Fault-status register flip while idle-adjacent logic runs: sticky
    // status is host-visible.
    let (_, _, _, ok) = inject(
        &mut sys,
        &problem,
        ft,
        FaultPlan {
            cycle: mid,
            site: SiteId::new(Module::FaultUnit, fu::STATUS_REG, 0),
            bit: 1,
            kind: FaultKind::StateUpset,
        },
    )?;
    println!("fault-status register SEU  -> correct {ok}");

    println!("\nfault_injection_demo OK");
    Ok(())
}
