//! Adaptive statistical campaign walkthrough: run the Table-1 workload
//! on the data-protected build until every outcome rate is pinned to a
//! ±2 % half-width at 95 % confidence, with stratified allocation over
//! the fault-site registry's area strata — then print the estimates the
//! way a paper table would quote them.
//!
//! ```bash
//! cargo run --release --example adaptive_campaign
//! ```

use redmule_ft::campaign::{Campaign, CampaignConfig, OUTCOMES};
use redmule_ft::redmule::Protection;

fn main() -> redmule_ft::Result<()> {
    let mut cfg = CampaignConfig::table1(Protection::Data, 20_000, 2025);
    cfg.precision_target = 0.02; // ±2 percentage points at 95 %
    cfg.batch_size = 500;
    cfg.min_injections = 500;
    cfg.stratify = true;

    println!(
        "adaptive campaign: {} build, cap {} injections, target ±{} (95 % half-width)\n",
        cfg.protection.name(),
        cfg.injections,
        cfg.precision_target
    );
    let r = Campaign::run(&cfg)?;

    println!(
        "stopped after {} injections in {} batches ({})\n",
        r.total,
        r.batches,
        if r.stopped_early {
            "early: every outcome CI met the target"
        } else {
            "at the injection cap"
        }
    );

    println!(
        "{:<22} {:>7} {:>9}  {:^19}  {:^19}",
        "outcome", "count", "rate", "95% Wilson CI", "95% exact CI"
    );
    for o in OUTCOMES {
        let e = r.estimate_of(o);
        println!(
            "{:<22} {:>7} {:>8.4} %  [{:>7.4}, {:>7.4}] %  [{:>7.4}, {:>7.4}] %",
            o.name(),
            e.count,
            100.0 * e.rate,
            100.0 * e.ci_lo,
            100.0 * e.ci_hi,
            100.0 * e.exact_lo,
            100.0 * e.exact_hi
        );
    }
    let fe = r.functional_error_estimate();
    if fe.count == 0 {
        println!(
            "{:<22} {:>7}   -> < {:.3e} at 95 % (rule-of-three bound)",
            "functional error", 0, fe.upper95()
        );
    } else {
        println!(
            "{:<22} {:>7} {:>8.4} %  [{:>7.4}, {:>7.4}] %",
            "functional error",
            fe.count,
            100.0 * fe.rate,
            100.0 * fe.ci_lo,
            100.0 * fe.ci_hi
        );
    }

    println!("\nper-stratum allocation (area share vs injections):");
    for s in &r.strata {
        println!(
            "  {:<10} share {:>6.3}  n {:>6}  [no-retry {:>5}, retry {:>4}, incorrect {:>4}, timeout {:>4}]",
            s.name, s.share, s.n, s.outcomes[0], s.outcomes[1], s.outcomes[2], s.outcomes[3]
        );
    }
    Ok(())
}
