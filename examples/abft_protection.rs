//! ABFT checksum protection, end to end: encode → carry through the GEMM
//! → verify at writeback → locate → selective row-band recompute.
//!
//! ```text
//! cargo run --release --example abft_protection
//! ```
//!
//! The third point in the paper's design space: instead of replicating
//! computation (2× throughput cost, `Full`) or sprinkling parity/ECC
//! (`Data`), the `Abft` build carries one checksum row/column through the
//! array and verifies the result's row/column sums at writeback — full
//! performance-mode speed, a ~3-4 % area adder bank, and coverage bounded
//! by the FP16 rounding tolerance of the checksum identity.

use redmule_ft::area::area_report;
use redmule_ft::campaign::classify;
use redmule_ft::cluster::System;
use redmule_ft::fault::FaultRegistry;
use redmule_ft::golden::Mat;
use redmule_ft::prelude::*;
use redmule_ft::util::rng::mix64;

fn main() -> redmule_ft::Result<()> {
    let cfg = RedMuleConfig::paper();

    // ---- 1. the checksum layer on its own --------------------------------
    let mut rng = Xoshiro256::new(7);
    let mut mat = Mat::random(8, 6, 1.0, &mut rng);
    let checksums = mat.abft_checksums();
    let orig = mat.at(3, 4);
    mat.set(3, 4, redmule_ft::fp::Fp16::from_bits(orig.to_bits() ^ (1 << 9)));
    let mismatch = mat.abft_verify(&checksums);
    println!(
        "exact checksums: corrupted bit 9 of element (3,4) -> located at {:?}",
        mismatch.located()
    );
    assert_eq!(mismatch.located(), Some((3, 4)));
    mat.set(3, 4, orig);

    // ---- 2. fault-free hosted run: zero retries, perf-mode speed ---------
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, 2026);
    let golden = problem.golden_z();
    let mut abft_sys = System::new(cfg, Protection::Abft);
    let clean = abft_sys.run_gemm(&problem, ExecMode::Performance)?;
    assert!(clean.z_matches(&golden) && clean.retries == 0);
    let mut full_sys = System::new(cfg, Protection::Full);
    let ft = full_sys.run_gemm(&problem, ExecMode::FaultTolerant)?;
    println!(
        "fault-free ({},{},{}): abft {} cycles (incl. checksum tiles) vs full-FT {} cycles",
        spec.m, spec.n, spec.k, clean.cycles, ft.cycles
    );

    // ---- 3. fault sweep: detection, location, band recovery --------------
    let n = 800u64;
    let reg_abft = FaultRegistry::new(cfg, Protection::Abft);
    let reg_base = FaultRegistry::new(cfg, Protection::Baseline);
    let mut base_sys = System::new(cfg, Protection::Baseline);
    let horizon_abft = clean.cycles;
    let horizon_base = base_sys.run_gemm(&problem, ExecMode::Performance)?.cycles;

    let (mut abft_err, mut base_err) = (0u64, 0u64);
    let (mut detections, mut bands, mut restarts) = (0u32, 0u32, 0u32);
    for i in 0..n {
        let mut rng = Xoshiro256::new(mix64(0xABF7, i));
        let plan = reg_abft.sample_plan(horizon_abft, &mut rng);
        let r = abft_sys.run_gemm_with_fault(&problem, ExecMode::Performance, Some(plan))?;
        let info = r.abft.expect("abft builds report checksum bookkeeping");
        detections += info.detections;
        bands += info.band_recomputes;
        restarts += info.full_restarts;
        if classify(&r, &golden).is_functional_error() {
            abft_err += 1;
        }

        let mut rng = Xoshiro256::new(mix64(0xABF7, i));
        let plan = reg_base.sample_plan(horizon_base, &mut rng);
        let r = base_sys.run_gemm_with_fault(&problem, ExecMode::Performance, Some(plan))?;
        if classify(&r, &golden).is_functional_error() {
            base_err += 1;
        }
    }
    println!(
        "\n{n} un-derated injections each:\n  baseline  {base_err} functional errors\n  abft      {abft_err} functional errors \
         ({detections} detections -> {bands} row-band recomputes, {restarts} full restarts)"
    );
    assert!(abft_err < base_err, "checksums must cut the error rate");
    assert!(detections > 0 && bands > 0, "selective recovery must be exercised");

    // ---- 4. what does it cost? -------------------------------------------
    let base_area = area_report(cfg, Protection::Baseline);
    for p in [Protection::Data, Protection::Abft, Protection::Full] {
        let r = area_report(cfg, p);
        println!(
            "area [{:<5}]: {:>6.1} kGE ({:+.1} % vs baseline)",
            p.name(),
            r.total_kge(),
            r.overhead_vs(&base_area)
        );
    }
    let abft_ovh = area_report(cfg, Protection::Abft).overhead_vs(&base_area);
    let full_ovh = area_report(cfg, Protection::Full).overhead_vs(&base_area);
    assert!(abft_ovh < full_ovh);
    println!("abft_protection OK");
    Ok(())
}
