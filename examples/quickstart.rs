//! Quickstart: the five-minute tour of the public API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Build a fully protected RedMulE-FT system (paper instance).
//! 2. Run a GEMM in both execution modes and verify bit-exactness.
//! 3. Inject one fault and watch detection → interrupt → retry.
//! 4. Print the area model's view of what the protection costs.

use redmule_ft::area::area_report;
use redmule_ft::fault::FaultRegistry;
use redmule_ft::prelude::*;

fn main() -> redmule_ft::Result<()> {
    // ---- 1. a cluster with a fully protected accelerator ---------------
    let cfg = RedMuleConfig::paper(); // L=12, H=4, P=3, FP16
    let mut sys = System::new(cfg, Protection::Full);

    // ---- 2. one GEMM, both modes ---------------------------------------
    let spec = GemmSpec::new(16, 16, 16);
    let problem = GemmProblem::random(&spec, 42);
    let golden = problem.golden_z();

    let ft = sys.run_gemm(&problem, ExecMode::FaultTolerant)?;
    let perf = sys.run_gemm(&problem, ExecMode::Performance)?;
    assert!(ft.z_matches(&golden) && perf.z_matches(&golden));
    println!(
        "GEMM {}x{}x{}: fault-tolerant {} cycles, performance {} cycles ({:.2}x)",
        spec.m,
        spec.n,
        spec.k,
        ft.cycles,
        perf.cycles,
        ft.cycles as f64 / perf.cycles as f64
    );

    // ---- 3. inject a fault, watch the recovery flow --------------------
    let registry = FaultRegistry::new(cfg, Protection::Full);
    let mut rng = Xoshiro256::new(7);
    let mut retried = None;
    for _ in 0..500 {
        let plan = registry.sample_plan(ft.cycles, &mut rng);
        let r = sys.run_gemm_with_fault(&problem, ExecMode::FaultTolerant, Some(plan))?;
        assert!(r.z_matches(&golden), "full protection must stay correct");
        if r.retries > 0 {
            retried = Some((plan, r));
            break;
        }
    }
    let (plan, r) = retried.expect("some injection should trigger a retry");
    println!(
        "injected {:?} bit {} at cycle {} -> detected ({}), IRQ seen: {}, retried {}x, result still bit-exact",
        plan.site.module(),
        plan.bit,
        plan.cycle,
        redmule_ft::redmule::fault_unit::cause::names(r.fault_causes).join("+"),
        r.irq_seen,
        r.retries
    );

    // ---- 4. what does it cost? -----------------------------------------
    let base = area_report(cfg, Protection::Baseline);
    for p in [Protection::Baseline, Protection::Data, Protection::Full] {
        let rep = area_report(cfg, p);
        println!(
            "area [{:<8}]: {:>6.1} kGE ({:+.1} % vs baseline)",
            p.name(),
            rep.total_kge(),
            rep.overhead_vs(&base)
        );
    }
    println!("quickstart OK");
    Ok(())
}
