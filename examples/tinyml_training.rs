//! End-to-end driver: TinyML training entirely from Rust via PJRT.
//!
//! This is the full three-layer stack composing on a real workload:
//! the Layer-1 Pallas GEMM kernel (FP16 RedMulE semantics) sits inside
//! the Layer-2 JAX train-step graph, AOT-lowered once by `make artifacts`;
//! this Rust binary loads the HLO artifact, holds the parameters, feeds
//! synthetic spiral-classification batches, runs a few hundred SGD steps,
//! logs the loss curve, and evaluates accuracy — Python never runs.
//!
//! ```text
//! make artifacts && cargo run --release --example tinyml_training
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use redmule_ft::runtime::GoldenRuntime;
use redmule_ft::util::rng::Xoshiro256;

const STEPS: usize = 300;
const BATCH: usize = 32;
const IN_DIM: usize = 16;
const HIDDEN: usize = 32;
const CLASSES: usize = 4;

/// Standard-normal sample (Box–Muller).
fn normal(rng: &mut Xoshiro256) -> f32 {
    let u1 = rng.next_f64().max(1e-12);
    let u2 = rng.next_f64();
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// He-initialized parameters (matches python/compile/model.py's shapes).
fn init_params(rng: &mut Xoshiro256) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let he1 = (2.0 / IN_DIM as f64).sqrt() as f32;
    let he2 = (2.0 / HIDDEN as f64).sqrt() as f32;
    let w1 = (0..IN_DIM * HIDDEN).map(|_| normal(rng) * he1).collect();
    let b1 = vec![0.0; HIDDEN];
    let w2 = (0..HIDDEN * CLASSES).map(|_| normal(rng) * he2).collect();
    let b2 = vec![0.0; CLASSES];
    (w1, b1, w2, b2)
}

/// The synthetic spiral workload (same construction as model.spiral_batch).
fn spiral_batch(seed: u64) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
    let mut rng = Xoshiro256::new(seed);
    let mut x = vec![0.0f32; BATCH * IN_DIM];
    let mut onehot = vec![0.0f32; BATCH * CLASSES];
    let mut labels = Vec::with_capacity(BATCH);
    for b in 0..BATCH {
        let label = rng.below(CLASSES as u64) as usize;
        let t = rng.next_f64() * 2.0 + 0.5;
        let theta = label as f64 * (2.0 * std::f64::consts::PI / CLASSES as f64) + t * 0.8;
        x[b * IN_DIM] = (t * theta.cos()) as f32;
        x[b * IN_DIM + 1] = (t * theta.sin()) as f32;
        for f in 2..IN_DIM {
            x[b * IN_DIM + f] = normal(&mut rng) * 0.02;
        }
        onehot[b * CLASSES + label] = 1.0;
        labels.push(label);
    }
    (x, onehot, labels)
}

fn main() -> redmule_ft::Result<()> {
    let rt = GoldenRuntime::load_default()?;
    println!(
        "loaded artifacts from {} (platform {})",
        rt.dir().display(),
        rt.platform()
    );
    let entry = rt
        .entry("mlp_train")
        .expect("mlp_train artifact (run `make artifacts`)");
    assert_eq!(entry.params, vec![BATCH, IN_DIM, HIDDEN, CLASSES]);

    let mut rng = Xoshiro256::new(0xE2E);
    let (mut w1, mut b1, mut w2, mut b2) = init_params(&mut rng);

    let dims_w1 = [IN_DIM as i64, HIDDEN as i64];
    let dims_b1 = [HIDDEN as i64];
    let dims_w2 = [HIDDEN as i64, CLASSES as i64];
    let dims_b2 = [CLASSES as i64];
    let dims_x = [BATCH as i64, IN_DIM as i64];
    let dims_y = [BATCH as i64, CLASSES as i64];

    let started = std::time::Instant::now();
    let mut first_losses = Vec::new();
    let mut last_losses = Vec::new();
    println!("step    loss");
    for step in 0..STEPS {
        let (x, onehot, _) = spiral_batch(step as u64);
        let outs = rt.execute_f32(
            "mlp_train",
            &[
                (&w1, &dims_w1),
                (&b1, &dims_b1),
                (&w2, &dims_w2),
                (&b2, &dims_b2),
                (&x, &dims_x),
                (&onehot, &dims_y),
            ],
        )?;
        w1 = outs[0].clone();
        b1 = outs[1].clone();
        w2 = outs[2].clone();
        b2 = outs[3].clone();
        let loss = outs[4][0];
        if step < 5 {
            first_losses.push(loss);
        }
        if step >= STEPS - 5 {
            last_losses.push(loss);
        }
        if step % 25 == 0 || step == STEPS - 1 {
            println!("{step:>4}    {loss:.4}");
        }
    }
    let train_secs = started.elapsed().as_secs_f64();

    // Evaluation via the predict artifact.
    let mut hits = 0usize;
    let mut total = 0usize;
    for s in 0..5u64 {
        let (x, _, labels) = spiral_batch(10_000 + s);
        let outs = rt.execute_f32(
            "mlp_predict",
            &[
                (&w1, &dims_w1),
                (&b1, &dims_b1),
                (&w2, &dims_w2),
                (&b2, &dims_b2),
                (&x, &dims_x),
            ],
        )?;
        for (p, l) in outs[0].iter().zip(&labels) {
            hits += ((*p as usize) == *l) as usize;
            total += 1;
        }
    }
    let acc = hits as f64 / total as f64;

    let first = first_losses.iter().sum::<f32>() / first_losses.len() as f32;
    let last = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    println!(
        "\n{} steps in {:.1} s ({:.1} steps/s), loss {:.3} -> {:.3}, eval accuracy {:.1} %",
        STEPS,
        train_secs,
        STEPS as f64 / train_secs,
        first,
        last,
        100.0 * acc
    );
    assert!(last < 0.5 * first, "training must reduce the loss");
    assert!(acc > 0.8, "accuracy {acc:.2} too low");
    println!("tinyml_training OK");
    Ok(())
}
