//! Mixed-criticality serving: the paper's §1 motivation end to end.
//!
//! An autonomous-system workload mixes throughput-oriented neural-network
//! GEMMs with safety-critical control-loop GEMMs on *one* accelerator.
//! The coordinator maps criticality to RedMulE-FT's runtime mode per task
//! (§3.4) and the metrics expose the throughput/reliability trade.
//!
//! ```text
//! cargo run --release --example mixed_criticality
//! ```

use redmule_ft::coordinator::{Coordinator, Criticality};
use redmule_ft::prelude::*;

fn main() -> redmule_ft::Result<()> {
    let mut coord = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
    let mut rng = Xoshiro256::new(99);

    // A plausible mixed workload: feature-extraction GEMMs (large,
    // best-effort) interleaved with control-law GEMMs (small, critical).
    let mut specs = Vec::new();
    for i in 0..24 {
        if i % 3 == 0 {
            // Control task: small state-space update, must be protected.
            specs.push((Criticality::Critical, GemmSpec::new(8, 16, 8)));
        } else {
            // Perception task: bigger, wants throughput.
            let n = 32 + (rng.below(4) as usize) * 16;
            specs.push((Criticality::BestEffort, GemmSpec::new(12, n, 24)));
        }
    }

    let problems: Vec<GemmProblem> = specs
        .iter()
        .enumerate()
        .map(|(i, (_, s))| GemmProblem::random(s, 1000 + i as u64))
        .collect();
    for ((crit, _), p) in specs.iter().zip(&problems) {
        coord.submit(*crit, p.clone());
    }

    let completed = coord.run_to_idle()?;
    println!("completed {completed}/{} tasks", coord.metrics.submitted);

    // Verify every result bit-exactly.
    for r in coord.results() {
        let golden = problems[r.id as usize].golden_z();
        assert_eq!(r.z.bits(), golden.bits(), "task {} corrupted", r.id);
    }
    println!("all results bit-exact vs golden");

    // The trade-off, visible in cycles.
    let m = &coord.metrics;
    let crit_tasks = coord
        .results()
        .iter()
        .filter(|r| r.criticality == Criticality::Critical)
        .count();
    let be_tasks = coord.results().len() - crit_tasks;
    println!(
        "critical:    {:>3} tasks, {:>7} cycles (fault-tolerant mode, 2x compute)",
        crit_tasks, m.critical_cycles
    );
    println!(
        "best-effort: {:>3} tasks, {:>7} cycles (performance mode)",
        be_tasks, m.best_effort_cycles
    );
    println!(
        "config overhead (incl. 120-cycle parity per protected task): {} cycles",
        m.config_cycles
    );

    // What the same queue would cost if *everything* ran fault-tolerant:
    // the flexibility argument of the paper in one number.
    let mut all_ft = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
    for p in &problems {
        all_ft.submit(Criticality::Critical, p.clone());
    }
    all_ft.run_to_idle()?;
    let mixed_total = m.total_cycles();
    let ft_total = all_ft.metrics.total_cycles();
    println!(
        "\neverything-critical would cost {ft_total} cycles; mixed-criticality costs {mixed_total} ({:.1} % saved)",
        100.0 * (1.0 - mixed_total as f64 / ft_total as f64)
    );
    println!("mixed_criticality OK");
    Ok(())
}
