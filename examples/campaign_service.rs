//! Campaign-as-a-service: submit a mix of campaign jobs to the
//! deterministic job engine, torment the scheduler with a chaotic fault
//! plan (dropped / duplicated / delayed messages and crashing workers),
//! stream per-batch progress, and verify that every completed job's
//! counts are byte-identical to the plain single-threaded engine.
//!
//! Run with: `cargo run --release --example campaign_service`

use redmule_ft::prelude::*;

fn main() -> redmule_ft::Result<()> {
    let mut sc = ServiceConfig::new(2025);
    sc.workers = 3;
    sc.chunk_injections = 32;
    sc.fault_plan = ServiceFaultPlan::chaos();
    let mut svc = CampaignService::new(sc)?;

    // Three jobs: fixed-budget Full, adaptive ABFT (multiple batches →
    // a streaming CI), fixed-budget Data — each its own campaign seed.
    let mut expected = Vec::new();
    for (i, (prot, adaptive)) in [
        (Protection::Full, false),
        (Protection::Abft, true),
        (Protection::Data, false),
    ]
    .into_iter()
    .enumerate()
    {
        let mut cfg = CampaignConfig::table1(prot, 200, 7 + i as u64);
        cfg.threads = 1;
        if adaptive {
            cfg.precision_target = 0.1;
            cfg.batch_size = 64;
        }
        expected.push(Campaign::run(&cfg)?);
        svc.submit(JobSpec::new(cfg).with_priority(i as i32));
    }

    let report = svc.run()?;
    for (job, want) in report.jobs.iter().zip(&expected) {
        match &job.outcome {
            JobOutcome::Completed(got) => {
                assert_eq!(
                    (got.total, got.incorrect, got.timeout, got.batches),
                    (want.total, want.incorrect, want.timeout, want.batches),
                    "service counts must match the single-threaded engine"
                );
                println!(
                    "job {} ({} requeues): {} injections in {} batches — identical to the single-threaded engine",
                    job.id, job.requeues, got.total, got.batches
                );
                for p in &job.progress {
                    println!(
                        "  vt {:>6}  n {:>4}  functional-error CI half-width {:.4}",
                        p.time, p.total, p.half_width
                    );
                }
            }
            other => println!("job {}: {}", job.id, other.name()),
        }
    }
    let t = &report.telemetry;
    println!(
        "chaos schedule: {} msgs ({} dropped, {} duplicated), {} worker crashes, {} requeues",
        t.msgs_sent, t.msgs_dropped, t.msgs_duplicated, t.worker_crashes, t.chunk_requeues
    );
    assert_eq!(report.trace_cache_resident, 0, "every job must release its pin");
    println!("trace cache drained: resident {}", report.trace_cache_resident);
    Ok(())
}
