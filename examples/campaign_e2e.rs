//! End-to-end SFI campaign: a scaled-down Table 1 with full reporting.
//!
//! ```text
//! cargo run --release --example campaign_e2e [injections]
//! ```
//!
//! Runs the three builds (baseline / data / full) through the statistical
//! fault-injection engine on the paper's (12×16×16) workload, prints the
//! Table-1 comparison against the published numbers, and asserts the
//! paper's qualitative claims. The full-scale run is
//! `cargo run --release -- table1 --injections 1000000`.

use redmule_ft::campaign::Table1;

fn main() -> redmule_ft::Result<()> {
    let injections: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);

    println!("running 3 x {injections} injections (seed 2025)...\n");
    let t = Table1::run(injections, 2025, None)?;
    println!("{}", t.render());

    // The paper's qualitative claims must hold at any reasonable scale.
    let base = &t.columns[0];
    let data = &t.columns[1];
    let full = &t.columns[2];
    assert!(
        data.functional_errors() * 4 < base.functional_errors().max(1),
        "data protection must reduce functional errors by >4x"
    );
    assert_eq!(
        full.functional_errors(),
        0,
        "full protection must show no functional errors"
    );
    assert!(full.correct_with_retry > 0, "retries must be exercised");
    assert_eq!(base.correct_with_retry, 0, "baseline cannot retry");
    println!("campaign_e2e OK ({:.0} runs/s)", base.runs_per_sec());
    Ok(())
}
