//! Scenario-grid sweep: multi-fault campaigns over protections, shapes
//! and ABFT tolerance factors, with machine-readable JSON output.
//!
//! ```text
//! cargo run --release --example sweep_grid [injections]
//! ```
//!
//! The equivalent CLI invocation is
//! `redmule-ft sweep --configs baseline,data,abft --shapes 12x16x16 \
//!  --faults 1,2 --tols 1,4 --injections 400`.

use redmule_ft::campaign::{Sweep, SweepConfig};
use redmule_ft::golden::GemmSpec;
use redmule_ft::redmule::Protection;

fn main() -> redmule_ft::Result<()> {
    let injections: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let mut cfg = SweepConfig::new(injections, 7);
    cfg.protections = vec![Protection::Baseline, Protection::Data, Protection::Abft];
    cfg.shapes = vec![GemmSpec::paper_workload()];
    cfg.fault_counts = vec![1, 2];
    cfg.tol_factors = vec![1.0, 4.0];
    eprintln!(
        "sweep_grid: {} cells x {injections} injections...",
        cfg.n_cells()
    );

    let r = Sweep::run(&cfg)?;
    println!("{}", r.to_json(false));

    // The grid must reproduce the design-space ordering cell by cell:
    // protected builds never do worse than baseline on the same data and
    // fault count.
    for faults in [1usize, 2] {
        let fe = |prot: Protection| {
            r.cells
                .iter()
                .filter(|c| c.protection == prot && c.faults == faults)
                .map(|c| c.result.functional_errors())
                .min()
                .expect("cell present")
        };
        let (base, data) = (fe(Protection::Baseline), fe(Protection::Data));
        assert!(
            data <= base,
            "{faults}-fault: data protection must not exceed baseline errors"
        );
    }
    eprintln!(
        "sweep_grid OK: {} runs in {:.1} s ({:.0} runs/s)",
        r.total_runs(),
        r.wall_seconds,
        r.runs_per_sec()
    );
    Ok(())
}
