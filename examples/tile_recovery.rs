//! Tile-level recovery — the paper's §5 future work, working end to end.
//!
//! "Future work could refine fault recovery to prevent full matrix
//! recomputation, enabling tile-level recovery with a more sophisticated
//! resynchronization mechanism."
//!
//! The resynchronization mechanism here: the fault unit latches a
//! conservative resume tile from the *lockstep scheduler pair* at the
//! first detection (lexicographic minimum — under the single-fault
//! assumption one of the two is uncorrupted, and resuming too early only
//! redoes committed, verified tiles). The host reads it with the status
//! registers and re-programs `REG_RESUME` + the tile-recovery flag.
//!
//! ```text
//! cargo run --release --example tile_recovery
//! ```

use redmule_ft::cluster::{RecoveryPolicy, System};
use redmule_ft::fault::FaultRegistry;
use redmule_ft::prelude::*;
use redmule_ft::util::rng::mix64;

fn main() -> redmule_ft::Result<()> {
    let cfg = RedMuleConfig::paper();
    // A workload with many FT tiles (8 M-tiles x 4 K-tiles), so partial
    // progress is worth preserving.
    let spec = GemmSpec::new(48, 32, 48);
    let problem = GemmProblem::random(&spec, 2026);
    let golden = problem.golden_z();

    let mut full = System::new(cfg, Protection::Full);
    let mut tile = System::new(cfg, Protection::Full).with_recovery(RecoveryPolicy::TileLevel);
    let clean = full.run_gemm(&problem, ExecMode::FaultTolerant)?.cycles;
    println!(
        "workload ({},{},{}): {} fault-free FT cycles across {} tiles\n",
        spec.m,
        spec.n,
        spec.k,
        clean,
        (48 / 6) * (48 / 12)
    );

    // Sweep injections; compare retry costs between the two policies.
    let reg = FaultRegistry::new(cfg, Protection::Full);
    let (mut n_retried, mut cyc_full, mut cyc_tile) = (0u64, 0u64, 0u64);
    println!("inj   detected-at        full-restart   tile-level   saved");
    for i in 0..300u64 {
        let mut rng = Xoshiro256::new(mix64(0x7115, i));
        let plan = reg.sample_plan(clean, &mut rng);
        let a = full.run_gemm_with_fault(&problem, ExecMode::FaultTolerant, Some(plan))?;
        let b = tile.run_gemm_with_fault(&problem, ExecMode::FaultTolerant, Some(plan))?;
        assert!(a.z_matches(&golden), "full restart must stay correct");
        assert!(b.z_matches(&golden), "tile recovery must stay correct");
        if a.retries > 0 || b.retries > 0 {
            n_retried += 1;
            cyc_full += a.cycles;
            cyc_tile += b.cycles;
            if n_retried <= 8 {
                println!(
                    "{:>4}  cycle {:>5} ({:?})  {:>10}  {:>10}  {:>5.1} %",
                    i,
                    plan.cycle,
                    plan.site.module(),
                    a.cycles,
                    b.cycles,
                    100.0 * (1.0 - b.cycles as f64 / a.cycles as f64)
                );
            }
        }
    }
    println!(
        "\n{} of 300 injections triggered retries; total retry-path cycles: \
         full-restart {}, tile-level {} ({:.1} % saved)",
        n_retried,
        cyc_full,
        cyc_tile,
        100.0 * (1.0 - cyc_tile as f64 / cyc_full as f64)
    );
    assert!(cyc_tile < cyc_full);
    println!("tile_recovery OK — every result bit-exact vs golden");
    Ok(())
}
