//! Integration: the scenario-grid sweep engine — grid determinism across
//! thread counts, multi-fault plan stability per injection index, and the
//! TCDM capacity boundary.

use redmule_ft::campaign::{injection_seed, Sweep, SweepConfig};
use redmule_ft::cluster::{HostOutcome, System};
use redmule_ft::fault::{FaultModel, FaultRegistry};
use redmule_ft::prelude::*;
use redmule_ft::tcdm::Tcdm;

/// The acceptance grid: 3 protections × 2 shapes × fault count ∈ {1, 2}.
fn acceptance_grid(seed: u64, threads: usize) -> SweepConfig {
    let mut c = SweepConfig::new(50, seed);
    c.protections = vec![Protection::Baseline, Protection::Data, Protection::Full];
    c.shapes = vec![GemmSpec::paper_workload(), GemmSpec::new(6, 8, 8)];
    c.fault_counts = vec![1, 2];
    c.threads = threads;
    c
}

#[test]
fn sweep_json_is_byte_identical_across_thread_counts() {
    let r1 = Sweep::run(&acceptance_grid(11, 1)).unwrap();
    let r4 = Sweep::run(&acceptance_grid(11, 4)).unwrap();
    assert_eq!(r1.cells.len(), 12, "3 protections x 2 shapes x {{1,2}} faults");
    assert_eq!(
        r1.to_json(false),
        r4.to_json(false),
        "sweep JSON must not depend on the worker-thread count"
    );
    // Every cell is a full campaign whose classification partitions.
    for c in &r1.cells {
        let r = &c.result;
        assert_eq!(r.total, 50);
        assert_eq!(r.correct() + r.functional_errors(), r.total);
    }
}

#[test]
fn sweep_is_seed_sensitive() {
    let a = Sweep::run(&acceptance_grid(11, 2)).unwrap();
    let b = Sweep::run(&acceptance_grid(12, 2)).unwrap();
    assert_ne!(a.to_json(false), b.to_json(false), "seed must matter");
}

#[test]
fn multi_fault_plans_are_deterministic_per_injection_index() {
    let reg = FaultRegistry::new(RedMuleConfig::paper(), Protection::Full);
    for model in [FaultModel::Independent, FaultModel::Burst] {
        for n in [2usize, 3] {
            for index in [0u64, 5, 1234, 0xC0FFEE] {
                let mut r1 = Xoshiro256::new(injection_seed(99, index));
                let mut r2 = Xoshiro256::new(injection_seed(99, index));
                let a = reg.sample_plans(700, n, model, &mut r1);
                let b = reg.sample_plans(700, n, model, &mut r2);
                assert_eq!(a, b, "{model:?} N={n} index={index}");
                assert!(!a.is_empty() && a.len() <= n);
                if model == FaultModel::Independent {
                    assert_eq!(a.len(), n);
                }
            }
        }
    }
}

#[test]
fn burst_runs_complete_end_to_end() {
    // A 3-bit burst through the hosted flow: the run must classify into
    // one of the four Table-1 outcomes, never panic or hang, on every
    // build of the design space.
    let p = GemmProblem::random(&GemmSpec::new(6, 8, 8), 3);
    for protection in [Protection::Baseline, Protection::Full, Protection::Abft] {
        let reg = FaultRegistry::new(RedMuleConfig::paper(), protection);
        let mut sys = System::new(RedMuleConfig::paper(), protection);
        let mode = if protection.has_data_protection() {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        let horizon = sys.run_gemm(&p, mode).unwrap().cycles;
        for i in 0..150u64 {
            let mut rng = Xoshiro256::new(injection_seed(42, i));
            let plans = reg.sample_plans(horizon, 3, FaultModel::Burst, &mut rng);
            let r = sys.run_gemm_with_faults(&p, mode, &plans).unwrap();
            assert!(
                r.faults_applied as usize <= plans.len(),
                "{protection:?} injection {i}"
            );
        }
    }
}

#[test]
fn exactly_fitting_task_is_accepted() {
    // (4,4,4) FP16 at base 0x100: X/W/Y/Z of 32 B each end at 0x180 =
    // 384 B. A TCDM of exactly 384 B fits to the last byte — this pins
    // the fit bound as *inclusive of the end address*.
    let spec = GemmSpec::new(4, 4, 4);
    let p = GemmProblem::random(&spec, 1);
    let exact = Tcdm::new(2, 192);
    assert_eq!(exact.size_bytes(), 384);
    let mut sys = System::with_tcdm(RedMuleConfig::paper(), Protection::Baseline, exact);
    let r = sys.run_gemm(&p, ExecMode::Performance).unwrap();
    assert_eq!(r.outcome, HostOutcome::Completed);
    assert!(r.z_matches(&p.golden_z()), "exact-fit run must stay golden");
}

#[test]
fn task_overflowing_past_the_staging_base_is_a_sim_error_not_a_panic() {
    // Regression for the pre-PR-2 fit check, which compared the footprint
    // alone against the capacity and ignored the 0x100 staging base:
    // (5,4,4) has footprint 152 B (< 384) but ends at 0x198 = 408 > 384,
    // so the old check let it through and staging blew the out-of-range
    // assert inside Tcdm::locate. It must be a structured Error::Sim.
    let spec = GemmSpec::new(5, 4, 4);
    let p = GemmProblem::random(&spec, 1);
    let tcdm = Tcdm::new(2, 192);
    assert_eq!(tcdm.size_bytes(), 384);
    let mut sys = System::with_tcdm(RedMuleConfig::paper(), Protection::Baseline, tcdm);
    match sys.run_gemm(&p, ExecMode::Performance) {
        Err(redmule_ft::Error::Sim(msg)) => {
            assert!(msg.contains("TCDM"), "diagnostic must name the capacity: {msg}");
        }
        other => panic!("expected Error::Sim for an overflowing task, got {other:?}"),
    }
}

#[test]
fn oversized_task_is_a_sim_error_not_a_panic() {
    let spec = GemmSpec::new(4, 4, 4);
    let p = GemmProblem::random(&spec, 1);
    // One word short of the exact fit.
    let tight = Tcdm::new(2, 188);
    assert_eq!(tight.size_bytes(), 376);
    let mut sys = System::with_tcdm(RedMuleConfig::paper(), Protection::Baseline, tight);
    match sys.run_gemm(&p, ExecMode::Performance) {
        Err(redmule_ft::Error::Sim(msg)) => {
            assert!(msg.contains("TCDM"), "diagnostic must name the capacity: {msg}");
        }
        other => panic!("expected Error::Sim for an oversized task, got {other:?}"),
    }
}
