//! A/B harness for the checkpointed fast-forward injection engine and
//! the two-level executor built on it: both fast paths must be
//! **bit-identical** to the direct path — same outcome counts at
//! campaign level, same `RunReport` field for field at single-run
//! level — across protections, fault models, multi-fault plans and
//! checkpoint intervals (including the K=1 and K>horizon edge cases).
//! Any missed field in the snapshot/restore/digest machinery — or a
//! two-level convergence probe accepting a state that is not actually
//! bit-identical to the reference — shows up here as a count diff, not
//! as silently corrupted Table-1 classifications.

use redmule_ft::campaign::{problem_seed, Campaign, CampaignConfig};
use redmule_ft::cluster::{RecoveryPolicy, System};
use redmule_ft::fault::{FaultModel, FaultRegistry};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};
use redmule_ft::util::rng::Xoshiro256;

type Counts = (u64, u64, u64, u64, u64, u64);

fn counts(r: &redmule_ft::campaign::CampaignResult) -> Counts {
    (
        r.correct_no_retry,
        r.correct_with_retry,
        r.incorrect,
        r.timeout,
        r.applied,
        r.faults_applied,
    )
}

/// Run one campaign on all three engines: direct, fast-forward, and
/// two-level. Every test below pins all three to identical counts, so a
/// regression names the first engine that diverged.
fn run_engines(mut cfg: CampaignConfig) -> (Counts, Counts, Counts) {
    cfg.fast_forward = false;
    cfg.two_level = false;
    let direct = Campaign::run(&cfg).unwrap();
    cfg.fast_forward = true;
    let fast = Campaign::run(&cfg).unwrap();
    cfg.two_level = true;
    let two = Campaign::run(&cfg).unwrap();
    assert_eq!(direct.total, fast.total);
    assert_eq!(direct.total, two.total);
    (counts(&direct), counts(&fast), counts(&two))
}

#[test]
fn fast_forward_matches_direct_across_all_protections() {
    for protection in [
        Protection::Baseline,
        Protection::Data,
        Protection::Full,
        Protection::PerCe,
        Protection::Abft,
        Protection::AbftOnline,
    ] {
        let mut cfg = CampaignConfig::table1(protection, 300, 0xFA57);
        cfg.threads = 2;
        let (d, f, t) = run_engines(cfg);
        assert_eq!(d, f, "{protection:?}: fast path diverged from direct");
        assert_eq!(d, t, "{protection:?}: two-level diverged from direct");
    }
}

#[test]
fn fast_forward_matches_direct_across_checkpoint_intervals() {
    // K = 1 (checkpoint every cycle), an awkward prime, auto, and
    // K > horizon (only checkpoint 0 exists: pure direct-from-start with
    // boundary convergence probes never firing — the two-level engine's
    // mid-segment probes still do).
    for k in [1u64, 7, 0, 100_000] {
        let mut cfg = CampaignConfig::table1(Protection::Baseline, 250, 0xC4EC);
        cfg.threads = 2;
        cfg.checkpoint_interval = k;
        let (d, f, t) = run_engines(cfg);
        assert_eq!(d, f, "interval {k}: fast path diverged from direct");
        assert_eq!(d, t, "interval {k}: two-level diverged from direct");
    }
}

#[test]
fn fast_forward_matches_direct_on_multi_fault_plans() {
    for (faults, model) in [
        (3usize, FaultModel::Independent),
        (3, FaultModel::Burst),
        (3, FaultModel::SiteBurst),
        (2, FaultModel::SiteBurst),
    ] {
        for protection in [Protection::Baseline, Protection::Data] {
            let mut cfg = CampaignConfig::table1(protection, 200, 0xB00B5);
            cfg.threads = 2;
            cfg.faults_per_run = faults;
            cfg.fault_model = model;
            let (d, f, t) = run_engines(cfg);
            assert_eq!(d, f, "{protection:?}/{model:?}/{faults} faults");
            assert_eq!(d, t, "{protection:?}/{model:?}/{faults} faults (two-level)");
        }
    }
}

#[test]
fn fast_forward_is_thread_layout_invariant_too() {
    let mut c1 = CampaignConfig::table1(Protection::Data, 200, 42);
    c1.threads = 1;
    let mut c4 = c1.clone();
    c4.threads = 4;
    let r1 = Campaign::run(&c1).unwrap();
    let r4 = Campaign::run(&c4).unwrap();
    assert_eq!(counts(&r1), counts(&r4));
}

/// Field-for-field `RunReport` equivalence on individually sampled plans:
/// stronger than the count-level campaign comparison because it also pins
/// cycles, config cycles, retries, causes, IRQ observation and the exact
/// Z bits of every run — including aborted/retried/timed-out ones that
/// never converge.
#[test]
fn per_run_reports_are_field_identical_between_engines() {
    // Full exercises the FT abort/retry (and the retry shortcut), PerCe
    // the performance-mode abort path with its distinct retry gating,
    // Abft the writeback-verification/band-recovery flow, AbftOnline the
    // fused-residual locate/correct path with its band-recompute
    // fallback (its `abft` info — corrections included — and corrected
    // Z bits must round-trip the snapshot/restore machinery exactly).
    for protection in [
        Protection::Full,
        Protection::PerCe,
        Protection::Abft,
        Protection::AbftOnline,
    ] {
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::paper_workload();
        let problem = GemmProblem::random(&spec, problem_seed(0xAB));
        let mode = if protection.has_data_protection() {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        let recovery = if protection.has_online_abft() {
            RecoveryPolicy::InPlaceCorrect
        } else if protection.has_abft_checksums() {
            RecoveryPolicy::TileLevel
        } else {
            RecoveryPolicy::FullRestart
        };
        let stage = || {
            let mut sys = System::new(cfg, protection).with_recovery(recovery);
            sys.redmule.reset();
            let layout = sys.stage(&problem).unwrap();
            let pristine = sys.tcdm.clone();
            sys.tcdm.enable_dirty_tracking();
            (sys, layout, pristine)
        };
        let (mut sys_ref, layout, pristine_ref) = stage();
        let trace = sys_ref
            .record_reference(&layout, &pristine_ref, mode, 16)
            .unwrap()
            .expect("default-tolerance reference must be clean");
        let (mut sys_ref2, _, pristine_ref2) = stage();
        let trace_tl = sys_ref2
            .record_reference_two_level(&layout, &pristine_ref2, mode, 16)
            .unwrap()
            .expect("two-level reference must be clean");
        // The instrumentation must not perturb the recording itself.
        assert_eq!(trace.cycles, trace_tl.cycles);
        assert_eq!(trace.z.bits(), trace_tl.z.bits());
        assert!(trace_tl.two_level.is_some());
        let (mut sys_d, _, pristine_d) = stage();
        let (mut sys_f, _, pristine_f) = stage();
        let (mut sys_t, _, pristine_t) = stage();
        let registry = FaultRegistry::new(cfg, protection);
        for i in 0..150u64 {
            let mut rng = Xoshiro256::new(0xF00D + i);
            let n = 1 + (i % 3) as usize;
            let plans = registry.sample_plans(trace.cycles, n, FaultModel::Independent, &mut rng);
            sys_d.tcdm.restore_from(&pristine_d);
            sys_d.redmule.reset();
            let d = sys_d.run_staged_with_faults(&layout, mode, &plans).unwrap();
            let f = sys_f
                .run_staged_with_faults_ff(&layout, mode, &plans, &trace, &pristine_f)
                .unwrap();
            let t = sys_t
                .run_staged_with_faults_tl(&layout, mode, &plans, &trace_tl, &pristine_t)
                .unwrap();
            for (name, r) in [("fast-forward", &f), ("two-level", &t)] {
                assert_eq!(d.outcome, r.outcome, "{protection:?}/{name} run {i}: {plans:?}");
                assert_eq!(d.cycles, r.cycles, "{protection:?}/{name} run {i} cycles");
                assert_eq!(
                    d.config_cycles, r.config_cycles,
                    "{protection:?}/{name} run {i} config cycles"
                );
                assert_eq!(d.retries, r.retries, "{protection:?}/{name} run {i} retries");
                assert_eq!(
                    d.fault_causes, r.fault_causes,
                    "{protection:?}/{name} run {i} causes"
                );
                assert_eq!(d.irq_seen, r.irq_seen, "{protection:?}/{name} run {i} irq");
                assert_eq!(
                    d.faults_applied, r.faults_applied,
                    "{protection:?}/{name} run {i} applied"
                );
                assert_eq!(d.abft, r.abft, "{protection:?}/{name} run {i} abft info");
                assert_eq!(
                    d.z.bits(),
                    r.z.bits(),
                    "{protection:?}/{name} run {i}: Z regions must be bit-identical"
                );
            }
        }
    }
}

/// The two-level entry point on a trace recorded *without* the
/// per-cycle instrumentation must degrade to checkpoint-boundary probes
/// (the fast-forward behavior) instead of erroring or diverging.
#[test]
fn two_level_degrades_gracefully_on_an_uninstrumented_trace() {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, problem_seed(0x2F));
    let stage = || {
        let mut sys = System::new(cfg, Protection::Full);
        sys.redmule.reset();
        let layout = sys.stage(&problem).unwrap();
        let pristine = sys.tcdm.clone();
        sys.tcdm.enable_dirty_tracking();
        (sys, layout, pristine)
    };
    let (mut sys_ref, layout, pristine_ref) = stage();
    let trace = sys_ref
        .record_reference(&layout, &pristine_ref, ExecMode::FaultTolerant, 16)
        .unwrap()
        .expect("reference must be clean");
    assert!(trace.two_level.is_none(), "plain recording is uninstrumented");
    let (mut sys_f, _, pristine_f) = stage();
    let (mut sys_t, _, pristine_t) = stage();
    let registry = FaultRegistry::new(cfg, Protection::Full);
    for i in 0..40u64 {
        let mut rng = Xoshiro256::new(0x9E77 + i);
        let plans = registry.sample_plans(trace.cycles, 1, FaultModel::Independent, &mut rng);
        let f = sys_f
            .run_staged_with_faults_ff(&layout, ExecMode::FaultTolerant, &plans, &trace, &pristine_f)
            .unwrap();
        let t = sys_t
            .run_staged_with_faults_tl(&layout, ExecMode::FaultTolerant, &plans, &trace, &pristine_t)
            .unwrap();
        assert_eq!(f.outcome, t.outcome, "run {i}");
        assert_eq!(f.cycles, t.cycles, "run {i}");
        assert_eq!(f.z.bits(), t.z.bits(), "run {i}");
    }
}

/// The reference trace itself must agree with the plain fault-free run it
/// replaces: same horizon, same golden result, checkpoint cycles on the
/// interval grid, and a clean-plan fast call returning the clean report.
#[test]
fn reference_trace_matches_the_fault_free_run() {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, problem_seed(7));
    let golden = problem.golden_z();
    let mut plain = System::new(cfg, Protection::Full);
    let clean = plain.run_gemm(&problem, ExecMode::FaultTolerant).unwrap();

    let mut sys = System::new(cfg, Protection::Full);
    sys.redmule.reset();
    let layout = sys.stage(&problem).unwrap();
    let pristine = sys.tcdm.clone();
    sys.tcdm.enable_dirty_tracking();
    let interval = 24;
    let trace = sys
        .record_reference(&layout, &pristine, ExecMode::FaultTolerant, interval)
        .unwrap()
        .expect("fault-free Full-build reference must be clean");
    assert_eq!(trace.cycles, clean.cycles, "horizon must match");
    assert_eq!(trace.config_cycles, clean.config_cycles);
    assert_eq!(trace.z.bits(), golden.bits());
    assert!(!trace.checkpoints.is_empty());
    for (i, cp) in trace.checkpoints.iter().enumerate() {
        assert_eq!(cp.cycle, i as u64 * interval, "checkpoint {i} cycle");
        assert!(cp.cycle < trace.cycles);
    }
    assert!(trace.checkpoints[0].tcdm_delta.is_empty(), "cp0 is pristine");
    let clean_ff = trace.clean_report();
    assert_eq!(clean_ff.outcome, clean.outcome);
    assert_eq!(clean_ff.cycles, clean.cycles);
    assert_eq!(clean_ff.z.bits(), clean.z.bits());
    // An empty plan list through the fast API returns the clean report
    // without touching the simulator.
    let mut sys2 = System::new(cfg, Protection::Full);
    sys2.redmule.reset();
    let layout2 = sys2.stage(&problem).unwrap();
    let pristine2 = sys2.tcdm.clone();
    sys2.tcdm.enable_dirty_tracking();
    assert_eq!(layout2, layout);
    let r = sys2
        .run_staged_with_faults_ff(&layout2, ExecMode::FaultTolerant, &[], &trace, &pristine2)
        .unwrap();
    assert_eq!(r.outcome, clean.outcome);
    assert_eq!(r.cycles, clean.cycles);
    assert_eq!(r.z.bits(), clean.z.bits());
    // The two-level entry point short-circuits the clean plan the same way.
    let r2 = sys2
        .run_staged_with_faults_tl(&layout2, ExecMode::FaultTolerant, &[], &trace, &pristine2)
        .unwrap();
    assert_eq!(r2.outcome, clean.outcome);
    assert_eq!(r2.cycles, clean.cycles);
    assert_eq!(r2.z.bits(), clean.z.bits());
}
