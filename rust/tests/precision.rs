//! Integration + property tests for the precision (FP8 storage grids)
//! and GEMM op-family axes: cast-path numerics vs the golden model,
//! engine-matrix byte-identity on the default path, thread invariance of
//! FP8 / op campaigns, and the up-front rejection of invalid
//! format × protection combinations.
//!
//! Property tests follow the repo convention (hand-rolled seeded sweeps;
//! proptest is not vendored offline): every case derives from a seed via
//! `Xoshiro256`, so failures reproduce exactly.

use redmule_ft::campaign::{Campaign, CampaignConfig};
use redmule_ft::cluster::System;
use redmule_ft::fp::{max16, min16, Fp16, Fp8Format, GemmFormat, GemmOp};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};
use redmule_ft::util::rng::{mix64, Xoshiro256};

// ---------------------------------------------------- cast numerics

/// Property: snapping onto any storage grid is idempotent — the clean
/// cast-in of a value already on the grid returns it bit-for-bit. This
/// is what makes a fault-free FP8 run reproduce `golden_z_for` exactly.
#[test]
fn prop_snap_is_idempotent_on_every_format() {
    for case in 0..2000u64 {
        let mut rng = Xoshiro256::new(mix64(case, 0xF8F8));
        let v = Fp16::from_bits(rng.next_u64() as u16);
        for fmt in GemmFormat::ALL {
            let once = fmt.snap(v);
            let twice = fmt.snap(once);
            if once.is_nan() {
                assert!(twice.is_nan(), "case {case} {fmt:?}: NaN not sticky");
            } else {
                assert_eq!(
                    once.to_bits(),
                    twice.to_bits(),
                    "case {case} {fmt:?}: snap not idempotent on {v:?}"
                );
            }
        }
    }
}

/// Property: for finite in-range values the snapped value stays within
/// the format's unit roundoff (relative), and out-of-range magnitudes
/// saturate to the format's largest finite value with the sign kept.
#[test]
fn prop_snap_error_bounded_by_unit_roundoff_and_saturates() {
    let max_finite = |fmt: GemmFormat| match fmt {
        GemmFormat::Fp16 => 65504.0,
        GemmFormat::Fp8(Fp8Format::E4M3) => 448.0,
        GemmFormat::Fp8(Fp8Format::E5M2) => 57344.0,
    };
    for case in 0..2000u64 {
        let mut rng = Xoshiro256::new(mix64(case, 0x5A7C));
        // Log-uniform magnitude across the normal range, random sign.
        let exp = rng.below(20) as i32 - 6;
        let frac = 1.0 + rng.next_u64() as f64 / u64::MAX as f64;
        let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
        let v = Fp16::from_f64(sign * frac * 2f64.powi(exp));
        for fmt in GemmFormat::ALL {
            let s = fmt.snap(v);
            let (a, b) = (v.to_f64(), s.to_f64());
            if a.abs() <= max_finite(fmt) {
                let rel = (b - a).abs() / a.abs().max(f64::MIN_POSITIVE);
                assert!(
                    rel <= fmt.unit_roundoff(),
                    "case {case} {fmt:?}: |{b} - {a}| rel err {rel} > u"
                );
            } else {
                assert_eq!(
                    b,
                    sign * max_finite(fmt),
                    "case {case} {fmt:?}: {a} must saturate"
                );
            }
        }
    }
}

/// The max/min reduction steps are IEEE maxNum/minNum with a total-order
/// tie-break on ±0: NaN loses to any number, and the zeros order by sign.
#[test]
fn max_min_follow_maxnum_semantics() {
    let one = Fp16::from_f64(1.0);
    let neg = Fp16::from_f64(-2.0);
    assert_eq!(max16(Fp16::NAN, one).to_bits(), one.to_bits());
    assert_eq!(max16(neg, Fp16::NAN).to_bits(), neg.to_bits());
    assert_eq!(min16(Fp16::NAN, neg).to_bits(), neg.to_bits());
    assert!(max16(Fp16::NAN, Fp16::NAN).is_nan());
    let pz = Fp16::ZERO;
    let nz = Fp16::from_bits(0x8000);
    assert_eq!(max16(nz, pz).to_bits(), pz.to_bits());
    assert_eq!(max16(pz, nz).to_bits(), pz.to_bits());
    assert_eq!(min16(nz, pz).to_bits(), nz.to_bits());
    assert_eq!(min16(pz, nz).to_bits(), nz.to_bits());
}

// -------------------------------------- accelerator vs golden model

/// A fault-free run reproduces `golden_z_for` bit-for-bit in every
/// format × op × mode combination — the cast units and the non-FMA
/// reduction steps land in the datapath exactly where the golden model
/// puts them.
#[test]
fn clean_runs_are_bit_exact_vs_golden_for_every_format_and_op() {
    let spec = GemmSpec::new(7, 9, 11);
    for (i, fmt) in GemmFormat::ALL.into_iter().enumerate() {
        for (j, op) in GemmOp::ALL.into_iter().enumerate() {
            let p = GemmProblem::random(&spec, mix64(i as u64, j as u64) | 1);
            let golden = p.golden_z_for(fmt, op);
            for (protection, mode) in [
                (Protection::Baseline, ExecMode::Performance),
                (Protection::Full, ExecMode::FaultTolerant),
            ] {
                let cfg = RedMuleConfig::paper().with_format(fmt).with_op(op);
                let mut sys = System::new(cfg, protection);
                let r = sys.run_gemm(&p, mode).unwrap();
                assert_eq!(r.retries, 0, "{fmt:?}/{op:?}/{protection:?}: clean run retried");
                assert!(
                    r.z_matches(&golden),
                    "{fmt:?}/{op:?}/{protection:?}/{mode:?}: Z diverged from golden"
                );
            }
        }
    }
}

// --------------------------------------------- campaign-level A/B

type Counts = (u64, u64, u64, u64, u64, u64);

fn counts(r: &redmule_ft::campaign::CampaignResult) -> Counts {
    (
        r.correct_no_retry,
        r.correct_with_retry,
        r.incorrect,
        r.timeout,
        r.applied,
        r.faults_applied,
    )
}

/// Run one campaign on all three engines and pin them to identical
/// counts (same harness as `tests/fastforward.rs`, here exercising the
/// cast-path fault sites and the non-FMA reduction steps).
fn run_engines(mut cfg: CampaignConfig) -> Counts {
    cfg.fast_forward = false;
    cfg.two_level = false;
    let direct = Campaign::run(&cfg).unwrap();
    cfg.fast_forward = true;
    let fast = Campaign::run(&cfg).unwrap();
    cfg.two_level = true;
    let two = Campaign::run(&cfg).unwrap();
    assert_eq!(counts(&direct), counts(&fast), "fast-forward diverged");
    assert_eq!(counts(&direct), counts(&two), "two-level diverged");
    counts(&direct)
}

/// Explicitly configuring the defaults (`fp16`, `mul`) is byte-identical
/// to not configuring them at all, on every engine — the tentpole's
/// default-path contract at campaign level.
#[test]
fn explicit_default_format_and_op_change_nothing() {
    let mut plain = CampaignConfig::table1(Protection::Full, 200, 0xF0_0D);
    plain.threads = 2;
    let mut tagged = plain.clone();
    tagged.cfg = tagged.cfg.with_format(GemmFormat::Fp16).with_op(GemmOp::Mul);
    assert_eq!(run_engines(plain), run_engines(tagged));
}

/// FP8 campaigns (cast-unit fault sites live) agree across all three
/// engines; so do non-FMA op campaigns.
#[test]
fn engine_matrix_agrees_on_fp8_and_op_campaigns() {
    for (fmt, op) in [
        (GemmFormat::Fp8(Fp8Format::E4M3), GemmOp::Mul),
        (GemmFormat::Fp8(Fp8Format::E5M2), GemmOp::MulMin),
        (GemmFormat::Fp16, GemmOp::AddMax),
    ] {
        let mut cfg = CampaignConfig::table1(Protection::Full, 200, 0xCA57);
        cfg.threads = 2;
        cfg.cfg = cfg.cfg.with_format(fmt).with_op(op);
        run_engines(cfg);
    }
}

/// Thread count is invisible: one FP8 campaign and one addmax campaign
/// produce identical counts on 1 and 8 threads.
#[test]
fn fp8_and_addmax_campaigns_are_thread_invariant() {
    for (fmt, op) in [
        (GemmFormat::Fp8(Fp8Format::E4M3), GemmOp::Mul),
        (GemmFormat::Fp16, GemmOp::AddMax),
    ] {
        let mut cfg = CampaignConfig::table1(Protection::Data, 240, 0x7EAD);
        cfg.cfg = cfg.cfg.with_format(fmt).with_op(op);
        cfg.threads = 1;
        let one = Campaign::run(&cfg).unwrap();
        cfg.threads = 8;
        let eight = Campaign::run(&cfg).unwrap();
        assert_eq!(counts(&one), counts(&eight), "{fmt:?}/{op:?}");
    }
}

/// Invalid combinations fail before any injection runs: a non-linear op
/// cannot carry ABFT checksums, and FP8 storage cannot run the online
/// in-place corrector.
#[test]
fn invalid_format_and_op_combinations_are_rejected() {
    let mut cfg = CampaignConfig::table1(Protection::Abft, 10, 1);
    cfg.cfg = cfg.cfg.with_op(GemmOp::AddMax);
    assert!(Campaign::run(&cfg).is_err(), "addmax x abft must be rejected");

    let mut cfg = CampaignConfig::table1(Protection::AbftOnline, 10, 1);
    cfg.cfg = cfg.cfg.with_format(GemmFormat::Fp8(Fp8Format::E4M3));
    assert!(Campaign::run(&cfg).is_err(), "fp8 x abft-online must be rejected");

    // The plain checksum build *does* accept FP8 — the verify tolerance
    // is scaled to the grid's unit roundoff.
    let mut cfg = CampaignConfig::table1(Protection::Abft, 50, 1);
    cfg.cfg = cfg.cfg.with_format(GemmFormat::Fp8(Fp8Format::E4M3));
    Campaign::run(&cfg).unwrap();
}
