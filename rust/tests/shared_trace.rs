//! A/B harness for the shared-trace / zero-copy / work-stealing sweep
//! engine (PR 5): the trace cache and the grid-wide scheduler must be
//! **byte-identical** to the legacy uncached per-cell path — same
//! sweep-v2 JSON across protections and thread layouts, same per-run
//! `RunReport` field for field when the clean run is adopted from a
//! cache or driven through the reusable worker scratch. Any state
//! leaking through the scratch arenas (TCDM copy, fault context,
//! digest buffers, reconfigured Systems) shows up here as a diff.

use redmule_ft::campaign::{problem_seed, Campaign, CampaignConfig, Sweep, SweepConfig, TraceCache};
use redmule_ft::cluster::{RecoveryPolicy, System};
use redmule_ft::fault::{FaultCtx, FaultModel, FaultRegistry};
use redmule_ft::golden::{GemmProblem, GemmSpec, ABFT_TOL_FACTOR};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};
use redmule_ft::util::rng::Xoshiro256;

/// The A/B grid: four protections (incl. the ABFT tolerance axis and
/// the in-place-correcting online build), two fault counts — small
/// budgets, every engine corner.
fn grid(seed: u64, threads: usize) -> SweepConfig {
    let mut c = SweepConfig::new(50, seed);
    c.shapes = vec![GemmSpec::new(6, 8, 8)];
    c.protections = vec![
        Protection::Baseline,
        Protection::Full,
        Protection::Abft,
        Protection::AbftOnline,
    ];
    c.fault_counts = vec![1, 2];
    c.tol_factors = vec![ABFT_TOL_FACTOR, 1.0];
    c.threads = threads;
    c
}

/// Acceptance: the four engine combinations {stealing, per-cell} ×
/// {cached, uncached} emit byte-identical sweep-v2 (and v1) JSON, at
/// 1 and at 8 threads — across protections, the ABFT tolerance axis
/// and multi-fault cells.
#[test]
fn sweep_json_is_byte_identical_across_engines_and_threads() {
    let reference = Sweep::run(&grid(0x5EED, 1)).unwrap();
    let ref_v2 = reference.to_json_v2();
    let ref_v1 = reference.to_json(false);
    for threads in [1usize, 8] {
        for stealing in [true, false] {
            for cached in [true, false] {
                let mut c = grid(0x5EED, threads);
                c.work_stealing = stealing;
                c.trace_cache = cached;
                let r = Sweep::run(&c).unwrap();
                assert_eq!(
                    r.to_json_v2(),
                    ref_v2,
                    "v2 diverged: threads={threads} stealing={stealing} cache={cached}"
                );
                assert_eq!(
                    r.to_json(false),
                    ref_v1,
                    "v1 diverged: threads={threads} stealing={stealing} cache={cached}"
                );
            }
        }
    }
}

/// The two-level executor joins the engine matrix: at 1 and 8 threads
/// its sweep JSON must be byte-identical to the fast-forward reference
/// (itself pinned to direct above), on both schedulers. A probe that
/// accepted a not-actually-converged state, a mis-sized fault window or
/// a divergent per-phase schedule all surface here as a byte diff.
#[test]
fn two_level_sweep_json_is_byte_identical_across_engines_and_threads() {
    let reference = Sweep::run(&grid(0x5EED, 1)).unwrap();
    let ref_v2 = reference.to_json_v2();
    let ref_v1 = reference.to_json(false);
    for threads in [1usize, 8] {
        for stealing in [true, false] {
            let mut c = grid(0x5EED, threads);
            c.two_level = true;
            c.work_stealing = stealing;
            let r = Sweep::run(&c).unwrap();
            assert_eq!(
                r.to_json_v2(),
                ref_v2,
                "v2 diverged: threads={threads} stealing={stealing} two-level"
            );
            assert_eq!(
                r.to_json(false),
                ref_v1,
                "v1 diverged: threads={threads} stealing={stealing} two-level"
            );
        }
    }
}

/// The recovery-policy axis crossed with the engine matrix: the same
/// grid run per-policy must be thread- and engine-invariant, and the
/// policy label must land in every cell of the v2 document.
#[test]
fn recovery_axis_sweeps_are_thread_and_engine_invariant() {
    let mut base = SweepConfig::new(40, 0x4EC);
    base.shapes = vec![GemmSpec::new(6, 8, 8)];
    base.protections = vec![Protection::Full, Protection::AbftOnline];
    base.fault_counts = vec![1, 2];
    base.recoveries = Some(vec![RecoveryPolicy::FullRestart, RecoveryPolicy::TileLevel]);
    base.threads = 2;
    assert_eq!(base.n_cells(), 8);
    let reference = Sweep::run(&base).unwrap();
    let ref_v2 = reference.to_json_v2();
    assert!(ref_v2.contains("\"recovery\": \"full-restart\""));
    assert!(ref_v2.contains("\"recovery\": \"tile-level\""));
    for threads in [1usize, 8] {
        for two_level in [false, true] {
            let mut c = base.clone();
            c.threads = threads;
            c.two_level = two_level;
            let r = Sweep::run(&c).unwrap();
            assert_eq!(
                r.to_json_v2(),
                ref_v2,
                "recovery axis diverged: threads={threads} two_level={two_level}"
            );
        }
    }
    let mut direct = base.clone();
    direct.fast_forward = false;
    assert_eq!(
        Sweep::run(&direct).unwrap().to_json_v2(),
        ref_v2,
        "recovery axis diverged on the direct engine"
    );
}

/// The adaptive + stratified engine exercises the scheduler's sequential
/// batch logic (allocation from merged counts, stop rule, batch
/// boundaries) — the stealing scheduler must reproduce the per-cell
/// pools' stop points and per-stratum tallies exactly.
#[test]
fn adaptive_stratified_sweeps_match_across_schedulers_and_threads() {
    let mut base = SweepConfig::new(3_000, 0xADA);
    base.shapes = vec![GemmSpec::new(6, 8, 8)];
    base.protections = vec![Protection::Baseline, Protection::Data];
    base.fault_counts = vec![1];
    base.precision_target = 0.08;
    base.batch_size = 150;
    base.min_injections = 150;
    base.stratify = true;
    let mut reference_cfg = base.clone();
    reference_cfg.threads = 2;
    reference_cfg.work_stealing = false;
    reference_cfg.trace_cache = false;
    let reference = Sweep::run(&reference_cfg).unwrap();
    let ref_v2 = reference.to_json_v2();
    assert!(
        reference.cells.iter().any(|c| c.result.stopped_early),
        "the A/B must cover an early-stopping adaptive cell"
    );
    for threads in [1usize, 8] {
        let mut c = base.clone();
        c.threads = threads;
        let r = Sweep::run(&c).unwrap();
        assert_eq!(
            r.to_json_v2(),
            ref_v2,
            "adaptive stratified sweep diverged at {threads} threads"
        );
    }
}

/// Campaign-level cache adoption: a campaign that adopts its clean run
/// from a `TraceCache` (recorded by an earlier campaign) produces the
/// same counts as one that records its own.
#[test]
fn campaign_counts_match_between_recorded_and_adopted_traces() {
    for protection in [Protection::Data, Protection::Abft] {
        let mut cfg = CampaignConfig::table1(protection, 200, 0x7E57);
        cfg.threads = 2;
        let problem = GemmProblem::random(&cfg.spec, problem_seed(cfg.seed));
        let plain = Campaign::run_with_problem(&cfg, &problem).unwrap();
        let cache = TraceCache::new();
        // Prime the cache with a different fault count (same identity).
        let mut primer = cfg.clone();
        primer.faults_per_run = 3;
        let _ = Campaign::run_with_problem_cached(&primer, &problem, Some(&cache)).unwrap();
        assert_eq!(cache.misses(), 1, "{protection:?}: primer records");
        let adopted = Campaign::run_with_problem_cached(&cfg, &problem, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 1, "{protection:?}: second campaign adopts");
        assert_eq!(
            (plain.correct_no_retry, plain.correct_with_retry, plain.incorrect, plain.timeout),
            (
                adopted.correct_no_retry,
                adopted.correct_with_retry,
                adopted.incorrect,
                adopted.timeout
            ),
            "{protection:?}: adopted-trace campaign must match"
        );
        assert_eq!(plain.applied, adopted.applied, "{protection:?}");
        assert_eq!(plain.faults_applied, adopted.faults_applied, "{protection:?}");
    }
}

/// Per-run `RunReport` equivalence through the reusable scratch path:
/// `run_staged_with_faults{,_ff}_scratch` with one long-lived
/// `FaultCtx` (and the digest scratch inside the TCDM) must be field-
/// identical to the allocating wrappers, run for run — including
/// retried and timed-out runs where the context's applied bookkeeping
/// matters.
#[test]
fn per_run_reports_are_field_identical_with_reused_scratch() {
    for protection in [Protection::Full, Protection::Abft, Protection::AbftOnline] {
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::paper_workload();
        let problem = GemmProblem::random(&spec, problem_seed(0xAB5));
        let mode = if protection.has_data_protection() {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        let recovery = if protection.has_online_abft() {
            RecoveryPolicy::InPlaceCorrect
        } else if protection.has_abft_checksums() {
            RecoveryPolicy::TileLevel
        } else {
            RecoveryPolicy::FullRestart
        };
        let stage = || {
            let mut sys = System::new(cfg, protection).with_recovery(recovery);
            sys.redmule.reset();
            let layout = sys.stage(&problem).unwrap();
            let pristine = sys.tcdm.clone();
            sys.tcdm.enable_dirty_tracking();
            (sys, layout, pristine)
        };
        let (mut sys_ref, layout, pristine_ref) = stage();
        let trace = sys_ref
            .record_reference(&layout, &pristine_ref, mode, 16)
            .unwrap()
            .expect("default-tolerance reference must be clean");
        let (mut sys_a, _, pristine_a) = stage();
        let (mut sys_b, _, pristine_b) = stage();
        let registry = FaultRegistry::new(cfg, protection);
        // ONE context reused across every run of the scratch system.
        let mut scratch_ctx = FaultCtx::clean();
        for i in 0..120u64 {
            let mut rng = Xoshiro256::new(0x5C4A + i);
            let n = 1 + (i % 3) as usize;
            let plans = registry.sample_plans(trace.cycles, n, FaultModel::Independent, &mut rng);
            let a = sys_a
                .run_staged_with_faults_ff(&layout, mode, &plans, &trace, &pristine_a)
                .unwrap();
            let b = sys_b
                .run_staged_with_faults_ff_scratch(
                    &layout,
                    mode,
                    &plans,
                    &trace,
                    &pristine_b,
                    &mut scratch_ctx,
                )
                .unwrap();
            assert_eq!(a.outcome, b.outcome, "{protection:?} run {i}: {plans:?}");
            assert_eq!(a.cycles, b.cycles, "{protection:?} run {i} cycles");
            assert_eq!(
                a.config_cycles, b.config_cycles,
                "{protection:?} run {i} config cycles"
            );
            assert_eq!(a.retries, b.retries, "{protection:?} run {i} retries");
            assert_eq!(a.fault_causes, b.fault_causes, "{protection:?} run {i} causes");
            assert_eq!(a.irq_seen, b.irq_seen, "{protection:?} run {i} irq");
            assert_eq!(
                a.faults_applied, b.faults_applied,
                "{protection:?} run {i} applied"
            );
            assert_eq!(a.abft, b.abft, "{protection:?} run {i} abft info");
            assert_eq!(
                a.z.bits(),
                b.z.bits(),
                "{protection:?} run {i}: Z regions must be bit-identical"
            );
        }
    }
}

/// The direct (non-fast-forward) scratch path too: reused context vs
/// fresh contexts, on a build whose aborts exercise the retry loop.
#[test]
fn direct_scratch_path_matches_the_allocating_wrapper() {
    let cfg = RedMuleConfig::paper();
    let protection = Protection::Data;
    let spec = GemmSpec::new(6, 8, 8);
    let problem = GemmProblem::random(&spec, problem_seed(0xD1));
    let stage = || {
        let mut sys = System::new(cfg, protection);
        sys.redmule.reset();
        let layout = sys.stage(&problem).unwrap();
        let pristine = sys.tcdm.clone();
        sys.tcdm.enable_dirty_tracking();
        (sys, layout, pristine)
    };
    let (mut sys_a, layout, pristine_a) = stage();
    let (mut sys_b, _, pristine_b) = stage();
    let registry = FaultRegistry::new(cfg, protection);
    let horizon = {
        let mut probe = System::new(cfg, protection);
        probe
            .run_gemm(&problem, ExecMode::FaultTolerant)
            .unwrap()
            .cycles
    };
    let mut scratch_ctx = FaultCtx::clean();
    for i in 0..80u64 {
        let mut rng = Xoshiro256::new(0xD1AB10 + i);
        let plans = registry.sample_plans(horizon, 2, FaultModel::Independent, &mut rng);
        sys_a.tcdm.restore_from(&pristine_a);
        sys_a.redmule.reset();
        let a = sys_a
            .run_staged_with_faults(&layout, ExecMode::FaultTolerant, &plans)
            .unwrap();
        sys_b.tcdm.restore_from(&pristine_b);
        sys_b.redmule.reset();
        let b = sys_b
            .run_staged_with_faults_scratch(
                &layout,
                ExecMode::FaultTolerant,
                &plans,
                &mut scratch_ctx,
            )
            .unwrap();
        assert_eq!(a.outcome, b.outcome, "run {i}");
        assert_eq!(a.cycles, b.cycles, "run {i}");
        assert_eq!(a.retries, b.retries, "run {i}");
        assert_eq!(a.faults_applied, b.faults_applied, "run {i}");
        assert_eq!(a.z.bits(), b.z.bits(), "run {i}");
    }
}
