//! Property tests for the interval / allocation math behind the
//! statistical campaign engine: monotonicity of the Wilson and
//! Clopper–Pearson endpoints, zero-count rule-of-three agreement, CI
//! containment under the binomial model with a seeded RNG, and the
//! determinism/exactness of the Neyman batch allocator.

use redmule_ft::util::rng::Xoshiro256;
use redmule_ft::util::stats::{
    clopper_pearson_ci, clopper_pearson_ci95, exact_upper, exact_upper95, neyman_allocation,
    wilson_ci95, wilson_ci_at, z_one_sided, z_two_sided, OutcomeEstimate, StratumSample, Z95,
    Z95_ONE_SIDED,
};

#[test]
fn intervals_contain_the_point_estimate_and_stay_in_unit_range() {
    for n in [1u64, 10, 100, 1_000, 10_000] {
        for k in [0u64, 1, n / 10, n / 2, n.saturating_sub(1), n] {
            let k = k.min(n);
            let p = k as f64 / n as f64;
            for (lo, hi) in [wilson_ci95(k, n), clopper_pearson_ci95(k, n)] {
                assert!(
                    (0.0..=1.0).contains(&lo) && (0.0..=1.0).contains(&hi),
                    "k={k} n={n}: [{lo}, {hi}] out of range"
                );
                assert!(lo <= hi, "k={k} n={n}");
                assert!(
                    lo <= p + 1e-12 && p <= hi + 1e-12,
                    "k={k} n={n}: p={p} outside [{lo}, {hi}]"
                );
            }
        }
    }
}

#[test]
fn interval_endpoints_are_monotone_in_the_count() {
    let n = 1_000u64;
    let (mut prev_wl, mut prev_wh) = (-1.0f64, -1.0f64);
    let (mut prev_cl, mut prev_ch) = (-1.0f64, -1.0f64);
    for k in (0..=n).step_by(7) {
        let (wl, wh) = wilson_ci95(k, n);
        let (cl, ch) = clopper_pearson_ci95(k, n);
        assert!(wl >= prev_wl - 1e-12, "wilson lo must not decrease at k={k}");
        assert!(wh >= prev_wh - 1e-12, "wilson hi must not decrease at k={k}");
        assert!(cl >= prev_cl - 1e-9, "exact lo must not decrease at k={k}");
        assert!(ch >= prev_ch - 1e-9, "exact hi must not decrease at k={k}");
        (prev_wl, prev_wh) = (wl, wh);
        (prev_cl, prev_ch) = (cl, ch);
    }
}

#[test]
fn intervals_tighten_with_the_sample_size() {
    // Fixed 5 % rate, growing n: both half-widths must shrink strictly.
    let mut prev_w = f64::INFINITY;
    let mut prev_c = f64::INFINITY;
    for n in [100u64, 400, 1_600, 6_400, 25_600] {
        let k = n / 20;
        let (wl, wh) = wilson_ci95(k, n);
        let (cl, ch) = clopper_pearson_ci95(k, n);
        let hw = (wh - wl) / 2.0;
        let hc = (ch - cl) / 2.0;
        assert!(hw < prev_w, "wilson half-width must shrink at n={n}");
        assert!(hc < prev_c, "exact half-width must shrink at n={n}");
        prev_w = hw;
        prev_c = hc;
    }
}

#[test]
fn zero_count_upper_bound_agrees_with_rule_of_three() {
    for n in [50u64, 100, 500, 5_000, 100_000, 1_000_000] {
        let ub = exact_upper95(0, n);
        let rot = 3.0 / n as f64;
        let rel = ((ub - rot) / rot).abs();
        assert!(
            rel < 0.05,
            "n={n}: upper {ub:.4e} vs rule-of-three {rot:.4e} ({rel:.3} off)"
        );
    }
}

#[test]
fn paper_scale_zero_error_bound() {
    // The reproduction of the paper's headline: 0 functional errors in
    // 1M injections is an upper bound of ~3e-6 (one-sided exact 95 %),
    // and ~3.7e-6 under the paper's own "one additional assumed error"
    // Poisson convention — both far below the baseline error rate.
    let exact = exact_upper95(0, 1_000_000);
    assert!(exact < 3.1e-6 && exact > 2.9e-6, "exact = {exact:.4e}");
    let paper = redmule_ft::util::stats::conservative_upper_rate(0, 1_000_000);
    assert!(paper < 3.8e-6 && paper > 3.3e-6, "paper = {paper:.4e}");
}

#[test]
fn coverage_under_the_binomial_model() {
    // Simulate binomials with a seeded RNG and check the intervals cover
    // the true rate at roughly their nominal level. Clopper–Pearson is
    // conservative by construction (>= 95 % up to simulation noise);
    // Wilson may dip slightly below nominal.
    let n = 300usize;
    let trials = 400usize;
    for (pi, &p) in [0.02f64, 0.1, 0.5].iter().enumerate() {
        let mut rng = Xoshiro256::new(0x57A7_5000 + pi as u64);
        let mut cover_w = 0usize;
        let mut cover_c = 0usize;
        for _ in 0..trials {
            let mut k = 0u64;
            for _ in 0..n {
                if rng.next_f64() < p {
                    k += 1;
                }
            }
            let (wl, wh) = wilson_ci95(k, n as u64);
            if wl <= p && p <= wh {
                cover_w += 1;
            }
            let (cl, ch) = clopper_pearson_ci95(k, n as u64);
            if cl <= p && p <= ch {
                cover_c += 1;
            }
        }
        let cw = cover_w as f64 / trials as f64;
        let cc = cover_c as f64 / trials as f64;
        assert!(cw >= 0.90, "p={p}: wilson coverage {cw}");
        assert!(cc >= 0.93, "p={p}: exact coverage {cc}");
    }
}

#[test]
fn stratified_estimator_matches_pooled_under_proportional_allocation() {
    // When allocation is exactly proportional to the weights and the
    // per-stratum rates are equal, the stratified point estimate equals
    // the pooled one and its interval is at least as tight.
    let strata = [
        StratumSample { weight: 0.6, count: 30, n: 600 },
        StratumSample { weight: 0.3, count: 15, n: 300 },
        StratumSample { weight: 0.1, count: 5, n: 100 },
    ];
    let st = OutcomeEstimate::stratified(&strata);
    let pooled = OutcomeEstimate::pooled(50, 1_000);
    assert!((st.rate - pooled.rate).abs() < 1e-12);
    assert!(st.half_width() <= pooled.half_width() * 1.1);
    assert_eq!(st.count, 50);
    assert_eq!(st.n, 1_000);
}

#[test]
fn neyman_allocator_is_exact_deterministic_and_floor_respecting() {
    let mut rng = Xoshiro256::new(42);
    for _ in 0..200 {
        let h = 2 + (rng.below(6) as usize);
        let scores: Vec<f64> = (0..h)
            .map(|_| {
                if rng.next_f64() < 0.2 {
                    0.0
                } else {
                    rng.next_f64()
                }
            })
            .collect();
        let batch = 1 + rng.below(5_000);
        let floor = rng.below(50);
        let a = neyman_allocation(&scores, batch, floor);
        let active: Vec<usize> = (0..h).filter(|&i| scores[i] > 0.0).collect();
        if active.is_empty() {
            assert!(a.iter().all(|&x| x == 0));
            continue;
        }
        assert_eq!(
            a.iter().sum::<u64>(),
            batch,
            "allocation must be exact: scores={scores:?} batch={batch}"
        );
        for (i, &x) in a.iter().enumerate() {
            if scores[i] <= 0.0 {
                assert_eq!(x, 0, "inactive stratum {i} must get nothing");
            } else {
                let expect_floor = floor.min(batch / active.len() as u64);
                assert!(
                    x >= expect_floor,
                    "stratum {i} got {x} < floor {expect_floor}"
                );
            }
        }
        assert_eq!(a, neyman_allocation(&scores, batch, floor), "pure function");
    }
}

#[test]
fn confidence_knob_at_90_and_99_nests_around_the_default() {
    // The `--confidence` satellite: the 95 % default is pinned to the
    // exact historical constants, and the 90 / 99 % levels produce
    // strictly nested intervals for every estimator.
    assert_eq!(z_two_sided(0.95), Z95);
    assert_eq!(z_one_sided(0.95), Z95_ONE_SIDED);
    // Known normal quantiles at the satellite's levels.
    assert!((z_two_sided(0.90) - 1.6448536).abs() < 1e-5);
    assert!((z_two_sided(0.99) - 2.5758293).abs() < 1e-5);
    assert!((z_one_sided(0.90) - 1.2815516).abs() < 1e-5);
    assert!((z_one_sided(0.99) - 2.3263479).abs() < 1e-5);
    for (k, n) in [(0u64, 50u64), (3, 50), (10, 100), (250, 1_000), (999, 1_000)] {
        // Wilson nesting: 90 ⊂ 95 ⊂ 99.
        let (l90, h90) = wilson_ci_at(k, n, 0.90);
        let (l95, h95) = wilson_ci95(k, n);
        let (l99, h99) = wilson_ci_at(k, n, 0.99);
        assert!(l99 <= l95 + 1e-12 && l95 <= l90 + 1e-12, "k={k} n={n} lo");
        assert!(h90 <= h95 + 1e-12 && h95 <= h99 + 1e-12, "k={k} n={n} hi");
        // And the 95 % `_at` path is bit-identical to the legacy one.
        assert_eq!(wilson_ci_at(k, n, 0.95), wilson_ci95(k, n));
        // Clopper–Pearson nesting.
        let (cl90, ch90) = clopper_pearson_ci(k, n, 0.90);
        let (cl99, ch99) = clopper_pearson_ci(k, n, 0.99);
        let (cl95, ch95) = clopper_pearson_ci95(k, n);
        assert!(cl99 <= cl95 + 1e-12 && cl95 <= cl90 + 1e-12, "k={k} n={n} cp lo");
        assert!(ch90 <= ch95 + 1e-12 && ch95 <= ch99 + 1e-12, "k={k} n={n} cp hi");
        // One-sided exact upper bound grows with the confidence.
        let (u90, u95, u99) = (
            exact_upper(k, n, 0.90),
            exact_upper95(k, n),
            exact_upper(k, n, 0.99),
        );
        assert!(u90 <= u95 + 1e-12 && u95 <= u99 + 1e-12, "k={k} n={n} upper");
    }
    // Zero-count closed forms at 90 / 99 %: 1 − (1−conf)^{1/n}.
    for &n in &[100u64, 10_000] {
        for &conf in &[0.90f64, 0.99] {
            let want = 1.0 - (1.0 - conf).powf(1.0 / n as f64);
            assert!((exact_upper(0, n, conf) - want).abs() < 1e-12, "n={n} conf={conf}");
        }
    }
}

#[test]
fn outcome_estimates_honor_the_confidence_level() {
    // Pooled: the default constructor IS the 95 % `_at` constructor.
    assert_eq!(
        OutcomeEstimate::pooled(7, 200),
        OutcomeEstimate::pooled_at(7, 200, 0.95)
    );
    let e90 = OutcomeEstimate::pooled_at(7, 200, 0.90);
    let e99 = OutcomeEstimate::pooled_at(7, 200, 0.99);
    assert_eq!(e90.rate, e99.rate, "point estimate is confidence-free");
    assert!(e90.half_width() < e99.half_width(), "99 % must be wider");
    assert!(e90.upper95() < e99.upper95(), "one-sided bound grows too");
    assert!(e99.ci_lo <= e90.ci_lo && e90.ci_hi <= e99.ci_hi, "nesting");
    // Stratified: same contract on the weighted estimator.
    let strata = [
        StratumSample { weight: 0.8, count: 2, n: 400 },
        StratumSample { weight: 0.2, count: 9, n: 100 },
    ];
    assert_eq!(
        OutcomeEstimate::stratified(&strata),
        OutcomeEstimate::stratified_at(&strata, 0.95)
    );
    let s90 = OutcomeEstimate::stratified_at(&strata, 0.90);
    let s99 = OutcomeEstimate::stratified_at(&strata, 0.99);
    assert_eq!(s90.rate, s99.rate);
    assert!(s90.half_width() < s99.half_width());
    assert!(s99.ci_lo <= s90.ci_lo && s90.ci_hi <= s99.ci_hi);
}

#[test]
fn neyman_allocation_tracks_the_scores() {
    // Without floors the split is exactly proportional.
    let a = neyman_allocation(&[8.0, 1.0, 1.0], 1_000, 0);
    assert_eq!(a, vec![800, 100, 100]);
    // A floor hands every active stratum its guarantee first and splits
    // the remainder proportionally, so the dominant stratum gives up a
    // little to the floors but still dominates.
    let b = neyman_allocation(&[8.0, 1.0, 1.0], 1_000, 50);
    assert_eq!(b.iter().sum::<u64>(), 1_000);
    assert!(b[1] >= 50 && b[2] >= 50, "{b:?}");
    assert!(b[0] > 700, "{b:?}");
}
