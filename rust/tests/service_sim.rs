//! Simulation tests of the campaign service — the deterministic async
//! job engine — under hostile schedules (satellites of the
//! campaign-as-a-service tentpole).
//!
//! The contract under test, for *every* fault schedule the message layer
//! can produce (drops, duplicates, delays/reorders, worker crashes):
//!
//! * every submitted job terminates exactly once ([`CampaignService::run`]
//!   errors at quiescence otherwise — here we also assert the outcomes);
//! * a completed job's counts are byte-identical to the plain
//!   single-threaded [`Campaign::run`] of the same configuration — no
//!   injection is ever lost or double-counted;
//! * every terminal job releases its [`TraceCache`] pin, so the shared
//!   cache is fully drained (`trace_cache_resident == 0`);
//! * the whole run is a pure function of its seeds — replaying a seed
//!   replays every outcome, progress sample and telemetry counter.

use redmule_ft::campaign::{Campaign, CampaignConfig, CampaignResult};
use redmule_ft::golden::GemmSpec;
use redmule_ft::redmule::Protection;
use redmule_ft::service::{
    BackoffPolicy, CampaignService, JobOutcome, JobSpec, ServiceConfig, ServiceFaultPlan,
    ServiceReport,
};

/// A small, fast campaign cell (6x8x8 workload) — the service machinery
/// under test is indifferent to the cell size.
fn small_cfg(protection: Protection, injections: u64, seed: u64, adaptive: bool) -> CampaignConfig {
    let mut cfg = CampaignConfig::table1(protection, injections, seed);
    cfg.spec = GemmSpec::new(6, 8, 8);
    cfg.threads = 1;
    if adaptive {
        cfg.precision_target = 0.2;
        cfg.batch_size = (injections / 3).max(4);
    }
    cfg
}

/// The standard job mix: a fixed-budget job, an adaptive multi-batch
/// job, and a third protection — enough shape diversity to exercise
/// batch barriers, progress streaming and distinct clean-run identities.
fn job_mix() -> Vec<CampaignConfig> {
    vec![
        small_cfg(Protection::Full, 48, 0xA11CE, false),
        small_cfg(Protection::Abft, 48, 0xB0B, true),
        small_cfg(Protection::Data, 32, 0xC0DE, false),
    ]
}

/// Byte-identity over every schedule-invariant field (wall-clock time is
/// explicitly out of contract — virtual worlds have none).
fn assert_counts_match(got: &CampaignResult, want: &CampaignResult, label: &str) {
    assert_eq!(got.total, want.total, "{label}: total");
    assert_eq!(got.correct_no_retry, want.correct_no_retry, "{label}: no-retry");
    assert_eq!(got.correct_with_retry, want.correct_with_retry, "{label}: retry");
    assert_eq!(got.incorrect, want.incorrect, "{label}: incorrect");
    assert_eq!(got.timeout, want.timeout, "{label}: timeout");
    assert_eq!(got.applied, want.applied, "{label}: applied");
    assert_eq!(got.faults_applied, want.faults_applied, "{label}: faults applied");
    assert_eq!(got.corrections, want.corrections, "{label}: corrections");
    assert_eq!(got.band_recomputes, want.band_recomputes, "{label}: band recomputes");
    assert_eq!(got.batches, want.batches, "{label}: batches");
    assert_eq!(got.stopped_early, want.stopped_early, "{label}: stopped early");
    assert_eq!(got.strata.len(), want.strata.len(), "{label}: strata layout");
    for (g, w) in got.strata.iter().zip(&want.strata) {
        assert_eq!(g.n, w.n, "{label}: stratum {} n", g.name);
        assert_eq!(g.outcomes, w.outcomes, "{label}: stratum {} outcomes", g.name);
    }
}

fn completed(report: &ServiceReport, id: u64, label: &str) -> &CampaignResult {
    match &report.jobs[id as usize].outcome {
        JobOutcome::Completed(r) => r,
        other => panic!("{label}: job {id} should complete, got {other:?}"),
    }
}

#[test]
fn a_reliable_world_reproduces_the_single_threaded_engine() {
    let cfg = small_cfg(Protection::Full, 40, 0x0FF1CE, false);
    let want = Campaign::run(&cfg).unwrap();
    let mut sc = ServiceConfig::new(1);
    sc.workers = 3;
    sc.chunk_injections = 7;
    let mut svc = CampaignService::new(sc).unwrap();
    let id = svc.submit(JobSpec::new(cfg));
    let report = svc.run().unwrap();
    assert_counts_match(completed(&report, id, "reliable"), &want, "reliable");
    assert_eq!(report.trace_cache_resident, 0, "pin must be released");
    assert!(
        !report.jobs[0].progress.is_empty(),
        "batch closes must stream progress"
    );
    assert_eq!(report.telemetry.chunk_requeues, 0, "nothing fails in a reliable world");
}

/// The randomized invariant sweep: 100 sampled fault schedules (each a
/// different mixture of drops, duplicates, delays and crashes, each with
/// its own worker count and chunking), and under every one of them the
/// merged counts must equal the single-threaded engine's byte for byte,
/// with the cache drained and every job completed exactly once.
#[test]
fn randomized_fault_schedules_preserve_byte_identity() {
    let mix = job_mix();
    let expected: Vec<CampaignResult> =
        mix.iter().map(|c| Campaign::run(c).unwrap()).collect();
    for svc_seed in 0..100u64 {
        let mut sc = ServiceConfig::new(svc_seed);
        sc.workers = 1 + (svc_seed % 3) as usize;
        sc.chunk_injections = 1 + svc_seed % 19;
        sc.fault_plan = ServiceFaultPlan::sample(svc_seed);
        let mut svc = CampaignService::new(sc).unwrap();
        for cfg in &mix {
            svc.submit(JobSpec::new(cfg.clone()));
        }
        let report = svc
            .run()
            .unwrap_or_else(|e| panic!("schedule {svc_seed}: {e}"));
        assert_eq!(
            report.trace_cache_resident, 0,
            "schedule {svc_seed}: cache must drain"
        );
        assert_eq!(report.jobs.len(), mix.len());
        for (jr, want) in report.jobs.iter().zip(&expected) {
            let label = format!("schedule {svc_seed} job {}", jr.id);
            assert_counts_match(completed(&report, jr.id, &label), want, &label);
        }
    }
}

/// Worker death mid-chunk: the attempt's partial work and its `Done` are
/// lost, the supervisor requeues the chunk, and nothing is lost or
/// double-counted. With crashes as the only fault source, requeues and
/// crashes pair up exactly one-to-one.
#[test]
fn worker_death_mid_chunk_requeues_without_losing_or_double_counting() {
    let cfg = small_cfg(Protection::Full, 40, 0xDEAD, false);
    let want = Campaign::run(&cfg).unwrap();
    let mut sc = ServiceConfig::new(3);
    sc.workers = 2;
    sc.chunk_injections = 4;
    sc.fault_plan = ServiceFaultPlan {
        crash_prob: 0.5,
        worker_restart: 16,
        ..ServiceFaultPlan::none()
    };
    let mut svc = CampaignService::new(sc).unwrap();
    let id = svc.submit(JobSpec::new(cfg));
    let report = svc.run().unwrap();
    let t = &report.telemetry;
    assert!(t.worker_crashes > 0, "the plan must actually crash workers");
    assert_eq!(
        t.chunk_requeues, t.worker_crashes,
        "every crashed attempt requeues exactly once (and nothing else does)"
    );
    assert_eq!(report.jobs[0].requeues, t.chunk_requeues);
    assert_counts_match(completed(&report, id, "crashes"), &want, "crashes");
    assert_eq!(report.trace_cache_resident, 0);
}

/// Cancellation storm: immediate, mid-run, duplicate and far-future
/// cancels plus an unknown job id. Every job still terminates exactly
/// once, cancelled jobs free their cache pins, and a cancel landing
/// after completion is a no-op.
#[test]
fn cancellation_storm_terminates_exactly_once_and_drains_the_cache() {
    let mix = job_mix();
    let expected: Vec<CampaignResult> =
        mix.iter().map(|c| Campaign::run(c).unwrap()).collect();
    let mut sc = ServiceConfig::new(99);
    sc.workers = 2;
    sc.chunk_injections = 5;
    sc.fault_plan = ServiceFaultPlan::chaos();
    let mut svc = CampaignService::new(sc).unwrap();
    for cfg in &mix {
        svc.submit(JobSpec::new(cfg.clone()));
    }
    svc.cancel_at(0, 1); // before any real work
    svc.cancel_at(1, 300); // mid-run (either side of completion is legal)
    svc.cancel_at(2, 50_000_000); // far future: must land after completion
    svc.cancel_at(2, 50_000_001); // duplicate cancel: idempotent
    svc.cancel_at(99, 10); // unknown job id: ignored
    let report = svc.run().unwrap();
    assert_eq!(report.trace_cache_resident, 0, "cancelled pins must be freed too");
    assert!(
        matches!(report.jobs[0].outcome, JobOutcome::Cancelled),
        "an immediate cancel wins the race against the first chunk"
    );
    match &report.jobs[1].outcome {
        JobOutcome::Cancelled => {}
        JobOutcome::Completed(r) => assert_counts_match(r, &expected[1], "race job"),
        other => panic!("job 1: {other:?}"),
    }
    assert_counts_match(
        completed(&report, 2, "late-cancel"),
        &expected[2],
        "late-cancel",
    );
}

/// Replay: the whole run — outcomes, every progress sample, every
/// telemetry counter — is a pure function of the seeds.
#[test]
fn identical_seeds_replay_identical_runs() {
    let run_once = || {
        let mut sc = ServiceConfig::new(7);
        sc.workers = 2;
        sc.chunk_injections = 7;
        sc.fault_plan = ServiceFaultPlan::chaos();
        let mut svc = CampaignService::new(sc).unwrap();
        for cfg in job_mix() {
            svc.submit(JobSpec::new(cfg));
        }
        svc.cancel_at(1, 400);
        svc.run().unwrap()
    };
    let a = run_once();
    let b = run_once();
    let (ta, tb) = (&a.telemetry, &b.telemetry);
    assert_eq!(ta.events, tb.events);
    assert_eq!(ta.virtual_time, tb.virtual_time);
    assert_eq!(ta.msgs_sent, tb.msgs_sent);
    assert_eq!(ta.msgs_dropped, tb.msgs_dropped);
    assert_eq!(ta.msgs_duplicated, tb.msgs_duplicated);
    assert_eq!(ta.worker_crashes, tb.worker_crashes);
    assert_eq!(ta.workers_killed, tb.workers_killed);
    assert_eq!(ta.chunk_requeues, tb.chunk_requeues);
    assert_eq!(ta.stale_dones, tb.stale_dones);
    assert_eq!(ta.stale_runs, tb.stale_runs);
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.outcome.name(), jb.outcome.name(), "job {}", ja.id);
        assert_eq!(ja.requeues, jb.requeues, "job {}", ja.id);
        assert_eq!(ja.progress.len(), jb.progress.len(), "job {}", ja.id);
        for (pa, pb) in ja.progress.iter().zip(&jb.progress) {
            assert_eq!(pa.time, pb.time);
            assert_eq!(pa.total, pb.total);
            assert_eq!(pa.batches, pb.batches);
            assert_eq!(
                pa.half_width.to_bits(),
                pb.half_width.to_bits(),
                "CI stream must replay bit-exactly"
            );
        }
        if let (JobOutcome::Completed(ra), JobOutcome::Completed(rb)) = (&ja.outcome, &jb.outcome)
        {
            assert_counts_match(ra, rb, "replay");
        }
    }
}

/// With one worker, a higher-priority job submitted *later* closes its
/// first batch before an earlier low-priority submission gets a turn.
#[test]
fn priorities_order_dispatch_under_contention() {
    let mut sc = ServiceConfig::new(5);
    sc.workers = 1;
    sc.chunk_injections = 64;
    let mut svc = CampaignService::new(sc).unwrap();
    let lo = svc.submit(JobSpec::new(small_cfg(Protection::Full, 24, 1, false)).with_priority(-1));
    let hi = svc.submit(JobSpec::new(small_cfg(Protection::Data, 24, 2, false)).with_priority(5));
    let report = svc.run().unwrap();
    let first_close = |id: u64| {
        report.jobs[id as usize]
            .progress
            .first()
            .unwrap_or_else(|| panic!("job {id} has no progress"))
            .time
    };
    assert!(
        first_close(hi) < first_close(lo),
        "priority must beat submission order"
    );
    completed(&report, lo, "lo");
    completed(&report, hi, "hi");
}

/// Two jobs with one clean-run identity share the recorded trace through
/// the cross-job cache: one miss (the recording), at least one hit (the
/// adoption), identical counts, and a fully drained cache afterwards.
#[test]
fn jobs_with_one_clean_run_identity_share_the_trace_cache() {
    let cfg = small_cfg(Protection::Full, 24, 0x5EED, false);
    let mut sc = ServiceConfig::new(11);
    sc.workers = 2;
    sc.chunk_injections = 6;
    let mut svc = CampaignService::new(sc).unwrap();
    svc.submit(JobSpec::new(cfg.clone()));
    svc.submit(JobSpec::new(cfg));
    let report = svc.run().unwrap();
    assert!(
        report.telemetry.cache_hits >= 1,
        "the twin job must adopt the shared recording"
    );
    let a = completed(&report, 0, "twin a").clone();
    let b = completed(&report, 1, "twin b");
    assert_counts_match(&a, b, "twins");
    assert_eq!(report.trace_cache_resident, 0);
}

/// Property sweep over the backoff policy through the public API: the
/// exponential component is monotone and capped, the full delay is
/// replayable, bounded, and jitter decorrelates across chunks.
#[test]
fn backoff_is_bounded_exponential_with_replayable_jitter() {
    let p = BackoffPolicy {
        base: 4,
        cap: 512,
        jitter_max: 32,
    };
    for job in 0..8u64 {
        for chunk in 0..8u64 {
            let mut prev = 0u64;
            for attempt in 0..40u32 {
                let exp = p.exp_component(attempt);
                assert!(exp >= prev, "monotone at attempt {attempt}");
                assert!(exp <= p.cap, "capped at attempt {attempt}");
                prev = exp;
                let d = p.delay(1234, job, chunk, attempt);
                assert_eq!(d, p.delay(1234, job, chunk, attempt), "replayable");
                assert!(d >= exp && d <= p.cap + p.jitter_max, "bounded");
            }
        }
    }
    let distinct: std::collections::HashSet<u64> =
        (0..128u64).map(|c| p.delay(9, 0, c, 3)).collect();
    assert!(
        distinct.len() > 8,
        "jitter streams must decorrelate retry storms across chunks"
    );
    // Degenerate policies stay well-defined.
    let flat = BackoffPolicy {
        base: 0,
        cap: 1,
        jitter_max: 0,
    };
    assert_eq!(flat.delay(0, 0, 0, 63), 0);
    assert!(BackoffPolicy { base: 1, cap: 0, jitter_max: 0 }.validate().is_err());
}

/// Configuration rails: invalid service configs are rejected up front,
/// and an unknown fault profile has no name.
#[test]
fn service_configuration_rails() {
    let mut sc = ServiceConfig::new(0);
    sc.workers = 0;
    assert!(CampaignService::new(sc).is_err(), "zero workers");
    let mut sc = ServiceConfig::new(0);
    sc.chunk_injections = 0;
    assert!(CampaignService::new(sc).is_err(), "zero chunk");
    let mut sc = ServiceConfig::new(0);
    sc.fault_plan.drop_prob = 0.95;
    assert!(CampaignService::new(sc).is_err(), "certain drops never terminate");
    assert!(ServiceFaultPlan::by_name("none").is_some());
    assert!(ServiceFaultPlan::by_name("certain-doom").is_none());
    // A failing job (invalid campaign config) is terminal, frees its
    // pin, and does not poison its neighbors.
    let mut bad = small_cfg(Protection::Full, 16, 1, false);
    bad.faults_per_run = 0;
    let good = small_cfg(Protection::Data, 16, 2, false);
    let want = Campaign::run(&good).unwrap();
    let mut svc = CampaignService::new(ServiceConfig::new(4)).unwrap();
    let bad_id = svc.submit(JobSpec::new(bad));
    let good_id = svc.submit(JobSpec::new(good));
    let report = svc.run().unwrap();
    assert!(
        matches!(report.jobs[bad_id as usize].outcome, JobOutcome::Failed(_)),
        "invalid config fails terminally"
    );
    assert_counts_match(completed(&report, good_id, "neighbor"), &want, "neighbor");
    assert_eq!(report.trace_cache_resident, 0, "failed pins are freed too");
}
