//! ABFT input-staging verification — the carried PR-1 satellite.
//!
//! The writeback checksums only cover the compute/store path: an X/W
//! image corrupted *at rest in TCDM after DMA* produces a wrong result
//! whose output checksums are self-consistent, so nothing downstream
//! can catch it. `System::verify_staged_inputs` closes that window by
//! digesting the staged operand images through the accelerator's own
//! TCDM port and comparing against the host-side expectation
//! (ABFT builds compare the augmented image they actually stage).
//!
//! TCDM words carry SECDED ECC, so a *single* flipped codeword bit is
//! repaired transparently at the read port — the staging check exists
//! for what ECC cannot fix: double-bit upsets and botched DMA bursts.
//! The corruption below is therefore a double flip in one codeword.

use redmule_ft::cluster::System;
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::mesh::{Mesh, MeshConfig};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};

fn problem() -> GemmProblem {
    GemmProblem::random(&GemmSpec::new(8, 6, 5), 33)
}

/// Codeword bit offsets of the FP16 half holding element `i` of the
/// X image: 0 for even elements, 16 for odd ones.
fn half_base(x_addr: u32, i: usize) -> (u32, u32) {
    let byte = x_addr + 2 * i as u32;
    (byte, if byte & 2 != 0 { 16 } else { 0 })
}

#[test]
fn clean_staging_verifies_on_every_build() {
    let p = problem();
    for protection in [
        Protection::Baseline,
        Protection::Full,
        Protection::Abft,
        Protection::AbftOnline,
    ] {
        let mut sys = System::new(RedMuleConfig::paper(), protection);
        let layout = sys.stage(&p).unwrap();
        assert!(
            sys.verify_staged_inputs(&p, &layout),
            "clean staging must verify on {}",
            protection.name()
        );
        // The digest is a pure function of the image: re-reading cannot
        // change it (scrubbing included).
        assert_eq!(
            sys.staged_input_digest(&layout),
            sys.staged_input_digest(&layout)
        );
    }
}

#[test]
fn double_bit_staging_corruption_is_detected_and_restaged() {
    let p = problem();
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Baseline);
    let layout = sys.stage(&p).unwrap();
    let clean_digest = sys.staged_input_digest(&layout);

    // Double flip inside one staged X element's half-word: exponent MSB
    // plus a mantissa bit — uncorrectable for SECDED, so the corrupted
    // value reaches the read port.
    let (byte, base) = half_base(layout.x_addr, 1);
    sys.tcdm.flip_bit(byte, base + 14);
    sys.tcdm.flip_bit(byte, base + 5);

    assert_ne!(sys.staged_input_digest(&layout), clean_digest);
    assert!(
        !sys.verify_staged_inputs(&p, &layout),
        "double-bit corruption must fail the staging check"
    );

    // Detect → restage → re-verify, then the run is clean end to end.
    sys.restage_inputs(&p, &layout).unwrap();
    assert!(sys.verify_staged_inputs(&p, &layout));
    assert_eq!(sys.staged_input_digest(&layout), clean_digest);
    let report = sys
        .run_staged_with_fault(&layout, ExecMode::Performance, None)
        .unwrap();
    assert!(report.z_matches(&p.golden_z()));
}

#[test]
fn unverified_staging_corruption_reaches_the_result() {
    // The negative control: skip the staging check and the corrupted
    // operand flows straight into the GEMM — a functional error no
    // output-side machinery flags.
    let p = problem();
    // Pick a comfortably non-zero element so the exponent flip is a
    // guaranteed large value change.
    let i = p
        .x
        .data
        .iter()
        .position(|v| v.to_f64().abs() > 0.01)
        .expect("random X has a non-tiny element");
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Baseline);
    let layout = sys.stage(&p).unwrap();
    let (byte, base) = half_base(layout.x_addr, i);
    sys.tcdm.flip_bit(byte, base + 14);
    sys.tcdm.flip_bit(byte, base + 5);
    let report = sys
        .run_staged_with_fault(&layout, ExecMode::Performance, None)
        .unwrap();
    assert!(
        !report.z_matches(&p.golden_z()),
        "corrupted staged input must corrupt the result when unverified"
    );
}

#[test]
fn mesh_staging_verification_is_a_clean_run_noop() {
    // The mesh plumbs the check through every tile's staging (direct
    // engine): on clean images it must neither repair anything nor
    // perturb the sharded result.
    let p = problem();
    let mut cfg = MeshConfig::new(2);
    cfg.verify_staging = true;
    let r = Mesh::run_clean(&cfg, &p).unwrap();
    assert!(r.completed);
    assert_eq!(r.events.staging_repairs, 0);
    assert_eq!(r.z.bits(), p.golden_z().bits());
}
