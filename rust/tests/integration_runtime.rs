//! Integration: the PJRT runtime executing the AOT artifacts, cross-
//! checked bit-for-bit against the Rust golden model and the cycle-level
//! simulator.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).

#![cfg(feature = "pjrt")]

use redmule_ft::cluster::System;
use redmule_ft::prelude::*;
use redmule_ft::runtime::GoldenRuntime;

fn runtime() -> GoldenRuntime {
    // Tests run from the crate root; artifacts live in ./artifacts.
    GoldenRuntime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test` \
         (the Makefile `test` target does this)",
    )
}

#[test]
fn gemm_artifacts_match_rust_golden_bitwise() {
    let rt = runtime();
    let mut checked = 0;
    for name in rt.names() {
        let e = rt.entry(name).unwrap().clone();
        if e.kind != "gemm" {
            continue;
        }
        let spec = GemmSpec::new(e.params[0], e.params[1], e.params[2]);
        for seed in [1u64, 2, 3] {
            let p = GemmProblem::random(&spec, seed);
            let z = rt.execute_gemm(name, &p.x, &p.w, &p.y).unwrap();
            assert_eq!(
                z.bits(),
                p.golden_z().bits(),
                "{name} seed {seed}: PJRT != golden"
            );
        }
        checked += 1;
    }
    assert!(checked >= 3, "expected >=3 gemm artifacts, saw {checked}");
}

#[test]
fn pjrt_simulator_golden_three_way_agreement() {
    let rt = runtime();
    let spec = GemmSpec::paper_workload();
    let p = GemmProblem::random(&spec, 0xDEAD);
    let golden = p.golden_z();
    let z_pjrt = rt.execute_gemm("gemm_12x16x16", &p.x, &p.w, &p.y).unwrap();
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    let z_sim = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap().z;
    assert_eq!(z_pjrt.bits(), golden.bits());
    assert_eq!(z_sim.bits(), golden.bits());
    assert_eq!(z_pjrt.bits(), z_sim.bits());
}

#[test]
fn redundant_artifact_returns_zero_mismatch_on_clean_input() {
    let rt = runtime();
    let e = rt.entry("gemm_redundant_12x16x16").expect("artifact").clone();
    let spec = GemmSpec::new(e.params[0], e.params[1], e.params[2]);
    let p = GemmProblem::random(&spec, 9);
    let xf: Vec<f32> = p.x.data.iter().map(|v| v.to_f32()).collect();
    let wf: Vec<f32> = p.w.data.iter().map(|v| v.to_f32()).collect();
    let yf: Vec<f32> = p.y.data.iter().map(|v| v.to_f32()).collect();
    let outs = rt
        .execute_f32(
            "gemm_redundant_12x16x16",
            &[
                (&xf, &[spec.m as i64, spec.n as i64]),
                (&wf, &[spec.n as i64, spec.k as i64]),
                (&yf, &[spec.m as i64, spec.k as i64]),
            ],
        )
        .unwrap();
    // Output 0: Z; output 1: the checker's mismatch count.
    let golden = p.golden_z();
    let z_bits: Vec<u16> = outs[0]
        .iter()
        .map(|&v| redmule_ft::fp::Fp16::from_f32(v).to_bits())
        .collect();
    assert_eq!(z_bits, golden.bits());
    assert_eq!(outs[1][0], 0.0, "duplicated compute must agree");
}

#[test]
fn mlp_train_step_decreases_loss_from_rust() {
    let rt = runtime();
    let e = rt.entry("mlp_train").expect("mlp_train artifact").clone();
    let (b, i, h, c) = (e.params[0], e.params[1], e.params[2], e.params[3]);
    let mut rng = Xoshiro256::new(4);
    let mut normal = |s: f32| {
        let u1: f64 = rng.next_f64().max(1e-12);
        let u2: f64 = rng.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32 * s
    };
    let mut w1: Vec<f32> = (0..i * h).map(|_| normal(0.35)).collect();
    let mut b1 = vec![0.0f32; h];
    let mut w2: Vec<f32> = (0..h * c).map(|_| normal(0.25)).collect();
    let mut b2 = vec![0.0f32; c];

    // A fixed, linearly separable batch.
    let mut x = vec![0.0f32; b * i];
    let mut onehot = vec![0.0f32; b * c];
    for r in 0..b {
        let label = r % c;
        x[r * i + label] = 2.0;
        onehot[r * c + label] = 1.0;
    }

    let mut losses = Vec::new();
    for _ in 0..30 {
        let outs = rt
            .execute_f32(
                "mlp_train",
                &[
                    (&w1, &[i as i64, h as i64]),
                    (&b1, &[h as i64]),
                    (&w2, &[h as i64, c as i64]),
                    (&b2, &[c as i64]),
                    (&x, &[b as i64, i as i64]),
                    (&onehot, &[b as i64, c as i64]),
                ],
            )
            .unwrap();
        w1 = outs[0].clone();
        b1 = outs[1].clone();
        w2 = outs[2].clone();
        b2 = outs[3].clone();
        losses.push(outs[4][0]);
    }
    assert!(
        losses[29] < 0.5 * losses[0],
        "loss {} -> {} did not halve",
        losses[0],
        losses[29]
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn fp8_artifacts_agree_with_rust_quantizer_bit_for_bit() {
    // Cross-language check of the hybrid-FP8 path (§2.1): the artifact
    // quantizes in-graph with the JAX quantizer; we feed it inputs
    // pre-quantized by the *Rust* FP8 implementation. If the two grids or
    // rounding rules differed anywhere, re-quantization would move a
    // value and the result would diverge from the Rust golden.
    use redmule_ft::fp::Fp8Format;
    let rt = runtime();
    for (name, fmt) in [
        ("gemm_fp8_e4m3_12x16x16", Fp8Format::E4M3),
        ("gemm_fp8_e5m2_12x16x16", Fp8Format::E5M2),
    ] {
        let e = rt.entry(name).expect("fp8 artifact").clone();
        let spec = GemmSpec::new(e.params[0], e.params[1], e.params[2]);
        for seed in [4u64, 5, 6] {
            // Larger magnitudes exercise saturation too.
            let mut p = GemmProblem::random(&spec, seed);
            for v in p.x.data.iter_mut() {
                *v = redmule_ft::fp::Fp16::from_f64(v.to_f64() * 300.0);
            }
            let p = redmule_ft::golden::GemmProblem {
                spec: p.spec,
                x: p.x.quantize_fp8(fmt),
                w: p.w.quantize_fp8(fmt),
                y: p.y,
            };
            let golden = p.golden_z();
            let xf: Vec<f32> = p.x.data.iter().map(|v| v.to_f32()).collect();
            let wf: Vec<f32> = p.w.data.iter().map(|v| v.to_f32()).collect();
            let yf: Vec<f32> = p.y.data.iter().map(|v| v.to_f32()).collect();
            let outs = rt
                .execute_f32(
                    name,
                    &[
                        (&xf, &[spec.m as i64, spec.n as i64]),
                        (&wf, &[spec.n as i64, spec.k as i64]),
                        (&yf, &[spec.m as i64, spec.k as i64]),
                    ],
                )
                .unwrap();
            let z_bits: Vec<u16> = outs[0]
                .iter()
                .map(|&v| redmule_ft::fp::Fp16::from_f32(v).to_bits())
                .collect();
            assert_eq!(z_bits, golden.bits(), "{name} seed {seed}");
        }
    }
}

#[test]
fn fp8_problem_runs_on_the_simulator_bit_exactly() {
    use redmule_ft::fp::Fp8Format;
    let spec = GemmSpec::paper_workload();
    for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
        let p = GemmProblem::random_fp8(&spec, fmt, 21);
        let golden = p.golden_z();
        let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
        let r = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap();
        assert!(r.z_matches(&golden), "{fmt:?}");
    }
}

#[test]
fn artifact_shape_validation_rejects_wrong_inputs() {
    let rt = runtime();
    let p = GemmProblem::random(&GemmSpec::new(5, 5, 5), 1);
    let err = rt.execute_gemm("gemm_12x16x16", &p.x, &p.w, &p.y);
    assert!(err.is_err(), "shape mismatch must be rejected");
    assert!(rt.execute_gemm("no_such_artifact", &p.x, &p.w, &p.y).is_err());
}
