//! Integration: fault injection, detection and recovery across builds.

use redmule_ft::campaign::{classify, Campaign, CampaignConfig, Outcome};
use redmule_ft::cluster::System;
use redmule_ft::fault::site::{ce_unit, fault_unit as fu, sched_unit, streamer_unit, Module, SiteId};
use redmule_ft::fault::{FaultKind, FaultPlan, FaultRegistry};
use redmule_ft::prelude::*;
use redmule_ft::redmule::fault_unit::cause;
use redmule_ft::util::rng::{mix64, Xoshiro256};

fn paper_problem(seed: u64) -> GemmProblem {
    GemmProblem::random(&GemmSpec::paper_workload(), seed)
}

#[test]
fn full_protection_never_produces_functional_errors() {
    // Deterministic sweep without masking derate: every latched fault on
    // the fully protected build must end correct (possibly after retry).
    let cfg = RedMuleConfig::paper();
    let reg = FaultRegistry::new(cfg, Protection::Full);
    let p = paper_problem(11);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Full);
    let horizon = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap().cycles;
    for i in 0..4000u64 {
        let mut rng = Xoshiro256::new(mix64(5, i));
        let plan = reg.sample_plan(horizon, &mut rng);
        let r = sys
            .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        let o = classify(&r, &golden);
        assert!(
            !o.is_functional_error(),
            "injection {i}: {plan:?} -> {o:?} (causes {:#x})",
            r.fault_causes
        );
    }
}

#[test]
fn baseline_exhibits_silent_corruption() {
    let cfg = RedMuleConfig::paper();
    let reg = FaultRegistry::new(cfg, Protection::Baseline);
    let p = paper_problem(13);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Baseline);
    let horizon = sys.run_gemm(&p, ExecMode::Performance).unwrap().cycles;
    let mut incorrect = 0;
    for i in 0..800u64 {
        let mut rng = Xoshiro256::new(mix64(17, i));
        let plan = reg.sample_plan(horizon, &mut rng);
        let r = sys
            .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
            .unwrap();
        assert_eq!(r.retries, 0, "baseline has nothing to detect with");
        if classify(&r, &golden) == Outcome::Incorrect {
            incorrect += 1;
        }
    }
    assert!(incorrect > 50, "only {incorrect}/800 silent corruptions");
}

#[test]
fn irq_double_assert_survives_single_transient_exhaustively() {
    // §3.3: find the exact IRQ cycles for a detected fault, then corrupt
    // the wire at *each* of them in turn — the host must see the IRQ
    // through the other cycle every time.
    let cfg = RedMuleConfig::paper();
    let p = paper_problem(23);
    let golden = p.golden_z();
    let trigger = FaultPlan {
        cycle: 2,
        site: SiteId::new(Module::StreamerX, streamer_unit::ADDR_REG, 0),
        bit: 4,
        kind: FaultKind::StateUpset,
    };
    let mut sys = System::new(cfg, Protection::Full);
    let base = sys
        .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(trigger))
        .unwrap();
    assert!(base.irq_seen && base.retries == 1 && base.z_matches(&golden));

    // The abort sequence runs IRQ1 at some cycle t and IRQ2 at t+1. Find
    // t by stepping manually.
    let mut sys2 = System::new(cfg, Protection::Full);
    let layout = sys2.stage(&p).unwrap();
    sys2.program(&layout, ExecMode::FaultTolerant);
    let mut ctx = redmule_ft::fault::FaultCtx::with_plan(trigger);
    sys2.redmule.reset();
    let layout = sys2.stage(&p).unwrap();
    sys2.program(&layout, ExecMode::FaultTolerant);
    sys2.redmule.start();
    let mut irq_cycles = Vec::new();
    for _ in 0..100 {
        sys2.redmule.step(&mut sys2.tcdm, &mut ctx);
        if sys2.redmule.irq() {
            irq_cycles.push(sys2.redmule.cycle);
        }
        if irq_cycles.len() == 2 {
            break;
        }
    }
    assert_eq!(irq_cycles.len(), 2, "IRQ must assert for two cycles");
    assert_eq!(irq_cycles[1], irq_cycles[0] + 1, "consecutive cycles");

    // NB: a single injected fault per run is the campaign's contract, so
    // the wire-transient variant (trigger + wire flip) is exercised via a
    // dedicated wire-only run: a spurious 1-cycle IRQ with no detection.
    let spurious = FaultPlan {
        cycle: irq_cycles[0],
        site: SiteId::new(Module::FaultUnit, fu::IRQ_NET, 0),
        bit: 0,
        kind: FaultKind::Transient,
    };
    let mut sys3 = System::new(cfg, Protection::Full);
    let r = sys3
        .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(spurious))
        .unwrap();
    // Spurious IRQ while running: host sees it, status reads clean, run
    // completes correctly with no retry.
    assert!(r.z_matches(&golden));
    assert_eq!(r.retries, 0);
}

#[test]
fn detection_latency_is_bounded() {
    // A detected fault must reach the IRQ within the same task (no
    // unbounded deferral): run with a mid-task FMA corruption and check
    // cycles stay within 2x the clean FT run + retry.
    let cfg = RedMuleConfig::paper();
    let p = paper_problem(31);
    let mut sys = System::new(cfg, Protection::Full);
    let clean = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap().cycles;
    for cyc in [20u64, 100, 200] {
        let plan = FaultPlan {
            cycle: cyc,
            site: SiteId::new(Module::CeArray, ce_unit::FMA_NET, 9),
            bit: 7,
            kind: FaultKind::Transient,
        };
        let r = sys
            .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        assert!(
            r.cycles <= 2 * clean + 10,
            "cycle {cyc}: took {} vs clean {clean}",
            r.cycles
        );
    }
}

#[test]
fn performance_mode_on_full_build_detects_control_faults_only() {
    // §3.4: in performance mode the control redundancy stays active but
    // data-path duplication is off.
    let cfg = RedMuleConfig::paper();
    let p = paper_problem(37);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Full);

    // Control fault: streamer addr-gen upset -> detected, aborted, and
    // (control protection allows re-execution) retried.
    let ctl = FaultPlan {
        cycle: 2,
        site: SiteId::new(Module::StreamerX, streamer_unit::ADDR_REG, 0),
        bit: 3,
        kind: FaultKind::StateUpset,
    };
    let r = sys
        .run_gemm_with_fault(&p, ExecMode::Performance, Some(ctl))
        .unwrap();
    assert!(r.fault_causes & cause::STREAMER_MISMATCH != 0);
    assert!(r.z_matches(&golden));

    // Data fault: FMA corruption mid-compute -> silent in performance
    // mode (exactly the §3.4 trade).
    let mid = sys.run_gemm(&p, ExecMode::Performance).unwrap().cycles / 2;
    let mut silent = 0;
    'outer: for cyc in mid..mid + 30 {
        for idx in 0..(cfg.l * cfg.h) as u16 {
            let plan = FaultPlan {
                cycle: cyc,
                site: SiteId::new(Module::CeArray, ce_unit::FMA_NET, idx),
                bit: 9,
                kind: FaultKind::Transient,
            };
            let r = sys
                .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
                .unwrap();
            if !r.z_matches(&golden) {
                assert_eq!(r.retries, 0, "data faults are undetected in perf mode");
                silent += 1;
                break 'outer;
            }
        }
    }
    assert!(silent > 0, "some CE must be live within 30 cycles of mid-task");
}

#[test]
fn tile_level_recovery_stays_correct_and_saves_cycles() {
    // §5 future work: tile-level recovery on a multi-tile workload must
    // (a) never lose correctness across a fault sweep and (b) cost fewer
    // re-execution cycles than full restart on average.
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::new(48, 32, 48); // 8x4 FT tiles
    let p = GemmProblem::random(&spec, 71);
    let golden = p.golden_z();
    let reg = FaultRegistry::new(cfg, Protection::Full);
    let mut full = System::new(cfg, Protection::Full);
    let mut tile =
        System::new(cfg, Protection::Full).with_recovery(RecoveryPolicy::TileLevel);
    let horizon = full.run_gemm(&p, ExecMode::FaultTolerant).unwrap().cycles;

    let mut full_cycles = 0u64;
    let mut tile_cycles = 0u64;
    let mut retried = 0u32;
    for i in 0..600u64 {
        let mut rng = Xoshiro256::new(mix64(1234, i));
        let plan = reg.sample_plan(horizon, &mut rng);
        let rf = full
            .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        let rt = tile
            .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        assert!(rf.z_matches(&golden), "full restart, injection {i}");
        assert!(rt.z_matches(&golden), "tile recovery, injection {i}: {plan:?}");
        if rf.retries > 0 || rt.retries > 0 {
            retried += 1;
            full_cycles += rf.cycles;
            tile_cycles += rt.cycles;
        }
    }
    assert!(retried > 20, "sweep must exercise retries ({retried})");
    assert!(
        tile_cycles < full_cycles,
        "tile recovery must be cheaper on retried runs: {tile_cycles} vs {full_cycles}"
    );
    let saved = 100.0 * (1.0 - tile_cycles as f64 / full_cycles as f64);
    eprintln!(
        "tile-level recovery: {retried} retried runs, {saved:.1} % of retry cycles saved"
    );
}

#[test]
fn tile_recovery_resume_register_is_conservative() {
    // Inject late (last tile region) and check the resumed run redoes at
    // most the whole task (idempotence guard) and finishes correct.
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::new(24, 16, 24);
    let p = GemmProblem::random(&spec, 5);
    let golden = p.golden_z();
    let mut sys =
        System::new(cfg, Protection::Full).with_recovery(RecoveryPolicy::TileLevel);
    let clean = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap().cycles;
    let plan = FaultPlan {
        cycle: clean - 30,
        site: SiteId::with_wide_index(Module::SchedFsm, sched_unit::COUNT_REG, 1),
        bit: 0,
        kind: FaultKind::StateUpset,
    };
    let r = sys
        .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
        .unwrap();
    assert!(r.z_matches(&golden));
    if r.retries > 0 {
        // Late-fault retry must cost much less than a second full pass.
        assert!(r.cycles < clean + clean / 2, "{} vs {}", r.cycles, clean);
    }
}

#[test]
fn campaign_smoke_all_columns() {
    for prot in [
        Protection::Baseline,
        Protection::Data,
        Protection::Full,
        Protection::Abft,
    ] {
        let mut cc = CampaignConfig::table1(prot, 400, 77);
        cc.threads = 2;
        let r = Campaign::run(&cc).unwrap();
        assert_eq!(r.total, 400);
        assert_eq!(
            r.correct() + r.functional_errors(),
            r.total,
            "classification must partition"
        );
    }
}

#[test]
fn seu_persistence_vs_transient_scoping() {
    // A transient fires exactly once; an SEU persists until overwritten.
    // Verify via the regfile: a transient on a config word has no effect
    // (words are only read, the read path isn't a modelled net), while an
    // SEU triggers the parity checker on the very next cycle.
    let cfg = RedMuleConfig::paper();
    let p = paper_problem(41);
    let mut sys = System::new(cfg, Protection::Full);
    let seu = FaultPlan {
        cycle: 50,
        site: SiteId::new(
            Module::RegFile,
            redmule_ft::fault::site::regfile_unit::WORD,
            (redmule_ft::redmule::regfile::WORDS + 4) as u16, // active M
        ),
        bit: 1,
        kind: FaultKind::StateUpset,
    };
    let r = sys
        .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(seu))
        .unwrap();
    assert!(r.fault_causes & cause::REGFILE_PARITY != 0);
    assert!(r.retries >= 1);
    assert!(r.z_matches(&p.golden_z()), "host re-programs cleanly");
}
