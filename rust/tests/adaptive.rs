//! Integration: the adaptive (confidence-bounded) campaign engine —
//! early stopping on the precision target, thread-count invariance of
//! the stop point and counts, the min/max budget rails, stratified
//! allocation coverage, and fast-forward/direct equivalence of the
//! sequential engine.

use redmule_ft::campaign::{Campaign, CampaignConfig, Outcome, OUTCOMES};
use redmule_ft::prelude::*;

fn counts(r: &redmule_ft::campaign::CampaignResult) -> (u64, u64, u64, u64) {
    (r.correct_no_retry, r.correct_with_retry, r.incorrect, r.timeout)
}

fn adaptive(protection: Protection, precision: f64, threads: usize) -> CampaignConfig {
    let mut c = CampaignConfig::table1(protection, 20_000, 0xADA9);
    c.precision_target = precision;
    c.batch_size = 500;
    c.min_injections = 500;
    c.threads = threads;
    c
}

#[test]
fn acceptance_precision_campaign_stops_early_and_is_thread_invariant() {
    // The PR's acceptance criterion: `--precision 0.1` on the Table-1
    // config stops early (< max_injections) with every reported outcome
    // CI half-width <= target, counts byte-identical across 1 vs 8
    // threads.
    for protection in [Protection::Baseline, Protection::Full] {
        let r1 = Campaign::run(&adaptive(protection, 0.1, 1)).unwrap();
        let r8 = Campaign::run(&adaptive(protection, 0.1, 8)).unwrap();
        assert!(
            r1.stopped_early && r1.total < 20_000,
            "{protection:?}: must stop before the cap (ran {})",
            r1.total
        );
        for o in OUTCOMES {
            let hw = r1.estimate_of(o).half_width();
            assert!(hw <= 0.1, "{protection:?}/{o:?}: half-width {hw}");
        }
        let fe_hw = r1.functional_error_estimate().half_width();
        assert!(fe_hw <= 0.1, "{protection:?}: functional-error half-width {fe_hw}");
        assert_eq!(counts(&r1), counts(&r8), "{protection:?}");
        assert_eq!(r1.total, r8.total, "{protection:?}: same stop point");
        assert_eq!(r1.batches, r8.batches, "{protection:?}: same stop batch");
        assert_eq!(r1.stopped_early, r8.stopped_early, "{protection:?}");
        assert_eq!(r1.applied, r8.applied, "{protection:?}");
    }
}

#[test]
fn stop_lands_on_a_batch_boundary_and_respects_the_floor() {
    let r = Campaign::run(&adaptive(Protection::Data, 0.05, 2)).unwrap();
    assert!(r.stopped_early);
    assert_eq!(r.total % 500, 0, "stop must land on a batch boundary");
    assert!(r.total >= 500, "min_injections floor");
    assert_eq!(r.batches, r.total / 500);
    // A looser target stops no later.
    let loose = Campaign::run(&adaptive(Protection::Data, 0.1, 2)).unwrap();
    assert!(loose.total <= r.total, "looser target cannot run longer");
}

#[test]
fn unreachable_target_runs_to_the_cap_without_early_flag() {
    let mut c = adaptive(Protection::Baseline, 1e-6, 2);
    c.max_injections = 1_200;
    c.batch_size = 500;
    let r = Campaign::run(&c).unwrap();
    assert_eq!(r.total, 1_200, "cap is exact even when not batch-aligned");
    assert_eq!(r.batches, 3, "500 + 500 + 200");
    assert!(!r.stopped_early, "hitting the cap is not an early stop");
}

#[test]
fn min_injections_floor_defers_an_immediately_met_target() {
    // A huge target is met after the first batch; the floor must force
    // the engine past it anyway.
    let mut c = adaptive(Protection::Baseline, 0.5, 2);
    c.batch_size = 200;
    c.min_injections = 600;
    let r = Campaign::run(&c).unwrap();
    assert!(r.total >= 600, "ran only {}", r.total);
    assert!(r.stopped_early);
}

#[test]
fn adaptive_engine_matches_between_fast_forward_and_direct() {
    // The sequential engine sits on top of either execution engine; the
    // stop point and all counts must be bit-identical.
    let mut fast = adaptive(Protection::Data, 0.1, 2);
    fast.max_injections = 2_000;
    let mut direct = fast.clone();
    direct.fast_forward = false;
    let a = Campaign::run(&fast).unwrap();
    let b = Campaign::run(&direct).unwrap();
    assert_eq!(counts(&a), counts(&b));
    assert_eq!(a.total, b.total);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.stopped_early, b.stopped_early);
}

#[test]
fn stratified_campaign_covers_every_stratum_and_is_thread_invariant() {
    let mk = |threads: usize| {
        let mut c = adaptive(Protection::Data, 0.08, threads);
        c.stratify = true;
        c.batch_size = 600;
        c.min_injections = 600;
        c.max_injections = 6_000;
        c
    };
    let r1 = Campaign::run(&mk(1)).unwrap();
    let r4 = Campaign::run(&mk(4)).unwrap();
    assert_eq!(counts(&r1), counts(&r4));
    assert_eq!(r1.total, r4.total);
    assert_eq!(r1.batches, r4.batches);
    assert!(!r1.strata.is_empty());
    for (a, b) in r1.strata.iter().zip(&r4.strata) {
        assert_eq!(a.n, b.n, "per-stratum allocation must be thread-invariant");
        assert_eq!(a.outcomes, b.outcomes, "stratum {}", a.name);
    }
    // Tallies partition the campaign.
    assert_eq!(r1.strata.iter().map(|s| s.n).sum::<u64>(), r1.total);
    let per_outcome: u64 = r1.strata.iter().map(|s| s.outcomes.iter().sum::<u64>()).sum();
    assert_eq!(per_outcome, r1.total);
    // Every populated stratum was sampled — the whole point of the
    // stratified design: rare-but-critical populations are not starved.
    let registry = FaultRegistry::new(RedMuleConfig::paper(), Protection::Data);
    for (s, st) in r1.strata.iter().enumerate() {
        if registry.stratum_len(s) > 0 {
            assert!(st.n > 0, "populated stratum {} was starved", st.name);
            // The floor guarantees at least batch/(8*H) per batch.
            assert!(
                st.n >= r1.batches * (600 / (8 * 5)),
                "stratum {} fell below the allocation floor: {}",
                st.name,
                st.n
            );
        } else {
            assert_eq!(st.n, 0, "empty stratum {} was sampled", st.name);
        }
    }
    // The stratified estimator is consistent: weighted rate within the
    // pooled interval's neighborhood and every estimate well-formed.
    for o in OUTCOMES {
        let e = r1.estimate_of(o);
        assert!(e.ci_lo <= e.ci_hi);
        assert!(e.rate.is_finite() && (0.0..=1.0).contains(&e.rate));
        assert!(e.half_width() <= 0.08, "{o:?}: {}", e.half_width());
    }
}

#[test]
fn stratified_campaign_samples_rare_sites_more_than_proportionally() {
    // On the Data build the regfile + scheduler + checker strata are a
    // few percent of the area; proportional sampling would hand them a
    // few injections per batch. The stratified floor must beat that.
    let mut c = adaptive(Protection::Data, 0.05, 2);
    c.stratify = true;
    c.batch_size = 800;
    c.min_injections = 800;
    c.max_injections = 1_600;
    let r = Campaign::run(&c).unwrap();
    let registry = FaultRegistry::new(RedMuleConfig::paper(), Protection::Data);
    for s in 0..registry.n_strata() {
        let share = registry.stratum_share(s);
        if registry.stratum_len(s) == 0 || share >= 0.1 {
            continue;
        }
        let st = &r.strata[s];
        let proportional = (share * r.total as f64) as u64;
        assert!(
            st.n >= proportional,
            "rare stratum {} got {} (< proportional {})",
            st.name,
            st.n,
            proportional
        );
    }
}

#[test]
fn zero_count_outcomes_report_the_exact_upper_bound() {
    // Full protection: no functional errors; the estimate must express
    // the zero as a "< p at 95%" bound that shrinks with n.
    let mut c = CampaignConfig::table1(Protection::Full, 1_000, 77);
    c.threads = 2;
    let r = Campaign::run(&c).unwrap();
    assert_eq!(r.functional_errors(), 0);
    let fe = r.functional_error_estimate();
    assert_eq!(fe.count, 0);
    assert_eq!(fe.ci_lo, 0.0);
    let ub = fe.upper95();
    let rot = 3.0 / r.total as f64;
    assert!(
        ((ub - rot) / rot).abs() < 0.05,
        "zero-count upper bound {ub:.3e} must track 3/n {rot:.3e}"
    );
    for o in [Outcome::Incorrect, Outcome::Timeout] {
        let e = r.estimate_of(o);
        assert_eq!(e.count, 0);
        assert!(e.upper95() > 0.0 && e.upper95() < 0.01);
    }
}
