//! Property tests for the two-level executor's convergence probes and
//! fault-window sizing (satellite of the two-level tentpole).
//!
//! The engine's safety story is that a probe only ever *proves*
//! bit-identity with the reference — it never assumes it. These tests
//! attack that claim directly: tamper the instrumented trace so the
//! functional level's evidence is wrong, and require the engine to fall
//! back to cycle-accurate stepping with reports field-identical to the
//! direct engine (silent divergence is the one unacceptable outcome).
//! The window-rail tests pin the degenerate window geometries: a window
//! saturating at cycle 0, one clamped at the horizon, one covering the
//! whole run, and overlapping windows from multi-fault plans.

use redmule_ft::campaign::problem_seed;
use redmule_ft::cluster::{RefTrace, System};
use redmule_ft::fault::{FaultModel, FaultPlan, FaultRegistry};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig, TaskLayout};
use redmule_ft::tcdm::Tcdm;
use redmule_ft::util::rng::Xoshiro256;

const CFG_PROT: Protection = Protection::Full;

fn stage(problem: &GemmProblem) -> (System, TaskLayout, Tcdm) {
    let cfg = RedMuleConfig::paper();
    let mut sys = System::new(cfg, CFG_PROT);
    sys.redmule.reset();
    let layout = sys.stage(problem).unwrap();
    let pristine = sys.tcdm.clone();
    sys.tcdm.enable_dirty_tracking();
    (sys, layout, pristine)
}

fn record_tl(problem: &GemmProblem) -> RefTrace {
    let (mut sys, layout, pristine) = stage(problem);
    sys.record_reference_two_level(&layout, &pristine, ExecMode::FaultTolerant, 16)
        .unwrap()
        .expect("fault-free Full-build reference must be clean")
}

/// Field-for-field report comparison (the same contract the engine A/B
/// suites pin).
fn assert_reports_match(
    d: &redmule_ft::cluster::RunReport,
    t: &redmule_ft::cluster::RunReport,
    label: &str,
) {
    assert_eq!(d.outcome, t.outcome, "{label}: outcome");
    assert_eq!(d.cycles, t.cycles, "{label}: cycles");
    assert_eq!(d.config_cycles, t.config_cycles, "{label}: config cycles");
    assert_eq!(d.retries, t.retries, "{label}: retries");
    assert_eq!(d.fault_causes, t.fault_causes, "{label}: causes");
    assert_eq!(d.irq_seen, t.irq_seen, "{label}: irq");
    assert_eq!(d.faults_applied, t.faults_applied, "{label}: applied");
    assert_eq!(d.abft, t.abft, "{label}: abft info");
    assert_eq!(d.z.bits(), t.z.bits(), "{label}: Z bits");
}

/// Run one plan set on the direct engine and on the two-level engine
/// with the given trace, and require identical reports.
fn assert_tl_matches_direct(problem: &GemmProblem, trace: &RefTrace, plans: &[FaultPlan], label: &str) {
    let (mut sys_d, layout, pristine_d) = stage(problem);
    sys_d.tcdm.restore_from(&pristine_d);
    sys_d.redmule.reset();
    let d = sys_d
        .run_staged_with_faults(&layout, ExecMode::FaultTolerant, plans)
        .unwrap();
    let (mut sys_t, _, pristine_t) = stage(problem);
    let t = sys_t
        .run_staged_with_faults_tl(&layout, ExecMode::FaultTolerant, plans, trace, &pristine_t)
        .unwrap();
    assert_reports_match(&d, &t, label);
}

/// Tampered accelerator digests: every per-cycle digest is flipped, so
/// no mid-segment (or window-edge) probe can ever match. The engine
/// must keep stepping cycle-accurately to the natural end of the run
/// and classify it exactly like the direct engine — a probe that
/// "mostly matches" must not be accepted, and a failing probe must not
/// abort the attempt.
#[test]
fn tampered_cycle_digests_fall_back_to_cycle_accurate_stepping() {
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, problem_seed(0x71D));
    let trace = record_tl(&problem);
    let mut bad = trace.clone();
    for d in &mut bad.two_level.as_mut().unwrap().cycle_digests {
        *d = !*d;
    }
    let registry = FaultRegistry::new(RedMuleConfig::paper(), CFG_PROT);
    for i in 0..25u64 {
        let mut rng = Xoshiro256::new(0xD16 + i);
        let n = 1 + (i % 3) as usize;
        let plans = registry.sample_plans(trace.cycles, n, FaultModel::Independent, &mut rng);
        assert_tl_matches_direct(&problem, &bad, &plans, &format!("digest-tamper run {i}"));
    }
}

/// Tampered reference write logs: every recorded TCDM codeword is
/// flipped, so a probe whose accelerator digest matches will still see
/// a memory mismatch for any word the reference wrote after the restore
/// checkpoint. The probe must reject (never "correct" the state toward
/// the log) and the run must again classify identically to direct.
#[test]
fn tampered_segment_logs_fall_back_to_cycle_accurate_stepping() {
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, problem_seed(0x71D));
    let trace = record_tl(&problem);
    let mut bad = trace.clone();
    {
        let tl = bad.two_level.as_mut().unwrap();
        for seg in tl.segments.iter_mut().chain(std::iter::once(&mut tl.tail)) {
            for e in &mut seg.log {
                e.2 = !e.2;
            }
        }
    }
    let registry = FaultRegistry::new(RedMuleConfig::paper(), CFG_PROT);
    for i in 0..25u64 {
        let mut rng = Xoshiro256::new(0x5E6 + i);
        let n = 1 + (i % 3) as usize;
        let plans = registry.sample_plans(trace.cycles, n, FaultModel::Independent, &mut rng);
        assert_tl_matches_direct(&problem, &bad, &plans, &format!("log-tamper run {i}"));
    }
}

/// Window-boundary rails: pin fault cycles to the degenerate window
/// geometries and require direct-identical reports for each.
///
/// * cycle 0 — the settle margin saturates the window start at 0;
/// * the last reference cycle — the window end clamps at the horizon;
/// * first + last together — one hull window covering the entire run
///   (window ≥ horizon: the functional level never gets a probe window
///   at all);
/// * a tight multi-fault cluster — overlapping per-fault windows that
///   must merge into one hull, not probe between the strikes.
#[test]
fn window_rails_match_direct_at_the_degenerate_geometries() {
    let spec = GemmSpec::paper_workload();
    let problem = GemmProblem::random(&spec, problem_seed(0x3A11));
    let trace = record_tl(&problem);
    let registry = FaultRegistry::new(RedMuleConfig::paper(), CFG_PROT);
    let mut rng = Xoshiro256::new(0xA115);
    let sample = |rng: &mut Xoshiro256| {
        registry.sample_plans(trace.cycles, 1, FaultModel::Independent, rng)[0]
    };
    // Window start saturates at cycle 0.
    let mut p = sample(&mut rng);
    p.cycle = 0;
    assert_tl_matches_direct(&problem, &trace, &[p], "window start at 0");
    // Window end clamps at the horizon.
    let mut p = sample(&mut rng);
    p.cycle = trace.cycles - 1;
    assert_tl_matches_direct(&problem, &trace, &[p], "window end at horizon");
    // Hull covers the whole run: no functional region remains.
    let (mut a, mut b) = (sample(&mut rng), sample(&mut rng));
    a.cycle = 0;
    b.cycle = trace.cycles - 1;
    assert_tl_matches_direct(&problem, &trace, &[a, b], "window covers horizon");
    // Overlapping windows from a tight multi-fault cluster mid-run.
    let mid = trace.cycles / 2;
    let mut cluster = [sample(&mut rng), sample(&mut rng), sample(&mut rng)];
    for (i, p) in cluster.iter_mut().enumerate() {
        p.cycle = mid + 2 * i as u64;
    }
    assert_tl_matches_direct(&problem, &trace, &cluster, "overlapping windows");
}

/// The instrumented recording itself must be a strict superset of the
/// plain one: identical checkpoints, horizon and clean outcome, plus
/// well-formed instrumentation (one digest per cycle inclusive, one
/// segment per checkpoint, empty segment 0).
#[test]
fn two_level_recording_is_a_strict_superset_of_the_plain_trace() {
    let spec = GemmSpec::new(6, 8, 8);
    let problem = GemmProblem::random(&spec, problem_seed(0x50B));
    let (mut sys_a, layout, pristine_a) = stage(&problem);
    let plain = sys_a
        .record_reference(&layout, &pristine_a, ExecMode::FaultTolerant, 16)
        .unwrap()
        .expect("clean");
    let (mut sys_b, _, pristine_b) = stage(&problem);
    let tl = sys_b
        .record_reference_two_level(&layout, &pristine_b, ExecMode::FaultTolerant, 16)
        .unwrap()
        .expect("clean");
    assert!(plain.two_level.is_none());
    assert_eq!(plain.cycles, tl.cycles);
    assert_eq!(plain.config_cycles, tl.config_cycles);
    assert_eq!(plain.z.bits(), tl.z.bits());
    assert_eq!(plain.checkpoints.len(), tl.checkpoints.len());
    for (a, b) in plain.checkpoints.iter().zip(&tl.checkpoints) {
        assert_eq!(a.cycle, b.cycle);
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.tcdm_delta, b.tcdm_delta);
    }
    let inst = tl.two_level.as_ref().expect("instrumented");
    assert_eq!(inst.cycle_digests.len() as u64, tl.cycles + 1);
    assert_eq!(inst.segments.len(), tl.checkpoints.len());
    assert!(inst.segments[0].log.is_empty(), "segment 0 pairs with cp0");
    for seg in inst.segments.iter().chain(std::iter::once(&inst.tail)) {
        let mut w: Vec<u32> = seg.log.iter().map(|e| e.1).collect();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w, seg.writes, "write-set must canonicalize its log");
        assert!(seg.log.windows(2).all(|p| p[0].0 <= p[1].0), "log is cycle-ordered");
    }
}

/// A/B pin of the coalesced restore order: grouping a chunk's injections
/// by restore checkpoint and rewinding between them via the dirty-log
/// watermark (instead of a full pristine restore per injection) is a
/// pure scheduling change — every campaign count, the applied/fault
/// tallies and the batch metadata must come out byte-identical to the
/// per-injection order, across protections and multi-fault models.
#[test]
fn coalesced_two_level_campaign_counts_match_per_injection_order() {
    use redmule_ft::campaign::{Campaign, CampaignConfig};
    use redmule_ft::cluster::RecoveryPolicy;

    for (prot, model, faults) in [
        (Protection::Full, FaultModel::Independent, 1usize),
        (Protection::Abft, FaultModel::Burst, 2),
        (Protection::AbftOnline, FaultModel::Independent, 1),
    ] {
        let mut cfg = CampaignConfig::table1(prot, 240, 0xC0A1);
        cfg.threads = 1;
        cfg.two_level = true;
        cfg.faults_per_run = faults;
        cfg.fault_model = model;
        if prot == Protection::AbftOnline {
            cfg.recovery = RecoveryPolicy::InPlaceCorrect;
        }
        cfg.tl_coalesce = true;
        let a = Campaign::run(&cfg).unwrap();
        cfg.tl_coalesce = false;
        let b = Campaign::run(&cfg).unwrap();
        let label = format!("{prot:?}/{model:?}/{faults}");
        assert_eq!(a.total, b.total, "{label}: total");
        assert_eq!(a.correct_no_retry, b.correct_no_retry, "{label}: no-retry");
        assert_eq!(a.correct_with_retry, b.correct_with_retry, "{label}: retry");
        assert_eq!(a.incorrect, b.incorrect, "{label}: incorrect");
        assert_eq!(a.timeout, b.timeout, "{label}: timeout");
        assert_eq!(a.applied, b.applied, "{label}: applied");
        assert_eq!(a.faults_applied, b.faults_applied, "{label}: faults applied");
        assert_eq!(a.corrections, b.corrections, "{label}: corrections");
        assert_eq!(a.band_recomputes, b.band_recomputes, "{label}: band recomputes");
        assert_eq!(a.batches, b.batches, "{label}: batches");
        assert_eq!(a.stopped_early, b.stopped_early, "{label}: stopped early");
    }
}
