//! Integration: the cluster substrate — TCDM + ECC + interconnect + DMA —
//! working together under the accelerator.

use redmule_ft::cluster::System;
use redmule_ft::dma::{Dma, L2Mem, BYTES_PER_CYCLE, PROGRAM_CYCLES};
use redmule_ft::ecc::DecodeStatus;
use redmule_ft::prelude::*;
use redmule_ft::tcdm::{Interconnect, Tcdm};
use redmule_ft::util::rng::Xoshiro256;

#[test]
fn dma_round_trip_preserves_matrices() {
    let spec = GemmSpec::new(9, 11, 13);
    let p = GemmProblem::random(&spec, 3);
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    let layout = sys.stage(&p).unwrap();
    assert_eq!(
        sys.tcdm.read_fp16_slice(layout.x_addr, p.x.data.len()),
        p.x.data
    );
    assert_eq!(
        sys.tcdm.read_fp16_slice(layout.w_addr, p.w.data.len()),
        p.w.data
    );
    assert_eq!(
        sys.tcdm.read_fp16_slice(layout.y_addr, p.y.data.len()),
        p.y.data
    );
    // Z region zeroed.
    for v in sys.tcdm.read_fp16_slice(layout.z_addr, spec.m * spec.k) {
        assert!(v.is_zero());
    }
}

#[test]
fn memory_upsets_during_execution_are_corrected_by_ecc() {
    // Flip single bits in the staged X region before running: the SECDED
    // decoder corrects them on the fly and the result stays golden.
    let spec = GemmSpec::paper_workload();
    let p = GemmProblem::random(&spec, 7);
    let golden = p.golden_z();
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    let layout = sys.stage(&p).unwrap();
    let mut rng = Xoshiro256::new(11);
    let mut flipped = Vec::new();
    for _ in 0..10 {
        let off = (rng.below((spec.m * spec.n) as u64 / 2) * 4) as u32;
        sys.tcdm.flip_bit(layout.x_addr + off, rng.below(39) as u32);
        flipped.push(layout.x_addr + off);
    }
    sys.program(&layout, ExecMode::FaultTolerant);
    // Run manually against the pre-staged (corrupted) TCDM.
    sys.redmule.start();
    let mut ctx = redmule_ft::fault::FaultCtx::clean();
    for _ in 0..20_000 {
        sys.redmule.step(&mut sys.tcdm, &mut ctx);
        if sys.redmule.state() == redmule_ft::redmule::RunState::Done {
            break;
        }
    }
    let z = sys.read_z(&layout);
    assert_eq!(z.bits(), golden.bits(), "ECC must hide single-bit upsets");
    // The streamer-side decoders corrected on the fly without scrubbing;
    // a direct read of a flipped word still reports (and repairs) it.
    let (_, st) = sys.tcdm.read_word(flipped[0] & !3);
    assert!(
        matches!(st, DecodeStatus::Corrected(_) | DecodeStatus::Clean),
        "flipped word must be correctable"
    );
}

#[test]
fn double_bit_memory_upset_is_flagged_not_silent() {
    let mut t = Tcdm::new(4, 1024);
    t.write_word(0x40, 0xDEAD_BEEF);
    t.flip_bit(0x40, 1);
    t.flip_bit(0x40, 17);
    let (_, st) = t.read_word(0x40);
    assert_eq!(st, DecodeStatus::DoubleError);
    assert_eq!(t.counters().uncorrectable, 1);
}

#[test]
fn interconnect_arbitration_models_bank_conflicts() {
    let mut ic = Interconnect::new(8);
    // 8 accesses to 8 distinct banks: no stalls.
    let a = ic.arbitrate(&[0, 1, 2, 3, 4, 5, 6, 7]);
    assert_eq!(a.stall_cycles, 0);
    // 8 accesses to one bank: 7 extra cycles to serialize.
    let b = ic.arbitrate(&[3; 8]);
    assert_eq!(b.stall_cycles, 7);
    // A 16-element contiguous FP16 burst spans 8 words over 8 banks.
    let c = ic.arbitrate_burst(0, 8);
    assert_eq!(c.stall_cycles, 0);
}

#[test]
fn dma_cycle_accounting_matches_model() {
    let mut dma = Dma::new();
    let l2 = L2Mem::new(4096);
    let mut t = Tcdm::new(8, 4096);
    let tr = dma.copy_in(&l2, 0, &mut t, 0, 1024);
    assert_eq!(tr.cycles, PROGRAM_CYCLES + 1024 / BYTES_PER_CYCLE);
    assert_eq!(dma.total_bytes, 1024);
}

#[test]
fn tasks_at_different_bases_do_not_interfere() {
    // Two problems staged back to back; running the second must not
    // disturb the first's result already parked in TCDM.
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    let p1 = GemmProblem::random(&GemmSpec::new(8, 8, 8), 1);
    let r1 = sys.run_gemm(&p1, ExecMode::FaultTolerant).unwrap();
    assert!(r1.z_matches(&p1.golden_z()));
    let p2 = GemmProblem::random(&GemmSpec::new(12, 16, 16), 2);
    let r2 = sys.run_gemm(&p2, ExecMode::FaultTolerant).unwrap();
    assert!(r2.z_matches(&p2.golden_z()));
}

#[test]
fn scrubbing_repairs_memory_on_read() {
    let mut t = Tcdm::cluster_default();
    t.write_word(0x100, 0x1234_5678);
    t.flip_bit(0x100, 5);
    let (v1, s1) = t.read_word(0x100);
    assert_eq!(v1, 0x1234_5678);
    assert!(matches!(s1, DecodeStatus::Corrected(_)));
    // After write-back scrubbing the stored codeword is clean again.
    let (v2, s2) = t.read_word(0x100);
    assert_eq!(v2, 0x1234_5678);
    assert_eq!(s2, DecodeStatus::Clean);
}

#[test]
fn ecc_storage_expansion_is_modelled() {
    // 39/32 expansion: the raw codeword has the check bits above bit 31.
    let mut t = Tcdm::new(4, 256);
    t.write_word(8, 0xFFFF_FFFF);
    let cw = t.raw_codeword(8);
    assert!(cw < (1 << 39), "codeword is 39 bits");
    // Interleaved Hamming layout: the stored word is not the plain data...
    assert_ne!(cw, 0xFFFF_FFFFu64);
    // ...but decodes back to it cleanly.
    let (d, st) = redmule_ft::ecc::decode32(cw);
    assert_eq!(d, 0xFFFF_FFFF);
    assert_eq!(st, DecodeStatus::Clean);
}
