//! Property-based tests (hand-rolled: proptest is not vendored offline,
//! so each property runs against a deterministic seeded sweep — shrinkage
//! is traded for exact reproducibility; the failing seed is printed).

use redmule_ft::campaign::classify;
use redmule_ft::cluster::System;
use redmule_ft::ecc::{config_parity, decode32, encode32, weight_parity, weight_parity_ok, DecodeStatus};
use redmule_ft::fault::FaultRegistry;
use redmule_ft::fp::{add16, fma16, mul16, Fp16};
use redmule_ft::fp::fma::fma16_via_f64;
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::prelude::*;
use redmule_ft::redmule::scheduler::{Dims, Scheduler};
use redmule_ft::util::rng::{mix64, Xoshiro256};

const CASES: u64 = 300;

fn rng_for(case: u64, salt: u64) -> Xoshiro256 {
    Xoshiro256::new(mix64(case, salt))
}

/// Property: the two independent FMA implementations agree on every
/// random input triple, including specials.
#[test]
fn prop_fma_integer_path_equals_f64_path() {
    for case in 0..20_000u64 {
        let mut rng = rng_for(case, 1);
        let a = Fp16::from_bits(rng.next_u32() as u16);
        let b = Fp16::from_bits(rng.next_u32() as u16);
        let c = Fp16::from_bits(rng.next_u32() as u16);
        let x = fma16(a, b, c);
        let y = fma16_via_f64(a, b, c);
        // NaNs: compare NaN-ness, not payload.
        if x.is_nan() || y.is_nan() {
            assert_eq!(x.is_nan(), y.is_nan(), "case {case}");
        } else {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: {a:?}*{b:?}+{c:?}");
        }
    }
}

/// Property: mul/add are consistent with fma (b*c = fma(b,c,±0); the
/// hardware decomposes the same way).
#[test]
fn prop_mul_add_consistent_with_fma() {
    for case in 0..5_000u64 {
        let mut rng = rng_for(case, 2);
        let a = rng.next_fp16_in(100.0);
        let b = rng.next_fp16_in(100.0);
        assert_eq!(mul16(a, b).to_bits(), fma16(a, b, Fp16::ZERO).to_bits());
        let s1 = add16(a, b);
        let s2 = fma16(a, Fp16::ONE, b);
        assert_eq!(s1.to_bits(), s2.to_bits(), "case {case}");
    }
}

/// Property: SECDED corrects every 1-bit error and flags every 2-bit
/// error, for random data words and random error positions.
#[test]
fn prop_secded_single_correct_double_detect() {
    for case in 0..2_000u64 {
        let mut rng = rng_for(case, 3);
        let data = rng.next_u32();
        let cw = encode32(data);
        let b1 = rng.below(39) as u32;
        let (d1, s1) = decode32(cw ^ (1 << b1));
        assert_eq!(d1, data, "case {case}");
        assert!(matches!(s1, DecodeStatus::Corrected(_)));
        let b2 = {
            let mut b = rng.below(39) as u32;
            while b == b1 {
                b = rng.below(39) as u32;
            }
            b
        };
        let (_, s2) = decode32(cw ^ (1 << b1) ^ (1 << b2));
        assert_eq!(s2, DecodeStatus::DoubleError, "case {case} bits {b1},{b2}");
    }
}

/// Property: weight parity detects every single-bit flip of value or
/// parity; config parity likewise.
#[test]
fn prop_parity_detects_single_flips() {
    for case in 0..2_000u64 {
        let mut rng = rng_for(case, 4);
        let w = Fp16::from_bits(rng.next_u32() as u16);
        let p = weight_parity(w);
        assert!(weight_parity_ok(w, p));
        let bit = rng.below(16) as u16;
        assert!(!weight_parity_ok(Fp16::from_bits(w.to_bits() ^ (1 << bit)), p));
        let cfg = rng.next_u32();
        assert_ne!(config_parity(cfg), config_parity(cfg ^ (1 << rng.below(32))));
    }
}

/// Property: simulator == golden for random shapes, seeds, geometries
/// and modes.
#[test]
fn prop_simulator_matches_golden() {
    for case in 0..CASES {
        let mut rng = rng_for(case, 5);
        let m = 1 + rng.below(20) as usize;
        let n = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(20) as usize;
        let spec = GemmSpec::new(m, n, k);
        let p = GemmProblem::random(&spec, mix64(case, 6));
        let (prot, mode) = match rng.below(3) {
            0 => (Protection::Baseline, ExecMode::Performance),
            1 => (Protection::Data, ExecMode::FaultTolerant),
            _ => (Protection::Full, ExecMode::FaultTolerant),
        };
        let mut sys = System::new(RedMuleConfig::paper(), prot);
        let r = sys.run_gemm(&p, mode).unwrap();
        assert!(
            r.z_matches(&p.golden_z()),
            "case {case}: ({m},{n},{k}) {prot:?} {mode:?}"
        );
    }
}

/// Property: `Scheduler::nominal_cycles` equals the walked cycle count
/// for random dims, and FT mode costs 1x..2.5x performance mode.
#[test]
fn prop_scheduler_closed_form_matches_walk() {
    for case in 0..CASES {
        let mut rng = rng_for(case, 7);
        let d = 12;
        let dims = Dims {
            m: 1 + rng.below(40) as u32,
            n: 1 + rng.below(64) as u32,
            k: 1 + rng.below(40) as u32,
            rows_per_tile: [12u32, 6][rng.below(2) as usize],
            d,
            h: 4,
        };
        let mut s = Scheduler::idle();
        s.start();
        let mut walked = 0u64;
        while s.advance(&dims) {
            walked += 1;
            assert!(walked < 10_000_000, "case {case}: non-terminating");
        }
        walked += 1; // the final advance that returned false consumed a cycle
        assert_eq!(walked, Scheduler::nominal_cycles(&dims), "case {case} {dims:?}");
    }
}

/// Property: classification is total and consistent — correct ⊕ error.
#[test]
fn prop_classification_partitions_outcomes() {
    use redmule_ft::fault::FaultKind;
    let cfg = RedMuleConfig::paper();
    let reg = FaultRegistry::new(cfg, Protection::Data);
    let spec = GemmSpec::paper_workload();
    let p = GemmProblem::random(&spec, 0xAB);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Data);
    let horizon = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap().cycles;
    for case in 0..CASES {
        let mut rng = rng_for(case, 8);
        let plan = reg.sample_plan(horizon, &mut rng);
        assert!(matches!(plan.kind, FaultKind::Transient | FaultKind::StateUpset));
        let r = sys
            .run_gemm_with_fault(&p, ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        let o = classify(&r, &golden);
        assert_eq!(
            o.is_functional_error(),
            !r.z_matches(&golden)
                || matches!(
                    r.outcome,
                    redmule_ft::cluster::HostOutcome::TimedOut
                        | redmule_ft::cluster::HostOutcome::Abandoned
                ),
            "case {case}: {o:?} vs {:?}",
            r.outcome
        );
    }
}

/// Property: registry weights are positive, finite, and the sampled
/// module distribution respects the area shares (chi-square-ish bound).
#[test]
fn prop_registry_sampling_unbiased() {
    let reg = FaultRegistry::new(RedMuleConfig::paper(), Protection::Full);
    let total = reg.total_weight();
    let mut rng = Xoshiro256::new(0xFEED);
    let n = 60_000;
    let mut by_module = std::collections::HashMap::new();
    for _ in 0..n {
        let e = reg.sample_entry(&mut rng);
        *by_module.entry(e.site.module()).or_insert(0u64) += 1;
    }
    for (module, count) in by_module {
        let weight: f64 = reg
            .entries()
            .iter()
            .filter(|e| e.site.module() == module)
            .map(|e| e.weight)
            .sum();
        let expect = weight / total;
        let got = count as f64 / n as f64;
        assert!(
            (got - expect).abs() < 0.02 + expect * 0.2,
            "{module:?}: got {got:.4}, expect {expect:.4}"
        );
    }
}

/// Property: area model is monotone in L, H, P and protection level.
#[test]
fn prop_area_monotonicity() {
    use redmule_ft::area::area_report;
    for case in 0..100u64 {
        let mut rng = rng_for(case, 9);
        let l = 2 * (1 + rng.below(12) as usize);
        let h = 1 + rng.below(8) as usize;
        let p = 1 + rng.below(4) as usize;
        let cfg = RedMuleConfig::new(l, h, p);
        let base = area_report(cfg, Protection::Baseline).total_kge();
        let data = area_report(cfg, Protection::Data).total_kge();
        let full = area_report(cfg, Protection::Full).total_kge();
        assert!(base < data && data < full, "case {case} ({l},{h},{p})");
        let bigger = area_report(RedMuleConfig::new(l + 2, h, p), Protection::Baseline).total_kge();
        assert!(bigger > base, "case {case}: more rows, more area");
    }
}

/// Property: FP8 widening/narrowing is exact and idempotent for every
/// 8-bit pattern in both formats (exhaustive).
#[test]
fn prop_fp8_exhaustive_round_trip() {
    use redmule_ft::fp::{Fp8, Fp8Format};
    for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
        for bits in 0..=u8::MAX {
            let v8 = Fp8::new(bits, fmt);
            let wide = v8.to_fp16();
            if v8.is_nan() {
                assert!(wide.is_nan(), "{fmt:?} {bits:#04x}");
                continue;
            }
            if v8.is_infinite() {
                // E5M2 infinity widens to FP16 infinity but *saturating*
                // re-narrowing clamps to the max finite — by design.
                assert!(wide.is_infinite(), "{fmt:?} {bits:#04x}");
                continue;
            }
            // Widening then re-narrowing returns a value that widens to
            // the same FP16 (the grid is a fixed point of quantization).
            let renarrow = Fp8::from_fp16(wide, fmt, true);
            assert_eq!(
                renarrow.to_fp16().to_bits(),
                wide.to_bits(),
                "{fmt:?} {bits:#04x}"
            );
        }
    }
}

/// Property: quantization never increases magnitude error beyond half a
/// grid step, and saturates at the format maximum.
#[test]
fn prop_fp8_quantization_error_bounded() {
    use redmule_ft::fp::{Fp8, Fp8Format};
    for (fmt, max) in [(Fp8Format::E4M3, 448.0), (Fp8Format::E5M2, 57344.0)] {
        let mut rng = Xoshiro256::new(0xF8);
        for _ in 0..5_000 {
            let v = (rng.next_f64() * 2.0 - 1.0) * max * 1.2;
            let q = Fp8::from_f64(v, fmt, true).to_fp16().to_f64();
            assert!(q.abs() <= max, "{fmt:?}: {v} -> {q}");
            if v.abs() <= max {
                // Relative error within one part in 2^m (plus subnormal floor).
                let m = if fmt == Fp8Format::E4M3 { 8.0 } else { 4.0 };
                let tol = v.abs() / m + 0.02;
                assert!((q - v).abs() <= tol, "{fmt:?}: {v} -> {q}");
            }
        }
    }
}

/// Property: the PerCe build's campaign sits strictly between baseline
/// and data protection on functional errors.
#[test]
fn prop_perce_build_is_intermediate() {
    use redmule_ft::campaign::{Campaign, CampaignConfig};
    let n = 4_000;
    let run = |p| {
        let mut c = CampaignConfig::table1(p, n, 33);
        c.threads = 1;
        Campaign::run(&c).unwrap()
    };
    let base = run(Protection::Baseline);
    let perce = run(Protection::PerCe);
    let data = run(Protection::Data);
    assert!(
        perce.functional_errors() < base.functional_errors(),
        "per-CE {} !< baseline {}",
        perce.functional_errors(),
        base.functional_errors()
    );
    assert!(
        data.functional_errors() < perce.functional_errors(),
        "data {} !< per-CE {}",
        data.functional_errors(),
        perce.functional_errors()
    );
    assert!(perce.correct_with_retry > 0, "per-CE checkers must retry");
}

/// Property: FP16 round-trip through f64 and f32 is lossless for every
/// representable value (exhaustive, including specials).
#[test]
fn prop_fp16_conversions_exhaustive() {
    for bits in 0..=u16::MAX {
        let v = Fp16::from_bits(bits);
        if v.is_nan() {
            assert!(Fp16::from_f64(v.to_f64()).is_nan());
            continue;
        }
        assert_eq!(Fp16::from_f64(v.to_f64()).to_bits(), bits);
        assert_eq!(Fp16::from_f32(v.to_f32()).to_bits(), bits);
    }
}
