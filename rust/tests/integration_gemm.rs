//! Integration: the cycle-level simulator against the bit-exact golden
//! model over a broad shape/mode/protection matrix.

use redmule_ft::cluster::{HostOutcome, System};
use redmule_ft::golden::{gemm_golden, GemmProblem, GemmSpec, Mat};
use redmule_ft::prelude::*;
use redmule_ft::util::rng::Xoshiro256;

fn check(cfg: RedMuleConfig, prot: Protection, mode: ExecMode, spec: GemmSpec, seed: u64) {
    let p = GemmProblem::random(&spec, seed);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, prot);
    let r = sys.run_gemm(&p, mode).expect("run");
    assert_eq!(r.outcome, HostOutcome::Completed, "{spec:?} {prot:?} {mode:?}");
    assert!(
        r.z_matches(&golden),
        "bit mismatch: {spec:?} {prot:?} {mode:?} seed {seed}"
    );
}

#[test]
fn shape_matrix_all_protections_and_modes() {
    let cfg = RedMuleConfig::paper();
    let shapes = [
        (1, 1, 1),
        (12, 16, 16),
        (16, 16, 16),
        (12, 12, 12),
        (24, 32, 24),
        (7, 5, 9),
        (13, 33, 29),
        (1, 64, 1),
        (48, 16, 48),
        (3, 100, 3),
    ];
    for &(m, n, k) in &shapes {
        let spec = GemmSpec::new(m, n, k);
        check(cfg, Protection::Baseline, ExecMode::Performance, spec, 1);
        check(cfg, Protection::Data, ExecMode::Performance, spec, 2);
        check(cfg, Protection::Data, ExecMode::FaultTolerant, spec, 3);
        check(cfg, Protection::Full, ExecMode::Performance, spec, 4);
        check(cfg, Protection::Full, ExecMode::FaultTolerant, spec, 5);
    }
}

#[test]
fn nonstandard_array_geometries() {
    // The simulator is parametric in (L, H, P) like the RTL.
    for (l, h, p) in [(2, 1, 1), (4, 2, 2), (8, 4, 1), (12, 4, 3), (16, 8, 2), (6, 3, 4)] {
        let cfg = RedMuleConfig::new(l, h, p);
        let spec = GemmSpec::new(11, 13, 17);
        check(cfg, Protection::Full, ExecMode::FaultTolerant, spec, 7);
        check(cfg, Protection::Baseline, ExecMode::Performance, spec, 8);
    }
}

#[test]
fn many_seeds_paper_workload() {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::paper_workload();
    for seed in 0..25 {
        check(cfg, Protection::Full, ExecMode::FaultTolerant, spec, seed);
    }
}

#[test]
fn sequential_tasks_reuse_the_same_system() {
    // State from one task must not leak into the next.
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    for seed in 0..8 {
        let spec = GemmSpec::new(6 + (seed as usize % 8), 10 + (seed as usize), 9);
        let p = GemmProblem::random(&spec, seed);
        let mode = if seed % 2 == 0 {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        let r = sys.run_gemm(&p, mode).unwrap();
        assert!(r.z_matches(&p.golden_z()), "task {seed} corrupted");
    }
}

#[test]
fn golden_model_matches_hand_computed_case() {
    // Z = Y + X·W on a case small enough to verify by hand:
    // X = [[1, 2]], W = [[3], [4]], Y = [[0.5]] -> 1*3 + 2*4 + 0.5 = 11.5
    let x = Mat::from_f64_slice(1, 2, &[1.0, 2.0]);
    let w = Mat::from_f64_slice(2, 1, &[3.0, 4.0]);
    let y = Mat::from_f64_slice(1, 1, &[0.5]);
    let z = gemm_golden(&x, &w, &y);
    assert_eq!(z.at(0, 0).to_f64(), 11.5);
}

#[test]
fn ft_and_perf_mode_agree_bitwise() {
    // The two modes must produce identical bits (same accumulation order,
    // the FT mode just duplicates work).
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::new(20, 24, 20);
    let p = GemmProblem::random(&spec, 99);
    let mut sys = System::new(cfg, Protection::Full);
    let a = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap();
    let b = sys.run_gemm(&p, ExecMode::Performance).unwrap();
    assert_eq!(a.z.bits(), b.z.bits());
}

#[test]
fn extreme_values_survive_the_pipeline() {
    // Large magnitudes (overflow to inf) must match golden bit-for-bit.
    let spec = GemmSpec::new(4, 32, 4);
    let mut rng = Xoshiro256::new(5);
    let mut p = GemmProblem::random(&spec, 5);
    for v in p.x.data.iter_mut() {
        *v = rng.next_fp16_in(1000.0);
    }
    for v in p.w.data.iter_mut() {
        *v = rng.next_fp16_in(1000.0);
    }
    let golden = p.golden_z();
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
    let r = sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap();
    assert!(r.z_matches(&golden));
}
