//! Mesh determinism + recovery acceptance suite.
//!
//! The mesh contract has three legs, all pinned here:
//!
//! 1. **Sharding is exact.** Row-band sharding leaves every output
//!    element's FMA chain intact, so a clean mesh result is
//!    *bit-identical* to the single-`System` path for any tile count,
//!    any tile scheduling order and any tile execution engine — and a
//!    1-tile mesh is byte-identical to the existing engine matrix.
//! 2. **The NoC is a real fault domain.** Without the mesh recovery
//!    stack, link flips / lost / duplicated / reordered result messages
//!    and tile crashes produce functional errors; with link CRC +
//!    reduction-tree ABFT + tile retirement enabled a ≥4-tile mesh
//!    under the chaos profile completes with **zero** functional
//!    errors, every event attributed to a `mesh/noc-*` stratum.
//! 3. **The default path is untouched.** The single-tile fault-site
//!    registry gains no strata, and default sweep documents carry no
//!    mesh fields (asserted in `campaign::sweep`'s own tests).

use redmule_ft::fault::{N_STRATA, STRATUM_NAMES};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::mesh::{
    Mesh, MeshCampaign, MeshCampaignConfig, MeshConfig, MeshFaultProfile, NocRegistry,
    NOC_STRATUM_NAMES,
};
use redmule_ft::prelude::TileEngine;
use redmule_ft::redmule::Protection;
use redmule_ft::util::rng::Xoshiro256;

/// A shape small enough for direct-engine tiles but uneven enough
/// (m not divisible by typical tile counts) to exercise ragged bands.
fn spec() -> GemmSpec {
    GemmSpec::new(14, 6, 5)
}

fn problem(seed: u64) -> GemmProblem {
    GemmProblem::random(&spec(), seed)
}

#[test]
fn one_tile_mesh_matches_the_single_system_path_across_the_engine_matrix() {
    let p = problem(42);
    for protection in [
        Protection::Baseline,
        Protection::Data,
        Protection::Full,
        Protection::Abft,
    ] {
        // The single-System reference result, run in the exact mode the
        // mesh derives for this build.
        let mut cfg1 = MeshConfig::new(1);
        cfg1.protection = protection;
        let mut sys = redmule_ft::cluster::System::new(
            redmule_ft::redmule::RedMuleConfig::paper(),
            protection,
        );
        let reference = sys.run_gemm(&p, cfg1.mode()).unwrap();
        for engine in TileEngine::ALL {
            let mut cfg = cfg1.clone();
            cfg.engine = engine;
            let r = Mesh::run_clean(&cfg, &p).unwrap();
            assert!(r.completed);
            assert_eq!(
                r.z.bits(),
                reference.z.bits(),
                "1-tile mesh diverged from System on {} / {}",
                protection.name(),
                engine.name()
            );
        }
    }
}

#[test]
fn sharded_result_is_tile_count_and_shard_count_invariant() {
    let p = problem(7);
    let golden = p.golden_z();
    let mut digests = Vec::new();
    for tiles in [1usize, 2, 3, 4, 5, 7] {
        let mut cfg = MeshConfig::new(tiles);
        cfg.engine = TileEngine::FastForward;
        let r = Mesh::run_clean(&cfg, &p).unwrap();
        assert_eq!(r.z.bits(), golden.bits(), "tiles={tiles}");
        digests.push(r.z_digest());
    }
    assert!(digests.windows(2).all(|w| w[0] == w[1]));
    // Explicit shard-count overrides cannot change a bit either.
    for shards in [1usize, 3, 5, 14] {
        let mut cfg = MeshConfig::new(3);
        cfg.engine = TileEngine::FastForward;
        cfg.shards = shards;
        let r = Mesh::run_clean(&cfg, &p).unwrap();
        assert_eq!(r.z.bits(), golden.bits(), "shards={shards}");
    }
}

#[test]
fn tile_scheduling_order_cannot_change_the_report() {
    // Same faulted run under every compute-order permutation of a
    // 3-tile mesh: the fault fates key on canonical message identity,
    // not scheduling, so z, events and cycles are all identical.
    let p = problem(12);
    let base = MeshConfig {
        engine: TileEngine::FastForward,
        ..MeshConfig::new(3)
    };
    let shards = base.shard_count(spec().m);
    let mut shards_of = vec![0u64; 3];
    for s in 0..shards {
        shards_of[s % 3] += 1;
    }
    let registry = NocRegistry::new(3, shards_of);
    let mut rng = Xoshiro256::new(99);
    let plan = registry.sample(&mut rng, 0, MeshFaultProfile::Chaos);
    assert!(!plan.is_empty());
    let orders: [Vec<usize>; 4] =
        [vec![], vec![0, 1, 2], vec![2, 1, 0], vec![1, 2, 0]];
    let reference = Mesh::run(&base, &p, &plan).unwrap();
    for order in orders {
        let mut cfg = base.clone();
        cfg.tile_order = order.clone();
        let r = Mesh::run(&cfg, &p, &plan).unwrap();
        assert_eq!(r.z.bits(), reference.z.bits(), "order {order:?}");
        assert_eq!(r.events, reference.events, "order {order:?}");
        assert_eq!(r.cycles, reference.cycles, "order {order:?}");
        assert_eq!(r.shard_map, reference.shard_map, "order {order:?}");
    }
}

#[test]
fn mesh_campaign_json_is_thread_invariant() {
    let mut mc = MeshCampaignConfig::new(4, 24, 2026);
    mc.spec = spec();
    mc.mesh.engine = TileEngine::FastForward;
    mc.threads = 1;
    let a = MeshCampaign::run(&mc).unwrap();
    mc.threads = 8;
    let b = MeshCampaign::run(&mc).unwrap();
    assert_eq!(a.to_json(), b.to_json());
    assert_eq!(a.total, 24);
}

/// Protected meshes must absorb every single-kind profile; the
/// unprotected transport must demonstrably NOT (otherwise the fault
/// domain is cosmetic).
#[test]
fn transport_profiles_are_harmful_unprotected_and_harmless_protected() {
    for profile in [
        MeshFaultProfile::Flip,
        MeshFaultProfile::Drop,
        MeshFaultProfile::Dup,
        MeshFaultProfile::Reorder,
        MeshFaultProfile::Crash,
        MeshFaultProfile::Mixed,
    ] {
        let mut mc = MeshCampaignConfig::new(3, 16, 7);
        mc.spec = spec();
        mc.mesh.engine = TileEngine::FastForward;
        mc.profile = profile;
        let protected = MeshCampaign::run(&mc).unwrap();
        assert_eq!(
            protected.functional_errors(),
            0,
            "protected mesh failed under {}",
            profile.name()
        );
        assert!(protected.applied_runs > 0, "{} never applied", profile.name());
    }
    // Unprotected: each harmful profile must produce at least one
    // functional error over the same budget.
    for profile in [
        MeshFaultProfile::Flip,
        MeshFaultProfile::Drop,
        MeshFaultProfile::Dup,
        MeshFaultProfile::Crash,
    ] {
        let mut mc = MeshCampaignConfig::new(3, 16, 7);
        mc.spec = spec();
        mc.mesh = MeshConfig::unprotected(3);
        mc.mesh.engine = TileEngine::FastForward;
        mc.profile = profile;
        let bare = MeshCampaign::run(&mc).unwrap();
        assert!(
            bare.functional_errors() > 0,
            "unprotected mesh shrugged off {}",
            profile.name()
        );
    }
}

/// The ISSUE acceptance scenario: a ≥4-tile mesh under the chaos
/// profile (flip + drop + dup + delay + one tile crash per injection)
/// with the full recovery stack completes every run with zero
/// functional errors, and the report attributes detected/corrected
/// events to the `mesh/noc-*` strata.
#[test]
fn chaos_profile_acceptance_on_a_four_tile_mesh() {
    let mut mc = MeshCampaignConfig::new(4, 32, 2025);
    mc.spec = GemmSpec::new(16, 6, 5);
    mc.mesh.engine = TileEngine::FastForward;
    let r = MeshCampaign::run(&mc).unwrap();
    assert_eq!(r.total, 32);
    assert_eq!(r.functional_errors(), 0, "chaos must be fully absorbed");
    assert_eq!(r.applied_runs, 32, "chaos applies faults on every run");
    assert!(r.events.detected() > 0 && r.events.corrected() > 0);
    assert_eq!(r.strata.len(), NOC_STRATUM_NAMES.len());
    for (st, name) in r.strata.iter().zip(NOC_STRATUM_NAMES) {
        assert_eq!(st.name, name);
        assert!(
            st.applied > 0,
            "chaos covers every stratum, {name} saw nothing"
        );
        assert_eq!(st.functional_errors, 0);
    }
    // Tile crashes were detected and survivors picked up the shards.
    assert!(r.events.tiles_retired > 0);
    assert!(r.events.shards_reassigned > 0);
}

#[test]
fn crash_retirement_is_what_saves_the_run() {
    let mut mc = MeshCampaignConfig::new(4, 16, 5);
    mc.spec = spec();
    mc.mesh.engine = TileEngine::FastForward;
    mc.profile = MeshFaultProfile::Crash;
    let with = MeshCampaign::run(&mc).unwrap();
    assert_eq!(with.functional_errors(), 0);
    assert!(with.events.tiles_retired > 0);
    assert!(with.events.shards_reassigned > 0);
    // Same plans, retirement off: crashed tiles' shards never arrive.
    mc.mesh.tile_retirement = false;
    let without = MeshCampaign::run(&mc).unwrap();
    assert!(
        without.timeout > 0,
        "without retirement a crash must surface as a timeout"
    );
}

#[test]
fn single_tile_fault_registry_is_untouched_by_the_mesh() {
    // The mesh NoC strata live in their own registry; the datapath
    // fault-site population the four-mode default path samples from
    // must not gain (or rename) a stratum.
    assert_eq!(N_STRATA, 5);
    assert!(STRATUM_NAMES.iter().all(|n| !n.starts_with("mesh/")));
    for name in NOC_STRATUM_NAMES {
        assert!(
            !STRATUM_NAMES.contains(&name),
            "NoC stratum {name} leaked into the datapath registry"
        );
    }
}
