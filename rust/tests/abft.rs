//! Integration + property tests for the ABFT checksum protection mode:
//! golden-layer encode/verify, the hosted verify-locate-recompute flow,
//! and the checksum unit's own fault sites.
//!
//! Property tests follow the repo convention (hand-rolled seeded sweeps;
//! proptest is not vendored offline): every case derives from a seed via
//! `Xoshiro256`, so failures reproduce exactly.

use redmule_ft::cluster::{HostOutcome, RecoveryPolicy, System};
use redmule_ft::fault::site::{checker_unit, streamer_unit, Module, SiteId};
use redmule_ft::fault::{FaultKind, FaultPlan};
use redmule_ft::golden::{split_abft_z, Mat};
use redmule_ft::prelude::*;
use redmule_ft::redmule::fault_unit::cause;
use redmule_ft::util::rng::{mix64, Xoshiro256};

// ------------------------------------------------------- golden layer

/// Property: exact checksum encode/verify round-trips cleanly on random
/// matrices of random shapes.
#[test]
fn prop_checksum_encode_verify_round_trip() {
    for case in 0..60u64 {
        let mut rng = Xoshiro256::new(mix64(case, 0xE7C0));
        let m = 1 + rng.below(16) as usize;
        let k = 1 + rng.below(16) as usize;
        let mat = Mat::random(m, k, 1.0, &mut rng);
        let chk = mat.abft_checksums();
        let mm = mat.abft_verify(&chk);
        assert!(mm.is_clean(), "case {case}: ({m},{k}) {mm:?}");
    }
}

/// Property: every single-bit flip of every element of a Z image is
/// detected AND located by the exact checksums — including sign flips of
/// zeros and flips into NaN/Inf space.
#[test]
fn prop_every_single_bit_flip_detected_and_located() {
    for case in 0..6u64 {
        let mut rng = Xoshiro256::new(mix64(case, 0x10CA7E));
        let m = 2 + rng.below(7) as usize;
        let k = 2 + rng.below(7) as usize;
        let mut mat = Mat::random(m, k, 1.0, &mut rng);
        if case == 0 {
            // Force the value-preserving corner: a +0 whose sign flip
            // only the bit-pattern checksum can see.
            mat.set(0, 0, redmule_ft::fp::Fp16::ZERO);
        }
        let chk = mat.abft_checksums();
        for i in 0..m {
            for j in 0..k {
                for b in 0..16u16 {
                    let orig = mat.at(i, j);
                    mat.set(i, j, redmule_ft::fp::Fp16::from_bits(orig.to_bits() ^ (1 << b)));
                    let mm = mat.abft_verify(&chk);
                    assert_eq!(
                        mm.located(),
                        Some((i, j)),
                        "case {case}: flip bit {b} of ({i},{j}) -> {mm:?}"
                    );
                    mat.set(i, j, orig);
                }
            }
        }
        assert!(mat.abft_verify(&chk).is_clean(), "case {case}: restore");
    }
}

// ----------------------------------------------------- hosted fault-free

/// Property: a fault-free ABFT run is bit-exact and adds zero retries —
/// the carried checksums always verify within the rounding tolerance,
/// across shapes, seeds, recovery policies and requested modes.
#[test]
fn prop_fault_free_abft_adds_zero_retries() {
    let shapes = [
        (12, 16, 16),
        (5, 7, 3),
        (13, 17, 19),
        (24, 33, 17),
        (12, 64, 48),
        (1, 1, 1),
        (3, 25, 3),
        (48, 16, 25),
    ];
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Abft);
    let mut sys_tile =
        System::new(RedMuleConfig::paper(), Protection::Abft).with_recovery(RecoveryPolicy::TileLevel);
    for (si, &(m, n, k)) in shapes.iter().enumerate() {
        for seed in 0..4u64 {
            let p = GemmProblem::random(&GemmSpec::new(m, n, k), 1000 * si as u64 + seed);
            let golden = p.golden_z();
            let check = |r: &redmule_ft::cluster::RunReport| {
                assert_eq!(r.outcome, HostOutcome::Completed, "({m},{n},{k}) seed {seed}");
                assert_eq!(r.retries, 0, "({m},{n},{k}) seed {seed}: spurious retry");
                assert_eq!(r.z.bits(), golden.bits(), "({m},{n},{k}) seed {seed}");
                let info = r.abft.expect("abft build must report bookkeeping");
                assert_eq!(info.detections, 0, "({m},{n},{k}) seed {seed}");
            };
            check(&sys.run_gemm(&p, ExecMode::Performance).unwrap());
            check(&sys_tile.run_gemm(&p, ExecMode::Performance).unwrap());
            // An FT-mode request degrades to performance mode (no
            // replication hardware) but the checksum layer still verifies.
            check(&sys.run_gemm(&p, ExecMode::FaultTolerant).unwrap());
        }
    }
}

// --------------------------------------------------- detection + recovery

/// A store-path transient that corrupts a committed Z element by an
/// exponent-MSB flip must be caught by the writeback verification and
/// repaired; when the corruption lands in a data row it is located and
/// fixed by recomputing only that row band. Sweeps every cycle of the
/// workload (lanes 0..4), so every store phase is exercised.
#[test]
fn store_corruption_is_detected_located_and_band_recovered() {
    let cfg = RedMuleConfig::paper();
    let p = GemmProblem::random(&GemmSpec::paper_workload(), 1);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Abft).with_recovery(RecoveryPolicy::TileLevel);
    let clean = sys.run_gemm(&p, ExecMode::Performance).unwrap().cycles;

    let (mut detected, mut band_recovered) = (0u32, 0u32);
    for cycle in 1..=clean {
        for lane in 0..4u16 {
            let plan = FaultPlan {
                cycle,
                site: SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, lane),
                bit: 14, // exponent MSB: the corruption is orders of magnitude
                kind: FaultKind::Transient,
            };
            let r = sys
                .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
                .unwrap();
            if r.retries == 0 {
                continue; // net idle this cycle (masked), or below tolerance
            }
            // Every recovered run must end bit-exact with the cause latched.
            assert_eq!(r.outcome, HostOutcome::CompletedAfterRetry, "cycle {cycle}");
            assert!(
                r.z_matches(&golden),
                "cycle {cycle} lane {lane}: recovery must restore the result"
            );
            assert!(r.fault_causes & cause::ABFT_CHECKSUM != 0, "cause bit must latch");
            let info = r.abft.unwrap();
            detected += 1;
            if info.band_recomputes >= 1 {
                band_recovered += 1;
            }
        }
    }
    assert!(detected > 10, "store phases must be live and detectable ({detected})");
    assert!(
        band_recovered * 2 > detected,
        "data-row corruptions dominate and must be band-recovered \
         ({band_recovered}/{detected})"
    );
}

/// An SEU in the checksum unit's own accumulator bank must cause a
/// spurious detection (fail-safe direction), one recovery pass, and a
/// bit-exact final result.
#[test]
fn checksum_unit_seu_causes_spurious_retry_not_corruption() {
    let cfg = RedMuleConfig::paper();
    let p = GemmProblem::random(&GemmSpec::paper_workload(), 2);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::Abft).with_recovery(RecoveryPolicy::TileLevel);
    let clean = sys.run_gemm(&p, ExecMode::Performance).unwrap().cycles;

    let plan = FaultPlan {
        cycle: clean / 2,
        site: SiteId::new(Module::Checker, checker_unit::ABFT_ACC_REG, 0),
        bit: 45, // 2^21 in value terms: far outside any tolerance
        kind: FaultKind::StateUpset,
    };
    let r = sys
        .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
        .unwrap();
    assert!(r.fault_applied(), "the accumulator is live for the whole run");
    assert_eq!(r.outcome, HostOutcome::CompletedAfterRetry);
    assert_eq!(r.retries, 1, "one recovery pass clears the upset");
    assert!(r.z_matches(&golden));
    let info = r.abft.unwrap();
    assert_eq!(info.detections, 1);
    assert_eq!(info.band_recomputes, 1, "row 0 is located and recomputed");
}

// ------------------------------------------- online in-place correction

/// The online build's headline property: a post-checker store-net
/// transient corrupts exactly one committed Z element, the fused store
/// residuals locate it as the row/column intersection, and the host
/// rewrites it in place from the bit-plane residual — zero retries, zero
/// recomputed cycles, bit-exact result. Sweeps every cycle × lanes 0..4
/// of the post-checker segment so every store phase is exercised.
#[test]
fn online_abft_corrects_single_store_corruption_in_place() {
    let cfg = RedMuleConfig::paper();
    let p = GemmProblem::random(&GemmSpec::paper_workload(), 3);
    let golden = p.golden_z();
    let mut sys = System::new(cfg, Protection::AbftOnline)
        .with_recovery(RecoveryPolicy::InPlaceCorrect);
    let clean = sys.run_gemm(&p, ExecMode::Performance).unwrap().cycles;

    let mut corrected = 0u32;
    for cycle in 1..=clean {
        for lane in 0..4u16 {
            let plan = FaultPlan {
                cycle,
                // 32.. is the post-checker segment: the fault lands
                // between the accumulator read (the online unit's `pre`
                // tap) and the TCDM commit, so pre != stored and the
                // residual pins the element exactly.
                site: SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 32 + lane),
                bit: 14,
                kind: FaultKind::Transient,
            };
            let r = sys
                .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
                .unwrap();
            if r.outcome == HostOutcome::Completed {
                continue; // net idle this cycle (masked)
            }
            let info = r.abft.unwrap();
            assert_eq!(
                r.outcome,
                HostOutcome::CompletedAfterRetry,
                "cycle {cycle} lane {lane}"
            );
            assert_eq!(
                r.retries, 0,
                "cycle {cycle} lane {lane}: in-place correction must not re-execute"
            );
            assert!(
                info.corrections >= 1,
                "cycle {cycle} lane {lane}: the residual intersection must correct"
            );
            assert_eq!(
                info.band_recomputes, 0,
                "cycle {cycle} lane {lane}: a single corruption needs no recompute"
            );
            assert!(r.fault_causes & cause::ABFT_CHECKSUM != 0, "cause bit must latch");
            assert!(
                r.z_matches(&golden),
                "cycle {cycle} lane {lane}: correction must be bit-exact"
            );
            assert_eq!(
                r.cycles, clean,
                "cycle {cycle} lane {lane}: zero recomputed cycles"
            );
            corrected += 1;
        }
    }
    assert!(corrected > 10, "store phases must be live and correctable ({corrected})");
}

/// Two elements corrupted in the same cycle (adjacent post-checker
/// lanes) produce a residual pattern the locator cannot pin to one
/// intersection: the online build must refuse to guess and fall back to
/// the detect-only row-band recompute — and still end bit-exact.
#[test]
fn online_abft_multi_error_residuals_fall_back_to_band_recompute() {
    let cfg = RedMuleConfig::paper();
    let p = GemmProblem::random(&GemmSpec::paper_workload(), 4);
    let golden = p.golden_z();
    let probe = System::new(cfg, Protection::AbftOnline)
        .with_recovery(RecoveryPolicy::InPlaceCorrect)
        .run_gemm(&p, ExecMode::Performance)
        .unwrap()
        .cycles;
    let mut sys = System::new(cfg, Protection::AbftOnline)
        .with_recovery(RecoveryPolicy::InPlaceCorrect);
    sys.redmule.reset();
    let layout = sys.stage(&p).unwrap();
    let pristine = sys.tcdm.clone();

    let (mut corrected, mut fell_back) = (0u32, 0u32);
    for cycle in 1..=probe {
        let plans = [
            FaultPlan {
                cycle,
                site: SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 32),
                bit: 14,
                kind: FaultKind::Transient,
            },
            FaultPlan {
                cycle,
                site: SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 33),
                bit: 14,
                kind: FaultKind::Transient,
            },
        ];
        sys.tcdm.restore_from(&pristine);
        sys.redmule.reset();
        let r = sys
            .run_staged_with_faults(&layout, ExecMode::Performance, &plans)
            .unwrap();
        if r.outcome == HostOutcome::Completed {
            continue; // both nets idle this cycle
        }
        assert_eq!(r.outcome, HostOutcome::CompletedAfterRetry, "cycle {cycle}");
        assert!(r.z_matches(&golden), "cycle {cycle}: recovery must restore");
        let info = r.abft.unwrap();
        if info.corrections >= 1 && info.band_recomputes == 0 {
            corrected += 1; // only one of the two lanes was live
        } else {
            assert!(
                info.band_recomputes >= 1,
                "cycle {cycle}: two-element residuals must band-recompute"
            );
            fell_back += 1;
        }
    }
    assert!(fell_back > 5, "double corruptions must hit the fallback ({fell_back})");
    assert!(corrected > 0, "single-live-lane cycles still correct in place");
}

/// Selective row-band recovery must cost less than a full restart for
/// the same detected corruption on a many-tile workload.
#[test]
fn band_recovery_is_cheaper_than_full_restart() {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::new(48, 32, 48);
    let p = GemmProblem::random(&spec, 606);
    let golden = p.golden_z();
    let mut full = System::new(cfg, Protection::Abft).with_recovery(RecoveryPolicy::FullRestart);
    let mut tile = System::new(cfg, Protection::Abft).with_recovery(RecoveryPolicy::TileLevel);
    let clean = full.run_gemm(&p, ExecMode::Performance).unwrap().cycles;

    // Store-path corruptions across the whole run: the two policies see
    // identical detections (verification is policy-independent); compare
    // retry cost whenever the corruption lands in a locatable data row.
    let mut compared = 0u32;
    for cycle in (1..=clean).step_by(3) {
        let plan = FaultPlan {
            cycle,
            site: SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 0),
            bit: 14,
            kind: FaultKind::Transient,
        };
        let rf = full
            .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
            .unwrap();
        let rt = tile
            .run_gemm_with_fault(&p, ExecMode::Performance, Some(plan))
            .unwrap();
        assert_eq!(rf.retries > 0, rt.retries > 0, "cycle {cycle}: same detection");
        if rf.retries == 0 {
            continue;
        }
        assert!(rf.z_matches(&golden), "cycle {cycle}: full restart result");
        assert!(rt.z_matches(&golden), "cycle {cycle}: band recovery result");
        if rt.abft.unwrap().band_recomputes >= 1 {
            assert!(
                rt.cycles < rf.cycles,
                "cycle {cycle}: band recompute {} must beat full restart {} (clean {})",
                rt.cycles,
                rf.cycles,
                clean
            );
            compared += 1;
        }
    }
    assert!(compared > 3, "band recoveries must be exercised ({compared})");
}

/// The carried checksum tiles ride through the same pipeline: the staged
/// augmented task in TCDM must decode back to the original matrices plus
/// FP16 checksum vectors, and the result region splits cleanly.
#[test]
fn staged_abft_task_layout_is_augmented() {
    let spec = GemmSpec::new(7, 5, 9);
    let p = GemmProblem::random(&spec, 11);
    let mut sys = System::new(RedMuleConfig::paper(), Protection::Abft);
    let layout = sys.stage(&p).unwrap();
    assert_eq!((layout.m, layout.n, layout.k), (8, 5, 10));
    // X data rows + checksum row (= FP16 column sums of X).
    let x = sys.tcdm.read_fp16_slice(layout.x_addr, 8 * 5);
    assert_eq!(&x[..7 * 5], &p.x.data[..]);
    assert_eq!(&x[7 * 5..], &p.x.col_sums_fp16()[..]);
    // W data columns + checksum column (= FP16 row sums of W).
    let w = sys.tcdm.read_fp16_slice(layout.w_addr, 5 * 10);
    let w_sums = p.w.row_sums_fp16();
    for i in 0..5 {
        assert_eq!(&w[i * 10..i * 10 + 9], &p.w.data[i * 9..(i + 1) * 9]);
        assert_eq!(w[i * 10 + 9], w_sums[i]);
    }
    // Run and split: data region == golden.
    let r = sys.run_gemm(&p, ExecMode::Performance).unwrap();
    assert!(r.z_matches(&p.golden_z()));
    let z_aug = sys.read_z(&layout);
    let (data, carried_rows, carried_cols) = split_abft_z(&z_aug);
    assert_eq!(data.bits(), p.golden_z().bits());
    assert_eq!(carried_rows.len(), 8);
    assert_eq!(carried_cols.len(), 9);
}
