//! Integration: the mixed-criticality coordinator over the full system.

use redmule_ft::coordinator::{Coordinator, Criticality};
use redmule_ft::prelude::*;

fn mixed_problems(n: usize, seed: u64) -> Vec<(Criticality, GemmProblem)> {
    (0..n)
        .map(|i| {
            let crit = if i % 3 == 0 {
                Criticality::Critical
            } else {
                Criticality::BestEffort
            };
            let spec = GemmSpec::new(4 + i % 9, 8 + i % 17, 6 + i % 11);
            (crit, GemmProblem::random(&spec, seed + i as u64))
        })
        .collect()
}

#[test]
fn large_mixed_queue_all_golden() {
    let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
    let tasks = mixed_problems(30, 100);
    for (crit, p) in &tasks {
        c.submit(*crit, p.clone());
    }
    let done = c.run_to_idle().unwrap();
    assert_eq!(done, 30);
    assert_eq!(c.results().len(), 30);
    for r in c.results() {
        let golden = tasks[r.id as usize].1.golden_z();
        assert_eq!(r.z.bits(), golden.bits(), "task {}", r.id);
        assert_eq!(r.retries, 0, "clean runs never retry");
    }
}

#[test]
fn results_preserve_submission_ids_in_completion_order() {
    let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Data);
    let tasks = mixed_problems(10, 55);
    let mut ids = Vec::new();
    for (crit, p) in &tasks {
        ids.push(c.submit(*crit, p.clone()));
    }
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    c.run_to_idle().unwrap();
    let completed: Vec<u64> = c.results().iter().map(|r| r.id).collect();
    assert_eq!(completed, ids, "FIFO queue completes in order");
}

#[test]
fn cycle_accounting_is_consistent() {
    let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
    let tasks = mixed_problems(12, 200);
    for (crit, p) in &tasks {
        c.submit(*crit, p.clone());
    }
    c.run_to_idle().unwrap();
    let m = &c.metrics;
    let sum: u64 = c.results().iter().map(|r| r.cycles).sum();
    assert_eq!(m.critical_cycles + m.best_effort_cycles, sum);
    // Every task paid the 120-cycle parity programming on the Full build.
    assert_eq!(m.config_cycles, 12 * 120);
    assert_eq!(m.submitted, 12);
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
}

#[test]
fn throughput_ratio_between_classes_is_about_2x() {
    // Same-shape tasks in both classes isolate the mode cost.
    let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
    let spec = GemmSpec::new(12, 48, 36);
    for i in 0..8 {
        let crit = if i < 4 {
            Criticality::Critical
        } else {
            Criticality::BestEffort
        };
        c.submit(crit, GemmProblem::random(&spec, 300 + i));
    }
    c.run_to_idle().unwrap();
    let avg = |crit: Criticality| {
        let v: Vec<u64> = c
            .results()
            .iter()
            .filter(|r| r.criticality == crit)
            .map(|r| r.cycles)
            .collect();
        v.iter().sum::<u64>() as f64 / v.len() as f64
    };
    let ratio = avg(Criticality::Critical) / avg(Criticality::BestEffort);
    assert!((1.7..=2.3).contains(&ratio), "ratio {ratio:.2}");
}

#[test]
fn baseline_build_serves_best_effort_only() {
    let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Baseline);
    let p = GemmProblem::random(&GemmSpec::new(8, 8, 8), 1);
    c.submit(Criticality::BestEffort, p.clone());
    c.run_to_idle().unwrap();
    assert_eq!(c.metrics.completed, 1);

    c.submit(Criticality::Critical, p);
    assert!(c.step().is_err(), "critical tasks need protection hardware");
}
