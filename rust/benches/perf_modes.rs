//! Bench: the §4.1/§3.4 performance claims.
//!
//! * fault-tolerant mode costs ≈2× performance mode (same workload);
//! * the register-file parity programming is a ≤120-cycle one-time cost;
//! * retry cost at the measured ~12 % detection rate stays manageable;
//! * the critical path is untouched — both modes run at the same
//!   (modelled) 500 MHz, so cycles translate directly to time.
//!
//! ```text
//! cargo bench --bench perf_modes
//! ```

use redmule_ft::cluster::CONFIG_PARITY_CYCLES;
use redmule_ft::golden::GemmSpec;
use redmule_ft::perf::{
    analytic_cycles, measured_cycles, mode_report, retry_expected_overhead, throughput, FREQ_MHZ,
};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};

fn main() {
    let cfg = RedMuleConfig::paper();
    println!(
        "perf_modes — RedMulE-FT L={} H={} P={} @ {} MHz (modelled)\n",
        cfg.l, cfg.h, cfg.p, FREQ_MHZ
    );

    println!(
        "{:<16} {:>10} {:>10} {:>8} {:>9} {:>9} {:>10}",
        "workload", "perf cyc", "ft cyc", "slow", "perf util", "ft util", "perf GFLOPS"
    );
    let workloads = [
        GemmSpec::paper_workload(),
        GemmSpec::new(12, 64, 48),
        GemmSpec::new(24, 96, 96),
        GemmSpec::new(48, 96, 96),
        GemmSpec::new(12, 256, 12),
        GemmSpec::new(96, 192, 96),
    ];
    for spec in workloads {
        let r = mode_report(cfg, Protection::Full, spec).expect("report");
        let tp = throughput(cfg, spec, r.perf_cycles);
        println!(
            "{:<16} {:>10} {:>10} {:>7.2}x {:>8.1} % {:>8.1} % {:>10.2}",
            format!("({},{},{})", spec.m, spec.n, spec.k),
            r.perf_cycles,
            r.ft_cycles,
            r.slowdown,
            100.0 * r.perf_util,
            100.0 * r.ft_util,
            tp.gflops
        );
        // Analytic model must agree exactly with the stepped simulator.
        assert_eq!(
            r.perf_cycles,
            analytic_cycles(cfg, spec, ExecMode::Performance)
        );
        assert_eq!(
            r.ft_cycles,
            analytic_cycles(cfg, spec, ExecMode::FaultTolerant)
        );
    }

    // Large-workload slowdown must approach the paper's 2x claim.
    let big = mode_report(cfg, Protection::Full, GemmSpec::new(96, 192, 96)).unwrap();
    assert!(
        (1.85..=2.15).contains(&big.slowdown),
        "steady-state FT slowdown {:.2} != ~2x",
        big.slowdown
    );

    // Configuration overhead (§3.2: "one-time increase of 120 cycles").
    println!(
        "\nconfig programming: {} cycles on protected builds (paper bound: 120)",
        CONFIG_PARITY_CYCLES
    );
    assert!(CONFIG_PARITY_CYCLES <= 120);

    // Retry economics at the measured detection rate.
    let ft = measured_cycles(cfg, Protection::Full, GemmSpec::paper_workload(), ExecMode::FaultTolerant)
        .unwrap();
    for p_retry in [0.05, 0.12, 0.25] {
        let ovh = retry_expected_overhead(ft, p_retry);
        println!(
            "expected retry overhead at {:>4.0} % detection: {:>6.1} cycles/workload ({:.1} % of FT runtime)",
            p_retry * 100.0,
            ovh,
            100.0 * ovh / ft as f64
        );
    }
    let at_measured = retry_expected_overhead(ft, 0.12);
    assert!(
        at_measured < 0.25 * ft as f64,
        "retry overhead must stay manageable (paper §4.1)"
    );

    // §5 future work, implemented: tile-level recovery vs full restart.
    // Measured over a fault sweep on a 32-tile FT workload.
    use redmule_ft::cluster::{RecoveryPolicy, System};
    use redmule_ft::fault::FaultRegistry;
    use redmule_ft::golden::GemmProblem;
    use redmule_ft::util::rng::{mix64, Xoshiro256};
    let spec = GemmSpec::new(48, 32, 48);
    let p = GemmProblem::random(&spec, 71);
    let reg = FaultRegistry::new(cfg, Protection::Full);
    let mut full_sys = System::new(cfg, Protection::Full);
    let mut tile_sys = System::new(cfg, Protection::Full).with_recovery(RecoveryPolicy::TileLevel);
    let horizon = full_sys
        .run_gemm(&p, redmule_ft::redmule::ExecMode::FaultTolerant)
        .unwrap()
        .cycles;
    let (mut fr, mut tr, mut n_retried) = (0u64, 0u64, 0u64);
    for i in 0..400u64 {
        let mut rng = Xoshiro256::new(mix64(4242, i));
        let plan = reg.sample_plan(horizon, &mut rng);
        let a = full_sys
            .run_gemm_with_fault(&p, redmule_ft::redmule::ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        let b = tile_sys
            .run_gemm_with_fault(&p, redmule_ft::redmule::ExecMode::FaultTolerant, Some(plan))
            .unwrap();
        if a.retries > 0 || b.retries > 0 {
            n_retried += 1;
            fr += a.cycles;
            tr += b.cycles;
        }
    }
    println!(
        "\ntile-level recovery (§5 future work, implemented): over {n_retried} retried runs of a 32-tile workload"
    );
    println!(
        "  full-restart retry cost {fr} cycles, tile-level {tr} cycles -> {:.1} % saved",
        100.0 * (1.0 - tr as f64 / fr as f64)
    );
    assert!(tr < fr, "tile recovery must save cycles");

    println!("\nperf_modes OK");
}
