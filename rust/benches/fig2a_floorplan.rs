//! Bench: regenerate **Figure 2a** — the PULP cluster floorplan with the
//! fully protected RedMulE-FT inside the published 1400 µm × 850 µm
//! GF12LP+ block, as ASCII art with a per-block area legend.
//!
//! ```text
//! cargo bench --bench fig2a_floorplan
//! ```

use redmule_ft::area::floorplan::{cluster_blocks, place, render, DIE_H_UM, DIE_W_UM};
use redmule_ft::redmule::{Protection, RedMuleConfig};

fn main() {
    let cfg = RedMuleConfig::paper();
    let (mut blocks, redmule) = cluster_blocks(cfg, Protection::Full);
    place(&mut blocks);
    println!("{}", render(&blocks));

    let total: f64 = blocks.iter().map(|b| b.area_um2).sum();
    let die = DIE_W_UM * DIE_H_UM;
    println!(
        "cluster inventory: {:.2} mm2 of logic+SRAM in a {:.2} mm2 outline ({:.0} % fill)",
        total / 1e6,
        die / 1e6,
        100.0 * total / die
    );
    println!(
        "RedMulE-FT (full protection): {:.0} kGE = {:.0} um2 ({:.1} % of the die)",
        redmule.total_kge(),
        blocks
            .iter()
            .find(|b| b.tag == 'R')
            .map(|b| b.area_um2)
            .unwrap_or(0.0),
        100.0
            * blocks
                .iter()
                .find(|b| b.tag == 'R')
                .map(|b| b.area_um2)
                .unwrap_or(0.0)
            / die
    );

    // Pass criteria: placement legal, fill plausible.
    for b in &blocks {
        let (x, y, w, h) = b.rect;
        assert!(x >= -1e-6 && y >= -1e-6 && x + w <= DIE_W_UM + 1e-6 && y + h <= DIE_H_UM + 1e-6);
    }
    assert!((0.5..=1.5).contains(&(total / die)));
    println!("\nfig2a OK");
}
