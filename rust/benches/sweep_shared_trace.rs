//! Self-checking bench: the shared-trace / zero-copy / work-stealing
//! sweep engine vs. the legacy uncached per-cell path, on the default
//! grid. Asserts two things and exits non-zero otherwise:
//!
//! 1. **equivalence** — the `redmule-ft/sweep-v2` JSON (and the legacy
//!    v1 document) are **byte-identical** between the two engines: the
//!    trace cache and the grid-wide scheduler change wall-clock only,
//!    never a count, interval or stop point;
//! 2. **speedup** — the fast engine's end-to-end wall-clock beats the
//!    legacy path by at least `--min-speedup` (default 1.5×, the PR-5
//!    acceptance bar — the saved reference recordings, the zero-copy
//!    hot loop and the stolen cell tails each contribute).
//!
//! Emits the fast run's timing sidecar (schema
//! `redmule-ft/bench-sweep-v1`, incl. the trace-cache hit/miss
//! counters) to `--out` (default `BENCH_sweep.json`) so the sweep
//! throughput trajectory is machine-readable across PRs.
//!
//! ```text
//! cargo bench --bench sweep_shared_trace \
//!     [-- --injections N] [-- --threads T] [-- --out PATH]
//!     [-- --min-speedup X]
//! ```

use redmule_ft::campaign::{Sweep, SweepConfig};

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a.as_str() == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let injections: u64 = arg("--injections")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_500);
    let threads: usize = arg("--threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_sweep.json".to_string());
    // Wall-clock gate; loosen on noisy shared runners without losing the
    // (always-on) byte-equivalence assertion.
    let min_speedup: f64 = arg("--min-speedup")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.5);
    let seed = 2025u64;

    let mut base = SweepConfig::new(injections, seed);
    base.threads = threads;
    println!(
        "sweep_shared_trace — default grid ({} cells), {injections} injections/cell, \
         {threads} threads\n",
        base.n_cells()
    );

    // Legacy engine: per-cell reference recordings, per-cell pools.
    let mut legacy_cfg = base.clone();
    legacy_cfg.trace_cache = false;
    legacy_cfg.work_stealing = false;
    let legacy = Sweep::run(&legacy_cfg).expect("legacy sweep");

    // Fast engine (the defaults): shared trace cache + grid stealing.
    let fast = Sweep::run(&base).expect("shared-trace sweep");

    // ---- equivalence: the deterministic documents must be identical.
    assert_eq!(
        legacy.to_json_v2(),
        fast.to_json_v2(),
        "sweep-v2 JSON must be byte-identical between the legacy and the \
         shared-trace/work-stealing engines"
    );
    assert_eq!(
        legacy.to_json(false),
        fast.to_json(false),
        "sweep-v1 JSON must be byte-identical between the engines"
    );

    let (hits, misses) = fast
        .trace_cache_stats
        .expect("fast engine runs with the cache on");
    println!(
        "reference traces: legacy recorded {}, fast recorded {misses} (+{hits} adopted)",
        legacy.cells.len()
    );
    println!(
        "legacy   {:>8.2} s   {:>8.0} runs/s",
        legacy.wall_seconds,
        legacy.runs_per_sec()
    );
    println!(
        "fast     {:>8.2} s   {:>8.0} runs/s",
        fast.wall_seconds,
        fast.runs_per_sec()
    );
    let speedup = legacy.wall_seconds / fast.wall_seconds.max(1e-9);
    println!("\nend-to-end speedup: {speedup:.2}x");

    // Machine-readable trajectory record (standard bench-sweep sidecar).
    std::fs::write(&out_path, fast.timing_json()).expect("write BENCH_sweep.json");
    println!("wrote {out_path}");

    assert!(
        misses < legacy.cells.len() as u64,
        "the cache must eliminate at least one reference recording on the \
         default grid ({misses} recorded for {} cells)",
        legacy.cells.len()
    );
    assert!(
        speedup >= min_speedup,
        "shared-trace engine must deliver >= {min_speedup}x end-to-end sweep \
         speedup, got {speedup:.2}x"
    );
    println!("sweep_shared_trace OK");
}
