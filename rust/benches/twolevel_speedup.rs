//! Self-checking bench: two-level executor vs. the fast-forward engine
//! on long-horizon workloads (`Campaign::run`, table1 configuration,
//! single thread). Asserts two things and exits non-zero otherwise:
//!
//! 1. **equivalence** — every column's outcome counts are bit-identical
//!    between the two engines, and
//! 2. **speedup** — the aggregate end-to-end speedup is ≥ 3× (the
//!    tentpole acceptance bar; pass `--min-speedup` to loosen it on
//!    noisy shared runners without losing the equivalence assertion).
//!
//! Long horizons are where the two level earns its keep: the
//! fast-forward engine still steps cycle-accurately from the restored
//! checkpoint to the *next checkpoint boundary* before its first
//! convergence probe, while the two-level engine probes mid-segment as
//! soon as the fault window's settling margin has elapsed — on a
//! multi-thousand-cycle run that skips most of the stepped tail of
//! every converging injection.
//!
//! Emits `BENCH_twolevel.json` (schema `redmule-ft/bench-twolevel-v1`)
//! with runs/sec per column for both engines.
//!
//! ```text
//! cargo bench --bench twolevel_speedup \
//!     [-- --injections N] [-- --out PATH] [-- --min-speedup X]
//! ```

use redmule_ft::campaign::{Campaign, CampaignConfig, CampaignResult};
use redmule_ft::golden::GemmSpec;
use redmule_ft::redmule::Protection;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a.as_str() == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn counts(r: &CampaignResult) -> (u64, u64, u64, u64, u64, u64) {
    (
        r.correct_no_retry,
        r.correct_with_retry,
        r.incorrect,
        r.timeout,
        r.applied,
        r.faults_applied,
    )
}

fn main() {
    let injections: u64 = arg("--injections")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_twolevel.json".to_string());
    let min_speedup: f64 = arg("--min-speedup")
        .and_then(|s| s.parse().ok())
        .unwrap_or(3.0);
    let seed = 2025u64;
    // Long-horizon shapes (thousands of cycles each): many checkpoint
    // segments, so the boundary-probe stepping the two-level engine
    // eliminates dominates the fast-forward engine's wall clock.
    let columns = [
        (Protection::Baseline, GemmSpec::new(32, 192, 48)),
        (Protection::Full, GemmSpec::new(32, 192, 48)),
        (Protection::Baseline, GemmSpec::new(24, 256, 32)),
    ];

    println!(
        "twolevel_speedup — long-horizon workloads, table1 config, \
         {injections} injections/column, single thread\n"
    );

    let mut rows = Vec::new();
    let (mut fast_total, mut two_total) = (0.0f64, 0.0f64);
    for (protection, spec) in columns {
        let mut cfg = CampaignConfig::table1(protection, injections, seed);
        cfg.spec = spec;
        cfg.threads = 1;
        cfg.fast_forward = true;
        cfg.two_level = false;
        let fast = Campaign::run(&cfg).expect("fast-forward campaign");
        cfg.two_level = true;
        let two = Campaign::run(&cfg).expect("two-level campaign");
        assert_eq!(
            counts(&fast),
            counts(&two),
            "{} {}x{}x{}: two-level results must be bit-identical to fast-forward",
            protection.name(),
            spec.m,
            spec.n,
            spec.k
        );
        let speedup = fast.wall_seconds / two.wall_seconds.max(1e-9);
        println!(
            "{:<10} {:>3}x{:<3}x{:<3} fast {:>7.0} runs/s   two-level {:>7.0} runs/s   \
             speedup {:>5.2}x",
            protection.name(),
            spec.m,
            spec.n,
            spec.k,
            fast.runs_per_sec(),
            two.runs_per_sec(),
            speedup
        );
        fast_total += fast.wall_seconds;
        two_total += two.wall_seconds;
        rows.push((protection, spec, fast, two, speedup));
    }

    let aggregate = fast_total / two_total.max(1e-9);
    println!(
        "\naggregate speedup: {aggregate:.2}x \
         (fast-forward {fast_total:.2} s vs two-level {two_total:.2} s)"
    );

    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"schema\": \"redmule-ft/bench-twolevel-v1\",\n");
    j.push_str("  \"engine\": \"two-level\",\n");
    j.push_str(&format!("  \"injections_per_column\": {injections},\n"));
    j.push_str(&format!("  \"seed\": {seed},\n"));
    j.push_str("  \"threads\": 1,\n");
    j.push_str(&format!("  \"aggregate_speedup\": {aggregate:.3},\n"));
    j.push_str("  \"columns\": [\n");
    for (i, (protection, spec, fast, two, speedup)) in rows.iter().enumerate() {
        j.push_str("    {");
        j.push_str(&format!("\"protection\": \"{}\", ", protection.name()));
        j.push_str(&format!(
            "\"shape\": {{\"m\": {}, \"n\": {}, \"k\": {}}}, ",
            spec.m, spec.n, spec.k
        ));
        j.push_str(&format!(
            "\"runs_per_sec_fast\": {:.1}, ",
            fast.runs_per_sec()
        ));
        j.push_str(&format!(
            "\"runs_per_sec_two_level\": {:.1}, ",
            two.runs_per_sec()
        ));
        j.push_str(&format!("\"speedup\": {speedup:.3}, "));
        j.push_str(&format!(
            "\"outcomes\": {{\"correct_no_retry\": {}, \"correct_with_retry\": {}, \
             \"incorrect\": {}, \"timeout\": {}}}",
            two.correct_no_retry, two.correct_with_retry, two.incorrect, two.timeout
        ));
        j.push_str(if i + 1 < rows.len() { "},\n" } else { "}\n" });
    }
    j.push_str("  ]\n}\n");
    std::fs::write(&out_path, &j).expect("write BENCH_twolevel.json");
    println!("wrote {out_path}");

    assert!(
        aggregate >= min_speedup,
        "two-level engine must deliver >= {min_speedup}x end-to-end speedup over \
         fast-forward on long horizons, got {aggregate:.2}x"
    );
    println!("twolevel_speedup OK");
}
