//! Bench: simulator hot-path throughput — the engineering metric that
//! bounds the 3×1M-injection Table-1 reproduction (EXPERIMENTS.md §Perf).
//!
//! Reports cycles/s of the cycle-level model and end-to-end injected
//! runs/s of the campaign engine, for each build.
//!
//! ```text
//! cargo bench --bench sim_throughput
//! ```

use redmule_ft::campaign::{Campaign, CampaignConfig};
use redmule_ft::cluster::System;
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};

fn main() {
    let cfg = RedMuleConfig::paper();
    let spec = GemmSpec::paper_workload();
    let p = GemmProblem::random(&spec, 1);

    println!("sim_throughput — paper workload (12x16x16), single thread\n");

    // 1. Raw stepping rate (fault-free runs, including re-staging).
    for (prot, mode) in [
        (Protection::Baseline, ExecMode::Performance),
        (Protection::Full, ExecMode::FaultTolerant),
    ] {
        let mut sys = System::new(cfg, prot);
        // Warm-up + measure.
        let r = sys.run_gemm(&p, mode).unwrap();
        let cycles_per_run = r.cycles;
        let started = std::time::Instant::now();
        let n = 2_000u64;
        for _ in 0..n {
            let r = sys.run_gemm(&p, mode).unwrap();
            std::hint::black_box(r.cycles);
        }
        let secs = started.elapsed().as_secs_f64();
        let runs_s = n as f64 / secs;
        println!(
            "{:<22} {:>8.0} runs/s  ({} cyc/run, {:>9.2} Mcyc/s)",
            format!("{}/{}", prot.name(), mode.name()),
            runs_s,
            cycles_per_run,
            runs_s * cycles_per_run as f64 / 1e6
        );
    }

    // 2. Campaign engine end-to-end (sampling + injection + classify).
    println!();
    let mut total_runs = 0u64;
    let mut total_secs = 0.0;
    for prot in [Protection::Baseline, Protection::Data, Protection::Full] {
        let mut cc = CampaignConfig::table1(prot, 10_000, 3);
        cc.threads = 1;
        let r = Campaign::run(&cc).unwrap();
        println!(
            "campaign [{:<8}]: {:>8.0} injections/s",
            prot.name(),
            r.runs_per_sec()
        );
        total_runs += r.total;
        total_secs += r.wall_seconds;
    }
    let agg = total_runs as f64 / total_secs;
    println!(
        "\naggregate: {agg:.0} injections/s -> full 3x1M Table-1 in ~{:.0} s single-threaded",
        3_000_000.0 / agg
    );
    assert!(agg > 2_000.0, "campaign engine too slow: {agg:.0} runs/s");
    println!("sim_throughput OK");
}
