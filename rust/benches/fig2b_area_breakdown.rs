//! Bench: regenerate **Figure 2b** — the area breakdown of the three
//! RedMulE versions with the FT overhead highlighted (the hatched bars),
//! plus the published totals for comparison.
//!
//! ```text
//! cargo bench --bench fig2b_area_breakdown
//! ```

use redmule_ft::area::{area_report, published};
use redmule_ft::redmule::{Protection, RedMuleConfig};

fn main() {
    let cfg = RedMuleConfig::paper();
    let base = area_report(cfg, Protection::Baseline);

    println!("Figure 2b — area breakdown (GE model vs GF12LP+ published)\n");
    for p in [Protection::Baseline, Protection::Data, Protection::Full] {
        let r = area_report(cfg, p);
        println!("{}", r.render());
        let published_total = match p {
            Protection::Baseline => published::BASELINE_KGE,
            Protection::Data => published::DATA_KGE,
            _ => published::FULL_KGE,
        };
        println!(
            "model total {:.1} kGE vs published {:.0} kGE ({:+.1} % model error)",
            r.total_kge(),
            published_total,
            100.0 * (r.total_kge() - published_total) / published_total
        );
        println!(
            "FT overhead: {:.1} kGE hatched, {:+.2} % vs baseline (paper: {:+.1} %)\n",
            r.ft_overhead_kge(),
            r.overhead_vs(&base),
            match p {
                Protection::Baseline => 0.0,
                Protection::Data => published::DATA_OVERHEAD_PCT,
                _ => published::FULL_OVERHEAD_PCT,
            }
        );
    }

    // ASCII bar chart in the figure's style.
    println!("kGE (hatched '#' = FT overhead, '=' = baseline logic)");
    for p in [Protection::Baseline, Protection::Data, Protection::Full] {
        let r = area_report(cfg, p);
        let base_units = ((r.total_kge() - r.ft_overhead_kge()) / 10.0).round() as usize;
        let ft_units = (r.ft_overhead_kge() / 10.0).round() as usize;
        println!(
            "{:<9} |{}{}| {:.0} kGE",
            p.name(),
            "=".repeat(base_units),
            "#".repeat(ft_units),
            r.total_kge()
        );
    }

    // Model-error bounds double as the bench's pass criteria.
    for (p, pub_kge) in [
        (Protection::Baseline, published::BASELINE_KGE),
        (Protection::Data, published::DATA_KGE),
        (Protection::Full, published::FULL_KGE),
    ] {
        let err = (area_report(cfg, p).total_kge() - pub_kge).abs() / pub_kge;
        assert!(err < 0.02, "{p:?}: model error {:.1} % > 2 %", err * 100.0);
    }
    println!("\nfig2b OK (model within 2 % of all three published totals)");
}
