//! Bench: regenerate **Table 1** — the paper's fault-injection results for
//! the three builds on the (12×16×16) workload.
//!
//! ```text
//! cargo bench --bench table1_fault_injection            # 20k/column
//! TABLE1_INJECTIONS=1000000 cargo bench --bench table1_fault_injection
//! ```
//!
//! Measured-vs-published rows are printed side by side; the campaign's own
//! throughput (runs/s) is reported so the full-scale 3M-run reproduction
//! can be budgeted.

use redmule_ft::campaign::Table1;

fn main() {
    let injections: u64 = std::env::var("TABLE1_INJECTIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let seed: u64 = std::env::var("TABLE1_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2025);

    eprintln!("table1_fault_injection: {injections} injections per column, seed {seed}");
    let started = std::time::Instant::now();
    let t = Table1::run(injections, seed, None).expect("campaign");
    let secs = started.elapsed().as_secs_f64();

    println!("{}", t.render());
    let total_runs: u64 = t.columns.iter().map(|c| c.total).sum();
    println!(
        "bench: {} total injected runs in {:.1} s ({:.0} runs/s)",
        total_runs,
        secs,
        total_runs as f64 / secs
    );

    // Shape assertions (the claims the paper makes of this table).
    let base = &t.columns[0];
    let data = &t.columns[1];
    let full = &t.columns[2];
    assert!(t.vulnerability_reduction() > 4.0, "data protection factor");
    assert_eq!(full.functional_errors(), 0, "full protection");
    assert_eq!(base.correct_with_retry, 0, "baseline cannot retry");
    assert!(
        data.correct_with_retry > 0 && full.correct_with_retry > 0,
        "retry mechanism exercised"
    );
}
