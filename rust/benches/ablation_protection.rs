//! Bench: ablations behind the paper's design choices (DESIGN.md §4).
//!
//! 1. **Per-module vulnerability** — where do the functional errors come
//!    from in each build? This is the evidence for §3.1's argument that
//!    per-CE checkers ([8], Ulbricht et al.) are insufficient: datapath
//!    sites are only part of the vulnerable population; buffers, streamer
//!    address paths and control logic carry the rest.
//! 2. **Area scaling** — §4.1's claim that "the relative cost of fault
//!    tolerance would considerably decrease in larger configurations".
//! 3. **Derating sensitivity** — the calibrated SET/SEU latch factors
//!    scale absolute rates but not the protection *ratios* (the claim the
//!    reproduction rests on).
//!
//! ```text
//! cargo bench --bench ablation_protection
//! ```

use redmule_ft::area::area_report;
use redmule_ft::campaign::{classify, Outcome};
use redmule_ft::cluster::System;
use redmule_ft::fault::registry::derating;
use redmule_ft::fault::{FaultRegistry, Module};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};
use redmule_ft::util::rng::{mix64, Xoshiro256};
use std::collections::HashMap;

const N: u64 = 12_000;

fn per_module_campaign(prot: Protection) -> (HashMap<Module, (u64, u64, u64)>, u64) {
    // (injections, retries, functional errors) per module; un-derated so
    // module-relative effects are visible.
    let cfg = RedMuleConfig::paper();
    let reg = FaultRegistry::new(cfg, prot);
    let mode = if prot.has_data_protection() {
        ExecMode::FaultTolerant
    } else {
        ExecMode::Performance
    };
    let p = GemmProblem::random(&GemmSpec::paper_workload(), mix64(9, 9));
    let golden = p.golden_z();
    let mut sys = System::new(cfg, prot);
    let horizon = sys.run_gemm(&p, mode).unwrap().cycles;
    let mut by_module: HashMap<Module, (u64, u64, u64)> = HashMap::new();
    let mut total_err = 0;
    for i in 0..N {
        let mut rng = Xoshiro256::new(mix64(31, i));
        let plan = reg.sample_plan(horizon, &mut rng);
        let r = sys.run_gemm_with_fault(&p, mode, Some(plan)).unwrap();
        let o = classify(&r, &golden);
        let e = by_module.entry(plan.site.module()).or_insert((0, 0, 0));
        e.0 += 1;
        if o == Outcome::CorrectWithRetry {
            e.1 += 1;
        }
        if o.is_functional_error() {
            e.2 += 1;
            total_err += 1;
        }
    }
    (by_module, total_err)
}

fn main() {
    println!("== Ablation 1: per-module vulnerability (un-derated, {N} injections) ==\n");
    for prot in [
        Protection::Baseline,
        Protection::PerCe,
        Protection::Abft,
        Protection::Data,
        Protection::Full,
    ] {
        let (by_module, total_err) = per_module_campaign(prot);
        let mut rows: Vec<_> = by_module.into_iter().collect();
        rows.sort_by_key(|(_, (_, _, e))| std::cmp::Reverse(*e));
        println!(
            "[{}] {} functional errors total",
            prot.name(),
            total_err
        );
        println!(
            "  {:<20} {:>8} {:>8} {:>8} {:>9}",
            "module", "inj", "retry", "errors", "err rate"
        );
        for (m, (n, retry, err)) in rows.iter().take(8) {
            println!(
                "  {:<20} {:>8} {:>8} {:>8} {:>8.2} %",
                m.name(),
                n,
                retry,
                err,
                100.0 * *err as f64 / (*n).max(1) as f64
            );
        }
        println!();
        if prot == Protection::Full {
            assert_eq!(total_err, 0, "full protection must hold in the ablation");
        }
    }
    // The [8]-style per-CE-checker argument, quantified two ways.
    // (a) In the *baseline*, errors are not confined to the CE datapath:
    let (base_modules, base_err) = per_module_campaign(Protection::Baseline);
    let ce_err = base_modules
        .iter()
        .filter(|(m, _)| matches!(m, Module::CeArray | Module::Accumulator))
        .map(|(_, (_, _, e))| e)
        .sum::<u64>();
    println!(
        "baseline errors outside CE datapath: {}/{} ({:.0} %) — per-CE checkers alone cannot catch these (§1, vs [8])",
        base_err - ce_err,
        base_err,
        100.0 * (base_err - ce_err) as f64 / base_err.max(1) as f64
    );
    assert!(base_err - ce_err > base_err / 10);
    // (b) The PerCe build itself: better than baseline, clearly worse
    // than RedMulE-FT's data protection — with comparable area cost.
    let (_, perce_err) = per_module_campaign(Protection::PerCe);
    let (_, data_err_a) = per_module_campaign(Protection::Data);
    let (_, abft_err) = per_module_campaign(Protection::Abft);
    let cfg = RedMuleConfig::paper();
    let base_area = area_report(cfg, Protection::Baseline);
    println!(
        "functional errors (un-derated): baseline {base_err}, per-CE [8] {perce_err}, \
         abft {abft_err}, data §3.1 {data_err_a}"
    );
    println!(
        "area overhead: per-CE [8] {:+.1} % vs abft {:+.1} % vs data §3.1 {:+.1} % — localized checkers cost more and protect less\n",
        area_report(cfg, Protection::PerCe).overhead_vs(&base_area),
        area_report(cfg, Protection::Abft).overhead_vs(&base_area),
        area_report(cfg, Protection::Data).overhead_vs(&base_area)
    );
    assert!(perce_err < base_err, "per-CE checkers do help somewhat");
    assert!(
        data_err_a * 2 < perce_err,
        "system-level protection must beat localized checkers"
    );
    // ABFT checksums: detect + recover the large-magnitude corruption
    // classes at performance-mode throughput; residual SDCs below the
    // rounding tolerance keep it above the replicated builds.
    assert!(abft_err < base_err, "checksums must cut the error rate");

    println!("== Ablation 2: FT area overhead vs array size (§4.1 scaling claim) ==\n");
    println!(
        "  {:<14} {:>10} {:>10} {:>10}",
        "config", "base kGE", "full kGE", "overhead"
    );
    let mut overheads = Vec::new();
    for (l, h, p) in [(12, 4, 3), (16, 8, 3), (24, 8, 3), (32, 16, 3), (48, 16, 3)] {
        let cfg = RedMuleConfig::new(l, h, p);
        let b = area_report(cfg, Protection::Baseline);
        let f = area_report(cfg, Protection::Full);
        let ovh = f.overhead_vs(&b);
        println!(
            "  L={:<3} H={:<3} P={} {:>10.0} {:>10.0} {:>9.1} %",
            l,
            h,
            p,
            b.total_kge(),
            f.total_kge(),
            ovh
        );
        overheads.push(ovh);
    }
    assert!(
        overheads.windows(2).all(|w| w[1] < w[0]),
        "overhead must decrease monotonically with array size"
    );
    println!();

    println!("== Ablation 3: derating sensitivity (protection ratio invariance) ==\n");
    println!(
        "calibrated factors: SET {} / SEU {} (fault/registry.rs)",
        derating::SET_LATCH,
        derating::SEU_LATCH
    );
    // Ratios computed from the un-derated per-module sweeps above: the
    // derate multiplies all outcome classes of a kind equally, so the
    // data-vs-baseline error ratio moves by <2x across any factor choice.
    let (_, data_err) = per_module_campaign(Protection::Data);
    let raw_ratio = base_err as f64 / data_err.max(1) as f64;
    println!(
        "un-derated vulnerability reduction (data vs baseline): {raw_ratio:.1}x; \
         derating rescales both columns, Table 1 reports ~11-12x"
    );
    assert!(raw_ratio > 3.0);
    println!("\nablation_protection OK");
}
