//! Self-checking bench: checkpointed fast-forward engine vs. the direct
//! engine on the paper workload (`Campaign::run`, table1 configuration,
//! single thread). Asserts two things and exits non-zero otherwise:
//!
//! 1. **equivalence** — every protection's outcome counts are
//!    bit-identical between the two engines, and
//! 2. **speedup** — the aggregate end-to-end speedup is ≥ 3× (the PR-3
//!    acceptance bar; typical measurements land well above it).
//!
//! Emits `BENCH_campaign.json` (schema `redmule-ft/bench-campaign-v1`)
//! with runs/sec per protection for both engines so the campaign
//! throughput trajectory is machine-readable across PRs.
//!
//! ```text
//! cargo bench --bench fastforward_speedup \
//!     [-- --injections N] [-- --out PATH] [-- --min-speedup X]
//! ```

use redmule_ft::campaign::{Campaign, CampaignConfig, CampaignResult};
use redmule_ft::redmule::Protection;

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a.as_str() == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn counts(r: &CampaignResult) -> (u64, u64, u64, u64, u64, u64) {
    (
        r.correct_no_retry,
        r.correct_with_retry,
        r.incorrect,
        r.timeout,
        r.applied,
        r.faults_applied,
    )
}

fn main() {
    let injections: u64 = arg("--injections")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8_000);
    let out_path = arg("--out").unwrap_or_else(|| "BENCH_campaign.json".to_string());
    // Wall-clock gate; loosen on noisy shared runners without losing the
    // (always-on) equivalence assertion.
    let min_speedup: f64 = arg("--min-speedup")
        .and_then(|s| s.parse().ok())
        .unwrap_or(3.0);
    let seed = 2025u64;
    let protections = [
        Protection::Baseline,
        Protection::Data,
        Protection::Full,
        Protection::Abft,
    ];

    println!(
        "fastforward_speedup — paper workload (12x16x16), table1 config, \
         {injections} injections/column, single thread\n"
    );

    let mut rows = Vec::new();
    let (mut direct_total, mut fast_total) = (0.0f64, 0.0f64);
    for protection in protections {
        let mut cfg = CampaignConfig::table1(protection, injections, seed);
        cfg.threads = 1;
        cfg.fast_forward = false;
        let direct = Campaign::run(&cfg).expect("direct campaign");
        cfg.fast_forward = true;
        let fast = Campaign::run(&cfg).expect("fast-forward campaign");
        assert_eq!(
            counts(&direct),
            counts(&fast),
            "{}: fast-forward results must be bit-identical to the direct engine",
            protection.name()
        );
        let speedup = direct.wall_seconds / fast.wall_seconds.max(1e-9);
        println!(
            "{:<10} direct {:>8.0} runs/s   fast {:>8.0} runs/s   speedup {:>5.2}x",
            protection.name(),
            direct.runs_per_sec(),
            fast.runs_per_sec(),
            speedup
        );
        direct_total += direct.wall_seconds;
        fast_total += fast.wall_seconds;
        rows.push((protection, direct, fast, speedup));
    }

    let aggregate = direct_total / fast_total.max(1e-9);
    println!(
        "\naggregate speedup: {aggregate:.2}x \
         (direct {direct_total:.2} s vs fast {fast_total:.2} s)"
    );

    // Machine-readable trajectory record.
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"schema\": \"redmule-ft/bench-campaign-v1\",\n");
    j.push_str(&format!("  \"injections_per_column\": {injections},\n"));
    j.push_str(&format!("  \"seed\": {seed},\n"));
    j.push_str("  \"threads\": 1,\n");
    j.push_str(&format!("  \"aggregate_speedup\": {aggregate:.3},\n"));
    j.push_str("  \"columns\": [\n");
    for (i, (protection, direct, fast, speedup)) in rows.iter().enumerate() {
        j.push_str("    {");
        j.push_str(&format!("\"protection\": \"{}\", ", protection.name()));
        j.push_str(&format!(
            "\"runs_per_sec_direct\": {:.1}, ",
            direct.runs_per_sec()
        ));
        j.push_str(&format!(
            "\"runs_per_sec_fast\": {:.1}, ",
            fast.runs_per_sec()
        ));
        j.push_str(&format!("\"speedup\": {speedup:.3}, "));
        j.push_str(&format!(
            "\"outcomes\": {{\"correct_no_retry\": {}, \"correct_with_retry\": {}, \
             \"incorrect\": {}, \"timeout\": {}}}",
            fast.correct_no_retry, fast.correct_with_retry, fast.incorrect, fast.timeout
        ));
        j.push_str(if i + 1 < rows.len() { "},\n" } else { "}\n" });
    }
    j.push_str("  ]\n}\n");
    std::fs::write(&out_path, &j).expect("write BENCH_campaign.json");
    println!("wrote {out_path}");

    assert!(
        aggregate >= min_speedup,
        "fast-forward engine must deliver >= {min_speedup}x end-to-end campaign speedup, \
         got {aggregate:.2}x"
    );
    println!("fastforward_speedup OK");
}
