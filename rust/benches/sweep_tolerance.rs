//! Bench: ABFT tolerance-factor sweep — the detection-rate vs
//! false-positive trade of floating-point checksum verification.
//!
//! ```text
//! cargo bench --bench sweep_tolerance
//! SWEEP_INJECTIONS=20000 cargo bench --bench sweep_tolerance
//! ```
//!
//! For each tolerance safety factor the bench measures, on the paper
//! workload:
//!
//! * **false positives** — fault-free runs whose writeback verification
//!   flags rounding noise as corruption (wasted recoveries, or abandoned
//!   workloads once retries run out);
//! * **detections** — injected runs recovered via checksum mismatch
//!   (`correct with retry`);
//! * **escapes** — injected runs ending in silent corruption
//!   (`incorrect`): corruptions below the tolerance pass unnoticed.
//!
//! Self-checks: a zero tolerance flags fault-free noise, the calibrated
//! default (factor 4) is false-positive free, and opening the tolerance
//! to effectively-infinite disables *finite-deviation* detection, so
//! escapes rise toward the unprotected level. (Non-finite corruptions —
//! an exponent flip driving a checksum to Inf/NaN — are flagged by the
//! verifier regardless of the factor, so detection shrinks but does not
//! reach zero.)

use redmule_ft::campaign::{Campaign, CampaignConfig};
use redmule_ft::cluster::{HostOutcome, RecoveryPolicy, System};
use redmule_ft::golden::{GemmProblem, GemmSpec, ABFT_TOL_FACTOR};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};

/// Fault-free runs whose verification fires at this tolerance factor.
fn false_positives(factor: f64, problems: u64, seed: u64) -> u64 {
    let cfg = RedMuleConfig::paper();
    let mut fp = 0;
    for i in 0..problems {
        let p = GemmProblem::random(&GemmSpec::paper_workload(), seed ^ (i << 8));
        let mut sys = System::new(cfg, Protection::Abft)
            .with_recovery(RecoveryPolicy::TileLevel)
            .with_abft_tolerance(factor);
        let r = sys.run_gemm(&p, ExecMode::Performance).expect("fault-free run");
        if r.retries > 0 || r.outcome != HostOutcome::Completed {
            fp += 1;
        }
    }
    fp
}

fn main() {
    let injections: u64 = std::env::var("SWEEP_INJECTIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4_000);
    let seed: u64 = std::env::var("SWEEP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2025);
    let fp_problems = 150;
    let factors = [0.0, 1.0, ABFT_TOL_FACTOR, 64.0, 1e9];

    eprintln!(
        "sweep_tolerance: {injections} injections and {fp_problems} fault-free \
         problems per factor, seed {seed}"
    );
    println!(
        "{:>10}  {:>8}  {:>10}  {:>9}  {:>8}  {:>8}",
        "factor", "fp", "detected", "incorrect", "timeout", "runs/s"
    );

    let mut rows = Vec::new();
    for &factor in &factors {
        let fp = false_positives(factor, fp_problems, seed);
        let mut cc = CampaignConfig::table1(Protection::Abft, injections, seed);
        cc.abft_tol_factor = factor;
        let r = Campaign::run(&cc).expect("campaign");
        println!(
            "{factor:>10.2}  {fp:>8}  {:>10}  {:>9}  {:>8}  {:>8.0}",
            r.correct_with_retry,
            r.incorrect,
            r.timeout,
            r.runs_per_sec()
        );
        rows.push((factor, fp, r));
    }

    // Shape assertions: the trade the sweep is meant to quantify.
    let zero = &rows[0];
    let default = rows
        .iter()
        .find(|(f, _, _)| *f == ABFT_TOL_FACTOR)
        .expect("default factor row");
    let open = rows.last().expect("open-tolerance row");

    assert!(
        zero.1 > 0,
        "zero tolerance must flag fault-free rounding noise ({} fp)",
        zero.1
    );
    assert_eq!(
        default.1, 0,
        "the calibrated factor {ABFT_TOL_FACTOR} must be false-positive free"
    );
    assert_eq!(open.1, 0, "an open tolerance cannot fire at all");
    assert!(
        default.2.correct_with_retry > 0,
        "the calibrated factor must drive checksum recoveries"
    );
    // An open tolerance only disables finite-deviation checks; Inf/NaN
    // checksums are still flagged, so detection shrinks but need not
    // vanish. Same seed => identical fault plans per row, so the
    // comparison is deterministic, not statistical.
    assert!(
        open.2.correct_with_retry <= default.2.correct_with_retry,
        "detection must not grow as the tolerance opens: {} vs {}",
        open.2.correct_with_retry,
        default.2.correct_with_retry
    );
    assert!(
        open.2.incorrect >= default.2.incorrect,
        "escapes must not shrink as the tolerance opens: {} vs {}",
        open.2.incorrect,
        default.2.incorrect
    );
    assert!(
        open.2.incorrect > 0,
        "with detection disabled the ABFT build must show silent corruption"
    );
    println!(
        "ok: fp {} -> 0 as the factor opens; escapes {} -> {} as detection disables",
        zero.1, default.2.incorrect, open.2.incorrect
    );
}
