//! `redmule-ft` — command-line front end for the RedMulE-FT reproduction.
//!
//! Subcommands (CLI parsing is hand-rolled; clap is not vendored):
//!
//! ```text
//! redmule-ft campaign [--config baseline|data|full|abft|abft-online|per-ce] [--injections N]
//!                     [--seed S] [--threads T] [--report]
//!                     [--format fp16|fp8-e4m3|fp8-e5m2] [--op mul|addmax|addmin|mulmax|mulmin]
//!                     [--direct] [--checkpoint-interval K]
//!                     [--two-level | --no-two-level]
//!                     [--precision P] [--batch-size B] [--min-injections N]
//!                     [--max-injections N] [--stratify] [--stratify-on O]
//!                     [--confidence C]
//! redmule-ft sweep    [--injections N] [--seed S] [--threads T]
//!                     [--configs a,b,..] [--geoms LxHxP,..] [--shapes MxNxK,..]
//!                     [--format f,..] [--op o,..]
//!                     [--faults 1,2,..] [--model independent|burst|site-burst]
//!                     [--tols F,..] [--recoveries full-restart,tile-level,..]
//!                     [--tiles 1,4,..] [--mesh-profile chaos|mixed|..]
//!                     [--schema v1|v2] [--timing [--timing-out F]]
//!                     [--precision P] [--batch-size B] [--min-injections N]
//!                     [--max-injections N] [--stratify] [--stratify-on O]
//!                     [--confidence C]
//!                     [--direct] [--checkpoint-interval K]
//!                     [--two-level | --no-two-level]
//!                     [--no-trace-cache] [--per-cell]
//! redmule-ft mesh     [--tiles N] [--shards S] [--config ...] [--m M --n N --k K]
//!                     [--profile none|flip|drop|dup|reorder|crash|mixed|chaos]
//!                     [--engine direct|ff|tl] [--faults F] [--injections N]
//!                     [--seed S] [--threads T] [--unprotected-noc | --no-link-crc
//!                     --no-reduction-abft --no-retirement] [--verify-staging] [--json]
//! redmule-ft table1   [--injections N] [--seed S] [--threads T] [--abft]
//! redmule-ft area     [--config baseline|data|full|abft] [--l L --h H --p P]
//!                     [--tiles N]
//! redmule-ft floorplan [--config ...]
//! redmule-ft perf     [--m M --n N --k K]
//! redmule-ft gemm     [--m M --n N --k K] [--config ...] [--mode ft|perf]
//!                     [--format F] [--op O]
//! redmule-ft golden-check [--artifacts DIR]
//! redmule-ft serve    [--tasks N] [--critical-pct P]
//! redmule-ft serve-sim [--jobs N] [--seed S] [--workers W] [--injections N]
//!                     [--chunk C] [--fault-profile none|drop|dup|delay|crash|chaos]
//!                     [--cancel-pct P] [--baseline] [--verify]
//! ```

use redmule_ft::area::{area_report, floorplan, mesh_area_report};
use redmule_ft::campaign::{
    Campaign, CampaignConfig, CampaignResult, StratifyObjective, Sweep, SweepConfig, Table1,
    OUTCOMES,
};
use redmule_ft::cluster::{RecoveryPolicy, System, TileEngine};
use redmule_ft::coordinator::{Coordinator, Criticality};
use redmule_ft::fault::FaultModel;
use redmule_ft::fp::{GemmFormat, GemmOp};
use redmule_ft::golden::{GemmProblem, GemmSpec};
use redmule_ft::mesh::{MeshCampaign, MeshCampaignConfig, MeshConfig, MeshFaultProfile};
use redmule_ft::perf::{mode_report, retry_expected_overhead, throughput};
use redmule_ft::redmule::{ExecMode, Protection, RedMuleConfig};
use redmule_ft::runtime::GoldenRuntime;
use redmule_ft::service::{CampaignService, JobOutcome, JobSpec, ServiceConfig, ServiceFaultPlan};
use redmule_ft::util::rng::Xoshiro256;

use std::collections::HashMap;
use std::process::ExitCode;

/// Minimal `--key value` / flag parser.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = HashMap::new();
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    kv.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                eprintln!("unexpected argument: {a}");
                i += 1;
            }
        }
        Self { cmd, kv, flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.kv
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    fn protection(&self) -> Protection {
        match self.kv.get("config") {
            None => Protection::Full,
            Some(name) => parse_protection(name).unwrap_or_else(|| {
                eprintln!("unknown --config {name}, using full");
                Protection::Full
            }),
        }
    }

    fn redmule_cfg(&self) -> RedMuleConfig {
        RedMuleConfig::new(
            self.get("l", 12usize),
            self.get("h", 4usize),
            self.get("p", 3usize),
        )
    }
}

/// Render a confidence level as a percent label without rounding away
/// fractional levels (`0.95` → `"95"`, `0.975` → `"97.5"`).
fn percent_label(confidence: f64) -> String {
    let p = confidence * 100.0;
    if (p - p.round()).abs() < 1e-9 {
        format!("{p:.0}")
    } else {
        format!("{p}")
    }
}

fn parse_protection(s: &str) -> Option<Protection> {
    match s {
        "baseline" => Some(Protection::Baseline),
        "data" => Some(Protection::Data),
        "full" => Some(Protection::Full),
        "per-ce" | "perce" => Some(Protection::PerCe),
        "abft" => Some(Protection::Abft),
        "abft-online" | "abftonline" | "abft_online" => Some(Protection::AbftOnline),
        _ => None,
    }
}

/// Parse a `MxNxK` shape token.
fn parse_shape(s: &str) -> Option<GemmSpec> {
    let mut it = s.split('x');
    let m: usize = it.next()?.parse().ok()?;
    let n: usize = it.next()?.parse().ok()?;
    let k: usize = it.next()?.parse().ok()?;
    if it.next().is_some() || m == 0 || n == 0 || k == 0 {
        return None;
    }
    Some(GemmSpec::new(m, n, k))
}

/// Resolve a single-valued `--format` (campaign / gemm). `None` means
/// the flag was absent and the default ([`GemmFormat::Fp16`]) applies.
fn format_of(args: &Args) -> redmule_ft::Result<Option<GemmFormat>> {
    match args.kv.get("format") {
        None => Ok(None),
        Some(raw) => GemmFormat::parse(raw).map(Some).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --format {raw} (expected fp16, fp8-e4m3 or fp8-e5m2)"
            ))
        }),
    }
}

/// Resolve a single-valued `--op` (campaign / gemm). `None` means the
/// flag was absent and the default ([`GemmOp::Mul`]) applies.
fn op_of(args: &Args) -> redmule_ft::Result<Option<GemmOp>> {
    match args.kv.get("op") {
        None => Ok(None),
        Some(raw) => GemmOp::parse(raw).map(Some).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --op {raw} (expected mul, addmax, addmin, mulmax or mulmin)"
            ))
        }),
    }
}

/// Parse a recovery-policy token for the sweep's `--recoveries` axis.
fn parse_recovery(s: &str) -> Option<RecoveryPolicy> {
    match s {
        "full-restart" | "full_restart" => Some(RecoveryPolicy::FullRestart),
        "tile-level" | "tile_level" => Some(RecoveryPolicy::TileLevel),
        "in-place-correct" | "in_place_correct" => Some(RecoveryPolicy::InPlaceCorrect),
        _ => None,
    }
}

/// Resolve the `--two-level` / `--no-two-level` pair. Off by default:
/// the two-level engine is byte-identical to fast-forward by contract,
/// so opting in is purely a throughput choice.
fn two_level_flag(args: &Args) -> bool {
    args.flag("two-level") && !args.flag("no-two-level")
}

/// Resolve `--stratify-on <outcome>` (default: functional-error, the
/// historical Neyman objective).
fn stratify_on(args: &Args) -> redmule_ft::Result<StratifyObjective> {
    match args.kv.get("stratify-on") {
        None => Ok(StratifyObjective::FunctionalError),
        Some(raw) => StratifyObjective::parse(raw).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --stratify-on {raw} (expected functional-error, \
                 correct-no-retry, correct-with-retry, incorrect or timeout)"
            ))
        }),
    }
}

/// Parse an `LxHxP` array-geometry token.
fn parse_geometry(s: &str) -> Option<RedMuleConfig> {
    let mut it = s.split('x');
    let l: usize = it.next()?.parse().ok()?;
    let h: usize = it.next()?.parse().ok()?;
    let p: usize = it.next()?.parse().ok()?;
    if it.next().is_some() || l == 0 || h == 0 || p == 0 {
        return None;
    }
    Some(RedMuleConfig::new(l, h, p))
}

/// Parse a comma-separated list, mapping each token through `f`.
fn parse_list<T>(raw: &str, what: &str, f: impl Fn(&str) -> Option<T>) -> redmule_ft::Result<Vec<T>> {
    let mut out = Vec::new();
    for tok in raw.split(',').filter(|t| !t.is_empty()) {
        match f(tok) {
            Some(v) => out.push(v),
            None => {
                return Err(redmule_ft::Error::Config(format!("bad {what} token: {tok}")));
            }
        }
    }
    if out.is_empty() {
        return Err(redmule_ft::Error::Config(format!("empty {what} list: {raw}")));
    }
    Ok(out)
}

fn main() -> ExitCode {
    let args = Args::parse();
    let r = match args.cmd.as_str() {
        "campaign" => cmd_campaign(&args),
        "sweep" => cmd_sweep(&args),
        "mesh" => cmd_mesh(&args),
        "table1" => cmd_table1(&args),
        "area" => cmd_area(&args),
        "floorplan" => cmd_floorplan(&args),
        "perf" => cmd_perf(&args),
        "gemm" => cmd_gemm(&args),
        "golden-check" => cmd_golden_check(&args),
        "serve" => cmd_serve(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command: {other}\n");
            print_help();
            Err(redmule_ft::Error::Config("unknown command".into()))
        }
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "redmule-ft — RedMulE-FT reproduction (CF Companion '25)\n\
         \n\
         commands:\n\
           campaign      run one SFI campaign column (--config baseline|data|full|abft|\n\
                         abft-online|per-ce — abft-online corrects single errors in\n\
                         place from the fused store residuals,\n\
                         --format fp16|fp8-e4m3|fp8-e5m2 picks the numeric format\n\
                         (FP8 adds cast-in/cast-out fault sites on every stream),\n\
                         --op mul|addmax|addmin|mulmax|mulmin picks the GEMM op\n\
                         family (non-mul ops reject ABFT-checksum builds),\n\
                         --injections, --seed, --threads, --report; --direct disables the\n\
                         checkpointed fast-forward engine, --checkpoint-interval K tunes it,\n\
                         --two-level runs fast-forward's functional level with\n\
                         cycle-accurate fault windows — byte-identical results,\n\
                         faster (--no-two-level opts back out);\n\
                         --precision P stops adaptively once every outcome's CI\n\
                         half-width <= P at the --confidence level (default 0.95),\n\
                         tuned by --batch-size/--min-injections/--max-injections,\n\
                         --stratify allocates over area strata and --stratify-on O\n\
                         picks the Neyman objective outcome (functional-error |\n\
                         correct-no-retry | correct-with-retry | incorrect | timeout))\n\
           sweep         run a scenario-grid campaign and print JSON (--configs a,b,..,\n\
                         --geoms LxHxP,.. array geometries, --format f,.. / --op o,..\n\
                         cross the numeric-format and op-family axes (cells keep the\n\
                         fp16 / mul defaults when unset), --shapes MxNxK,..,\n\
                         --faults 1,2,.., --model independent|burst|site-burst,\n\
                         --tols F,.. for ABFT cells, --recoveries full-restart,\n\
                         tile-level,in-place-correct crosses the recovery-policy\n\
                         axis (invalid protection pairs are rejected up front),\n\
                         --injections per cell, --seed,\n\
                         --threads, --schema v2 (default, per-outcome CIs; v1 legacy),\n\
                         --precision / --batch-size / --min-injections / --max-injections /\n\
                         --stratify run every cell to its own stopping point\n\
                         (--stratify-on O as in campaign),\n\
                         --confidence C sets the interval level (default 0.95),\n\
                         --timing writes the bench-sweep sidecar (--timing-out FILE;\n\
                         v1 keeps its legacy inline fields), --direct /\n\
                         --checkpoint-interval / --two-level as in campaign;\n\
                         --tiles 1,4,.. crosses the mesh tile-count axis (multi-tile\n\
                         cells shard the workload across a RedMulE mesh and inject\n\
                         interconnect faults under --mesh-profile, default chaos),\n\
                         --no-trace-cache\n\
                         disables the shared reference-trace cache and --per-cell\n\
                         the grid-wide work stealing — byte-identical output either\n\
                         way, only slower)\n\
           mesh          run a multi-tile NoC fault campaign: one GEMM sharded over\n\
                         --tiles RedMulE instances, faults on the interconnect\n\
                         (--profile none|flip|drop|dup|reorder|crash|mixed|chaos),\n\
                         recovery by per-link CRC + retransmit, reduction-tree ABFT\n\
                         and crashed-tile retirement (--unprotected-noc or the\n\
                         individual --no-link-crc/--no-reduction-abft/\n\
                         --no-retirement flags switch them off, --engine picks the\n\
                         tile execution engine, --verify-staging checks staged\n\
                         inputs at rest, --json prints the deterministic document)\n\
           table1        run the Table-1 columns (--injections, --seed, --threads;\n\
                         --abft appends the ABFT checksum and online-ABFT columns)\n\
           area          GE area model breakdown (--config, --l/--h/--p; --tiles N\n\
                         adds the mesh interconnect: N tile instances plus NoC\n\
                         links/routers, link CRC, the reduction-ABFT checker and\n\
                         heartbeat watchdogs)\n\
           floorplan     Fig. 2a textual floorplan (--config)\n\
           perf          performance-mode vs FT-mode cycle model (--m/--n/--k)\n\
           gemm          run one GEMM on the simulator and verify vs golden\n\
           golden-check  execute AOT artifacts via PJRT and compare bit-exactly\n\
           serve         mixed-criticality coordinator demo (--tasks, --critical-pct)\n\
           serve-sim     deterministic campaign-service simulation: a priority job\n\
                         queue over supervised workers on a virtual clock with a\n\
                         faulty message layer (--jobs, --seed, --workers,\n\
                         --injections per job, --chunk injections per dispatch,\n\
                         --fault-profile none|drop|dup|delay|crash|chaos,\n\
                         --cancel-pct P cancels ~P % of jobs mid-run; stdout is a\n\
                         deterministic JSON doc whose counts are byte-identical\n\
                         under every profile — --baseline prints the same doc from\n\
                         the plain single-threaded engine, --verify re-checks every\n\
                         completed job against it in-process)"
    );
}

fn cmd_campaign(args: &Args) -> redmule_ft::Result<()> {
    let protection = args.protection();
    let injections = args.get("injections", 20_000u64);
    let seed = args.get("seed", 2025u64);
    let mut cfg = CampaignConfig::table1(protection, injections, seed);
    if let Some(f) = format_of(args)? {
        cfg.cfg = cfg.cfg.with_format(f);
    }
    if let Some(o) = op_of(args)? {
        cfg.cfg = cfg.cfg.with_op(o);
    }
    cfg.threads = args.get("threads", cfg.threads);
    cfg.fast_forward = !args.flag("direct");
    cfg.checkpoint_interval = args.get("checkpoint-interval", 0u64);
    cfg.two_level = two_level_flag(args);
    cfg.tl_coalesce = !args.flag("no-coalesce");
    cfg.precision_target = args.get("precision", 0.0f64);
    cfg.batch_size = args.get("batch-size", 0u64);
    cfg.min_injections = args.get("min-injections", 0u64);
    cfg.max_injections = args.get("max-injections", 0u64);
    cfg.stratify = args.flag("stratify");
    cfg.stratify_on = stratify_on(args)?;
    cfg.confidence = args.get("confidence", 0.95f64);
    let fo_note = if cfg.cfg.format != GemmFormat::Fp16 || cfg.cfg.op != GemmOp::Mul {
        format!(" [{} / {}]", cfg.cfg.format.name(), cfg.cfg.op.name())
    } else {
        String::new()
    };
    eprintln!(
        "campaign: {} build{fo_note}, {} injections{}, seed {}, {} threads, {} engine{}",
        protection.name(),
        injections,
        if cfg.precision_target > 0.0 {
            format!(
                " (cap; adaptive to ±{} at {} %)",
                cfg.precision_target,
                percent_label(cfg.confidence)
            )
        } else {
            String::new()
        },
        seed,
        cfg.threads,
        if cfg.two_level {
            "two-level"
        } else if cfg.fast_forward {
            "fast-forward"
        } else {
            "direct"
        },
        if cfg.stratify { ", stratified" } else { "" }
    );
    let r = Campaign::run(&cfg)?;
    println!(
        "total {}  correct(no-retry) {}  correct(retry) {}  incorrect {}  timeout {}",
        r.total, r.correct_no_retry, r.correct_with_retry, r.incorrect, r.timeout
    );
    println!(
        "applied {} ({:.2} %)   {:.0} runs/s",
        r.applied,
        100.0 * r.applied as f64 / r.total.max(1) as f64,
        r.runs_per_sec()
    );
    let pct = percent_label(cfg.confidence);
    if cfg.precision_target > 0.0 {
        println!(
            "adaptive: {} batches, stopped {} (target ±{} at {pct} %)",
            r.batches,
            if r.stopped_early {
                "early — every outcome CI met the target"
            } else {
                "at the injection cap"
            },
            cfg.precision_target
        );
    }
    if args.flag("report") {
        println!();
        for o in OUTCOMES {
            let e = r.estimate_of(o);
            if e.count == 0 {
                println!(
                    "{:<22}: 0 observed in {} -> < {:.3e} at {pct} %",
                    o.name(),
                    e.n,
                    e.upper95()
                );
            } else {
                println!(
                    "{:<22}: {:>7.4} %  {pct}% CI [{:.4}, {:.4}] %  (exact [{:.4}, {:.4}] %)",
                    o.name(),
                    100.0 * e.rate,
                    100.0 * e.ci_lo,
                    100.0 * e.ci_hi,
                    100.0 * e.exact_lo,
                    100.0 * e.exact_hi
                );
            }
        }
        let fe = r.functional_error_estimate();
        if fe.count == 0 {
            println!(
                "{:<22}: 0 observed in {} -> < {:.3e} at {pct} %",
                "functional error",
                fe.n,
                fe.upper95()
            );
        } else {
            println!(
                "{:<22}: {:>7.4} %  {pct}% CI [{:.4}, {:.4}] %",
                "functional error",
                100.0 * fe.rate,
                100.0 * fe.ci_lo,
                100.0 * fe.ci_hi
            );
        }
        if !r.strata.is_empty() {
            println!();
            println!(
                "{:<12} {:>7} {:>8} {:>10} {:>8} {:>10} {:>8}",
                "stratum", "share", "n", "no-retry", "retry", "incorrect", "timeout"
            );
            for s in &r.strata {
                println!(
                    "{:<12} {:>6.3} {:>8} {:>10} {:>8} {:>10} {:>8}",
                    s.name, s.share, s.n, s.outcomes[0], s.outcomes[1], s.outcomes[2],
                    s.outcomes[3]
                );
            }
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> redmule_ft::Result<()> {
    let mut sc = SweepConfig::new(args.get("injections", 500u64), args.get("seed", 2025u64));
    sc.threads = args.get("threads", sc.threads);
    sc.fast_forward = !args.flag("direct");
    sc.checkpoint_interval = args.get("checkpoint-interval", 0u64);
    sc.two_level = two_level_flag(args);
    sc.tl_coalesce = !args.flag("no-coalesce");
    if let Some(raw) = args.kv.get("configs") {
        sc.protections = parse_list(raw, "--configs", parse_protection)?;
    }
    if let Some(raw) = args.kv.get("geoms") {
        sc.geometries = parse_list(raw, "--geoms", parse_geometry)?;
    }
    if let Some(raw) = args.kv.get("format") {
        sc.formats = parse_list(raw, "--format", GemmFormat::parse)?;
    }
    if let Some(raw) = args.kv.get("op") {
        sc.ops = parse_list(raw, "--op", GemmOp::parse)?;
    }
    if let Some(raw) = args.kv.get("shapes") {
        sc.shapes = parse_list(raw, "--shapes", parse_shape)?;
    }
    if let Some(raw) = args.kv.get("faults") {
        sc.fault_counts = parse_list(raw, "--faults", |t| {
            t.parse::<usize>().ok().filter(|&n| n >= 1)
        })?;
    }
    if let Some(raw) = args.kv.get("model") {
        sc.fault_model = FaultModel::parse(raw)
            .ok_or_else(|| redmule_ft::Error::Config(format!("unknown --model {raw}")))?;
    }
    if let Some(raw) = args.kv.get("tols") {
        sc.tol_factors = parse_list(raw, "--tols", |t| {
            t.parse::<f64>().ok().filter(|f| f.is_finite() && *f >= 0.0)
        })?;
    }
    if let Some(raw) = args.kv.get("recoveries") {
        sc.recoveries = Some(parse_list(raw, "--recoveries", parse_recovery)?);
    }
    if let Some(raw) = args.kv.get("tiles") {
        sc.tiles = parse_list(raw, "--tiles", |t| {
            t.parse::<usize>().ok().filter(|&n| n >= 1)
        })?;
    }
    if let Some(raw) = args.kv.get("mesh-profile") {
        sc.mesh_profile = MeshFaultProfile::parse(raw).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --mesh-profile {raw} (expected none|flip|drop|dup|reorder|\
                 crash|mixed|chaos)"
            ))
        })?;
    }
    sc.precision_target = args.get("precision", 0.0f64);
    sc.batch_size = args.get("batch-size", 0u64);
    sc.min_injections = args.get("min-injections", 0u64);
    sc.max_injections = args.get("max-injections", 0u64);
    sc.stratify = args.flag("stratify");
    sc.stratify_on = stratify_on(args)?;
    sc.confidence = args.get("confidence", 0.95f64);
    sc.trace_cache = !args.flag("no-trace-cache");
    sc.work_stealing = !args.flag("per-cell");
    let schema = args
        .kv
        .get("schema")
        .map(|s| s.as_str())
        .unwrap_or("v2")
        .to_string();
    if schema != "v1" && schema != "v2" {
        return Err(redmule_ft::Error::Config(format!(
            "unknown --schema {schema} (expected v1 or v2)"
        )));
    }
    eprintln!(
        "sweep: {} cells ({} geometries x {} formats x {} ops x {} protections x {} shapes \
         x {} fault counts, {} model), {} injections/cell{}, seed {}, {} threads, {} engine, \
         schema {}",
        sc.n_cells(),
        sc.geometries.len(),
        sc.formats.len().max(1),
        sc.ops.len().max(1),
        sc.protections.len(),
        sc.shapes.len(),
        sc.fault_counts.len(),
        sc.fault_model.name(),
        sc.injections,
        if sc.precision_target > 0.0 {
            format!(" (cap; adaptive to ±{})", sc.precision_target)
        } else {
            String::new()
        },
        sc.seed,
        sc.threads,
        if sc.two_level {
            "two-level"
        } else if sc.fast_forward {
            "fast-forward"
        } else {
            "direct"
        },
        schema
    );
    let scheduler = if sc.work_stealing {
        "grid-stealing"
    } else {
        "per-cell pools"
    };
    let cache_mode = if sc.trace_cache { "shared" } else { "off" };
    eprintln!("sweep: scheduler {scheduler}, reference-trace cache {cache_mode}");
    let r = Sweep::run(&sc)?;
    if schema == "v1" {
        // Legacy document; `--timing` keeps its historical inline
        // behavior there (the fields the determinism checks must strip).
        println!("{}", r.to_json(args.flag("timing")));
    } else {
        println!("{}", r.to_json_v2());
        if args.flag("timing") {
            // v2 keeps the deterministic document clean: wall-clock goes
            // to a sidecar file (schema redmule-ft/bench-sweep-v1).
            let path = args
                .kv
                .get("timing-out")
                .cloned()
                .unwrap_or_else(|| "BENCH_sweep.json".to_string());
            std::fs::write(&path, r.timing_json())
                .map_err(|e| redmule_ft::Error::Sim(format!("cannot write {path}: {e}")))?;
            eprintln!("sweep: wrote timing sidecar to {path}");
        }
    }
    eprintln!(
        "sweep: {} runs in {:.1} s ({:.0} runs/s)",
        r.total_runs(),
        r.wall_seconds,
        r.runs_per_sec()
    );
    if let Some((hits, misses)) = r.trace_cache_stats {
        eprintln!(
            "sweep: reference traces — {misses} recorded, {hits} adopted from the shared cache"
        );
    }
    Ok(())
}

fn cmd_mesh(args: &Args) -> redmule_ft::Result<()> {
    let tiles = args.get("tiles", 4usize);
    let mut mesh = if args.flag("unprotected-noc") {
        MeshConfig::unprotected(tiles)
    } else {
        MeshConfig::new(tiles)
    };
    mesh.shards = args.get("shards", 0usize);
    mesh.cfg = args.redmule_cfg();
    if let Some(f) = format_of(args)? {
        mesh.cfg = mesh.cfg.with_format(f);
    }
    if let Some(o) = op_of(args)? {
        mesh.cfg = mesh.cfg.with_op(o);
    }
    mesh.protection = args.protection();
    if let Some(raw) = args.kv.get("engine") {
        mesh.engine = TileEngine::parse(raw).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --engine {raw} (expected direct, fast-forward/ff or two-level/tl)"
            ))
        })?;
    }
    if args.flag("no-link-crc") {
        mesh.link_crc = false;
    }
    if args.flag("no-reduction-abft") {
        mesh.reduction_abft = false;
    }
    if args.flag("no-retirement") {
        mesh.tile_retirement = false;
    }
    if args.flag("verify-staging") {
        mesh.verify_staging = true;
    }
    let mut mc = MeshCampaignConfig::new(
        tiles,
        args.get("injections", 200u64),
        args.get("seed", 2025u64),
    );
    mc.mesh = mesh;
    mc.spec = GemmSpec::new(
        args.get("m", mc.spec.m),
        args.get("n", mc.spec.n),
        args.get("k", mc.spec.k),
    );
    mc.faults_per_run = args.get("faults", mc.faults_per_run);
    mc.threads = args.get("threads", 1usize);
    if let Some(raw) = args.kv.get("profile") {
        mc.profile = MeshFaultProfile::parse(raw).ok_or_else(|| {
            redmule_ft::Error::Config(format!(
                "unknown --profile {raw} (expected none|flip|drop|dup|reorder|crash|\
                 mixed|chaos)"
            ))
        })?;
    }
    eprintln!(
        "mesh: {} tiles x {} shards on {} ({}x{}x{}), {} injections, profile {}, \
         engine {}, crc={} abft={} retirement={}",
        mc.mesh.tiles,
        mc.mesh.shard_count(mc.spec.m),
        mc.mesh.protection.name(),
        mc.spec.m,
        mc.spec.n,
        mc.spec.k,
        mc.injections,
        mc.profile.name(),
        mc.mesh.engine.name(),
        mc.mesh.link_crc,
        mc.mesh.reduction_abft,
        mc.mesh.tile_retirement,
    );
    let r = MeshCampaign::run(&mc)?;
    if args.flag("json") {
        println!("{}", r.to_json());
    } else {
        println!("{}", r.render());
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> redmule_ft::Result<()> {
    let injections = args.get("injections", 20_000u64);
    let seed = args.get("seed", 2025u64);
    let threads = args.kv.get("threads").and_then(|t| t.parse().ok());
    let t = if args.flag("abft") {
        Table1::run_with_abft(injections, seed, threads)?
    } else {
        Table1::run(injections, seed, threads)?
    };
    println!("{}", t.render());
    Ok(())
}

fn cmd_area(args: &Args) -> redmule_ft::Result<()> {
    let cfg = args.redmule_cfg();
    let tiles = args.get("tiles", 1usize);
    if tiles > 1 {
        // Mesh variant: tile instances plus the NoC fault-domain
        // hardware (links, routers, CRC, reduction checker, heartbeat).
        let base = mesh_area_report(cfg, Protection::Baseline, tiles, false, false, false);
        for p in [Protection::Baseline, Protection::Full] {
            let r = mesh_area_report(cfg, p, tiles, true, true, true);
            println!("{}", r.render());
            println!("overhead vs unprotected mesh: {:+.1} %\n", r.overhead_vs(&base));
        }
        return Ok(());
    }
    let base = area_report(cfg, Protection::Baseline);
    for p in [
        Protection::Baseline,
        Protection::Data,
        Protection::Abft,
        Protection::AbftOnline,
        Protection::Full,
    ] {
        let r = area_report(cfg, p);
        println!("{}", r.render());
        println!(
            "overhead vs baseline: {:+.1} %\n",
            r.overhead_vs(&base)
        );
    }
    Ok(())
}

fn cmd_floorplan(args: &Args) -> redmule_ft::Result<()> {
    let (mut blocks, redmule) = floorplan::cluster_blocks(args.redmule_cfg(), args.protection());
    floorplan::place(&mut blocks);
    println!("{}", floorplan::render(&blocks));
    println!(
        "RedMulE-FT [{}]: {:.0} kGE",
        args.protection().name(),
        redmule.total_kge()
    );
    Ok(())
}

fn cmd_perf(args: &Args) -> redmule_ft::Result<()> {
    let cfg = args.redmule_cfg();
    let spec = GemmSpec::new(
        args.get("m", 12usize),
        args.get("n", 16usize),
        args.get("k", 16usize),
    );
    let r = mode_report(cfg, Protection::Full, spec)?;
    println!(
        "workload ({},{},{}) on L={} H={} P={}",
        spec.m, spec.n, spec.k, cfg.l, cfg.h, cfg.p
    );
    let tp = throughput(cfg, spec, r.perf_cycles);
    let tf = throughput(cfg, spec, r.ft_cycles);
    println!(
        "performance mode : {:>8} cycles  util {:>5.1} %  {:>6.2} GFLOPS",
        r.perf_cycles,
        100.0 * tp.utilization,
        tp.gflops
    );
    println!(
        "fault-tolerant   : {:>8} cycles  util {:>5.1} %  {:>6.2} GFLOPS",
        r.ft_cycles,
        100.0 * tf.utilization,
        tf.gflops
    );
    println!("slowdown         : {:.2}x  [paper: 2x]", r.slowdown);
    println!(
        "retry overhead at 12 % detection rate: {:.0} cycles expected per workload",
        retry_expected_overhead(r.ft_cycles, 0.12)
    );
    Ok(())
}

fn cmd_gemm(args: &Args) -> redmule_ft::Result<()> {
    let mut cfg = args.redmule_cfg();
    if let Some(f) = format_of(args)? {
        cfg = cfg.with_format(f);
    }
    if let Some(o) = op_of(args)? {
        cfg = cfg.with_op(o);
    }
    let protection = args.protection();
    let mode = match args.kv.get("mode").map(|s| s.as_str()) {
        Some("perf") | Some("performance") => ExecMode::Performance,
        _ => ExecMode::FaultTolerant,
    };
    let spec = GemmSpec::new(
        args.get("m", 12usize),
        args.get("n", 16usize),
        args.get("k", 16usize),
    );
    let p = GemmProblem::random(&spec, args.get("seed", 1u64));
    let golden = p.golden_z_for(cfg.format, cfg.op);
    let mut sys = System::new(cfg, protection);
    let r = sys.run_gemm(&p, mode)?;
    println!(
        "({},{},{}) [{}/{}] {} {}: {:?} in {} cycles, golden match = {}",
        spec.m,
        spec.n,
        spec.k,
        protection.name(),
        mode.name(),
        cfg.format.name(),
        cfg.op.name(),
        r.outcome,
        r.cycles,
        r.z_matches(&golden)
    );
    if !r.z_matches(&golden) {
        return Err(redmule_ft::Error::Sim("simulator diverged from golden".into()));
    }
    Ok(())
}

fn cmd_golden_check(args: &Args) -> redmule_ft::Result<()> {
    let dir = args
        .kv
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = GoldenRuntime::load(&dir)?;
    #[cfg(feature = "pjrt")]
    {
        println!("platform: {}", rt.platform());
        let mut checked = 0;
        for name in rt.names() {
            let e = rt.entry(name).unwrap().clone();
            if e.kind != "gemm" {
                continue;
            }
            let spec = GemmSpec::new(e.params[0], e.params[1], e.params[2]);
            let p = GemmProblem::random(&spec, 0xA0_7E57);
            let golden = p.golden_z();
            let z = rt.execute_gemm(name, &p.x, &p.w, &p.y)?;
            let ok = z.bits() == golden.bits();
            println!(
                "{name}: PJRT vs Rust golden — {}",
                if ok { "bit-exact" } else { "MISMATCH" }
            );
            if !ok {
                return Err(redmule_ft::Error::Sim(format!(
                    "{name}: PJRT result differs from golden"
                )));
            }
            // And against the cycle-level simulator.
            let mut sys = System::new(RedMuleConfig::paper(), Protection::Full);
            let r = sys.run_gemm(&p, ExecMode::FaultTolerant)?;
            if r.z.bits() != z.bits() {
                return Err(redmule_ft::Error::Sim(format!(
                    "{name}: simulator differs from PJRT artifact"
                )));
            }
            println!("{name}: simulator vs PJRT — bit-exact");
            checked += 1;
        }
        println!("{checked} gemm artifact(s) verified");
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = rt;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> redmule_ft::Result<()> {
    let n_tasks = args.get("tasks", 20u64);
    let critical_pct = args.get("critical-pct", 50u64).min(100);
    let mut coord = Coordinator::new(args.redmule_cfg(), args.protection());
    let mut rng = Xoshiro256::new(args.get("seed", 7u64));
    for _ in 0..n_tasks {
        let crit = if rng.below(100) < critical_pct {
            Criticality::Critical
        } else {
            Criticality::BestEffort
        };
        let spec = GemmSpec::new(
            2 + rng.below(11) as usize,
            4 + rng.below(29) as usize,
            4 + rng.below(29) as usize,
        );
        coord.submit(crit, GemmProblem::random(&spec, rng.next_u64()));
    }
    let done = coord.run_to_idle()?;
    let m = &coord.metrics;
    println!(
        "completed {done}/{} (after-retry {}, requeued {}, failed {})",
        m.submitted, m.completed_after_retry, m.requeued, m.failed
    );
    println!(
        "cycles: critical {}  best-effort {}  config {}  total {}",
        m.critical_cycles,
        m.best_effort_cycles,
        m.config_cycles,
        m.total_cycles()
    );
    Ok(())
}

/// The deterministic job mix of `serve-sim`: consecutive pairs share a
/// clean-run identity (protection + campaign seed), so the shared
/// [`redmule_ft::campaign::TraceCache`] is genuinely exercised across
/// jobs; odd jobs run the adaptive batch schedule so progress streams
/// and batch barriers are exercised too. Both the service arm and the
/// `--baseline` arm build jobs through this one function — that is what
/// makes their byte-for-byte comparison meaningful.
fn serve_sim_job_config(seed: u64, injections: u64, i: u64) -> CampaignConfig {
    const PROTS: [Protection; 4] = [
        Protection::Full,
        Protection::Abft,
        Protection::Data,
        Protection::AbftOnline,
    ];
    let family = i / 2;
    let protection = PROTS[(family % 4) as usize];
    let job_seed = seed.wrapping_add(family.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut cfg = CampaignConfig::table1(protection, injections, job_seed);
    cfg.threads = 1;
    if i % 2 == 1 {
        cfg.precision_target = 0.05;
        cfg.batch_size = (injections / 4).max(8);
    }
    cfg
}

/// Schedule-invariant count fields of one campaign result — exactly the
/// fields the service's byte-identity contract covers (no wall-clock
/// throughput, no scheduler telemetry).
fn result_json(r: &CampaignResult) -> String {
    let strata: Vec<String> = r
        .strata
        .iter()
        .map(|s| {
            format!(
                "{{\"name\":\"{}\",\"n\":{},\"outcomes\":[{},{},{},{}]}}",
                s.name, s.n, s.outcomes[0], s.outcomes[1], s.outcomes[2], s.outcomes[3]
            )
        })
        .collect();
    format!(
        "{{\"total\":{},\"correct_no_retry\":{},\"correct_with_retry\":{},\"incorrect\":{},\
         \"timeout\":{},\"applied\":{},\"faults_applied\":{},\"corrections\":{},\
         \"band_recomputes\":{},\"batches\":{},\"stopped_early\":{},\"strata\":[{}]}}",
        r.total,
        r.correct_no_retry,
        r.correct_with_retry,
        r.incorrect,
        r.timeout,
        r.applied,
        r.faults_applied,
        r.corrections,
        r.band_recomputes,
        r.batches,
        r.stopped_early,
        strata.join(",")
    )
}

fn job_json(
    id: u64,
    priority: i32,
    protection: Protection,
    outcome: &str,
    result: Option<&CampaignResult>,
) -> String {
    format!(
        "{{\"id\":{id},\"protection\":\"{}\",\"priority\":{priority},\"outcome\":\"{outcome}\",\"result\":{}}}",
        protection.name(),
        result.map_or_else(|| "null".to_string(), result_json)
    )
}

fn cmd_serve_sim(args: &Args) -> redmule_ft::Result<()> {
    let n_jobs = args.get("jobs", 6u64);
    let seed = args.get("seed", 2025u64);
    let injections = args.get("injections", 400u64);
    let profile = args
        .kv
        .get("fault-profile")
        .map(String::as_str)
        .unwrap_or("none");
    let plan = ServiceFaultPlan::by_name(profile).ok_or_else(|| {
        redmule_ft::Error::Config(format!(
            "unknown --fault-profile '{profile}' (none|drop|dup|delay|crash|chaos)"
        ))
    })?;
    let cancel_pct = args.get("cancel-pct", 0u64).min(100);

    if args.flag("baseline") {
        // Ground truth: the same jobs through the plain single-threaded
        // engine. The service arm under any fault profile (with no
        // cancellations) must print this document byte for byte.
        let mut jobs = Vec::new();
        for i in 0..n_jobs {
            let cfg = serve_sim_job_config(seed, injections, i);
            let protection = cfg.protection;
            let mut r = Campaign::run(&cfg)?;
            r.wall_seconds = 0.0;
            jobs.push(job_json(i, (i % 3) as i32, protection, "completed", Some(&r)));
        }
        println!(
            "{{\"schema\":\"redmule-ft/service-v1\",\"seed\":{seed},\"injections\":{injections},\
             \"jobs\":[{}],\"cache_resident\":0}}",
            jobs.join(",")
        );
        return Ok(());
    }

    let mut sc = ServiceConfig::new(seed);
    sc.workers = args.get("workers", 3u64).max(1) as usize;
    sc.chunk_injections = args.get("chunk", 64u64);
    sc.fault_plan = plan;
    let mut svc = CampaignService::new(sc)?;
    let mut cancel_rng = Xoshiro256::new(seed ^ 0x5245_444D_5343_414E); // "REDMSCAN"
    for i in 0..n_jobs {
        let cfg = serve_sim_job_config(seed, injections, i);
        let id = svc.submit(JobSpec::new(cfg).with_priority((i % 3) as i32));
        if cancel_rng.below(100) < cancel_pct {
            svc.cancel_at(id, 1 + cancel_rng.below(5_000));
        }
    }
    let report = svc.run()?;

    let mut jobs = Vec::new();
    let mut mismatches = 0u64;
    for jr in &report.jobs {
        let cfg = serve_sim_job_config(seed, injections, jr.id);
        let protection = cfg.protection;
        let (name, result) = match &jr.outcome {
            JobOutcome::Completed(r) => ("completed", Some(r)),
            JobOutcome::Cancelled => ("cancelled", None),
            JobOutcome::Failed(_) => ("failed", None),
        };
        jobs.push(job_json(jr.id, jr.priority, protection, name, result));
        eprintln!(
            "job {}: {} ({} requeues, {} progress points)",
            jr.id,
            name,
            jr.requeues,
            jr.progress.len()
        );
        if args.flag("verify") {
            if let JobOutcome::Completed(r) = &jr.outcome {
                let mut want = Campaign::run(&cfg)?;
                want.wall_seconds = 0.0;
                if result_json(r) != result_json(&want) {
                    mismatches += 1;
                    eprintln!("job {}: MISMATCH vs the single-threaded engine", jr.id);
                }
            }
        }
    }
    println!(
        "{{\"schema\":\"redmule-ft/service-v1\",\"seed\":{seed},\"injections\":{injections},\
         \"jobs\":[{}],\"cache_resident\":{}}}",
        jobs.join(","),
        report.trace_cache_resident
    );
    let t = &report.telemetry;
    eprintln!(
        "serve-sim: profile {profile}, {} events to vt {}, {} msgs ({} dropped, {} duplicated), \
         {} crashes, {} kills, {} requeues, {} stale dones, {} stale runs",
        t.events,
        t.virtual_time,
        t.msgs_sent,
        t.msgs_dropped,
        t.msgs_duplicated,
        t.worker_crashes,
        t.workers_killed,
        t.chunk_requeues,
        t.stale_dones,
        t.stale_runs
    );
    if report.trace_cache_resident != 0 {
        return Err(redmule_ft::Error::Sim(format!(
            "trace cache still holds {} entries after every job terminated",
            report.trace_cache_resident
        )));
    }
    if mismatches > 0 {
        return Err(redmule_ft::Error::Sim(format!(
            "{mismatches} completed job(s) diverged from the single-threaded engine"
        )));
    }
    Ok(())
}
