//! Bit-accurate floating-point arithmetic for the RedMulE datapath.
//!
//! RedMulE's compute elements (CEs) are FPnew-derived **fused**
//! multiply-add units operating on IEEE-754 binary16 (and, in hybrid mode,
//! widening from FP8 inputs). For the fault-injection campaign the
//! simulator must classify a run as *Incorrect* only when the accelerator's
//! result differs bit-for-bit from the fault-free result, so the model
//! needs FMA numerics that exactly match both the hardware semantics
//! (single rounding, round-to-nearest-even) and the Layer-1 Pallas golden
//! kernel (which computes `fp16(f64(x)*f64(w) + f64(acc))`; see
//! `python/compile/kernels/redmule.py` for why that is single-rounding
//! equivalent).
//!
//! Two independent implementations are provided and cross-checked in tests:
//!
//! * [`fma::fma16`] — exact integer arithmetic (i128 alignment + one final
//!   round-to-nearest-even). This is the reference used by the simulator.
//! * [`fma::fma16_via_f64`] — `f64` arithmetic followed by a correctly
//!   rounded `f64 → fp16` conversion. By the innocuous-double-rounding
//!   theorem (Figueroa), rounding an exact ≤46-bit intermediate through 53
//!   bits and then to 11 bits equals a single rounding, so the two paths
//!   must agree bit-for-bit on every input.

pub mod fma;
pub mod fp16;
pub mod fp8;
pub mod ops;

pub use fma::{add16, fma16, mul16};
pub use fp16::Fp16;
pub use fp8::{Fp8, Fp8Format};
pub use ops::{max16, min16, op_step16, GemmFormat, GemmOp};
