//! FP8 formats (E4M3 / E5M2) for RedMulE's hybrid-FP8 input mode.
//!
//! RedMulE supports a hybrid mode where the `X` and `W` inputs are stored
//! as FP8 and widened to FP16 inside the streamer before entering the CE
//! array (compute and accumulation stay FP16). Both OCP FP8 formats are
//! supported:
//!
//! * **E4M3** — 1-4-3, bias 7, *no infinities*; `S.1111.111` is NaN and
//!   `S.1111.110` is the largest finite value (±448).
//! * **E5M2** — 1-5-2, bias 15, IEEE-like with infinities and NaNs.
//!
//! Decoding to FP16 is exact for every finite FP8 value in either format.

use super::fp16::Fp16;
use super::fma::round_to_fp16;

/// Which 8-bit floating-point encoding a [`Fp8`] byte uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fp8Format {
    E4M3,
    E5M2,
}

/// An 8-bit float: raw byte plus its format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fp8 {
    pub bits: u8,
    pub format: Fp8Format,
}

impl Fp8 {
    pub fn new(bits: u8, format: Fp8Format) -> Self {
        Self { bits, format }
    }

    pub fn sign(self) -> u16 {
        (self.bits >> 7) as u16
    }

    pub fn is_nan(self) -> bool {
        match self.format {
            // E4M3: only S.1111.111 is NaN (no infinities exist).
            Fp8Format::E4M3 => self.bits & 0x7F == 0x7F,
            Fp8Format::E5M2 => (self.bits & 0x7C == 0x7C) && (self.bits & 0x03 != 0),
        }
    }

    pub fn is_infinite(self) -> bool {
        match self.format {
            Fp8Format::E4M3 => false,
            Fp8Format::E5M2 => self.bits & 0x7F == 0x7C,
        }
    }

    /// Exact widening to FP16 (the streamer's decode step in hybrid mode).
    pub fn to_fp16(self) -> Fp16 {
        if self.is_nan() {
            return Fp16::NAN;
        }
        if self.is_infinite() {
            return if self.sign() == 1 { Fp16::NEG_INFINITY } else { Fp16::INFINITY };
        }
        let s = self.sign();
        let (exp_bits, man_bits, bias) = match self.format {
            Fp8Format::E4M3 => (4u32, 3u32, 7i32),
            Fp8Format::E5M2 => (5u32, 2u32, 15i32),
        };
        let e = ((self.bits >> man_bits) & ((1 << exp_bits) - 1)) as i32;
        let f = (self.bits & ((1 << man_bits) - 1)) as u32;
        if e == 0 && f == 0 {
            return Fp16(s << 15);
        }
        let (mag, exp) = if e == 0 {
            (f, 1 - bias - man_bits as i32) // subnormal
        } else {
            (f | (1 << man_bits), e - bias - man_bits as i32)
        };
        // Every finite FP8 fits exactly in FP16 (E4M3 max 448, min 2^-9;
        // E5M2 is a strict subset), so round_to_fp16 never actually rounds.
        Fp16(round_to_fp16(s, mag as u128, exp))
    }

    /// Round-to-nearest-even narrowing from FP16.
    ///
    /// `saturate` selects OCP "saturating" conversion (overflow clamps to
    /// the maximum finite value) vs. non-saturating (overflow produces NaN
    /// for E4M3 / ±inf for E5M2).
    pub fn from_fp16(x: Fp16, format: Fp8Format, saturate: bool) -> Fp8 {
        let v = x.to_f64();
        Self::from_f64(v, format, saturate)
    }

    /// Round-to-nearest-even conversion from f64 (single rounding for any
    /// value already rounded to ≤ 22 significant bits, which covers FP16).
    pub fn from_f64(v: f64, format: Fp8Format, saturate: bool) -> Fp8 {
        let (exp_bits, man_bits, bias): (u32, u32, i32) = match format {
            Fp8Format::E4M3 => (4, 3, 7),
            Fp8Format::E5M2 => (5, 2, 15),
        };
        let nan = match format {
            Fp8Format::E4M3 => 0x7Fu8,
            Fp8Format::E5M2 => 0x7Eu8,
        };
        if v.is_nan() {
            return Fp8::new(nan, format);
        }
        let s = u8::from(v.is_sign_negative());
        let max_finite: f64 = match format {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        };
        let overflow = |s: u8| -> Fp8 {
            if saturate {
                let maxbits = match format {
                    Fp8Format::E4M3 => 0x7Eu8, // S.1111.110 = 448
                    Fp8Format::E5M2 => 0x7Bu8, // S.11110.11 = 57344
                };
                Fp8::new((s << 7) | maxbits, format)
            } else {
                match format {
                    Fp8Format::E4M3 => Fp8::new(nan, format),
                    Fp8Format::E5M2 => Fp8::new((s << 7) | 0x7C, format),
                }
            }
        };
        if v.is_infinite() {
            return if saturate {
                overflow(s)
            } else {
                match format {
                    Fp8Format::E4M3 => Fp8::new(nan, format),
                    Fp8Format::E5M2 => Fp8::new((s << 7) | 0x7C, format),
                }
            };
        }
        let a = v.abs();
        if a == 0.0 {
            return Fp8::new(s << 7, format);
        }

        // Decompose |v| = mant * 2^exp exactly from the f64 bits.
        let bits = a.to_bits();
        let e_field = ((bits >> 52) & 0x7FF) as i32;
        let frac = bits & 0x000F_FFFF_FFFF_FFFF;
        let (mant, exp) = if e_field == 0 {
            (frac as u128, -1074i32)
        } else {
            ((frac | (1 << 52)) as u128, e_field - 1075)
        };
        let nb = 127 - mant.leading_zeros() as i32;
        let e = nb + exp;
        let emin = 1 - bias; // smallest normal exponent
        let subnormal = e < emin;
        let q = if subnormal {
            emin - man_bits as i32
        } else {
            e - man_bits as i32
        };
        let shift = exp - q;
        let r: u128 = if shift >= 0 {
            mant << shift.min(40)
        } else {
            let sh = (-shift) as u32;
            if sh > 127 {
                0
            } else {
                let keep = mant >> sh;
                let rem = mant & ((1u128 << sh) - 1);
                let half = 1u128 << (sh - 1);
                if rem > half || (rem == half && keep & 1 == 1) {
                    keep + 1
                } else {
                    keep
                }
            }
        };
        let hidden = 1u128 << man_bits;
        if subnormal {
            if r == 0 {
                return Fp8::new(s << 7, format);
            }
            if r >= hidden {
                return Fp8::new((s << 7) | (1 << man_bits), format); // min normal
            }
            return Fp8::new((s << 7) | r as u8, format);
        }
        let (mut r, mut e) = (r, e);
        if r == hidden << 1 {
            r = hidden;
            e += 1;
        }
        // Check overflow against the format's max finite value.
        let val = r as f64 * 2f64.powi(e - nb_of(r)); // |rounded| value
        if val > max_finite {
            return overflow(s);
        }
        let e_fld = (e + bias) as u8;
        debug_assert!(e_fld < (1 << exp_bits));
        let enc = (s << 7) | (e_fld << man_bits) | (r & (hidden - 1)) as u8;
        // E4M3: the encoding S.1111.111 is NaN; value 464+ was caught by the
        // overflow check (448 is S.1111.110), so enc != NaN-pattern here
        // unless val == 464 rounded from (448,480)... guard explicitly.
        if format == Fp8Format::E4M3 && enc & 0x7F == 0x7F {
            return overflow(s);
        }
        Fp8::new(enc, format)
    }
}

fn nb_of(r: u128) -> i32 {
    127 - r.leading_zeros() as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_decode_known_values() {
        // 0x3F: e=7 f=7 -> (8+7)*2^(7-7-3) = 15/8 = 1.875
        assert_eq!(Fp8::new(0x3F, Fp8Format::E4M3).to_fp16().to_f64(), 1.875);
        // Max finite 0x7E = 448.
        assert_eq!(Fp8::new(0x7E, Fp8Format::E4M3).to_fp16().to_f64(), 448.0);
        // 0x7F is NaN, no infinities.
        assert!(Fp8::new(0x7F, Fp8Format::E4M3).to_fp16().is_nan());
        assert!(!Fp8::new(0x7F, Fp8Format::E4M3).is_infinite());
        // Smallest subnormal 2^-9.
        assert_eq!(Fp8::new(0x01, Fp8Format::E4M3).to_fp16().to_f64(), 2f64.powi(-9));
        // Signed zero.
        assert_eq!(Fp8::new(0x80, Fp8Format::E4M3).to_fp16().0, 0x8000);
    }

    #[test]
    fn e5m2_decode_known_values() {
        assert_eq!(Fp8::new(0x3C, Fp8Format::E5M2).to_fp16().to_f64(), 1.0);
        assert_eq!(Fp8::new(0x7B, Fp8Format::E5M2).to_fp16().to_f64(), 57344.0);
        assert!(Fp8::new(0x7C, Fp8Format::E5M2).to_fp16().is_infinite());
        assert!(Fp8::new(0x7D, Fp8Format::E5M2).to_fp16().is_nan());
        // Smallest subnormal 2^-16.
        assert_eq!(Fp8::new(0x01, Fp8Format::E5M2).to_fp16().to_f64(), 2f64.powi(-16));
    }

    #[test]
    fn round_trip_all_fp8_values_exact() {
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for bits in 0u16..=255 {
                let x = Fp8::new(bits as u8, fmt);
                let wide = x.to_fp16();
                if wide.is_nan() {
                    continue;
                }
                let back = Fp8::from_fp16(wide, fmt, false);
                assert_eq!(back.bits, x.bits, "fmt={fmt:?} bits=0x{bits:02X} wide={wide:?}");
            }
        }
    }

    #[test]
    fn narrowing_rounds_to_nearest_even() {
        // E4M3 around 1.0: ulp = 2^-3 = 0.125. 1.0625 is halfway -> 1.0 (even).
        let y = Fp8::from_f64(1.0625, Fp8Format::E4M3, false);
        assert_eq!(y.to_fp16().to_f64(), 1.0);
        let y = Fp8::from_f64(1.0626, Fp8Format::E4M3, false);
        assert_eq!(y.to_fp16().to_f64(), 1.125);
    }

    #[test]
    fn e4m3_overflow_behaviour() {
        // Non-saturating: overflow -> NaN (E4M3 has no inf).
        assert!(Fp8::from_f64(1000.0, Fp8Format::E4M3, false).is_nan());
        // Saturating: clamps to 448.
        let s = Fp8::from_f64(1000.0, Fp8Format::E4M3, true);
        assert_eq!(s.to_fp16().to_f64(), 448.0);
        // Boundary: everything in (448, 464] rounds back to 448 — including
        // 464.0 itself, which is a tie and rounds to the even significand
        // (14) rather than the phantom odd one (15). Above 464 overflows.
        assert_eq!(Fp8::from_f64(463.9, Fp8Format::E4M3, false).to_fp16().to_f64(), 448.0);
        assert_eq!(Fp8::from_f64(464.0, Fp8Format::E4M3, false).to_fp16().to_f64(), 448.0);
        assert!(Fp8::from_f64(464.1, Fp8Format::E4M3, false).is_nan());
    }

    #[test]
    fn e5m2_overflow_behaviour() {
        assert!(Fp8::from_f64(1e9, Fp8Format::E5M2, false).is_infinite());
        let s = Fp8::from_f64(1e9, Fp8Format::E5M2, true);
        assert_eq!(s.to_fp16().to_f64(), 57344.0);
    }

    #[test]
    fn underflow_to_zero_and_subnormals() {
        // E4M3 smallest subnormal is 2^-9; half of it ties to even (0).
        assert_eq!(Fp8::from_f64(2f64.powi(-10), Fp8Format::E4M3, false).bits, 0);
        assert_eq!(
            Fp8::from_f64(2f64.powi(-10) * 1.001, Fp8Format::E4M3, false).bits,
            0x01
        );
        assert_eq!(Fp8::from_f64(-2f64.powi(-9), Fp8Format::E4M3, false).bits, 0x81);
    }
}
