//! GEMM numeric formats and the computation-op family.
//!
//! Upstream RedMulE is not a pure FP16 multiply-accumulate engine: the
//! streamers carry FP8↔FP16 casting units on the input and output paths
//! (hybrid-FP8 mode), and the scheduler supports a family of GEMM-shaped
//! reductions `Z = (X op1 W) op2 Z` beyond FMA — element-wise add/mul
//! combined with a running max/min. Both knobs live here as plain enums so
//! every layer (config → golden model → fault sites → sweep axes) speaks
//! the same vocabulary.
//!
//! * [`GemmFormat`] — the *storage* format of the operands. `Fp16` is the
//!   paper instance and the crate-wide default. `Fp8(_)` keeps compute and
//!   accumulation in FP16 but stores operands on the FP8 grid: a cast-in
//!   unit narrows-then-widens every fetched value (idempotent when the
//!   value is already on the grid) and a cast-out unit narrows every
//!   stored result. The cast units are modelled as real pipeline
//!   components with their own fault-site populations (`dp/castin*`,
//!   `dp/castout*` in [`crate::area`] / [`crate::fault::registry`]).
//! * [`GemmOp`] — which reduction step each CE performs. Only
//!   [`GemmOp::Mul`] (fused multiply-add) satisfies the linear checksum
//!   identity that ABFT relies on; the max/min family is rejected on ABFT
//!   builds up front (see [`GemmOp::is_linear`]).
//!
//! [`op_step16`] is the single shared definition of one reduction step,
//! used by the CE array, the per-CE recompute checkers and the golden
//! model, so the three can never drift apart.

use super::fma::{add16, fma16, mul16};
use super::fp16::Fp16;
use super::fp8::{Fp8, Fp8Format};

/// Numeric storage format of a GEMM task (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmFormat {
    /// IEEE binary16 end to end — the paper instance and the default.
    Fp16,
    /// FP8 storage grid with FP16 compute: cast-in on fetch, cast-out on
    /// store. The TCDM still holds 16-bit carriers (task layout, DMA and
    /// ECC are unchanged); the *values* are constrained to the FP8 grid.
    Fp8(Fp8Format),
}

impl GemmFormat {
    pub const ALL: [GemmFormat; 3] = [
        GemmFormat::Fp16,
        GemmFormat::Fp8(Fp8Format::E4M3),
        GemmFormat::Fp8(Fp8Format::E5M2),
    ];

    pub fn name(self) -> &'static str {
        match self {
            GemmFormat::Fp16 => "fp16",
            GemmFormat::Fp8(Fp8Format::E4M3) => "fp8-e4m3",
            GemmFormat::Fp8(Fp8Format::E5M2) => "fp8-e5m2",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fp16" => Some(GemmFormat::Fp16),
            "fp8-e4m3" | "e4m3" => Some(GemmFormat::Fp8(Fp8Format::E4M3)),
            "fp8-e5m2" | "e5m2" => Some(GemmFormat::Fp8(Fp8Format::E5M2)),
            _ => None,
        }
    }

    /// Does this format route values through the cast units?
    #[inline]
    pub fn is_fp8(self) -> bool {
        matches!(self, GemmFormat::Fp8(_))
    }

    /// Unit roundoff of the storage grid: the maximum *relative* error of
    /// rounding a real number to the nearest representable value. This is
    /// what makes the ABFT residual tolerance format-aware: checksum
    /// residuals on an FP8 grid carry quantization noise proportional to
    /// this bound instead of FP16's (see
    /// [`crate::golden::abft_tolerance_scaled_for`]).
    pub fn unit_roundoff(self) -> f64 {
        match self {
            // binary16: 11-bit significand, u = 2^-11 = 1/2048 (= EPS16).
            GemmFormat::Fp16 => 2f64.powi(-11),
            // E4M3: 4-bit significand, u = 2^-4.
            GemmFormat::Fp8(Fp8Format::E4M3) => 2f64.powi(-4),
            // E5M2: 3-bit significand, u = 2^-3.
            GemmFormat::Fp8(Fp8Format::E5M2) => 2f64.powi(-3),
        }
    }

    /// Snap one FP16 value onto this format's storage grid (saturating
    /// RTNE narrowing + exact widening). Identity for [`GemmFormat::Fp16`]
    /// and idempotent for all formats — the clean cast-in of a value that
    /// is already on the grid returns it unchanged.
    #[inline]
    pub fn snap(self, v: Fp16) -> Fp16 {
        match self {
            GemmFormat::Fp16 => v,
            GemmFormat::Fp8(f) => Fp8::from_fp16(v, f, true).to_fp16(),
        }
    }
}

impl Default for GemmFormat {
    fn default() -> Self {
        GemmFormat::Fp16
    }
}

/// Which reduction step each CE performs: `acc ← (x op1 w) op2 acc`.
///
/// [`GemmOp::Mul`] is the classic GEMM (`op1 = ×` fused with `op2 = +`
/// into a single-rounding FMA). The other four combine an element-wise
/// stage (`add`/`mul`, each individually rounded) with a running
/// `max`/`min` — the upstream datapath's op family used for pooling-like
/// and tropical-algebra workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmOp {
    /// `acc ← fma(x, w, acc)` — single rounding, the default.
    Mul,
    /// `acc ← max(x + w, acc)`.
    AddMax,
    /// `acc ← min(x + w, acc)`.
    AddMin,
    /// `acc ← max(x × w, acc)`.
    MulMax,
    /// `acc ← min(x × w, acc)`.
    MulMin,
}

impl GemmOp {
    pub const ALL: [GemmOp; 5] = [
        GemmOp::Mul,
        GemmOp::AddMax,
        GemmOp::AddMin,
        GemmOp::MulMax,
        GemmOp::MulMin,
    ];

    pub fn name(self) -> &'static str {
        match self {
            GemmOp::Mul => "mul",
            GemmOp::AddMax => "addmax",
            GemmOp::AddMin => "addmin",
            GemmOp::MulMax => "mulmax",
            GemmOp::MulMin => "mulmin",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mul" | "gemm" => Some(GemmOp::Mul),
            "addmax" => Some(GemmOp::AddMax),
            "addmin" => Some(GemmOp::AddMin),
            "mulmax" => Some(GemmOp::MulMax),
            "mulmin" => Some(GemmOp::MulMin),
            _ => None,
        }
    }

    /// Does the reduction satisfy the linear checksum identity
    /// (`checksum(Z) = checksum(X)·W`) that ABFT relies on? Only the FMA
    /// reduction does; max/min reductions are rejected on ABFT builds.
    #[inline]
    pub fn is_linear(self) -> bool {
        matches!(self, GemmOp::Mul)
    }
}

impl Default for GemmOp {
    fn default() -> Self {
        GemmOp::Mul
    }
}

/// Monotone total-order key: `a.to_f64() < b.to_f64() ⇔ key(a) < key(b)`
/// for non-NaN values, with `+0` strictly above `−0` so max/min ties on
/// signed zeros are deterministic at the bit level.
#[inline]
fn ord_key(x: Fp16) -> u16 {
    let b = x.to_bits();
    if b & 0x8000 != 0 {
        !b
    } else {
        b | 0x8000
    }
}

/// IEEE-754 `maxNum` on binary16: the larger operand; a quiet-NaN operand
/// loses to a non-NaN one; two NaNs give the canonical NaN. Ties on
/// `±0` pick `+0`.
#[inline]
pub fn max16(a: Fp16, b: Fp16) -> Fp16 {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Fp16::NAN,
        (true, false) => b,
        (false, true) => a,
        (false, false) => {
            if ord_key(a) >= ord_key(b) {
                a
            } else {
                b
            }
        }
    }
}

/// IEEE-754 `minNum` on binary16 (see [`max16`]). Ties on `±0` pick `−0`.
#[inline]
pub fn min16(a: Fp16, b: Fp16) -> Fp16 {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Fp16::NAN,
        (true, false) => b,
        (false, true) => a,
        (false, false) => {
            if ord_key(a) <= ord_key(b) {
                a
            } else {
                b
            }
        }
    }
}

/// One reduction step of the op family: `(x op1 w) op2 acc`.
///
/// This is the single definition shared by the CE array, the per-CE
/// recompute checkers and the golden model.
#[inline]
pub fn op_step16(op: GemmOp, x: Fp16, w: Fp16, acc: Fp16) -> Fp16 {
    match op {
        GemmOp::Mul => fma16(x, w, acc),
        GemmOp::AddMax => max16(add16(x, w), acc),
        GemmOp::AddMin => min16(add16(x, w), acc),
        GemmOp::MulMax => max16(mul16(x, w), acc),
        GemmOp::MulMin => min16(mul16(x, w), acc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_names_round_trip() {
        for f in GemmFormat::ALL {
            assert_eq!(GemmFormat::parse(f.name()), Some(f));
        }
        assert_eq!(GemmFormat::parse("e4m3"), Some(GemmFormat::Fp8(Fp8Format::E4M3)));
        assert_eq!(GemmFormat::parse("nope"), None);
        assert_eq!(GemmFormat::default(), GemmFormat::Fp16);
    }

    #[test]
    fn op_names_round_trip() {
        for o in GemmOp::ALL {
            assert_eq!(GemmOp::parse(o.name()), Some(o));
        }
        assert_eq!(GemmOp::parse("gemm"), Some(GemmOp::Mul));
        assert_eq!(GemmOp::parse("nope"), None);
        assert_eq!(GemmOp::default(), GemmOp::Mul);
        assert!(GemmOp::Mul.is_linear());
        for o in [GemmOp::AddMax, GemmOp::AddMin, GemmOp::MulMax, GemmOp::MulMin] {
            assert!(!o.is_linear(), "{o:?}");
        }
    }

    #[test]
    fn unit_roundoff_ordering() {
        let u16_ = GemmFormat::Fp16.unit_roundoff();
        let e4 = GemmFormat::Fp8(Fp8Format::E4M3).unit_roundoff();
        let e5 = GemmFormat::Fp8(Fp8Format::E5M2).unit_roundoff();
        assert_eq!(u16_, 1.0 / 2048.0);
        assert_eq!(e4, 1.0 / 16.0);
        assert_eq!(e5, 1.0 / 8.0);
        assert!(u16_ < e4 && e4 < e5);
    }

    #[test]
    fn snap_is_identity_for_fp16_and_idempotent_for_fp8() {
        for bits in (0u16..=0xFFFF).step_by(11) {
            let v = Fp16(bits);
            if v.is_nan() {
                continue;
            }
            assert_eq!(GemmFormat::Fp16.snap(v), v);
            for f in [Fp8Format::E4M3, Fp8Format::E5M2] {
                let g = GemmFormat::Fp8(f);
                let once = g.snap(v);
                assert_eq!(g.snap(once), once, "bits=0x{bits:04X} fmt={f:?}");
            }
        }
    }

    #[test]
    fn max_min_follow_ieee_nan_and_zero_rules() {
        let two = Fp16::from_f64(2.0);
        assert_eq!(max16(Fp16::ONE, two), two);
        assert_eq!(min16(Fp16::ONE, two), Fp16::ONE);
        assert_eq!(max16(Fp16::NEG_ONE, Fp16::ONE), Fp16::ONE);
        // NaN loses to a number; two NaNs canonicalize.
        assert_eq!(max16(Fp16::NAN, Fp16::ONE), Fp16::ONE);
        assert_eq!(min16(Fp16::ONE, Fp16::NAN), Fp16::ONE);
        assert!(max16(Fp16::NAN, Fp16::NAN).is_nan());
        // Signed-zero ties are deterministic: max → +0, min → −0.
        assert_eq!(max16(Fp16::ZERO, Fp16::NEG_ZERO).to_bits(), 0x0000);
        assert_eq!(max16(Fp16::NEG_ZERO, Fp16::ZERO).to_bits(), 0x0000);
        assert_eq!(min16(Fp16::ZERO, Fp16::NEG_ZERO).to_bits(), 0x8000);
        assert_eq!(min16(Fp16::NEG_ZERO, Fp16::ZERO).to_bits(), 0x8000);
        // Infinities order correctly.
        assert_eq!(max16(Fp16::INFINITY, two), Fp16::INFINITY);
        assert_eq!(min16(Fp16::NEG_INFINITY, two), Fp16::NEG_INFINITY);
    }

    #[test]
    fn op_step_matches_componentwise_reference() {
        // Cross-check against f64 componentwise evaluation on a grid of
        // exact values (no double-rounding hazard at these magnitudes).
        let vals: Vec<Fp16> = [-4.0, -1.5, -0.5, 0.0, 0.25, 1.0, 3.0]
            .iter()
            .map(|&v| Fp16::from_f64(v))
            .collect();
        for &x in &vals {
            for &w in &vals {
                for &acc in &vals {
                    let am = op_step16(GemmOp::AddMax, x, w, acc).to_f64();
                    assert_eq!(am, (x.to_f64() + w.to_f64()).max(acc.to_f64()));
                    let mm = op_step16(GemmOp::MulMin, x, w, acc).to_f64();
                    assert_eq!(mm, (x.to_f64() * w.to_f64()).min(acc.to_f64()));
                    assert_eq!(
                        op_step16(GemmOp::Mul, x, w, acc),
                        fma16(x, w, acc)
                    );
                }
            }
        }
    }
}
