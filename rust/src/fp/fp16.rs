//! IEEE-754 binary16 representation and conversions.

/// An IEEE-754 binary16 value, stored as its raw bit pattern.
///
/// Layout: `[15] sign | [14:10] exponent (bias 15) | [9:0] fraction`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Fp16(pub u16);

pub const EXP_BIAS: i32 = 15;
pub const FRAC_BITS: u32 = 10;
pub const EXP_BITS: u32 = 5;
pub const EXP_MAX_FIELD: u16 = 0x1F;

impl Fp16 {
    pub const ZERO: Fp16 = Fp16(0x0000);
    pub const NEG_ZERO: Fp16 = Fp16(0x8000);
    pub const ONE: Fp16 = Fp16(0x3C00);
    pub const NEG_ONE: Fp16 = Fp16(0xBC00);
    pub const INFINITY: Fp16 = Fp16(0x7C00);
    pub const NEG_INFINITY: Fp16 = Fp16(0xFC00);
    /// Canonical quiet NaN (matches FPnew's canonical NaN output).
    pub const NAN: Fp16 = Fp16(0x7E00);
    /// Largest finite value: 65504.
    pub const MAX: Fp16 = Fp16(0x7BFF);
    /// Smallest positive normal: 2^-14.
    pub const MIN_POSITIVE: Fp16 = Fp16(0x0400);
    /// Smallest positive subnormal: 2^-24.
    pub const MIN_SUBNORMAL: Fp16 = Fp16(0x0001);

    #[inline]
    pub fn from_bits(bits: u16) -> Self {
        Fp16(bits)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn sign(self) -> u16 {
        self.0 >> 15
    }

    #[inline]
    pub fn exp_field(self) -> u16 {
        (self.0 >> FRAC_BITS) & EXP_MAX_FIELD
    }

    #[inline]
    pub fn frac(self) -> u16 {
        self.0 & 0x3FF
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        self.exp_field() == EXP_MAX_FIELD && self.frac() != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        self.exp_field() == EXP_MAX_FIELD && self.frac() == 0
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 & 0x7FFF == 0
    }

    #[inline]
    pub fn is_subnormal(self) -> bool {
        self.exp_field() == 0 && self.frac() != 0
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.exp_field() != EXP_MAX_FIELD
    }

    /// Decode a finite non-zero value as `(sign, magnitude, exp2)` with
    /// `|value| = magnitude * 2^exp2` and `magnitude` an integer.
    #[inline]
    pub fn decode(self) -> (u16, u32, i32) {
        debug_assert!(self.is_finite());
        let e = self.exp_field();
        let f = self.frac() as u32;
        if e == 0 {
            // Subnormal: f * 2^-24.
            (self.sign(), f, -24)
        } else {
            // Normal: (1024 + f) * 2^(e - 15 - 10).
            (self.sign(), 1024 + f, e as i32 - EXP_BIAS - FRAC_BITS as i32)
        }
    }

    /// Exact widening conversion to `f64` (every binary16 is representable).
    pub fn to_f64(self) -> f64 {
        let s = if self.sign() == 1 { -1.0 } else { 1.0 };
        if self.is_nan() {
            return f64::NAN;
        }
        if self.is_infinite() {
            return s * f64::INFINITY;
        }
        if self.is_zero() {
            return s * 0.0;
        }
        let (_, m, e) = self.decode();
        s * (m as f64) * (e as f64).exp2()
    }

    /// Exact widening conversion to `f32`.
    pub fn to_f32(self) -> f32 {
        self.to_f64() as f32 // binary16 ⊂ binary32, so this is exact
    }

    /// Correctly rounded (RN-even) conversion from `f64`.
    pub fn from_f64(v: f64) -> Self {
        if v.is_nan() {
            return Fp16::NAN;
        }
        let sign = if v.is_sign_negative() { 1u16 } else { 0u16 };
        if v.is_infinite() {
            return if sign == 1 { Fp16::NEG_INFINITY } else { Fp16::INFINITY };
        }
        if v == 0.0 {
            return Fp16(sign << 15);
        }
        // Decompose the f64: magnitude = mant * 2^exp with mant a 52/53-bit int.
        let bits = v.abs().to_bits();
        let e_field = ((bits >> 52) & 0x7FF) as i32;
        let frac = bits & 0x000F_FFFF_FFFF_FFFF;
        let (mant, exp) = if e_field == 0 {
            (frac as u128, -1074)
        } else {
            ((frac | (1 << 52)) as u128, e_field - 1075)
        };
        Fp16(super::fma::round_to_fp16(sign, mant, exp))
    }

    /// Correctly rounded conversion from `f32` (goes through `f64`, which
    /// is exact for binary32 inputs, so the overall rounding is single).
    pub fn from_f32(v: f32) -> Self {
        Self::from_f64(v as f64)
    }
}

impl std::fmt::Debug for Fp16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Fp16(0x{:04X} = {})", self.0, self.to_f64())
    }
}

impl std::fmt::Display for Fp16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_decode_correctly() {
        assert_eq!(Fp16::ONE.to_f64(), 1.0);
        assert_eq!(Fp16::NEG_ONE.to_f64(), -1.0);
        assert_eq!(Fp16::MAX.to_f64(), 65504.0);
        assert_eq!(Fp16::MIN_POSITIVE.to_f64(), 2f64.powi(-14));
        assert_eq!(Fp16::MIN_SUBNORMAL.to_f64(), 2f64.powi(-24));
        assert!(Fp16::NAN.is_nan());
        assert!(Fp16::INFINITY.is_infinite());
        assert!(Fp16::ZERO.is_zero() && Fp16::NEG_ZERO.is_zero());
    }

    #[test]
    fn f64_round_trip_is_identity_for_all_finite_fp16() {
        // Exhaustive: every finite bit pattern survives fp16 -> f64 -> fp16.
        for bits in 0u16..=0xFFFF {
            let x = Fp16(bits);
            if x.is_nan() {
                assert!(Fp16::from_f64(x.to_f64()).is_nan());
            } else {
                assert_eq!(Fp16::from_f64(x.to_f64()).0, bits, "bits=0x{bits:04X}");
            }
        }
    }

    #[test]
    fn from_f64_rounding_cases() {
        // Halfway cases round to even.
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 -> rounds to 1.0 (even).
        assert_eq!(Fp16::from_f64(1.0 + 2f64.powi(-11)).0, Fp16::ONE.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 -> rounds to 1+2^-9 (even frac=2).
        assert_eq!(Fp16::from_f64(1.0 + 3.0 * 2f64.powi(-11)).0, 0x3C02);
        // Slightly above halfway rounds up.
        assert_eq!(Fp16::from_f64(1.0 + 2f64.powi(-11) + 2f64.powi(-30)).0, 0x3C01);
        // Overflow threshold: 65520 rounds (ties-even) to infinity.
        assert_eq!(Fp16::from_f64(65520.0).0, Fp16::INFINITY.0);
        assert_eq!(Fp16::from_f64(65519.999).0, Fp16::MAX.0);
        assert_eq!(Fp16::from_f64(-65520.0).0, Fp16::NEG_INFINITY.0);
        // Underflow to zero: below 2^-25 -> 0; exactly 2^-25 ties to even (0).
        assert_eq!(Fp16::from_f64(2f64.powi(-25)).0, 0);
        assert_eq!(Fp16::from_f64(2f64.powi(-25) * 1.0001).0, 1);
        // Subnormal rounding.
        assert_eq!(Fp16::from_f64(2f64.powi(-24) * 1.5).0, 2); // ties to even
        // Signed zero preserved.
        assert_eq!(Fp16::from_f64(-0.0).0, 0x8000);
    }

    #[test]
    fn decode_magnitudes() {
        let (s, m, e) = Fp16::ONE.decode();
        assert_eq!((s, m, e), (0, 1024, -10));
        let (s, m, e) = Fp16::MIN_SUBNORMAL.decode();
        assert_eq!((s, m, e), (0, 1, -24));
        let (s, m, e) = Fp16::MAX.decode();
        assert_eq!((s, m, e), (0, 2047, 5));
        assert_eq!(2047.0 * 32.0, 65504.0);
    }

    #[test]
    fn f32_conversions_match_f64_path() {
        for bits in (0u16..=0xFFFF).step_by(7) {
            let x = Fp16(bits);
            if !x.is_nan() {
                assert_eq!(Fp16::from_f32(x.to_f32()).0, x.0);
            }
        }
    }
}
