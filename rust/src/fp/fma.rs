//! Fused multiply-add on binary16, single-rounded, two independent paths.

use super::fp16::Fp16;

/// Round `(-1)^sign * mag * 2^exp` (with `mag > 0`, exactly represented)
/// to binary16 with round-to-nearest, ties-to-even.
///
/// This is the single rounding step shared by [`fma16`], [`mul16`] and the
/// `f64 → fp16` conversion. Overflow produces ±∞, underflow produces
/// subnormals or signed zero.
pub fn round_to_fp16(sign: u16, mag: u128, exp: i32) -> u16 {
    debug_assert!(mag != 0);
    let nb = 127 - mag.leading_zeros() as i32; // msb index
    let e = nb + exp; // value in [2^e, 2^{e+1})

    // Quantum (ulp) exponent: normals have an 11-bit significand, subnormals
    // a fixed quantum of 2^-24.
    let subnormal = e < -14;
    let q = if subnormal { -24 } else { e - 10 };

    let shift = exp - q;
    let r: u128 = if shift >= 0 {
        // Guaranteed to fit: shift <= 10 - nb in both paths.
        mag << shift
    } else {
        let sh = (-shift) as u32;
        if sh > 127 {
            // Value below half the smallest quantum: rounds to zero.
            0
        } else {
            let keep = mag >> sh;
            let rem = mag & ((1u128 << sh) - 1);
            let half = 1u128 << (sh - 1);
            if rem > half || (rem == half && keep & 1 == 1) {
                keep + 1
            } else {
                keep
            }
        }
    };

    if subnormal {
        // r <= 1024 by construction (value < 2^-14 => mag*2^(exp+24) < 2^10).
        if r == 0 {
            return sign << 15;
        }
        if r >= 1024 {
            // Rounded up to the smallest normal.
            return (sign << 15) | (1 << 10);
        }
        return (sign << 15) | r as u16;
    }

    let (mut r, mut e) = (r, e);
    if r == 2048 {
        // Rounding carried into the next binade.
        r = 1024;
        e += 1;
    }
    debug_assert!((1024..2048).contains(&(r as u32)));
    if e > 15 {
        return (sign << 15) | 0x7C00; // ±inf
    }
    (sign << 15) | (((e + 15) as u16) << 10) | (r as u16 - 1024)
}

/// Fused multiply-add `a*b + c` on binary16 with a **single** rounding,
/// computed with exact integer arithmetic. This models the hardware FMA
/// unit inside each RedMulE compute element.
pub fn fma16(a: Fp16, b: Fp16, c: Fp16) -> Fp16 {
    // IEEE-754 special-case handling (canonical quiet NaN, as FPnew emits).
    if a.is_nan() || b.is_nan() || c.is_nan() {
        return Fp16::NAN;
    }
    let sp = a.sign() ^ b.sign();
    let prod_inf = a.is_infinite() || b.is_infinite();
    if prod_inf {
        if a.is_zero() || b.is_zero() {
            return Fp16::NAN; // inf * 0: invalid
        }
        if c.is_infinite() && c.sign() != sp {
            return Fp16::NAN; // inf - inf: invalid
        }
        return if sp == 1 { Fp16::NEG_INFINITY } else { Fp16::INFINITY };
    }
    if c.is_infinite() {
        return c;
    }

    // All operands finite. Decode to integer magnitudes.
    let (mp, ep): (u64, i32) = if a.is_zero() || b.is_zero() {
        (0, 0)
    } else {
        let (_, ma, ea) = a.decode();
        let (_, mb, eb) = b.decode();
        ((ma as u64) * (mb as u64), ea + eb) // <= 2047^2 < 2^22, exact
    };
    let (sc, mc, ec): (u16, u32, i32) = if c.is_zero() {
        (c.sign(), 0, 0)
    } else {
        let (s, m, e) = c.decode();
        (s, m, e)
    };

    if mp == 0 && mc == 0 {
        // Sum of (signed) zeros: same sign keeps it, else +0 (RN).
        let s = if sp == sc { sp } else { 0 };
        return Fp16(s << 15);
    }
    if mp == 0 {
        return c;
    }
    if mc == 0 {
        return Fp16(round_to_fp16(sp, mp as u128, ep));
    }

    // Exact signed alignment and addition in i128.
    let emin = ep.min(ec);
    let vp = (mp as i128) << (ep - emin); // shift <= 58, mp < 2^22: exact
    let vc = (mc as i128) << (ec - emin); // shift <= 53, mc < 2^11: exact
    let v = if sp == 1 { -vp } else { vp } + if sc == 1 { -vc } else { vc };

    if v == 0 {
        return Fp16::ZERO; // exact cancellation: +0 under RN
    }
    let sign = u16::from(v < 0);
    Fp16(round_to_fp16(sign, v.unsigned_abs(), emin))
}

/// `a*b + c` computed through `f64` (exact product, 53-bit sum) followed by
/// a correctly rounded conversion. Bit-identical to [`fma16`] by the
/// innocuous-double-rounding theorem (53 ≥ 2·22 + 2); cross-checked in
/// tests and against the Pallas kernel, which uses the same construction.
pub fn fma16_via_f64(a: Fp16, b: Fp16, c: Fp16) -> Fp16 {
    Fp16::from_f64(a.to_f64().mul_add(b.to_f64(), c.to_f64()))
}

/// Single-rounded binary16 multiplication.
pub fn mul16(a: Fp16, b: Fp16) -> Fp16 {
    if a.is_nan() || b.is_nan() {
        return Fp16::NAN;
    }
    let s = a.sign() ^ b.sign();
    if a.is_infinite() || b.is_infinite() {
        if a.is_zero() || b.is_zero() {
            return Fp16::NAN;
        }
        return if s == 1 { Fp16::NEG_INFINITY } else { Fp16::INFINITY };
    }
    if a.is_zero() || b.is_zero() {
        return Fp16(s << 15);
    }
    let (_, ma, ea) = a.decode();
    let (_, mb, eb) = b.decode();
    Fp16(round_to_fp16(s, (ma as u128) * (mb as u128), ea + eb))
}

/// Single-rounded binary16 addition, expressed as `fma(a, 1, b)` — the
/// product `a * 1` is exact, so the semantics (including signed-zero and
/// special-case rules) coincide with IEEE addition.
pub fn add16(a: Fp16, b: Fp16) -> Fp16 {
    fma16(a, Fp16::ONE, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_fp16(r: &mut Xoshiro256) -> Fp16 {
        // Uniform over bit patterns: exercises subnormals/inf/NaN heavily.
        Fp16::from_bits(r.next_u32() as u16)
    }

    #[test]
    fn fma_matches_f64_path_on_random_patterns() {
        let mut r = Xoshiro256::new(0xF16F16);
        for i in 0..2_000_000 {
            let (a, b, c) = (rand_fp16(&mut r), rand_fp16(&mut r), rand_fp16(&mut r));
            let x = fma16(a, b, c);
            let y = fma16_via_f64(a, b, c);
            if x.is_nan() || y.is_nan() {
                assert_eq!(x.is_nan(), y.is_nan(), "iter {i}: {a:?} {b:?} {c:?}");
            } else {
                assert_eq!(x.0, y.0, "iter {i}: {a:?} * {b:?} + {c:?} -> {x:?} vs {y:?}");
            }
        }
    }

    #[test]
    fn fma_matches_f64_path_on_edge_values() {
        let edges = [
            Fp16::ZERO,
            Fp16::NEG_ZERO,
            Fp16::ONE,
            Fp16::NEG_ONE,
            Fp16::MAX,
            Fp16(0xFBFF), // -MAX
            Fp16::MIN_POSITIVE,
            Fp16::MIN_SUBNORMAL,
            Fp16(0x8001), // -min subnormal
            Fp16(0x03FF), // largest subnormal
            Fp16::INFINITY,
            Fp16::NEG_INFINITY,
            Fp16::NAN,
            Fp16(0x3C01), // 1 + ulp
            Fp16(0x7BFE), // MAX - ulp
        ];
        for &a in &edges {
            for &b in &edges {
                for &c in &edges {
                    let x = fma16(a, b, c);
                    let y = fma16_via_f64(a, b, c);
                    if x.is_nan() || y.is_nan() {
                        assert_eq!(x.is_nan(), y.is_nan(), "{a:?} {b:?} {c:?}");
                    } else {
                        assert_eq!(x.0, y.0, "{a:?} {b:?} {c:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn fma_known_values() {
        let two = Fp16::from_f64(2.0);
        let three = Fp16::from_f64(3.0);
        assert_eq!(fma16(two, three, Fp16::ONE).to_f64(), 7.0);
        assert_eq!(fma16(two, three, Fp16::NEG_ONE).to_f64(), 5.0);
        // Single rounding visible: 4097 = 2^12 + 1 is not representable
        // (ulp = 4 there) but fma(64, 64, 1) must round 4097 -> 4096,
        // whereas a*b then +c would also give 4096; use a case where they
        // differ: x = 1 + 2^-10 (0x3C01); x*x = 1 + 2^-9 + 2^-20.
        // fused: + c = -(1+2^-9) gives exactly 2^-20.
        let x = Fp16(0x3C01);
        let c = Fp16::from_f64(-(1.0 + 2f64.powi(-9)));
        let fused = fma16(x, x, c);
        assert_eq!(fused.to_f64(), 2f64.powi(-20), "fused keeps the low term");
        // Unfused would first round x*x to 1+2^-9 and return 0.
        let unfused = add16(mul16(x, x), c);
        assert_eq!(unfused.to_f64(), 0.0);
    }

    #[test]
    fn mul_special_cases() {
        assert!(mul16(Fp16::INFINITY, Fp16::ZERO).is_nan());
        assert_eq!(mul16(Fp16::NEG_ONE, Fp16::ZERO).0, 0x8000);
        assert_eq!(mul16(Fp16::MAX, Fp16::from_f64(2.0)).0, Fp16::INFINITY.0);
        assert_eq!(
            mul16(Fp16::MIN_SUBNORMAL, Fp16::MIN_SUBNORMAL).0,
            0 // total underflow
        );
    }

    #[test]
    fn add_special_cases() {
        assert_eq!(add16(Fp16::ZERO, Fp16::NEG_ZERO).0, 0x0000); // +0
        assert_eq!(add16(Fp16::NEG_ZERO, Fp16::NEG_ZERO).0, 0x8000); // -0
        assert!(add16(Fp16::INFINITY, Fp16::NEG_INFINITY).is_nan());
        assert_eq!(add16(Fp16::ONE, Fp16::NEG_ONE).0, 0x0000);
        assert_eq!(add16(Fp16::MAX, Fp16::MAX).0, Fp16::INFINITY.0);
    }

    #[test]
    fn add_matches_f64_on_all_pairs_sampled() {
        let mut r = Xoshiro256::new(0xADD);
        for _ in 0..500_000 {
            let a = rand_fp16(&mut r);
            let b = rand_fp16(&mut r);
            let x = add16(a, b);
            let y = Fp16::from_f64(a.to_f64() + b.to_f64());
            if x.is_nan() || y.is_nan() {
                assert_eq!(x.is_nan(), y.is_nan());
            } else {
                assert_eq!(x.0, y.0, "{a:?} + {b:?}");
            }
        }
    }

    #[test]
    fn accumulation_chain_is_deterministic() {
        // The simulator and the Pallas kernel must agree on chained FMAs.
        let mut r = Xoshiro256::new(1);
        let xs: Vec<Fp16> = (0..64).map(|_| r.next_fp16_in(4.0)).collect();
        let ws: Vec<Fp16> = (0..64).map(|_| r.next_fp16_in(4.0)).collect();
        let mut acc = Fp16::from_f64(0.5);
        let mut acc2 = acc;
        for i in 0..64 {
            acc = fma16(xs[i], ws[i], acc);
            acc2 = fma16_via_f64(xs[i], ws[i], acc2);
        }
        assert_eq!(acc.0, acc2.0);
        assert!(acc.is_finite());
    }
}
