//! Tightly-coupled data memory (TCDM) substrate.
//!
//! The PULP cluster's shared scratchpad: word-interleaved SRAM banks behind
//! a single-cycle logarithmic interconnect. In the enhanced cluster used by
//! the paper (§3), every 32-bit word is stored as a SECDED (39,32)
//! codeword, so single-bit upsets in memory are corrected at the read port
//! and double-bit upsets are reported.
//!
//! The model keeps the *stored* representation as codewords — not decoded
//! data — so the fault injector can flip real memory bits and the ECC
//! machinery is exercised on every access, exactly like the RTL.

pub mod interconnect;

pub use interconnect::Interconnect;

use crate::ecc::{decode32, encode32, DecodeStatus};
use crate::fp::Fp16;

/// Counters reported by the TCDM (feeds the cluster's fault telemetry).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EccCounters {
    pub corrected: u64,
    pub uncorrectable: u64,
}

/// Word-interleaved, ECC-protected multi-bank scratchpad.
#[derive(Debug, Clone)]
pub struct Tcdm {
    /// `banks[b][row]` is a 39-bit SECDED codeword in the low bits.
    banks: Vec<Vec<u64>>,
    n_banks: usize,
    words_per_bank: usize,
    counters: EccCounters,
    /// Optional write log (flat word indices) for fast snapshot-restore
    /// in the campaign engine: restoring only the dirtied words beats a
    /// full-image copy by orders of magnitude on small workloads.
    dirty: Option<Vec<u32>>,
    /// Reusable index buffer of [`Tcdm::digest_delta_scratch`]: the
    /// fast-forward convergence probe sorts/dedups the dirty log here so
    /// steady-state digest probes perform no heap allocation.
    scratch_idx: Vec<u32>,
}

impl Tcdm {
    /// A cluster-like TCDM: `n_banks` single-ported banks of
    /// `bytes_per_bank` bytes each (PULP clusters commonly use 16 or 32
    /// banks × 8 KiB).
    pub fn new(n_banks: usize, bytes_per_bank: usize) -> Self {
        assert!(n_banks.is_power_of_two(), "bank count must be a power of two");
        assert_eq!(bytes_per_bank % 4, 0);
        let words_per_bank = bytes_per_bank / 4;
        let zero = encode32(0);
        Self {
            banks: vec![vec![zero; words_per_bank]; n_banks],
            n_banks,
            words_per_bank,
            counters: EccCounters::default(),
            dirty: None,
            scratch_idx: Vec::new(),
        }
    }

    /// Copy another instance's stored contents (codewords + ECC counters)
    /// into this one's existing buffers — `copy_from_slice` per bank, no
    /// heap allocation. The campaign's worker scratch arenas adopt the
    /// shared pristine staged image this way instead of `clone()`ing a
    /// fresh TCDM per batch. The two instances must share geometry; the
    /// dirty log (if tracking is enabled) is cleared, since the contents
    /// now equal the copied image exactly.
    pub fn copy_state_from(&mut self, other: &Tcdm) {
        assert_eq!(self.n_banks, other.n_banks);
        assert_eq!(self.words_per_bank, other.words_per_bank);
        for (dst, src) in self.banks.iter_mut().zip(&other.banks) {
            dst.copy_from_slice(src);
        }
        self.counters = other.counters;
        if let Some(d) = &mut self.dirty {
            d.clear();
        }
    }

    /// Start logging writes for [`Tcdm::restore_from`].
    pub fn enable_dirty_tracking(&mut self) {
        self.dirty = Some(Vec::with_capacity(1024));
    }

    /// True once [`Tcdm::enable_dirty_tracking`] has been called — i.e.
    /// [`Tcdm::restore_from`] actually undoes writes.
    pub fn dirty_tracking_enabled(&self) -> bool {
        self.dirty.is_some()
    }

    /// Undo every logged write by copying the pristine codewords back.
    /// The two instances must share geometry. Clears the log.
    pub fn restore_from(&mut self, pristine: &Tcdm) {
        assert_eq!(self.n_banks, pristine.n_banks);
        assert_eq!(self.words_per_bank, pristine.words_per_bank);
        let mut dirty = self.dirty.take().unwrap_or_default();
        for &idx in &dirty {
            let (b, r) = ((idx as usize) / self.words_per_bank, (idx as usize) % self.words_per_bank);
            self.banks[b][r] = pristine.banks[b][r];
        }
        dirty.clear();
        self.dirty = Some(dirty);
    }

    #[inline]
    fn mark_dirty(&mut self, bank: usize, row: usize) {
        if let Some(d) = &mut self.dirty {
            d.push((bank * self.words_per_bank + row) as u32);
        }
    }

    /// Shared kernel of the canonical delta: visit `(flat index, raw
    /// codeword)` for every word in the (sorted, de-duplicated) index
    /// list whose stored codeword differs from `pristine`'s. Both
    /// [`Tcdm::dirty_delta`] and [`Tcdm::digest_delta_scratch`] go
    /// through this, so the delta canonicalization — and therefore the
    /// fast-forward reference digests vs. probe digests — cannot fork.
    fn for_each_delta_entry(&self, pristine: &Tcdm, idxs: &[u32], mut f: impl FnMut(u32, u64)) {
        assert_eq!(self.n_banks, pristine.n_banks);
        assert_eq!(self.words_per_bank, pristine.words_per_bank);
        for &idx in idxs {
            let (b, r) = (
                (idx as usize) / self.words_per_bank,
                (idx as usize) % self.words_per_bank,
            );
            let cw = self.banks[b][r];
            if cw != pristine.banks[b][r] {
                f(idx, cw);
            }
        }
    }

    /// The candidate index list of the canonical delta, sorted and
    /// de-duplicated into `idxs` (reused buffer): the dirty log when
    /// tracking is enabled, the whole memory otherwise.
    fn candidate_idxs_into(&self, idxs: &mut Vec<u32>) {
        idxs.clear();
        match &self.dirty {
            Some(log) => idxs.extend_from_slice(log),
            None => idxs.extend(0..(self.n_banks * self.words_per_bank) as u32),
        }
        idxs.sort_unstable();
        idxs.dedup();
    }

    /// Canonical difference against a pristine image: sorted, de-duplicated
    /// `(flat word index, raw codeword)` pairs for every word whose stored
    /// codeword differs from `pristine`'s. With dirty tracking enabled
    /// (the campaign hot path) only the logged words are inspected;
    /// without it the whole memory is scanned. Words that were written
    /// and later restored to their pristine value are *not* reported, so
    /// two instances with equal contents always produce equal deltas
    /// regardless of their write histories.
    pub fn dirty_delta(&self, pristine: &Tcdm) -> Vec<(u32, u64)> {
        let mut idxs = Vec::new();
        self.candidate_idxs_into(&mut idxs);
        let mut delta = Vec::new();
        self.for_each_delta_entry(pristine, &idxs, |idx, cw| delta.push((idx, cw)));
        delta
    }

    /// Copy-on-write restore to a checkpointed state: the caller first
    /// [`Tcdm::restore_from`]s the pristine image (undoing this run's
    /// writes), then applies the checkpoint's recorded delta on top. The
    /// applied words are logged as dirty so a later restore undoes them
    /// too.
    pub fn apply_delta(&mut self, delta: &[(u32, u64)]) {
        for &(idx, cw) in delta {
            let (b, r) = (
                (idx as usize) / self.words_per_bank,
                (idx as usize) % self.words_per_bank,
            );
            self.banks[b][r] = cw;
            self.mark_dirty(b, r);
        }
    }

    /// Rewind to a checkpointed state recorded at a dirty-log watermark:
    /// every word written after `watermark` is restored to the
    /// checkpoint image — `pristine` overlaid with the sorted canonical
    /// `delta`, exactly the state a full [`Tcdm::restore_from`] +
    /// [`Tcdm::apply_delta`] pair produces — and the log is truncated
    /// back to `watermark`.
    ///
    /// Contract: dirty tracking is enabled, `delta` is a canonical
    /// (sorted, de-duplicated) [`Tcdm::dirty_delta`], and the log prefix
    /// `[0, watermark)` was written by applying exactly that delta after
    /// a pristine restore. Then contents *and* log are bit-identical to
    /// redoing the full restore — the two-level campaign engine leans on
    /// this to coalesce adjacent fault windows onto one checkpoint
    /// restore, undoing only the previous window's writes.
    pub fn undo_to_watermark(&mut self, pristine: &Tcdm, delta: &[(u32, u64)], watermark: usize) {
        assert_eq!(self.n_banks, pristine.n_banks);
        assert_eq!(self.words_per_bank, pristine.words_per_bank);
        let mut dirty = self
            .dirty
            .take()
            .expect("undo_to_watermark requires dirty tracking");
        debug_assert!(watermark <= dirty.len());
        for &idx in &dirty[watermark.min(dirty.len())..] {
            let cw = match delta.binary_search_by_key(&idx, |e| e.0) {
                Ok(at) => delta[at].1,
                Err(_) => {
                    let (b, r) = (
                        (idx as usize) / self.words_per_bank,
                        (idx as usize) % self.words_per_bank,
                    );
                    pristine.banks[b][r]
                }
            };
            let (b, r) = (
                (idx as usize) / self.words_per_bank,
                (idx as usize) % self.words_per_bank,
            );
            self.banks[b][r] = cw;
        }
        dirty.truncate(watermark);
        self.dirty = Some(dirty);
    }

    /// Current length of the write log (0 when tracking is disabled).
    /// The two-level engine uses log-length *watermarks* to delimit the
    /// writes of a window or reference segment: every store appends one
    /// entry (duplicates included), so `dirty_log_since(mark)` is exactly
    /// the set of words touched after the watermark was taken.
    pub fn dirty_log_len(&self) -> usize {
        self.dirty.as_ref().map_or(0, |d| d.len())
    }

    /// The flat word indices written since a [`Tcdm::dirty_log_len`]
    /// watermark, in write order (duplicates included).
    pub fn dirty_log_since(&self, watermark: usize) -> &[u32] {
        match &self.dirty {
            Some(d) => &d[watermark.min(d.len())..],
            None => &[],
        }
    }

    /// The raw stored codeword at a flat dirty-log index (bank-major
    /// `bank * words_per_bank + row` — the encoding the write log and
    /// the deltas use).
    pub fn raw_codeword_flat(&self, flat_idx: u32) -> u64 {
        let bank = (flat_idx as usize) / self.words_per_bank;
        let row = (flat_idx as usize) % self.words_per_bank;
        self.banks[bank][row]
    }

    /// Linear word index (`byte_addr / 4`) of a flat dirty-log index.
    /// The log and the deltas use the bank-major encoding
    /// `bank * words_per_bank + row`, while task layouts address memory
    /// linearly through the bank interleaving — this is the inverse of
    /// [`Tcdm::locate`]'s mapping.
    pub fn linear_word_of(&self, flat_idx: u32) -> u32 {
        let bank = (flat_idx as usize) / self.words_per_bank;
        let row = (flat_idx as usize) % self.words_per_bank;
        (row * self.n_banks + bank) as u32
    }

    /// Fold the canonical delta vs. `pristine` into a state digest (the
    /// TCDM half of the fast-forward convergence digest).
    pub fn digest_delta_into(&self, pristine: &Tcdm, h: &mut crate::util::digest::Fnv64) {
        Self::digest_delta_entries(&self.dirty_delta(pristine), h)
    }

    /// Fold an already-computed canonical delta into a digest — the
    /// byte stream [`Tcdm::digest_delta_into`] produces, without
    /// recomputing the delta.
    pub fn digest_delta_entries(delta: &[(u32, u64)], h: &mut crate::util::digest::Fnv64) {
        for &(idx, cw) in delta {
            h.write_u32(idx);
            h.write_u64(cw);
        }
    }

    /// Fold the canonical delta vs. `pristine` into a digest **without
    /// materializing it**: the byte stream is identical to
    /// [`Tcdm::digest_delta_into`]'s, but the dirty log is sorted and
    /// de-duplicated in an internal reusable scratch buffer and each
    /// surviving word is hashed in place — the fast-forward convergence
    /// probe runs one of these per checkpoint boundary, so the steady
    /// state allocates nothing.
    pub fn digest_delta_scratch(&mut self, pristine: &Tcdm, h: &mut crate::util::digest::Fnv64) {
        let mut idxs = std::mem::take(&mut self.scratch_idx);
        self.candidate_idxs_into(&mut idxs);
        self.for_each_delta_entry(pristine, &idxs, |idx, cw| {
            h.write_u32(idx);
            h.write_u64(cw);
        });
        self.scratch_idx = idxs;
    }

    /// The paper's cluster configuration: 16 banks × 16 KiB = 256 KiB.
    pub fn cluster_default() -> Self {
        Self::new(16, 16 * 1024)
    }

    pub fn size_bytes(&self) -> usize {
        self.n_banks * self.words_per_bank * 4
    }

    pub fn n_banks(&self) -> usize {
        self.n_banks
    }

    pub fn counters(&self) -> EccCounters {
        self.counters
    }

    pub fn reset_counters(&mut self) {
        self.counters = EccCounters::default();
    }

    #[inline]
    fn locate(&self, byte_addr: u32) -> (usize, usize) {
        let word = (byte_addr / 4) as usize;
        let bank = word & (self.n_banks - 1);
        let row = word / self.n_banks;
        assert!(
            row < self.words_per_bank,
            "TCDM address 0x{byte_addr:08X} out of range ({} bytes)",
            self.size_bytes()
        );
        (bank, row)
    }

    /// The bank a byte address maps to (for interconnect arbitration).
    #[inline]
    pub fn bank_of(&self, byte_addr: u32) -> usize {
        ((byte_addr / 4) as usize) & (self.n_banks - 1)
    }

    /// Read one 32-bit word through the ECC decoder.
    pub fn read_word(&mut self, byte_addr: u32) -> (u32, DecodeStatus) {
        let (bank, row) = self.locate(byte_addr);
        let (data, status) = decode32(self.banks[bank][row]);
        match status {
            DecodeStatus::Corrected(_) => {
                self.counters.corrected += 1;
                // Write-back scrubbing: repair the stored codeword.
                self.banks[bank][row] = encode32(data);
                self.mark_dirty(bank, row);
            }
            DecodeStatus::DoubleError => self.counters.uncorrectable += 1,
            DecodeStatus::Clean => {}
        }
        (data, status)
    }

    /// Write one 32-bit word (re-encoded).
    pub fn write_word(&mut self, byte_addr: u32, data: u32) {
        let (bank, row) = self.locate(byte_addr);
        self.banks[bank][row] = encode32(data);
        self.mark_dirty(bank, row);
    }

    /// Read the *raw* stored codeword (fault-injection / test hook).
    pub fn raw_codeword(&self, byte_addr: u32) -> u64 {
        let (bank, row) = self.locate(byte_addr);
        self.banks[bank][row]
    }

    /// Flip a stored codeword bit (fault-injection hook: SEU in SRAM).
    pub fn flip_bit(&mut self, byte_addr: u32, bit: u32) {
        let (bank, row) = self.locate(byte_addr);
        self.banks[bank][row] ^= 1 << (bit % 39);
        self.mark_dirty(bank, row);
    }

    /// Read an FP16 element (two per word; `byte_addr` must be 2-aligned).
    pub fn read_fp16(&mut self, byte_addr: u32) -> (Fp16, DecodeStatus) {
        debug_assert_eq!(byte_addr % 2, 0);
        let (word, status) = self.read_word(byte_addr & !3);
        let half = if byte_addr & 2 == 0 {
            word as u16
        } else {
            (word >> 16) as u16
        };
        (Fp16::from_bits(half), status)
    }

    /// Write an FP16 element (read-modify-write of the containing word).
    pub fn write_fp16(&mut self, byte_addr: u32, v: Fp16) {
        debug_assert_eq!(byte_addr % 2, 0);
        let aligned = byte_addr & !3;
        let (mut word, _) = self.read_word(aligned);
        if byte_addr & 2 == 0 {
            word = (word & 0xFFFF_0000) | v.to_bits() as u32;
        } else {
            word = (word & 0x0000_FFFF) | ((v.to_bits() as u32) << 16);
        }
        self.write_word(aligned, word);
    }

    /// Bulk helpers used by the host/DMA to stage matrices.
    pub fn write_fp16_slice(&mut self, byte_addr: u32, values: &[Fp16]) {
        for (i, &v) in values.iter().enumerate() {
            self.write_fp16(byte_addr + 2 * i as u32, v);
        }
    }

    pub fn read_fp16_slice(&mut self, byte_addr: u32, n: usize) -> Vec<Fp16> {
        (0..n)
            .map(|i| self.read_fp16(byte_addr + 2 * i as u32).0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_round_trip_across_banks() {
        let mut t = Tcdm::new(8, 1024);
        for i in 0..256u32 {
            t.write_word(i * 4, i.wrapping_mul(0x9E37_79B9));
        }
        for i in 0..256u32 {
            let (v, st) = t.read_word(i * 4);
            assert_eq!(v, i.wrapping_mul(0x9E37_79B9));
            assert_eq!(st, DecodeStatus::Clean);
        }
    }

    #[test]
    fn fp16_halfword_packing() {
        let mut t = Tcdm::new(4, 256);
        let a = Fp16::from_f64(1.5);
        let b = Fp16::from_f64(-2.25);
        t.write_fp16(0, a);
        t.write_fp16(2, b);
        assert_eq!(t.read_fp16(0).0, a);
        assert_eq!(t.read_fp16(2).0, b);
        // The containing word holds both halves.
        let (w, _) = t.read_word(0);
        assert_eq!(w & 0xFFFF, a.to_bits() as u32);
        assert_eq!(w >> 16, b.to_bits() as u32);
    }

    #[test]
    fn single_bit_upset_is_corrected_and_scrubbed() {
        let mut t = Tcdm::new(4, 256);
        t.write_word(16, 0xCAFE_BABE);
        t.flip_bit(16, 7);
        let (v, st) = t.read_word(16);
        assert_eq!(v, 0xCAFE_BABE);
        assert!(matches!(st, DecodeStatus::Corrected(_)));
        assert_eq!(t.counters().corrected, 1);
        // Scrubbed: second read is clean.
        let (v2, st2) = t.read_word(16);
        assert_eq!(v2, 0xCAFE_BABE);
        assert_eq!(st2, DecodeStatus::Clean);
    }

    #[test]
    fn double_bit_upset_is_reported() {
        let mut t = Tcdm::new(4, 256);
        t.write_word(20, 0x1234_5678);
        t.flip_bit(20, 3);
        t.flip_bit(20, 11);
        let (_, st) = t.read_word(20);
        assert_eq!(st, DecodeStatus::DoubleError);
        assert_eq!(t.counters().uncorrectable, 1);
    }

    #[test]
    fn bank_interleaving_is_word_granular() {
        let t = Tcdm::new(8, 1024);
        assert_eq!(t.bank_of(0), 0);
        assert_eq!(t.bank_of(4), 1);
        assert_eq!(t.bank_of(28), 7);
        assert_eq!(t.bank_of(32), 0);
    }

    #[test]
    fn dirty_tracking_restores_exactly_the_written_words() {
        let mut pristine = Tcdm::new(4, 1024);
        for i in 0..32u32 {
            pristine.write_word(i * 4, 0xAAAA_0000 | i);
        }
        let mut t = pristine.clone();
        t.enable_dirty_tracking();
        t.write_word(0, 1);
        t.write_word(64, 2);
        t.flip_bit(128, 3);
        t.restore_from(&pristine);
        for i in 0..32u32 {
            let (v, _) = t.read_word(i * 4);
            assert_eq!(v, 0xAAAA_0000 | i, "word {i}");
        }
        // The log is cleared and reusable.
        t.write_word(4, 9);
        t.restore_from(&pristine);
        assert_eq!(t.read_word(4).0, 0xAAAA_0001);
    }

    #[test]
    fn dirty_delta_is_canonical_and_restorable() {
        let mut pristine = Tcdm::new(4, 1024);
        for i in 0..16u32 {
            pristine.write_word(i * 4, 0x5500_0000 | i);
        }
        let mut t = pristine.clone();
        t.enable_dirty_tracking();
        t.write_word(8, 0xAAAA_AAAA);
        t.write_word(40, 0xBBBB_BBBB);
        t.write_word(8, 0xAAAA_AAAA); // duplicate write, one delta entry
        t.write_word(24, 0x5500_0006); // rewritten with the pristine value
        let delta = t.dirty_delta(&pristine);
        assert_eq!(delta.len(), 2, "{delta:?}");
        assert!(delta.windows(2).all(|w| w[0].0 < w[1].0), "sorted");
        // A scan without dirty tracking finds the identical delta.
        let mut untracked = pristine.clone();
        untracked.write_word(8, 0xAAAA_AAAA);
        untracked.write_word(40, 0xBBBB_BBBB);
        assert_eq!(untracked.dirty_delta(&pristine), delta);
        // Restore + apply reproduces the checkpointed contents exactly,
        // and the applied words stay undoable.
        let mut u = pristine.clone();
        u.enable_dirty_tracking();
        u.write_word(100, 7); // unrelated write the restore must undo
        u.restore_from(&pristine);
        u.apply_delta(&delta);
        assert_eq!(u.read_word(8).0, 0xAAAA_AAAA);
        assert_eq!(u.read_word(40).0, 0xBBBB_BBBB);
        assert_eq!(u.read_word(100).0, 0);
        assert_eq!(u.dirty_delta(&pristine), delta);
        u.restore_from(&pristine);
        assert!(u.dirty_delta(&pristine).is_empty());
        // Equal contents => equal digests, different => different.
        use crate::util::digest::Fnv64;
        let mut h1 = Fnv64::new();
        t.digest_delta_into(&pristine, &mut h1);
        let mut h2 = Fnv64::new();
        untracked.digest_delta_into(&pristine, &mut h2);
        assert_eq!(h1.finish(), h2.finish());
        let mut h3 = Fnv64::new();
        pristine.digest_delta_into(&pristine, &mut h3);
        assert_ne!(h1.finish(), h3.finish());
    }

    #[test]
    fn copy_state_from_equals_clone_and_clears_the_log() {
        let mut pristine = Tcdm::new(4, 1024);
        for i in 0..64u32 {
            pristine.write_word(i * 4, 0xBEEF_0000 | i);
        }
        // A scratch instance with unrelated prior contents and a dirty log.
        let mut t = Tcdm::new(4, 1024);
        t.enable_dirty_tracking();
        t.write_word(12, 0xFFFF_FFFF);
        t.copy_state_from(&pristine);
        assert!(t.dirty_tracking_enabled(), "tracking survives the copy");
        assert!(t.dirty_delta(&pristine).is_empty(), "contents equal pristine");
        for i in 0..64u32 {
            assert_eq!(t.read_word(i * 4).0, 0xBEEF_0000 | i, "word {i}");
        }
        assert_eq!(t.counters(), pristine.counters());
        // Writes after the copy are tracked and restorable as usual.
        t.write_word(8, 7);
        assert_eq!(t.dirty_delta(&pristine).len(), 1);
        t.restore_from(&pristine);
        assert!(t.dirty_delta(&pristine).is_empty());
    }

    #[test]
    fn digest_delta_scratch_matches_the_materialized_digest() {
        use crate::util::digest::Fnv64;
        let mut pristine = Tcdm::new(4, 1024);
        for i in 0..32u32 {
            pristine.write_word(i * 4, 0x1100_0000 | i);
        }
        let mut t = pristine.clone();
        t.enable_dirty_tracking();
        t.write_word(16, 0xAAAA_AAAA);
        t.write_word(80, 0xBBBB_BBBB);
        t.write_word(16, 0xAAAA_AAAA); // duplicate log entry
        t.write_word(24, 0x1100_0006); // rewritten to the pristine value
        let mut ha = Fnv64::new();
        Tcdm::digest_delta_entries(&t.dirty_delta(&pristine), &mut ha);
        let mut hb = Fnv64::new();
        t.digest_delta_scratch(&pristine, &mut hb);
        assert_eq!(ha.finish(), hb.finish(), "scratch digest must match");
        // Reuse is idempotent (scratch buffer state cannot leak between
        // probes) and the untracked full-scan path agrees too.
        let mut hc = Fnv64::new();
        t.digest_delta_scratch(&pristine, &mut hc);
        assert_eq!(ha.finish(), hc.finish());
        let mut untracked = pristine.clone();
        untracked.write_word(16, 0xAAAA_AAAA);
        untracked.write_word(80, 0xBBBB_BBBB);
        let mut hd = Fnv64::new();
        untracked.digest_delta_scratch(&pristine, &mut hd);
        assert_eq!(ha.finish(), hd.finish());
    }

    #[test]
    fn linear_word_of_inverts_the_bank_interleaving() {
        // words_per_bank = 256, n_banks = 8: every linear word maps to
        // flat `bank * words_per_bank + row` (the dirty-log encoding)
        // and back.
        let t = Tcdm::new(8, 1024);
        for word in 0..2048u32 {
            let (bank, row) = t.locate(word * 4);
            let flat = (bank * t.words_per_bank + row) as u32;
            assert_eq!(t.linear_word_of(flat), word, "word {word}");
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_access_panics() {
        let mut t = Tcdm::new(4, 256);
        t.write_word(4 * 256, 0);
    }

    #[test]
    fn undo_to_watermark_equals_full_restore_plus_delta() {
        // The pristine image the campaign engine snapshots after staging.
        let mut pristine = Tcdm::new(4, 1024);
        for i in 0..64u32 {
            pristine.write_word(i * 4, 0xD00D_0000 | i);
        }
        // A recorded checkpoint delta: the canonical (sorted, deduped)
        // difference of some mid-run state against pristine.
        let mut mid = pristine.clone();
        mid.enable_dirty_tracking();
        mid.write_word(8, 0xAAAA_AAAA);
        mid.write_word(40, 0xBBBB_BBBB);
        mid.write_word(200, 0xCCCC_CCCC);
        let delta = mid.dirty_delta(&pristine);
        // Path A (reference): full restore + delta replay per window.
        let window = |t: &mut Tcdm| {
            t.write_word(8, 0x1111_1111); // overlaps a delta word
            t.write_word(40, 0xBBBB_BBBB); // rewrite to the delta value
            t.write_word(96, 0x2222_2222); // pristine-only word
            t.write_word(96, 0x3333_3333); // duplicate log entry
        };
        let mut a = pristine.clone();
        a.enable_dirty_tracking();
        a.restore_from(&pristine);
        a.apply_delta(&delta);
        window(&mut a);
        a.restore_from(&pristine);
        a.apply_delta(&delta);
        // Path B (coalesced): one restore, then rewind past the watermark.
        let mut b = pristine.clone();
        b.enable_dirty_tracking();
        b.restore_from(&pristine);
        b.apply_delta(&delta);
        let mark = b.dirty_log_len();
        window(&mut b);
        b.undo_to_watermark(&pristine, &delta, mark);
        // Contents AND write log are bit-identical — the two-level
        // window probes read both.
        assert_eq!(a.dirty_delta(&pristine), b.dirty_delta(&pristine));
        assert_eq!(a.dirty_log_since(0), b.dirty_log_since(0));
        assert_eq!(b.dirty_log_len(), mark);
        for w in 0..(4 * 1024 / 4) as u32 {
            assert_eq!(a.read_word(w * 4).0, b.read_word(w * 4).0, "word {w}");
        }
    }
}
