//! Logarithmic-interconnect timing model.
//!
//! The PULP cluster's interconnect routes any master to any TCDM bank with
//! single-cycle latency; when two masters hit the same bank in the same
//! cycle, one is stalled (round-robin arbitration). RedMulE's streamer
//! issues wide, word-contiguous bursts, so in steady state it is
//! conflict-free; conflicts appear when the DMA or host cores access the
//! TCDM concurrently. We model exactly that: per-cycle request sets in,
//! stall count out.

/// Per-cycle arbitration result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arbitration {
    /// Number of extra cycles needed to serialize the worst-loaded bank.
    pub stall_cycles: u32,
    /// Number of requests that were in conflict.
    pub conflicts: u32,
}

/// Stateless arbitration calculator plus running statistics.
#[derive(Debug, Clone, Default)]
pub struct Interconnect {
    pub total_requests: u64,
    pub total_conflicts: u64,
    pub total_stall_cycles: u64,
    scratch: Vec<u16>,
}

impl Interconnect {
    pub fn new(n_banks: usize) -> Self {
        Self {
            scratch: vec![0; n_banks],
            ..Default::default()
        }
    }

    /// Arbitrate one cycle's worth of bank requests. `banks` lists the
    /// target bank of every request issued this cycle (duplicates = same
    /// bank conflicts).
    pub fn arbitrate(&mut self, banks: &[usize]) -> Arbitration {
        for c in self.scratch.iter_mut() {
            *c = 0;
        }
        let mut worst = 0u16;
        for &b in banks {
            let c = &mut self.scratch[b];
            *c += 1;
            worst = worst.max(*c);
        }
        let stall = worst.saturating_sub(1) as u32;
        let conflicts: u32 = self
            .scratch
            .iter()
            .map(|&c| (c.saturating_sub(1)) as u32)
            .sum();
        self.total_requests += banks.len() as u64;
        self.total_conflicts += conflicts as u64;
        self.total_stall_cycles += stall as u64;
        Arbitration {
            stall_cycles: stall,
            conflicts,
        }
    }

    /// Arbitrate a contiguous word burst of `n` words starting at
    /// `byte_addr` against `n_banks` interleaved banks — contiguous bursts
    /// never self-conflict when `n <= n_banks`.
    pub fn arbitrate_burst(&mut self, byte_addr: u32, n: usize) -> Arbitration {
        let n_banks = self.scratch.len();
        let first = (byte_addr / 4) as usize;
        let mut banks = Vec::with_capacity(n);
        for i in 0..n {
            banks.push((first + i) & (n_banks - 1));
        }
        self.arbitrate(&banks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_banks_no_stall() {
        let mut ic = Interconnect::new(8);
        let a = ic.arbitrate(&[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(a.stall_cycles, 0);
        assert_eq!(a.conflicts, 0);
    }

    #[test]
    fn same_bank_serializes() {
        let mut ic = Interconnect::new(8);
        let a = ic.arbitrate(&[3, 3, 3]);
        assert_eq!(a.stall_cycles, 2);
        assert_eq!(a.conflicts, 2);
    }

    #[test]
    fn contiguous_burst_within_bank_count_is_free() {
        let mut ic = Interconnect::new(16);
        let a = ic.arbitrate_burst(0x100, 16);
        assert_eq!(a.stall_cycles, 0);
    }

    #[test]
    fn long_burst_wraps_and_conflicts() {
        let mut ic = Interconnect::new(4);
        // 8 contiguous words over 4 banks: each bank hit twice.
        let a = ic.arbitrate_burst(0, 8);
        assert_eq!(a.stall_cycles, 1);
        assert_eq!(a.conflicts, 4);
    }

    #[test]
    fn statistics_accumulate() {
        let mut ic = Interconnect::new(4);
        ic.arbitrate(&[0, 0]);
        ic.arbitrate(&[1]);
        assert_eq!(ic.total_requests, 3);
        assert_eq!(ic.total_conflicts, 1);
        assert_eq!(ic.total_stall_cycles, 1);
    }
}
