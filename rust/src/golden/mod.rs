//! Bit-exact golden model of the RedMulE operation `Z = Y + X·W`.
//!
//! The accumulation order is the contract: the hardware's row of `H`
//! cascaded FMAs sweeps the inner dimension in ascending order, so
//!
//! ```text
//! acc = Y[m][k]
//! for n in 0..N: acc = fma16(X[m][n], W[n][k], acc)   // single rounding
//! Z[m][k] = acc
//! ```
//!
//! The same order is implemented by the Layer-1 Pallas kernel (see
//! `python/compile/kernels/redmule.py`), which makes the Rust golden, the
//! simulator and the PJRT-executed artifact all bit-identical. Run
//! classification in the fault campaign compares raw `u16` patterns.

use crate::fp::{add16, op_step16, Fp16, Fp8, Fp8Format, GemmFormat, GemmOp};
use crate::util::rng::Xoshiro256;

// ------------------------------------------------------------------ ABFT
//
// Algorithm-based fault tolerance (Huang & Abraham) for `Z = Y + X·W`:
// augment X with one extra row of column sums and W with one extra column
// of row sums, so the GEMM itself produces a checksum row/column of Z.
// Verification compares the *observed* row/column sums of the computed Z
// against the carried checksums. Two layers are provided:
//
// * **Exact checksums** ([`ChecksumWord`], [`Mat::abft_checksums`],
//   [`Mat::abft_verify`]) over a known matrix image: an exact fixed-point
//   value sum plus a bit-pattern sum, so *every* single-bit corruption of
//   a stored element is detected and located. Used to protect matrix
//   images at rest (and to test the machinery itself).
// * **Carried checksums with a rounding tolerance**
//   ([`abft_tolerance`]): the checksum row/column computed *through* the
//   FP16 pipeline differs from the observed exact sums by accumulated
//   rounding error, so online verification at writeback uses a
//   calibrated tolerance. Corruptions below the tolerance escape — the
//   fundamental coverage limit of floating-point ABFT (FT-GEMM, Wu et
//   al. 2023) that the campaign quantifies against replication.

/// Fractional bits of the exact fixed-point checksum arithmetic. Every
/// finite FP16 value is an integer multiple of 2^-24, so sums in this
/// representation are exact and order-independent.
pub const FX_FRAC_BITS: u32 = 24;

/// Exact fixed-point image of an FP16 value (units of 2^-24). Non-finite
/// values map to a sentinel far outside any finite sum so that a
/// corruption to Inf/NaN can never cancel.
#[inline]
pub fn fp16_to_fixed(v: Fp16) -> i64 {
    if v.is_finite() {
        // |v| <= 65504, so |v|*2^24 < 2^41: exact in f64 and in range.
        (v.to_f64() * (1u64 << FX_FRAC_BITS) as f64) as i64
    } else {
        (1i64 << 45) + v.to_bits() as i64
    }
}

/// Scale an exact fixed-point sum back to a real value.
#[inline]
pub fn fixed_to_f64(fx: i64) -> f64 {
    fx as f64 / (1u64 << FX_FRAC_BITS) as f64
}

/// One exact checksum: fixed-point value sum + bit-pattern sum. The value
/// sum carries the ABFT arithmetic meaning; the bit sum guarantees that
/// even value-preserving corruptions (±0 sign flips, NaN payloads) are
/// caught.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ChecksumWord {
    pub fx: i64,
    pub bits: i64,
}

impl ChecksumWord {
    #[inline]
    pub fn accumulate(&mut self, v: Fp16) {
        self.fx += fp16_to_fixed(v);
        self.bits += v.to_bits() as i64;
    }
}

/// Exact row + column checksums of a matrix image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AbftChecksums {
    pub row: Vec<ChecksumWord>,
    pub col: Vec<ChecksumWord>,
}

/// Result of an ABFT verification: the rows/columns whose checksums
/// disagree (empty = clean).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AbftMismatch {
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
}

impl AbftMismatch {
    pub fn is_clean(&self) -> bool {
        self.rows.is_empty() && self.cols.is_empty()
    }

    /// The located cell, when the mismatch pattern pins down exactly one:
    /// a single corrupted element fails exactly one row and one column.
    pub fn located(&self) -> Option<(usize, usize)> {
        match (self.rows.as_slice(), self.cols.as_slice()) {
            ([r], [c]) => Some((*r, *c)),
            _ => None,
        }
    }
}

/// Verdict of the online residual analysis (`Protection::AbftOnline`):
/// what the per-row/per-column store residuals say about the committed
/// result image.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidualVerdict {
    /// All residuals zero: every store committed exactly what the array
    /// presented.
    Clean,
    /// Exactly one row and one column disagree, consistently across both
    /// planes: a single corrupted element at their intersection, whose
    /// original bit pattern is `stored_bits − delta_bits`.
    Single {
        row: usize,
        col: usize,
        delta_fx: i64,
        delta_bits: i64,
    },
    /// More than one element disagrees (or the planes are inconsistent,
    /// e.g. after an SEU in a residual register): not correctable in
    /// place — the caller must fall back to recompute-based recovery.
    Multi,
}

/// Analyze the online store-residual banks (see
/// [`crate::redmule::abft::AbftUnit::observe_online`]): `rows`/`cols`
/// are the (fixed-point plane, bit plane) residual pairs. The verdict is
/// `Single` only when exactly one row and one column are flagged *and*
/// the row's deltas equal the column's deltas in both planes — anything
/// less self-consistent degrades to `Multi` so a confused locate can
/// never drive a wrong correction.
pub fn analyze_residuals(
    rows: (&[i64], &[i64]),
    cols: (&[i64], &[i64]),
) -> ResidualVerdict {
    let flagged = |fx: &[i64], bits: &[i64]| -> Vec<usize> {
        (0..fx.len().max(bits.len()))
            .filter(|&i| {
                fx.get(i).copied().unwrap_or(0) != 0 || bits.get(i).copied().unwrap_or(0) != 0
            })
            .collect()
    };
    let frows = flagged(rows.0, rows.1);
    let fcols = flagged(cols.0, cols.1);
    match (frows.as_slice(), fcols.as_slice()) {
        ([], []) => ResidualVerdict::Clean,
        ([r], [c]) => {
            let (rfx, rbits) = (rows.0[*r], rows.1[*r]);
            let (cfx, cbits) = (cols.0[*c], cols.1[*c]);
            if rfx == cfx && rbits == cbits && rbits != 0 {
                ResidualVerdict::Single {
                    row: *r,
                    col: *c,
                    delta_fx: rfx,
                    delta_bits: rbits,
                }
            } else {
                ResidualVerdict::Multi
            }
        }
        _ => ResidualVerdict::Multi,
    }
}

/// Reconstruct the original element from the corrupted stored value and
/// the bit-plane residual (`delta_bits = stored_bits − original_bits`
/// for a single corruption). Returns `None` when the delta does not
/// invert to a 16-bit pattern — the residual was not a single-element
/// store corruption, so the caller must fall back instead of writing a
/// fabricated value.
pub fn correct_from_residual(stored: Fp16, delta_bits: i64) -> Option<Fp16> {
    let bits = stored.to_bits() as i64 - delta_bits;
    if (0..=0xFFFF).contains(&bits) {
        Some(Fp16::from_bits(bits as u16))
    } else {
        None
    }
}

/// FP16 unit roundoff (2^-11), the grain of the checksum tolerance.
pub const EPS16: f64 = 1.0 / 2048.0;

/// Calibrated safety factor of [`abft_tolerance`]. Fault-free checksum
/// deviations measured over the campaign workload distribution stay below
/// ~0.6× the F=1 tolerance (tail over ~2000 problems); factor 4 leaves
/// ~7× margin while still detecting every corruption that moves a row or
/// column sum by more than a few FP16 ulps of its magnitude.
pub const ABFT_TOL_FACTOR: f64 = 4.0;

/// Rounding tolerance for comparing an observed (exact) row/column sum of
/// Z against the checksum carried through the FP16 pipeline. `inner` is
/// the GEMM inner dimension (accumulation chain length), `terms` the
/// number of elements summed, `abs_sum` the sum of their magnitudes
/// (which scales the reachable ulp sizes).
#[inline]
pub fn abft_tolerance(inner: usize, terms: usize, abs_sum: f64) -> f64 {
    abft_tolerance_scaled(ABFT_TOL_FACTOR, inner, terms, abs_sum)
}

/// [`abft_tolerance`] with an explicit safety factor — the sweep axis of
/// the detection-rate vs false-positive trade (`benches/sweep_tolerance`):
/// a small factor flags fault-free rounding noise (false positives, wasted
/// recoveries), a large one lets real corruptions below the bound escape.
#[inline]
pub fn abft_tolerance_scaled(factor: f64, inner: usize, terms: usize, abs_sum: f64) -> f64 {
    factor * EPS16 * (inner + terms + 1) as f64 * (1.0 + abs_sum)
}

/// Format-aware variant of [`abft_tolerance_scaled`]: the tolerance grain
/// is the storage format's unit roundoff instead of FP16's.
///
/// On an FP8 task every value crossing the cast units is re-rounded onto
/// the FP8 grid — the carried checksum inputs at fetch, and every data
/// element of `Z` at store — so fault-free residuals carry quantization
/// noise proportional to `2^-4` (E4M3) / `2^-3` (E5M2) rather than FP16's
/// `2^-11`. Keeping the FP16 bound would flag clean FP8 runs as corrupted
/// on essentially every workload; widening it is the honest trade: the
/// detection floor rises with the grid coarseness, and the campaign
/// measures exactly how much coverage that costs. For
/// [`GemmFormat::Fp16`] this is *identical* (same expression, same
/// floating-point evaluation) to [`abft_tolerance_scaled`], preserving
/// byte-identity of every default-path campaign. Calibration mirrors the
/// FP16 one: fault-free FP8 deviations measured over the campaign
/// workload distribution stay well under the F=1 bound (see
/// `fp8_abft_carried_checksums_are_within_format_tolerance`).
#[inline]
pub fn abft_tolerance_scaled_for(
    format: GemmFormat,
    factor: f64,
    inner: usize,
    terms: usize,
    abs_sum: f64,
) -> f64 {
    match format {
        GemmFormat::Fp16 => abft_tolerance_scaled(factor, inner, terms, abs_sum),
        f => factor * f.unit_roundoff() * (inner + terms + 1) as f64 * (1.0 + abs_sum),
    }
}

/// A row-major FP16 matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<Fp16>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![Fp16::ZERO; rows * cols],
        }
    }

    /// Uniform random entries in `[-mag, mag]` (finite, well-conditioned
    /// for FP16 accumulation — the campaign workload uses mag = 1).
    pub fn random(rows: usize, cols: usize, mag: f64, rng: &mut Xoshiro256) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_fp16_in(mag)).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> Fp16 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: Fp16) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn bits(&self) -> Vec<u16> {
        self.data.iter().map(|v| v.to_bits()).collect()
    }

    pub fn to_f64_vec(&self) -> Vec<f64> {
        self.data.iter().map(|v| v.to_f64()).collect()
    }

    pub fn from_f64_slice(rows: usize, cols: usize, vals: &[f64]) -> Self {
        assert_eq!(vals.len(), rows * cols);
        Self {
            rows,
            cols,
            data: vals.iter().map(|&v| Fp16::from_f64(v)).collect(),
        }
    }

    /// FP16 row sums (one per row), folded in ascending column order with
    /// single-rounded adds — the encode step for the W column checksum
    /// and Y row checksums of the ABFT augmentation.
    pub fn row_sums_fp16(&self) -> Vec<Fp16> {
        (0..self.rows)
            .map(|i| {
                let mut acc = Fp16::ZERO;
                for j in 0..self.cols {
                    acc = add16(acc, self.at(i, j));
                }
                acc
            })
            .collect()
    }

    /// FP16 column sums (one per column), folded in ascending row order.
    pub fn col_sums_fp16(&self) -> Vec<Fp16> {
        (0..self.cols)
            .map(|j| {
                let mut acc = Fp16::ZERO;
                for i in 0..self.rows {
                    acc = add16(acc, self.at(i, j));
                }
                acc
            })
            .collect()
    }

    /// Exact row/column checksums of this matrix image (encode).
    pub fn abft_checksums(&self) -> AbftChecksums {
        let mut row = vec![ChecksumWord::default(); self.rows];
        let mut col = vec![ChecksumWord::default(); self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.at(i, j);
                row[i].accumulate(v);
                col[j].accumulate(v);
            }
        }
        AbftChecksums { row, col }
    }

    /// Verify this matrix image against previously encoded checksums.
    /// Any single corrupted element fails exactly its row and its column,
    /// so [`AbftMismatch::located`] pins it down.
    pub fn abft_verify(&self, reference: &AbftChecksums) -> AbftMismatch {
        assert_eq!(reference.row.len(), self.rows, "checksum shape mismatch");
        assert_eq!(reference.col.len(), self.cols, "checksum shape mismatch");
        let now = self.abft_checksums();
        AbftMismatch {
            rows: (0..self.rows).filter(|&i| now.row[i] != reference.row[i]).collect(),
            cols: (0..self.cols).filter(|&j| now.col[j] != reference.col[j]).collect(),
        }
    }

    /// Snap every element onto the FP8 grid (RTNE, saturating) — the
    /// hybrid-FP8 input path of §2.1: values arrive as 8-bit floats and
    /// widen losslessly back to FP16 at the compute elements.
    pub fn quantize_fp8(&self, format: Fp8Format) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .map(|&v| Fp8::from_fp16(v, format, true).to_fp16())
                .collect(),
        }
    }

    /// Snap every element onto a [`GemmFormat`]'s storage grid — what the
    /// cast-in units do to each fetched operand. Identity (a plain clone)
    /// for [`GemmFormat::Fp16`].
    pub fn snap_to(&self, format: GemmFormat) -> Mat {
        match format {
            GemmFormat::Fp16 => self.clone(),
            GemmFormat::Fp8(f) => self.quantize_fp8(f),
        }
    }
}

/// GEMM problem dimensions: `X[M][N] · W[N][K] + Y[M][K]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmSpec {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmSpec {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        assert!(m > 0 && n > 0 && k > 0);
        Self { m, n, k }
    }

    /// The paper's fault-injection workload: (12 × 16 × 16).
    pub fn paper_workload() -> Self {
        Self::new(12, 16, 16)
    }

    pub fn macs(&self) -> u64 {
        (self.m * self.n * self.k) as u64
    }
}

/// A concrete GEMM instance: inputs plus the memoised golden output.
#[derive(Debug, Clone)]
pub struct GemmProblem {
    pub spec: GemmSpec,
    pub x: Mat,
    pub w: Mat,
    pub y: Mat,
}

impl GemmProblem {
    /// Hybrid-FP8 workload: X and W on the FP8 grid, Y/Z in FP16 — the
    /// accumulation path is unchanged (widening CEs), so the same golden,
    /// simulator and kernel all apply bit-exactly.
    pub fn random_fp8(spec: &GemmSpec, format: Fp8Format, seed: u64) -> Self {
        let p = Self::random(spec, seed);
        Self {
            spec: p.spec,
            x: p.x.quantize_fp8(format),
            w: p.w.quantize_fp8(format),
            y: p.y,
        }
    }

    pub fn random(spec: &GemmSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self {
            spec: *spec,
            x: Mat::random(spec.m, spec.n, 1.0, &mut rng),
            w: Mat::random(spec.n, spec.k, 1.0, &mut rng),
            y: Mat::random(spec.m, spec.k, 1.0, &mut rng),
        }
    }

    /// Bit-exact reference result in the hardware accumulation order.
    pub fn golden_z(&self) -> Mat {
        gemm_golden(&self.x, &self.w, &self.y)
    }

    /// Bit-exact reference for an arbitrary task datatype: the storage
    /// [`GemmFormat`] and reduction [`GemmOp`] of the accelerator config.
    ///
    /// Mirrors the hardware cast model exactly: every operand is snapped
    /// onto the storage grid (the cast-in units re-quantize each fetched
    /// value, idempotently), the reduction runs in FP16, and the final
    /// result is snapped once more (the cast-out unit narrows every
    /// store). For `(Fp16, Mul)` this is bit-identical to
    /// [`GemmProblem::golden_z`].
    pub fn golden_z_for(&self, format: GemmFormat, op: GemmOp) -> Mat {
        let z = gemm_golden_op(
            &self.x.snap_to(format),
            &self.w.snap_to(format),
            &self.y.snap_to(format),
            op,
        );
        z.snap_to(format)
    }

    /// Order-stable FNV-1a digest of the problem's exact bit content
    /// (dimensions plus every FP16 pattern of X, W and Y) — the
    /// workload-identity component of the campaign's shared-trace cache
    /// key. Two problems digest equal iff they stage identical images,
    /// so a cached clean-run trace can never be replayed against a
    /// different workload.
    pub fn content_digest(&self) -> u64 {
        let mut h = crate::util::digest::Fnv64::new();
        h.write_u64(self.spec.m as u64);
        h.write_u64(self.spec.n as u64);
        h.write_u64(self.spec.k as u64);
        for m in [&self.x, &self.w, &self.y] {
            for v in &m.data {
                h.write_bytes(&v.to_bits().to_le_bytes());
            }
        }
        h.finish()
    }

    /// The ABFT-augmented problem: X gains a checksum row (column sums),
    /// W a checksum column (row sums), Y both plus the corner (fold of
    /// Y's column sums). The `(m+1) × (k+1)` result's data region is
    /// bit-identical to this problem's [`GemmProblem::golden_z`] — the
    /// extra row/column rides along through the same pipeline — while
    /// `Z_aug[i][k]` ≈ the i-th row sum of Z and `Z_aug[m][j]` ≈ the j-th
    /// column sum, within [`abft_tolerance`].
    pub fn augment_abft(&self) -> GemmProblem {
        let (m, n, k) = (self.spec.m, self.spec.n, self.spec.k);
        let mut x = Mat::zeros(m + 1, n);
        for i in 0..m {
            for j in 0..n {
                x.set(i, j, self.x.at(i, j));
            }
        }
        for (j, v) in self.x.col_sums_fp16().into_iter().enumerate() {
            x.set(m, j, v);
        }
        let mut w = Mat::zeros(n, k + 1);
        for i in 0..n {
            for j in 0..k {
                w.set(i, j, self.w.at(i, j));
            }
        }
        for (i, v) in self.w.row_sums_fp16().into_iter().enumerate() {
            w.set(i, k, v);
        }
        let mut y = Mat::zeros(m + 1, k + 1);
        for i in 0..m {
            for j in 0..k {
                y.set(i, j, self.y.at(i, j));
            }
        }
        for (i, v) in self.y.row_sums_fp16().into_iter().enumerate() {
            y.set(i, k, v);
        }
        let y_col_sums = self.y.col_sums_fp16();
        let mut corner = Fp16::ZERO;
        for (j, v) in y_col_sums.into_iter().enumerate() {
            y.set(m, j, v);
            corner = add16(corner, v);
        }
        y.set(m, k, corner);
        GemmProblem {
            spec: GemmSpec::new(m + 1, n, k + 1),
            x,
            w,
            y,
        }
    }
}

/// Split an ABFT-augmented result into its data region and the carried
/// checksum column (`Z_aug[0..m][k]`) and row (`Z_aug[m][0..k]`). The
/// corner `Z_aug[m][k]` is returned with the checksum column (index `m`).
pub fn split_abft_z(z_aug: &Mat) -> (Mat, Vec<Fp16>, Vec<Fp16>) {
    assert!(z_aug.rows >= 2 && z_aug.cols >= 2, "not an augmented result");
    let (m, k) = (z_aug.rows - 1, z_aug.cols - 1);
    let mut data = Mat::zeros(m, k);
    for i in 0..m {
        for j in 0..k {
            data.set(i, j, z_aug.at(i, j));
        }
    }
    let carried_rows = (0..=m).map(|i| z_aug.at(i, k)).collect();
    let carried_cols = (0..k).map(|j| z_aug.at(m, j)).collect();
    (data, carried_rows, carried_cols)
}

/// `Z = Y + X·W` with the RedMulE accumulation order (ascending `n`,
/// single-rounded FMA at every step).
pub fn gemm_golden(x: &Mat, w: &Mat, y: &Mat) -> Mat {
    gemm_golden_op(x, w, y, GemmOp::Mul)
}

/// The op-family generalization of [`gemm_golden`]: each output element
/// is the ascending-`n` fold `acc ← (x op1 w) op2 acc` seeded with `Y`,
/// using the single shared step definition [`op_step16`] — the same one
/// the CE array and the per-CE recompute checkers execute, so golden and
/// simulator can never drift apart.
pub fn gemm_golden_op(x: &Mat, w: &Mat, y: &Mat, op: GemmOp) -> Mat {
    assert_eq!(x.cols, w.rows, "inner dimensions must agree");
    assert_eq!(y.rows, x.rows);
    assert_eq!(y.cols, w.cols);
    let (m, n, k) = (x.rows, x.cols, w.cols);
    let mut z = Mat::zeros(m, k);
    for i in 0..m {
        for j in 0..k {
            let mut acc = y.at(i, j);
            for t in 0..n {
                acc = op_step16(op, x.at(i, t), w.at(t, j), acc);
            }
            z.set(i, j, acc);
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::fma16;

    #[test]
    fn identity_weight_passes_x_through_plus_y() {
        let m = 4;
        let n = 4;
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            w.set(i, i, Fp16::ONE);
        }
        let mut rng = Xoshiro256::new(3);
        let x = Mat::random(m, n, 1.0, &mut rng);
        let y = Mat::zeros(m, n);
        let z = gemm_golden(&x, &w, &y);
        assert_eq!(z.bits(), x.bits());
    }

    #[test]
    fn zero_x_returns_y_when_y_nonnegative() {
        // With x = 0 every FMA adds 0*w — exact, so acc stays y... except
        // that adding -0 or crossing signed zero never occurs for finite y:
        // fma(0, w, y) = y exactly (0*w = ±0, y + ±0 = y for y != 0).
        let spec = GemmSpec::new(3, 5, 4);
        let mut rng = Xoshiro256::new(7);
        let x = Mat::zeros(spec.m, spec.n);
        let w = Mat::random(spec.n, spec.k, 1.0, &mut rng);
        let mut y = Mat::random(spec.m, spec.k, 1.0, &mut rng);
        // Avoid y == -0 edge (would become +0).
        for v in y.data.iter_mut() {
            if v.is_zero() {
                *v = Fp16::ONE;
            }
        }
        let z = gemm_golden(&x, &w, &y);
        assert_eq!(z.bits(), y.bits());
    }

    #[test]
    fn accumulation_order_matters_and_is_fixed() {
        // FP16 addition is not associative; verify our order is the
        // ascending-n chain by checking against a hand-rolled loop.
        let spec = GemmSpec::new(2, 8, 2);
        let p = GemmProblem::random(&spec, 99);
        let z = p.golden_z();
        for i in 0..spec.m {
            for j in 0..spec.k {
                let mut acc = p.y.at(i, j);
                for t in 0..spec.n {
                    acc = fma16(p.x.at(i, t), p.w.at(t, j), acc);
                }
                assert_eq!(z.at(i, j).to_bits(), acc.to_bits());
            }
        }
    }

    #[test]
    fn golden_is_deterministic_across_seeds_and_calls() {
        let spec = GemmSpec::paper_workload();
        let p1 = GemmProblem::random(&spec, 1234);
        let p2 = GemmProblem::random(&spec, 1234);
        assert_eq!(p1.golden_z().bits(), p2.golden_z().bits());
        let p3 = GemmProblem::random(&spec, 1235);
        assert_ne!(p3.golden_z().bits(), p1.golden_z().bits());
    }

    #[test]
    fn fp8_quantization_is_idempotent_and_lossy() {
        let spec = GemmSpec::new(6, 8, 6);
        let p = GemmProblem::random(&spec, 77);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let q = p.x.quantize_fp8(fmt);
            // Idempotent: the grid is a fixed point.
            assert_eq!(q.quantize_fp8(fmt).bits(), q.bits());
            // Lossy on generic FP16 data.
            assert_ne!(q.bits(), p.x.bits());
        }
    }

    #[test]
    fn fp8_problem_runs_through_the_same_golden() {
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random_fp8(&spec, Fp8Format::E4M3, 3);
        let z = p.golden_z();
        for v in &z.data {
            assert!(v.is_finite());
        }
        // X/W really live on the FP8 grid.
        for v in &p.x.data {
            let rt = Fp8::from_fp16(*v, Fp8Format::E4M3, true).to_fp16();
            assert_eq!(rt.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn golden_z_for_default_path_is_bit_identical_to_golden_z() {
        for spec in [GemmSpec::paper_workload(), GemmSpec::new(5, 7, 3)] {
            let p = GemmProblem::random(&spec, 0xD0 + spec.n as u64);
            assert_eq!(
                p.golden_z_for(GemmFormat::Fp16, GemmOp::Mul).bits(),
                p.golden_z().bits()
            );
        }
    }

    #[test]
    fn fp8_golden_is_on_the_grid_and_idempotent_under_requantization() {
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random(&spec, 42);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let g = GemmFormat::Fp8(fmt);
            let z = p.golden_z_for(g, GemmOp::Mul);
            // Cast-out leaves every stored element on the FP8 grid.
            assert_eq!(z.snap_to(g).bits(), z.bits(), "{fmt:?}");
            // A problem whose operands already live on the grid gives the
            // same result whether or not the host pre-quantized it: the
            // cast-in is idempotent.
            let pq = GemmProblem {
                spec,
                x: p.x.snap_to(g),
                w: p.w.snap_to(g),
                y: p.y.snap_to(g),
            };
            assert_eq!(pq.golden_z_for(g, GemmOp::Mul).bits(), z.bits());
            // And differs from the FP16 result on generic data.
            assert_ne!(z.bits(), p.golden_z().bits(), "{fmt:?}");
        }
    }

    #[test]
    fn op_family_golden_matches_componentwise_f64_reference() {
        // For max/min-reduced ops every intermediate is exactly
        // representable after one rounding, so the f64 componentwise fold
        // (rounding each op1 result to FP16 first) is an independent
        // reference.
        let spec = GemmSpec::new(6, 9, 7);
        let p = GemmProblem::random(&spec, 911);
        for op in [GemmOp::AddMax, GemmOp::AddMin, GemmOp::MulMax, GemmOp::MulMin] {
            let z = gemm_golden_op(&p.x, &p.w, &p.y, op);
            for i in 0..spec.m {
                for j in 0..spec.k {
                    let mut acc = p.y.at(i, j).to_f64();
                    for t in 0..spec.n {
                        let (x, w) = (p.x.at(i, t).to_f64(), p.w.at(t, j).to_f64());
                        let e = match op {
                            GemmOp::AddMax | GemmOp::AddMin => Fp16::from_f64(x + w).to_f64(),
                            _ => Fp16::from_f64(x * w).to_f64(),
                        };
                        acc = match op {
                            GemmOp::AddMax | GemmOp::MulMax => acc.max(e),
                            _ => acc.min(e),
                        };
                    }
                    assert_eq!(z.at(i, j).to_f64(), acc, "{op:?} at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn max_min_reductions_bound_each_other() {
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random(&spec, 31337);
        let zmax = gemm_golden_op(&p.x, &p.w, &p.y, GemmOp::MulMax);
        let zmin = gemm_golden_op(&p.x, &p.w, &p.y, GemmOp::MulMin);
        for i in 0..spec.m {
            for j in 0..spec.k {
                let y = p.y.at(i, j).to_f64();
                assert!(zmax.at(i, j).to_f64() >= y, "max reduction can only raise y");
                assert!(zmin.at(i, j).to_f64() <= y, "min reduction can only lower y");
                assert!(zmax.at(i, j).to_f64() >= zmin.at(i, j).to_f64());
            }
        }
    }

    #[test]
    fn fp8_abft_carried_checksums_are_within_format_tolerance() {
        // Empirical calibration of the format-aware tolerance, mirroring
        // `abft_carried_checksums_are_within_tolerance`: the augmented
        // problem's operands (including the checksum row/column) and the
        // final Z all pass through the cast units, so residuals carry FP8
        // quantization noise. The F=1 format bound must hold on clean
        // runs for both formats across shapes and seeds.
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let g = GemmFormat::Fp8(fmt);
            let u = g.unit_roundoff();
            for (m, n, k) in [(12, 16, 16), (5, 7, 3), (24, 33, 17)] {
                for seed in 0..8u64 {
                    let p = GemmProblem::random(&GemmSpec::new(m, n, k), 9_000 + seed * 131 + n as u64);
                    // The hardware path: host augments the (unquantized)
                    // problem, every fetched operand is cast-in, Z is
                    // cast-out. golden_z_for models exactly that.
                    let z_aug = p.augment_abft().golden_z_for(g, GemmOp::Mul);
                    let (data, carried_rows, carried_cols) = split_abft_z(&z_aug);
                    for i in 0..m {
                        let obs: f64 = (0..k).map(|j| data.at(i, j).to_f64()).sum();
                        let abs: f64 = (0..k).map(|j| data.at(i, j).to_f64().abs()).sum();
                        let dev = (obs - carried_rows[i].to_f64()).abs();
                        let tol = abft_tolerance_scaled_for(g, 1.0, n, k, abs);
                        assert!(
                            dev <= tol,
                            "{fmt:?} row {i} of ({m},{n},{k}) seed {seed}: dev {dev} > tol {tol}"
                        );
                    }
                    for j in 0..k {
                        let obs: f64 = (0..m).map(|i| data.at(i, j).to_f64()).sum();
                        let abs: f64 = (0..m).map(|i| data.at(i, j).to_f64().abs()).sum();
                        let dev = (obs - carried_cols[j].to_f64()).abs();
                        let tol = abft_tolerance_scaled_for(g, 1.0, n, m, abs);
                        assert!(
                            dev <= tol,
                            "{fmt:?} col {j} of ({m},{n},{k}) seed {seed}: dev {dev} > tol {tol}"
                        );
                    }
                }
            }
            // And the format bound is genuinely looser than FP16's.
            assert!(u > EPS16);
            assert!(
                abft_tolerance_scaled_for(g, 4.0, 16, 16, 10.0)
                    > abft_tolerance_scaled(4.0, 16, 16, 10.0)
            );
        }
        // FP16 delegates to the exact legacy expression.
        assert_eq!(
            abft_tolerance_scaled_for(GemmFormat::Fp16, 4.0, 16, 16, 10.0).to_bits(),
            abft_tolerance_scaled(4.0, 16, 16, 10.0).to_bits()
        );
    }

    #[test]
    fn abft_augmented_data_region_is_bit_exact() {
        for (m, n, k) in [(12, 16, 16), (1, 1, 1), (5, 7, 3), (13, 17, 19)] {
            let p = GemmProblem::random(&GemmSpec::new(m, n, k), 0xAB + m as u64);
            let golden = p.golden_z();
            let aug = p.augment_abft();
            assert_eq!((aug.spec.m, aug.spec.n, aug.spec.k), (m + 1, n, k + 1));
            let z_aug = aug.golden_z();
            let (data, carried_rows, carried_cols) = split_abft_z(&z_aug);
            assert_eq!(data.bits(), golden.bits(), "({m},{n},{k})");
            assert_eq!(carried_rows.len(), m + 1);
            assert_eq!(carried_cols.len(), k);
        }
    }

    #[test]
    fn abft_carried_checksums_are_within_tolerance() {
        for (m, n, k) in [(12, 16, 16), (5, 7, 3), (24, 33, 17), (12, 64, 48)] {
            let p = GemmProblem::random(&GemmSpec::new(m, n, k), 7_000 + n as u64);
            let z_aug = p.augment_abft().golden_z();
            let (data, carried_rows, carried_cols) = split_abft_z(&z_aug);
            for i in 0..m {
                let obs: f64 = (0..k).map(|j| data.at(i, j).to_f64()).sum();
                let abs: f64 = (0..k).map(|j| data.at(i, j).to_f64().abs()).sum();
                let dev = (obs - carried_rows[i].to_f64()).abs();
                let tol = abft_tolerance(n, k, abs);
                assert!(dev <= tol, "row {i} of ({m},{n},{k}): dev {dev} > tol {tol}");
            }
            for j in 0..k {
                let obs: f64 = (0..m).map(|i| data.at(i, j).to_f64()).sum();
                let abs: f64 = (0..m).map(|i| data.at(i, j).to_f64().abs()).sum();
                let dev = (obs - carried_cols[j].to_f64()).abs();
                let tol = abft_tolerance(n, m, abs);
                assert!(dev <= tol, "col {j} of ({m},{n},{k}): dev {dev} > tol {tol}");
            }
        }
    }

    #[test]
    fn exact_checksums_round_trip_clean() {
        let mut rng = Xoshiro256::new(55);
        for _ in 0..20 {
            let m = 1 + rng.below(10) as usize;
            let k = 1 + rng.below(10) as usize;
            let mat = Mat::random(m, k, 1.0, &mut rng);
            let chk = mat.abft_checksums();
            assert!(mat.abft_verify(&chk).is_clean());
        }
    }

    #[test]
    fn exact_checksums_detect_and_locate_every_single_bit_flip() {
        let mut rng = Xoshiro256::new(91);
        let mut mat = Mat::random(6, 5, 1.0, &mut rng);
        let chk = mat.abft_checksums();
        for i in 0..6 {
            for j in 0..5 {
                for b in 0..16u16 {
                    let orig = mat.at(i, j);
                    mat.set(i, j, Fp16::from_bits(orig.to_bits() ^ (1 << b)));
                    let mm = mat.abft_verify(&chk);
                    assert_eq!(mm.located(), Some((i, j)), "flip bit {b} of ({i},{j})");
                    mat.set(i, j, orig);
                }
            }
        }
        assert!(mat.abft_verify(&chk).is_clean(), "restores must round-trip");
    }

    #[test]
    fn fixed_point_conversion_is_exact_and_flags_non_finite() {
        let mut rng = Xoshiro256::new(123);
        for _ in 0..5_000 {
            let v = Fp16::from_bits(rng.next_u32() as u16);
            if v.is_finite() {
                assert_eq!(fixed_to_f64(fp16_to_fixed(v)), v.to_f64());
            } else {
                assert!(fp16_to_fixed(v) > 1 << 44, "{v:?}");
            }
        }
        assert_eq!(fp16_to_fixed(Fp16::MIN_SUBNORMAL), 1);
        assert_eq!(fp16_to_fixed(Fp16::ONE), 1 << FX_FRAC_BITS);
        assert_eq!(fp16_to_fixed(Fp16::ZERO), 0);
    }

    #[test]
    fn residual_analysis_locates_and_corrects_every_single_bit_flip() {
        // Simulate the online taps over a 4x5 store stream with one
        // corrupted element per trial: every bit flip of every element is
        // located and corrected bit-exactly, including flips into
        // NaN/Inf space and sign flips of zero.
        let mut rng = Xoshiro256::new(0x0511);
        let (m, k) = (4usize, 5usize);
        let mut mat = Mat::random(m, k, 1.0, &mut rng);
        mat.set(2, 3, Fp16::ZERO); // value-preserving corner
        for i in 0..m {
            for j in 0..k {
                for b in 0..16u16 {
                    let orig = mat.at(i, j);
                    let bad = Fp16::from_bits(orig.to_bits() ^ (1 << b));
                    let mut row_fx = vec![0i64; m];
                    let mut row_bits = vec![0i64; m];
                    let mut col_fx = vec![0i64; k];
                    let mut col_bits = vec![0i64; k];
                    for r in 0..m {
                        for c in 0..k {
                            let pre = mat.at(r, c);
                            let stored = if (r, c) == (i, j) { bad } else { pre };
                            let dfx = fp16_to_fixed(stored) - fp16_to_fixed(pre);
                            let dbits = stored.to_bits() as i64 - pre.to_bits() as i64;
                            row_fx[r] += dfx;
                            row_bits[r] += dbits;
                            col_fx[c] += dfx;
                            col_bits[c] += dbits;
                        }
                    }
                    match analyze_residuals((&row_fx, &row_bits), (&col_fx, &col_bits)) {
                        ResidualVerdict::Single { row, col, delta_bits, .. } => {
                            assert_eq!((row, col), (i, j), "flip bit {b} of ({i},{j})");
                            let fixed = correct_from_residual(bad, delta_bits)
                                .expect("single store corruption must invert");
                            assert_eq!(fixed.to_bits(), orig.to_bits());
                        }
                        v => panic!("flip bit {b} of ({i},{j}): verdict {v:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn residual_analysis_refuses_multi_error_and_inconsistent_patterns() {
        // Clean banks.
        let z4 = vec![0i64; 4];
        let z5 = vec![0i64; 5];
        assert_eq!(
            analyze_residuals((&z4, &z4), (&z5, &z5)),
            ResidualVerdict::Clean
        );
        // Two corrupted elements in distinct rows/columns.
        let mut rb = z4.clone();
        let mut cb = z5.clone();
        rb[0] = 7;
        rb[2] = -3;
        cb[1] = 7;
        cb[4] = -3;
        assert_eq!(
            analyze_residuals((&z4, &rb), (&z5, &cb)),
            ResidualVerdict::Multi
        );
        // One row flagged, no column (residual-register SEU): not a
        // locatable corruption.
        let mut rfx = z4.clone();
        rfx[1] = 1 << 24;
        assert_eq!(
            analyze_residuals((&rfx, &z4), (&z5, &z5)),
            ResidualVerdict::Multi
        );
        // Row and column flagged but with disagreeing deltas.
        let mut rb2 = z4.clone();
        let mut cb2 = z5.clone();
        rb2[1] = 5;
        cb2[2] = 6;
        assert_eq!(
            analyze_residuals((&z4, &rb2), (&z5, &cb2)),
            ResidualVerdict::Multi
        );
        // Out-of-range delta refuses to fabricate a value.
        assert_eq!(correct_from_residual(Fp16::ZERO, 1), None);
        assert_eq!(correct_from_residual(Fp16::ZERO, -0x1_0000), None);
        assert_eq!(
            correct_from_residual(Fp16::ZERO, -1).map(|v| v.to_bits()),
            Some(1)
        );
    }

    #[test]
    fn result_stays_finite_for_unit_magnitude_inputs() {
        // 16-term dot products of values in [-1, 1] plus y in [-1, 1] can
        // reach at most 17 — far from FP16 overflow (65504).
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random(&spec, 5);
        let z = p.golden_z();
        for v in &z.data {
            assert!(v.is_finite());
        }
    }
}
