//! Bit-exact golden model of the RedMulE operation `Z = Y + X·W`.
//!
//! The accumulation order is the contract: the hardware's row of `H`
//! cascaded FMAs sweeps the inner dimension in ascending order, so
//!
//! ```text
//! acc = Y[m][k]
//! for n in 0..N: acc = fma16(X[m][n], W[n][k], acc)   // single rounding
//! Z[m][k] = acc
//! ```
//!
//! The same order is implemented by the Layer-1 Pallas kernel (see
//! `python/compile/kernels/redmule.py`), which makes the Rust golden, the
//! simulator and the PJRT-executed artifact all bit-identical. Run
//! classification in the fault campaign compares raw `u16` patterns.

use crate::fp::{fma16, Fp16, Fp8, Fp8Format};
use crate::util::rng::Xoshiro256;

/// A row-major FP16 matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<Fp16>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![Fp16::ZERO; rows * cols],
        }
    }

    /// Uniform random entries in `[-mag, mag]` (finite, well-conditioned
    /// for FP16 accumulation — the campaign workload uses mag = 1).
    pub fn random(rows: usize, cols: usize, mag: f64, rng: &mut Xoshiro256) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_fp16_in(mag)).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> Fp16 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: Fp16) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn bits(&self) -> Vec<u16> {
        self.data.iter().map(|v| v.to_bits()).collect()
    }

    pub fn to_f64_vec(&self) -> Vec<f64> {
        self.data.iter().map(|v| v.to_f64()).collect()
    }

    pub fn from_f64_slice(rows: usize, cols: usize, vals: &[f64]) -> Self {
        assert_eq!(vals.len(), rows * cols);
        Self {
            rows,
            cols,
            data: vals.iter().map(|&v| Fp16::from_f64(v)).collect(),
        }
    }

    /// Snap every element onto the FP8 grid (RTNE, saturating) — the
    /// hybrid-FP8 input path of §2.1: values arrive as 8-bit floats and
    /// widen losslessly back to FP16 at the compute elements.
    pub fn quantize_fp8(&self, format: Fp8Format) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .map(|&v| Fp8::from_fp16(v, format, true).to_fp16())
                .collect(),
        }
    }
}

/// GEMM problem dimensions: `X[M][N] · W[N][K] + Y[M][K]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmSpec {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmSpec {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        assert!(m > 0 && n > 0 && k > 0);
        Self { m, n, k }
    }

    /// The paper's fault-injection workload: (12 × 16 × 16).
    pub fn paper_workload() -> Self {
        Self::new(12, 16, 16)
    }

    pub fn macs(&self) -> u64 {
        (self.m * self.n * self.k) as u64
    }
}

/// A concrete GEMM instance: inputs plus the memoised golden output.
#[derive(Debug, Clone)]
pub struct GemmProblem {
    pub spec: GemmSpec,
    pub x: Mat,
    pub w: Mat,
    pub y: Mat,
}

impl GemmProblem {
    /// Hybrid-FP8 workload: X and W on the FP8 grid, Y/Z in FP16 — the
    /// accumulation path is unchanged (widening CEs), so the same golden,
    /// simulator and kernel all apply bit-exactly.
    pub fn random_fp8(spec: &GemmSpec, format: Fp8Format, seed: u64) -> Self {
        let p = Self::random(spec, seed);
        Self {
            spec: p.spec,
            x: p.x.quantize_fp8(format),
            w: p.w.quantize_fp8(format),
            y: p.y,
        }
    }

    pub fn random(spec: &GemmSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self {
            spec: *spec,
            x: Mat::random(spec.m, spec.n, 1.0, &mut rng),
            w: Mat::random(spec.n, spec.k, 1.0, &mut rng),
            y: Mat::random(spec.m, spec.k, 1.0, &mut rng),
        }
    }

    /// Bit-exact reference result in the hardware accumulation order.
    pub fn golden_z(&self) -> Mat {
        gemm_golden(&self.x, &self.w, &self.y)
    }
}

/// `Z = Y + X·W` with the RedMulE accumulation order (ascending `n`,
/// single-rounded FMA at every step).
pub fn gemm_golden(x: &Mat, w: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols, w.rows, "inner dimensions must agree");
    assert_eq!(y.rows, x.rows);
    assert_eq!(y.cols, w.cols);
    let (m, n, k) = (x.rows, x.cols, w.cols);
    let mut z = Mat::zeros(m, k);
    for i in 0..m {
        for j in 0..k {
            let mut acc = y.at(i, j);
            for t in 0..n {
                acc = fma16(x.at(i, t), w.at(t, j), acc);
            }
            z.set(i, j, acc);
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_weight_passes_x_through_plus_y() {
        let m = 4;
        let n = 4;
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            w.set(i, i, Fp16::ONE);
        }
        let mut rng = Xoshiro256::new(3);
        let x = Mat::random(m, n, 1.0, &mut rng);
        let y = Mat::zeros(m, n);
        let z = gemm_golden(&x, &w, &y);
        assert_eq!(z.bits(), x.bits());
    }

    #[test]
    fn zero_x_returns_y_when_y_nonnegative() {
        // With x = 0 every FMA adds 0*w — exact, so acc stays y... except
        // that adding -0 or crossing signed zero never occurs for finite y:
        // fma(0, w, y) = y exactly (0*w = ±0, y + ±0 = y for y != 0).
        let spec = GemmSpec::new(3, 5, 4);
        let mut rng = Xoshiro256::new(7);
        let x = Mat::zeros(spec.m, spec.n);
        let w = Mat::random(spec.n, spec.k, 1.0, &mut rng);
        let mut y = Mat::random(spec.m, spec.k, 1.0, &mut rng);
        // Avoid y == -0 edge (would become +0).
        for v in y.data.iter_mut() {
            if v.is_zero() {
                *v = Fp16::ONE;
            }
        }
        let z = gemm_golden(&x, &w, &y);
        assert_eq!(z.bits(), y.bits());
    }

    #[test]
    fn accumulation_order_matters_and_is_fixed() {
        // FP16 addition is not associative; verify our order is the
        // ascending-n chain by checking against a hand-rolled loop.
        let spec = GemmSpec::new(2, 8, 2);
        let p = GemmProblem::random(&spec, 99);
        let z = p.golden_z();
        for i in 0..spec.m {
            for j in 0..spec.k {
                let mut acc = p.y.at(i, j);
                for t in 0..spec.n {
                    acc = fma16(p.x.at(i, t), p.w.at(t, j), acc);
                }
                assert_eq!(z.at(i, j).to_bits(), acc.to_bits());
            }
        }
    }

    #[test]
    fn golden_is_deterministic_across_seeds_and_calls() {
        let spec = GemmSpec::paper_workload();
        let p1 = GemmProblem::random(&spec, 1234);
        let p2 = GemmProblem::random(&spec, 1234);
        assert_eq!(p1.golden_z().bits(), p2.golden_z().bits());
        let p3 = GemmProblem::random(&spec, 1235);
        assert_ne!(p3.golden_z().bits(), p1.golden_z().bits());
    }

    #[test]
    fn fp8_quantization_is_idempotent_and_lossy() {
        let spec = GemmSpec::new(6, 8, 6);
        let p = GemmProblem::random(&spec, 77);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let q = p.x.quantize_fp8(fmt);
            // Idempotent: the grid is a fixed point.
            assert_eq!(q.quantize_fp8(fmt).bits(), q.bits());
            // Lossy on generic FP16 data.
            assert_ne!(q.bits(), p.x.bits());
        }
    }

    #[test]
    fn fp8_problem_runs_through_the_same_golden() {
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random_fp8(&spec, Fp8Format::E4M3, 3);
        let z = p.golden_z();
        for v in &z.data {
            assert!(v.is_finite());
        }
        // X/W really live on the FP8 grid.
        for v in &p.x.data {
            let rt = Fp8::from_fp16(*v, Fp8Format::E4M3, true).to_fp16();
            assert_eq!(rt.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn result_stays_finite_for_unit_magnitude_inputs() {
        // 16-term dot products of values in [-1, 1] plus y in [-1, 1] can
        // reach at most 17 — far from FP16 overflow (65504).
        let spec = GemmSpec::paper_workload();
        let p = GemmProblem::random(&spec, 5);
        let z = p.golden_z();
        for v in &z.data {
            assert!(v.is_finite());
        }
    }
}
