//! Mixed-criticality task coordinator — the system-level face of the
//! paper's *runtime-configurable* fault tolerance (§1, §3.4).
//!
//! The motivation in the paper's introduction is mixed-criticality
//! autonomous systems: neural-network feature extraction wants maximum
//! throughput, safety-critical control tasks want guaranteed detection.
//! RedMulE-FT serves both from one accelerator because the mode lives in
//! a register, not in the netlist. The coordinator is the runtime that
//! exploits that: a leader thread owns a queue of GEMM tasks tagged with
//! a criticality class, maps each class to an execution mode and a retry
//! policy, drives one or more [`System`] workers, and accounts for every
//! cycle so the throughput/reliability trade-off is visible in metrics.
//!
//! Policy (matching §3.4 semantics):
//!
//! * `Critical` tasks run in fault-tolerant mode; detected faults are
//!   retried on the spot (bounded by [`crate::cluster::MAX_RETRIES`]).
//! * `BestEffort` tasks run in performance mode; on protected builds a
//!   detected control-path fault aborts the task, and the coordinator
//!   either re-queues or fails it depending on the policy.

use crate::cluster::{HostOutcome, RunReport, System};
use crate::golden::{GemmProblem, Mat};
use crate::redmule::{ExecMode, Protection, RedMuleConfig};
use crate::{Error, Result};
use std::collections::VecDeque;

/// Criticality classes of submitted work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Criticality {
    /// Safety-critical: must be fault-tolerant; silent corruption is
    /// unacceptable.
    Critical,
    /// Throughput-oriented: runs unprotected at 2× speed.
    BestEffort,
}

impl Criticality {
    pub fn exec_mode(self) -> ExecMode {
        match self {
            Criticality::Critical => ExecMode::FaultTolerant,
            Criticality::BestEffort => ExecMode::Performance,
        }
    }
}

/// One unit of work.
#[derive(Debug, Clone)]
pub struct TaskRequest {
    pub id: u64,
    pub criticality: Criticality,
    pub problem: GemmProblem,
    /// Re-queue budget for best-effort tasks aborted by the control-path
    /// checkers.
    pub requeue_budget: u32,
}

impl TaskRequest {
    pub fn new(id: u64, criticality: Criticality, problem: GemmProblem) -> Self {
        Self {
            id,
            criticality,
            problem,
            requeue_budget: 1,
        }
    }
}

/// Completed-task record.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub id: u64,
    pub criticality: Criticality,
    pub outcome: HostOutcome,
    pub retries: u32,
    pub requeues: u32,
    pub cycles: u64,
    pub z: Mat,
}

/// Aggregate coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub completed: u64,
    pub completed_after_retry: u64,
    pub requeued: u64,
    pub failed: u64,
    pub critical_cycles: u64,
    pub best_effort_cycles: u64,
    pub config_cycles: u64,
}

impl Metrics {
    pub fn total_cycles(&self) -> u64 {
        self.critical_cycles + self.best_effort_cycles + self.config_cycles
    }
}

/// The leader: owns the queue and the accelerator system(s).
pub struct Coordinator {
    queue: VecDeque<TaskRequest>,
    system: System,
    pub metrics: Metrics,
    results: Vec<TaskResult>,
    next_id: u64,
}

impl Coordinator {
    pub fn new(cfg: RedMuleConfig, protection: Protection) -> Self {
        Self {
            queue: VecDeque::new(),
            system: System::new(cfg, protection),
            metrics: Metrics::default(),
            results: Vec::new(),
            next_id: 0,
        }
    }

    pub fn protection(&self) -> Protection {
        self.system.protection()
    }

    /// Enqueue a task; returns its id.
    pub fn submit(&mut self, criticality: Criticality, problem: GemmProblem) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(TaskRequest::new(id, criticality, problem));
        self.metrics.submitted += 1;
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn results(&self) -> &[TaskResult] {
        &self.results
    }

    /// Run one queued task to completion (the leader loop's body).
    /// Returns `Ok(None)` when the queue is empty or the task was
    /// re-queued.
    pub fn step(&mut self) -> Result<Option<&TaskResult>> {
        let Some(task) = self.queue.pop_front() else {
            return Ok(None);
        };
        let mode = task.criticality.exec_mode();
        let protection = self.system.protection();
        if task.criticality == Criticality::Critical
            && !protection.has_data_protection()
            && !protection.has_abft_checksums()
        {
            return Err(Error::Config(
                "critical tasks require a data-protected or ABFT build".into(),
            ));
        }
        let report = self.system.run_gemm(&task.problem, mode)?;
        self.account(&task, &report);

        match report.outcome {
            HostOutcome::Completed | HostOutcome::CompletedAfterRetry => {
                self.finish(task, report);
                Ok(self.results.last())
            }
            HostOutcome::Abandoned if task.requeue_budget > 0 => {
                // Best-effort abort: re-queue once at the tail.
                self.metrics.requeued += 1;
                let mut requeued = task;
                requeued.requeue_budget -= 1;
                self.queue.push_back(requeued);
                Ok(None)
            }
            HostOutcome::Abandoned | HostOutcome::TimedOut => {
                self.metrics.failed += 1;
                self.finish(task, report);
                Ok(self.results.last())
            }
        }
    }

    /// Drain the queue, returning how many tasks completed successfully.
    pub fn run_to_idle(&mut self) -> Result<u64> {
        let mut steps = 0u64;
        while !self.queue.is_empty() {
            self.step()?;
            steps += 1;
            if steps > 1_000_000 {
                return Err(Error::Sim("coordinator livelock".into()));
            }
        }
        Ok(self.metrics.completed)
    }

    fn account(&mut self, task: &TaskRequest, report: &RunReport) {
        match task.criticality {
            Criticality::Critical => self.metrics.critical_cycles += report.cycles,
            Criticality::BestEffort => self.metrics.best_effort_cycles += report.cycles,
        }
        self.metrics.config_cycles += report.config_cycles;
    }

    fn finish(&mut self, task: TaskRequest, report: RunReport) {
        if matches!(
            report.outcome,
            HostOutcome::Completed | HostOutcome::CompletedAfterRetry
        ) {
            self.metrics.completed += 1;
            if report.outcome == HostOutcome::CompletedAfterRetry {
                self.metrics.completed_after_retry += 1;
            }
        }
        self.results.push(TaskResult {
            id: task.id,
            criticality: task.criticality,
            outcome: report.outcome,
            retries: report.retries,
            requeues: 1u32.saturating_sub(task.requeue_budget),
            cycles: report.cycles,
            z: report.z,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::GemmSpec;

    fn problems(n: usize, seed: u64) -> Vec<GemmProblem> {
        (0..n)
            .map(|i| GemmProblem::random(&GemmSpec::paper_workload(), seed + i as u64))
            .collect()
    }

    #[test]
    fn mixed_queue_completes_and_results_are_golden() {
        let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
        let ps = problems(6, 10);
        for (i, p) in ps.iter().enumerate() {
            let crit = if i % 2 == 0 {
                Criticality::Critical
            } else {
                Criticality::BestEffort
            };
            c.submit(crit, p.clone());
        }
        let done = c.run_to_idle().unwrap();
        assert_eq!(done, 6);
        for r in c.results() {
            let golden = ps[r.id as usize].golden_z();
            assert_eq!(r.z.bits(), golden.bits(), "task {}", r.id);
        }
        // Critical tasks pay ~2× the cycles of best-effort ones.
        let crit: Vec<_> = c
            .results()
            .iter()
            .filter(|r| r.criticality == Criticality::Critical)
            .collect();
        let be: Vec<_> = c
            .results()
            .iter()
            .filter(|r| r.criticality == Criticality::BestEffort)
            .collect();
        let avg = |v: &[&TaskResult]| {
            v.iter().map(|r| r.cycles).sum::<u64>() as f64 / v.len() as f64
        };
        let ratio = avg(&crit) / avg(&be);
        assert!((1.5..=2.5).contains(&ratio), "FT/perf ratio {ratio:.2}");
    }

    #[test]
    fn critical_on_unprotected_build_is_rejected() {
        let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Baseline);
        c.submit(Criticality::Critical, problems(1, 3)[0].clone());
        assert!(c.step().is_err());
    }

    #[test]
    fn best_effort_on_baseline_build_works() {
        let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Baseline);
        let p = problems(1, 4)[0].clone();
        c.submit(Criticality::BestEffort, p.clone());
        c.run_to_idle().unwrap();
        assert_eq!(c.metrics.completed, 1);
        assert_eq!(c.results()[0].z.bits(), p.golden_z().bits());
    }

    #[test]
    fn metrics_track_cycles_by_class() {
        let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
        let ps = problems(2, 20);
        c.submit(Criticality::Critical, ps[0].clone());
        c.submit(Criticality::BestEffort, ps[1].clone());
        c.run_to_idle().unwrap();
        assert!(c.metrics.critical_cycles > c.metrics.best_effort_cycles);
        assert!(c.metrics.config_cycles >= 120);
        assert_eq!(c.metrics.submitted, 2);
    }

    #[test]
    fn empty_queue_steps_to_none() {
        let mut c = Coordinator::new(RedMuleConfig::paper(), Protection::Full);
        assert!(c.step().unwrap().is_none());
    }
}
