//! Cluster DMA engine model.
//!
//! Moves data between the (flat, un-modelled-latency) L2 memory and the
//! TCDM, as the PULP cluster's dedicated DMA does for kernel staging. The
//! timing model charges a programming overhead per transfer plus a
//! bandwidth-limited copy (8 bytes/cycle toward TCDM, matching a 64-bit
//! AXI port), and reports the cycles consumed so the performance model can
//! account for staging in end-to-end numbers.

use crate::fp::Fp16;
use crate::tcdm::Tcdm;

/// Cycles to program one DMA transfer descriptor from a core.
pub const PROGRAM_CYCLES: u64 = 10;
/// Bytes moved per cycle once a transfer is running.
pub const BYTES_PER_CYCLE: u64 = 8;

/// Flat external (L2) memory.
#[derive(Debug, Clone, Default)]
pub struct L2Mem {
    pub bytes: Vec<u8>,
}

impl L2Mem {
    pub fn new(size: usize) -> Self {
        Self { bytes: vec![0; size] }
    }

    pub fn write_fp16_slice(&mut self, addr: usize, values: &[Fp16]) {
        for (i, v) in values.iter().enumerate() {
            let b = v.to_bits().to_le_bytes();
            self.bytes[addr + 2 * i] = b[0];
            self.bytes[addr + 2 * i + 1] = b[1];
        }
    }

    pub fn read_fp16_slice(&self, addr: usize, n: usize) -> Vec<Fp16> {
        (0..n)
            .map(|i| {
                Fp16::from_bits(u16::from_le_bytes([
                    self.bytes[addr + 2 * i],
                    self.bytes[addr + 2 * i + 1],
                ]))
            })
            .collect()
    }
}

/// Completed-transfer record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub bytes: u64,
    pub cycles: u64,
}

/// The DMA engine: synchronous copy + cycle accounting.
#[derive(Debug, Default)]
pub struct Dma {
    pub total_cycles: u64,
    pub total_bytes: u64,
    pub transfers: u64,
}

impl Dma {
    pub fn new() -> Self {
        Self::default()
    }

    fn charge(&mut self, bytes: u64) -> Transfer {
        let cycles = PROGRAM_CYCLES + bytes.div_ceil(BYTES_PER_CYCLE);
        self.total_cycles += cycles;
        self.total_bytes += bytes;
        self.transfers += 1;
        Transfer { bytes, cycles }
    }

    /// L2 → TCDM copy (word granular; `len` in bytes, 4-aligned).
    pub fn copy_in(&mut self, l2: &L2Mem, l2_addr: usize, tcdm: &mut Tcdm, tcdm_addr: u32, len: usize) -> Transfer {
        assert_eq!(len % 4, 0, "DMA transfers are word-granular");
        for i in (0..len).step_by(4) {
            let w = u32::from_le_bytes([
                l2.bytes[l2_addr + i],
                l2.bytes[l2_addr + i + 1],
                l2.bytes[l2_addr + i + 2],
                l2.bytes[l2_addr + i + 3],
            ]);
            tcdm.write_word(tcdm_addr + i as u32, w);
        }
        self.charge(len as u64)
    }

    /// TCDM → L2 copy.
    pub fn copy_out(&mut self, tcdm: &mut Tcdm, tcdm_addr: u32, l2: &mut L2Mem, l2_addr: usize, len: usize) -> Transfer {
        assert_eq!(len % 4, 0, "DMA transfers are word-granular");
        for i in (0..len).step_by(4) {
            let (w, _) = tcdm.read_word(tcdm_addr + i as u32);
            l2.bytes[l2_addr + i..l2_addr + i + 4].copy_from_slice(&w.to_le_bytes());
        }
        self.charge(len as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_through_tcdm() {
        let mut l2 = L2Mem::new(4096);
        let mut l2_out = L2Mem::new(4096);
        let mut tcdm = Tcdm::new(4, 1024);
        let mut dma = Dma::new();

        let vals: Vec<Fp16> = (0..64).map(|i| Fp16::from_f64(i as f64 * 0.25 - 4.0)).collect();
        l2.write_fp16_slice(0, &vals);
        let t1 = dma.copy_in(&l2, 0, &mut tcdm, 0x40, 128);
        assert_eq!(t1.bytes, 128);
        assert_eq!(t1.cycles, PROGRAM_CYCLES + 16);

        let got = tcdm.read_fp16_slice(0x40, 64);
        assert_eq!(got, vals);

        dma.copy_out(&mut tcdm, 0x40, &mut l2_out, 256, 128);
        assert_eq!(l2_out.read_fp16_slice(256, 64), vals);
        assert_eq!(dma.transfers, 2);
        assert_eq!(dma.total_bytes, 256);
    }

    #[test]
    fn cycle_model_rounds_up() {
        let mut dma = Dma::new();
        let l2 = L2Mem::new(64);
        let mut tcdm = Tcdm::new(4, 256);
        let t = dma.copy_in(&l2, 0, &mut tcdm, 0, 12);
        assert_eq!(t.cycles, PROGRAM_CYCLES + 2); // 12 bytes over 8 B/cyc
    }

    #[test]
    #[should_panic(expected = "word-granular")]
    fn unaligned_length_rejected() {
        let mut dma = Dma::new();
        let l2 = L2Mem::new(64);
        let mut tcdm = Tcdm::new(4, 256);
        dma.copy_in(&l2, 0, &mut tcdm, 0, 6);
    }
}
