//! SECDED Hamming (39,32): 32 data bits, 6 Hamming check bits, 1 overall
//! parity bit. Corrects any single-bit error, detects any double-bit error.
//!
//! Codeword layout (classic Hamming positions): bit positions 1..=38 hold
//! check bits at powers of two (1,2,4,8,16,32) and data bits elsewhere;
//! position 0 holds the overall (even) parity over positions 1..=38.

pub const DATA_BITS: u32 = 32;
pub const CODE_BITS: u32 = 39;

/// Result of decoding a (possibly corrupted) codeword.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeStatus {
    /// No error detected.
    Clean,
    /// Single-bit error corrected (codeword bit position reported).
    Corrected(u32),
    /// Uncorrectable double-bit error detected.
    DoubleError,
}

/// Positions 1..=38 that carry data (everything that isn't a power of two).
fn data_positions() -> impl Iterator<Item = u32> {
    (1u32..39).filter(|p| !p.is_power_of_two())
}

/// Encode 32 data bits into a 39-bit codeword (stored in the low bits).
pub fn encode32(data: u32) -> u64 {
    let mut code: u64 = 0;
    // Scatter data bits into non-power-of-two positions.
    for (i, p) in data_positions().enumerate() {
        if (data >> i) & 1 == 1 {
            code |= 1 << p;
        }
    }
    // Hamming check bits: check bit at position 2^k covers positions with
    // bit k set in their index.
    for k in 0..6 {
        let pbit = 1u32 << k;
        let mut parity = 0u64;
        for p in 1..39u32 {
            if p & pbit != 0 && !p.is_power_of_two() {
                parity ^= (code >> p) & 1;
            }
        }
        if parity == 1 {
            code |= 1 << pbit;
        }
    }
    // Overall even parity over positions 1..=38 goes to position 0.
    let overall = ((code >> 1).count_ones() & 1) as u64;
    code | overall
}

/// Decode a 39-bit codeword, correcting single errors.
pub fn decode32(code: u64) -> (u32, DecodeStatus) {
    // Recompute the syndrome.
    let mut syndrome = 0u32;
    for k in 0..6 {
        let pbit = 1u32 << k;
        let mut parity = 0u64;
        for p in 1..39u32 {
            if p & pbit != 0 {
                parity ^= (code >> p) & 1;
            }
        }
        if parity == 1 {
            syndrome |= pbit;
        }
    }
    let overall = (code.count_ones() & 1) as u64; // parity over all 39 bits

    let mut corrected = code;
    let status = match (syndrome, overall & 1) {
        (0, 0) => DecodeStatus::Clean,
        (0, _) => {
            // Overall parity bit itself flipped.
            corrected ^= 1;
            DecodeStatus::Corrected(0)
        }
        (s, 1) => {
            // Single-bit error at codeword position s.
            if s < 39 {
                corrected ^= 1 << s;
                DecodeStatus::Corrected(s)
            } else {
                DecodeStatus::DoubleError
            }
        }
        (_, _) => DecodeStatus::DoubleError,
    };

    if status == DecodeStatus::DoubleError {
        // Return the raw data bits; callers must treat them as poisoned.
        return (gather(code), status);
    }
    (gather(corrected), status)
}

fn gather(code: u64) -> u32 {
    let mut data = 0u32;
    for (i, p) in data_positions().enumerate() {
        if (code >> p) & 1 == 1 {
            data |= 1 << i;
        }
    }
    data
}

/// Gate-count estimate for one encoder (XOR tree): used by the area model.
pub fn encoder_xor_count() -> u32 {
    // Each of the 6 check bits XORs ~18 inputs; overall parity XORs 38.
    6 * 18 + 38
}

/// Gate-count estimate for one decoder (syndrome + correct mux).
pub fn decoder_xor_count() -> u32 {
    6 * 19 + 39 + 39 // syndrome trees + overall parity + correction muxes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn clean_round_trip() {
        let mut r = Xoshiro256::new(0xECC);
        for _ in 0..10_000 {
            let d = r.next_u32();
            let c = encode32(d);
            assert!(c < (1 << 39));
            let (back, st) = decode32(c);
            assert_eq!(back, d);
            assert_eq!(st, DecodeStatus::Clean);
        }
    }

    #[test]
    fn corrects_every_single_bit_error() {
        let mut r = Xoshiro256::new(0xECC1);
        for _ in 0..500 {
            let d = r.next_u32();
            let c = encode32(d);
            for b in 0..39u32 {
                let (back, st) = decode32(c ^ (1 << b));
                assert_eq!(back, d, "data recovered after flipping bit {b}");
                assert_eq!(st, DecodeStatus::Corrected(b));
            }
        }
    }

    #[test]
    fn detects_every_double_bit_error() {
        let mut r = Xoshiro256::new(0xECC2);
        for _ in 0..100 {
            let d = r.next_u32();
            let c = encode32(d);
            for b1 in 0..39u32 {
                for b2 in (b1 + 1)..39u32 {
                    let (_, st) = decode32(c ^ (1 << b1) ^ (1 << b2));
                    assert_eq!(
                        st,
                        DecodeStatus::DoubleError,
                        "double flip {b1},{b2} must be detected"
                    );
                }
            }
        }
    }

    #[test]
    fn distinct_data_distinct_codewords() {
        // Injectivity sanity (Hamming distance >= 4 between codewords).
        let c1 = encode32(0);
        let c2 = encode32(1);
        assert!((c1 ^ c2).count_ones() >= 4);
    }
}
