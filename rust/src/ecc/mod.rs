//! Error-detecting / error-correcting codes.
//!
//! Three codes are used across the system, mirroring the paper:
//!
//! * **SECDED Hamming (39,32)** — protects TCDM words and the
//!   interconnect (the "enhanced PULP cluster with ECC-protected
//!   interconnect and TCDM" of §3). Single-bit errors are corrected,
//!   double-bit errors detected.
//! * **Single parity bits** — accompany every broadcast weight element so
//!   each CE can verify `W` at the point of use (§3.1), and protect the
//!   configuration register file via host-computed XOR parity (§3.2).
//!
//! The encoder/decoder are deliberately written at bit level (not table
//! driven) so the fault injector can flip bits *inside* codewords and the
//! area model can count their gates.

pub mod secded;

pub use secded::{decode32, encode32, DecodeStatus, CODE_BITS, DATA_BITS};

use crate::fp::Fp16;
use crate::util::bits::{parity_u32, parity_u64};

/// Odd parity bit for a 16-bit weight element (odd so that an all-zero
/// wire bundle — a classic stuck/idle pattern — is detected as invalid).
#[inline]
pub fn weight_parity(w: Fp16) -> u8 {
    (parity_u32(w.to_bits() as u32) ^ 1) as u8
}

/// Check a weight element against its parity bit.
#[inline]
pub fn weight_parity_ok(w: Fp16, p: u8) -> bool {
    weight_parity(w) == (p & 1)
}

/// XOR parity over a configuration word, as computed by the cluster cores
/// before offloading (§3.2: "we extend it with XOR-based parity bits
/// computed by the cluster cores").
#[inline]
pub fn config_parity(word: u32) -> u8 {
    parity_u32(word) as u8
}

/// XOR parity over a full register-file image: one bit per word.
pub fn config_parity_vec(words: &[u32]) -> Vec<u8> {
    words.iter().map(|&w| config_parity(w)).collect()
}

/// Parity of a 64-bit beat, used on wide data links.
#[inline]
pub fn beat_parity(x: u64) -> u8 {
    parity_u64(x) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_parity_detects_any_single_flip() {
        for bits in (0u16..=0xFFFF).step_by(13) {
            let w = Fp16::from_bits(bits);
            let p = weight_parity(w);
            assert!(weight_parity_ok(w, p));
            for b in 0..16 {
                let w2 = Fp16::from_bits(bits ^ (1 << b));
                assert!(!weight_parity_ok(w2, p), "flip bit {b} of 0x{bits:04X}");
            }
            // Parity-bit flip is also detected.
            assert!(!weight_parity_ok(w, p ^ 1));
        }
    }

    #[test]
    fn all_zero_bundle_is_invalid() {
        // Odd parity: data=0 requires p=1, so (0, 0) must fail.
        assert!(!weight_parity_ok(Fp16::ZERO, 0));
    }

    #[test]
    fn config_parity_flags_single_bit_corruption() {
        let words = [0u32, 0xFFFF_FFFF, 0xDEAD_BEEF, 0x1234_5678];
        let ps = config_parity_vec(&words);
        for (i, &w) in words.iter().enumerate() {
            for b in 0..32 {
                assert_ne!(config_parity(w ^ (1 << b)), ps[i]);
            }
        }
    }
}
