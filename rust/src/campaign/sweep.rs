//! Scenario-grid sweep campaigns — the scale-out generalization of the
//! single-cell Table-1 campaign.
//!
//! A [`SweepConfig`] spans these axes:
//!
//! * **array geometry** (`RedMuleConfig` L/H/P instances): compare how
//!   array shape trades throughput against cross-section — more rows mean
//!   more exposed state per cycle but fewer cycles per workload,
//! * **numeric format** ([`GemmFormat`]): FP16 or an FP8 storage grid
//!   (E4M3/E5M2) — FP8 cells add the cast-unit fault sites to the
//!   population and quantize the golden expectations,
//! * **GEMM op** ([`GemmOp`]): the `(x op1 w) op2 acc` reduction family
//!   (mul/addmax/addmin/mulmax/mulmin),
//! * **protection build** (baseline / data / full / per-CE / ABFT),
//! * **GEMM shape** (the workload the faults land in),
//! * **fault count** per run, under an [`FaultModel`] (independent SEUs,
//!   one multi-bit burst, or one burst spanning adjacent *sites*) —
//!   FT-GEMM (arXiv:2305.02444) and the online ABFT GPU work
//!   (arXiv:2305.01024) both validate ABFT under multi-error regimes,
//!   not just single upsets,
//! * **ABFT tolerance factor** (ABFT cells only): the detection-rate vs
//!   false-positive trade of floating-point checksum verification,
//! * **mesh tile count** ([`SweepConfig::tiles`], default single-tile):
//!   multi-tile cells shard the workload across a RedMulE mesh and
//!   inject *interconnect* faults through the [`crate::mesh`] campaign
//!   (NoC link flips, lost/duplicated/delayed result messages, tile
//!   crashes) instead of datapath faults — the `"tiles"` / `"mesh"`
//!   JSON fields appear only on those cells, so single-tile documents
//!   stay byte-identical to pre-axis sweeps.
//!
//! The grid is the cartesian product of the axes; every *cell* is a full
//! campaign ([`Campaign::run_with_problem`]) sharing one workload per
//! shape, so columns differing only in geometry, protection, fault count
//! or tolerance are controlled comparisons on identical data. Every
//! cell's campaign is seeded from the sweep seed and the cell's grid
//! coordinates — never its worker thread — so the result (and the JSON
//! emitted by [`SweepResult::to_json`] / [`SweepResult::to_json_v2`]) is
//! byte-identical for a fixed seed regardless of `--threads`. Cell
//! campaigns run on the checkpointed fast-forward engine by default (see
//! [`CampaignConfig::fast_forward`]); results are bit-identical either
//! way.
//!
//! # Execution engine: shared traces + grid-wide work stealing
//!
//! Two layers of reuse keep the grid as fast as the hardware allows:
//!
//! * **Shared reference-trace cache** ([`super::TraceCache`], default
//!   on, `--no-trace-cache` to disable): cells whose fault-free runs are
//!   identical — same geometry, protection/mode, shape/workload,
//!   tolerance and checkpoint interval; they differ only in fault count,
//!   fault model or statistical knobs — record ONE instrumented
//!   reference run and adopt it via `Arc` instead of one each. On the
//!   default grid this halves the reference recordings.
//! * **Grid-wide work stealing** ([`SweepConfig::work_stealing`],
//!   default on): instead of one pool *per cell* (which leaves threads
//!   idle at every cell tail, and starves wide pools on small grids),
//!   one deterministic scheduler interleaves batch chunks from every
//!   unfinished cell over a single worker pool. Workers keep a reusable
//!   `System` arena (`copy_from_slice` adoption of each cell's pristine
//!   image — no per-chunk allocation) and hop between cells freely.
//!   Because every injection's plans are a pure function of
//!   `(seed, index)` and batch boundaries depend only on merged counts,
//!   scheduling order cannot change any count: the emitted JSON is
//!   byte-identical to the per-cell pools, which remain available for
//!   A/B (`tests/shared_trace.rs`, `benches/sweep_shared_trace.rs`).
//!
//! With [`SweepConfig::precision_target`] `> 0` every cell runs the
//! adaptive engine to its own stopping point instead of a fixed budget —
//! cheap cells stop after a batch or two, rare-outcome cells spend the
//! cap — and the `redmule-ft/sweep-v2` schema reports per-outcome
//! `{count, rate, ci_lo, ci_hi}` with `n_injections` / `stopped_early`
//! per cell (plus per-stratum estimates when stratified). Wall-clock
//! lives in the [`SweepResult::timing_json`] sidecar
//! (`redmule-ft/bench-sweep-v1`), never in the deterministic document.

use crate::cluster::{recovery_valid, RecoveryPolicy, System, TileEngine};
use crate::fault::FaultModel;
use crate::fp::{GemmFormat, GemmOp};
use crate::golden::{GemmProblem, GemmSpec, ABFT_TOL_FACTOR};
use crate::mesh::{MeshCampaign, MeshCampaignConfig, MeshCellInfo, MeshConfig, MeshFaultProfile};
use crate::redmule::{Protection, RedMuleConfig};
use crate::util::stats::OutcomeEstimate;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use super::{
    stream_seed, BatchAssign, BatchSchedule, Campaign, CampaignConfig, CampaignResult, CellCtx,
    InjectScratch, Outcome, StratifyObjective, TraceCache, TraceKey, OUTCOMES,
};

/// Domain tag of the per-shape workload streams (one problem per shape,
/// shared by every cell of that shape).
const DOMAIN_SWEEP_PROBLEM: u64 = 0x5245_444D_5357_5052; // "REDMSWPR"
/// Domain tag of the per-cell campaign seeds. The tag folds in the shape
/// and fault-count coordinates only, so cells differing in protection or
/// tolerance factor see identical fault-plan streams (the same reuse of
/// one seed across columns as `Table1`).
const DOMAIN_SWEEP_CELL: u64 = 0x5245_444D_5357_434C; // "REDMSWCL"

/// The sweep grid specification.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Array geometries (L/H/P instances), one grid axis — the outermost
    /// loop of the cell enumeration. Replicated (data-protected) cells
    /// need an even row count.
    pub geometries: Vec<RedMuleConfig>,
    /// Numeric-format axis, crossed right after geometry (empty = the
    /// default FP16 only, byte-identical to pre-axis sweeps). FP8 ×
    /// online-ABFT combinations are rejected up front — the dual-plane
    /// residuals are exact only on the FP16 path.
    pub formats: Vec<GemmFormat>,
    /// GEMM-op axis, crossed after format (empty = the default `mul`
    /// only). Non-linear ops × ABFT-checksum builds are rejected up
    /// front — only `mul` preserves the row/column-sum identity.
    pub ops: Vec<GemmOp>,
    pub protections: Vec<Protection>,
    pub shapes: Vec<GemmSpec>,
    /// Faults per run, each entry one grid column (all ≥ 1).
    pub fault_counts: Vec<usize>,
    pub fault_model: FaultModel,
    /// ABFT tolerance factors. Applied to ABFT cells only; builds without
    /// checksum hardware ignore the axis (one cell at the default
    /// factor). Empty = default factor for ABFT cells too.
    pub tol_factors: Vec<f64>,
    /// Injections per cell.
    pub injections: u64,
    pub seed: u64,
    /// Worker threads of the sweep's pool (does not affect results).
    pub threads: usize,
    /// Run cell campaigns on the checkpointed fast-forward engine
    /// (bit-identical results; see [`CampaignConfig::fast_forward`]).
    pub fast_forward: bool,
    /// Checkpoint spacing for the fast-forward engine (0 = auto).
    pub checkpoint_interval: u64,
    /// Per-cell adaptive precision target (`0` = every cell runs the
    /// fixed `injections` budget). With a target, `injections` becomes
    /// the per-cell cap and each cell stops as soon as its outcome CIs
    /// are tight enough — cheap cells stop early, rare-outcome cells run
    /// long (see [`CampaignConfig::precision_target`]).
    pub precision_target: f64,
    /// Per-cell adaptive floor (see [`CampaignConfig::min_injections`]).
    pub min_injections: u64,
    /// Per-cell adaptive cap override (`0` = `injections`).
    pub max_injections: u64,
    /// Per-cell batch size (`0` = auto).
    pub batch_size: u64,
    /// Stratified allocation inside every cell campaign.
    pub stratify: bool,
    /// Outcome class the stratified Neyman reallocation scores on
    /// (see [`StratifyObjective`]; the default reproduces the historical
    /// functional-error allocation bit for bit).
    pub stratify_on: StratifyObjective,
    /// Recovery-policy axis: `None` keeps every cell on its build's
    /// Table-1 default policy (byte-identical to pre-axis sweeps);
    /// `Some(policies)` crosses the grid with each listed policy as the
    /// innermost axis. Protection × recovery pairs the hardware cannot
    /// honour ([`recovery_valid`]) are rejected up front as a
    /// configuration error rather than silently skipped.
    pub recoveries: Option<Vec<RecoveryPolicy>>,
    /// Run cell campaigns on the two-level executor (functional fast
    /// path + cycle-accurate fault windows with mid-segment convergence
    /// probes; requires [`SweepConfig::fast_forward`]). Byte-identical
    /// JSON across the whole engine matrix — `tests/shared_trace.rs`
    /// pins it.
    pub two_level: bool,
    /// Coalesce adjacent per-injection fault windows on the two-level
    /// executor (see [`CampaignConfig::tl_coalesce`]; default on,
    /// ignored unless [`SweepConfig::two_level`]; results byte-identical
    /// either way — the CLI escape hatch is `--no-coalesce`).
    pub tl_coalesce: bool,
    /// Share one recorded reference trace (and staged image) across all
    /// cells with the same clean-run identity (default on; results are
    /// byte-identical either way — the CLI escape hatch is
    /// `--no-trace-cache`).
    pub trace_cache: bool,
    /// One grid-wide deterministic work-stealing pool interleaving batch
    /// chunks from all unfinished cells (default on; `false` = legacy
    /// per-cell pools, kept for A/B comparison — results are
    /// byte-identical either way).
    pub work_stealing: bool,
    /// Confidence level of every reported interval and of the adaptive
    /// stop rule (see [`CampaignConfig::confidence`]; default 0.95).
    pub confidence: f64,
    /// Mesh tile-count axis, crossed innermost (after recovery). Empty
    /// or `[1]` = single-tile only — byte-identical grid enumeration
    /// and JSON to pre-axis sweeps (the `"tiles"` / `"mesh"` fields are
    /// emitted only for multi-tile cells). Cells with `tiles > 1` run
    /// the [`crate::mesh`] campaign: the shape's workload is sharded
    /// across that many tiles and the faults strike the *interconnect*
    /// ([`MeshFaultProfile`]), not the datapath — `fault_model` and the
    /// statistical knobs (`stratify`, `precision_target`) do not apply
    /// and crossing them with a multi-tile axis is a configuration
    /// error.
    pub tiles: Vec<usize>,
    /// NoC fault profile of mesh cells (`tiles > 1`); single-tile cells
    /// ignore it. Default [`MeshFaultProfile::Chaos`].
    pub mesh_profile: MeshFaultProfile,
}

impl SweepConfig {
    /// The default smoke grid: the paper instance × its three builds ×
    /// two shapes × fault count ∈ {1, 2} — 12 cells.
    pub fn new(injections: u64, seed: u64) -> Self {
        Self {
            geometries: vec![RedMuleConfig::paper()],
            formats: vec![GemmFormat::Fp16],
            ops: vec![GemmOp::Mul],
            protections: vec![Protection::Baseline, Protection::Data, Protection::Full],
            shapes: vec![GemmSpec::paper_workload(), GemmSpec::new(6, 8, 8)],
            fault_counts: vec![1, 2],
            fault_model: FaultModel::Independent,
            tol_factors: vec![ABFT_TOL_FACTOR],
            injections,
            seed,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            fast_forward: true,
            checkpoint_interval: 0,
            precision_target: 0.0,
            min_injections: 0,
            max_injections: 0,
            batch_size: 0,
            stratify: false,
            stratify_on: StratifyObjective::FunctionalError,
            recoveries: None,
            two_level: false,
            tl_coalesce: true,
            trace_cache: true,
            work_stealing: true,
            confidence: 0.95,
            tiles: vec![1],
            mesh_profile: MeshFaultProfile::Chaos,
        }
    }

    /// Number of grid cells this configuration expands to.
    pub fn n_cells(&self) -> usize {
        let tols = self.tol_factors.len().max(1);
        let recoveries = self.recoveries.as_ref().map_or(1, |r| r.len().max(1));
        let per_geometry: usize = self
            .protections
            .iter()
            .map(|p| {
                let t = if p.has_abft_checksums() { tols } else { 1 };
                self.shapes.len() * self.fault_counts.len() * t
            })
            .sum();
        self.geometries.len().max(1)
            * self.formats.len().max(1)
            * self.ops.len().max(1)
            * per_geometry
            * recoveries
            * self.tiles.len().max(1)
    }
}

/// One cell of the grid with its campaign outcome.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub geometry: RedMuleConfig,
    pub format: GemmFormat,
    pub op: GemmOp,
    pub protection: Protection,
    pub shape: GemmSpec,
    pub faults: usize,
    pub tol_factor: f64,
    /// Mesh tile count of the cell (1 = the single-`System` path).
    pub tiles: usize,
    /// Mesh attribution of a multi-tile cell — shard map, retirement
    /// and NoC applied/detected/corrected totals. `None` on single-tile
    /// cells; carried here (not in [`CampaignResult::strata`]) so the
    /// campaign-level stratified estimators never see mesh counts.
    pub mesh: Option<MeshCellInfo>,
    pub result: CampaignResult,
}

/// A completed sweep.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub fault_model: FaultModel,
    pub injections: u64,
    pub seed: u64,
    /// The per-cell precision target the sweep ran with (0 = fixed
    /// budget).
    pub precision_target: f64,
    /// Whether cells ran with stratified allocation.
    pub stratified: bool,
    /// Confidence level of the reported intervals.
    pub confidence: f64,
    /// Cells in deterministic grid order (geometry-major, then numeric
    /// format, GEMM op, protection, shape, fault count, tolerance
    /// factor, then — when the axes are crossed — recovery policy and
    /// mesh tile count innermost).
    pub cells: Vec<SweepCell>,
    /// Which execution engine produced the counts: `"direct"`,
    /// `"fast-forward"` or `"two-level"`. Reported in the timing sidecar
    /// only — the deterministic documents are engine-invariant by
    /// contract, so stamping the engine there would break the byte
    /// comparison that proves it.
    pub engine: &'static str,
    pub wall_seconds: f64,
    /// Reference traces recorded / adopted from the shared cache
    /// (`None` when the sweep ran with the cache disabled). Reported in
    /// the timing sidecar only — never in the deterministic documents.
    pub trace_cache_stats: Option<(u64, u64)>,
    /// Clean-run entries still resident in the cache when the sweep
    /// finished (`None` without the cache). Every cell pins its identity
    /// up front and releases it on completion, so this must be 0 — the
    /// cache no longer holds every identity's `CleanRun` for the whole
    /// sweep.
    pub trace_cache_resident: Option<usize>,
}

impl SweepResult {
    pub fn total_runs(&self) -> u64 {
        self.cells.iter().map(|c| c.result.total).sum()
    }

    pub fn runs_per_sec(&self) -> f64 {
        self.total_runs() as f64 / self.wall_seconds.max(1e-9)
    }

    /// Machine-readable JSON (schema `redmule-ft/sweep-v1`), suitable for
    /// `BENCH_*.json` trajectory tracking. Deterministic for a fixed seed
    /// and grid: wall-clock fields are emitted only when `timing` is set,
    /// so the default output is byte-identical across thread counts.
    pub fn to_json(&self, timing: bool) -> String {
        let mut s = String::with_capacity(256 + 512 * self.cells.len());
        s.push_str("{\n");
        s.push_str("  \"schema\": \"redmule-ft/sweep-v1\",\n");
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"injections_per_cell\": {},\n", self.injections));
        s.push_str(&format!("  \"fault_model\": \"{}\",\n", self.fault_model.name()));
        s.push_str(&format!("  \"total_runs\": {},\n", self.total_runs()));
        if timing {
            s.push_str(&format!("  \"wall_seconds\": {:.3},\n", self.wall_seconds));
            s.push_str(&format!("  \"runs_per_sec\": {:.1},\n", self.runs_per_sec()));
        }
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            let r = &c.result;
            let total = r.total.max(1) as f64;
            s.push_str("    {");
            s.push_str(&format!(
                "\"geometry\": {{\"l\": {}, \"h\": {}, \"p\": {}}}, ",
                c.geometry.l, c.geometry.h, c.geometry.p
            ));
            Self::format_op_fields(&mut s, c);
            s.push_str(&format!("\"protection\": \"{}\", ", c.protection.name()));
            s.push_str(&format!("\"mode\": \"{}\", ", r.config.mode.name()));
            s.push_str(&format!(
                "\"shape\": {{\"m\": {}, \"n\": {}, \"k\": {}}}, ",
                c.shape.m, c.shape.n, c.shape.k
            ));
            s.push_str(&format!("\"faults\": {}, ", c.faults));
            s.push_str(&format!("\"tol_factor\": {:?}, ", c.tol_factor));
            s.push_str(&format!("\"total\": {}, ", r.total));
            s.push_str(&format!(
                "\"outcomes\": {{\"correct_no_retry\": {}, \"correct_with_retry\": {}, \
                 \"incorrect\": {}, \"timeout\": {}}}, ",
                r.correct_no_retry, r.correct_with_retry, r.incorrect, r.timeout
            ));
            s.push_str(&format!(
                "\"applied\": {}, \"faults_applied\": {}, ",
                r.applied, r.faults_applied
            ));
            s.push_str(&format!(
                "\"rates\": {{\"correct\": {:.6}, \"functional_error\": {:.6}}}",
                r.correct() as f64 / total,
                r.functional_errors() as f64 / total
            ));
            if timing {
                s.push_str(&format!(
                    ", \"wall_seconds\": {:.3}, \"runs_per_sec\": {:.1}",
                    r.wall_seconds,
                    r.runs_per_sec()
                ));
            }
            s.push_str(if i + 1 < self.cells.len() { "},\n" } else { "}\n" });
        }
        s.push_str("  ]\n}");
        s
    }

    /// Format/op/tiles coordinate fields, emitted only when the cell
    /// deviates from the `fp16`/`mul`/single-tile defaults: default-path
    /// documents must stay byte-identical to pre-axis sweeps (the A/B
    /// contract every engine and schema test pins).
    fn format_op_fields(s: &mut String, c: &SweepCell) {
        if c.format != GemmFormat::Fp16 {
            s.push_str(&format!("\"format\": \"{}\", ", c.format.name()));
        }
        if c.op != GemmOp::Mul {
            s.push_str(&format!("\"op\": \"{}\", ", c.op.name()));
        }
        if c.tiles != 1 {
            s.push_str(&format!("\"tiles\": {}, ", c.tiles));
        }
    }

    /// Shared cell-coordinate prefix of the v2 and timing documents.
    fn cell_coords(s: &mut String, c: &SweepCell) {
        s.push_str(&format!(
            "\"geometry\": {{\"l\": {}, \"h\": {}, \"p\": {}}}, ",
            c.geometry.l, c.geometry.h, c.geometry.p
        ));
        Self::format_op_fields(s, c);
        s.push_str(&format!("\"protection\": \"{}\", ", c.protection.name()));
        s.push_str(&format!(
            "\"shape\": {{\"m\": {}, \"n\": {}, \"k\": {}}}, ",
            c.shape.m, c.shape.n, c.shape.k
        ));
        s.push_str(&format!("\"faults\": {}, ", c.faults));
        s.push_str(&format!("\"tol_factor\": {:?}, ", c.tol_factor));
    }

    /// JSON key of one Table-1 outcome class.
    fn outcome_key(o: Outcome) -> &'static str {
        match o {
            Outcome::CorrectNoRetry => "correct_no_retry",
            Outcome::CorrectWithRetry => "correct_with_retry",
            Outcome::Incorrect => "incorrect",
            Outcome::Timeout => "timeout",
        }
    }

    /// One v2 outcome object: `{"count", "rate", "ci_lo", "ci_hi"}`
    /// (plus the one-sided exact `"upper95"` when requested — named for
    /// the default confidence; it is the bound at the configured level).
    fn v2_outcome(s: &mut String, key: &str, e: &OutcomeEstimate, upper: bool) {
        s.push_str(&format!(
            "\"{}\": {{\"count\": {}, \"rate\": {:.8}, \"ci_lo\": {:.8}, \"ci_hi\": {:.8}",
            key, e.count, e.rate, e.ci_lo, e.ci_hi
        ));
        if upper {
            s.push_str(&format!(", \"upper95\": {:.8}", e.upper95()));
        }
        s.push('}');
    }

    /// The per-stratum estimate block of one stratified cell: every
    /// stratum's allocation (`n`), sampling share and per-outcome
    /// pooled-within-stratum estimates, plus its combined
    /// functional-error object — the ROADMAP follow-up to the
    /// campaign-level-only v2 of PR 4. Within a stratum the sample is a
    /// plain binomial, so pooled Wilson/Clopper–Pearson at the cell's
    /// confidence level applies.
    fn v2_strata(s: &mut String, r: &CampaignResult) {
        let conf = r.config.confidence;
        s.push_str(", \"strata\": [");
        for (i, st) in r.strata.iter().enumerate() {
            s.push_str(&format!(
                "{{\"name\": \"{}\", \"share\": {:.8}, \"n\": {}, ",
                st.name, st.share, st.n
            ));
            s.push_str("\"outcomes\": {");
            for (j, &o) in OUTCOMES.iter().enumerate() {
                let e = OutcomeEstimate::pooled_at(st.outcomes[o.index()], st.n, conf);
                Self::v2_outcome(s, Self::outcome_key(o), &e, false);
                if j + 1 < OUTCOMES.len() {
                    s.push_str(", ");
                }
            }
            s.push_str("}, ");
            let fe_count = st.outcomes[Outcome::Incorrect.index()]
                + st.outcomes[Outcome::Timeout.index()];
            let fe = OutcomeEstimate::pooled_at(fe_count, st.n, conf);
            Self::v2_outcome(s, "functional_error", &fe, true);
            s.push_str(if i + 1 < r.strata.len() { "}, " } else { "}" });
        }
        s.push(']');
    }

    /// Machine-readable JSON, schema `redmule-ft/sweep-v2`: every outcome
    /// of every cell carries its rate with a confidence interval at the
    /// sweep's configured level (Wilson on pooled counts; the stratified
    /// normal interval when the sweep ran stratified), each cell reports
    /// the injections it actually ran (`n_injections`) and whether the
    /// precision target stopped it early, the combined
    /// `functional_error` object adds the one-sided exact upper bound —
    /// so a zero-error cell reads as "< upper at the configured
    /// confidence" instead of a bare 0 — and stratified cells carry the
    /// full per-stratum estimate table. Deterministic for a fixed seed
    /// and grid: timing lives in the separate
    /// [`SweepResult::timing_json`] sidecar, never here.
    pub fn to_json_v2(&self) -> String {
        let mut s = String::with_capacity(512 + 1024 * self.cells.len());
        s.push_str("{\n");
        s.push_str("  \"schema\": \"redmule-ft/sweep-v2\",\n");
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"injections_per_cell\": {},\n", self.injections));
        s.push_str(&format!("  \"precision_target\": {:?},\n", self.precision_target));
        s.push_str(&format!("  \"stratified\": {},\n", self.stratified));
        s.push_str(&format!("  \"confidence\": {:?},\n", self.confidence));
        s.push_str(&format!("  \"fault_model\": \"{}\",\n", self.fault_model.name()));
        s.push_str(&format!("  \"total_runs\": {},\n", self.total_runs()));
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            let r = &c.result;
            s.push_str("    {");
            Self::cell_coords(&mut s, c);
            s.push_str(&format!("\"mode\": \"{}\", ", r.config.mode.name()));
            s.push_str(&format!("\"n_injections\": {}, ", r.total));
            s.push_str(&format!("\"stopped_early\": {}, ", r.stopped_early));
            s.push_str(&format!("\"batches\": {}, ", r.batches));
            s.push_str(&format!(
                "\"applied\": {}, \"faults_applied\": {}, ",
                r.applied, r.faults_applied
            ));
            s.push_str(&format!(
                "\"recovery\": \"{}\", ",
                r.config.recovery.name()
            ));
            s.push_str(&format!(
                "\"corrections\": {}, \"band_recomputes\": {}, ",
                r.corrections, r.band_recomputes
            ));
            // Mesh attribution, multi-tile cells only: the default
            // (single-tile) document stays byte-identical to pre-axis
            // sweeps.
            if let Some(m) = &c.mesh {
                s.push_str(&format!(
                    "\"mesh\": {{\"tiles\": {}, \"shards\": {}, \"retired_tiles\": {}, \
                     \"reassigned_shards\": {}, \"noc_applied\": {}, \"noc_detected\": {}, \
                     \"noc_corrected\": {}}}, ",
                    m.tiles,
                    m.shards,
                    m.retired_tiles,
                    m.reassigned_shards,
                    m.noc_applied,
                    m.noc_detected,
                    m.noc_corrected
                ));
            }
            s.push_str("\"outcomes\": {");
            for (j, &o) in OUTCOMES.iter().enumerate() {
                Self::v2_outcome(&mut s, Self::outcome_key(o), &r.estimate_of(o), false);
                if j + 1 < OUTCOMES.len() {
                    s.push_str(", ");
                }
            }
            s.push_str("}, ");
            Self::v2_outcome(
                &mut s,
                "functional_error",
                &r.functional_error_estimate(),
                true,
            );
            if !r.strata.is_empty() {
                Self::v2_strata(&mut s, r);
            }
            s.push_str(if i + 1 < self.cells.len() { "},\n" } else { "}\n" });
        }
        s.push_str("  ]\n}");
        s
    }

    /// Wall-clock sidecar, schema `redmule-ft/bench-sweep-v1`: per-cell
    /// wall seconds and injections/sec plus sweep totals (and the
    /// trace-cache hit/miss counters when the cache ran). Under the
    /// grid-stealing scheduler a cell's `wall_seconds` is the pool's
    /// accumulated *busy* time injecting for that cell (its chunks), so
    /// the number stays comparable across cells and engines instead of
    /// absorbing interleaved work on other cells or a blocked wait on
    /// another cell's in-flight trace recording. Kept
    /// as a **separate document** so the deterministic v2 JSON stays
    /// byte-identical across thread counts and machines — the
    /// byte-compared path never carries timing (pre-PR-4, `--timing`
    /// spliced wall-clock fields into the main document and every
    /// determinism check had to strip them ad hoc).
    pub fn timing_json(&self) -> String {
        let mut s = String::with_capacity(256 + 256 * self.cells.len());
        s.push_str("{\n");
        s.push_str("  \"schema\": \"redmule-ft/bench-sweep-v1\",\n");
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"fault_model\": \"{}\",\n", self.fault_model.name()));
        s.push_str(&format!("  \"engine\": \"{}\",\n", self.engine));
        s.push_str(&format!("  \"total_runs\": {},\n", self.total_runs()));
        s.push_str(&format!("  \"wall_seconds\": {:.3},\n", self.wall_seconds));
        s.push_str(&format!("  \"runs_per_sec\": {:.1},\n", self.runs_per_sec()));
        if let Some((hits, misses)) = self.trace_cache_stats {
            s.push_str(&format!(
                "  \"trace_cache\": {{\"hits\": {hits}, \"misses\": {misses}}},\n"
            ));
        }
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            let r = &c.result;
            s.push_str("    {");
            Self::cell_coords(&mut s, c);
            s.push_str(&format!("\"n_injections\": {}, ", r.total));
            s.push_str(&format!("\"wall_seconds\": {:.3}, ", r.wall_seconds));
            s.push_str(&format!("\"injections_per_sec\": {:.1}", r.runs_per_sec()));
            s.push_str(if i + 1 < self.cells.len() { "},\n" } else { "}\n" });
        }
        s.push_str("  ]\n}");
        s
    }
}

/// Grid coordinates of one cell before it runs.
#[derive(Debug, Clone, Copy)]
struct CellSpec {
    geometry: RedMuleConfig,
    format: GemmFormat,
    op: GemmOp,
    protection: Protection,
    shape_idx: usize,
    shape: GemmSpec,
    faults: usize,
    tol_factor: f64,
    /// Recovery-policy override; `None` keeps the build's Table-1
    /// default so a sweep without the axis stays byte-identical.
    recovery: Option<RecoveryPolicy>,
    /// Mesh tile count; 1 = the exact single-`System` campaign path.
    tiles: usize,
}

/// The sweep driver.
pub struct Sweep;

impl Sweep {
    /// Run the full grid. Deterministic for a fixed seed: cell enumeration
    /// order, per-shape problems and per-cell campaign seeds depend only
    /// on the configuration, never on worker-thread scheduling (and the
    /// scheduler / trace-cache toggles cannot change a single count —
    /// only wall-clock).
    pub fn run(config: &SweepConfig) -> Result<SweepResult> {
        if config.geometries.is_empty()
            || config.protections.is_empty()
            || config.shapes.is_empty()
            || config.fault_counts.is_empty()
        {
            return Err(Error::Config(
                "sweep needs at least one geometry, protection, shape and fault count".into(),
            ));
        }
        // FT (replicated) execution pairs consecutive rows, so a
        // data-protected cell on an odd-row geometry would assert deep in
        // the accelerator — reject it as a configuration error up front.
        if let Some(g) = config.geometries.iter().find(|g| g.l % 2 != 0) {
            if config.protections.iter().any(|p| p.has_data_protection()) {
                return Err(Error::Config(format!(
                    "geometry L={} H={} P={} has an odd row count: replicated \
                     (data/full) cells need an even L",
                    g.l, g.h, g.p
                )));
            }
        }
        // Validate every axis up front: a bad cell must fail before any
        // cell burns injection time, not mid-sweep.
        if config.fault_counts.iter().any(|&n| n == 0) {
            return Err(Error::Config("sweep fault counts must be >= 1".into()));
        }
        if let Some(&n) = config
            .fault_counts
            .iter()
            .find(|&&n| n > crate::fault::MAX_PLANS_PER_RUN)
        {
            return Err(Error::Config(format!(
                "sweep fault count {n} exceeds the per-run maximum of {}",
                crate::fault::MAX_PLANS_PER_RUN
            )));
        }
        if let Some(&f) = config
            .tol_factors
            .iter()
            .find(|f| !f.is_finite() || **f < 0.0)
        {
            return Err(Error::Config(format!(
                "sweep tolerance factors must be finite and >= 0 (got {f})"
            )));
        }
        if !config.precision_target.is_finite() || config.precision_target < 0.0 {
            return Err(Error::Config(
                "sweep precision target must be finite and >= 0".into(),
            ));
        }
        if !config.confidence.is_finite()
            || config.confidence <= 0.0
            || config.confidence >= 1.0
        {
            return Err(Error::Config(format!(
                "sweep confidence must be in (0, 1), got {}",
                config.confidence
            )));
        }
        if config.two_level && !config.fast_forward {
            return Err(Error::Config(
                "the two-level engine is the fast-forward engine's functional level — \
                 it cannot run on the direct engine (drop --direct or --no-two-level)"
                    .into(),
            ));
        }
        // Format/op axes are crossed against *every* protection, so a
        // combination the hardware cannot honour is a configuration
        // error up front, not a cell to skip silently — same contract as
        // the recovery axis below.
        for &op in &config.ops {
            if !op.is_linear() {
                if let Some(p) = config
                    .protections
                    .iter()
                    .find(|p| p.has_abft_checksums())
                {
                    return Err(Error::Config(format!(
                        "op '{}' breaks the ABFT checksum identity (only the linear \
                         'mul' reduction preserves row/column sums) — drop it or the \
                         {} protection from the grid",
                        op.name(),
                        p.name()
                    )));
                }
            }
        }
        for &format in &config.formats {
            if format.is_fp8() {
                if let Some(p) = config.protections.iter().find(|p| p.has_online_abft()) {
                    return Err(Error::Config(format!(
                        "format '{}' cannot run online ABFT (the dual-plane residuals \
                         are exact only on the FP16 path) — drop it or the {} \
                         protection from the grid",
                        format.name(),
                        p.name()
                    )));
                }
            }
        }
        // The mesh tile axis: multi-tile cells run the NoC-fault mesh
        // campaign, which has its own fault domain and no stratified /
        // adaptive machinery — crossing those knobs with it would
        // silently mean something different per cell, so reject up
        // front like every other invalid axis pairing.
        if config.tiles.iter().any(|&t| t == 0) {
            return Err(Error::Config("sweep tile counts must be >= 1".into()));
        }
        if config.tiles.iter().any(|&t| t > 1) {
            if config.stratify {
                return Err(Error::Config(
                    "mesh cells (tiles > 1) have their own NoC fault domain and do not \
                     run stratified allocation — drop --stratify or the multi-tile axis"
                        .into(),
                ));
            }
            if config.precision_target > 0.0 {
                return Err(Error::Config(
                    "mesh cells (tiles > 1) run a fixed injection budget — drop the \
                     precision target or the multi-tile axis"
                        .into(),
                ));
            }
            if config.recoveries.is_some() {
                return Err(Error::Config(
                    "mesh cells (tiles > 1) take their recovery options from the mesh \
                     build (link CRC / reduction ABFT / tile retirement), not the \
                     single-tile recovery axis — drop one of the two axes"
                        .into(),
                ));
            }
        }
        // The recovery axis is crossed against *every* protection, so a
        // pair the hardware cannot honour (e.g. in-place correction
        // without online ABFT) is a configuration error, not a cell to
        // skip silently.
        if let Some(recoveries) = &config.recoveries {
            if recoveries.is_empty() {
                return Err(Error::Config(
                    "sweep recovery axis must list at least one policy".into(),
                ));
            }
            for &protection in &config.protections {
                for &recovery in recoveries {
                    if !recovery_valid(protection, recovery) {
                        return Err(Error::Config(format!(
                            "recovery policy '{}' is invalid on {} builds",
                            recovery.name(),
                            protection.name()
                        )));
                    }
                }
            }
        }
        let started = std::time::Instant::now();

        let default_tols = [ABFT_TOL_FACTOR];
        let recovery_axis: Vec<Option<RecoveryPolicy>> = match &config.recoveries {
            Some(rs) => rs.iter().map(|&r| Some(r)).collect(),
            None => vec![None],
        };
        // Empty format/op axes mean "default only" — byte-identical grid
        // enumeration to pre-axis sweeps.
        let default_formats = [GemmFormat::Fp16];
        let default_ops = [GemmOp::Mul];
        let format_axis: &[GemmFormat] = if config.formats.is_empty() {
            &default_formats
        } else {
            &config.formats
        };
        let op_axis: &[GemmOp] = if config.ops.is_empty() {
            &default_ops
        } else {
            &config.ops
        };
        let default_tiles = [1usize];
        let tile_axis: &[usize] = if config.tiles.is_empty() {
            &default_tiles
        } else {
            &config.tiles
        };
        let mut specs: Vec<CellSpec> = Vec::new();
        for &geometry in &config.geometries {
            for &format in format_axis {
                for &op in op_axis {
                    for &protection in &config.protections {
                        for (shape_idx, &shape) in config.shapes.iter().enumerate() {
                            for &faults in &config.fault_counts {
                                let tols: &[f64] = if protection.has_abft_checksums()
                                    && !config.tol_factors.is_empty()
                                {
                                    &config.tol_factors
                                } else {
                                    &default_tols
                                };
                                for &tol_factor in tols {
                                    for &recovery in &recovery_axis {
                                        for &tiles in tile_axis {
                                            specs.push(CellSpec {
                                                geometry,
                                                format,
                                                op,
                                                protection,
                                                shape_idx,
                                                shape,
                                                faults,
                                                tol_factor,
                                                recovery,
                                                tiles,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        // One workload per shape, shared by every cell of that shape.
        let problems: Vec<GemmProblem> = config
            .shapes
            .iter()
            .enumerate()
            .map(|(si, shape)| {
                GemmProblem::random(shape, stream_seed(config.seed, DOMAIN_SWEEP_PROBLEM, si as u64))
            })
            .collect();

        let cache = if config.trace_cache {
            Some(TraceCache::new())
        } else {
            None
        };
        // Pin every cell's clean-run identity before any cell runs, so a
        // completed cell's release ([`Sweep::release_trace`]) evicts the
        // shared `CleanRun` exactly when the last unfinished cell using
        // it lets go — never earlier (an unstarted cell would re-record
        // and perturb the hit/miss counters), never later (the old
        // cache held every identity until sweep end).
        // Mesh cells never record or adopt a reference trace (the NoC
        // campaign has its own tile pool), so they take no pin.
        if let Some(c) = cache.as_ref() {
            for spec in specs.iter().filter(|s| s.tiles == 1) {
                c.retain(Self::trace_key(config, spec, &problems));
            }
        }
        let cells = if config.work_stealing {
            Self::run_stealing(config, &specs, &problems, cache.as_ref())?
        } else {
            Self::run_percell(config, &specs, &problems, cache.as_ref())?
        };
        Ok(SweepResult {
            fault_model: config.fault_model,
            injections: config.injections,
            seed: config.seed,
            precision_target: config.precision_target,
            stratified: config.stratify,
            confidence: config.confidence,
            cells,
            engine: if config.two_level {
                "two-level"
            } else if config.fast_forward {
                "fast-forward"
            } else {
                "direct"
            },
            wall_seconds: started.elapsed().as_secs_f64(),
            trace_cache_resident: cache.as_ref().map(|c| c.len()),
            trace_cache_stats: cache.map(|c| (c.hits(), c.misses())),
        })
    }

    /// The clean-run identity of one cell — shared by the up-front pin
    /// and the completion release, so the two always agree.
    fn trace_key(config: &SweepConfig, spec: &CellSpec, problems: &[GemmProblem]) -> TraceKey {
        TraceKey::of(&Self::cell_config(config, spec), &problems[spec.shape_idx])
    }

    /// Release one cell's pin on its shared clean run, evicting the
    /// cache entry if this cell was its last user. Called on every cell
    /// completion path — success and failure — of both engines. Mesh
    /// cells hold no pin (see the pin loop in [`Sweep::run`]), so the
    /// release is a no-op for them.
    fn release_trace(
        config: &SweepConfig,
        spec: &CellSpec,
        problems: &[GemmProblem],
        cache: Option<&TraceCache>,
    ) {
        if spec.tiles != 1 {
            return;
        }
        if let Some(c) = cache {
            c.release(&Self::trace_key(config, spec, problems));
        }
    }

    /// The campaign configuration of one cell: seeded from the sweep
    /// seed and the cell's (shape, fault count) coordinates — geometry,
    /// protection and tolerance columns at the same coordinates share
    /// plan streams, the same controlled comparison `Table1` makes
    /// across builds. The per-build execution mode and recovery policy
    /// come from [`CampaignConfig::table1`] so sweep cells and Table-1
    /// columns are always configured identically.
    fn cell_config(config: &SweepConfig, spec: &CellSpec) -> CampaignConfig {
        let tag = ((spec.shape_idx as u64) << 32) | spec.faults as u64;
        let seed = stream_seed(config.seed, DOMAIN_SWEEP_CELL, tag);
        let mut cc = CampaignConfig::table1(spec.protection, config.injections, seed);
        cc.cfg = spec.geometry.with_format(spec.format).with_op(spec.op);
        cc.spec = spec.shape;
        cc.threads = config.threads;
        cc.faults_per_run = spec.faults;
        cc.fault_model = config.fault_model;
        cc.abft_tol_factor = spec.tol_factor;
        cc.fast_forward = config.fast_forward;
        cc.checkpoint_interval = config.checkpoint_interval;
        cc.precision_target = config.precision_target;
        cc.min_injections = config.min_injections;
        cc.max_injections = config.max_injections;
        cc.batch_size = config.batch_size;
        cc.stratify = config.stratify;
        cc.stratify_on = config.stratify_on;
        cc.two_level = config.two_level;
        cc.tl_coalesce = config.tl_coalesce;
        cc.confidence = config.confidence;
        if let Some(recovery) = spec.recovery {
            cc.recovery = recovery;
        }
        cc
    }

    /// The mesh-campaign configuration of a multi-tile cell. Seeding
    /// reuses [`Sweep::cell_config`]'s per-(shape, fault count) stream,
    /// so mesh columns at the same coordinates are controlled
    /// comparisons like every other axis. The NoC recovery options
    /// follow the protection column: a baseline build gets the
    /// unprotected transport, every protected build the full link-CRC /
    /// reduction-ABFT / retirement stack. The tile engine follows the
    /// sweep's engine toggles.
    fn mesh_cell_config(
        config: &SweepConfig,
        spec: &CellSpec,
        threads: usize,
    ) -> MeshCampaignConfig {
        let cc = Self::cell_config(config, spec);
        let mut mesh = if spec.protection == Protection::Baseline {
            MeshConfig::unprotected(spec.tiles)
        } else {
            MeshConfig::new(spec.tiles)
        };
        mesh.cfg = cc.cfg;
        mesh.protection = spec.protection;
        mesh.engine = if config.two_level {
            TileEngine::TwoLevel
        } else if config.fast_forward {
            TileEngine::FastForward
        } else {
            TileEngine::Direct
        };
        MeshCampaignConfig {
            mesh,
            spec: spec.shape,
            injections: config.injections,
            faults_per_run: spec.faults,
            profile: config.mesh_profile,
            seed: cc.seed,
            threads,
        }
    }

    /// Run one multi-tile cell as a mesh campaign — the `tiles > 1`
    /// branch of both schedulers. The mesh result folds into the same
    /// [`CampaignResult`] outcome table as a single-tile cell
    /// (NoC attribution rides in [`SweepCell::mesh`], never in the
    /// campaign strata), so downstream consumers see one uniform grid.
    fn run_mesh_cell(
        config: &SweepConfig,
        spec: &CellSpec,
        problem: &GemmProblem,
        threads: usize,
    ) -> Result<SweepCell> {
        let started = std::time::Instant::now();
        let mc = Self::mesh_cell_config(config, spec, threads);
        let mr = MeshCampaign::run_with_problem(&mc, problem)?;
        let result =
            mr.to_campaign_result(Self::cell_config(config, spec), started.elapsed().as_secs_f64());
        Ok(SweepCell {
            geometry: spec.geometry,
            format: spec.format,
            op: spec.op,
            protection: spec.protection,
            shape: spec.shape,
            faults: spec.faults,
            tol_factor: spec.tol_factor,
            tiles: spec.tiles,
            mesh: Some(mr.cell_info()),
            result,
        })
    }

    /// Legacy execution: fan whole cells out over the worker pool, one
    /// campaign (with its own inner thread split) per cell. Kept for A/B
    /// comparison against the grid-wide scheduler — byte-identical
    /// output, worse tail utilization (threads idle once fewer cells
    /// than workers remain).
    fn run_percell(
        config: &SweepConfig,
        specs: &[CellSpec],
        problems: &[GemmProblem],
        cache: Option<&TraceCache>,
    ) -> Result<Vec<SweepCell>> {
        // A shared atomic cursor hands each worker the next unclaimed
        // cell; results land in per-cell slots so completion order never
        // reorders the grid. When the pool is larger than the grid, the
        // leftover threads are split *inside* the cells' campaigns (the
        // first `threads % cells` cells get one extra — a function of
        // the cell index, never of worker scheduling). Sound because the
        // campaign itself is thread-layout invariant (its determinism
        // tests pin that), so the output stays byte-identical for any
        // `--threads`.
        let pool = config.threads.max(1);
        let threads = pool.min(specs.len());
        let inner_base = pool / specs.len();
        let inner_rem = pool % specs.len();
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<SweepCell>>>> =
            specs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let inner = (inner_base + usize::from(i < inner_rem)).max(1);
                    let cell = Self::run_cell(
                        config,
                        &specs[i],
                        &problems[specs[i].shape_idx],
                        inner,
                        cache,
                    );
                    Self::release_trace(config, &specs[i], problems, cache);
                    *slots[i].lock().unwrap() = Some(cell);
                });
            }
        });

        let mut cells = Vec::with_capacity(specs.len());
        for slot in slots {
            let cell = slot
                .into_inner()
                .expect("sweep slot poisoned")
                .expect("sweep cell never ran")?;
            cells.push(cell);
        }
        Ok(cells)
    }

    /// Run one cell as a self-contained campaign (legacy scheduler).
    fn run_cell(
        config: &SweepConfig,
        spec: &CellSpec,
        problem: &GemmProblem,
        threads: usize,
        cache: Option<&TraceCache>,
    ) -> Result<SweepCell> {
        if spec.tiles > 1 {
            return Self::run_mesh_cell(config, spec, problem, threads);
        }
        let mut cc = Self::cell_config(config, spec);
        cc.threads = threads;
        let result = Campaign::run_with_problem_cached(&cc, problem, cache)?;
        Ok(SweepCell {
            geometry: spec.geometry,
            format: spec.format,
            op: spec.op,
            protection: spec.protection,
            shape: spec.shape,
            faults: spec.faults,
            tol_factor: spec.tol_factor,
            tiles: 1,
            mesh: None,
            result,
        })
    }

    /// Grid-wide work-stealing execution (the default): one worker pool
    /// pulls units — cell preparations and batch chunks — from a shared
    /// queue, so every thread stays busy until the *whole grid* is done
    /// rather than until its own cell is. See [`Grid`].
    fn run_stealing(
        config: &SweepConfig,
        specs: &[CellSpec],
        problems: &[GemmProblem],
        cache: Option<&TraceCache>,
    ) -> Result<Vec<SweepCell>> {
        let grid = Grid {
            config,
            specs,
            problems,
            cache,
            slots: specs
                .iter()
                .map(|_| CellSlot {
                    ctx: OnceLock::new(),
                    prog: Mutex::new(None),
                    out: Mutex::new(None),
                })
                .collect(),
            state: Mutex::new(GridState {
                queue: (0..specs.len()).map(Unit::Init).collect(),
                open_cells: specs.len(),
            }),
            cv: Condvar::new(),
        };
        let threads = config.threads.max(1);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    let mut worker = WorkerArena::new();
                    while let Some(unit) = grid.next_unit() {
                        match unit {
                            Unit::Init(cell) => grid.run_init(cell),
                            Unit::Chunk {
                                cell,
                                lo,
                                hi,
                                assign,
                            } => grid.run_chunk(&mut worker, cell, lo, hi, assign.as_deref()),
                        }
                    }
                });
            }
        });
        let mut cells = Vec::with_capacity(specs.len());
        for slot in grid.slots {
            let cell = slot
                .out
                .into_inner()
                .expect("sweep slot poisoned")
                .expect("sweep cell never ran")?;
            cells.push(cell);
        }
        Ok(cells)
    }
}

// ------------------------------------------- grid-stealing scheduler

/// A caught worker panic as a structured error: the sweep fails fast
/// with the panic's message instead of hanging the pool.
fn panic_error(what: &str, payload: Box<dyn std::any::Any + Send>) -> Error {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    Error::Sim(format!("sweep worker panicked in {what}: {msg}"))
}

/// One unit of schedulable work in the grid-wide pool.
enum Unit {
    /// Prepare cell `i`: validate, stage, record/adopt the reference
    /// trace, open its first batch.
    Init(usize),
    /// Run injections `[lo, hi)` of cell `cell`'s current batch.
    Chunk {
        cell: usize,
        lo: u64,
        hi: u64,
        /// Stratum layout of the batch (stratified cells only) — shared
        /// by every chunk of the batch.
        assign: Option<Arc<BatchAssign>>,
    },
}

/// Mutable per-cell progress, guarded by the cell slot's mutex. Only
/// merged counts and the deterministic batch schedule live here, so
/// scheduling order cannot influence anything the JSON reports.
struct CellProg {
    result: CampaignResult,
    sched: BatchSchedule,
    /// Injections fully merged (always a batch boundary).
    start: u64,
    /// End of the batch currently in flight.
    batch_end: u64,
    /// Chunks of the current batch not yet merged.
    pending: usize,
    /// First chunk error of the cell, if any.
    failed: Option<Error>,
    /// Accumulated busy time actually spent injecting for this cell
    /// (its chunks), so the timing sidecar's per-cell wall_seconds
    /// stays comparable across cells and engines — init-to-finalize
    /// wall clock would also count time the pool spent on *other*
    /// cells' chunks, and preparation time can be another key's
    /// recording this cell merely waited on.
    busy_seconds: f64,
}

struct CellSlot {
    /// Immutable shared cell context, set once by the Init unit.
    ctx: OnceLock<Arc<CellCtx>>,
    prog: Mutex<Option<CellProg>>,
    out: Mutex<Option<Result<SweepCell>>>,
}

/// State of the grid-wide scheduler: a queue of ready units plus the
/// number of cells still open. Workers block on the condvar when the
/// queue is momentarily empty (all in-flight chunks are being executed)
/// and exit once every cell is finalized.
struct GridState {
    queue: VecDeque<Unit>,
    open_cells: usize,
}

/// The shared scheduler. Lock order is always cell-slot → grid-state;
/// the state lock is never held while a slot lock is taken, so the two
/// cannot deadlock.
struct Grid<'a> {
    config: &'a SweepConfig,
    specs: &'a [CellSpec],
    problems: &'a [GemmProblem],
    cache: Option<&'a TraceCache>,
    slots: Vec<CellSlot>,
    state: Mutex<GridState>,
    cv: Condvar,
}

/// Worker-local scratch arena: one long-lived `System` (rebuilt only
/// when the worker hops to a cell with a different hardware build — the
/// TCDM and L2 allocations survive the hop) plus the injection scratch
/// buffers. This is what makes chunk execution zero-copy: adopting a
/// cell's pristine image is a `copy_from_slice` into existing buffers.
pub(crate) struct WorkerArena {
    sys: Option<(RedMuleConfig, Protection, System)>,
    scratch: InjectScratch,
}

impl WorkerArena {
    pub(crate) fn new() -> Self {
        Self {
            sys: None,
            scratch: InjectScratch::new(crate::fault::MAX_PLANS_PER_RUN),
        }
    }

    /// The worker's `System` (configured for `ctx`'s cell) plus its
    /// injection scratch — returned together so the two disjoint
    /// borrows can feed `CellCtx::run_chunk`.
    pub(crate) fn arena(&mut self, ctx: &CellCtx) -> (&mut System, &mut InjectScratch) {
        let cfg = ctx.config.cfg;
        let prot = ctx.config.protection;
        let rebuild = match &self.sys {
            Some((c, p, _)) => *c != cfg || *p != prot,
            None => true,
        };
        if rebuild {
            match self.sys.take() {
                Some((_, _, mut sys)) => {
                    sys.reconfigure(cfg, prot);
                    self.sys = Some((cfg, prot, sys));
                }
                None => self.sys = Some((cfg, prot, System::new(cfg, prot))),
            }
        }
        let (_, _, sys) = self.sys.as_mut().unwrap();
        sys.recovery = ctx.config.recovery;
        sys.abft_tol_factor = ctx.config.abft_tol_factor;
        (sys, &mut self.scratch)
    }
}

impl Grid<'_> {
    /// Enqueue units and wake the pool.
    fn push_units(&self, units: Vec<Unit>) {
        let mut st = self.state.lock().unwrap();
        st.queue.extend(units);
        drop(st);
        self.cv.notify_all();
    }

    /// Next unit to execute, or `None` once the whole grid is finalized.
    fn next_unit(&self) -> Option<Unit> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(u) = st.queue.pop_front() {
                return Some(u);
            }
            if st.open_cells == 0 {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn close_cell(&self) {
        let mut st = self.state.lock().unwrap();
        st.open_cells -= 1;
        drop(st);
        // Wake every waiter — on the last cell they must observe
        // `open_cells == 0` and exit.
        self.cv.notify_all();
    }

    /// Split the batch `[start, end)` into chunks sized for the pool.
    /// Chunking affects scheduling only — every injection's plans are a
    /// pure function of its global index.
    fn chunk_units(
        cell: usize,
        start: u64,
        end: u64,
        threads: usize,
        assign: Option<Arc<BatchAssign>>,
    ) -> Vec<Unit> {
        let chunk = (end - start).div_ceil(threads as u64).max(1);
        let mut units = Vec::new();
        let mut lo = start;
        while lo < end {
            let hi = (lo + chunk).min(end);
            units.push(Unit::Chunk {
                cell,
                lo,
                hi,
                assign: assign.clone(),
            });
            lo = hi;
        }
        units
    }

    /// Open the next batch of `cell`: the same allocation + schedule
    /// math as the single-campaign driver, split into chunks. `None`
    /// when the campaign's budget is complete.
    fn open_batch(&self, cell: usize, ctx: &CellCtx, prog: &mut CellProg) -> Option<Vec<Unit>> {
        let size = prog.sched.batch_at(prog.start);
        if size == 0 {
            return None;
        }
        let assign = if ctx.config.stratify {
            Some(Arc::new(BatchAssign::new(
                prog.start,
                &ctx.allocate(&prog.result, size),
            )))
        } else {
            None
        };
        prog.batch_end = prog.start + size;
        let units = Self::chunk_units(
            cell,
            prog.start,
            prog.batch_end,
            self.config.threads.max(1),
            assign,
        );
        prog.pending = units.len();
        Some(units)
    }

    /// Record a cell's final result, release its clean-run pin and close
    /// it.
    fn finalize(&self, cell: usize, out: Result<SweepCell>) {
        Sweep::release_trace(self.config, &self.specs[cell], self.problems, self.cache);
        *self.slots[cell].out.lock().unwrap() = Some(out);
        self.close_cell();
    }

    fn cell_of(spec: &CellSpec, mut prog: CellProg) -> SweepCell {
        prog.result.wall_seconds = prog.busy_seconds;
        SweepCell {
            geometry: spec.geometry,
            format: spec.format,
            op: spec.op,
            protection: spec.protection,
            shape: spec.shape,
            faults: spec.faults,
            tol_factor: spec.tol_factor,
            tiles: 1,
            mesh: None,
            result: prog.result,
        }
    }

    /// Execute an Init unit: prepare the cell (stage + trace via the
    /// shared cache) and enqueue its first batch. Panics inside the
    /// preparation are caught and finalize the cell as an error — an
    /// escaped panic would leave `open_cells` permanently non-zero and
    /// hang every worker in [`Grid::next_unit`] (the legacy per-cell
    /// engine re-raised worker panics at scope join; here the sweep
    /// fails fast with the panic's message instead).
    fn run_init(&self, cell: usize) {
        let spec = &self.specs[cell];
        // Multi-tile cells run the whole mesh campaign as one unit: the
        // mesh engine has its own deterministic tile pool and inner
        // thread split, so chunking it through the grid scheduler would
        // only duplicate that machinery. Panics are caught for the same
        // reason as below — an escaped one would hang the pool.
        if spec.tiles > 1 {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                Sweep::run_mesh_cell(
                    self.config,
                    spec,
                    &self.problems[spec.shape_idx],
                    self.config.threads.max(1),
                )
            }));
            let out = match caught {
                Ok(r) => r,
                Err(p) => Err(panic_error("mesh cell", p)),
            };
            self.finalize(cell, out);
            return;
        }
        let cc = Sweep::cell_config(self.config, spec);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            CellCtx::prepare(&cc, &self.problems[spec.shape_idx], self.cache)
        }));
        let prepared = match caught {
            Ok(r) => r,
            Err(p) => Err(panic_error("cell preparation", p)),
        };
        match prepared {
            Ok(ctx) => {
                let ctx = Arc::new(ctx);
                let mut prog = CellProg {
                    result: ctx.init_result(),
                    sched: ctx.schedule(),
                    start: 0,
                    batch_end: 0,
                    pending: 0,
                    failed: None,
                    // The busy clock starts *after* preparation: an
                    // adopting cell can spend its Init blocked on
                    // another worker's in-flight recording of the same
                    // trace-cache key, and that wait is not this cell's
                    // cost. Per-cell wall_seconds therefore measures
                    // injection work (chunks), comparable across cells
                    // and engines.
                    busy_seconds: 0.0,
                };
                let _ = self.slots[cell].ctx.set(Arc::clone(&ctx));
                match self.open_batch(cell, &ctx, &mut prog) {
                    Some(units) => {
                        *self.slots[cell].prog.lock().unwrap() = Some(prog);
                        self.push_units(units);
                    }
                    // Zero-budget cell: complete on the spot.
                    None => self.finalize(cell, Ok(Self::cell_of(spec, prog))),
                }
            }
            Err(e) => self.finalize(cell, Err(e)),
        }
    }

    /// Execute a Chunk unit: run the injections on the worker's arena,
    /// merge, and — as the last chunk of its batch — close the batch:
    /// advance the deterministic schedule, open the next batch or
    /// finalize the cell. Exactly the single-campaign driver's loop,
    /// interleaved across cells.
    fn run_chunk(
        &self,
        worker: &mut WorkerArena,
        cell: usize,
        lo: u64,
        hi: u64,
        assign: Option<&BatchAssign>,
    ) {
        let ctx = Arc::clone(self.slots[cell].ctx.get().expect("chunk scheduled before init"));
        let chunk_started = std::time::Instant::now();
        // Catch panics so a failing chunk still decrements `pending` and
        // closes its batch — an escaped panic would hang the whole pool
        // (see `run_init`). The worker arena is rebuilt afterwards: a
        // mid-run panic can leave its System in an arbitrary state.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (sys, scratch) = worker.arena(&ctx);
            ctx.run_chunk(sys, scratch, assign, lo, hi)
        }));
        let run = match caught {
            Ok(r) => r,
            Err(p) => {
                worker.sys = None;
                Err(panic_error("injection chunk", p))
            }
        };
        let mut prog_slot = self.slots[cell].prog.lock().unwrap();
        let prog = prog_slot.as_mut().expect("chunk after cell finalized");
        prog.busy_seconds += chunk_started.elapsed().as_secs_f64();
        match run {
            Ok((local, local_strata)) => {
                prog.result.merge_counts(&local);
                prog.result.merge_strata(&local_strata);
            }
            Err(e) => {
                if prog.failed.is_none() {
                    prog.failed = Some(e);
                }
            }
        }
        prog.pending -= 1;
        if prog.pending > 0 {
            return;
        }
        // Last chunk of the batch: take the progress out (no chunks of
        // this cell can be queued or in flight now) and close the batch.
        let mut prog = prog_slot.take().unwrap();
        drop(prog_slot);
        if let Some(e) = prog.failed.take() {
            self.finalize(cell, Err(e));
            return;
        }
        prog.start = prog.batch_end;
        prog.result.batches += 1;
        let target = ctx.config.precision_target;
        if prog.sched.continues(prog.start, &prog.result, target) {
            if let Some(units) = self.open_batch(cell, &ctx, &mut prog) {
                *self.slots[cell].prog.lock().unwrap() = Some(prog);
                self.push_units(units);
                return;
            }
            // Unreachable in practice (`continues` implies budget left),
            // kept as a defensive fall-through to finalization.
        }
        prog.result.stopped_early = prog.sched.stopped_early(prog.start, &prog.result, target);
        self.finalize(cell, Ok(Self::cell_of(&self.specs[cell], prog)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(seed: u64, threads: usize) -> SweepConfig {
        let mut c = SweepConfig::new(40, seed);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Abft];
        c.fault_counts = vec![1, 2];
        c.tol_factors = vec![1.0, ABFT_TOL_FACTOR];
        c.threads = threads;
        c
    }

    #[test]
    fn grid_expansion_counts_abft_tolerance_cells_only() {
        let c = tiny(1, 1);
        // baseline: 1 shape × 2 fault counts × 1 tol; abft: × 2 tols.
        assert_eq!(c.n_cells(), 2 + 4);
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), c.n_cells());
        for cell in &r.cells {
            assert_eq!(cell.result.total, 40);
        }
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let a = Sweep::run(&tiny(11, 1)).unwrap();
        let b = Sweep::run(&tiny(11, 4)).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
    }

    #[test]
    fn scheduler_and_cache_toggles_do_not_change_the_json() {
        // The 2×2 engine matrix {stealing, per-cell} × {cache, no cache}
        // must emit byte-identical v1 and v2 documents — the tentpole
        // invariant (the full cross-protection A/B lives in
        // tests/shared_trace.rs).
        let base = tiny(19, 3);
        let mut docs = Vec::new();
        for stealing in [true, false] {
            for cached in [true, false] {
                let mut c = base.clone();
                c.work_stealing = stealing;
                c.trace_cache = cached;
                let r = Sweep::run(&c).unwrap();
                docs.push((stealing, cached, r.to_json(false), r.to_json_v2()));
            }
        }
        for (stealing, cached, v1, v2) in &docs[1..] {
            assert_eq!(
                v1, &docs[0].2,
                "v1 diverged at stealing={stealing} cache={cached}"
            );
            assert_eq!(
                v2, &docs[0].3,
                "v2 diverged at stealing={stealing} cache={cached}"
            );
        }
    }

    #[test]
    fn trace_cache_shares_clean_runs_across_fault_counts() {
        // tiny(): baseline × {1,2} faults on one shape = one identity;
        // abft × {1.0, default tol} × {1,2} faults = two identities.
        // 6 cells → 3 recordings, 3 adoptions.
        let r = Sweep::run(&tiny(5, 2)).unwrap();
        let (hits, misses) = r.trace_cache_stats.expect("cache on by default");
        assert_eq!(misses, 3, "one recording per clean-run identity");
        assert_eq!(hits, 3, "every other cell adopts a shared trace");
        assert_eq!(hits + misses, r.cells.len() as u64);
        // Refcounted eviction: once every cell released its pin, no
        // clean run stays resident.
        assert_eq!(r.trace_cache_resident, Some(0));
        // The sidecar reports the counters; the deterministic documents
        // never do.
        assert!(r.timing_json().contains("\"trace_cache\": {\"hits\": 3, \"misses\": 3}"));
        assert!(!r.to_json_v2().contains("trace_cache"));
        assert!(!r.to_json(false).contains("trace_cache"));
        // With the cache off the stats are absent.
        let mut off = tiny(5, 2);
        off.trace_cache = false;
        let r_off = Sweep::run(&off).unwrap();
        assert!(r_off.trace_cache_stats.is_none());
        assert!(!r_off.timing_json().contains("trace_cache"));
    }

    #[test]
    fn trace_cache_evicts_every_entry_by_sweep_end() {
        // The sweep pins each cell's clean-run identity up front and
        // releases it on completion, so the cache must end empty on BOTH
        // engines — and eviction must not change a single hit/miss
        // (pinned to the keep-forever cache's 3/3 on the tiny grid).
        for stealing in [true, false] {
            let mut c = tiny(5, 2);
            c.work_stealing = stealing;
            let r = Sweep::run(&c).unwrap();
            assert_eq!(
                r.trace_cache_resident,
                Some(0),
                "stealing={stealing}: entries must be evicted as cells finish"
            );
            assert_eq!(
                r.trace_cache_stats,
                Some((3, 3)),
                "stealing={stealing}: eviction must not perturb the counters"
            );
        }
    }

    #[test]
    fn online_abft_cells_report_thread_invariant_recovery_counters() {
        // Satellite of the online-ABFT tentpole: the new per-cell
        // `corrections` / `band_recomputes` counters are part of the
        // deterministic v2 document, so they must be byte-identical
        // across thread layouts like every other count.
        let mut c = SweepConfig::new(800, 77);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Abft, Protection::AbftOnline];
        c.fault_counts = vec![1, 2];
        c.threads = 1;
        let a = Sweep::run(&c).unwrap();
        let mut c8 = c.clone();
        c8.threads = 8;
        let b = Sweep::run(&c8).unwrap();
        let j = a.to_json_v2();
        assert_eq!(j, b.to_json_v2(), "recovery counters must be thread-invariant");
        // The document names each cell's recovery policy and counters.
        assert!(j.contains("\"recovery\": \"tile-level\""));
        assert!(j.contains("\"recovery\": \"in-place-correct\""));
        assert!(j.contains("\"corrections\": "));
        assert!(j.contains("\"band_recomputes\": "));
        // The online build corrects single-element corruptions in place
        // in the single-fault cell (the tentpole's acceptance bar), and
        // the detect-only ABFT build never reports a correction.
        for cell in &a.cells {
            match cell.protection {
                Protection::AbftOnline if cell.faults == 1 => assert!(
                    cell.result.corrections > 0,
                    "single-fault online cell must correct in place"
                ),
                Protection::Abft => assert_eq!(
                    cell.result.corrections, 0,
                    "detect-only ABFT has no correction hardware"
                ),
                _ => {}
            }
        }
    }

    #[test]
    fn site_burst_multi_errors_fall_back_to_band_recompute() {
        // Multi-error regime (FT-GEMM / online-ABFT GPUs validate ABFT
        // under bursts, not just single upsets): a burst spanning
        // adjacent sites produces residual patterns the locator cannot
        // pin to one element, so the online build must fall back to the
        // row-band recompute instead of guessing a correction.
        let mut c = SweepConfig::new(300, 99);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::AbftOnline];
        c.fault_counts = vec![3];
        c.fault_model = FaultModel::SiteBurst;
        c.threads = 2;
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), 1);
        assert!(
            r.cells[0].result.band_recomputes > 0,
            "uncorrectable burst residuals must drive band recomputes"
        );
    }

    #[test]
    fn cells_share_problem_and_plan_streams_across_protections() {
        // Two protections over one shape and fault count: the grid must
        // give both columns the same campaign seed (controlled
        // comparison), differing only in the build under test.
        let mut c = SweepConfig::new(30, 5);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Full];
        c.fault_counts = vec![2];
        c.threads = 2;
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), 2);
        assert_eq!(
            r.cells[0].result.config.seed, r.cells[1].result.config.seed,
            "same (shape, faults) cell coordinates must share the stream"
        );
        // The protected build must not do worse than the unprotected one.
        assert!(
            r.cells[1].result.functional_errors() <= r.cells[0].result.functional_errors()
        );
    }

    #[test]
    fn geometry_axis_multiplies_the_grid_and_lands_in_cells_and_json() {
        let mut c = SweepConfig::new(25, 13);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Full];
        c.fault_counts = vec![1];
        c.geometries = vec![RedMuleConfig::paper(), RedMuleConfig::new(8, 2, 2)];
        c.threads = 2;
        assert_eq!(c.n_cells(), 4);
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), 4);
        // Geometry-major order: the first two cells run the paper array.
        assert_eq!(r.cells[0].geometry, RedMuleConfig::paper());
        assert_eq!(r.cells[1].geometry, RedMuleConfig::paper());
        assert_eq!(r.cells[2].geometry, RedMuleConfig::new(8, 2, 2));
        assert_eq!(r.cells[3].geometry, RedMuleConfig::new(8, 2, 2));
        let j = r.to_json(false);
        assert!(j.contains("\"geometry\": {\"l\": 12, \"h\": 4, \"p\": 3}"));
        assert!(j.contains("\"geometry\": {\"l\": 8, \"h\": 2, \"p\": 2}"));
        // Same-coordinate cells share the campaign seed across geometries
        // (controlled comparison).
        assert_eq!(r.cells[0].result.config.seed, r.cells[2].result.config.seed);
        // Protection still beats baseline on every geometry.
        for g in 0..2 {
            assert!(
                r.cells[2 * g + 1].result.functional_errors()
                    <= r.cells[2 * g].result.functional_errors(),
                "geometry {g}"
            );
        }
    }

    #[test]
    fn format_and_op_axes_multiply_the_grid_and_tag_only_non_default_cells() {
        use crate::fp::Fp8Format;
        let mut c = SweepConfig::new(25, 21);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Full];
        c.fault_counts = vec![1];
        c.formats = vec![GemmFormat::Fp16, GemmFormat::Fp8(Fp8Format::E4M3)];
        c.ops = vec![GemmOp::Mul, GemmOp::AddMax];
        c.threads = 2;
        assert_eq!(c.n_cells(), 8);
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), 8);
        // Axis order: format outside op outside protection.
        assert_eq!(r.cells[0].format, GemmFormat::Fp16);
        assert_eq!(r.cells[0].op, GemmOp::Mul);
        assert_eq!(r.cells[2].op, GemmOp::AddMax);
        assert_eq!(r.cells[4].format, GemmFormat::Fp8(Fp8Format::E4M3));
        // Same-coordinate cells share the campaign seed across the new
        // axes (controlled comparison, like geometry/protection).
        assert_eq!(r.cells[0].result.config.seed, r.cells[4].result.config.seed);
        // JSON tags only the non-default cells, in both schemas.
        for j in [r.to_json(false), r.to_json_v2()] {
            assert_eq!(j.matches("\"format\": \"fp8-e4m3\"").count(), 4);
            assert_eq!(j.matches("\"op\": \"addmax\"").count(), 4);
            assert!(!j.contains("\"format\": \"fp16\""));
            assert!(!j.contains("\"op\": \"mul\""));
        }
        // Every cell ran its full budget (the FP8/op paths complete).
        for cell in &r.cells {
            assert_eq!(cell.result.total, 25);
        }
    }

    #[test]
    fn default_format_and_op_axes_are_byte_identical_to_unset_axes() {
        // Explicitly listing the defaults must reproduce the axis-free
        // documents byte for byte — the tentpole's A/B contract.
        let base = tiny(29, 2);
        let mut explicit = base.clone();
        explicit.formats = vec![GemmFormat::Fp16];
        explicit.ops = vec![GemmOp::Mul];
        let a = Sweep::run(&base).unwrap();
        let b = Sweep::run(&explicit).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
        assert_eq!(a.to_json_v2(), b.to_json_v2());
        // And an *empty* axis means "default only", not zero cells.
        let mut empty = base.clone();
        empty.formats = vec![];
        empty.ops = vec![];
        assert_eq!(empty.n_cells(), base.n_cells());
        assert_eq!(Sweep::run(&empty).unwrap().to_json_v2(), a.to_json_v2());
    }

    #[test]
    fn fp8_and_op_sweeps_are_thread_invariant_across_engines() {
        use crate::fp::Fp8Format;
        let mut c = SweepConfig::new(60, 37);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        // No ABFT build here: a non-linear op × checksums is rejected.
        c.protections = vec![Protection::Full];
        c.fault_counts = vec![1];
        c.formats = vec![GemmFormat::Fp8(Fp8Format::E5M2)];
        c.ops = vec![GemmOp::MulMin];
        c.threads = 1;
        let a = Sweep::run(&c).unwrap();
        let mut c8 = c.clone();
        c8.threads = 8;
        assert_eq!(a.to_json_v2(), Sweep::run(&c8).unwrap().to_json_v2());
        let mut direct = c.clone();
        direct.fast_forward = false;
        assert_eq!(a.to_json(false), Sweep::run(&direct).unwrap().to_json(false));
    }

    #[test]
    fn rejected_format_and_op_combinations_fail_before_any_cell_runs() {
        use crate::fp::Fp8Format;
        // Non-linear op × ABFT checksums.
        let mut c = SweepConfig::new(10, 1);
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.fault_counts = vec![1];
        c.protections = vec![Protection::Abft];
        c.ops = vec![GemmOp::AddMax];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // FP8 × online ABFT.
        let mut c = SweepConfig::new(10, 1);
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.fault_counts = vec![1];
        c.protections = vec![Protection::AbftOnline];
        c.formats = vec![GemmFormat::Fp8(Fp8Format::E4M3)];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // FP8 × plain (offline) ABFT is allowed — the format-aware
        // tolerance absorbs the quantization noise.
        let mut c = SweepConfig::new(10, 1);
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.fault_counts = vec![1];
        c.protections = vec![Protection::Abft];
        c.formats = vec![GemmFormat::Fp8(Fp8Format::E4M3)];
        c.threads = 1;
        assert!(Sweep::run(&c).is_ok());
    }

    #[test]
    fn odd_row_geometry_with_replicated_builds_is_a_config_error() {
        let mut c = SweepConfig::new(10, 1);
        c.geometries = vec![RedMuleConfig::new(5, 2, 2)];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // Non-replicated builds accept odd rows.
        c.protections = vec![Protection::Baseline, Protection::Abft];
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.fault_counts = vec![1];
        c.threads = 1;
        assert!(Sweep::run(&c).is_ok());
    }

    #[test]
    fn fast_forward_and_direct_sweeps_emit_identical_json() {
        let mut fast = tiny(23, 2);
        fast.fault_counts = vec![1, 3];
        let mut direct = fast.clone();
        direct.fast_forward = false;
        let a = Sweep::run(&fast).unwrap();
        let b = Sweep::run(&direct).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
        // The sidecar names the engine that ran; the deterministic
        // documents never do.
        assert!(a.timing_json().contains("\"engine\": \"fast-forward\""));
        assert!(b.timing_json().contains("\"engine\": \"direct\""));
        assert!(!a.to_json(false).contains("\"engine\""));
        assert!(!a.to_json_v2().contains("\"engine\""));
    }

    #[test]
    fn two_level_sweeps_emit_identical_json_across_thread_counts() {
        let mut tl = tiny(23, 2);
        tl.fault_counts = vec![1, 3];
        tl.two_level = true;
        let mut ff = tl.clone();
        ff.two_level = false;
        let a = Sweep::run(&tl).unwrap();
        let b = Sweep::run(&ff).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
        assert_eq!(a.to_json_v2(), b.to_json_v2());
        assert!(a.timing_json().contains("\"engine\": \"two-level\""));
        // Thread-invariance holds on the two-level engine too.
        let mut tl1 = tl.clone();
        tl1.threads = 1;
        assert_eq!(Sweep::run(&tl1).unwrap().to_json_v2(), a.to_json_v2());
        // The two-level engine is the functional level of fast-forward:
        // combining it with the direct engine is a configuration error.
        let mut bad = tl.clone();
        bad.fast_forward = false;
        assert!(matches!(Sweep::run(&bad), Err(Error::Config(_))));
    }

    #[test]
    fn explicit_default_recovery_axis_is_byte_identical_to_no_axis() {
        // `Some([FullRestart])` on builds whose Table-1 default *is*
        // full-restart must reproduce the axis-free document byte for
        // byte — the axis only re-labels the same cells.
        let mut c = SweepConfig::new(30, 11);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Data];
        c.fault_counts = vec![1];
        c.threads = 2;
        let base = Sweep::run(&c).unwrap();
        let mut axis = c.clone();
        axis.recoveries = Some(vec![RecoveryPolicy::FullRestart]);
        assert_eq!(axis.n_cells(), c.n_cells());
        let r = Sweep::run(&axis).unwrap();
        assert_eq!(r.to_json(false), base.to_json(false));
        assert_eq!(r.to_json_v2(), base.to_json_v2());
    }

    #[test]
    fn recovery_axis_multiplies_the_grid_and_shares_plan_streams() {
        let mut c = SweepConfig::new(30, 7);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::AbftOnline];
        c.fault_counts = vec![1];
        c.threads = 2;
        c.recoveries = Some(vec![
            RecoveryPolicy::FullRestart,
            RecoveryPolicy::TileLevel,
            RecoveryPolicy::InPlaceCorrect,
        ]);
        assert_eq!(c.n_cells(), 3);
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.cells.len(), 3);
        // Recovery variants of one coordinate share the campaign seed —
        // same plan streams, a controlled comparison across policies.
        let seeds: Vec<u64> = r.cells.iter().map(|c| c.result.config.seed).collect();
        assert!(seeds.windows(2).all(|w| w[0] == w[1]));
        // The v2 document names each cell's policy.
        let j = r.to_json_v2();
        assert!(j.contains("\"recovery\": \"full-restart\""));
        assert!(j.contains("\"recovery\": \"tile-level\""));
        assert!(j.contains("\"recovery\": \"in-place-correct\""));
    }

    #[test]
    fn invalid_recovery_pairs_are_config_errors_before_any_cell_runs() {
        // In-place correction needs online-ABFT hardware.
        let mut c = SweepConfig::new(10, 1);
        c.protections = vec![Protection::Baseline];
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.fault_counts = vec![1];
        c.recoveries = Some(vec![RecoveryPolicy::InPlaceCorrect]);
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // Tile-level re-execution needs some detection capability.
        c.recoveries = Some(vec![RecoveryPolicy::TileLevel]);
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // An empty axis is rejected rather than producing zero cells.
        c.recoveries = Some(vec![]);
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
    }

    #[test]
    fn invalid_axes_are_config_errors_before_any_cell_runs() {
        let mut c = SweepConfig::new(10, 1);
        c.protections.clear();
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = SweepConfig::new(10, 1);
        c.fault_counts = vec![1, 0];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = SweepConfig::new(10, 1);
        c.fault_counts = vec![1, crate::fault::MAX_PLANS_PER_RUN + 1];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = SweepConfig::new(10, 1);
        c.protections = vec![Protection::Abft];
        c.tol_factors = vec![f64::NAN];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        // The confidence knob is validated up front too.
        for bad in [0.0, 1.0, -0.5, f64::NAN] {
            let mut c = SweepConfig::new(10, 1);
            c.confidence = bad;
            assert!(
                matches!(Sweep::run(&c), Err(Error::Config(_))),
                "confidence {bad} must be rejected"
            );
        }
    }

    #[test]
    fn v2_json_is_deterministic_and_carries_intervals() {
        let a = Sweep::run(&tiny(31, 1)).unwrap();
        let b = Sweep::run(&tiny(31, 4)).unwrap();
        let ja = a.to_json_v2();
        assert_eq!(ja, b.to_json_v2(), "v2 JSON must be thread-invariant");
        for key in [
            "\"schema\": \"redmule-ft/sweep-v2\"",
            "\"precision_target\": 0.0",
            "\"stratified\": false",
            "\"confidence\": 0.95",
            "\"n_injections\": 40",
            "\"stopped_early\": false",
            "\"batches\": 1",
            "\"correct_no_retry\": {\"count\": ",
            "\"ci_lo\": ",
            "\"ci_hi\": ",
            "\"functional_error\": {\"count\": ",
            "\"upper95\": ",
        ] {
            assert!(ja.contains(key), "missing {key} in:\n{ja}");
        }
        // Timing never leaks into the deterministic v2 document.
        assert!(!ja.contains("wall_seconds"), "v2 must not carry timing");
        assert!(!ja.contains("runs_per_sec"));
        // Unstratified cells carry no per-stratum block.
        assert!(!ja.contains("\"strata\""));
    }

    #[test]
    fn timing_sidecar_is_a_separate_valid_document() {
        let r = Sweep::run(&tiny(17, 2)).unwrap();
        let timing = r.timing_json();
        for key in [
            "\"schema\": \"redmule-ft/bench-sweep-v1\"",
            "\"wall_seconds\": ",
            "\"runs_per_sec\": ",
            "\"injections_per_sec\": ",
            "\"n_injections\": 40",
        ] {
            assert!(timing.contains(key), "missing {key} in:\n{timing}");
        }
        // One timing record per grid cell.
        assert_eq!(
            timing.matches("\"injections_per_sec\"").count(),
            r.cells.len()
        );
        // And the main documents stay timing-free regardless of the
        // sidecar (the pre-PR-4 `--timing` flag spliced wall-clock into
        // the byte-compared JSON).
        assert!(!r.to_json_v2().contains("wall_seconds"));
        assert!(!r.to_json(false).contains("wall_seconds"));
    }

    #[test]
    fn precision_target_stops_cells_early_with_tight_intervals() {
        let mut c = SweepConfig::new(4_000, 9);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline, Protection::Full];
        c.fault_counts = vec![1];
        c.threads = 2;
        c.precision_target = 0.1;
        c.batch_size = 200;
        c.min_injections = 200;
        let r = Sweep::run(&c).unwrap();
        assert_eq!(r.precision_target, 0.1);
        for cell in &r.cells {
            let res = &cell.result;
            assert!(
                res.stopped_early && res.total < 4_000,
                "{:?}: a 0.1 target must stop well before the cap (ran {})",
                cell.protection,
                res.total
            );
            assert_eq!(res.total % 200, 0, "stop lands on a batch boundary");
            for o in OUTCOMES {
                assert!(
                    res.estimate_of(o).half_width() <= 0.1,
                    "{:?}/{o:?}: half-width {}",
                    cell.protection,
                    res.estimate_of(o).half_width()
                );
            }
        }
        let j = r.to_json_v2();
        assert!(j.contains("\"stopped_early\": true"));
        assert!(j.contains("\"precision_target\": 0.1"));
        // Thread-invariance holds for adaptive sweeps too.
        let mut c1 = c.clone();
        c1.threads = 1;
        assert_eq!(Sweep::run(&c1).unwrap().to_json_v2(), j);
        // And the legacy per-cell pools produce the same document.
        let mut legacy = c.clone();
        legacy.work_stealing = false;
        legacy.trace_cache = false;
        assert_eq!(Sweep::run(&legacy).unwrap().to_json_v2(), j);
    }

    #[test]
    fn stratified_sweep_is_deterministic_and_carries_strata() {
        let mut c = SweepConfig::new(600, 5);
        c.shapes = vec![GemmSpec::new(6, 8, 8)];
        c.protections = vec![Protection::Baseline];
        c.fault_counts = vec![1];
        c.threads = 2;
        c.stratify = true;
        let a = Sweep::run(&c).unwrap();
        let mut c1 = c.clone();
        c1.threads = 1;
        let b = Sweep::run(&c1).unwrap();
        assert_eq!(a.to_json_v2(), b.to_json_v2());
        assert!(a.to_json_v2().contains("\"stratified\": true"));
        // The cell's campaign carried per-stratum tallies that sum to
        // the cell total.
        let res = &a.cells[0].result;
        assert!(!res.strata.is_empty());
        assert_eq!(res.strata.iter().map(|s| s.n).sum::<u64>(), res.total);
        // The v2 document carries the per-stratum estimate table: one
        // strata block, one entry per stratum, each with its own
        // functional_error object.
        let j = a.to_json_v2();
        assert!(j.contains("\"strata\": ["));
        for s in &res.strata {
            assert!(
                j.contains(&format!("\"name\": \"{}\"", s.name)),
                "stratum {} missing from the JSON",
                s.name
            );
        }
        assert_eq!(
            j.matches("\"functional_error\":").count(),
            1 + res.strata.len(),
            "cell-level + one per stratum"
        );
    }

    #[test]
    fn invalid_precision_is_a_config_error_before_cells_run() {
        let mut c = SweepConfig::new(10, 1);
        c.precision_target = f64::NAN;
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = SweepConfig::new(10, 1);
        c.precision_target = -1.0;
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
    }

    #[test]
    fn confidence_knob_widens_intervals_without_touching_counts() {
        let mut c90 = tiny(41, 2);
        c90.confidence = 0.90;
        let mut c99 = c90.clone();
        c99.confidence = 0.99;
        let r90 = Sweep::run(&c90).unwrap();
        let r99 = Sweep::run(&c99).unwrap();
        // Counts are untouched by the reporting confidence.
        assert_eq!(r90.to_json(false), r99.to_json(false));
        // Intervals nest: every cell/outcome's 99 % CI contains the 90 %.
        for (a, b) in r90.cells.iter().zip(&r99.cells) {
            for o in OUTCOMES {
                let (e90, e99) = (a.result.estimate_of(o), b.result.estimate_of(o));
                assert!(e99.ci_lo <= e90.ci_lo + 1e-12, "{o:?} lo");
                assert!(e99.ci_hi + 1e-12 >= e90.ci_hi, "{o:?} hi");
            }
        }
        assert!(r90.to_json_v2().contains("\"confidence\": 0.9"));
        assert!(r99.to_json_v2().contains("\"confidence\": 0.99"));
    }

    #[test]
    fn json_shape_is_stable() {
        let mut c = SweepConfig::new(10, 3);
        c.shapes = vec![GemmSpec::new(4, 4, 4)];
        c.protections = vec![Protection::Baseline];
        c.fault_counts = vec![1];
        c.threads = 1;
        let r = Sweep::run(&c).unwrap();
        let j = r.to_json(false);
        for key in [
            "\"schema\": \"redmule-ft/sweep-v1\"",
            "\"seed\": 3",
            "\"injections_per_cell\": 10",
            "\"fault_model\": \"independent\"",
            "\"cells\": [",
            "\"geometry\": {\"l\": 12, \"h\": 4, \"p\": 3}",
            "\"protection\": \"baseline\"",
            "\"shape\": {\"m\": 4, \"n\": 4, \"k\": 4}",
            "\"outcomes\": ",
            "\"rates\": ",
        ] {
            assert!(j.contains(key), "missing {key} in:\n{j}");
        }
        assert!(!j.contains("wall_seconds"), "timing must be opt-in");
        // Timing variant adds the fields without breaking the rest.
        let jt = r.to_json(true);
        assert!(jt.contains("wall_seconds") && jt.contains("runs_per_sec"));
    }

    #[test]
    fn default_tile_axis_is_byte_identical_and_emits_no_mesh_fields() {
        // The explicit `tiles = [1]` default and an empty axis are the
        // same grid, and neither leaks the mesh fields into the JSON —
        // the A/B contract that keeps historical documents stable.
        let a = Sweep::run(&tiny(23, 2)).unwrap();
        let mut empty = tiny(23, 2);
        empty.tiles = Vec::new();
        let b = Sweep::run(&empty).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
        assert_eq!(a.to_json_v2(), b.to_json_v2());
        for doc in [a.to_json(false), a.to_json_v2(), a.timing_json()] {
            assert!(!doc.contains("\"tiles\""), "single-tile docs must not carry tiles");
            assert!(!doc.contains("\"mesh\""), "single-tile docs must not carry mesh");
        }
        assert!(a.cells.iter().all(|c| c.tiles == 1 && c.mesh.is_none()));
    }

    fn mesh_tiny(seed: u64, threads: usize) -> SweepConfig {
        let mut c = SweepConfig::new(10, seed);
        c.shapes = vec![GemmSpec::new(12, 6, 5)];
        c.protections = vec![Protection::Baseline, Protection::Full];
        c.fault_counts = vec![1];
        c.tiles = vec![1, 3];
        c.threads = threads;
        c
    }

    #[test]
    fn mesh_tile_axis_runs_both_schedulers_byte_identically() {
        let c = mesh_tiny(31, 2);
        assert_eq!(c.n_cells(), 4, "2 protections x 1 shape x 1 fault x 2 tiles");
        let a = Sweep::run(&c).unwrap();
        assert_eq!(a.cells.len(), 4);
        // Multi-tile cells carry the mesh block with consistent shard
        // accounting; single-tile cells stay on the exact legacy path.
        for cell in &a.cells {
            if cell.tiles == 1 {
                assert!(cell.mesh.is_none());
            } else {
                let m = cell.mesh.as_ref().expect("mesh cell info");
                assert_eq!(m.tiles, 3);
                assert!(m.shards >= m.tiles);
                assert_eq!(cell.result.total, 10);
                // CRITICAL: mesh attribution never rides in the
                // campaign strata (the stratified estimators key off
                // non-empty strata).
                assert!(cell.result.strata.is_empty());
            }
        }
        // Scheduler/thread invariance extends to the mesh axis.
        let mut legacy = mesh_tiny(31, 1);
        legacy.work_stealing = false;
        let b = Sweep::run(&legacy).unwrap();
        assert_eq!(a.to_json(false), b.to_json(false));
        assert_eq!(a.to_json_v2(), b.to_json_v2());
        // The mesh fields surface in both documents for mesh cells only.
        let v1 = a.to_json(false);
        let v2 = a.to_json_v2();
        assert_eq!(v1.matches("\"tiles\": 3").count(), 2);
        assert_eq!(v2.matches("\"mesh\": {\"tiles\": 3").count(), 2);
        // The full-protection chaos cell must correct everything the
        // NoC throws at it: zero functional errors.
        let full = a
            .cells
            .iter()
            .find(|c| c.tiles == 3 && c.protection == Protection::Full)
            .unwrap();
        assert_eq!(full.result.functional_errors(), 0);
        assert!(full.mesh.as_ref().unwrap().noc_applied > 0);
    }

    #[test]
    fn mesh_axis_rejects_incompatible_knobs_up_front() {
        let mut c = mesh_tiny(1, 1);
        c.tiles = vec![1, 0];
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = mesh_tiny(1, 1);
        c.stratify = true;
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = mesh_tiny(1, 1);
        c.precision_target = 0.1;
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
        let mut c = mesh_tiny(1, 1);
        c.recoveries = Some(vec![RecoveryPolicy::FullRestart]);
        assert!(matches!(Sweep::run(&c), Err(Error::Config(_))));
    }
}
