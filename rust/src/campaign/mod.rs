//! Statistical fault-injection (SFI) campaign engine — the machinery
//! behind Table 1 of the paper (§4.2).
//!
//! One *injection* = one independent hosted execution of the workload with
//! a planned fault drawn from the build's area-weighted site population
//! ([`crate::fault::FaultRegistry`]): a uniformly random cycle, an
//! area-weighted site, a uniformly random bit. Clock and reset are not
//! part of the population (excluded in the paper too). Table-1 campaigns
//! inject exactly one fault per run — the paper's assumption that "no
//! additional faults occur during the recomputation phase" — while the
//! scenario-grid engine in [`sweep`] raises
//! [`CampaignConfig::faults_per_run`] to N ≥ 1 (independent SEUs or a
//! multi-bit burst, see [`crate::fault::FaultModel`]).
//!
//! Every RNG stream is domain-separated: the problem data and the
//! per-injection fault draws descend from `mix64(mix64(seed, DOMAIN), ..)`
//! with distinct domain tags, so no injection index can replay the
//! problem-generation stream (a pre-PR-2 bug: injection `0xC0FFEE`
//! correlated its fault plan with the workload data).
//!
//! Outcomes are classified exactly as in Table 1 by comparing the TCDM Z
//! region bit-for-bit against the fault-free golden:
//!
//! * **CorrectNoRetry** — completed, Z matches, no retry needed.
//! * **CorrectWithRetry** — a checker detected the fault, the host
//!   re-programmed and re-executed, and the retry's Z matches.
//! * **Incorrect** — completed (with or without retry) but Z differs:
//!   silent data corruption, the worst case.
//! * **Timeout** — did not finish within `20×` the fault-free cycles
//!   (hung FSM, lost handshake, or abort the host never saw).
//!
//! Error bounds use a Poisson 95 % CI, "conservatively assuming one
//! additional observed error" — the same procedure as the paper's
//! footnote a).
//!
//! # Fast-forward engine
//!
//! Nearly every simulated cycle of a campaign replays the fault-free
//! trace: the sampled fault fires at one cycle, everything before it is
//! the clean prefix and — for the overwhelmingly common masked/absorbed
//! outcomes — everything after some point is the clean tail. With
//! [`CampaignConfig::fast_forward`] (the default) the engine records one
//! instrumented reference run per campaign
//! ([`crate::cluster::System::record_reference`]): full architectural
//! snapshots every `checkpoint_interval` cycles plus a per-checkpoint
//! state digest. Each injection restores the checkpoint just before its
//! earliest fault, simulates only from there, and short-circuits to the
//! recorded clean outcome as soon as its rolling digest matches the
//! reference again. Outcome counts are **bit-identical** to the direct
//! engine (`fast_forward = false`) — `tests/fastforward.rs` and the
//! `fastforward_speedup` bench assert both the equivalence and the
//! speedup.
//!
//! # Zero-copy hot path and shared clean runs
//!
//! The injection loop is arena-based: workers adopt the campaign's
//! pristine staged image by `copy_from_slice` into their existing TCDM
//! buffers ([`crate::cluster::System::restore_from`]), re-arm one
//! reusable [`crate::fault::FaultCtx`] per injection, and the
//! fast-forward digest probes hash the TCDM delta in place — a
//! steady-state injection performs no heap allocation in the
//! restore/plan/digest machinery. The clean-run artifacts themselves
//! (staging + reference trace + horizon) are a pure function of the
//! campaign's *clean-run identity* and can be shared across campaigns
//! through a [`TraceCache`]: the sweep grid hands one cache to all its
//! cells, so cells differing only in fault count / model / statistical
//! knobs record one reference run instead of one each. All of it is
//! byte-identical to the unshared engines (`tests/shared_trace.rs`,
//! `benches/sweep_shared_trace.rs`).

//! # Statistical (adaptive) campaigns
//!
//! A fixed injection budget answers the wrong question: the paper's "no
//! functional errors after 1 M injections" is a *statistical* claim — an
//! upper bound on the residual error rate — and different cells of a
//! sweep need very different sample sizes to pin their rates to the same
//! precision. Setting [`CampaignConfig::precision_target`] `> 0` turns
//! the campaign sequential: it runs deterministic batches of
//! [`CampaignConfig::batch_size`] injections and stops as soon as every
//! tracked outcome rate's 95 % Wilson half-width is at or below the
//! target (never before [`CampaignConfig::min_injections`], never past
//! the [`CampaignConfig::max_injections`] cap). Because every
//! injection's fault plan is still a pure function of `(seed, index)`
//! and batch boundaries depend only on merged batch counts, the stop
//! point and all counts are **thread-count invariant**, and the engine
//! sits directly on top of the PR 3 fast-forward machinery (one
//! reference trace per campaign, reused by every batch).
//!
//! With [`CampaignConfig::stratify`] the per-batch injections are further
//! allocated over the fault-site registry's area strata
//! ([`crate::fault::registry::stratum_of_module`]): batch 1 splits
//! proportional to stratum weight, later batches re-allocate
//! Neyman-style (`∝ W_h·s_h` on the functional-error rate, floored so no
//! stratum starves), so rare-but-critical populations — register file,
//! scheduler, ABFT checksum unit — receive enough samples to bound their
//! outcome rates. Stratified results are reported with the standard
//! area-weighted estimator ([`crate::util::stats::OutcomeEstimate`]).

pub mod sweep;

pub use sweep::{Sweep, SweepCell, SweepConfig, SweepResult};

use crate::cluster::{HostOutcome, RecoveryPolicy, RefTrace, System};
use crate::fault::{FaultCtx, FaultModel, FaultPlan, FaultRegistry};
use crate::golden::{GemmProblem, GemmSpec, Mat, ABFT_TOL_FACTOR};
use crate::redmule::{ExecMode, Protection, RedMuleConfig, TaskLayout};
use crate::tcdm::Tcdm;
use crate::util::rng::{mix64, Xoshiro256};
use crate::util::stats::{
    conservative_upper_rate, neyman_allocation, OutcomeEstimate, Rate, StratumSample,
};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ------------------------------------------------- RNG stream domains
//
// The campaign derives every random quantity from `(seed, purpose)` so a
// run is exactly reproducible and thread-layout independent. Purposes are
// kept apart by domain tags: seeding the problem with `mix64(seed, TAG)`
// while injection `i` uses `mix64(seed, i)` would make injection
// `i == TAG` replay the problem stream verbatim — its fault plan drawn
// from the very numbers that generated the workload data. (That was the
// pre-PR-2 scheme with `TAG = 0xC0FFEE`; see the regression test
// `rng_streams_are_domain_separated_at_the_old_collision_index`.)

/// Domain tag of the problem-generation stream.
pub const DOMAIN_PROBLEM: u64 = 0x5245_444D_5052_4F42; // "REDMPROB"
/// Domain tag of the per-injection fault-plan streams.
pub const DOMAIN_INJECT: u64 = 0x5245_444D_494E_4A43; // "REDMINJC"

/// Seed of the `(seed, domain, index)` stream: two mixing rounds keep the
/// domains apart for every index (a single round cannot — the index would
/// add onto the same word the domain occupies).
#[inline]
pub fn stream_seed(seed: u64, domain: u64, index: u64) -> u64 {
    mix64(mix64(seed, domain), index)
}

/// Seed of a campaign's workload-generation RNG.
#[inline]
pub fn problem_seed(seed: u64) -> u64 {
    stream_seed(seed, DOMAIN_PROBLEM, 0)
}

/// Seed of injection `i`'s fault-plan RNG.
#[inline]
pub fn injection_seed(seed: u64, i: u64) -> u64 {
    stream_seed(seed, DOMAIN_INJECT, i)
}

/// Table-1 outcome classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Outcome {
    CorrectNoRetry,
    CorrectWithRetry,
    Incorrect,
    Timeout,
}

impl Outcome {
    pub fn name(self) -> &'static str {
        match self {
            Outcome::CorrectNoRetry => "correct (w/o retry)",
            Outcome::CorrectWithRetry => "correct (with retry)",
            Outcome::Incorrect => "incorrect",
            Outcome::Timeout => "timeout",
        }
    }

    pub fn is_functional_error(self) -> bool {
        matches!(self, Outcome::Incorrect | Outcome::Timeout)
    }

    /// Canonical index into per-outcome arrays (the [`OUTCOMES`] order).
    pub fn index(self) -> usize {
        match self {
            Outcome::CorrectNoRetry => 0,
            Outcome::CorrectWithRetry => 1,
            Outcome::Incorrect => 2,
            Outcome::Timeout => 3,
        }
    }
}

/// The four Table-1 outcome classes in canonical order.
pub const OUTCOMES: [Outcome; 4] = [
    Outcome::CorrectNoRetry,
    Outcome::CorrectWithRetry,
    Outcome::Incorrect,
    Outcome::Timeout,
];

/// The outcome class the stratified engine's Neyman reallocation scores
/// its per-stratum spread on ([`CellCtx::allocate`]): later batches
/// direct samples toward strata whose *rate of this class* is most
/// uncertain. The default — the combined functional-error rate — is the
/// paper's headline quantity and reproduces the historical allocation
/// bit for bit; picking a single class instead sharpens that class's
/// stratified interval (e.g. `CorrectWithRetry` when studying recovery
/// coverage rather than failures).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StratifyObjective {
    /// Incorrect + Timeout — the paper's functional-error class.
    #[default]
    FunctionalError,
    /// One specific Table-1 outcome class.
    Outcome(Outcome),
}

impl StratifyObjective {
    /// Stable CLI/JSON slug.
    pub fn name(self) -> &'static str {
        match self {
            StratifyObjective::FunctionalError => "functional-error",
            StratifyObjective::Outcome(Outcome::CorrectNoRetry) => "correct-no-retry",
            StratifyObjective::Outcome(Outcome::CorrectWithRetry) => "correct-with-retry",
            StratifyObjective::Outcome(Outcome::Incorrect) => "incorrect",
            StratifyObjective::Outcome(Outcome::Timeout) => "timeout",
        }
    }

    /// Parse a [`StratifyObjective::name`] slug.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "functional-error" => StratifyObjective::FunctionalError,
            "correct-no-retry" => StratifyObjective::Outcome(Outcome::CorrectNoRetry),
            "correct-with-retry" => StratifyObjective::Outcome(Outcome::CorrectWithRetry),
            "incorrect" => StratifyObjective::Outcome(Outcome::Incorrect),
            "timeout" => StratifyObjective::Outcome(Outcome::Timeout),
            _ => return None,
        })
    }

    /// Count of the scored class in a per-stratum outcome tally
    /// (in [`OUTCOMES`] order).
    pub fn count_in(self, outcomes: &[u64; 4]) -> u64 {
        match self {
            StratifyObjective::FunctionalError => {
                outcomes[Outcome::Incorrect.index()] + outcomes[Outcome::Timeout.index()]
            }
            StratifyObjective::Outcome(o) => outcomes[o.index()],
        }
    }
}

/// Classify one hosted run against the golden result.
pub fn classify(report: &crate::cluster::RunReport, golden: &Mat) -> Outcome {
    match report.outcome {
        HostOutcome::Completed => {
            if report.z_matches(golden) {
                Outcome::CorrectNoRetry
            } else {
                Outcome::Incorrect
            }
        }
        HostOutcome::CompletedAfterRetry => {
            if report.z_matches(golden) {
                Outcome::CorrectWithRetry
            } else {
                Outcome::Incorrect
            }
        }
        // An abandoned workload never delivers a result; like a hung one,
        // it surfaces as a liveness failure at system level.
        HostOutcome::Abandoned | HostOutcome::TimedOut => Outcome::Timeout,
    }
}

/// Campaign parameters.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub cfg: RedMuleConfig,
    pub protection: Protection,
    pub mode: ExecMode,
    pub spec: GemmSpec,
    pub injections: u64,
    pub seed: u64,
    pub threads: usize,
    /// Host re-execution policy after detected faults.
    pub recovery: RecoveryPolicy,
    /// Faults injected per run (Table 1 uses 1; sweep grids raise it).
    pub faults_per_run: usize,
    /// Correlation model of the faults when `faults_per_run > 1`.
    pub fault_model: FaultModel,
    /// ABFT verification tolerance safety factor (ABFT builds only; the
    /// sweep's tolerance axis).
    pub abft_tol_factor: f64,
    /// Use the checkpointed fast-forward engine: one instrumented
    /// fault-free reference run per campaign snapshots the full
    /// architectural state every [`CampaignConfig::checkpoint_interval`]
    /// cycles; each injection then restores the checkpoint just before
    /// its earliest fault and, once every plan is behind, exits early
    /// when the state digest re-converges with the reference (fault
    /// masked or absorbed). Results are bit-identical to the direct
    /// engine — `tests/fastforward.rs` pins the equivalence — at roughly
    /// an order of magnitude fewer simulated cycles.
    pub fast_forward: bool,
    /// Reference checkpoint spacing in cycles; `0` = auto
    /// (`horizon / 16`, clamped to `[8, 256]`). Smaller intervals skip
    /// more prefix and detect convergence sooner but cost more digest
    /// probes and snapshot memory.
    pub checkpoint_interval: u64,
    /// Adaptive precision target: run in sequential batches and stop as
    /// soon as every tracked outcome rate's 95 % CI half-width is at or
    /// below this value (an absolute rate, e.g. `0.01` = ±1 percentage
    /// point). `0` disables the adaptive engine — the campaign runs the
    /// fixed `injections` budget exactly as before.
    pub precision_target: f64,
    /// Adaptive floor: the stop rule may not fire before this many
    /// injections (`0` = after the first batch).
    pub min_injections: u64,
    /// Adaptive cap: hard upper budget (`0` = use `injections`).
    pub max_injections: u64,
    /// Batch size of the sequential engine (`0` = auto: `cap / 16`
    /// clamped to `[100, 10000]`). Batch boundaries are part of the
    /// deterministic schedule — the same seed, target and batch size
    /// stop at the same injection count on any thread layout.
    pub batch_size: u64,
    /// Stratified allocation over the fault-site registry's area strata
    /// with Neyman-style reallocation between batches (see the module
    /// docs). Changes which sites injection index `i` may strike, so a
    /// stratified campaign is a different (deliberately designed) sample
    /// than an unstratified one.
    pub stratify: bool,
    /// Outcome class the Neyman reallocation scores per-stratum spread
    /// on (stratified campaigns only; see [`StratifyObjective`]). The
    /// default reproduces the historical functional-error allocation bit
    /// for bit.
    pub stratify_on: StratifyObjective,
    /// Run injections on the two-level executor: the functional fast
    /// path of the fast-forward engine plus per-cycle convergence probes
    /// that hand the run back to the recorded reference within a few
    /// cycles of the fault window settling, instead of at the next
    /// checkpoint boundary (see
    /// [`crate::cluster::System::run_staged_with_faults_tl`]). Requires
    /// [`CampaignConfig::fast_forward`]; results are bit-identical to
    /// both other engines (`tests/fastforward.rs`,
    /// `tests/shared_trace.rs`, `tests/twolevel.rs`).
    pub two_level: bool,
    /// Coalesce adjacent per-injection fault windows on the two-level
    /// engine: a worker's chunk groups its injections by restored
    /// reference checkpoint and rewinds the TCDM to the shared
    /// checkpoint image by undoing only the previous window's writes
    /// ([`crate::tcdm::Tcdm::undo_to_watermark`]) instead of a full
    /// pristine-restore + delta replay per injection. Counts are
    /// byte-identical either way — plan streams are `(seed, index)`-pure
    /// and chunk tallies are additive sums, so processing order cannot
    /// change a result (`tests/twolevel.rs` A/B-pins it). Default on;
    /// ignored unless [`CampaignConfig::two_level`].
    pub tl_coalesce: bool,
    /// Confidence level of every reported interval and of the adaptive
    /// stop rule (`0.95` = the paper's convention and the historical
    /// hardwired level; must be in the open interval (0, 1)). At the
    /// default the interval math is bit-identical to pre-knob builds —
    /// the 95 % critical values are pinned to their exact constants.
    pub confidence: f64,
}

impl CampaignConfig {
    /// The paper's configuration for one Table-1 column: the (12×16×16)
    /// workload on the paper instance. Baseline runs unprotected;
    /// replicated builds run in fault-tolerant mode; the ABFT build runs
    /// in performance mode (its protection is the checksum layer) with
    /// selective row-band recovery.
    pub fn table1(protection: Protection, injections: u64, seed: u64) -> Self {
        let mode = if protection.has_data_protection() {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        let recovery = if protection.has_online_abft() {
            RecoveryPolicy::InPlaceCorrect
        } else if protection.has_abft_checksums() {
            RecoveryPolicy::TileLevel
        } else {
            RecoveryPolicy::FullRestart
        };
        Self {
            cfg: RedMuleConfig::paper(),
            protection,
            mode,
            spec: GemmSpec::paper_workload(),
            injections,
            seed,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            recovery,
            faults_per_run: 1,
            fault_model: FaultModel::Independent,
            abft_tol_factor: ABFT_TOL_FACTOR,
            fast_forward: true,
            checkpoint_interval: 0,
            precision_target: 0.0,
            min_injections: 0,
            max_injections: 0,
            batch_size: 0,
            stratify: false,
            stratify_on: StratifyObjective::FunctionalError,
            two_level: false,
            tl_coalesce: true,
            confidence: 0.95,
        }
    }
}

/// Per-stratum tally of a stratified campaign.
#[derive(Debug, Clone)]
pub struct StratumStats {
    /// Display name (see [`crate::fault::STRATUM_NAMES`]).
    pub name: &'static str,
    /// Normalized share of the population's sampling weight (`W_h`).
    pub share: f64,
    /// Injections allocated to the stratum so far.
    pub n: u64,
    /// Outcome counts in [`OUTCOMES`] order.
    pub outcomes: [u64; 4],
}

/// Aggregated campaign results.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub config: CampaignConfig,
    pub total: u64,
    pub correct_no_retry: u64,
    pub correct_with_retry: u64,
    pub incorrect: u64,
    pub timeout: u64,
    /// Injections where at least one fault actually perturbed live state
    /// / an exercised net (the rest were architecturally masked on
    /// arrival).
    pub applied: u64,
    /// Total faults that landed across all runs (equals `applied` on
    /// single-fault campaigns; larger on multi-fault ones).
    pub faults_applied: u64,
    /// In-place corrections performed across all runs (`AbftOnline`
    /// builds under [`RecoveryPolicy::InPlaceCorrect`]; 0 elsewhere).
    pub corrections: u64,
    /// Row-band recompute recoveries across all runs (ABFT builds under
    /// band-capable recovery policies; 0 elsewhere).
    pub band_recomputes: u64,
    /// Wall-clock seconds and throughput of the campaign itself.
    pub wall_seconds: f64,
    /// Batches the sequential engine ran (1 for fixed-budget campaigns).
    pub batches: u64,
    /// True when the precision target stopped the campaign before its
    /// injection cap.
    pub stopped_early: bool,
    /// Per-stratum tallies (empty unless [`CampaignConfig::stratify`]).
    pub strata: Vec<StratumStats>,
}

impl CampaignResult {
    pub fn correct(&self) -> u64 {
        self.correct_no_retry + self.correct_with_retry
    }

    pub fn functional_errors(&self) -> u64 {
        self.incorrect + self.timeout
    }

    pub fn rate(&self, count: u64) -> Rate {
        Rate::new(count, self.total)
    }

    pub fn runs_per_sec(&self) -> f64 {
        self.total as f64 / self.wall_seconds.max(1e-9)
    }

    /// Upper-bound rate for a zero/low count, Poisson 95 % CI with one
    /// conservatively assumed extra error (the paper's footnote a).
    pub fn conservative_upper(&self, count: u64) -> f64 {
        conservative_upper_rate(count, self.total)
    }

    /// Count of one outcome class.
    pub fn count_of(&self, o: Outcome) -> u64 {
        match o {
            Outcome::CorrectNoRetry => self.correct_no_retry,
            Outcome::CorrectWithRetry => self.correct_with_retry,
            Outcome::Incorrect => self.incorrect,
            Outcome::Timeout => self.timeout,
        }
    }

    /// Rate estimate with confidence intervals for one outcome class at
    /// the campaign's [`CampaignConfig::confidence`] level: pooled
    /// Wilson + Clopper–Pearson, or the area-weighted stratified
    /// estimator when the campaign ran stratified.
    pub fn estimate_of(&self, o: Outcome) -> OutcomeEstimate {
        let conf = self.config.confidence;
        if self.strata.is_empty() {
            OutcomeEstimate::pooled_at(self.count_of(o), self.total, conf)
        } else {
            let samples: Vec<StratumSample> = self
                .strata
                .iter()
                .map(|s| StratumSample {
                    weight: s.share,
                    count: s.outcomes[o.index()],
                    n: s.n,
                })
                .collect();
            OutcomeEstimate::stratified_at(&samples, conf)
        }
    }

    /// Rate estimate of the combined functional-error class
    /// (incorrect + timeout) — the paper's headline quantity — at the
    /// campaign's confidence level.
    pub fn functional_error_estimate(&self) -> OutcomeEstimate {
        let conf = self.config.confidence;
        if self.strata.is_empty() {
            OutcomeEstimate::pooled_at(self.functional_errors(), self.total, conf)
        } else {
            let samples: Vec<StratumSample> = self
                .strata
                .iter()
                .map(|s| StratumSample {
                    weight: s.share,
                    count: s.outcomes[Outcome::Incorrect.index()]
                        + s.outcomes[Outcome::Timeout.index()],
                    n: s.n,
                })
                .collect();
            OutcomeEstimate::stratified_at(&samples, conf)
        }
    }

    /// True when every tracked outcome rate's CI half-width — at the
    /// campaign's [`CampaignConfig::confidence`] level (0.95 by default)
    /// — is at or below `target`: the adaptive engine's stop criterion.
    /// Tracked rates are the four Table-1 classes *and* the combined
    /// functional-error rate (the headline quantity users actually gate
    /// on, whose interval can be wider than either component's). A
    /// higher confidence level widens the intervals, so the same target
    /// demands more injections.
    pub fn meets_precision(&self, target: f64) -> bool {
        self.total > 0
            && self.functional_error_estimate().half_width() <= target
            && OUTCOMES
                .iter()
                .all(|&o| self.estimate_of(o).half_width() <= target)
    }

    pub fn add(&mut self, outcome: Outcome, applied_faults: u32) {
        self.total += 1;
        if applied_faults > 0 {
            self.applied += 1;
        }
        self.faults_applied += applied_faults as u64;
        match outcome {
            Outcome::CorrectNoRetry => self.correct_no_retry += 1,
            Outcome::CorrectWithRetry => self.correct_with_retry += 1,
            Outcome::Incorrect => self.incorrect += 1,
            Outcome::Timeout => self.timeout += 1,
        }
    }

    fn empty(config: CampaignConfig) -> Self {
        Self {
            config,
            total: 0,
            correct_no_retry: 0,
            correct_with_retry: 0,
            incorrect: 0,
            timeout: 0,
            applied: 0,
            faults_applied: 0,
            corrections: 0,
            band_recomputes: 0,
            wall_seconds: 0.0,
            batches: 0,
            stopped_early: false,
            strata: Vec::new(),
        }
    }

    /// Fold a worker-local tally into the aggregate (count fields only;
    /// config/time/strata stay with the aggregate).
    pub(crate) fn merge_counts(&mut self, local: &CampaignResult) {
        self.total += local.total;
        self.correct_no_retry += local.correct_no_retry;
        self.correct_with_retry += local.correct_with_retry;
        self.incorrect += local.incorrect;
        self.timeout += local.timeout;
        self.applied += local.applied;
        self.faults_applied += local.faults_applied;
        self.corrections += local.corrections;
        self.band_recomputes += local.band_recomputes;
    }

    /// Fold a chunk's per-stratum outcome tallies into the aggregate
    /// (no-op when the campaign is unstratified). Pure sums, so the
    /// merge order — and therefore the scheduler — cannot change the
    /// result.
    pub(crate) fn merge_strata(&mut self, local: &[[u64; 4]]) {
        if self.strata.is_empty() {
            return;
        }
        for (s, o) in local.iter().enumerate() {
            let st = &mut self.strata[s];
            st.n += o.iter().sum::<u64>();
            for (j, &c) in o.iter().enumerate() {
                st.outcomes[j] += c;
            }
        }
    }
}

// ---------------------------------------------- shared clean-run cache

/// The clean-run artifacts every injection of a campaign reuses: the
/// task layout, the staged pristine TCDM image, the fault-free horizon
/// and — on the fast-forward engine — the recorded reference trace.
/// One of these is built per campaign, or fetched from a [`TraceCache`]
/// shared across sweep cells with the same clean-run identity.
#[derive(Debug)]
pub struct CleanRun {
    pub(crate) layout: TaskLayout,
    pub(crate) pristine: Tcdm,
    /// `None` = direct engine, or an ABFT tight-tolerance soft-decline.
    pub(crate) trace: Option<RefTrace>,
    pub(crate) horizon: u64,
}

/// Identity of a campaign's fault-free run: every knob that can change a
/// staged bit, a reference checkpoint or the clean cycle count. Two
/// campaigns with equal keys share staging, horizon and reference trace
/// verbatim. Fault count, fault model, seed, thread/batch layout and
/// precision settings all act strictly *after* the clean run, so they
/// are deliberately not part of the key — that is exactly the sharing
/// the sweep grid exploits (cells differing only along those axes record
/// one reference instead of one each).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub(crate) struct TraceKey {
    l: usize,
    h: usize,
    p: usize,
    protection: &'static str,
    /// Numeric format and op discriminants: both change the staged golden
    /// expectations and (for FP8) the fault-site population and every
    /// value crossing the cast units, so cells differing on either axis
    /// must never share a reference trace even when the workload images
    /// (`problem_digest`) coincide.
    format: &'static str,
    op: &'static str,
    ft_mode: bool,
    /// Recovery-policy discriminant (0 = full restart, 1 = tile-level,
    /// 2 = in-place correct): the policy changes retry behavior, not the
    /// clean run itself, but it is part of the key so pinned hit/miss
    /// expectations partition exactly as the historical `tile_recovery`
    /// bool did — extended, not reshuffled, by the third policy.
    recovery: u8,
    m: usize,
    n: usize,
    k: usize,
    /// `abft_tol_factor` as raw bits (`f64` is not `Eq`/`Hash`).
    tol_bits: u64,
    checkpoint_interval: u64,
    fast_forward: bool,
    /// Two-level instrumentation changes what the reference recording
    /// carries (per-cycle digests + segment write logs), so traces with
    /// and without it are distinct cache identities — a two-level cell
    /// never silently degrades by adopting a plain trace, and a plain
    /// cell never pays the instrumented recording.
    two_level: bool,
    /// Content digest of the exact workload images (see
    /// [`GemmProblem::content_digest`]).
    problem_digest: u64,
}

impl TraceKey {
    pub(crate) fn of(config: &CampaignConfig, problem: &GemmProblem) -> Self {
        Self {
            l: config.cfg.l,
            h: config.cfg.h,
            p: config.cfg.p,
            protection: config.protection.name(),
            format: config.cfg.format.name(),
            op: config.cfg.op.name(),
            ft_mode: config.mode == ExecMode::FaultTolerant,
            recovery: match config.recovery {
                RecoveryPolicy::FullRestart => 0,
                RecoveryPolicy::TileLevel => 1,
                RecoveryPolicy::InPlaceCorrect => 2,
            },
            m: config.spec.m,
            n: config.spec.n,
            k: config.spec.k,
            tol_bits: config.abft_tol_factor.to_bits(),
            checkpoint_interval: config.checkpoint_interval,
            fast_forward: config.fast_forward,
            two_level: config.two_level,
            problem_digest: problem.content_digest(),
        }
    }
}

type CacheSlot = Arc<OnceLock<std::result::Result<Arc<CleanRun>, String>>>;

/// Shared reference-trace cache: clean-run artifacts keyed by
/// [`TraceKey`], shared across concurrent campaigns via `Arc`. The
/// sweep engine hands one cache to every cell of a grid, so cells that
/// differ only in fault count / fault model / seed-independent axes
/// record the (expensive) instrumented reference run once instead of
/// once each — on the default grid that halves the reference runs, and
/// wider fault-count axes save proportionally more. Results are
/// byte-identical with or without the cache because the recording is a
/// pure function of the key (`benches/sweep_shared_trace.rs` and
/// `tests/shared_trace.rs` pin this).
///
/// Concurrency: the per-key slot is a `OnceLock`, so racing builders of
/// the *same* key serialize on that key alone (the first records, the
/// rest block and adopt), while distinct keys build fully in parallel.
///
/// Memory: the sweep engine pins every cell's clean-run identity up
/// front (`TraceCache::retain`) and releases it as the cell completes
/// (`TraceCache::release`); the `Arc<CleanRun>` slot is evicted when
/// the last unfinished cell sharing the key lets go, so peak memory is
/// one `CleanRun` per identity *still in use* rather than per identity
/// ever seen — the cache is empty again at sweep end. Callers that
/// never pin (plain cached campaigns) keep the old keep-forever
/// behavior. Eviction only ever drops the cache's own `Arc`; in-flight
/// adopters keep theirs, and because every pin is taken before the
/// first cell runs, an evicted identity can never be re-recorded — the
/// hit/miss counters are exactly those of the keep-forever cache.
#[derive(Debug, Default)]
pub struct TraceCache {
    entries: Mutex<HashMap<TraceKey, CacheSlot>>,
    /// Outstanding-cell refcounts per identity (sweep engine only).
    pins: Mutex<HashMap<TraceKey, u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl TraceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clean runs adopted from an already-recorded entry.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Clean runs recorded into the cache (unique identities seen).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Resident clean-run entries (recorded and not yet evicted).
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when no clean-run entry is resident — the expected state at
    /// sweep end once every cell released its pin.
    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }

    /// Pin one future use of `key`: the entry (once recorded) stays
    /// resident until a matching [`TraceCache::release`]. The sweep
    /// engine pins every cell's identity before any cell runs, so
    /// releases can never evict an identity another unstarted cell still
    /// needs.
    pub(crate) fn retain(&self, key: TraceKey) {
        *self.pins.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    /// Release one pinned use of `key`; evicts the `Arc<CleanRun>` slot
    /// when this was the last outstanding pin. Unpinned keys are left
    /// alone (the keep-forever behavior of plain cached campaigns).
    pub(crate) fn release(&self, key: &TraceKey) {
        let mut pins = self.pins.lock().unwrap();
        if let Some(n) = pins.get_mut(key) {
            *n -= 1;
            if *n == 0 {
                pins.remove(key);
                self.entries.lock().unwrap().remove(key);
            }
        }
    }

    fn get_or_record(
        &self,
        key: TraceKey,
        record: impl FnOnce() -> Result<CleanRun>,
    ) -> Result<Arc<CleanRun>> {
        let slot = {
            let mut map = self.entries.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        let mut recorded = false;
        let out = slot.get_or_init(|| {
            recorded = true;
            record().map(Arc::new).map_err(|e| e.to_string())
        });
        if recorded {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        match out {
            Ok(clean) => Ok(Arc::clone(clean)),
            // The error type is flattened through the cache (errors are
            // not `Clone`); recording errors are simulation-level.
            Err(e) => Err(Error::Sim(e.clone())),
        }
    }
}

// ------------------------------------------------ shared cell machinery

/// Deterministic batch layout of one campaign: a pure function of the
/// configuration (and, for the adaptive stop rule, the merged counts so
/// far) — never of thread layout or scheduling. Extracted so the
/// single-campaign driver and the sweep's grid-wide scheduler run the
/// *same* schedule code and cannot drift apart.
pub(crate) struct BatchSchedule {
    pub(crate) adaptive: bool,
    pub(crate) cap: u64,
    pub(crate) batch_size: u64,
    pub(crate) min_floor: u64,
}

impl BatchSchedule {
    pub(crate) fn of(config: &CampaignConfig) -> Self {
        let adaptive = config.precision_target > 0.0;
        let cap = if adaptive && config.max_injections > 0 {
            config.max_injections
        } else {
            config.injections
        };
        let batch_size = if !adaptive {
            cap
        } else if config.batch_size > 0 {
            config.batch_size.min(cap).max(1)
        } else {
            (cap / 16).clamp(100, 10_000).min(cap).max(1)
        };
        let min_floor = if config.min_injections > 0 {
            config.min_injections.min(cap)
        } else {
            batch_size
        };
        Self {
            adaptive,
            cap,
            batch_size,
            min_floor,
        }
    }

    /// Size of the batch starting at injection `start` (0 = complete).
    pub(crate) fn batch_at(&self, start: u64) -> u64 {
        self.batch_size.min(self.cap - start)
    }

    /// The decision after merging the batch that ended at `start`:
    /// true = open another batch.
    pub(crate) fn continues(&self, start: u64, result: &CampaignResult, target: f64) -> bool {
        if !self.adaptive || start >= self.cap {
            return false;
        }
        !(start >= self.min_floor && result.meets_precision(target))
    }

    /// The final early-stop flag once no further batch will run.
    pub(crate) fn stopped_early(&self, start: u64, result: &CampaignResult, target: f64) -> bool {
        self.adaptive && start < self.cap && result.meets_precision(target)
    }
}

/// Worker-local reusable buffers of the injection hot loop: the sampled
/// and derated plan lists plus the fault context. One per worker thread;
/// steady-state injections allocate nothing through them.
pub(crate) struct InjectScratch {
    plans: Vec<FaultPlan>,
    live: Vec<FaultPlan>,
    fctx: FaultCtx,
    /// Window-coalescing order buffer of the two-level engine:
    /// `(base checkpoint index, injection index, pool offset, pool len)`
    /// per live injection of the current chunk, sorted so injections
    /// restoring the same checkpoint run back to back.
    tl_order: Vec<(u32, u64, u32, u32)>,
    /// Backing pool for the coalesced chunk's derated plan lists.
    tl_pool: Vec<FaultPlan>,
}

impl InjectScratch {
    pub(crate) fn new(faults_per_run: usize) -> Self {
        Self {
            plans: Vec::with_capacity(faults_per_run),
            live: Vec::with_capacity(faults_per_run),
            fctx: FaultCtx::clean(),
            tl_order: Vec::new(),
            tl_pool: Vec::new(),
        }
    }
}

/// Everything immutable a campaign's workers share: the configuration,
/// the fault-site registry, the golden result and the clean-run
/// artifacts. The single-campaign driver borrows one on the stack; the
/// sweep's grid scheduler hands `Arc<CellCtx>`s to its worker pool.
pub(crate) struct CellCtx {
    pub(crate) config: CampaignConfig,
    pub(crate) registry: FaultRegistry,
    pub(crate) golden: Mat,
    pub(crate) clean: Arc<CleanRun>,
}

impl CellCtx {
    /// Validate the configuration, then build the shared state: stage
    /// the workload and record the reference trace — or adopt both from
    /// `cache` when another campaign with the same clean-run identity
    /// already recorded them.
    pub(crate) fn prepare(
        config: &CampaignConfig,
        problem: &GemmProblem,
        cache: Option<&TraceCache>,
    ) -> Result<CellCtx> {
        if problem.spec != config.spec {
            return Err(Error::Config(format!(
                "campaign spec ({},{},{}) does not match the supplied problem ({},{},{})",
                config.spec.m, config.spec.n, config.spec.k,
                problem.spec.m, problem.spec.n, problem.spec.k
            )));
        }
        if config.faults_per_run == 0 {
            return Err(Error::Config("campaign needs at least one fault per run".into()));
        }
        if config.faults_per_run > crate::fault::MAX_PLANS_PER_RUN {
            return Err(Error::Config(format!(
                "at most {} faults per run",
                crate::fault::MAX_PLANS_PER_RUN
            )));
        }
        if !config.precision_target.is_finite() || config.precision_target < 0.0 {
            return Err(Error::Config(
                "campaign precision target must be finite and >= 0".into(),
            ));
        }
        if !config.confidence.is_finite() || config.confidence <= 0.0 || config.confidence >= 1.0 {
            return Err(Error::Config(format!(
                "campaign confidence must be in (0, 1), got {}",
                config.confidence
            )));
        }
        if config.two_level && !config.fast_forward {
            return Err(Error::Config(
                "the two-level engine is the fast-forward engine's functional level — \
                 it requires fast_forward (cannot combine with the direct engine)"
                    .into(),
            ));
        }
        if !config.cfg.op.is_linear() && config.protection.has_abft_checksums() {
            return Err(Error::Config(format!(
                "op '{}' breaks the ABFT checksum identity (only the linear 'mul' \
                 reduction preserves row/column sums) — use a non-ABFT protection level",
                config.cfg.op.name()
            )));
        }
        if config.cfg.format.is_fp8() && config.protection.has_online_abft() {
            return Err(Error::Config(format!(
                "format '{}' cannot run online ABFT: the dual-plane residuals are exact \
                 only on the FP16 path — use plain 'abft' or a lower protection level",
                config.cfg.format.name()
            )));
        }
        let registry = FaultRegistry::new(config.cfg, config.protection);
        if config.stratify {
            let sched = BatchSchedule::of(config);
            let active = (0..registry.n_strata())
                .filter(|&s| registry.stratum_len(s) > 0)
                .count() as u64;
            if sched.batch_size < active {
                return Err(Error::Config(format!(
                    "stratified campaign needs a batch of at least {active} injections \
                     (one per populated stratum)"
                )));
            }
        }
        let golden = problem.golden_z_for(config.cfg.format, config.cfg.op);
        let clean = match cache {
            Some(c) => c.get_or_record(TraceKey::of(config, problem), || {
                Campaign::record_clean_run(config, problem, &golden)
            })?,
            None => Arc::new(Campaign::record_clean_run(config, problem, &golden)?),
        };
        Ok(CellCtx {
            config: config.clone(),
            registry,
            golden,
            clean,
        })
    }

    pub(crate) fn schedule(&self) -> BatchSchedule {
        BatchSchedule::of(&self.config)
    }

    /// An empty result with the per-stratum tally slots laid out (when
    /// stratified).
    pub(crate) fn init_result(&self) -> CampaignResult {
        let mut result = CampaignResult::empty(self.config.clone());
        if self.config.stratify {
            result.strata = (0..self.registry.n_strata())
                .map(|s| StratumStats {
                    name: FaultRegistry::stratum_name(s),
                    share: self.registry.stratum_share(s),
                    n: 0,
                    outcomes: [0; 4],
                })
                .collect();
        }
        result
    }

    /// Neyman-style allocation of one batch over the registry's strata:
    /// scores `W_h · s_h` with `s_h = sqrt(p̃_h(1−p̃_h))` on the rate of
    /// the configured [`StratifyObjective`] (functional errors by
    /// default), Laplace-smoothed so an error-free stratum keeps a small
    /// score and a never-sampled stratum counts as maximally uncertain;
    /// floored at `batch / (8·H)` so rare strata are never starved.
    /// Deterministic: a pure function of the merged counts so far.
    pub(crate) fn allocate(&self, result: &CampaignResult, batch: u64) -> Vec<u64> {
        let objective = self.config.stratify_on;
        let mut scores = vec![0.0f64; self.registry.n_strata()];
        for (s, score) in scores.iter_mut().enumerate() {
            if self.registry.stratum_len(s) == 0 {
                continue;
            }
            let st = &result.strata[s];
            let sd = if st.n == 0 {
                0.5
            } else {
                let k = objective.count_in(&st.outcomes) as f64;
                let pt = (k + 1.0) / (st.n as f64 + 2.0);
                (pt * (1.0 - pt)).sqrt()
            };
            *score = st.share * sd;
        }
        let active = scores.iter().filter(|&&x| x > 0.0).count() as u64;
        let floor = (batch / (8 * active.max(1))).max(1);
        neyman_allocation(&scores, batch, floor)
    }

    /// One worker's chunk of a batch: injections `[lo, hi)` on the
    /// caller's scratch `System`, returning the local tally plus
    /// per-stratum outcome counts (all zeros when unstratified).
    ///
    /// The hot loop is zero-copy: the shared pristine image is adopted
    /// into the worker's existing TCDM buffers (`System::restore_from`),
    /// plan sampling, derating and the fault context all run through
    /// reusable scratch, and the fast-forward digest probes hash in
    /// place — a steady-state injection performs no heap allocation in
    /// the restore/plan/digest machinery. Thread chunking never
    /// influences the drawn plans: injection `i`'s RNG is seeded by its
    /// global index, and its stratum (if any) by the batch schedule.
    pub(crate) fn run_chunk(
        &self,
        sys: &mut System,
        scratch: &mut InjectScratch,
        assign: Option<&BatchAssign>,
        lo: u64,
        hi: u64,
    ) -> Result<(CampaignResult, Vec<[u64; 4]>)> {
        let config = &self.config;
        let clean = self.clean.as_ref();
        let trace = clean.trace.as_ref();
        let mut local = CampaignResult::empty(config.clone());
        let mut local_strata = vec![[0u64; 4]; self.registry.n_strata()];
        // Adopt the campaign's shared pristine TCDM image into the
        // worker's existing buffers — staging ran exactly once per
        // clean-run identity, and the adoption is a `copy_from_slice`,
        // not a clone (§Perf: staging dominates per-run cost on the
        // small Table-1 workload).
        sys.restore_from(&clean.pristine);
        if let Some(tr) = trace.filter(|_| config.two_level && config.tl_coalesce) {
            self.run_chunk_tl_coalesced(
                sys,
                scratch,
                assign,
                lo,
                hi,
                tr,
                &mut local,
                &mut local_strata,
            )?;
            return Ok((local, local_strata));
        }
        for i in lo..hi {
            let stratum = assign.map(|a| a.stratum_of(i));
            self.draw_plans(i, stratum, scratch);
            if scratch.live.is_empty() {
                local.add(Outcome::CorrectNoRetry, 0);
                if let Some(s) = stratum {
                    local_strata[s][Outcome::CorrectNoRetry.index()] += 1;
                }
                continue;
            }
            let report = match trace {
                // Two-level path: functional fast-forward plus mid-
                // segment convergence probes against the instrumented
                // trace (bit-identical results; see
                // `System::run_staged_with_faults_tl`).
                Some(tr) if config.two_level => sys.run_staged_with_faults_tl_scratch(
                    &clean.layout,
                    config.mode,
                    &scratch.live,
                    tr,
                    &clean.pristine,
                    &mut scratch.fctx,
                )?,
                // Fast path: checkpoint restore + convergence early-exit
                // (bit-identical results; see
                // `System::run_staged_with_faults_ff`). The restore is
                // internal to the call.
                Some(tr) => sys.run_staged_with_faults_ff_scratch(
                    &clean.layout,
                    config.mode,
                    &scratch.live,
                    tr,
                    &clean.pristine,
                    &mut scratch.fctx,
                )?,
                // Direct path: undo the previous run's writes and
                // re-step the whole workload from cycle 0.
                None => {
                    sys.tcdm.restore_from(&clean.pristine);
                    sys.redmule.reset();
                    sys.run_staged_with_faults_scratch(
                        &clean.layout,
                        config.mode,
                        &scratch.live,
                        &mut scratch.fctx,
                    )?
                }
            };
            let outcome = classify(&report, &self.golden);
            local.add(outcome, report.faults_applied);
            if let Some(info) = report.abft {
                local.corrections += info.corrections as u64;
                local.band_recomputes += info.band_recomputes as u64;
            }
            if let Some(s) = stratum {
                local_strata[s][outcome.index()] += 1;
            }
        }
        Ok((local, local_strata))
    }

    /// Sample injection `i`'s fault plans into `scratch.plans` and the
    /// derated (latched) subset into `scratch.live`. The stream is
    /// seeded by the global injection index alone and every engine path
    /// consumes it identically, so thread chunking, window coalescing
    /// and execution order can never perturb the drawn plans.
    fn draw_plans(&self, i: u64, stratum: Option<usize>, scratch: &mut InjectScratch) {
        use crate::fault::registry::derating;
        let config = &self.config;
        let clean = self.clean.as_ref();
        // Per-injection RNG: deterministic regardless of thread
        // layout, in its own domain so no index can replay the
        // problem-generation stream.
        let mut rng = Xoshiro256::new(injection_seed(config.seed, i));
        match stratum {
            Some(s) => self.registry.sample_plans_in_stratum_into(
                clean.horizon,
                config.faults_per_run,
                config.fault_model,
                s,
                &mut rng,
                &mut scratch.plans,
            ),
            None => self.registry.sample_plans_into(
                clean.horizon,
                config.faults_per_run,
                config.fault_model,
                &mut rng,
                &mut scratch.plans,
            ),
        }
        // Masking derate (see fault::registry::derating): an
        // un-latched pulse is a clean run by construction — the
        // fault-free execution was verified against golden above, so
        // skip the simulation when nothing latches. A burst is one
        // physical event (one latch draw for the whole plan);
        // independent faults latch independently.
        scratch.live.clear();
        match config.fault_model {
            FaultModel::Burst | FaultModel::SiteBurst => {
                // One physical event, ONE latch draw — compared per
                // plan, so a site burst spanning sites of mixed kinds
                // stays correlated while each site keeps its own
                // masking factor. A single-kind burst (always true
                // for `Burst`, whose plans share one site) latches
                // all-or-nothing as before.
                let u = rng.next_f64();
                for &plan in &scratch.plans {
                    if u < derating::for_kind(plan.kind) {
                        scratch.live.push(plan);
                    }
                }
            }
            FaultModel::Independent => {
                for &plan in &scratch.plans {
                    if rng.next_f64() < derating::for_kind(plan.kind) {
                        scratch.live.push(plan);
                    }
                }
            }
        }
    }

    /// Coalesced two-level chunk: pass 1 draws every injection's plans
    /// (tallying masked runs immediately) and pools the live plan lists
    /// keyed by the reference checkpoint their fault windows restore
    /// from; pass 2 runs the pool grouped by checkpoint, so adjacent
    /// windows rewind the TCDM with [`Tcdm::undo_to_watermark`] (undo
    /// only the previous window's writes) instead of a full pristine
    /// restore + delta replay each. Outcome tallies are additive and
    /// plan streams `(seed, index)`-pure, so the execution reorder is
    /// invisible in every count — `tests/twolevel.rs` A/B-pins the
    /// coalesced engine against [`CampaignConfig::tl_coalesce`] `=
    /// false` byte for byte.
    #[allow(clippy::too_many_arguments)]
    fn run_chunk_tl_coalesced(
        &self,
        sys: &mut System,
        scratch: &mut InjectScratch,
        assign: Option<&BatchAssign>,
        lo: u64,
        hi: u64,
        trace: &RefTrace,
        local: &mut CampaignResult,
        local_strata: &mut [[u64; 4]],
    ) -> Result<()> {
        let config = &self.config;
        let clean = self.clean.as_ref();
        scratch.tl_order.clear();
        scratch.tl_pool.clear();
        for i in lo..hi {
            let stratum = assign.map(|a| a.stratum_of(i));
            self.draw_plans(i, stratum, scratch);
            if scratch.live.is_empty() {
                local.add(Outcome::CorrectNoRetry, 0);
                if let Some(s) = stratum {
                    local_strata[s][Outcome::CorrectNoRetry.index()] += 1;
                }
                continue;
            }
            let first = crate::fault::first_fault_cycle(&scratch.live)
                .expect("live plan list is nonempty");
            let base = trace.checkpoint_index_before(first) as u32;
            let start = scratch.tl_pool.len() as u32;
            scratch.tl_pool.extend_from_slice(&scratch.live);
            scratch
                .tl_order
                .push((base, i, start, scratch.live.len() as u32));
        }
        // Group on restored checkpoint, ascending injection index within
        // a group — a pure function of the drawn plans, so the grouping
        // is identical however the batch was chunked across workers.
        scratch.tl_order.sort_unstable();
        let mut restore_cache = None;
        let InjectScratch {
            tl_order,
            tl_pool,
            fctx,
            ..
        } = scratch;
        for &(_, i, start, len) in tl_order.iter() {
            let plans = &tl_pool[start as usize..(start + len) as usize];
            let report = sys.run_staged_with_faults_tl_cached(
                &clean.layout,
                config.mode,
                plans,
                trace,
                &clean.pristine,
                fctx,
                &mut restore_cache,
            )?;
            let outcome = classify(&report, &self.golden);
            local.add(outcome, report.faults_applied);
            if let Some(info) = report.abft {
                local.corrections += info.corrections as u64;
                local.band_recomputes += info.band_recomputes as u64;
            }
            if let Some(s) = assign.map(|a| a.stratum_of(i)) {
                local_strata[s][outcome.index()] += 1;
            }
        }
        Ok(())
    }
}

/// The campaign driver.
pub struct Campaign;

impl Campaign {
    /// A `System` built to the campaign's recovery + tolerance settings.
    pub(crate) fn system(config: &CampaignConfig) -> System {
        System::new(config.cfg, config.protection)
            .with_recovery(config.recovery)
            .with_abft_tolerance(config.abft_tol_factor)
    }

    /// The fault-free duration of the workload in the campaign's mode.
    /// The clean run must be bit-exact against golden — anything else
    /// means the build is broken and every classification would silently
    /// be poisoned, so this is a hard error (not a debug assertion).
    fn fault_free_horizon(
        config: &CampaignConfig,
        problem: &GemmProblem,
        golden: &Mat,
    ) -> Result<u64> {
        let mut sys = Self::system(config);
        let r = sys.run_gemm(problem, config.mode)?;
        if !r.z_matches(golden) {
            return Err(Error::Sim(format!(
                "fault-free {} run diverged from golden — campaign aborted",
                config.protection.name()
            )));
        }
        Ok(r.cycles)
    }

    /// Run a full campaign: `config.injections` independent fault-injected
    /// executions, chunked over `config.threads` worker threads. Fully
    /// deterministic for a given seed (thread count does not change the
    /// drawn plans — each injection's RNG is seeded by its index, in a
    /// domain-separated stream).
    pub fn run(config: &CampaignConfig) -> Result<CampaignResult> {
        let problem = GemmProblem::random(&config.spec, problem_seed(config.seed));
        Self::run_with_problem(config, &problem)
    }

    /// Record a campaign's clean run: stage the workload (once per
    /// clean-run identity — the DMA + ECC staging drive dominates setup
    /// cost), snapshot the pristine image, and run the fault-free
    /// horizon — instrumented with checkpoints on the fast-forward
    /// engine, validated bit-exact against golden either way. A pure
    /// function of [`TraceKey`], which is what makes the result safely
    /// cacheable across sweep cells.
    fn record_clean_run(
        config: &CampaignConfig,
        problem: &GemmProblem,
        golden: &Mat,
    ) -> Result<CleanRun> {
        let mut sys = Self::system(config);
        sys.redmule.reset();
        let layout = sys.stage(problem)?;
        let pristine = sys.tcdm.clone();
        let mut trace = None;
        let horizon = if config.fast_forward {
            sys.tcdm.enable_dirty_tracking();
            let recorded = if config.two_level {
                sys.record_reference_two_level(
                    &layout,
                    &pristine,
                    config.mode,
                    config.checkpoint_interval,
                )?
            } else {
                sys.record_reference(
                    &layout,
                    &pristine,
                    config.mode,
                    config.checkpoint_interval,
                )?
            };
            match recorded {
                Some(t) => {
                    if t.z.bits() != golden.bits() {
                        return Err(Error::Sim(format!(
                            "fault-free {} run diverged from golden — campaign aborted",
                            config.protection.name()
                        )));
                    }
                    let h = t.cycles;
                    trace = Some(t);
                    h
                }
                // Soft decline (an ABFT tolerance probe whose clean run
                // retries): direct engine, classic horizon run.
                None => Self::fault_free_horizon(config, problem, golden)?,
            }
        } else {
            Self::fault_free_horizon(config, problem, golden)?
        };
        Ok(CleanRun {
            layout,
            pristine,
            trace,
            horizon,
        })
    }

    /// Like [`Campaign::run`] with a caller-supplied workload: the sweep
    /// engine shares one problem instance (and hence one golden and one
    /// staged TCDM image per worker) across every cell of a shape, so
    /// protection / fault-count / tolerance columns are a controlled
    /// comparison on identical data.
    pub fn run_with_problem(
        config: &CampaignConfig,
        problem: &GemmProblem,
    ) -> Result<CampaignResult> {
        Self::run_with_problem_cached(config, problem, None)
    }

    /// [`Campaign::run_with_problem`] with an optional shared
    /// [`TraceCache`]: when another campaign with the same clean-run
    /// identity already recorded its reference trace and staged image,
    /// this campaign adopts them instead of re-recording — results are
    /// byte-identical either way (the recording is a pure function of
    /// the identity).
    pub fn run_with_problem_cached(
        config: &CampaignConfig,
        problem: &GemmProblem,
        cache: Option<&TraceCache>,
    ) -> Result<CampaignResult> {
        let started = std::time::Instant::now();
        let ctx = CellCtx::prepare(config, problem, cache)?;
        let sched = ctx.schedule();
        let mut result = ctx.init_result();
        // One `(System, InjectScratch)` arena per worker for the whole
        // campaign: batches reuse them instead of rebuilding a `System`
        // per worker per batch, so steady-state adaptive batches
        // allocate nothing. Safe because `run_chunk` stages the pristine
        // image into the system before every injection anyway.
        let mut arenas: Vec<(System, InjectScratch)> = (0..config.threads.max(1))
            .map(|_| {
                (
                    Campaign::system(config),
                    InjectScratch::new(config.faults_per_run),
                )
            })
            .collect();
        // ---- Deterministic batch loop (the adaptive engine). A
        // fixed-budget campaign is the degenerate single-batch case, so
        // both paths share one worker loop and one plan-stream layout.
        let mut start = 0u64;
        loop {
            let size = sched.batch_at(start);
            if size == 0 {
                break;
            }
            let assign = if config.stratify {
                Some(BatchAssign::new(start, &ctx.allocate(&result, size)))
            } else {
                None
            };
            Self::run_batch(
                &ctx,
                assign.as_ref(),
                start,
                start + size,
                &mut arenas,
                &mut result,
            )?;
            start += size;
            result.batches += 1;
            if !sched.continues(start, &result, config.precision_target) {
                break;
            }
        }
        result.stopped_early = sched.stopped_early(start, &result, config.precision_target);
        result.wall_seconds = started.elapsed().as_secs_f64();
        Ok(result)
    }

    /// Run injections `[lo_all, hi_all)` as one deterministic batch,
    /// fanned over the configured worker threads, folding outcome counts
    /// (and per-stratum tallies) into `result`. Thread chunking never
    /// influences the drawn plans — injection `i`'s RNG is seeded by its
    /// global index, and its stratum (if any) by the batch schedule.
    fn run_batch(
        ctx: &CellCtx,
        assign: Option<&BatchAssign>,
        lo_all: u64,
        hi_all: u64,
        arenas: &mut [(System, InjectScratch)],
        result: &mut CampaignResult,
    ) -> Result<()> {
        let threads = arenas.len().max(1);
        let chunk = (hi_all - lo_all).div_ceil(threads as u64).max(1);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for (t, arena) in arenas.iter_mut().enumerate() {
                let lo = lo_all + t as u64 * chunk;
                let hi = (lo_all + (t as u64 + 1) * chunk).min(hi_all);
                if lo >= hi {
                    break;
                }
                handles.push(scope.spawn(move || {
                    let (sys, scratch) = arena;
                    ctx.run_chunk(sys, scratch, assign, lo, hi)
                }));
            }
            for h in handles {
                let (local, local_strata) = h.join().expect("campaign worker panicked")?;
                result.merge_counts(&local);
                result.merge_strata(&local_strata);
            }
            Ok(())
        })
    }
}

/// Deterministic stratum layout of one batch: the batch's injection
/// indices are laid out stratum-major (`alloc[0]` indices for stratum 0,
/// then stratum 1, …), so the stratum of a global injection index is a
/// pure function of the batch schedule — independent of worker threads.
pub(crate) struct BatchAssign {
    start: u64,
    /// Cumulative allocation bounds, as offsets within the batch.
    ends: Vec<u64>,
}

impl BatchAssign {
    pub(crate) fn new(start: u64, alloc: &[u64]) -> Self {
        let mut ends = Vec::with_capacity(alloc.len());
        let mut acc = 0u64;
        for &c in alloc {
            acc += c;
            ends.push(acc);
        }
        Self { start, ends }
    }

    pub(crate) fn stratum_of(&self, i: u64) -> usize {
        let off = i - self.start;
        self.ends.partition_point(|&e| e <= off)
    }
}

// ---------------------------------------------------------------- Table 1

/// The paper's three Table-1 protection columns.
pub const TABLE1_PROTECTIONS: [Protection; 3] =
    [Protection::Baseline, Protection::Data, Protection::Full];

/// The extended five-column comparison: the paper's three builds plus the
/// ABFT error-detecting-code point of the design space and the online
/// fused-checksum variant that corrects single errors in place.
pub const TABLE1_PROTECTIONS_ABFT: [Protection; 5] = [
    Protection::Baseline,
    Protection::Data,
    Protection::Full,
    Protection::Abft,
    Protection::AbftOnline,
];

/// Table 1 of the paper — one campaign column per protection build.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub columns: Vec<CampaignResult>,
}

impl Table1 {
    /// Run the paper's Table-1 campaign: baseline, data-protected, fully
    /// protected — `injections` single-fault runs each.
    pub fn run(injections: u64, seed: u64, threads: Option<usize>) -> Result<Self> {
        Self::run_protections(&TABLE1_PROTECTIONS, injections, seed, threads)
    }

    /// Run the extended comparison with the ABFT column appended.
    pub fn run_with_abft(injections: u64, seed: u64, threads: Option<usize>) -> Result<Self> {
        Self::run_protections(&TABLE1_PROTECTIONS_ABFT, injections, seed, threads)
    }

    /// Run one campaign column per listed protection build.
    pub fn run_protections(
        protections: &[Protection],
        injections: u64,
        seed: u64,
        threads: Option<usize>,
    ) -> Result<Self> {
        if protections.is_empty() {
            return Err(Error::Config("table1 needs at least one protection column".into()));
        }
        let mut columns = Vec::new();
        for &protection in protections {
            let mut cfg = CampaignConfig::table1(protection, injections, seed);
            if let Some(t) = threads {
                cfg.threads = t;
            }
            columns.push(Campaign::run(&cfg)?);
        }
        Ok(Self { columns })
    }

    fn column_of(&self, protection: Protection) -> Option<&CampaignResult> {
        self.columns.iter().find(|c| c.config.protection == protection)
    }

    /// Functional-error rate ratio of `column` vs. the baseline column.
    /// Returns `NaN` when the table has no baseline column to compare
    /// against (never silently substitutes another column).
    pub fn vulnerability_reduction_of(&self, column: usize) -> f64 {
        let Some(base) = self.column_of(Protection::Baseline) else {
            return f64::NAN;
        };
        let col = &self.columns[column];
        let base_rate = base.functional_errors() as f64 / base.total.max(1) as f64;
        let col_rate = col.functional_errors() as f64 / col.total.max(1) as f64;
        if col_rate == 0.0 {
            f64::INFINITY
        } else {
            base_rate / col_rate
        }
    }

    /// The paper's headline: vulnerability reduction of the data-protected
    /// build vs. baseline (functional-error rate ratio, ≈11× in §4.2).
    /// `NaN` when the table lacks a Data or Baseline column.
    pub fn vulnerability_reduction(&self) -> f64 {
        match self
            .columns
            .iter()
            .position(|c| c.config.protection == Protection::Data)
        {
            Some(idx) => self.vulnerability_reduction_of(idx),
            None => f64::NAN,
        }
    }

    /// Column header for a protection build.
    fn header(p: Protection) -> &'static str {
        match p {
            Protection::Baseline => "Baseline",
            Protection::Data => "Data Protection",
            Protection::Full => "Full Protection",
            Protection::PerCe => "Per-CE [8]",
            Protection::Abft => "ABFT Checksums",
            Protection::AbftOnline => "Online ABFT",
        }
    }

    /// Published Table-1 cells for a protection build (rows: correct,
    /// w/o retry, with retry, functional error, incorrect, timeout).
    /// Builds outside the paper's table have no published column.
    fn published_cells(p: Protection) -> [&'static str; 6] {
        match p {
            Protection::Baseline => {
                ["92.92 %", "92.92 %", "0.00 %", "7.08 %", "6.97 %", "0.11 %"]
            }
            Protection::Data => {
                ["99.36 %", "88.01 %", "11.35 %", "0.65 %", "0.46 %", "0.19 %"]
            }
            Protection::Full => [
                ">99.9997 %",
                "87.4457 %",
                "12.5543 %",
                "<0.0003 %",
                "<0.0003 %",
                "<0.0003 %",
            ],
            _ => ["-", "-", "-", "-", "-", "-"],
        }
    }

    /// Render the paper's Table 1 with our measured numbers (plus the
    /// published values alongside for comparison), one column per
    /// campaign build.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Table 1 — fault-injection results ({} injections per column, seed {})\n",
            self.columns[0].total, self.columns[0].config.seed
        ));
        s.push_str(&format!("{:<24}", ""));
        for c in &self.columns {
            s.push_str(&format!(" {:>22}", Self::header(c.config.protection)));
        }
        s.push('\n');
        let cell = |c: &CampaignResult, count: u64, upper_if_zero: bool| -> String {
            if upper_if_zero && count == 0 {
                format!("<{:.4} %", c.conservative_upper(0) * 100.0)
            } else {
                c.rate(count).table1_cell()
            }
        };
        let rows: Vec<(&str, Vec<String>)> = vec![
            (
                "Correct Termination",
                self.columns.iter().map(|c| cell(c, c.correct(), false)).collect(),
            ),
            (
                "  w/o Retry",
                self.columns
                    .iter()
                    .map(|c| cell(c, c.correct_no_retry, false))
                    .collect(),
            ),
            (
                "  with Retry",
                self.columns
                    .iter()
                    .map(|c| cell(c, c.correct_with_retry, false))
                    .collect(),
            ),
            (
                "Functional Error",
                self.columns
                    .iter()
                    .map(|c| cell(c, c.functional_errors(), true))
                    .collect(),
            ),
            (
                "  Incorrect",
                self.columns.iter().map(|c| cell(c, c.incorrect, true)).collect(),
            ),
            (
                "  Timeout",
                self.columns.iter().map(|c| cell(c, c.timeout, true)).collect(),
            ),
        ];
        for (i, (name, cells)) in rows.iter().enumerate() {
            s.push_str(&format!("{:<24}", name));
            for c in cells {
                s.push_str(&format!(" {:>22}", c));
            }
            s.push('\n');
            s.push_str(&format!("{:<24}", format!("  [paper: {}]", name.trim())));
            for c in &self.columns {
                s.push_str(&format!(
                    " {:>22}",
                    Self::published_cells(c.config.protection)[i]
                ));
            }
            s.push('\n');
        }
        // Area row, from the GE model.
        use crate::area::{area_report, published};
        let base = area_report(RedMuleConfig::paper(), Protection::Baseline);
        s.push_str(&format!("{:<24}", "Area Overhead (model)"));
        for c in &self.columns {
            let r = area_report(c.config.cfg, c.config.protection);
            s.push_str(&format!(" {:>21.1} %", r.overhead_vs(&base)));
        }
        s.push('\n');
        s.push_str(&format!("{:<24}", "  [paper]"));
        for c in &self.columns {
            let p = match c.config.protection {
                Protection::Baseline => "0.0 %".to_string(),
                Protection::Data => format!("{:.1} %", published::DATA_OVERHEAD_PCT),
                Protection::Full => format!("{:.1} %", published::FULL_OVERHEAD_PCT),
                _ => "-".to_string(),
            };
            s.push_str(&format!(" {:>22}", p));
        }
        s.push('\n');
        s.push('\n');
        if self.column_of(Protection::Baseline).is_some() {
            for (i, c) in self.columns.iter().enumerate() {
                if c.config.protection == Protection::Baseline {
                    continue;
                }
                let note = match c.config.protection {
                    Protection::Data => "   [paper: 11x]",
                    _ => "",
                };
                let reduction = self.vulnerability_reduction_of(i);
                s.push_str(&format!(
                    "vulnerability reduction ({} vs baseline): {:.1}x{}\n",
                    c.config.protection.name(),
                    reduction,
                    note
                ));
            }
        }
        if let Some(full) = self.column_of(Protection::Full) {
            let k = full.functional_errors();
            s.push_str(&format!(
                "full protection: {} functional errors in {} injections \
                 (exact 95 % upper bound {:.2e}; paper convention <{:.5} %)\n",
                k,
                full.total,
                crate::util::stats::exact_upper95(k, full.total.max(1)),
                full.conservative_upper(k) * 100.0
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini(protection: Protection, n: u64) -> CampaignResult {
        let mut c = CampaignConfig::table1(protection, n, 2024);
        c.threads = 2;
        Campaign::run(&c).unwrap()
    }

    #[test]
    fn rng_streams_are_domain_separated_at_the_old_collision_index() {
        // Regression for the pre-PR-2 stream collision: the problem was
        // seeded with `mix64(seed, 0xC0FFEE)` while injection `i` used
        // `mix64(seed, i)`, so injection 12,648,430 (0xC0FFEE) replayed
        // the problem-generation stream verbatim and its fault plan was
        // correlated with the workload data. Under the domain-separated
        // derivation the two streams must differ — at the old collision
        // index and around it — for any seed.
        for seed in [0u64, 1, 7, 2024, 2025, 0xBEEF, 0xDEAD_BEEF] {
            for index in [0xC0FFEEu64, 0, 1, 0xC0FFEF] {
                let p = problem_seed(seed);
                let i = injection_seed(seed, index);
                assert_ne!(p, i, "seed {seed}: streams collide at index {index:#X}");
                // The full generator outputs must diverge too, not just
                // the derived seeds.
                let mut a = Xoshiro256::new(p);
                let mut b = Xoshiro256::new(i);
                let aw: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
                let bw: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
                assert_ne!(aw, bw, "seed {seed}, index {index:#X}: streams replay");
            }
        }
    }

    #[test]
    fn multi_fault_campaigns_are_deterministic_across_thread_counts() {
        for (faults, model) in [
            (2usize, FaultModel::Independent),
            (3, FaultModel::Independent),
            (3, FaultModel::Burst),
        ] {
            let mut c1 = CampaignConfig::table1(Protection::Data, 150, 9);
            c1.faults_per_run = faults;
            c1.fault_model = model;
            c1.threads = 1;
            let mut c4 = c1.clone();
            c4.threads = 4;
            let r1 = Campaign::run(&c1).unwrap();
            let r4 = Campaign::run(&c4).unwrap();
            let t1 = (r1.correct_no_retry, r1.correct_with_retry, r1.incorrect, r1.timeout);
            let t4 = (r4.correct_no_retry, r4.correct_with_retry, r4.incorrect, r4.timeout);
            assert_eq!(t1, t4, "{faults} faults / {model:?}");
            assert_eq!(r1.applied, r4.applied, "{faults} faults / {model:?}");
            assert_eq!(
                r1.faults_applied, r4.faults_applied,
                "{faults} faults / {model:?}"
            );
            assert_eq!(r1.total, 150);
        }
    }

    #[test]
    fn multi_fault_runs_stress_the_protection_harder() {
        // More simultaneous faults cannot make the unprotected build
        // healthier: at equal injection counts the 3-fault campaign must
        // apply at least as many faults and produce at least as many
        // functional errors (statistically, with a deterministic seed).
        let n = 800;
        let one = mini(Protection::Baseline, n);
        let mut cfg = CampaignConfig::table1(Protection::Baseline, n, 2024);
        cfg.threads = 2;
        cfg.faults_per_run = 3;
        let three = Campaign::run(&cfg).unwrap();
        assert!(three.faults_applied > one.faults_applied);
        assert!(
            three.functional_errors() >= one.functional_errors(),
            "3-fault {} vs 1-fault {}",
            three.functional_errors(),
            one.functional_errors()
        );
    }

    #[test]
    fn campaign_is_deterministic_across_thread_counts() {
        // Covers both a replicated column and the ABFT column: the ABFT
        // writeback verification + band recovery must be as thread-layout
        // independent as the abort/retry flow.
        for protection in [Protection::Data, Protection::Abft] {
            let mut c1 = CampaignConfig::table1(protection, 200, 7);
            c1.threads = 1;
            let mut c4 = c1.clone();
            c4.threads = 4;
            let r1 = Campaign::run(&c1).unwrap();
            let r4 = Campaign::run(&c4).unwrap();
            assert_eq!(r1.correct_no_retry, r4.correct_no_retry, "{protection:?}");
            assert_eq!(r1.correct_with_retry, r4.correct_with_retry, "{protection:?}");
            assert_eq!(r1.incorrect, r4.incorrect, "{protection:?}");
            assert_eq!(r1.timeout, r4.timeout, "{protection:?}");
            assert_eq!(r1.applied, r4.applied, "{protection:?}");
        }
    }

    #[test]
    fn mini_table1_regression_pins_counts_across_all_four_modes() {
        // Mini-Table-1 regression pin, in two layers:
        //
        // 1. For a fixed seed the outcome 4-tuple of every protection
        //    mode must be identical across runs and thread layouts (the
        //    campaign derives each injection from (seed, index) alone).
        // 2. When the committed pin file exists, the counts are
        //    additionally pinned to its literals, so ANY behavioral
        //    change to sampling, the engine or classification fails
        //    with a diff. On a fresh tree without the file the measured
        //    baseline is printed, ready to commit.
        let pin_path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/mini_table1_pins.txt");
        let mut measured = String::new();
        for protection in [
            Protection::Baseline,
            Protection::Data,
            Protection::Full,
            Protection::Abft,
        ] {
            let mut a_cfg = CampaignConfig::table1(protection, 400, 0xBEEF);
            a_cfg.threads = 2;
            let mut b_cfg = a_cfg.clone();
            b_cfg.threads = 5;
            let a = Campaign::run(&a_cfg).unwrap();
            let b = Campaign::run(&b_cfg).unwrap();
            let counts = (a.correct_no_retry, a.correct_with_retry, a.incorrect, a.timeout);
            assert_eq!(
                counts,
                (b.correct_no_retry, b.correct_with_retry, b.incorrect, b.timeout),
                "{protection:?} counts must be reproducible"
            );
            measured.push_str(&format!(
                "{} {} {} {} {}\n",
                protection.name(),
                a.correct_no_retry,
                a.correct_with_retry,
                a.incorrect,
                a.timeout
            ));
            assert_eq!(a.total, 400);
            assert_eq!(a.correct() + a.functional_errors(), a.total);
            match protection {
                Protection::Baseline => {
                    assert_eq!(a.correct_with_retry, 0, "baseline cannot retry");
                    assert!(a.functional_errors() > 0, "baseline must show errors");
                }
                Protection::Full => {
                    assert_eq!(a.functional_errors(), 0, "full protection holds");
                }
                _ => {}
            }
        }
        if std::env::var_os("REDMULE_UPDATE_PINS").is_some() {
            // Re-baselining hook: any environment with a toolchain can
            // record the pin file in one command (see tests/data/README.md):
            //   REDMULE_UPDATE_PINS=1 cargo test --release -q mini_table1
            std::fs::write(pin_path, &measured)
                .unwrap_or_else(|e| panic!("cannot write {pin_path}: {e}"));
            eprintln!("mini_table1 pins recorded to {pin_path}:\n{measured}");
            return;
        }
        match std::fs::read_to_string(pin_path) {
            Ok(expected) => assert_eq!(
                measured, expected,
                "outcome counts diverged from the pinned baseline in {pin_path}"
            ),
            Err(_) => eprintln!(
                "mini_table1 pins not found; commit the measured baseline to \
                 {pin_path}:\n{measured}"
            ),
        }
    }

    #[test]
    fn abft_reduces_functional_errors_vs_baseline() {
        let n = 2_000;
        let base = mini(Protection::Baseline, n);
        let abft = mini(Protection::Abft, n);
        assert!(
            abft.functional_errors() < base.functional_errors(),
            "abft must measurably cut functional errors: {} vs {}",
            abft.functional_errors(),
            base.functional_errors()
        );
        assert!(
            abft.correct_with_retry > 0,
            "checksum detections must drive recoveries"
        );
        // The coverage ordering of the design space: checksums beat
        // nothing, replication beats checksums.
        let data = mini(Protection::Data, n);
        assert!(data.functional_errors() <= abft.functional_errors());
    }

    #[test]
    fn counts_sum_to_total() {
        let r = mini(Protection::Baseline, 300);
        assert_eq!(r.total, 300);
        assert_eq!(
            r.correct_no_retry + r.correct_with_retry + r.incorrect + r.timeout,
            r.total
        );
    }

    #[test]
    fn baseline_never_retries() {
        let r = mini(Protection::Baseline, 300);
        assert_eq!(r.correct_with_retry, 0, "baseline has no detection hardware");
    }

    #[test]
    fn data_protection_reduces_functional_errors() {
        let n = 1500;
        let base = mini(Protection::Baseline, n);
        let data = mini(Protection::Data, n);
        assert!(
            data.functional_errors() * 3 < base.functional_errors().max(1) * 2,
            "data protection must cut functional errors substantially: {} vs {}",
            data.functional_errors(),
            base.functional_errors()
        );
        assert!(data.correct_with_retry > 0, "retries must occur under faults");
    }

    #[test]
    fn full_protection_has_no_functional_errors_in_small_campaign() {
        let r = mini(Protection::Full, 1500);
        assert_eq!(
            r.functional_errors(),
            0,
            "full protection: incorrect={} timeout={}",
            r.incorrect,
            r.timeout
        );
        assert!(r.correct_with_retry > 0);
    }

    #[test]
    fn conservative_upper_bound_behaves_like_the_paper() {
        let r = mini(Protection::Full, 100);
        // 0 observed + 1 assumed over 100 runs: upper bound well under 6 %.
        let ub = r.conservative_upper(0);
        assert!(ub > 0.0 && ub < 0.06, "ub = {ub}");
    }

    #[test]
    fn fixed_budget_campaign_is_one_batch_and_never_early() {
        let r = mini(Protection::Baseline, 300);
        assert_eq!(r.batches, 1);
        assert!(!r.stopped_early);
        assert!(r.strata.is_empty());
        // Estimates on the fixed path are pooled and contain the rate.
        for o in OUTCOMES {
            let e = r.estimate_of(o);
            assert_eq!(e.count, r.count_of(o));
            assert_eq!(e.n, 300);
            assert!(e.ci_lo <= e.rate && e.rate <= e.ci_hi);
            assert!(e.exact_lo <= e.rate && e.rate <= e.exact_hi);
        }
        let fe = r.functional_error_estimate();
        assert_eq!(fe.count, r.functional_errors());
    }

    #[test]
    fn batch_assign_is_stratum_major_and_total() {
        let a = BatchAssign::new(100, &[3, 0, 4, 2, 0]);
        assert_eq!(a.stratum_of(100), 0);
        assert_eq!(a.stratum_of(102), 0);
        assert_eq!(a.stratum_of(103), 2, "empty stratum 1 is skipped");
        assert_eq!(a.stratum_of(106), 2);
        assert_eq!(a.stratum_of(107), 3);
        assert_eq!(a.stratum_of(108), 3);
    }

    #[test]
    fn invalid_precision_target_is_a_config_error() {
        for bad in [f64::NAN, f64::INFINITY, -0.01] {
            let mut c = CampaignConfig::table1(Protection::Baseline, 10, 1);
            c.precision_target = bad;
            assert!(
                matches!(Campaign::run(&c), Err(crate::Error::Config(_))),
                "precision {bad} must be rejected"
            );
        }
    }

    #[test]
    fn invalid_confidence_is_a_config_error() {
        for bad in [0.0, 1.0, -0.2, 1.5, f64::NAN, f64::INFINITY] {
            let mut c = CampaignConfig::table1(Protection::Baseline, 10, 1);
            c.confidence = bad;
            assert!(
                matches!(Campaign::run(&c), Err(crate::Error::Config(_))),
                "confidence {bad} must be rejected"
            );
        }
    }

    #[test]
    fn cached_clean_run_reproduces_the_uncached_campaign() {
        let problem = GemmProblem::random(&GemmSpec::paper_workload(), problem_seed(0xCAFE));
        let mut cfg = CampaignConfig::table1(Protection::Data, 150, 0xCAFE);
        cfg.threads = 2;
        let plain = Campaign::run_with_problem(&cfg, &problem).unwrap();
        let cache = TraceCache::new();
        let first = Campaign::run_with_problem_cached(&cfg, &problem, Some(&cache)).unwrap();
        assert_eq!(cache.misses(), 1, "first campaign records the trace");
        assert_eq!(cache.hits(), 0);
        // A second campaign with a different seed / fault count shares
        // the clean run (the identity excludes post-clean-run knobs) …
        let mut cfg2 = cfg.clone();
        cfg2.seed = 0xCAFE; // same seed → identical campaign
        cfg2.faults_per_run = 2;
        let _ = Campaign::run_with_problem_cached(&cfg2, &problem, Some(&cache)).unwrap();
        assert_eq!(cache.misses(), 1, "fault count is not part of the identity");
        assert_eq!(cache.hits(), 1);
        // … while a different tolerance factor records its own.
        let mut cfg3 = cfg.clone();
        cfg3.abft_tol_factor *= 2.0;
        let _ = Campaign::run_with_problem_cached(&cfg3, &problem, Some(&cache)).unwrap();
        assert_eq!(cache.misses(), 2, "tolerance is part of the identity");
        // Counts are byte-identical across all three engines.
        let t = |r: &CampaignResult| {
            (r.correct_no_retry, r.correct_with_retry, r.incorrect, r.timeout, r.applied)
        };
        assert_eq!(t(&plain), t(&first));
    }

    #[test]
    fn dirty_worker_arenas_reproduce_fresh_campaign_counts() {
        // Satellite of the arena hoist: the batch loop now reuses one
        // `(System, InjectScratch)` per worker across batches instead of
        // rebuilding them. Running the same injection range through
        // freshly-built arenas and again through the now-dirty ones must
        // give byte-identical counts — per-injection staging leaves no
        // state behind that can change a classification.
        let problem = GemmProblem::random(&GemmSpec::paper_workload(), problem_seed(0xA11));
        let mut cfg = CampaignConfig::table1(Protection::Abft, 120, 0xA11);
        cfg.threads = 3;
        let ctx = CellCtx::prepare(&cfg, &problem, None).unwrap();
        let mut arenas: Vec<(System, InjectScratch)> = (0..3)
            .map(|_| (Campaign::system(&cfg), InjectScratch::new(cfg.faults_per_run)))
            .collect();
        let mut fresh = ctx.init_result();
        Campaign::run_batch(&ctx, None, 0, 120, &mut arenas, &mut fresh).unwrap();
        let mut reused = ctx.init_result();
        Campaign::run_batch(&ctx, None, 0, 120, &mut arenas, &mut reused).unwrap();
        let t = |r: &CampaignResult| {
            (
                r.correct_no_retry,
                r.correct_with_retry,
                r.incorrect,
                r.timeout,
                r.applied,
                r.faults_applied,
                r.corrections,
                r.band_recomputes,
            )
        };
        assert_eq!(t(&fresh), t(&reused));
        // The end-to-end engine (which owns its arenas) must agree too.
        let whole = Campaign::run_with_problem(&cfg, &problem).unwrap();
        assert_eq!(t(&whole), t(&fresh));
    }

    #[test]
    fn classify_covers_all_paths() {
        use crate::cluster::RunReport;
        let golden = Mat::zeros(1, 1);
        let mut wrong = Mat::zeros(1, 1);
        wrong.set(0, 0, crate::fp::Fp16::ONE);
        let mk = |outcome, z: &Mat| RunReport {
            outcome,
            cycles: 1,
            config_cycles: 0,
            retries: 0,
            fault_causes: 0,
            irq_seen: false,
            faults_applied: 1,
            abft: None,
            z: z.clone(),
        };
        assert_eq!(
            classify(&mk(HostOutcome::Completed, &golden), &golden),
            Outcome::CorrectNoRetry
        );
        assert_eq!(
            classify(&mk(HostOutcome::CompletedAfterRetry, &golden), &golden),
            Outcome::CorrectWithRetry
        );
        assert_eq!(
            classify(&mk(HostOutcome::Completed, &wrong), &golden),
            Outcome::Incorrect
        );
        assert_eq!(
            classify(&mk(HostOutcome::CompletedAfterRetry, &wrong), &golden),
            Outcome::Incorrect
        );
        assert_eq!(
            classify(&mk(HostOutcome::TimedOut, &golden), &golden),
            Outcome::Timeout
        );
        assert_eq!(
            classify(&mk(HostOutcome::Abandoned, &golden), &golden),
            Outcome::Timeout
        );
    }
}
