//! Deterministic simulation substrate of the campaign service — the
//! FoundationDB idea in ~200 lines: one real thread, a virtual clock, a
//! total event order, and a faulty message layer whose every decision is
//! a pure function of a seed and a global message sequence number.
//!
//! Nothing here reads a wall clock or an OS scheduler, so a service run
//! is a pure function of `(jobs, ServiceConfig)` — replaying the same
//! seed replays the exact interleaving, including every dropped,
//! duplicated, delayed and reordered message and every worker crash.
//! That is what turns the service layer itself into a fault-injection
//! target with byte-exact invariants instead of a flaky integration
//! test.

use crate::campaign::stream_seed;
use crate::util::rng::Xoshiro256;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Message-layer fault decisions (drop / duplicate / per-copy delay) —
/// one RNG stream per global message sequence number.
pub const DOMAIN_SVC_MSG: u64 = 0x5245_444D_534D_5347; // "REDMSMSG"
/// Worker-crash decisions — one RNG stream per chunk execution.
pub const DOMAIN_SVC_CRASH: u64 = 0x5245_444D_5343_5253; // "REDMSCRS"
/// Requeue-backoff jitter — one RNG stream per (job, chunk, attempt).
pub const DOMAIN_SVC_JITTER: u64 = 0x5245_444D_534A_4954; // "REDMSJIT"
/// Random service-fault-plan sampling ([`ServiceFaultPlan::sample`]).
pub const DOMAIN_SVC_PLAN: u64 = 0x5245_444D_5350_4C4E; // "REDMSPLN"

/// The service layer's fault schedule: how hostile the simulated world
/// is to the job engine. All probabilities are per *decision* (one
/// message send, one chunk execution) and are drawn from domain-
/// separated streams, so the schedule perturbs nothing in the campaign
/// layer's plan or problem streams — which is exactly why merged counts
/// must come out byte-identical under every schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceFaultPlan {
    /// Probability a message copy is dropped in flight.
    pub drop_prob: f64,
    /// Probability a message is duplicated (a second independently
    /// delayed copy is delivered).
    pub dup_prob: f64,
    /// Per-copy uniform extra delay in `[0, delay_max]` virtual ticks —
    /// unequal delays are what reorder messages.
    pub delay_max: u64,
    /// Probability a worker process dies mid-chunk (its partial work and
    /// its `Done` are lost; the supervisor's timeout recovers the chunk).
    pub crash_prob: f64,
    /// Virtual ticks a crashed worker takes to restart.
    pub worker_restart: u64,
}

impl ServiceFaultPlan {
    /// A perfectly reliable world — the control arm every fault profile
    /// is diffed against.
    pub fn none() -> Self {
        Self {
            drop_prob: 0.0,
            dup_prob: 0.0,
            delay_max: 0,
            crash_prob: 0.0,
            worker_restart: 0,
        }
    }

    /// Lossy links: a third of all message copies vanish.
    pub fn drops() -> Self {
        Self {
            drop_prob: 1.0 / 3.0,
            ..Self::none()
        }
    }

    /// Duplicating + reordering links: a third of all messages arrive
    /// twice, every copy up to 32 ticks late.
    pub fn dups() -> Self {
        Self {
            dup_prob: 1.0 / 3.0,
            delay_max: 32,
            ..Self::none()
        }
    }

    /// Heavily delayed (and therefore reordered) links.
    pub fn delays() -> Self {
        Self {
            delay_max: 256,
            ..Self::none()
        }
    }

    /// Crash-prone workers: a quarter of chunk executions die mid-run.
    pub fn crashes() -> Self {
        Self {
            crash_prob: 0.25,
            worker_restart: 64,
            ..Self::none()
        }
    }

    /// Everything at once.
    pub fn chaos() -> Self {
        Self {
            drop_prob: 0.25,
            dup_prob: 0.25,
            delay_max: 64,
            crash_prob: 0.2,
            worker_restart: 48,
        }
    }

    /// A named profile (the CLI / CI matrix vocabulary).
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "none" => Self::none(),
            "drop" => Self::drops(),
            "dup" => Self::dups(),
            "delay" => Self::delays(),
            "crash" => Self::crashes(),
            "chaos" => Self::chaos(),
            _ => return None,
        })
    }

    /// A random schedule for the randomized invariant sweep: every
    /// probability capped well below 1 so forward progress stays almost
    /// sure, drawn from its own domain so schedules never correlate with
    /// the campaign streams of the jobs they torment.
    pub fn sample(seed: u64) -> Self {
        let mut rng = Xoshiro256::new(stream_seed(seed, DOMAIN_SVC_PLAN, 0));
        Self {
            drop_prob: rng.next_f64() * 0.35,
            dup_prob: rng.next_f64() * 0.35,
            delay_max: rng.below(96),
            crash_prob: rng.next_f64() * 0.3,
            worker_restart: 1 + rng.below(128),
        }
    }

    /// Configuration sanity: probabilities in `[0, 0.9]` (1.0 would make
    /// nontermination certain rather than measure-zero).
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("drop_prob", self.drop_prob),
            ("dup_prob", self.dup_prob),
            ("crash_prob", self.crash_prob),
        ] {
            if !(0.0..=0.9).contains(&p) || !p.is_finite() {
                return Err(format!("service fault plan {name} must be in [0, 0.9], got {p}"));
            }
        }
        Ok(())
    }
}

/// The fate of one message send: a pure function of `(seed, msg_seq)`,
/// never of RNG call order — two runs that send the same messages in the
/// same order see the same fates regardless of anything else the engine
/// drew in between.
#[derive(Debug, Clone, Copy)]
pub struct LinkFault {
    pub dropped: bool,
    pub duplicated: bool,
    /// Extra delay of the primary and (if duplicated) the second copy.
    pub delays: [u64; 2],
}

/// Draw message `msg_seq`'s fate under `plan`. The stream shape is fixed
/// (both delay draws always happen) so the decision layout can never
/// shift between schedule variants.
pub fn link_fault(seed: u64, plan: &ServiceFaultPlan, msg_seq: u64) -> LinkFault {
    let mut rng = Xoshiro256::new(stream_seed(seed, DOMAIN_SVC_MSG, msg_seq));
    let dropped = rng.next_f64() < plan.drop_prob;
    let duplicated = rng.next_f64() < plan.dup_prob;
    let bound = plan.delay_max.saturating_add(1);
    let delays = [rng.below(bound), rng.below(bound)];
    LinkFault {
        dropped,
        duplicated,
        delays,
    }
}

/// Crash draw for chunk execution `exec_seq`: `(died, ticks worked
/// before dying)` — the partial work is bounded by the chunk's full
/// cost, and the stream is again pure in the sequence number.
pub fn crash_fault(seed: u64, plan: &ServiceFaultPlan, exec_seq: u64, cost: u64) -> (bool, u64) {
    let mut rng = Xoshiro256::new(stream_seed(seed, DOMAIN_SVC_CRASH, exec_seq));
    let died = rng.next_f64() < plan.crash_prob;
    let worked = rng.below(cost.saturating_add(1));
    (died, worked)
}

struct Entry<E> {
    time: u64,
    seq: u64,
    ev: E,
}

// Total order on (time, seq) only — the payload needs no Ord, and the
// monotone sequence number makes the order total, so `BinaryHeap`'s
// unspecified tie handling can never surface: determinism is
// structural, not a testing artifact.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        (self.time, self.seq) == (other.time, other.seq)
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// The virtual clock and event queue: a discrete-event loop delivering
/// events in `(time, insertion sequence)` order. Time only moves when
/// an event is popped, so "now" is always the timestamp of the event
/// being handled.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
        }
    }

    /// The virtual time of the most recently popped event.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `ev` at absolute virtual time `time` (clamped to `now` —
    /// the past is immutable).
    pub fn push_at(&mut self, time: u64, ev: E) {
        let entry = Entry {
            time: time.max(self.now),
            seq: self.seq,
            ev,
        };
        self.seq += 1;
        self.heap.push(entry);
    }

    /// Schedule `ev` `delay` ticks from now (saturating).
    pub fn push_after(&mut self, delay: u64, ev: E) {
        self.push_at(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(u64, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_then_insertion_order() {
        let mut q = EventQueue::new();
        q.push_at(5, "b");
        q.push_at(3, "a");
        q.push_at(5, "c");
        q.push_at(0, "zero");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(0, "zero"), (3, "a"), (5, "b"), (5, "c")]);
    }

    #[test]
    fn the_clock_never_runs_backwards() {
        let mut q = EventQueue::new();
        q.push_at(10, ());
        assert_eq!(q.pop(), Some((10, ())));
        // An event scheduled "in the past" lands at now.
        q.push_at(3, ());
        assert_eq!(q.pop(), Some((10, ())));
        assert_eq!(q.now(), 10);
    }

    #[test]
    fn link_faults_are_pure_in_the_sequence_number() {
        let plan = ServiceFaultPlan::chaos();
        for msg in 0..64u64 {
            let a = link_fault(7, &plan, msg);
            let b = link_fault(7, &plan, msg);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.duplicated, b.duplicated);
            assert_eq!(a.delays, b.delays);
            assert!(a.delays[0] <= plan.delay_max && a.delays[1] <= plan.delay_max);
        }
    }

    #[test]
    fn named_profiles_round_trip() {
        for name in ["none", "drop", "dup", "delay", "crash", "chaos"] {
            let p = ServiceFaultPlan::by_name(name).expect(name);
            assert!(p.validate().is_ok(), "{name}");
        }
        assert!(ServiceFaultPlan::by_name("nope").is_none());
        assert!(ServiceFaultPlan::sample(99).validate().is_ok());
    }
}
