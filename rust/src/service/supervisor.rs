//! Supervision policy: how long a chunk attempt may run before it is
//! presumed dead, and how long a presumed-dead chunk waits before being
//! requeued.
//!
//! The backoff is bounded exponential with *seed-derived* jitter: the
//! jitter of `(job, chunk, attempt)` comes from its own domain-separated
//! RNG stream ([`crate::service::sim::DOMAIN_SVC_JITTER`]), never from a
//! wall clock — so a retry schedule is replayable, and the property
//! tests in `tests/service_sim.rs` can pin it (seed-pure, bounded by
//! `cap + jitter_max`, deterministic base component monotone in the
//! attempt number).

use super::sim::DOMAIN_SVC_JITTER;
use crate::campaign::stream_seed;
use crate::util::rng::{mix64, Xoshiro256};

/// Bounded exponential backoff with deterministic jitter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackoffPolicy {
    /// Delay of the first retry, in virtual ticks.
    pub base: u64,
    /// Hard ceiling on the exponential component.
    pub cap: u64,
    /// Jitter drawn uniformly from `[0, jitter_max]` on top of the
    /// exponential component (decorrelates retry storms).
    pub jitter_max: u64,
}

impl Default for BackoffPolicy {
    fn default() -> Self {
        Self {
            base: 8,
            cap: 4096,
            jitter_max: 16,
        }
    }
}

impl BackoffPolicy {
    /// The deterministic exponential component: `min(base << attempt,
    /// cap)`, saturating (never overflow-wraps back down). Monotone
    /// nondecreasing in `attempt` by construction.
    pub fn exp_component(&self, attempt: u32) -> u64 {
        if self.base == 0 {
            return 0;
        }
        let shifted = if attempt >= self.base.leading_zeros() {
            u64::MAX
        } else {
            self.base << attempt
        };
        shifted.min(self.cap)
    }

    /// The full requeue delay of `(job, chunk_tag, attempt)`: exponential
    /// component plus the attempt's own jittered stream. A pure function
    /// of its arguments — no clock, no shared RNG state.
    pub fn delay(&self, seed: u64, job: u64, chunk_tag: u64, attempt: u32) -> u64 {
        let exp = self.exp_component(attempt);
        if self.jitter_max == 0 {
            return exp;
        }
        let stream = stream_seed(seed, DOMAIN_SVC_JITTER, mix64(mix64(job, chunk_tag), attempt as u64));
        exp.saturating_add(Xoshiro256::new(stream).below(self.jitter_max.saturating_add(1)))
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.cap == 0 {
            return Err("backoff cap must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_component_is_monotone_capped_and_saturating() {
        let p = BackoffPolicy {
            base: 8,
            cap: 1 << 20,
            jitter_max: 0,
        };
        let mut prev = 0;
        for a in 0..=80u32 {
            let e = p.exp_component(a);
            assert!(e >= prev, "attempt {a}");
            assert!(e <= p.cap);
            prev = e;
        }
        assert_eq!(p.exp_component(200), p.cap, "deep attempts saturate at the cap");
    }

    #[test]
    fn delay_is_seed_pure_and_bounded() {
        let p = BackoffPolicy::default();
        for a in 0..12u32 {
            let d1 = p.delay(42, 3, 17, a);
            let d2 = p.delay(42, 3, 17, a);
            assert_eq!(d1, d2);
            assert!(d1 >= p.exp_component(a));
            assert!(d1 <= p.cap + p.jitter_max);
        }
        // Distinct chunks get distinct jitter streams (decorrelated
        // storms) under the same seed.
        let spread: std::collections::HashSet<u64> =
            (0..64u64).map(|c| p.delay(42, 3, c, 0)).collect();
        assert!(spread.len() > 1, "jitter must actually vary across chunks");
    }
}
