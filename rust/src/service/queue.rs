//! The service's ready-job queue: which job the dispatcher serves next.
//!
//! Ordering is `(priority descending, submission order ascending)` — a
//! pure function of the submitted jobs, never of timing — so the
//! scheduler cannot introduce nondeterminism even under a hostile
//! message schedule. The queue holds at most one entry per job (the
//! dispatcher re-inserts a job only while it still has ready chunks),
//! so there is no lazy-deletion ambiguity to reason about.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Max-heap over `(priority, Reverse(submission seq))`: highest priority
/// first, FIFO within a priority.
#[derive(Debug, Default)]
pub(crate) struct ReadyQueue {
    heap: BinaryHeap<(i32, Reverse<u64>, u64)>,
}

impl ReadyQueue {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    pub(crate) fn push(&mut self, priority: i32, submit_seq: u64, job: u64) {
        self.heap.push((priority, Reverse(submit_seq), job));
    }

    /// The next job to serve, by `(priority desc, submission asc)`.
    pub(crate) fn pop(&mut self) -> Option<u64> {
        self.heap.pop().map(|(_, _, job)| job)
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_then_submission_order() {
        let mut q = ReadyQueue::new();
        q.push(0, 0, 10);
        q.push(5, 1, 11);
        q.push(5, 2, 12);
        q.push(-3, 3, 13);
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![11, 12, 10, 13]);
        assert!(q.is_empty());
    }
}
