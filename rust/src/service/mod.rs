//! Campaign-as-a-service: a deterministic async job engine over the
//! campaign layer — submission, priorities, per-job cancellation,
//! streaming per-batch progress, a shared cross-job
//! [`TraceCache`], supervised workers with timeout, and bounded
//! exponential backoff with seed-derived jitter.
//!
//! There is no tokio and no OS thread pool in here: the engine runs on
//! the in-crate deterministic runtime of [`sim`] — one real thread, a
//! virtual clock, a totally ordered event queue, and a message layer
//! whose drop/duplicate/delay/reorder behavior (plus worker crashes) is
//! driven by a [`ServiceFaultPlan`] from the same domain-separated RNG
//! streams the campaign layer already uses. The service layer is
//! therefore itself a fault-injection target with *checkable*
//! invariants rather than a best-effort integration test:
//!
//! * **exactly-once termination** — every submitted job reaches exactly
//!   one terminal [`JobOutcome`], under every fault schedule;
//! * **byte-identical counts** — a completed job's
//!   [`CampaignResult`] count fields equal the single-threaded
//!   [`Campaign::run`](crate::campaign::Campaign::run) of the same
//!   configuration, byte for byte, because injection plans are
//!   `(seed, index)`-pure, chunk tallies are additive, and batch
//!   boundaries are pure functions of the merged counts — no lost and
//!   no double-counted injection survives the invariant;
//! * **cache drain** — every terminal job (completed, failed *or*
//!   cancelled) releases its [`TraceCache`] pin, so
//!   [`ServiceReport::trace_cache_resident`] is 0 after every run.
//!
//! # Exactly-once chunk accounting
//!
//! A batch is split into chunks; a chunk attempt is sent to a worker
//! over the faulty link, computed at delivery (results are index-pure,
//! so *when* a chunk computes is unobservable), and its tally returns
//! as a `Done` message. The dispatcher merges the **first** `Done` per
//! chunk and ignores the rest — a stale `Done` from a presumed-dead
//! attempt merges just as well as the retry's, because both carry the
//! identical deterministic tally. Timeouts are attempt-stamped, so a
//! late heartbeat can never kill a newer attempt; requeues back off
//! exponentially with per-`(job, chunk, attempt)` jitter streams
//! ([`BackoffPolicy`]).

pub mod sim;
pub mod supervisor;

mod queue;

pub use sim::ServiceFaultPlan;
pub use supervisor::BackoffPolicy;

use crate::campaign::sweep::WorkerArena;
use crate::campaign::{
    problem_seed, BatchAssign, BatchSchedule, CampaignConfig, CampaignResult, CellCtx, TraceCache,
    TraceKey,
};
use crate::golden::GemmProblem;
use crate::util::rng::mix64;
use crate::{Error, Result};
use queue::ReadyQueue;
use sim::{crash_fault, link_fault, EventQueue};
use std::collections::VecDeque;

/// Handle of a submitted job (its submission index).
pub type JobId = u64;

/// One unit of service work: a full campaign configuration plus a
/// scheduling priority (higher runs first; FIFO within a priority).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: CampaignConfig,
    pub priority: i32,
}

impl JobSpec {
    pub fn new(config: CampaignConfig) -> Self {
        Self {
            config,
            priority: 0,
        }
    }

    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }
}

/// The exactly-once terminal state of a job.
#[derive(Debug, Clone)]
pub enum JobOutcome {
    /// The campaign ran to its stop rule; counts are byte-identical to
    /// the single-threaded CLI run of the same configuration.
    Completed(CampaignResult),
    /// Cancelled before completion (its partial tallies are discarded).
    Cancelled,
    /// Rejected or aborted with a deterministic error (bad
    /// configuration, simulation-level failure).
    Failed(String),
}

impl JobOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            JobOutcome::Completed(_) => "completed",
            JobOutcome::Cancelled => "cancelled",
            JobOutcome::Failed(_) => "failed",
        }
    }
}

/// One streaming progress sample, emitted every time a batch fully
/// merges: the confidence interval tightens batch over batch, which is
/// exactly what a subscribed client would watch.
#[derive(Debug, Clone)]
pub struct ProgressUpdate {
    pub job: JobId,
    /// Virtual time of the batch close.
    pub time: u64,
    /// Injections merged so far.
    pub total: u64,
    /// Batches merged so far.
    pub batches: u64,
    /// Functional-error CI half-width at the job's confidence level
    /// (via [`CampaignResult::functional_error_estimate`]).
    pub half_width: f64,
}

/// Per-job slice of the final report.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub id: JobId,
    pub priority: i32,
    pub outcome: JobOutcome,
    pub progress: Vec<ProgressUpdate>,
    /// Chunk attempts this job lost to timeouts (crashes, drops, stuck
    /// workers) and requeued.
    pub requeues: u64,
}

/// Scheduler-side counters — diagnostics only, deliberately *not* part
/// of any byte-identity comparison (they vary across fault schedules;
/// the campaign counts must not).
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    pub events: u64,
    pub virtual_time: u64,
    pub msgs_sent: u64,
    pub msgs_dropped: u64,
    pub msgs_duplicated: u64,
    pub worker_crashes: u64,
    /// Workers force-freed by a supervisor timeout or a cancellation.
    pub workers_killed: u64,
    pub chunk_requeues: u64,
    /// `Done` deliveries ignored as duplicates or stale.
    pub stale_dones: u64,
    /// `Run` deliveries ignored as duplicates or stale.
    pub stale_runs: u64,
    /// Shared [`TraceCache`] adoptions — jobs with one clean-run
    /// identity record it once and share it.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Everything `run()` hands back.
#[derive(Debug)]
pub struct ServiceReport {
    pub jobs: Vec<JobReport>,
    /// Clean-run entries still resident in the shared [`TraceCache`] —
    /// the cache-drain invariant says this is 0.
    pub trace_cache_resident: usize,
    pub telemetry: Telemetry,
}

/// Service-level knobs. Everything that shapes timing is in virtual
/// ticks; nothing reads a wall clock.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Root seed of every service-level stream (messages, crashes,
    /// jitter). Job campaigns keep their own per-job seeds.
    pub seed: u64,
    /// Simulated worker processes.
    pub workers: usize,
    /// Injections per dispatched chunk.
    pub chunk_injections: u64,
    /// Supervisor deadline per chunk attempt, in virtual ticks; 0 = auto
    /// (chunk cost plus round-trip margin — always at least that, so a
    /// healthy attempt can never be declared dead before its `Done`
    /// could possibly arrive).
    pub chunk_timeout: u64,
    pub backoff: BackoffPolicy,
    pub fault_plan: ServiceFaultPlan,
    /// Base one-way message latency in virtual ticks.
    pub base_latency: u64,
    /// Virtual ticks a worker spends per injection of a chunk.
    pub tick_per_injection: u64,
    /// Watchdog: abort (as a scheduler bug) after this many events.
    pub max_events: u64,
}

impl ServiceConfig {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            workers: 4,
            chunk_injections: 256,
            chunk_timeout: 0,
            backoff: BackoffPolicy::default(),
            fault_plan: ServiceFaultPlan::none(),
            base_latency: 1,
            tick_per_injection: 1,
            max_events: 10_000_000,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("service needs at least one worker".into()));
        }
        if self.chunk_injections == 0 {
            return Err(Error::Config("service chunk size must be >= 1".into()));
        }
        if self.max_events == 0 {
            return Err(Error::Config("service event watchdog must be >= 1".into()));
        }
        self.fault_plan.validate().map_err(Error::Config)?;
        self.backoff.validate().map_err(Error::Config)?;
        Ok(())
    }
}

// ------------------------------------------------------------ internals

#[derive(Debug, Clone)]
struct ChunkCounts {
    local: CampaignResult,
    strata: Vec<[u64; 4]>,
}

#[derive(Clone)]
enum Ev {
    /// A chunk assignment arriving at a worker (faulty link).
    Run {
        worker: usize,
        job: JobId,
        batch: u64,
        idx: u32,
        attempt: u32,
        lo: u64,
        hi: u64,
    },
    /// A chunk tally arriving back at the dispatcher (faulty link).
    Done {
        job: JobId,
        batch: u64,
        idx: u32,
        counts: Box<ChunkCounts>,
    },
    /// A crashed worker finished restarting (reliable local timer).
    WorkerUp { worker: usize, gen: u64 },
    /// A worker finished computing and is free again (local, reliable).
    WorkerDone { worker: usize, gen: u64 },
    /// A requeued chunk's backoff expired.
    Retry {
        job: JobId,
        batch: u64,
        idx: u32,
        attempt: u32,
    },
    /// Supervisor deadline of one chunk attempt.
    Timeout {
        job: JobId,
        batch: u64,
        idx: u32,
        attempt: u32,
    },
    /// Client-requested cancellation.
    Cancel { job: JobId },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CState {
    Ready,
    InFlight,
    Waiting,
    Merged,
}

struct ChunkRt {
    lo: u64,
    hi: u64,
    attempt: u32,
    state: CState,
}

struct Batch {
    start: u64,
    size: u64,
    assign: Option<BatchAssign>,
    chunks: Vec<ChunkRt>,
    /// Chunk indices ready for dispatch (may hold stale entries for
    /// chunks merged by a late `Done`; consumers skip non-`Ready` ones).
    ready: VecDeque<u32>,
    outstanding: u32,
}

struct RunState {
    ctx: CellCtx,
    sched: BatchSchedule,
    result: CampaignResult,
    /// First injection index of the *next* batch.
    start: u64,
    batch: Batch,
}

enum Phase {
    Queued,
    Running(Box<RunState>),
    Done(JobOutcome),
}

struct JobRt {
    spec: JobSpec,
    problem: GemmProblem,
    /// The pinned clean-run identity; taken (exactly once) on any
    /// terminal transition.
    key: Option<TraceKey>,
    phase: Phase,
    progress: Vec<ProgressUpdate>,
    requeues: u64,
    in_ready: bool,
}

struct Reservation {
    job: JobId,
    batch: u64,
    idx: u32,
    attempt: u32,
    /// Set when the (first copy of the) `Run` actually arrived.
    started: bool,
}

struct WorkerRt {
    up: bool,
    /// Bumped whenever the supervisor force-frees or crashes the worker;
    /// stale `WorkerDone`/`WorkerUp` timers carry the old generation and
    /// are ignored.
    gen: u64,
    res: Option<Reservation>,
    arena: WorkerArena,
}

/// The deterministic campaign service. Build with [`CampaignService::new`],
/// [`CampaignService::submit`] jobs (plus optional
/// [`CampaignService::cancel_at`] schedules), then [`CampaignService::run`]
/// the whole simulation to quiescence.
pub struct CampaignService {
    cfg: ServiceConfig,
    cache: TraceCache,
    jobs: Vec<JobRt>,
    workers: Vec<WorkerRt>,
    queue: EventQueue<Ev>,
    ready: ReadyQueue,
    msg_seq: u64,
    exec_seq: u64,
    telemetry: Telemetry,
}

impl CampaignService {
    pub fn new(cfg: ServiceConfig) -> Result<Self> {
        cfg.validate()?;
        let workers = (0..cfg.workers)
            .map(|_| WorkerRt {
                up: true,
                gen: 0,
                res: None,
                arena: WorkerArena::new(),
            })
            .collect();
        Ok(Self {
            cfg,
            cache: TraceCache::new(),
            jobs: Vec::new(),
            workers,
            queue: EventQueue::new(),
            ready: ReadyQueue::new(),
            msg_seq: 0,
            exec_seq: 0,
            telemetry: Telemetry::default(),
        })
    }

    /// Submit a job. Its clean-run identity is pinned in the shared
    /// [`TraceCache`] immediately (so a later-starting job can never
    /// evict an identity a queued job still needs) and released exactly
    /// once on the terminal transition. The problem instance is the
    /// same one [`Campaign::run`](crate::campaign::Campaign::run) would
    /// draw — that is what makes service-vs-CLI byte-identity a
    /// meaningful assertion.
    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        let id = self.jobs.len() as JobId;
        let problem =
            GemmProblem::random(&spec.config.spec, problem_seed(spec.config.seed));
        let key = TraceKey::of(&spec.config, &problem);
        self.cache.retain(key.clone());
        self.ready.push(spec.priority, id, id);
        self.jobs.push(JobRt {
            spec,
            problem,
            key: Some(key),
            phase: Phase::Queued,
            progress: Vec::new(),
            requeues: 0,
            in_ready: true,
        });
        id
    }

    /// Schedule a cancellation of `job` at virtual time `time` (fires
    /// mid-run like any other event; cancelling a terminal job is a
    /// no-op).
    pub fn cancel_at(&mut self, job: JobId, time: u64) {
        self.queue.push_at(time, Ev::Cancel { job });
    }

    /// Run the simulation to quiescence and report. Errors only on
    /// scheduler bugs (watchdog overrun, a non-terminal job at
    /// quiescence) — per-job failures are [`JobOutcome::Failed`].
    pub fn run(mut self) -> Result<ServiceReport> {
        self.pump();
        let mut events = 0u64;
        while let Some((_, ev)) = self.queue.pop() {
            events += 1;
            if events > self.cfg.max_events {
                return Err(Error::Sim(format!(
                    "campaign service watchdog: {events} events without quiescing"
                )));
            }
            self.handle(ev);
            self.pump();
        }
        self.telemetry.events = events;
        self.telemetry.virtual_time = self.queue.now();
        self.telemetry.cache_hits = self.cache.hits();
        self.telemetry.cache_misses = self.cache.misses();
        for (i, jr) in self.jobs.iter().enumerate() {
            if !matches!(jr.phase, Phase::Done(_)) {
                return Err(Error::Sim(format!(
                    "service quiesced with job {i} non-terminal — scheduler bug"
                )));
            }
            debug_assert!(jr.key.is_none(), "terminal job {i} still holds its pin");
        }
        let trace_cache_resident = self.cache.len();
        let jobs = self
            .jobs
            .into_iter()
            .enumerate()
            .map(|(i, jr)| JobReport {
                id: i as JobId,
                priority: jr.spec.priority,
                outcome: match jr.phase {
                    Phase::Done(o) => o,
                    _ => unreachable!("checked above"),
                },
                progress: jr.progress,
                requeues: jr.requeues,
            })
            .collect();
        Ok(ServiceReport {
            jobs,
            trace_cache_resident,
            telemetry: self.telemetry,
        })
    }

    // ------------------------------------------------------ dispatcher

    /// Assign ready chunks to free workers until one side runs out.
    fn pump(&mut self) {
        loop {
            let Some(w) = self
                .workers
                .iter()
                .position(|wk| wk.up && wk.res.is_none())
            else {
                return;
            };
            // Highest-priority job with dispatchable work; lazily
            // prepared on first pick.
            let j = loop {
                let Some(job) = self.ready.pop() else { return };
                let j = job as usize;
                self.jobs[j].in_ready = false;
                if matches!(self.jobs[j].phase, Phase::Queued) {
                    self.prepare(j);
                }
                if self.has_ready_chunk(j) {
                    break j;
                }
            };
            let Some(idx) = self.take_ready_chunk(j) else {
                continue;
            };
            self.assign_chunk(w, j, idx);
            if self.has_ready_chunk(j) {
                self.mark_job_ready(j);
            }
        }
    }

    /// Lazy job start: validate + stage + record (or adopt from the
    /// shared cache), then open the first batch. Failures are terminal.
    fn prepare(&mut self, j: usize) {
        let prepared = CellCtx::prepare(
            &self.jobs[j].spec.config,
            &self.jobs[j].problem,
            Some(&self.cache),
        );
        match prepared {
            Ok(ctx) => {
                let sched = ctx.schedule();
                let result = ctx.init_result();
                self.jobs[j].phase = Phase::Running(Box::new(RunState {
                    ctx,
                    sched,
                    result,
                    start: 0,
                    batch: Batch {
                        start: 0,
                        size: 0,
                        assign: None,
                        chunks: Vec::new(),
                        ready: VecDeque::new(),
                        outstanding: 0,
                    },
                }));
                self.open_batch(j);
            }
            Err(e) => self.finish(j, JobOutcome::Failed(e.to_string())),
        }
    }

    /// Open the next batch (size, stratum allocation and chunk split are
    /// pure functions of the merged counts so far — identical to the
    /// single-threaded engine's batch loop), or finalize when the
    /// schedule is exhausted.
    fn open_batch(&mut self, j: usize) {
        let done = {
            let Phase::Running(rs) = &mut self.jobs[j].phase else {
                return;
            };
            let size = rs.sched.batch_at(rs.start);
            if size == 0 {
                true
            } else {
                let assign = if rs.ctx.config.stratify {
                    Some(BatchAssign::new(rs.start, &rs.ctx.allocate(&rs.result, size)))
                } else {
                    None
                };
                let chunk_len = self.cfg.chunk_injections;
                let mut chunks = Vec::new();
                let mut ready = VecDeque::new();
                let mut lo = rs.start;
                let end = rs.start + size;
                while lo < end {
                    let hi = (lo + chunk_len).min(end);
                    ready.push_back(chunks.len() as u32);
                    chunks.push(ChunkRt {
                        lo,
                        hi,
                        attempt: 0,
                        state: CState::Ready,
                    });
                    lo = hi;
                }
                rs.batch = Batch {
                    start: rs.start,
                    size,
                    assign,
                    outstanding: chunks.len() as u32,
                    chunks,
                    ready,
                };
                false
            }
        };
        if done {
            self.finalize_completed(j);
        } else {
            self.mark_job_ready(j);
        }
    }

    fn finalize_completed(&mut self, j: usize) {
        let outcome = {
            let Phase::Running(rs) = &mut self.jobs[j].phase else {
                return;
            };
            let target = rs.ctx.config.precision_target;
            rs.result.stopped_early = rs.sched.stopped_early(rs.start, &rs.result, target);
            // Virtual worlds have no wall clock; the comparison contract
            // is "count fields byte-identical", and 0.0 keeps the field
            // honest rather than pretending ticks are seconds.
            rs.result.wall_seconds = 0.0;
            JobOutcome::Completed(rs.result.clone())
        };
        self.finish(j, outcome);
    }

    /// The exactly-once terminal transition: set the outcome, release
    /// the cache pin, and kill any worker still reserved for this job.
    fn finish(&mut self, j: usize, outcome: JobOutcome) {
        if matches!(self.jobs[j].phase, Phase::Done(_)) {
            return;
        }
        self.jobs[j].phase = Phase::Done(outcome);
        if let Some(key) = self.jobs[j].key.take() {
            self.cache.release(&key);
        }
        let job = j as JobId;
        for wk in &mut self.workers {
            if wk.res.as_ref().is_some_and(|r| r.job == job) {
                wk.res = None;
                wk.gen += 1;
                self.telemetry.workers_killed += 1;
            }
        }
    }

    /// Drop stale (merged) entries off the ready deque, then report
    /// whether a dispatchable chunk remains.
    fn has_ready_chunk(&mut self, j: usize) -> bool {
        let Phase::Running(rs) = &mut self.jobs[j].phase else {
            return false;
        };
        while let Some(&idx) = rs.batch.ready.front() {
            if rs.batch.chunks[idx as usize].state == CState::Ready {
                return true;
            }
            rs.batch.ready.pop_front();
        }
        false
    }

    fn take_ready_chunk(&mut self, j: usize) -> Option<u32> {
        let Phase::Running(rs) = &mut self.jobs[j].phase else {
            return None;
        };
        while let Some(idx) = rs.batch.ready.pop_front() {
            if rs.batch.chunks[idx as usize].state == CState::Ready {
                return Some(idx);
            }
        }
        None
    }

    fn mark_job_ready(&mut self, j: usize) {
        if self.jobs[j].in_ready || !self.has_ready_chunk(j) {
            return;
        }
        self.jobs[j].in_ready = true;
        self.ready
            .push(self.jobs[j].spec.priority, j as u64, j as u64);
    }

    fn chunk_cost(&self, lo: u64, hi: u64) -> u64 {
        (hi - lo)
            .saturating_mul(self.cfg.tick_per_injection)
            .saturating_add(1)
    }

    /// Supervisor deadline of one attempt: never below the chunk cost
    /// plus a full round trip at maximum link delay, so a healthy
    /// attempt cannot be declared dead before its `Done` could arrive.
    fn deadline(&self, cost: u64) -> u64 {
        let round_trip = (self.cfg.base_latency)
            .saturating_add(self.cfg.fault_plan.delay_max)
            .saturating_mul(2)
            .saturating_add(2);
        self.cfg.chunk_timeout.max(cost.saturating_add(round_trip))
    }

    fn assign_chunk(&mut self, w: usize, j: usize, idx: u32) {
        let (batch, attempt, lo, hi) = {
            let Phase::Running(rs) = &mut self.jobs[j].phase else {
                return;
            };
            let c = &mut rs.batch.chunks[idx as usize];
            c.state = CState::InFlight;
            (rs.batch.start, c.attempt, c.lo, c.hi)
        };
        let job = j as JobId;
        self.workers[w].res = Some(Reservation {
            job,
            batch,
            idx,
            attempt,
            started: false,
        });
        self.send(
            0,
            Ev::Run {
                worker: w,
                job,
                batch,
                idx,
                attempt,
                lo,
                hi,
            },
        );
        let deadline = self.deadline(self.chunk_cost(lo, hi));
        self.queue.push_after(
            deadline,
            Ev::Timeout {
                job,
                batch,
                idx,
                attempt,
            },
        );
    }

    /// Send `ev` over the faulty link, `extra` ticks from now: the
    /// message's fate (drop / duplicate / per-copy delay) is a pure
    /// function of the global message sequence number.
    fn send(&mut self, extra: u64, ev: Ev) {
        let fault = link_fault(self.cfg.seed, &self.cfg.fault_plan, self.msg_seq);
        self.msg_seq += 1;
        self.telemetry.msgs_sent += 1;
        let base = extra + self.cfg.base_latency;
        if fault.dropped {
            self.telemetry.msgs_dropped += 1;
        } else {
            self.queue.push_after(base + fault.delays[0], ev.clone());
        }
        if fault.duplicated {
            self.telemetry.msgs_duplicated += 1;
            self.queue.push_after(base + fault.delays[1], ev);
        }
    }

    // --------------------------------------------------- event handlers

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Run {
                worker,
                job,
                batch,
                idx,
                attempt,
                lo,
                hi,
            } => self.on_run(worker, job, batch, idx, attempt, lo, hi),
            Ev::Done {
                job,
                batch,
                idx,
                counts,
            } => self.on_done(job, batch, idx, *counts),
            Ev::WorkerUp { worker, gen } => {
                let wk = &mut self.workers[worker];
                if wk.gen == gen && !wk.up {
                    wk.up = true;
                }
            }
            Ev::WorkerDone { worker, gen } => {
                let wk = &mut self.workers[worker];
                if wk.gen == gen && wk.res.as_ref().is_some_and(|r| r.started) {
                    wk.res = None;
                }
            }
            Ev::Retry {
                job,
                batch,
                idx,
                attempt,
            } => self.on_retry(job, batch, idx, attempt),
            Ev::Timeout {
                job,
                batch,
                idx,
                attempt,
            } => self.on_timeout(job, batch, idx, attempt),
            Ev::Cancel { job } => {
                let j = job as usize;
                if j < self.jobs.len() && !matches!(self.jobs[j].phase, Phase::Done(_)) {
                    self.finish(j, JobOutcome::Cancelled);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_run(
        &mut self,
        w: usize,
        job: JobId,
        batch: u64,
        idx: u32,
        attempt: u32,
        lo: u64,
        hi: u64,
    ) {
        let matches = self.workers[w].up
            && self.workers[w].res.as_ref().is_some_and(|r| {
                r.job == job && r.batch == batch && r.idx == idx && r.attempt == attempt
                    && !r.started
            });
        if !matches {
            self.telemetry.stale_runs += 1;
            return;
        }
        if let Some(r) = &mut self.workers[w].res {
            r.started = true;
        }
        let cost = self.chunk_cost(lo, hi);
        let exec = self.exec_seq;
        self.exec_seq += 1;
        let (died, worked) = crash_fault(self.cfg.seed, &self.cfg.fault_plan, exec, cost);
        if died {
            // The process dies `worked` ticks in: partial state is lost
            // (worker-local arenas hold nothing observable), no `Done`
            // is ever sent, and the supervisor's timeout requeues the
            // chunk. The worker restarts after the plan's restart time.
            let wk = &mut self.workers[w];
            wk.res = None;
            wk.up = false;
            wk.gen += 1;
            let gen = wk.gen;
            self.telemetry.worker_crashes += 1;
            self.queue.push_after(
                worked + self.cfg.fault_plan.worker_restart.max(1),
                Ev::WorkerUp { worker: w, gen },
            );
            return;
        }
        // Compute the chunk. Results are a pure function of
        // `(config, [lo, hi))` — independent of worker, attempt, and
        // virtual time — so computing at delivery time and timestamping
        // the completion `cost` ticks later is unobservable.
        let j = job as usize;
        let computed = {
            let jr = &self.jobs[j];
            let Phase::Running(rs) = &jr.phase else {
                // Unreachable: a terminal transition kills this
                // reservation, which un-matches the delivery above.
                self.workers[w].res = None;
                return;
            };
            let wk = &mut self.workers[w];
            let (sys, scratch) = wk.arena.arena(&rs.ctx);
            rs.ctx
                .run_chunk(sys, scratch, rs.batch.assign.as_ref(), lo, hi)
        };
        let gen = self.workers[w].gen;
        match computed {
            Ok((local, strata)) => {
                self.queue
                    .push_after(cost, Ev::WorkerDone { worker: w, gen });
                self.send(
                    cost,
                    Ev::Done {
                        job,
                        batch,
                        idx,
                        counts: Box::new(ChunkCounts { local, strata }),
                    },
                );
            }
            Err(e) => {
                // Deterministic simulation-level failure: every retry
                // would fail identically, so fail the job (freeing its
                // workers) instead of spinning on requeues.
                self.workers[w].res = None;
                self.finish(j, JobOutcome::Failed(e.to_string()));
            }
        }
    }

    fn on_done(&mut self, job: JobId, batch: u64, idx: u32, counts: ChunkCounts) {
        let now = self.queue.now();
        let j = job as usize;
        let closed = {
            let jr = &mut self.jobs[j];
            let Phase::Running(rs) = &mut jr.phase else {
                self.telemetry.stale_dones += 1;
                return;
            };
            if rs.batch.start != batch
                || rs.batch.chunks[idx as usize].state == CState::Merged
            {
                // A duplicate delivery, or a straggler from an attempt
                // the supervisor presumed dead. Merging the *first*
                // arrival — whichever attempt produced it — is correct
                // because every attempt's tally is byte-identical.
                self.telemetry.stale_dones += 1;
                return;
            }
            rs.batch.chunks[idx as usize].state = CState::Merged;
            rs.batch.outstanding -= 1;
            rs.result.merge_counts(&counts.local);
            rs.result.merge_strata(&counts.strata);
            if rs.batch.outstanding > 0 {
                None
            } else {
                // Batch barrier: the stop rule and the next stratum
                // allocation read the fully merged counts, exactly like
                // the single-threaded batch loop.
                rs.result.batches += 1;
                rs.start += rs.batch.size;
                let target = rs.ctx.config.precision_target;
                let cont = rs.sched.continues(rs.start, &rs.result, target);
                let hw = rs.result.functional_error_estimate().half_width();
                let (total, batches) = (rs.result.total, rs.result.batches);
                jr.progress.push(ProgressUpdate {
                    job,
                    time: now,
                    total,
                    batches,
                    half_width: hw,
                });
                Some(cont)
            }
        };
        match closed {
            Some(true) => self.open_batch(j),
            Some(false) => self.finalize_completed(j),
            None => {}
        }
    }

    fn on_retry(&mut self, job: JobId, batch: u64, idx: u32, attempt: u32) {
        let j = job as usize;
        {
            let Phase::Running(rs) = &mut self.jobs[j].phase else {
                return;
            };
            if rs.batch.start != batch {
                return;
            }
            let c = &mut rs.batch.chunks[idx as usize];
            if c.state != CState::Waiting || c.attempt != attempt {
                return;
            }
            c.state = CState::Ready;
            rs.batch.ready.push_back(idx);
        }
        self.mark_job_ready(j);
    }

    fn on_timeout(&mut self, job: JobId, batch: u64, idx: u32, attempt: u32) {
        // Free a worker still reserved for this exact attempt — the
        // supervisor kills stuck processes whether or not the chunk
        // still needs requeueing (its `Run` or `Done` may merely have
        // been dropped).
        for wk in &mut self.workers {
            if wk.res.as_ref().is_some_and(|r| {
                r.job == job && r.batch == batch && r.idx == idx && r.attempt == attempt
            }) {
                wk.res = None;
                wk.gen += 1;
                self.telemetry.workers_killed += 1;
            }
        }
        let j = job as usize;
        {
            let jr = &mut self.jobs[j];
            let Phase::Running(rs) = &mut jr.phase else {
                return;
            };
            if rs.batch.start != batch {
                return;
            }
            let c = &mut rs.batch.chunks[idx as usize];
            if c.state != CState::InFlight || c.attempt != attempt {
                // Already merged (a late `Done` beat the deadline),
                // already requeued, or a stale deadline of an older
                // attempt.
                return;
            }
            c.state = CState::Waiting;
            c.attempt += 1;
            jr.requeues += 1;
        }
        self.telemetry.chunk_requeues += 1;
        let delay =
            self.cfg
                .backoff
                .delay(self.cfg.seed, job, mix64(batch, idx as u64), attempt);
        self.queue.push_after(
            delay,
            Ev::Retry {
                job,
                batch,
                idx,
                attempt: attempt + 1,
            },
        );
    }
}
