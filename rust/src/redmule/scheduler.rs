//! Scheduler FSM: phase sequencing and loop counters.
//!
//! The schedule tiles the output matrix `Z[M][K]` into blocks of
//! `rows_per_tile × D` (where `D = H·P` is the per-row in-flight column
//! count) and, per block, runs four phases:
//!
//! ```text
//! LoadY   — preload the block's Y elements into the accumulators
//! Compute — for each inner chunk nt (H terms of the dot product),
//!           issue one output column per cycle into the row pipelines
//! Drain   — let the last D waves retire
//! StoreZ  — stream the accumulators out (checked/filtered in FT mode)
//! ```
//!
//! In fault-tolerant mode consecutive row pairs carry the same logical
//! row, so `rows_per_tile = L/2` and the M-tile count doubles — the 2×
//! performance cost the paper quotes for redundant execution.
//!
//! The whole scheduler state is a handful of registers; each is a fault
//! site. In the fully protected build a **replica** scheduler steps in
//! lockstep and a comparator flags any divergence (§3.2).

/// Phase encodings. Values above `DONE` are unreachable by construction
/// and only arise from injected faults; the FSM treats them as an illegal
/// state and halts (the run then times out — or, in the fully protected
/// build, the comparator aborts it first).
pub const PH_IDLE: u8 = 0;
pub const PH_LOAD_Y: u8 = 1;
pub const PH_COMPUTE: u8 = 2;
pub const PH_DRAIN: u8 = 3;
pub const PH_STORE_Z: u8 = 4;
pub const PH_DONE: u8 = 5;

/// Elements the streamer moves per cycle in load/store phases (a 256-bit
/// TCDM port: 16 FP16 elements).
pub const STREAM_ELEMS_PER_CYCLE: usize = 16;

/// Loop-counter ids (used as SEU site indices).
pub const CNT_MT: u16 = 0;
pub const CNT_KT: u16 = 1;
pub const CNT_NT: u16 = 2;
pub const CNT_CC: u16 = 3;
pub const CNT_PTR: u16 = 4;

/// Dimensions the scheduler derives each cycle from the register file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    pub m: u32,
    pub n: u32,
    pub k: u32,
    /// Logical (distinct) output rows processed per M-tile.
    pub rows_per_tile: u32,
    /// Column-tile width (= D).
    pub d: u32,
    /// Inner chunk width (= H).
    pub h: u32,
}

impl Dims {
    pub fn tiles_m(&self) -> u32 {
        self.m.div_ceil(self.rows_per_tile.max(1)).max(1)
    }

    pub fn tiles_k(&self) -> u32 {
        self.k.div_ceil(self.d.max(1)).max(1)
    }

    pub fn chunks_n(&self) -> u32 {
        self.n.div_ceil(self.h.max(1)).max(1)
    }

    /// Columns in K-tile `kt` (tail tiles are narrower).
    pub fn dk(&self, kt: u32) -> u32 {
        let start = kt * self.d;
        self.k.saturating_sub(start).min(self.d)
    }

    /// Logical rows in M-tile `mt`.
    pub fn rows(&self, mt: u32) -> u32 {
        let start = mt * self.rows_per_tile;
        self.m.saturating_sub(start).min(self.rows_per_tile)
    }
}

/// The scheduler's architectural state (every field is a fault site).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Scheduler {
    pub phase: u8,
    pub mt: u16,
    pub kt: u16,
    pub nt: u16,
    /// Cycle-in-chunk during Compute (issues column `cc` when `cc < dk`),
    /// drain counter during Drain.
    pub cc: u16,
    /// Cycle counter within LoadY / StoreZ.
    pub ptr: u16,
}

impl Scheduler {
    pub fn idle() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        *self = Self {
            phase: PH_LOAD_Y,
            ..Self::default()
        };
    }

    /// Tile-level recovery entry point (the paper's §5 future work): begin
    /// at tile `(mt, kt)` instead of `(0, 0)`. Earlier tiles' Z results
    /// are already committed to TCDM, so skipping them is sound as long
    /// as committed stores are trustworthy (write gating — see
    /// `cluster::RecoveryPolicy`).
    pub fn start_from(&mut self, mt: u16, kt: u16) {
        *self = Self {
            phase: PH_LOAD_Y,
            mt,
            kt,
            ..Self::default()
        };
    }

    /// Cycles remaining from tile `(mt, kt)` (inclusive) to the end of
    /// the task — the re-execution cost of tile-level recovery.
    pub fn cycles_from(dims: &Dims, mt0: u32, kt0: u32) -> u64 {
        let mut total = 0u64;
        for mt in mt0..dims.tiles_m() {
            let k_start = if mt == mt0 { kt0 } else { 0 };
            for kt in k_start..dims.tiles_k() {
                total += Self::load_cycles(dims, mt, kt) as u64;
                total += (dims.chunks_n() as u64) * dims.d as u64;
                total += dims.d as u64;
                total += Self::store_cycles(dims, mt, kt) as u64;
            }
        }
        total
    }

    pub fn is_illegal(&self) -> bool {
        self.phase > PH_DONE
    }

    /// Cycles the LoadY phase takes for tile (mt, kt).
    pub fn load_cycles(dims: &Dims, mt: u32, kt: u32) -> u32 {
        let elems = dims.rows(mt) * dims.dk(kt);
        elems.div_ceil(STREAM_ELEMS_PER_CYCLE as u32).max(1)
    }

    /// Cycles the StoreZ phase takes for tile (mt, kt) (logical rows: the
    /// write filter collapses redundant pairs to a single write).
    pub fn store_cycles(dims: &Dims, mt: u32, kt: u32) -> u32 {
        let elems = dims.rows(mt) * dims.dk(kt);
        elems.div_ceil(STREAM_ELEMS_PER_CYCLE as u32).max(1)
    }

    /// Advance one cycle. Returns `true` while the task is still running.
    /// Illegal phase encodings halt (no advance) — the control FSM's
    /// timeout / comparator machinery deals with them.
    pub fn advance(&mut self, dims: &Dims) -> bool {
        match self.phase {
            PH_IDLE | PH_DONE => false,
            PH_LOAD_Y => {
                self.ptr += 1;
                if u32::from(self.ptr) >= Self::load_cycles(dims, self.mt.into(), self.kt.into()) {
                    self.ptr = 0;
                    self.nt = 0;
                    self.cc = 0;
                    self.phase = PH_COMPUTE;
                }
                true
            }
            PH_COMPUTE => {
                self.cc += 1;
                if u32::from(self.cc) >= dims.d {
                    self.cc = 0;
                    self.nt += 1;
                    if u32::from(self.nt) >= dims.chunks_n() {
                        self.nt = 0;
                        self.phase = PH_DRAIN;
                    }
                }
                true
            }
            PH_DRAIN => {
                self.cc += 1;
                if u32::from(self.cc) >= dims.d {
                    self.cc = 0;
                    self.ptr = 0;
                    self.phase = PH_STORE_Z;
                }
                true
            }
            PH_STORE_Z => {
                self.ptr += 1;
                if u32::from(self.ptr) >= Self::store_cycles(dims, self.mt.into(), self.kt.into()) {
                    self.ptr = 0;
                    // Next tile: K-major inner loop, M outer.
                    self.kt += 1;
                    if u32::from(self.kt) >= dims.tiles_k() {
                        self.kt = 0;
                        self.mt += 1;
                        if u32::from(self.mt) >= dims.tiles_m() {
                            self.phase = PH_DONE;
                            return false;
                        }
                    }
                    self.phase = PH_LOAD_Y;
                }
                true
            }
            _ => false, // illegal encoding: halt
        }
    }

    /// Total fault-free cycles for a task (used by the perf model and for
    /// campaign cycle-sampling).
    pub fn nominal_cycles(dims: &Dims) -> u64 {
        let mut total = 0u64;
        for mt in 0..dims.tiles_m() {
            for kt in 0..dims.tiles_k() {
                total += Self::load_cycles(dims, mt, kt) as u64;
                total += (dims.chunks_n() as u64) * dims.d as u64; // compute
                total += dims.d as u64; // drain
                total += Self::store_cycles(dims, mt, kt) as u64;
            }
        }
        total
    }

    /// SEU hook: flip a counter bit. `which` selects the counter.
    pub fn flip_counter(&mut self, which: u16, bit: u8) -> bool {
        let b = bit & 15;
        match which {
            CNT_MT => self.mt ^= 1 << b,
            CNT_KT => self.kt ^= 1 << b,
            CNT_NT => self.nt ^= 1 << b,
            CNT_CC => self.cc ^= 1 << b,
            CNT_PTR => self.ptr ^= 1 << b,
            _ => return false,
        }
        true
    }

    /// SEU hook: flip a phase-encoding bit.
    pub fn flip_phase(&mut self, bit: u8) {
        self.phase ^= 1 << (bit & 7);
    }

    /// Raw state tuple for the lockstep comparator.
    pub fn compare_key(&self) -> (u8, u16, u16, u16, u16, u16) {
        (self.phase, self.mt, self.kt, self.nt, self.cc, self.ptr)
    }

    /// Fold the full architectural state into a fast-forward digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        h.write_u8(self.phase);
        h.write_u16(self.mt);
        h.write_u16(self.kt);
        h.write_u16(self.nt);
        h.write_u16(self.cc);
        h.write_u16(self.ptr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_dims_perf() -> Dims {
        // L=12, H=4, P=3 in performance mode on the (12,16,16) workload.
        Dims {
            m: 12,
            n: 16,
            k: 16,
            rows_per_tile: 12,
            d: 12,
            h: 4,
        }
    }

    fn paper_dims_ft() -> Dims {
        Dims {
            rows_per_tile: 6,
            ..paper_dims_perf()
        }
    }

    #[test]
    fn tile_arithmetic() {
        let d = paper_dims_perf();
        assert_eq!(d.tiles_m(), 1);
        assert_eq!(d.tiles_k(), 2);
        assert_eq!(d.chunks_n(), 4);
        assert_eq!(d.dk(0), 12);
        assert_eq!(d.dk(1), 4);
        assert_eq!(d.rows(0), 12);
        let f = paper_dims_ft();
        assert_eq!(f.tiles_m(), 2);
        assert_eq!(f.rows(0), 6);
        assert_eq!(f.rows(1), 6);
    }

    #[test]
    fn walks_all_phases_to_done() {
        let dims = paper_dims_perf();
        let mut s = Scheduler::idle();
        s.start();
        let mut phases_seen = [false; 6];
        let mut cycles = 0u64;
        while s.phase != PH_DONE {
            phases_seen[s.phase as usize] = true;
            assert!(cycles < 100_000, "scheduler must terminate");
            s.advance(&dims);
            cycles += 1;
        }
        assert!(phases_seen[PH_LOAD_Y as usize]);
        assert!(phases_seen[PH_COMPUTE as usize]);
        assert!(phases_seen[PH_DRAIN as usize]);
        assert!(phases_seen[PH_STORE_Z as usize]);
        assert_eq!(cycles, Scheduler::nominal_cycles(&dims));
    }

    #[test]
    fn ft_mode_roughly_doubles_cycles() {
        let perf = Scheduler::nominal_cycles(&paper_dims_perf());
        let ft = Scheduler::nominal_cycles(&paper_dims_ft());
        let ratio = ft as f64 / perf as f64;
        assert!(
            (1.7..=2.3).contains(&ratio),
            "FT/perf cycle ratio {ratio} should be ~2 (ft={ft}, perf={perf})"
        );
    }

    #[test]
    fn illegal_phase_halts() {
        let dims = paper_dims_perf();
        let mut s = Scheduler::idle();
        s.start();
        s.phase = 0x13; // injected garbage
        assert!(s.is_illegal());
        let before = s;
        assert!(!s.advance(&dims));
        assert_eq!(s, before, "illegal state must not advance");
    }

    #[test]
    fn counter_flip_hooks_work() {
        let mut s = Scheduler::idle();
        assert!(s.flip_counter(CNT_NT, 2));
        assert_eq!(s.nt, 4);
        assert!(s.flip_counter(CNT_NT, 2));
        assert_eq!(s.nt, 0);
        assert!(!s.flip_counter(99, 0));
        s.flip_phase(0);
        assert_eq!(s.phase, 1);
    }

    #[test]
    fn compare_key_detects_any_divergence() {
        let mut a = Scheduler::idle();
        a.start();
        let mut b = a;
        assert_eq!(a.compare_key(), b.compare_key());
        b.flip_counter(CNT_CC, 0);
        assert_ne!(a.compare_key(), b.compare_key());
        let dims = paper_dims_perf();
        a.advance(&dims);
        let mut c = a;
        c.flip_phase(3);
        assert_ne!(a.compare_key(), c.compare_key());
    }

    #[test]
    fn start_from_resumes_at_tile_and_costs_the_remainder() {
        let dims = Dims {
            m: 24,
            n: 16,
            k: 24,
            rows_per_tile: 6,
            d: 12,
            h: 4,
        };
        // Walk from (2, 1) and compare against the closed form.
        let mut s = Scheduler::idle();
        s.start_from(2, 1);
        assert_eq!((s.mt, s.kt, s.phase), (2, 1, PH_LOAD_Y));
        let mut walked = 1u64;
        while s.advance(&dims) {
            walked += 1;
            assert!(walked < 1_000_000);
        }
        assert_eq!(walked, Scheduler::cycles_from(&dims, 2, 1));
        // Resuming at (0,0) is the full task.
        assert_eq!(
            Scheduler::cycles_from(&dims, 0, 0),
            Scheduler::nominal_cycles(&dims)
        );
        // Resuming at the last tile costs strictly less.
        assert!(
            Scheduler::cycles_from(&dims, dims.tiles_m() - 1, dims.tiles_k() - 1)
                < Scheduler::nominal_cycles(&dims) / 2
        );
    }

    #[test]
    fn nominal_cycles_scale_with_problem() {
        let small = Scheduler::nominal_cycles(&paper_dims_perf());
        let big = Scheduler::nominal_cycles(&Dims {
            m: 48,
            n: 64,
            k: 64,
            rows_per_tile: 12,
            d: 12,
            h: 4,
        });
        assert!(big > 10 * small);
    }
}
