//! Streamer model: address generation with fault-corruption state, plus
//! the reduced-width replica address path of the fully protected build.
//!
//! The real streamer generates addresses with nested counters and adders.
//! We compute each issued address functionally from the scheduler counters
//! (whose bits are fault sites of their own) and model a *corrupted
//! address-generator register* as a persistent XOR mask applied to every
//! issued address from the upset until the task ends — the dominant effect
//! of a latched flip in an incrementing generator.
//!
//! In the fully protected build (§3.2) each streamer has a **replica with
//! reduced data width**: it recomputes all control information (addresses,
//! valids, write-enables) but carries no data. The issued primary address
//! is compared against the replica's every cycle; any divergence raises a
//! `STREAMER_MISMATCH` fault.

use crate::fault::site::{streamer_unit, Module, SiteId};
use crate::fault::FaultCtx;

/// Stream indices (also used as replica unit offsets).
pub const STREAM_X: usize = 0;
pub const STREAM_W: usize = 1;
pub const STREAM_Y: usize = 2;
pub const STREAM_Z: usize = 3;

pub const STREAM_MODULES: [Module; 4] = [
    Module::StreamerX,
    Module::StreamerW,
    Module::StreamerY,
    Module::StreamerZ,
];

/// One operand/result stream's address-generation state.
#[derive(Debug, Clone, Copy, Default)]
pub struct Streamer {
    /// XOR corruption of the primary address generator (SEU site).
    pub mask: u32,
    /// XOR corruption of the replica address generator (SEU site, Full).
    pub mask_rep: u32,
}

/// Result of issuing one address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Issue {
    /// Effective (possibly corrupted) primary address — what the data
    /// path actually uses.
    pub addr: u32,
    /// The replica's address (meaningful only when the replica exists).
    pub addr_rep: u32,
    /// Primary vs. replica divergence (drives `STREAMER_MISMATCH`).
    pub mismatch: bool,
}

impl Streamer {
    /// Issue the address for one element access. `nominal` is the
    /// fault-free address from the scheduler counters; `lane` distinguishes
    /// parallel request nets within a cycle (wide-port beats).
    #[inline]
    pub fn issue(
        &self,
        stream: usize,
        nominal: u32,
        lane: u16,
        has_replica: bool,
        ctx: &mut FaultCtx,
    ) -> Issue {
        let module = STREAM_MODULES[stream];
        // Transient on the primary request net.
        let addr = ctx.u32(
            SiteId::new(module, streamer_unit::REQ_NET, lane),
            nominal ^ self.mask,
        );
        if !has_replica {
            return Issue {
                addr,
                addr_rep: addr,
                mismatch: false,
            };
        }
        // Transient on the replica request net (replica sites live under
        // Module::StreamerReplica; unit = stream*2+1).
        let addr_rep = ctx.u32(
            SiteId::new(Module::StreamerReplica, (stream * 2 + 1) as u8, lane),
            nominal ^ self.mask_rep,
        );
        Issue {
            addr,
            addr_rep,
            mismatch: addr != addr_rep,
        }
    }

    /// SEU hooks.
    pub fn flip_mask_bit(&mut self, bit: u8) {
        self.mask ^= 1 << (bit & 31);
    }

    pub fn flip_replica_mask_bit(&mut self, bit: u8) {
        self.mask_rep ^= 1 << (bit & 31);
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Fold the address-generator state into a fast-forward digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        h.write_u32(self.mask);
        h.write_u32(self.mask_rep);
    }
}

/// Clamp an effective address into the TCDM and align it to an element
/// boundary — a corrupted address still lands *somewhere* in memory, as in
/// the RTL where the upper bits simply alias.
#[inline]
pub fn wrap_addr(addr: u32, tcdm_bytes: u32) -> u32 {
    (addr & !1) % tcdm_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultKind, FaultPlan};

    #[test]
    fn clean_issue_passes_nominal_address() {
        let s = Streamer::default();
        let mut ctx = FaultCtx::clean();
        let i = s.issue(STREAM_X, 0x1234, 0, true, &mut ctx);
        assert_eq!(i.addr, 0x1234);
        assert!(!i.mismatch);
    }

    #[test]
    fn primary_mask_corruption_is_caught_by_replica() {
        let mut s = Streamer::default();
        s.flip_mask_bit(4);
        let mut ctx = FaultCtx::clean();
        let i = s.issue(STREAM_Y, 0x100, 0, true, &mut ctx);
        assert_eq!(i.addr, 0x110);
        assert_eq!(i.addr_rep, 0x100);
        assert!(i.mismatch);
        // Without a replica the corruption is silent.
        let i2 = s.issue(STREAM_Y, 0x100, 0, false, &mut ctx);
        assert_eq!(i2.addr, 0x110);
        assert!(!i2.mismatch);
    }

    #[test]
    fn replica_mask_corruption_also_mismatches() {
        let mut s = Streamer::default();
        s.flip_replica_mask_bit(2);
        let mut ctx = FaultCtx::clean();
        let i = s.issue(STREAM_Z, 0x80, 3, true, &mut ctx);
        assert_eq!(i.addr, 0x80); // data path unaffected
        assert!(i.mismatch); // but the divergence is detected
    }

    #[test]
    fn transient_on_request_net_fires_once() {
        let s = Streamer::default();
        let site = SiteId::new(Module::StreamerW, streamer_unit::REQ_NET, 2);
        let mut ctx = FaultCtx::with_plan(FaultPlan {
            cycle: 7,
            site,
            bit: 3,
            kind: FaultKind::Transient,
        });
        ctx.set_cycle(7);
        let i = s.issue(STREAM_W, 0x40, 2, true, &mut ctx);
        assert_eq!(i.addr, 0x48);
        assert!(i.mismatch, "replica sees the clean address");
        // A different lane is a different site: untouched.
        let j = s.issue(STREAM_W, 0x40, 1, true, &mut ctx);
        assert_eq!(j.addr, 0x40);
        assert!(!j.mismatch);
        // A different cycle: untouched even on the planned lane.
        ctx.set_cycle(8);
        let k = s.issue(STREAM_W, 0x44, 2, true, &mut ctx);
        assert_eq!(k.addr, 0x44);
        assert!(!k.mismatch);
    }

    #[test]
    fn wrap_addr_aligns_and_bounds() {
        assert_eq!(wrap_addr(0x1001, 0x1000), 0x0000);
        assert_eq!(wrap_addr(0x0FFF, 0x1000), 0x0FFE);
        assert_eq!(wrap_addr(0xFFFF_FFFF, 0x4000), 0xFFFF_FFFE % 0x4000);
    }
}
