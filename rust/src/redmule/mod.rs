//! Cycle-level model of the RedMulE / RedMulE-FT accelerator.
//!
//! The module decomposition mirrors Figure 1 of the paper:
//!
//! * [`regfile`] — shadowed-context configuration registers (+ parity).
//! * [`streamer`] — address generation (+ reduced-width replicas).
//! * [`array`] — X/W operand buffers, CE FMA pipelines, accumulators.
//! * [`scheduler`] — the schedule FSM (+ lockstep replica).
//! * [`fault_unit`] — fault status registers and the 2-cycle interrupt.
//!
//! [`RedMule::step`] executes one clock cycle: it applies any due SEU,
//! runs the active phase's work (memory traffic, FMA issue/retire) with
//! every datum passing through its [`FaultCtx`] hook, steps the FSMs and
//! their replicas, evaluates the build's detectors, and drives the
//! abort/interrupt sequence of §3.3 when a fault is flagged.
//!
//! On FP8 builds ([`RedMuleConfig::format`] ≠ `Fp16`) every fetched
//! operand additionally passes through a fetch-path *cast-in* unit
//! (narrow to the 8-bit code, expose the code on its own fault sites,
//! widen back onto the FP16 carrier) and every stored result through the
//! store-path *cast-out* unit — see the `CASTIN_*`/`CASTOUT_*` tags in
//! [`crate::fault::site::streamer_unit`]. Both stages are combinational
//! (zero extra cycles) and identity on FP16 builds, so the default path
//! is bit-for-bit unchanged. The reduction itself is selected by
//! [`RedMuleConfig::op`] (see [`crate::fp::op_step16`]).

pub mod abft;
pub mod array;
pub mod config;
pub mod fault_unit;
pub mod regfile;
pub mod scheduler;
pub mod streamer;

pub use config::{ExecMode, Protection, RedMuleConfig, TaskLayout};

use crate::ecc::{decode32, weight_parity, weight_parity_ok, DecodeStatus};
use crate::fault::site::{
    ce_unit, checker_unit, ctrl_unit, fault_unit as fu_sites, regfile_unit, sched_unit,
    streamer_unit, wbuf_unit, Module, SiteId,
};
use crate::fault::{FaultCtx, FaultPlan};
use crate::fp::{op_step16, Fp16, Fp8, GemmFormat};
use crate::tcdm::Tcdm;
use abft::AbftUnit;
use array::{CeArray, InFlight};
use fault_unit::{cause, FaultUnit};
use regfile::{
    RegFile, FLAG_ABFT, FLAG_FT_MODE, FLAG_TILE_RECOVERY, REG_FLAGS, REG_K, REG_M, REG_N,
    REG_RESUME, REG_W_ADDR, REG_X_ADDR, REG_Y_ADDR, REG_Z_ADDR,
};
use scheduler::{Dims, Scheduler, PH_COMPUTE, PH_DONE, PH_DRAIN, PH_LOAD_Y, PH_STORE_Z, STREAM_ELEMS_PER_CYCLE};
use streamer::{wrap_addr, Streamer, STREAM_W, STREAM_X, STREAM_Y, STREAM_Z};

/// Control-FSM state encodings (values > `CTRL_DONE` are illegal and
/// reachable only through injected faults; the FSM then halts).
pub const CTRL_IDLE: u8 = 0;
pub const CTRL_RUN: u8 = 1;
pub const CTRL_IRQ1: u8 = 2;
pub const CTRL_IRQ2: u8 = 3;
pub const CTRL_DONE: u8 = 4;

/// Host-visible accelerator state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    Idle,
    Running,
    Done,
    /// Aborted after a detected fault; status registers hold the cause.
    Aborted,
}

/// Cycle/traffic counters (feeds the performance model).
#[derive(Debug, Clone, Copy, Default)]
pub struct PerfCounters {
    pub cycles: u64,
    pub phase_cycles: [u64; 6],
    pub macs: u64,
    pub tcdm_reads: u64,
    pub tcdm_writes: u64,
}

/// The accelerator.
#[derive(Debug, Clone)]
pub struct RedMule {
    pub cfg: RedMuleConfig,
    pub protection: Protection,
    pub regfile: RegFile,
    pub sched: Scheduler,
    pub sched_rep: Scheduler,
    pub ctrl_state: u8,
    pub ctrl_state_rep: u8,
    pub array: CeArray,
    pub streamers: [Streamer; 4],
    pub fault_unit: FaultUnit,
    /// ABFT writeback checksum unit (live only on `Protection::Abft`).
    pub abft: AbftUnit,
    pub perf: PerfCounters,
    pub cycle: u64,
    irq_line: bool,
    /// Execution mode latched from the register file at task start.
    mode: ExecMode,
    /// Global mirror of the wave identities in the (row-uniform) pipeline,
    /// drives the W broadcast buffer.
    wave_pipe: Vec<Option<(u16, u16)>>,
    /// Pending SEU masks on the cast units' 8-bit code registers, one per
    /// stream (X/W/Y/Z). The register is rewritten every beat, so an upset
    /// corrupts exactly the next code cast through that stream and is then
    /// cleared. Always zero on FP16 builds (the sites are not populated).
    cast_upset: [u8; 4],
}

impl RedMule {
    pub fn new(cfg: RedMuleConfig, protection: Protection) -> Self {
        Self {
            cfg,
            protection,
            regfile: RegFile::new(protection.has_control_protection()),
            sched: Scheduler::idle(),
            sched_rep: Scheduler::idle(),
            ctrl_state: CTRL_IDLE,
            ctrl_state_rep: CTRL_IDLE,
            array: CeArray::new(cfg.l, cfg.h, cfg.p),
            streamers: [Streamer::default(); 4],
            fault_unit: FaultUnit::new(),
            abft: AbftUnit::default(),
            perf: PerfCounters::default(),
            cycle: 0,
            irq_line: false,
            mode: ExecMode::Performance,
            wave_pipe: vec![None; cfg.d()],
            cast_upset: [0; 4],
        }
    }

    /// Latch the committed configuration and start the task.
    pub fn start(&mut self) {
        let flags = self.regfile.read(REG_FLAGS);
        let ft_requested = flags & FLAG_FT_MODE != 0;
        self.mode = if ft_requested && self.protection.has_data_protection() {
            assert!(self.cfg.l % 2 == 0, "FT mode requires an even row count");
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        };
        if self.protection.has_abft_checksums() && flags & FLAG_ABFT != 0 {
            // Arm the writeback checksum unit with the task's (augmented)
            // dimensions; accumulators start from zero on every attempt.
            let (m, k) = (self.regfile.read(REG_M) as usize, self.regfile.read(REG_K) as usize);
            if self.protection.has_online_abft() {
                self.abft.arm_online(m, k);
            } else {
                self.abft.arm(m, k);
            }
        } else {
            self.abft.disarm();
        }
        if flags & FLAG_TILE_RECOVERY != 0 {
            // §5 future work: resume from the tile the host read out of
            // the progress register instead of recomputing everything.
            let resume = self.regfile.read(REG_RESUME);
            let (mt, kt) = ((resume >> 16) as u16, resume as u16);
            self.sched.start_from(mt, kt);
            self.sched_rep.start_from(mt, kt);
        } else {
            self.sched.start();
            self.sched_rep.start();
        }
        self.ctrl_state = CTRL_RUN;
        self.ctrl_state_rep = CTRL_RUN;
        self.array.clear();
        for s in &mut self.streamers {
            s.reset();
        }
        self.wave_pipe.fill(None);
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Full reset to power-on state, preserving the build parameters.
    /// Used between independent campaign runs so cycle numbering and any
    /// latched state cannot leak from one injection to the next.
    /// Allocation-free: reuses the array/pipe buffers (hot path of the
    /// campaign engine — see EXPERIMENTS.md §Perf).
    pub fn reset(&mut self) {
        self.regfile = RegFile::new(self.protection.has_control_protection());
        self.sched = Scheduler::idle();
        self.sched_rep = Scheduler::idle();
        self.ctrl_state = CTRL_IDLE;
        self.ctrl_state_rep = CTRL_IDLE;
        self.array.clear();
        for s in &mut self.streamers {
            s.reset();
        }
        self.fault_unit = FaultUnit::new();
        self.abft.disarm();
        self.perf = PerfCounters::default();
        self.cycle = 0;
        self.irq_line = false;
        self.mode = ExecMode::Performance;
        self.wave_pipe.fill(None);
        self.cast_upset = [0; 4];
    }

    pub fn irq(&self) -> bool {
        self.irq_line
    }

    /// Copy another instance's complete mutable state into this one —
    /// checkpoint restore for the campaign's fast-forward engine. Buffer
    /// allocations are reused; the build parameters must match (a
    /// checkpoint only makes sense on the geometry it was taken from).
    pub fn restore_from(&mut self, snap: &RedMule) {
        debug_assert_eq!(self.cfg, snap.cfg);
        debug_assert_eq!(self.protection, snap.protection);
        self.regfile = snap.regfile.clone();
        self.sched = snap.sched;
        self.sched_rep = snap.sched_rep;
        self.ctrl_state = snap.ctrl_state;
        self.ctrl_state_rep = snap.ctrl_state_rep;
        self.array.restore_from(&snap.array);
        self.streamers = snap.streamers;
        self.fault_unit = snap.fault_unit;
        self.abft.clone_from(&snap.abft);
        self.perf = snap.perf;
        self.cycle = snap.cycle;
        self.irq_line = snap.irq_line;
        self.mode = snap.mode;
        self.wave_pipe.clone_from(&snap.wave_pipe);
        self.cast_upset = snap.cast_upset;
    }

    /// Fold every piece of *behavioral* architectural state into a
    /// fast-forward digest. Two instances with equal digests (and equal
    /// TCDM contents) evolve identically under fault-free stepping, so
    /// the campaign can substitute the recorded reference tail for the
    /// rest of the simulation. Performance counters are excluded — they
    /// never feed back into execution, and an absorbed fault may leave
    /// them permanently offset (e.g. a corrupted store address that was
    /// later overwritten).
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        self.regfile.digest_into(h);
        self.sched.digest_into(h);
        self.sched_rep.digest_into(h);
        h.write_u8(self.ctrl_state);
        h.write_u8(self.ctrl_state_rep);
        self.array.digest_into(h);
        for s in &self.streamers {
            s.digest_into(h);
        }
        self.fault_unit.digest_into(h);
        self.abft.digest_into(h);
        h.write_u64(self.cycle);
        h.write_bool(self.irq_line);
        h.write_u8(match self.mode {
            ExecMode::Performance => 0,
            ExecMode::FaultTolerant => 1,
        });
        for w in &self.wave_pipe {
            match w {
                None => h.write_u8(0),
                Some((nt, cc)) => {
                    h.write_u8(1);
                    h.write_u16(*nt);
                    h.write_u16(*cc);
                }
            }
        }
        for m in &self.cast_upset {
            h.write_u8(*m);
        }
    }

    /// [`RedMule::digest_into`] folded into a standalone value — the
    /// accelerator half of the fast-forward convergence digest. The
    /// two-level engine records one of these per reference cycle so a
    /// faulted run can probe for re-convergence *between* checkpoints.
    pub fn digest64(&self) -> u64 {
        let mut h = crate::util::digest::Fnv64::new();
        self.digest_into(&mut h);
        h.finish()
    }

    pub fn state(&self) -> RunState {
        match self.ctrl_state {
            CTRL_DONE => RunState::Done,
            CTRL_IDLE if self.fault_unit.status != 0 => RunState::Aborted,
            CTRL_IDLE => RunState::Idle,
            _ => RunState::Running,
        }
    }

    /// Dimensions as seen by the FSMs this cycle (register-file reads).
    pub fn dims(&self) -> Dims {
        let rows_per_tile = if self.mode == ExecMode::FaultTolerant {
            (self.cfg.l / 2) as u32
        } else {
            self.cfg.l as u32
        };
        Dims {
            m: self.regfile.read(REG_M),
            n: self.regfile.read(REG_N),
            k: self.regfile.read(REG_K),
            rows_per_tile,
            d: self.cfg.d() as u32,
            h: self.cfg.h as u32,
        }
    }

    /// Detector causes enabled by this build + latched mode (§3.4).
    fn enabled_causes(&self) -> u32 {
        let mut e = 0;
        if self.protection.has_data_protection() {
            e |= cause::ECC_DOUBLE;
            if self.mode == ExecMode::FaultTolerant {
                e |= cause::W_PARITY | cause::Z_MISMATCH;
            }
        }
        if self.protection.has_control_protection() {
            e |= cause::FSM_MISMATCH
                | cause::STREAMER_MISMATCH
                | cause::REGFILE_PARITY
                | cause::STORE_PARITY;
        }
        if self.protection.has_per_ce_checkers() {
            e |= cause::CE_CHECK;
        }
        e
    }

    /// Execute one clock cycle against `tcdm`.
    pub fn step(&mut self, tcdm: &mut Tcdm, ctx: &mut FaultCtx) {
        self.cycle += 1;
        ctx.set_cycle(self.cycle);
        self.perf.cycles += 1;

        // SEUs land at the cycle boundary, before any logic evaluates.
        // Multi-fault runs may schedule several for the same cycle, so
        // every due plan is applied, not just the first.
        for i in 0..ctx.n_plans() {
            if let Some(plan) = ctx.seu_due_at(i, self.cycle) {
                if self.apply_seu(plan) {
                    ctx.mark_applied_at(i);
                }
            }
        }

        let mut detect: u32 = 0;
        let mut sched_done = false;
        // Tile coordinates *before* the FSMs advance: a fault detected on
        // a tile's last cycle must latch THAT tile into the progress
        // register, not its successor.
        let tile_now = (self.sched.mt, self.sched.kt);
        let tile_now_rep = (self.sched_rep.mt, self.sched_rep.kt);

        if self.ctrl_state == CTRL_RUN {
            // Continuous register-file parity verification (§3.3).
            if self.regfile.parity_violation(ctx) {
                detect |= cause::REGFILE_PARITY;
            }

            // Lockstep comparison of the schedule FSMs at the *register
            // outputs*, i.e. before this cycle's logic consumes them. An
            // upset that would self-heal at the next transition (e.g. a
            // counter flip that immediately saturates a phase) is still a
            // one-cycle divergence on the comparator and must abort —
            // the corrupted value already drove one cycle of addresses.
            if self.protection.has_control_protection()
                && self.sched.compare_key() != self.sched_rep.compare_key()
            {
                detect |= cause::FSM_MISMATCH;
            }

            let dims = self.dims();
            if !self.sched.is_illegal() {
                let phase = self.sched.phase;
                if (phase as usize) < 6 {
                    self.perf.phase_cycles[phase as usize] += 1;
                }
                match phase {
                    PH_LOAD_Y => self.do_load_y(&dims, tcdm, ctx, &mut detect),
                    PH_COMPUTE => self.do_compute(&dims, tcdm, ctx, true, &mut detect),
                    PH_DRAIN => self.do_compute(&dims, tcdm, ctx, false, &mut detect),
                    PH_STORE_Z => self.do_store_z(&dims, tcdm, ctx, &mut detect),
                    _ => {}
                }
            }

            // Step the schedule FSM and its lockstep replica.
            let running = self.sched.advance(&dims);
            if self.protection.has_control_protection() {
                self.sched_rep.advance(&dims);
                if self.sched.compare_key() != self.sched_rep.compare_key() {
                    detect |= cause::FSM_MISMATCH;
                }
            }
            sched_done = !running && self.sched.phase == PH_DONE;
        }

        // Resolve detections against the build's enabled detectors.
        let effective = detect & self.enabled_causes();
        let detected = effective != 0 && self.ctrl_state == CTRL_RUN;
        if detected {
            self.fault_unit.record(effective);
            self.fault_unit.record_progress(tile_now, tile_now_rep);
            // Return toward idle; the array and schedule state are
            // discarded (the host will re-program and retry).
            self.sched = Scheduler::idle();
            self.sched_rep = Scheduler::idle();
            self.array.clear();
            self.wave_pipe.fill(None);
        }

        // Control FSM (+ replica) transition. The comparator watches the
        // state *continuously*: the two instances receive identical inputs
        // every cycle, so any divergence — including an upset that knocks
        // the primary out of RUN entirely — forces the abort sequence.
        self.ctrl_state = step_ctrl(self.ctrl_state, detected, sched_done);
        self.ctrl_state_rep = step_ctrl(self.ctrl_state_rep, detected, sched_done);
        if self.protection.has_control_protection()
            && self.ctrl_state != self.ctrl_state_rep
        {
            // Comparator forces the abort sequence even if the primary FSM
            // wandered off (§3.2).
            self.fault_unit.record(cause::FSM_MISMATCH);
            self.fault_unit.record_progress(tile_now, tile_now_rep);
            self.ctrl_state = CTRL_IRQ1;
            self.ctrl_state_rep = CTRL_IRQ1;
            self.sched = Scheduler::idle();
            self.sched_rep = Scheduler::idle();
            self.array.clear();
            self.wave_pipe.fill(None);
        }

        // Interrupt wire: asserted for the two IRQ states; a transient can
        // flip one sample but not both (§3.3).
        let irq_nominal = matches!(self.ctrl_state, CTRL_IRQ1 | CTRL_IRQ2);
        self.irq_line = ctx.flag(
            SiteId::new(Module::FaultUnit, fu_sites::IRQ_NET, 0),
            irq_nominal,
        );
    }

    // ------------------------------------------------------- cast units

    /// Fetch-path cast unit (FP8 builds only; identity on FP16). Models
    /// the streamer's narrow → code-register → widen pipeline: the value
    /// is rounded to the 8-bit code, any pending [`Self::cast_upset`] SEU
    /// is consumed, the code crosses the `CASTIN_NET` fault site, and the
    /// (possibly corrupted) code is widened back onto the FP16 carrier.
    /// `lane` indexes the consumer row (X/Y) or CE column (W).
    fn cast_in(
        &mut self,
        stream: usize,
        module: Module,
        lane: u16,
        v: Fp16,
        ctx: &mut FaultCtx,
    ) -> Fp16 {
        let GemmFormat::Fp8(f) = self.cfg.format else {
            return v;
        };
        let mut code = Fp8::from_fp16(v, f, true).bits;
        code ^= core::mem::take(&mut self.cast_upset[stream]);
        let code = ctx.u8(SiteId::new(module, streamer_unit::CASTIN_NET, lane), code);
        Fp8::new(code, f).to_fp16()
    }

    /// Store-path cast unit on the Z streamer (FP8 builds only; identity
    /// on FP16). Same narrow → upset → net → widen structure as
    /// [`Self::cast_in`]; `lane` is the store lane (0..16). In FT mode
    /// only the primary copy routes through the hooked unit — the
    /// redundant copy is cast nominally by the caller so cast-stage
    /// faults surface at the Z output checker, mirroring how the replica
    /// W fetch keeps parity generation independent of the primary path.
    fn cast_out(&mut self, lane: u16, v: Fp16, ctx: &mut FaultCtx) -> Fp16 {
        let GemmFormat::Fp8(f) = self.cfg.format else {
            return v;
        };
        let mut code = Fp8::from_fp16(v, f, true).bits;
        code ^= core::mem::take(&mut self.cast_upset[STREAM_Z]);
        let code = ctx.u8(
            SiteId::new(Module::StreamerZ, streamer_unit::CASTOUT_NET, lane),
            code,
        );
        Fp8::new(code, f).to_fp16()
    }

    // ------------------------------------------------------------ phases

    /// Preload Y elements of the current tile into the accumulators.
    fn do_load_y(&mut self, dims: &Dims, tcdm: &mut Tcdm, ctx: &mut FaultCtx, detect: &mut u32) {
        let (mt, kt) = (self.sched.mt as u32, self.sched.kt as u32);
        let dk = dims.dk(kt);
        if dk == 0 {
            return;
        }
        let elems = dims.rows(mt) * dk;
        let start = u32::from(self.sched.ptr) * STREAM_ELEMS_PER_CYCLE as u32;
        let end = (start + STREAM_ELEMS_PER_CYCLE as u32).min(elems);
        let y_base = self.regfile.read(REG_Y_ADDR);
        let tcdm_bytes = tcdm.size_bytes() as u32;
        let ft = self.mode == ExecMode::FaultTolerant;
        let has_rep = self.protection.has_control_protection();

        for e in start..end {
            let lr = e / dk;
            let c = e % dk;
            let m = mt * dims.rows_per_tile + lr;
            let nominal = y_base.wrapping_add((m.wrapping_mul(dims.k) + kt * dims.d + c) * 2);
            let lane = (e % STREAM_ELEMS_PER_CYCLE as u32) as u16;
            let issue = self.streamers[STREAM_Y].issue(STREAM_Y, nominal, lane, has_rep, ctx);
            if issue.mismatch {
                *detect |= cause::STREAMER_MISMATCH;
            }
            let addr = wrap_addr(issue.addr, tcdm_bytes);
            self.perf.tcdm_reads += 1;

            if ft {
                let (row_a, row_b) = ((lr * 2) as usize, (lr * 2 + 1) as usize);
                let (va, vb, dbl) =
                    fetch_dup_protected(tcdm, addr, Module::StreamerY, lane, row_a, row_b, ctx);
                if dbl {
                    *detect |= cause::ECC_DOUBLE;
                }
                // One cast unit per consumer row (like `DEC_NET`): a cast
                // fault corrupts a single copy and surfaces at the Z
                // output checker.
                let va = self.cast_in(STREAM_Y, Module::StreamerY, row_a as u16, va, ctx);
                let vb = self.cast_in(STREAM_Y, Module::StreamerY, row_b as u16, vb, ctx);
                if c < dims.d {
                    self.array.set_acc(row_a, c as usize, va);
                    self.array.set_acc(row_b, c as usize, vb);
                }
            } else {
                let v = fetch_single(
                    tcdm,
                    addr,
                    Module::StreamerY,
                    lane,
                    lr as usize,
                    self.protection,
                    ctx,
                    detect,
                );
                let v = self.cast_in(STREAM_Y, Module::StreamerY, lr as u16, v, ctx);
                if (lr as usize) < self.cfg.l && c < dims.d {
                    self.array.set_acc(lr as usize, c as usize, v);
                }
            }
        }
    }

    /// One compute/drain cycle: retire, shift, issue, refresh W, apply FMAs.
    fn do_compute(
        &mut self,
        dims: &Dims,
        tcdm: &mut Tcdm,
        ctx: &mut FaultCtx,
        issuing: bool,
        detect: &mut u32,
    ) {
        let (mt, kt, nt, cc) = (
            self.sched.mt as u32,
            self.sched.kt as u32,
            self.sched.nt as u32,
            self.sched.cc as u32,
        );
        let dk = dims.dk(kt);
        let rows_logical = dims.rows(mt);
        let ft = self.mode == ExecMode::FaultTolerant;

        // Chunk boundary: fetch this chunk's X operands into bank nt%2.
        if issuing && cc == 0 {
            self.load_x_chunk(dims, tcdm, ctx, detect);
        }

        let issue_wave = issuing && cc < dk && dk > 0;

        // Per row: retire -> write accumulator -> issue new wave.
        for row in 0..self.cfg.l {
            let lr = if ft { (row / 2) as u32 } else { row as u32 };
            let active = lr < rows_logical;

            if let Some(r) = self.array.take_retired(row) {
                if (r.col as usize) < self.cfg.d() {
                    self.array.set_acc(row, r.col as usize, r.val);
                }
            }
            let new = if issue_wave && active {
                // Row-control gate: the issue-valid line from the driving
                // FSM (alternating primary/replica assignment in Full).
                let valid = ctx.flag(
                    SiteId::new(Module::SchedFsm, sched_unit::CTRL_NET, row as u16),
                    true,
                );
                if valid {
                    Some(InFlight {
                        nt: nt as u16,
                        col: cc as u16,
                        val: self.array.acc_at(row, cc as usize),
                    })
                } else {
                    None
                }
            } else {
                None
            };
            self.array.shift_issue(row, new);
        }

        // Mirror wave identities (row-uniform) for the W broadcast.
        for s in (1..self.cfg.d()).rev() {
            self.wave_pipe[s] = self.wave_pipe[s - 1];
        }
        self.wave_pipe[0] = if issue_wave {
            Some((nt as u16, cc as u16))
        } else {
            None
        };

        // W broadcast buffer refresh: one element per CE column whose
        // entry slot holds a wave this cycle.
        let w_base = self.regfile.read(REG_W_ADDR);
        let tcdm_bytes = tcdm.size_bytes() as u32;
        let has_rep = self.protection.has_control_protection();
        for j in 0..self.cfg.h {
            let slot = self.wave_pipe[j * self.cfg.p];
            self.array.wbuf_valid[j] = false;
            let Some((wnt, wcol)) = slot else { continue };
            let n_row = u32::from(wnt) * dims.h + j as u32;
            if n_row >= dims.n {
                continue; // tail chunk: this CE passes through
            }
            let nominal = w_base
                .wrapping_add((n_row.wrapping_mul(dims.k) + kt * dims.d + u32::from(wcol)) * 2);
            let issue = self.streamers[STREAM_W].issue(STREAM_W, nominal, j as u16, has_rep, ctx);
            if issue.mismatch {
                *detect |= cause::STREAMER_MISMATCH;
            }
            let addr = wrap_addr(issue.addr, tcdm_bytes);
            self.perf.tcdm_reads += 1;
            let mut v = tcdm.read_fp16(addr).0;
            // Cast-in sits between the TCDM response and the parity
            // generator's tap, so a cast-stage fault misaligns value and
            // parity and is caught at the CEs (FT mode).
            v = self.cast_in(STREAM_W, Module::StreamerW, j as u16, v, ctx);
            // The tiny unprotected window: decode output before the parity
            // generator taps it.
            v = ctx.fp16(SiteId::new(Module::WBuf, wbuf_unit::PRE_PARITY_NET, j as u16), v);
            let par = if self.protection.has_control_protection() {
                // §3.2: parity generated by *separate logic* — the replica
                // address path fetches its own copy (cast through its own
                // nominal unit), so a control or cast fault misaligns data
                // and parity and is caught at the CEs.
                let addr_rep = wrap_addr(issue.addr_rep, tcdm_bytes);
                weight_parity(self.cfg.format.snap(tcdm.read_fp16(addr_rep).0))
            } else {
                weight_parity(v)
            };
            self.array.wbuf_val[j] = v;
            self.array.wbuf_par[j] = par;
            self.array.wbuf_valid[j] = true;
        }

        // FMAs at CE entry slots.
        let check_w_parity =
            ft && self.protection.has_data_protection();
        let per_ce = self.protection.has_per_ce_checkers();
        for row in 0..self.cfg.l {
            let lr = if ft { (row / 2) as u32 } else { row as u32 };
            if lr >= rows_logical {
                continue;
            }
            for j in 0..self.cfg.h {
                let (wv_reg, wp_reg, wvalid) = (
                    self.array.wbuf_val[j],
                    self.array.wbuf_par[j],
                    self.array.wbuf_valid[j],
                );
                let entry = self.array.ce_entry_slot(row, j);
                let Some(e) = entry.as_mut() else { continue };
                let n_row = u32::from(e.nt) * dims.h + j as u32;
                if n_row >= dims.n || !wvalid {
                    continue; // pass-through CE
                }
                let idx = (row * self.cfg.h + j) as u16;
                // Operand nets.
                let bank = (e.nt % 2) as usize;
                let x_raw = self.array.x_at(bank, row, j);
                let x = ctx.fp16(SiteId::new(Module::CeArray, ce_unit::X_NET, idx), x_raw);
                // The W register + per-row broadcast tap.
                let wv0 = ctx.fp16(SiteId::new(Module::WBuf, wbuf_unit::VALUE_REG, j as u16), wv_reg);
                let wp = ctx.u32(
                    SiteId::new(Module::WBuf, wbuf_unit::PARITY_REG, j as u16),
                    wp_reg as u32,
                ) as u8;
                let wv = ctx.fp16(SiteId::new(Module::CeArray, ce_unit::W_NET, idx), wv0);
                if check_w_parity && !weight_parity_ok(wv, wp) {
                    *detect |= cause::W_PARITY;
                }
                let entry = self.array.ce_entry_slot(row, j).as_mut().unwrap();
                let acc_in = entry.val;
                let res = op_step16(self.cfg.op, x, wv, acc_in);
                entry.val = ctx.fp16(SiteId::new(Module::CeArray, ce_unit::FMA_NET, idx), res);
                if per_ce {
                    // [8]-style localized checker: an independent reduced
                    // datapath recomputes the configured op from the
                    // *register* operands and compares at the CE output.
                    // Catches transients on the CE's own operand/result
                    // nets — and nothing upstream of the operand
                    // registers, which is exactly the coverage gap §1
                    // argues about.
                    let recompute = op_step16(self.cfg.op, x_raw, wv_reg, acc_in);
                    let eq_nominal = recompute.to_bits() == entry.val.to_bits();
                    let eq = ctx.flag(
                        SiteId::new(Module::Checker, checker_unit::PERCE_CMP_NET, idx),
                        eq_nominal,
                    );
                    if !eq {
                        *detect |= cause::CE_CHECK;
                    }
                }
                self.perf.macs += 1;
            }
        }
    }

    /// Fetch one chunk's X operands (H per logical row) into bank nt%2.
    fn load_x_chunk(&mut self, dims: &Dims, tcdm: &mut Tcdm, ctx: &mut FaultCtx, detect: &mut u32) {
        let (mt, nt) = (self.sched.mt as u32, self.sched.nt as u32);
        let bank = (nt % 2) as usize;
        let x_base = self.regfile.read(REG_X_ADDR);
        let tcdm_bytes = tcdm.size_bytes() as u32;
        let ft = self.mode == ExecMode::FaultTolerant;
        let has_rep = self.protection.has_control_protection();
        for lr in 0..dims.rows(mt) {
            let m = mt * dims.rows_per_tile + lr;
            for j in 0..self.cfg.h {
                let n_col = nt * dims.h + j as u32;
                if n_col >= dims.n {
                    // Zero the register so a stale value can't leak in.
                    if ft {
                        self.array.set_x(bank, (lr * 2) as usize, j, Fp16::ZERO);
                        self.array.set_x(bank, (lr * 2 + 1) as usize, j, Fp16::ZERO);
                    } else {
                        self.array.set_x(bank, lr as usize, j, Fp16::ZERO);
                    }
                    continue;
                }
                let nominal = x_base.wrapping_add((m.wrapping_mul(dims.n) + n_col) * 2);
                let lane = (lr * dims.h.min(16) + j as u32) as u16 % 64;
                let issue = self.streamers[STREAM_X].issue(STREAM_X, nominal, lane, has_rep, ctx);
                if issue.mismatch {
                    *detect |= cause::STREAMER_MISMATCH;
                }
                let addr = wrap_addr(issue.addr, tcdm_bytes);
                self.perf.tcdm_reads += 1;
                if ft {
                    let (ra, rb) = ((lr * 2) as usize, (lr * 2 + 1) as usize);
                    let (va, vb, dbl) =
                        fetch_dup_protected(tcdm, addr, Module::StreamerX, lane, ra, rb, ctx);
                    if dbl {
                        *detect |= cause::ECC_DOUBLE;
                    }
                    let va = self.cast_in(STREAM_X, Module::StreamerX, ra as u16, va, ctx);
                    let vb = self.cast_in(STREAM_X, Module::StreamerX, rb as u16, vb, ctx);
                    self.array.set_x(bank, ra, j, va);
                    self.array.set_x(bank, rb, j, vb);
                } else {
                    let v = fetch_single(
                        tcdm,
                        addr,
                        Module::StreamerX,
                        lane,
                        lr as usize,
                        self.protection,
                        ctx,
                        detect,
                    );
                    let v = self.cast_in(STREAM_X, Module::StreamerX, lr as u16, v, ctx);
                    self.array.set_x(bank, lr as usize, j, v);
                }
            }
        }
    }

    /// Stream the tile's accumulators out through checker + write filter.
    fn do_store_z(&mut self, dims: &Dims, tcdm: &mut Tcdm, ctx: &mut FaultCtx, detect: &mut u32) {
        let (mt, kt) = (self.sched.mt as u32, self.sched.kt as u32);
        let dk = dims.dk(kt);
        if dk == 0 {
            return;
        }
        let elems = dims.rows(mt) * dk;
        let start = u32::from(self.sched.ptr) * STREAM_ELEMS_PER_CYCLE as u32;
        let end = (start + STREAM_ELEMS_PER_CYCLE as u32).min(elems);
        let z_base = self.regfile.read(REG_Z_ADDR);
        let tcdm_bytes = tcdm.size_bytes() as u32;
        let ft = self.mode == ExecMode::FaultTolerant;
        let has_rep = self.protection.has_control_protection();
        let store_parity = self.protection.has_control_protection();

        for e in start..end {
            let lr = e / dk;
            let c = e % dk;
            let m = mt * dims.rows_per_tile + lr;
            let nominal = z_base.wrapping_add((m.wrapping_mul(dims.k) + kt * dims.d + c) * 2);
            let lane = (e % STREAM_ELEMS_PER_CYCLE as u32) as u16;
            let issue = self.streamers[STREAM_Z].issue(STREAM_Z, nominal, lane, has_rep, ctx);
            if issue.mismatch {
                *detect |= cause::STREAMER_MISMATCH;
            }
            let addr = wrap_addr(issue.addr, tcdm_bytes);
            // In the Full build the replica's write request is compared
            // against the primary *before* the store commits, so a
            // divergent address never reaches the TCDM (§3.2). Without the
            // replica a corrupted store lands wherever the bad address
            // points.
            if has_rep && issue.mismatch {
                continue;
            }

            let value = if ft {
                let (ra, rb) = ((lr * 2) as usize, (lr * 2 + 1) as usize);
                if c as usize >= self.cfg.d() || rb >= self.cfg.l {
                    continue;
                }
                // Cast-out runs where each copy leaves its accumulator;
                // the hooked unit serves the primary copy and the
                // redundant copy is cast nominally, so a cast-stage fault
                // desynchronizes the pair and trips the output checker.
                let a0 = self.array.acc_at(ra, c as usize);
                let a1 = self.array.acc_at(rb, c as usize);
                let z0 = self.cast_out(lane, a0, ctx);
                let z1 = self.cfg.format.snap(a1);
                // The two copies travel on separate store nets ...
                let v0 = ctx.fp16(
                    SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, lane),
                    z0,
                );
                let v1 = ctx.fp16(
                    SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 16 + lane),
                    z1,
                );
                // ... and the checker compares them (§3.1, Fig. 1 (4)).
                let eq_nominal = v0.to_bits() == v1.to_bits();
                let eq = ctx.flag(
                    SiteId::new(Module::Checker, checker_unit::Z_CMP_NET, lr as u16),
                    eq_nominal,
                );
                if !eq {
                    *detect |= cause::Z_MISMATCH;
                }
                // Write filter drops the redundant write; its decision net
                // is compared against the replica streamer's write-enable
                // in the Full build.
                let suppress = ctx.flag(
                    SiteId::new(Module::Checker, checker_unit::WFILTER_NET, lane),
                    true,
                );
                if !suppress {
                    if has_rep {
                        *detect |= cause::STREAMER_MISMATCH;
                    }
                    // Duplicate write to the same address: harmless when
                    // the pair agrees (and flagged above when it doesn't).
                    tcdm.write_fp16(addr, v1);
                    self.perf.tcdm_writes += 1;
                }
                v0
            } else {
                if lr as usize >= self.cfg.l || c as usize >= self.cfg.d() {
                    continue;
                }
                let a = self.array.acc_at(lr as usize, c as usize);
                let z = self.cast_out(lane, a, ctx);
                ctx.fp16(
                    SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, lane),
                    z,
                )
            };

            // Post-checker store segment: parity-carried in the Full build.
            let par = weight_parity(value);
            let stored = ctx.fp16(
                SiteId::new(Module::StreamerZ, streamer_unit::STORE_NET, 32 + lane),
                value,
            );
            if store_parity && !weight_parity_ok(stored, par) {
                *detect |= cause::STORE_PARITY;
            }
            tcdm.write_fp16(addr, stored);
            self.perf.tcdm_writes += 1;

            // ABFT checksum unit: tap the committed store value at its
            // logical (row, column) position. The tap net is a fault site
            // of its own — a transient here corrupts only the observed
            // sum (a spurious mismatch), never the stored data.
            if self.abft.armed() {
                let tapped = ctx.fp16(
                    SiteId::new(Module::Checker, checker_unit::ABFT_TAP_NET, lane),
                    stored,
                );
                let col = (kt * dims.d + c) as usize;
                self.abft.observe(m as usize, col, tapped);
                // Online residual taps (`AbftOnline`): observe the value
                // presented to the store network and the committed value;
                // a store-path corruption leaves the exact delta in the
                // residual banks. The pre-store tap net is a fault site of
                // its own — a transient there fabricates a residual (a
                // spurious locate attempt) without touching the data.
                if self.abft.online() {
                    let pre = ctx.fp16(
                        SiteId::new(Module::Checker, checker_unit::ABFT_ONLINE_TAP_NET, lane),
                        value,
                    );
                    self.abft.observe_online(m as usize, col, pre, stored);
                }
            }
        }
    }

    // --------------------------------------------------------------- SEUs

    /// Apply a state-upset to live state. Returns `true` if the fault hit
    /// real storage (false = architecturally masked, e.g. an empty slot).
    pub fn apply_seu(&mut self, plan: FaultPlan) -> bool {
        let site = plan.site;
        let (unit, index, bit) = (site.unit(), site.index(), plan.bit);
        match site.module() {
            Module::RegFile => match unit {
                regfile_unit::WORD => self.regfile.flip_word_bit(index, bit),
                regfile_unit::PARITY => self.regfile.flip_parity_bit(index),
                _ => false,
            },
            Module::XBuf => self.array.flip_x_bit(index, bit),
            Module::Accumulator => self.array.flip_acc_bit(index, bit),
            Module::CeArray => match unit {
                ce_unit::PIPE_REG => self.array.flip_pipe_bit(index, bit),
                _ => false,
            },
            Module::SchedFsm => match unit {
                sched_unit::STATE_REG => {
                    self.sched.flip_phase(bit);
                    true
                }
                sched_unit::COUNT_REG => self.sched.flip_counter(index as u16, bit),
                _ => false,
            },
            Module::CtrlFsm => match unit {
                ctrl_unit::STATE_REG => {
                    self.ctrl_state ^= 1 << (bit % 3);
                    true
                }
                _ => false,
            },
            Module::FsmReplica => match unit {
                0 => {
                    self.sched_rep.flip_phase(bit);
                    true
                }
                1 => self.sched_rep.flip_counter(index as u16, bit),
                2 => {
                    self.ctrl_state_rep ^= 1 << (bit % 3);
                    true
                }
                _ => false,
            },
            Module::StreamerX => self.flip_stream_mask(STREAM_X, unit, bit),
            Module::StreamerW => self.flip_stream_mask(STREAM_W, unit, bit),
            Module::StreamerY => self.flip_stream_mask(STREAM_Y, unit, bit),
            Module::StreamerZ => self.flip_stream_mask(STREAM_Z, unit, bit),
            Module::StreamerReplica => {
                // unit = stream*2 (mask register of the replica).
                let stream = (unit / 2) as usize;
                if unit % 2 == 0 && stream < 4 {
                    self.streamers[stream].flip_replica_mask_bit(bit);
                    true
                } else {
                    false
                }
            }
            Module::FaultUnit => match unit {
                fu_sites::STATUS_REG => {
                    self.fault_unit.flip_status_bit(bit);
                    true
                }
                _ => false,
            },
            Module::Checker => match unit {
                // ABFT accumulator bank: row accumulators first, then the
                // column bank (hardware indices 0..L+D). The physical slot
                // holds the logical row/column of the tile currently in
                // flight, so the upset lands on whatever sum is resident —
                // an idle slot (tail tile) is architecturally masked.
                checker_unit::ABFT_ACC_REG => {
                    let l = self.cfg.l as u32;
                    let dims = self.dims();
                    if index < l {
                        let row = u32::from(self.sched.mt) * dims.rows_per_tile + index;
                        self.abft.flip_row_acc_bit(row as usize, bit)
                    } else {
                        let col = u32::from(self.sched.kt) * dims.d + (index - l);
                        self.abft.flip_col_acc_bit(col as usize, bit)
                    }
                }
                // Online residual bank (`AbftOnline`): same physical
                // row-then-column indexing as the accumulator bank.
                checker_unit::ABFT_RES_REG => {
                    let l = self.cfg.l as u32;
                    let dims = self.dims();
                    if index < l {
                        let row = u32::from(self.sched.mt) * dims.rows_per_tile + index;
                        self.abft.flip_res_row_bit(row as usize, bit)
                    } else {
                        let col = u32::from(self.sched.kt) * dims.d + (index - l);
                        self.abft.flip_res_col_bit(col as usize, bit)
                    }
                }
                _ => false,
            },
            _ => false,
        }
    }

    fn flip_stream_mask(&mut self, stream: usize, unit: u8, bit: u8) -> bool {
        match unit {
            streamer_unit::ADDR_REG => {
                self.streamers[stream].flip_mask_bit(bit);
                true
            }
            // Cast-unit code registers (FP8 builds only — the registry
            // never samples these sites on FP16 populations). The pending
            // mask is consumed by the stream's next cast.
            streamer_unit::CASTIN_REG | streamer_unit::CASTOUT_REG
                if self.cfg.format.is_fp8() =>
            {
                self.cast_upset[stream] ^= 1 << (bit % 8);
                true
            }
            _ => false,
        }
    }

    /// Nominal (fault-free) cycle count for the committed task.
    pub fn nominal_cycles(&self) -> u64 {
        Scheduler::nominal_cycles(&self.dims())
    }
}

/// Control-FSM transition function (shared by primary and replica).
fn step_ctrl(cur: u8, detected: bool, sched_done: bool) -> u8 {
    match cur {
        CTRL_RUN => {
            if detected {
                CTRL_IRQ1
            } else if sched_done {
                CTRL_DONE
            } else {
                CTRL_RUN
            }
        }
        CTRL_IRQ1 => CTRL_IRQ2,
        CTRL_IRQ2 => CTRL_IDLE,
        other => other, // IDLE / DONE latched; illegal encodings halt
    }
}

/// Protected fetch: the raw SECDED codeword is duplicated **before**
/// decoding, one decoder per consumer row (§3.1). A single-bit transient
/// on the shared response net is therefore *corrected* by both decoders;
/// a fault inside one decoder corrupts only that row's copy and surfaces
/// at the output checker.
fn fetch_dup_protected(
    tcdm: &mut Tcdm,
    addr: u32,
    module: Module,
    lane: u16,
    row_a: usize,
    row_b: usize,
    ctx: &mut FaultCtx,
) -> (Fp16, Fp16, bool) {
    let word_addr = addr & !3;
    let cw = tcdm.raw_codeword(word_addr);
    // Shared response net carries the 39-bit codeword.
    let cw = ctx.u64(SiteId::new(module, streamer_unit::RESP_NET, lane), cw) & ((1 << 39) - 1);
    let (word, status) = decode32(cw);
    let half = if addr & 2 == 0 {
        word as u16
    } else {
        (word >> 16) as u16
    };
    let va = ctx.fp16(
        SiteId::new(module, streamer_unit::DEC_NET, row_a as u16),
        Fp16::from_bits(half),
    );
    let vb = ctx.fp16(
        SiteId::new(module, streamer_unit::DEC_NET, row_b as u16),
        Fp16::from_bits(half),
    );
    (va, vb, status == DecodeStatus::DoubleError)
}

/// Unprotected (baseline) or single-consumer (performance-mode) fetch.
#[allow(clippy::too_many_arguments)]
fn fetch_single(
    tcdm: &mut Tcdm,
    addr: u32,
    module: Module,
    lane: u16,
    row: usize,
    protection: Protection,
    ctx: &mut FaultCtx,
    detect: &mut u32,
) -> Fp16 {
    if protection.has_data_protection() {
        // The streamer still decodes ECC (single consumer).
        let word_addr = addr & !3;
        let cw = tcdm.raw_codeword(word_addr);
        let cw = ctx.u64(SiteId::new(module, streamer_unit::RESP_NET, lane), cw) & ((1 << 39) - 1);
        let (word, status) = decode32(cw);
        if status == DecodeStatus::DoubleError {
            *detect |= cause::ECC_DOUBLE;
        }
        let half = if addr & 2 == 0 {
            word as u16
        } else {
            (word >> 16) as u16
        };
        ctx.fp16(
            SiteId::new(module, streamer_unit::DEC_NET, row as u16),
            Fp16::from_bits(half),
        )
    } else {
        // Baseline: the response net carries bare FP16 data.
        let v = tcdm.read_fp16(addr).0;
        ctx.fp16(SiteId::new(module, streamer_unit::RESP_NET, lane), v)
    }
}
