//! RedMulE instance configuration and execution modes.

use crate::fp::{GemmFormat, GemmOp};

/// Hardware build parameters of a RedMulE instance (§2.1): a 2-D array of
/// `L` rows × `H` compute elements per row, each CE an FP16 FMA with `P`
/// internal pipeline registers.
///
/// Derived quantity `D = H·P`: the number of output columns a row keeps in
/// flight. A row's cascaded chain of `H` FMAs has a latency of `H·P`
/// cycles; issuing one output column per cycle for `D` cycles hides that
/// latency completely, which is exactly how RedMulE reaches one FMA per CE
/// per cycle in steady state.
///
/// Beyond the array geometry the config carries the *task datatype*: the
/// operand storage [`GemmFormat`] (FP16, or an FP8 grid routed through
/// cast-in/cast-out units) and the reduction [`GemmOp`] (classic FMA or
/// the add/mul-max/min family). Both default to the paper instance
/// (`Fp16` / `Mul`), so every pre-existing call site is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RedMuleConfig {
    /// Number of compute rows (paper instance: 12).
    pub l: usize,
    /// CEs (cascaded FMAs) per row (paper instance: 4).
    pub h: usize,
    /// Pipeline registers per CE (paper instance: 3).
    pub p: usize,
    /// Operand storage format (default [`GemmFormat::Fp16`]).
    pub format: GemmFormat,
    /// Reduction op each CE performs (default [`GemmOp::Mul`]).
    pub op: GemmOp,
}

impl RedMuleConfig {
    pub fn new(l: usize, h: usize, p: usize) -> Self {
        assert!(l >= 1 && h >= 1 && p >= 1, "degenerate array");
        Self {
            l,
            h,
            p,
            format: GemmFormat::Fp16,
            op: GemmOp::Mul,
        }
    }

    /// The instance evaluated in the paper: L=12, H=4, P=3, FP16.
    pub fn paper() -> Self {
        Self::new(12, 4, 3)
    }

    /// Same geometry, different operand storage format.
    pub fn with_format(mut self, format: GemmFormat) -> Self {
        self.format = format;
        self
    }

    /// Same geometry, different reduction op.
    pub fn with_op(mut self, op: GemmOp) -> Self {
        self.op = op;
        self
    }

    /// In-flight output columns per row (`D = H·P`), which is also the
    /// column-tile width of the schedule.
    #[inline]
    pub fn d(&self) -> usize {
        self.h * self.p
    }

    /// Peak multiply-accumulate throughput (MACs per cycle).
    #[inline]
    pub fn macs_per_cycle(&self) -> usize {
        self.l * self.h
    }

    /// Number of CEs in the array.
    #[inline]
    pub fn n_ce(&self) -> usize {
        self.l * self.h
    }
}

/// Which protection hardware is *built in* — the three synthesized
/// versions compared in §4, plus two related-work comparators:
///
/// 1. `Baseline` — the unprotected RedMulE of [7].
/// 2. `Data` — §3.1 only: duplicated read responses + per-row ECC
///    decoding, redundant computation on consecutive rows, parity-checked
///    weight broadcast, output checker, TCDM write filter.
/// 3. `Full` — `Data` plus §3.2: reduced-width replica streamers,
///    duplicated control/scheduler FSMs with comparators, parity-protected
///    register file, alternating row-to-FSM assignment.
/// 4. `PerCe` — the prior approach of [8] (Ulbricht et al.): one
///    localized recompute-and-compare checker per compute element. It
///    guards the FMA datapath only; buffers, weight-broadcast paths and
///    control logic stay exposed — the gap §1 calls out and the
///    `ablation_protection` bench quantifies.
/// 5. `Abft` — algorithm-based fault tolerance (Huang & Abraham; FT-GEMM,
///    Wu et al. 2023): the classic third point in the replication-vs-code
///    design space. The host stages row/column checksum vectors with the
///    operands, the array carries them through the GEMM as one extra
///    row/column, and a small checksum unit on the writeback path
///    accumulates the observed row/column sums of `Z` and compares them
///    against the carried checksums — detecting *and locating* corrupted
///    output rows so the host can recompute only the affected row band
///    instead of the whole matrix. No row duplication, so throughput
///    stays at performance-mode level; coverage is bounded by the FP16
///    rounding tolerance of the checksum identity (see
///    [`crate::golden::abft_tolerance`]).
/// 6. `AbftOnline` — the online-fused variant (FT-GEMM, Wu et al. 2023;
///    "Anatomy of High-Performance GEMM with Online Fault Tolerance on
///    GPUs", Zhai et al. 2023): the checksum unit additionally taps the
///    store network *before and after* the commit point and accumulates
///    exact per-row/per-column store residuals while the tile streams
///    out. A single corrupted output element shows up as the (row, col)
///    intersection of the nonzero residuals and is corrected *in place*
///    from the residual value — detect+correct instead of
///    detect+recompute, so single store-path errors cost a handful of
///    host cycles rather than a row-band recompute. Corruptions the
///    residual taps cannot see (upstream of the store network) still
///    fall back to the carried-checksum check and row-band recompute of
///    the base `Abft` build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Protection {
    Baseline,
    Data,
    Full,
    PerCe,
    Abft,
    AbftOnline,
}

impl Protection {
    pub fn name(self) -> &'static str {
        match self {
            Protection::Baseline => "baseline",
            Protection::Data => "data",
            Protection::Full => "full",
            Protection::PerCe => "per-ce",
            Protection::Abft => "abft",
            Protection::AbftOnline => "abft-online",
        }
    }

    /// Does this build have the §3.1 data-path machinery?
    pub fn has_data_protection(self) -> bool {
        matches!(self, Protection::Data | Protection::Full)
    }

    /// Does this build have the §3.2 control-path machinery?
    pub fn has_control_protection(self) -> bool {
        matches!(self, Protection::Full)
    }

    /// Does this build have [8]-style localized per-CE checkers?
    pub fn has_per_ce_checkers(self) -> bool {
        matches!(self, Protection::PerCe)
    }

    /// Does this build have the ABFT writeback checksum unit?
    pub fn has_abft_checksums(self) -> bool {
        matches!(self, Protection::Abft | Protection::AbftOnline)
    }

    /// Does this build additionally have the online residual taps that
    /// enable in-place single-error correction?
    pub fn has_online_abft(self) -> bool {
        matches!(self, Protection::AbftOnline)
    }
}

/// Runtime-selected execution mode (§3.4), configured in the register file
/// before the task starts.
///
/// * `FaultTolerant` — redundant computation on consecutive row pairs plus
///   all built-in checkers; detected faults abort the workload so the host
///   can retry. Throughput is halved (half the rows carry unique work).
/// * `Performance` — every row carries unique work. On `Data`/`Full`
///   builds the control-path redundancy (if built in) stays active and
///   detected faults abort the workload, but computations are not
///   duplicated so data-path faults go undetected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecMode {
    Performance,
    FaultTolerant,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Performance => "performance",
            ExecMode::FaultTolerant => "fault-tolerant",
        }
    }
}

/// Byte layout of one GEMM task in TCDM, programmed into the register
/// file. All matrices are row-major FP16.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskLayout {
    pub x_addr: u32,
    pub w_addr: u32,
    pub y_addr: u32,
    pub z_addr: u32,
    pub m: u32,
    pub n: u32,
    pub k: u32,
}

impl TaskLayout {
    /// Pack matrices back-to-back starting at `base`, 4-byte aligned.
    pub fn contiguous(base: u32, m: u32, n: u32, k: u32) -> Self {
        let align = |v: u32| (v + 3) & !3;
        let x_addr = align(base);
        let w_addr = align(x_addr + 2 * m * n);
        let y_addr = align(w_addr + 2 * n * k);
        let z_addr = align(y_addr + 2 * m * k);
        Self {
            x_addr,
            w_addr,
            y_addr,
            z_addr,
            m,
            n,
            k,
        }
    }

    /// Total bytes of TCDM this task touches.
    pub fn footprint(&self) -> u32 {
        self.z_addr + 2 * self.m * self.k - self.x_addr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_instance_parameters() {
        let c = RedMuleConfig::paper();
        assert_eq!((c.l, c.h, c.p), (12, 4, 3));
        assert_eq!(c.d(), 12);
        assert_eq!(c.macs_per_cycle(), 48);
        assert_eq!(c.n_ce(), 48);
    }

    #[test]
    fn protection_capability_matrix() {
        assert!(!Protection::Baseline.has_data_protection());
        assert!(Protection::Data.has_data_protection());
        assert!(!Protection::Data.has_control_protection());
        assert!(Protection::Full.has_data_protection());
        assert!(Protection::Full.has_control_protection());
        // ABFT is an error-detecting-code build: no replication machinery.
        assert!(!Protection::Abft.has_data_protection());
        assert!(!Protection::Abft.has_control_protection());
        assert!(!Protection::Abft.has_per_ce_checkers());
        assert!(Protection::Abft.has_abft_checksums());
        assert!(!Protection::Abft.has_online_abft());
        // The online variant is the base checksum build plus residual taps.
        assert!(!Protection::AbftOnline.has_data_protection());
        assert!(!Protection::AbftOnline.has_control_protection());
        assert!(!Protection::AbftOnline.has_per_ce_checkers());
        assert!(Protection::AbftOnline.has_abft_checksums());
        assert!(Protection::AbftOnline.has_online_abft());
        for p in [Protection::Baseline, Protection::Data, Protection::Full, Protection::PerCe] {
            assert!(!p.has_abft_checksums(), "{p:?}");
            assert!(!p.has_online_abft(), "{p:?}");
        }
    }

    #[test]
    fn contiguous_layout_is_disjoint_and_aligned() {
        let t = TaskLayout::contiguous(0x100, 12, 16, 16);
        assert_eq!(t.x_addr % 4, 0);
        assert!(t.w_addr >= t.x_addr + 2 * 12 * 16);
        assert!(t.y_addr >= t.w_addr + 2 * 16 * 16);
        assert!(t.z_addr >= t.y_addr + 2 * 12 * 16);
        assert!(t.footprint() >= 2 * (12 * 16 + 16 * 16 + 2 * 12 * 16));
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_rows_rejected() {
        RedMuleConfig::new(0, 4, 3);
    }

    #[test]
    fn format_and_op_default_to_paper_instance() {
        use crate::fp::{Fp8Format, GemmFormat, GemmOp};
        let c = RedMuleConfig::paper();
        assert_eq!(c.format, GemmFormat::Fp16);
        assert_eq!(c.op, GemmOp::Mul);
        let c8 = c
            .with_format(GemmFormat::Fp8(Fp8Format::E4M3))
            .with_op(GemmOp::AddMax);
        assert_eq!(c8.format, GemmFormat::Fp8(Fp8Format::E4M3));
        assert_eq!(c8.op, GemmOp::AddMax);
        // Geometry untouched, and the default-path config still compares
        // equal to a freshly built one (WorkerArena reuse relies on this).
        assert_eq!((c8.l, c8.h, c8.p), (c.l, c.h, c.p));
        assert_eq!(RedMuleConfig::paper(), c);
        assert_ne!(c8, c);
    }
}
