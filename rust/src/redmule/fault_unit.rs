//! Fault status registers and interrupt generation (§3.3).
//!
//! On detection: (1) the status registers capture the cause, (2) the
//! interrupt line is asserted for **two consecutive cycles** so a single
//! transient on the wire cannot make the host miss it, (3) the FSM returns
//! to idle so the host can re-program and retry.

/// Detection-cause bits (the fault status register layout).
pub mod cause {
    /// Weight parity violated at a CE (§3.1).
    pub const W_PARITY: u32 = 1 << 0;
    /// Redundant row pair disagreed at the output checker (§3.1).
    pub const Z_MISMATCH: u32 = 1 << 1;
    /// Primary/replica FSM state divergence (§3.2).
    pub const FSM_MISMATCH: u32 = 1 << 2;
    /// Primary/replica streamer control divergence (§3.2).
    pub const STREAMER_MISMATCH: u32 = 1 << 3;
    /// Register-file parity violation (§3.2).
    pub const REGFILE_PARITY: u32 = 1 << 4;
    /// Uncorrectable ECC error on a memory response (§3.1).
    pub const ECC_DOUBLE: u32 = 1 << 5;
    /// Store-path parity violation between checker and encoder (Full).
    pub const STORE_PARITY: u32 = 1 << 6;
    /// Localized per-CE recompute checker disagreed ([8]-style builds).
    pub const CE_CHECK: u32 = 1 << 7;
    /// ABFT row/column checksum verification failed at writeback (`Abft`
    /// builds; raised by the host driver, not the FSM abort path).
    pub const ABFT_CHECKSUM: u32 = 1 << 8;

    pub const ALL: u32 = 0x1FF;

    pub fn names(bits: u32) -> Vec<&'static str> {
        let mut v = Vec::new();
        if bits & W_PARITY != 0 {
            v.push("w-parity");
        }
        if bits & Z_MISMATCH != 0 {
            v.push("z-mismatch");
        }
        if bits & FSM_MISMATCH != 0 {
            v.push("fsm-mismatch");
        }
        if bits & STREAMER_MISMATCH != 0 {
            v.push("streamer-mismatch");
        }
        if bits & REGFILE_PARITY != 0 {
            v.push("regfile-parity");
        }
        if bits & ECC_DOUBLE != 0 {
            v.push("ecc-double");
        }
        if bits & STORE_PARITY != 0 {
            v.push("store-parity");
        }
        if bits & CE_CHECK != 0 {
            v.push("ce-check");
        }
        if bits & ABFT_CHECKSUM != 0 {
            v.push("abft-checksum");
        }
        v
    }
}

/// Fault status registers + interrupt bookkeeping.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultUnit {
    /// Sticky cause bits, readable (and clearable) by the host.
    pub status: u32,
    /// Total detections since last clear (second status register).
    pub detect_count: u32,
    /// Tile-progress register (§5 future work): the conservative
    /// `(mt, kt)` the task can safely resume from, latched at the first
    /// detection since clear.
    pub progress: (u16, u16),
    progress_valid: bool,
}

impl FaultUnit {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latch a detection's cause bits.
    pub fn record(&mut self, causes: u32) {
        self.status |= causes;
        self.detect_count = self.detect_count.wrapping_add(1);
    }

    /// Latch the resume tile at the first detection since clear. Under
    /// the single-fault assumption one of the two lockstep schedulers is
    /// uncorrupted; the lexicographic minimum is safe either way (a too-
    /// early resume only redoes committed tiles, which is idempotent).
    pub fn record_progress(&mut self, primary: (u16, u16), replica: (u16, u16)) {
        if !self.progress_valid {
            self.progress = primary.min(replica);
            self.progress_valid = true;
        }
    }

    /// Host-side read-and-clear (after acknowledging the interrupt).
    /// Returns (status, detect_count, resume_tile).
    pub fn read_clear(&mut self) -> (u32, u32) {
        let out = (self.status, self.detect_count);
        self.status = 0;
        self.detect_count = 0;
        self.progress_valid = false;
        out
    }

    /// The latched resume tile (valid between detection and clear).
    pub fn progress_tile(&self) -> (u16, u16) {
        if self.progress_valid {
            self.progress
        } else {
            (0, 0)
        }
    }

    /// SEU hook on the status register bits.
    pub fn flip_status_bit(&mut self, bit: u8) {
        self.status ^= 1 << (bit & 31);
    }

    /// Fold the status/progress registers into a fast-forward digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        h.write_u32(self.status);
        h.write_u32(self.detect_count);
        h.write_u16(self.progress.0);
        h.write_u16(self.progress.1);
        h.write_bool(self.progress_valid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_and_clears() {
        let mut f = FaultUnit::new();
        f.record(cause::W_PARITY);
        f.record(cause::Z_MISMATCH);
        assert_eq!(f.status, cause::W_PARITY | cause::Z_MISMATCH);
        assert_eq!(f.detect_count, 2);
        let (s, c) = f.read_clear();
        assert_eq!(s, cause::W_PARITY | cause::Z_MISMATCH);
        assert_eq!(c, 2);
        assert_eq!(f.status, 0);
    }

    #[test]
    fn cause_names_cover_all_bits() {
        assert_eq!(cause::names(cause::ALL).len(), 9);
        assert!(cause::names(0).is_empty());
        assert_eq!(cause::names(cause::ECC_DOUBLE), vec!["ecc-double"]);
        assert_eq!(cause::names(cause::ABFT_CHECKSUM), vec!["abft-checksum"]);
    }

    #[test]
    fn progress_latches_min_of_lockstep_pair_once() {
        let mut f = FaultUnit::new();
        assert_eq!(f.progress_tile(), (0, 0));
        f.record_progress((3, 1), (2, 7));
        assert_eq!(f.progress_tile(), (2, 7));
        // Later detections in the same abort window don't move it.
        f.record_progress((9, 9), (9, 9));
        assert_eq!(f.progress_tile(), (2, 7));
        f.read_clear();
        assert_eq!(f.progress_tile(), (0, 0));
    }

    #[test]
    fn seu_flip_is_visible() {
        let mut f = FaultUnit::new();
        f.flip_status_bit(3);
        assert_eq!(f.status, 1 << 3);
    }
}
