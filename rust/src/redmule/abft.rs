//! ABFT writeback checksum unit (`Protection::Abft`).
//!
//! A bank of wide fixed-point accumulators sits on the Z store path: as
//! each result element streams out, the unit adds its value (and its
//! magnitude, which scales the verification tolerance) into the running
//! sum of the element's logical row and column. The host reads the
//! accumulated sums after task completion and compares them against the
//! checksum row/column the GEMM carried through the array (see
//! [`crate::golden::abft_tolerance`] and the recovery flow in
//! [`crate::cluster`]).
//!
//! Sums are exact 2^-24 fixed point ([`crate::golden::fp16_to_fixed`]),
//! so accumulation order cannot introduce error and an SEU on an
//! accumulator register is a plain stored-bit flip — both the input tap
//! nets and the accumulator registers are fault sites with area-derived
//! weights (`ft/abft*` in [`crate::area`]).
//!
//! The model keeps one row accumulator per output row and one column
//! accumulator per data column of the *task* (the hardware equivalent
//! tiles this through `L + D` physical accumulators; the area model
//! charges for the physical bank).
//!
//! `Protection::AbftOnline` adds a second, *online* bank: a tap pair on
//! the store network observes each element both before and after the
//! commit point and accumulates the exact per-row/per-column store
//! residual `stored − pre` in two planes — the 2^-24 fixed-point value
//! plane and the raw bit-pattern plane. A fault-free store contributes
//! zero to both; a store-path corruption leaves the exact delta at the
//! (row, col) intersection of the nonzero residuals, from which the host
//! reconstructs the original bit pattern and corrects the element in
//! place (see [`crate::golden::analyze_residuals`]). The bit plane is
//! what makes the correction bit-exact even for value-preserving
//! corruptions (±0 sign flips, NaN payloads).

use crate::fp::Fp16;
use crate::golden::{fixed_to_f64, fp16_to_fixed};

/// Width of one physical accumulator register in bits (fault-site and
/// area-model width: sign + 16 integer + 24 fractional + margin).
pub const ABFT_ACC_BITS: u8 = 48;

/// The checksum unit: armed per task with the augmented task dimensions.
#[derive(Debug, Clone, Default)]
pub struct AbftUnit {
    armed: bool,
    /// Data columns of the task (`k_aug - 1`): the carried checksum
    /// column itself is excluded from the observed sums.
    data_cols: usize,
    /// Rows of the task; the last row (the carried checksum row) is
    /// excluded from the column sums.
    rows: usize,
    row_fx: Vec<i64>,
    row_abs_fx: Vec<i64>,
    col_fx: Vec<i64>,
    col_abs_fx: Vec<i64>,
    /// Online residual banks (`Protection::AbftOnline` only): exact
    /// `stored − pre` store residuals per row/column, in the fixed-point
    /// value plane and the raw bit plane. All-zero on a clean run.
    online: bool,
    res_row_fx: Vec<i64>,
    res_row_bits: Vec<i64>,
    res_col_fx: Vec<i64>,
    res_col_bits: Vec<i64>,
}

impl AbftUnit {
    /// Arm for a task of `m` rows × `k` columns (augmented dimensions,
    /// both ≥ 1). Clears all accumulators.
    pub fn arm(&mut self, m: usize, k: usize) {
        self.armed = true;
        self.rows = m;
        self.data_cols = k.saturating_sub(1);
        self.row_fx = vec![0; m];
        self.row_abs_fx = vec![0; m];
        self.col_fx = vec![0; self.data_cols];
        self.col_abs_fx = vec![0; self.data_cols];
        self.online = false;
        self.res_row_fx.clear();
        self.res_row_bits.clear();
        self.res_col_fx.clear();
        self.res_col_bits.clear();
    }

    /// Arm with the online residual banks too (`Protection::AbftOnline`):
    /// the residual taps cover the *whole* augmented result, carried
    /// checksum row/column included, so any store corruption is
    /// locatable.
    pub fn arm_online(&mut self, m: usize, k: usize) {
        self.arm(m, k);
        self.online = true;
        self.res_row_fx = vec![0; m];
        self.res_row_bits = vec![0; m];
        self.res_col_fx = vec![0; k];
        self.res_col_bits = vec![0; k];
    }

    /// Disarm (builds without the unit, or tasks without the ABFT flag).
    pub fn disarm(&mut self) {
        self.armed = false;
        self.row_fx.clear();
        self.row_abs_fx.clear();
        self.col_fx.clear();
        self.col_abs_fx.clear();
        self.online = false;
        self.res_row_fx.clear();
        self.res_row_bits.clear();
        self.res_col_fx.clear();
        self.res_col_bits.clear();
    }

    #[inline]
    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Is the online residual bank live (armed via [`Self::arm_online`])?
    #[inline]
    pub fn online(&self) -> bool {
        self.armed && self.online
    }

    /// Observe one stored element at logical position `(row, col)` of the
    /// augmented result. Out-of-range coordinates (possible only under
    /// injected control faults) are ignored, like a store the decoder
    /// does not claim.
    #[inline]
    pub fn observe(&mut self, row: usize, col: usize, v: Fp16) {
        if !self.armed || row >= self.rows || col >= self.data_cols {
            return;
        }
        let fx = fp16_to_fixed(v);
        self.row_fx[row] += fx;
        self.row_abs_fx[row] += fx.abs();
        if row + 1 < self.rows {
            self.col_fx[col] += fx;
            self.col_abs_fx[col] += fx.abs();
        }
    }

    /// Observe one store through the online residual taps: `pre` is the
    /// value presented to the store network, `stored` what was committed
    /// to TCDM. A fault-free store contributes exactly zero to both
    /// planes; a corrupted one leaves the exact delta at its row and
    /// column.
    #[inline]
    pub fn observe_online(&mut self, row: usize, col: usize, pre: Fp16, stored: Fp16) {
        if !self.online()
            || row >= self.res_row_fx.len()
            || col >= self.res_col_fx.len()
        {
            return;
        }
        let dfx = fp16_to_fixed(stored) - fp16_to_fixed(pre);
        let dbits = stored.to_bits() as i64 - pre.to_bits() as i64;
        self.res_row_fx[row] += dfx;
        self.res_row_bits[row] += dbits;
        self.res_col_fx[col] += dfx;
        self.res_col_bits[col] += dbits;
    }

    /// Online row residual banks: (fixed-point plane, bit plane).
    pub fn res_rows(&self) -> (&[i64], &[i64]) {
        (&self.res_row_fx, &self.res_row_bits)
    }

    /// Online column residual banks: (fixed-point plane, bit plane).
    pub fn res_cols(&self) -> (&[i64], &[i64]) {
        (&self.res_col_fx, &self.res_col_bits)
    }

    /// Clear the online residual banks after the host consumed them
    /// (post-correction revalidation starts from a clean slate).
    pub fn clear_residuals(&mut self) {
        for bank in [
            &mut self.res_row_fx,
            &mut self.res_row_bits,
            &mut self.res_col_fx,
            &mut self.res_col_bits,
        ] {
            bank.iter_mut().for_each(|v| *v = 0);
        }
    }

    /// Host-side fix-up after an in-place correction: migrate the
    /// writeback observation of `(row, col)` from the corrupted stored
    /// value to the corrected one, so the carried-checksum comparison
    /// validates the repaired image rather than the corrupted one.
    pub fn adjust_observation(&mut self, row: usize, col: usize, old: Fp16, new: Fp16) {
        if !self.armed || row >= self.rows || col >= self.data_cols {
            return;
        }
        let (ofx, nfx) = (fp16_to_fixed(old), fp16_to_fixed(new));
        let d = nfx - ofx;
        let dabs = nfx.abs() - ofx.abs();
        self.row_fx[row] += d;
        self.row_abs_fx[row] += dabs;
        if row + 1 < self.rows {
            self.col_fx[col] += d;
            self.col_abs_fx[col] += dabs;
        }
    }

    /// Observed row sum / magnitude sum (data columns only).
    pub fn row_sum(&self, row: usize) -> f64 {
        fixed_to_f64(self.row_fx.get(row).copied().unwrap_or(0))
    }

    pub fn row_abs(&self, row: usize) -> f64 {
        fixed_to_f64(self.row_abs_fx.get(row).copied().unwrap_or(0))
    }

    /// Observed column sum / magnitude sum (data rows only).
    pub fn col_sum(&self, col: usize) -> f64 {
        fixed_to_f64(self.col_fx.get(col).copied().unwrap_or(0))
    }

    pub fn col_abs(&self, col: usize) -> f64 {
        fixed_to_f64(self.col_abs_fx.get(col).copied().unwrap_or(0))
    }

    /// Fold the armed state and every accumulator into a fast-forward
    /// digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        h.write_bool(self.armed);
        h.write_bool(self.online);
        h.write_u64(self.rows as u64);
        h.write_u64(self.data_cols as u64);
        for bank in [
            &self.row_fx,
            &self.row_abs_fx,
            &self.col_fx,
            &self.col_abs_fx,
            &self.res_row_fx,
            &self.res_row_bits,
            &self.res_col_fx,
            &self.res_col_bits,
        ] {
            h.write_u64(bank.len() as u64);
            for &v in bank.iter() {
                h.write_i64(v);
            }
        }
    }

    /// SEU hook: flip a stored bit of row accumulator `index`. Returns
    /// `false` (architecturally masked) when the bank slot is not live.
    pub fn flip_row_acc_bit(&mut self, index: usize, bit: u8) -> bool {
        match self.row_fx.get_mut(index) {
            Some(v) if self.armed => {
                *v ^= 1i64 << (bit % ABFT_ACC_BITS);
                true
            }
            _ => false,
        }
    }

    /// SEU hook: flip a stored bit of column accumulator `index`.
    pub fn flip_col_acc_bit(&mut self, index: usize, bit: u8) -> bool {
        match self.col_fx.get_mut(index) {
            Some(v) if self.armed => {
                *v ^= 1i64 << (bit % ABFT_ACC_BITS);
                true
            }
            _ => false,
        }
    }

    /// SEU hook: flip a stored bit of online row-residual register
    /// `index` (fixed-point plane — the plane the locate logic trusts
    /// least, so an upset degrades to a fail-safe fallback, never a
    /// wrong correction).
    pub fn flip_res_row_bit(&mut self, index: usize, bit: u8) -> bool {
        let live = self.online();
        match self.res_row_fx.get_mut(index) {
            Some(v) if live => {
                *v ^= 1i64 << (bit % ABFT_ACC_BITS);
                true
            }
            _ => false,
        }
    }

    /// SEU hook: flip a stored bit of online column-residual register
    /// `index`.
    pub fn flip_res_col_bit(&mut self, index: usize, bit: u8) -> bool {
        let live = self.online();
        match self.res_col_fx.get_mut(index) {
            Some(v) if live => {
                *v ^= 1i64 << (bit % ABFT_ACC_BITS);
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::add16;

    #[test]
    fn observes_exact_sums_excluding_checksum_row_and_column() {
        let mut u = AbftUnit::default();
        assert!(!u.armed());
        u.arm(3, 4); // 3 rows (last = checksum row), 3 data cols
        assert!(u.armed());
        let v = Fp16::from_f64(1.5);
        for row in 0..3 {
            for col in 0..4 {
                u.observe(row, col, v);
            }
        }
        // Row sums count data columns only (3 of the 4).
        for row in 0..3 {
            assert_eq!(u.row_sum(row), 4.5, "row {row}");
            assert_eq!(u.row_abs(row), 4.5);
        }
        // Column sums exclude the checksum row (2 of the 3 rows).
        for col in 0..3 {
            assert_eq!(u.col_sum(col), 3.0, "col {col}");
        }
        // Out-of-range observations are ignored.
        u.observe(9, 0, v);
        u.observe(0, 9, v);
        assert_eq!(u.row_sum(0), 4.5);
    }

    #[test]
    fn negative_values_and_magnitudes() {
        let mut u = AbftUnit::default();
        u.arm(2, 3);
        u.observe(0, 0, Fp16::from_f64(-2.0));
        u.observe(0, 1, Fp16::from_f64(0.5));
        assert_eq!(u.row_sum(0), -1.5);
        assert_eq!(u.row_abs(0), 2.5);
    }

    #[test]
    fn accumulation_is_exact_for_fp16_inputs() {
        // 2^-24 fixed point: the sum of any FP16 values equals the f64
        // sum exactly (no accumulation-order dependence).
        let mut u = AbftUnit::default();
        u.arm(2, 100);
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let mut expect = 0.0f64;
        let mut fold = Fp16::ZERO;
        for col in 0..99 {
            let v = rng.next_fp16_in(1.0);
            u.observe(0, col, v);
            expect += v.to_f64();
            fold = add16(fold, v);
        }
        assert_eq!(u.row_sum(0), expect);
        // ... and generally differs from the FP16 fold (rounding).
        assert!((u.row_sum(0) - fold.to_f64()).abs() < 0.1);
    }

    #[test]
    fn online_residuals_are_zero_on_clean_stores_and_exact_on_corrupt_ones() {
        let mut u = AbftUnit::default();
        u.arm(3, 4);
        assert!(!u.online(), "plain arm must not enable the residual taps");
        u.observe_online(0, 0, Fp16::ONE, Fp16::from_f64(2.0));
        assert!(u.res_rows().0.is_empty(), "disabled taps accumulate nothing");

        u.arm_online(3, 4);
        assert!(u.online());
        let v = Fp16::from_f64(1.5);
        // Clean stores across the whole augmented tile, checksum row/col
        // included: residuals stay exactly zero.
        for row in 0..3 {
            for col in 0..4 {
                u.observe_online(row, col, v, v);
            }
        }
        assert!(u.res_rows().0.iter().all(|&x| x == 0));
        assert!(u.res_rows().1.iter().all(|&x| x == 0));
        assert!(u.res_cols().0.iter().all(|&x| x == 0));
        assert!(u.res_cols().1.iter().all(|&x| x == 0));
        // One corrupted store: the exact delta lands at (1, 2) in both
        // planes, and the bit plane recovers the original pattern.
        let bad = Fp16::from_bits(v.to_bits() ^ (1 << 14));
        u.observe_online(1, 2, v, bad);
        let (rfx, rbits) = u.res_rows();
        assert_eq!(rfx[1], fp16_to_fixed(bad) - fp16_to_fixed(v));
        assert_eq!(rbits[1], bad.to_bits() as i64 - v.to_bits() as i64);
        assert_eq!(rfx[0], 0);
        let (cfx, cbits) = u.res_cols();
        assert_eq!(cfx[2], rfx[1]);
        assert_eq!(cbits[2], rbits[1]);
        let recovered = (bad.to_bits() as i64 - rbits[1]) as u16;
        assert_eq!(recovered, v.to_bits(), "bit plane must invert the corruption");
        // Value-preserving corruption (+0 -> -0): only the bit plane sees it.
        u.clear_residuals();
        u.observe_online(0, 0, Fp16::ZERO, Fp16::from_bits(0x8000));
        assert_eq!(u.res_rows().0[0], 0, "fx plane is value-blind to signed zero");
        assert_eq!(u.res_rows().1[0], 0x8000);
        u.clear_residuals();
        assert!(u.res_rows().1.iter().all(|&x| x == 0));
        assert!(u.online(), "clearing residuals must not disarm");
    }

    #[test]
    fn adjust_observation_migrates_writeback_sums() {
        let mut u = AbftUnit::default();
        u.arm_online(3, 4);
        let bad = Fp16::from_f64(8.0);
        let good = Fp16::from_f64(-1.5);
        for col in 0..3 {
            u.observe(0, col, if col == 1 { bad } else { good });
        }
        u.adjust_observation(0, 1, bad, good);
        assert_eq!(u.row_sum(0), -4.5);
        assert_eq!(u.row_abs(0), 4.5);
        assert_eq!(u.col_sum(1), -1.5);
        // Checksum-column / out-of-range targets are ignored.
        u.adjust_observation(0, 3, bad, good);
        u.adjust_observation(9, 0, bad, good);
        assert_eq!(u.row_sum(0), -4.5);
    }

    #[test]
    fn residual_seu_hooks_hit_live_online_slots_only() {
        let mut u = AbftUnit::default();
        assert!(!u.flip_res_row_bit(0, 3), "disarmed unit has no residual state");
        u.arm(4, 5);
        assert!(!u.flip_res_row_bit(0, 3), "plain ABFT build has no residual bank");
        u.arm_online(4, 5);
        assert!(u.flip_res_row_bit(0, 24));
        assert_eq!(u.res_rows().0[0], 1 << 24);
        assert_eq!(u.res_rows().1[0], 0, "bit plane untouched: planes disagree");
        assert!(u.flip_res_col_bit(4, 0), "residual cols cover the checksum column");
        assert!(!u.flip_res_col_bit(5, 0));
        u.arm_online(4, 5);
        assert_eq!(u.res_rows().0[0], 0, "re-arming clears the upset");
    }

    #[test]
    fn seu_hooks_hit_live_slots_only() {
        let mut u = AbftUnit::default();
        assert!(!u.flip_row_acc_bit(0, 3), "disarmed unit has no state");
        u.arm(4, 5);
        assert!(u.flip_row_acc_bit(0, 24)); // 2^24 fx = 1.0
        assert_eq!(u.row_sum(0), 1.0);
        assert!(u.flip_row_acc_bit(0, 24));
        assert_eq!(u.row_sum(0), 0.0);
        assert!(u.flip_col_acc_bit(3, 25));
        assert_eq!(u.col_sum(3), 2.0);
        assert!(!u.flip_row_acc_bit(99, 0));
        assert!(!u.flip_col_acc_bit(99, 0));
        // Re-arming clears the upset.
        u.arm(4, 5);
        assert_eq!(u.col_sum(3), 0.0);
    }
}
