//! Configuration register file with shadowed contexts (§2.1, §3.2).
//!
//! RedMulE is programmed through a HWPE-style register file with **two
//! shadowed contexts**: the host can write the next task's configuration
//! while the current task runs, then commit it atomically on offload. In
//! the fully protected build every word carries an XOR parity bit
//! *computed by the cluster cores in software* (a one-time cost the paper
//! bounds at 120 cycles per workload) and a hardware checker continuously
//! re-derives the parity of the active context; any mismatch raises a
//! fault.

use crate::ecc::config_parity;
use crate::fault::site::{regfile_unit, Module, SiteId};
use crate::fault::FaultCtx;

/// Word indices within one context.
pub const REG_X_ADDR: usize = 0;
pub const REG_W_ADDR: usize = 1;
pub const REG_Y_ADDR: usize = 2;
pub const REG_Z_ADDR: usize = 3;
pub const REG_M: usize = 4;
pub const REG_N: usize = 5;
pub const REG_K: usize = 6;
/// Flags: bit 0 = fault-tolerant mode (redundant compute), bit 1 =
/// tile-level recovery enabled (resume from [`REG_RESUME`]), bit 2 =
/// ABFT checksum mode (the staged task carries one checksum row/column
/// and the writeback checksum unit is armed); others reserved.
pub const REG_FLAGS: usize = 7;
/// Resume tile for tile-level recovery: `mt << 16 | kt` (§5 future work).
pub const REG_RESUME: usize = 8;
/// Words per context (the real regfile has more; unused words read zero).
pub const WORDS: usize = 16;
/// Number of shadowed contexts.
pub const CONTEXTS: usize = 2;

pub const FLAG_FT_MODE: u32 = 1 << 0;
pub const FLAG_TILE_RECOVERY: u32 = 1 << 1;
pub const FLAG_ABFT: u32 = 1 << 2;

/// The register file: `CONTEXTS` shadowed copies of `WORDS` words plus
/// (in protected builds) one parity bit per word.
#[derive(Debug, Clone)]
pub struct RegFile {
    words: [[u32; WORDS]; CONTEXTS],
    parity: [[u8; WORDS]; CONTEXTS],
    /// Context used by the currently running task.
    active: usize,
    /// True if the hardware parity checker is present (Full protection).
    check_parity: bool,
}

impl RegFile {
    pub fn new(check_parity: bool) -> Self {
        Self {
            words: [[0; WORDS]; CONTEXTS],
            parity: [[0; WORDS]; CONTEXTS],
            active: 0,
            check_parity,
        }
    }

    /// Host-side write into the *shadow* (inactive) context.
    pub fn host_write(&mut self, word: usize, value: u32) {
        let ctx = 1 - self.active;
        self.words[ctx][word] = value;
    }

    /// Host-side parity write (software-computed, §3.2).
    pub fn host_write_parity(&mut self, word: usize, parity: u8) {
        let ctx = 1 - self.active;
        self.parity[ctx][word] = parity & 1;
    }

    /// Convenience: program a whole context (values + parity bits).
    pub fn host_program(&mut self, values: &[(usize, u32)]) {
        for &(w, v) in values {
            self.host_write(w, v);
            self.host_write_parity(w, config_parity(v));
        }
    }

    /// Commit the shadow context: it becomes active for the next task.
    pub fn commit(&mut self) {
        self.active = 1 - self.active;
    }

    pub fn active_context(&self) -> usize {
        self.active
    }

    /// Hardware read of an active-context word (used by FSMs every cycle).
    #[inline]
    pub fn read(&self, word: usize) -> u32 {
        self.words[self.active][word]
    }

    /// Continuous parity check over the active context (§3.3: "RedMulE-FT
    /// continuously verifies the integrity of the register file").
    /// Returns `true` if a parity violation is detected this cycle.
    pub fn parity_violation(&self, ctx: &mut FaultCtx) -> bool {
        if !self.check_parity {
            return false;
        }
        let c = self.active;
        for w in 0..WORDS {
            // The checker itself is hardware: its recomputed parity net is
            // a (replicated, compared — see checker.rs) fault site handled
            // by the caller; here we model the ideal comparison.
            let _ = ctx;
            if config_parity(self.words[c][w]) != self.parity[c][w] & 1 {
                return true;
            }
        }
        false
    }

    /// SEU hook: flip a stored configuration bit.
    /// `index` encodes `ctx*WORDS + word`.
    pub fn flip_word_bit(&mut self, index: u32, bit: u8) -> bool {
        let ctx = (index as usize) / WORDS;
        let word = (index as usize) % WORDS;
        if ctx >= CONTEXTS {
            return false;
        }
        self.words[ctx][word] ^= 1 << (bit & 31);
        true
    }

    /// SEU hook: flip a stored parity bit.
    pub fn flip_parity_bit(&mut self, index: u32) -> bool {
        let ctx = (index as usize) / WORDS;
        let word = (index as usize) % WORDS;
        if ctx >= CONTEXTS {
            return false;
        }
        self.parity[ctx][word] ^= 1;
        true
    }

    /// Fold both shadowed contexts (values, parity bits, active selector)
    /// into a fast-forward digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        for ctx in 0..CONTEXTS {
            for w in 0..WORDS {
                h.write_u32(self.words[ctx][w]);
                h.write_u8(self.parity[ctx][w]);
            }
        }
        h.write_u8(self.active as u8);
    }

    /// Site id of a configuration word (for the registry).
    pub fn word_site(ctx: usize, word: usize) -> SiteId {
        SiteId::new(Module::RegFile, regfile_unit::WORD, (ctx * WORDS + word) as u16)
    }

    pub fn parity_site(ctx: usize, word: usize) -> SiteId {
        SiteId::new(Module::RegFile, regfile_unit::PARITY, (ctx * WORDS + word) as u16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn programmed() -> RegFile {
        let mut rf = RegFile::new(true);
        rf.host_program(&[
            (REG_X_ADDR, 0x100),
            (REG_W_ADDR, 0x400),
            (REG_M, 12),
            (REG_N, 16),
            (REG_K, 16),
            (REG_FLAGS, FLAG_FT_MODE),
        ]);
        rf.commit();
        rf
    }

    #[test]
    fn shadow_write_then_commit() {
        let mut rf = RegFile::new(false);
        rf.host_write(REG_M, 99);
        // Not visible before commit.
        assert_eq!(rf.read(REG_M), 0);
        rf.commit();
        assert_eq!(rf.read(REG_M), 99);
        // New shadow is the old active context.
        rf.host_write(REG_M, 7);
        assert_eq!(rf.read(REG_M), 99);
        rf.commit();
        assert_eq!(rf.read(REG_M), 7);
    }

    #[test]
    fn parity_clean_after_host_program() {
        let rf = programmed();
        let mut ctx = FaultCtx::clean();
        assert!(!rf.parity_violation(&mut ctx));
    }

    #[test]
    fn seu_on_word_is_detected_by_parity() {
        let mut rf = programmed();
        let active = rf.active_context();
        assert!(rf.flip_word_bit((active * WORDS + REG_M) as u32, 3));
        let mut ctx = FaultCtx::clean();
        assert!(rf.parity_violation(&mut ctx));
    }

    #[test]
    fn seu_on_parity_bit_is_detected() {
        let mut rf = programmed();
        let active = rf.active_context();
        assert!(rf.flip_parity_bit((active * WORDS + REG_N) as u32));
        let mut ctx = FaultCtx::clean();
        assert!(rf.parity_violation(&mut ctx));
    }

    #[test]
    fn seu_on_inactive_context_is_not_flagged() {
        let mut rf = programmed();
        let inactive = 1 - rf.active_context();
        assert!(rf.flip_word_bit((inactive * WORDS + REG_M) as u32, 3));
        let mut ctx = FaultCtx::clean();
        assert!(!rf.parity_violation(&mut ctx));
    }

    #[test]
    fn unprotected_regfile_never_flags() {
        let mut rf = RegFile::new(false);
        rf.host_write(REG_M, 5); // no parity written
        rf.commit();
        rf.flip_word_bit((rf.active_context() * WORDS + REG_M) as u32, 0);
        let mut ctx = FaultCtx::clean();
        assert!(!rf.parity_violation(&mut ctx));
    }
}
