//! The compute-element array: operand buffers, FMA pipelines and
//! output-stationary accumulators.
//!
//! Mechanics (per row): a chain of `H` cascaded FMA units, each with `P`
//! pipeline registers, modelled as a shift queue of `D = H·P` slots. A
//! *wave* — one output column's partial accumulation — enters at slot 0,
//! receives CE `j`'s FMA when it lands in slot `j·P`, and retires from
//! slot `D-1` into the accumulator. One wave issues per cycle per row, so
//! the array sustains `L·H` MACs/cycle with the pipeline exactly hidden.
//!
//! The X operand registers are **double-buffered** (banked by inner-chunk
//! parity): a wave from chunk `nt` is still in flight while chunk `nt+1`'s
//! operands load, so each chunk's X elements live in bank `nt % 2` — the
//! same skew the RTL implements with per-CE operand registers.
//!
//! Every stored bit here is a fault site: X operand registers (`XBuf`),
//! W broadcast registers + parity (`WBuf`), pipeline slot registers
//! (`CeArray`), and accumulators (`Accumulator`).

use crate::fp::Fp16;

/// One in-flight wave: which inner chunk/column it belongs to plus the
/// running partial value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InFlight {
    pub nt: u16,
    pub col: u16,
    pub val: Fp16,
}

/// Array state for an `L × H × P` instance.
#[derive(Debug, Clone)]
pub struct CeArray {
    pub l: usize,
    pub h: usize,
    pub p: usize,
    pub d: usize,
    /// Pipeline slots, row-major: `slots[row * d + s]`.
    pub slots: Vec<Option<InFlight>>,
    /// Accumulators, row-major: `acc[row * d + col]`.
    pub acc: Vec<Fp16>,
    /// X operand registers, two banks: `xbuf[bank * l * h + row * h + j]`.
    pub xbuf: Vec<Fp16>,
    /// W broadcast value registers (one per CE column, shared by rows).
    pub wbuf_val: Vec<Fp16>,
    /// W broadcast parity bits (FT builds).
    pub wbuf_par: Vec<u8>,
    /// W broadcast valid flags (tail chunks leave columns idle).
    pub wbuf_valid: Vec<bool>,
}

impl CeArray {
    pub fn new(l: usize, h: usize, p: usize) -> Self {
        let d = h * p;
        Self {
            l,
            h,
            p,
            d,
            slots: vec![None; l * d],
            acc: vec![Fp16::ZERO; l * d],
            xbuf: vec![Fp16::ZERO; 2 * l * h],
            wbuf_val: vec![Fp16::ZERO; h],
            wbuf_par: vec![0; h],
            wbuf_valid: vec![false; h],
        }
    }

    /// Reset all pipeline/buffer state (start of task or after abort).
    pub fn clear(&mut self) {
        self.slots.fill(None);
        self.acc.fill(Fp16::ZERO);
        self.xbuf.fill(Fp16::ZERO);
        self.wbuf_val.fill(Fp16::ZERO);
        self.wbuf_par.fill(0);
        self.wbuf_valid.fill(false);
    }

    /// Take the wave retiring from `row` this cycle (slot `D-1`). The
    /// caller writes it to the accumulator **before** issuing a new wave,
    /// matching the RTL's retire-then-issue ordering within a cycle.
    #[inline]
    pub fn take_retired(&mut self, row: usize) -> Option<InFlight> {
        self.slots[row * self.d + self.d - 1].take()
    }

    /// Shift `row`'s pipeline by one slot and inject `new` at slot 0.
    /// Must be called after [`CeArray::take_retired`].
    #[inline]
    pub fn shift_issue(&mut self, row: usize, new: Option<InFlight>) {
        let base = row * self.d;
        for s in (1..self.d).rev() {
            self.slots[base + s] = self.slots[base + s - 1];
        }
        self.slots[base] = new;
    }

    /// Entries currently sitting at CE entry positions (slot `j·P`) for
    /// `row`; the caller applies the FMA for CE `j` to each.
    #[inline]
    pub fn ce_entry_slot(&mut self, row: usize, j: usize) -> &mut Option<InFlight> {
        &mut self.slots[row * self.d + j * self.p]
    }

    #[inline]
    pub fn acc_at(&self, row: usize, col: usize) -> Fp16 {
        self.acc[row * self.d + col]
    }

    #[inline]
    pub fn set_acc(&mut self, row: usize, col: usize, v: Fp16) {
        self.acc[row * self.d + col] = v;
    }

    /// X operand of CE `j` in `row`, from chunk-parity bank `bank`.
    #[inline]
    pub fn x_at(&self, bank: usize, row: usize, j: usize) -> Fp16 {
        self.xbuf[bank * self.l * self.h + row * self.h + j]
    }

    #[inline]
    pub fn set_x(&mut self, bank: usize, row: usize, j: usize, v: Fp16) {
        self.xbuf[bank * self.l * self.h + row * self.h + j] = v;
    }

    /// True if any pipeline slot is occupied (used to validate drain).
    pub fn pipelines_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Copy another array's state into this one, reusing the existing
    /// buffer allocations (checkpoint restore — the campaign hot path).
    pub fn restore_from(&mut self, other: &CeArray) {
        debug_assert_eq!((self.l, self.h, self.p), (other.l, other.h, other.p));
        self.slots.clone_from(&other.slots);
        self.acc.clone_from(&other.acc);
        self.xbuf.clone_from(&other.xbuf);
        self.wbuf_val.clone_from(&other.wbuf_val);
        self.wbuf_par.clone_from(&other.wbuf_par);
        self.wbuf_valid.clone_from(&other.wbuf_valid);
    }

    /// Fold every stored bit into a fast-forward digest.
    pub fn digest_into(&self, h: &mut crate::util::digest::Fnv64) {
        for s in &self.slots {
            match s {
                None => h.write_u8(0),
                Some(e) => {
                    h.write_u8(1);
                    h.write_u16(e.nt);
                    h.write_u16(e.col);
                    h.write_u16(e.val.to_bits());
                }
            }
        }
        for v in &self.acc {
            h.write_u16(v.to_bits());
        }
        for v in &self.xbuf {
            h.write_u16(v.to_bits());
        }
        for (j, v) in self.wbuf_val.iter().enumerate() {
            h.write_u16(v.to_bits());
            h.write_u8(self.wbuf_par[j]);
            h.write_bool(self.wbuf_valid[j]);
        }
    }

    // ---------------------------------------------------------- SEU hooks

    /// Flip a bit of the wave value in pipeline slot `index = row*D + s`.
    /// Misses (empty slot / out of range) return false — the fault is
    /// architecturally masked.
    pub fn flip_pipe_bit(&mut self, index: u32, bit: u8) -> bool {
        match self.slots.get_mut(index as usize) {
            Some(Some(e)) => {
                e.val = Fp16::from_bits(e.val.to_bits() ^ (1 << (bit & 15)));
                true
            }
            _ => false,
        }
    }

    /// Flip an accumulator bit (`index = row*D + col`).
    pub fn flip_acc_bit(&mut self, index: u32, bit: u8) -> bool {
        match self.acc.get_mut(index as usize) {
            Some(v) => {
                *v = Fp16::from_bits(v.to_bits() ^ (1 << (bit & 15)));
                true
            }
            None => false,
        }
    }

    /// Flip an X operand register bit (`index = bank*L*H + row*H + j`).
    pub fn flip_x_bit(&mut self, index: u32, bit: u8) -> bool {
        match self.xbuf.get_mut(index as usize) {
            Some(v) => {
                *v = Fp16::from_bits(v.to_bits() ^ (1 << (bit & 15)));
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_row(a: &mut CeArray, row: usize, new: Option<InFlight>) -> Option<InFlight> {
        let r = a.take_retired(row);
        a.shift_issue(row, new);
        r
    }

    #[test]
    fn shift_queue_retires_in_order_after_d_cycles() {
        let mut a = CeArray::new(2, 4, 3); // d = 12
        let mk = |col: u16| {
            Some(InFlight {
                nt: 0,
                col,
                val: Fp16::from_f64(col as f64),
            })
        };
        for c in 0..12u16 {
            assert!(step_row(&mut a, 0, mk(c)).is_none(), "cycle {c}");
        }
        for c in 0..12u16 {
            let r = step_row(&mut a, 0, None).expect("retire");
            assert_eq!(r.col, c);
        }
        assert!(a.pipelines_empty());
    }

    #[test]
    fn retire_is_visible_before_issue_same_cycle() {
        // A wave retiring at cycle t must update the accumulator before
        // the same-cycle issue reads it (chunk-to-chunk dependency).
        let mut a = CeArray::new(1, 1, 2); // d = 2
        a.set_acc(0, 0, Fp16::from_f64(1.0));
        // Issue wave for col 0 reading acc.
        let v0 = a.acc_at(0, 0);
        a.shift_issue(0, Some(InFlight { nt: 0, col: 0, val: v0 }));
        a.shift_issue(0, None); // wave moves to slot 1 (= d-1)
        // Cycle t: retire first, write acc, then issue next chunk's wave.
        let mut r = a.take_retired(0).unwrap();
        r.val = Fp16::from_f64(5.0); // pretend the FMA chain produced 5
        a.set_acc(0, r.col as usize, r.val);
        let v1 = a.acc_at(0, 0);
        assert_eq!(v1.to_f64(), 5.0, "issue must observe the retired value");
        a.shift_issue(0, Some(InFlight { nt: 1, col: 0, val: v1 }));
    }

    #[test]
    fn rows_are_independent() {
        let mut a = CeArray::new(2, 2, 2); // d = 4
        let w = InFlight {
            nt: 1,
            col: 2,
            val: Fp16::ONE,
        };
        step_row(&mut a, 1, Some(w));
        assert!(a.slots[0].is_none()); // row 0 untouched
        assert_eq!(a.slots[4], Some(w));
    }

    #[test]
    fn ce_entry_positions() {
        let mut a = CeArray::new(1, 3, 2); // d = 6, CE entries at slots 0,2,4
        step_row(
            &mut a,
            0,
            Some(InFlight {
                nt: 0,
                col: 0,
                val: Fp16::ONE,
            }),
        );
        assert!(a.ce_entry_slot(0, 0).is_some());
        assert!(a.ce_entry_slot(0, 1).is_none());
        step_row(&mut a, 0, None);
        step_row(&mut a, 0, None);
        assert!(a.ce_entry_slot(0, 1).is_some()); // wave reached CE 1
        assert!(a.ce_entry_slot(0, 0).is_none());
    }

    #[test]
    fn x_banks_are_disjoint() {
        let mut a = CeArray::new(2, 2, 2);
        a.set_x(0, 1, 1, Fp16::ONE);
        a.set_x(1, 1, 1, Fp16::NEG_ONE);
        assert_eq!(a.x_at(0, 1, 1), Fp16::ONE);
        assert_eq!(a.x_at(1, 1, 1), Fp16::NEG_ONE);
        assert_eq!(a.x_at(0, 0, 0), Fp16::ZERO);
    }

    #[test]
    fn seu_hooks_hit_and_miss() {
        let mut a = CeArray::new(2, 2, 2);
        assert!(!a.flip_pipe_bit(0, 3)); // empty slot: masked
        step_row(&mut a, 0, Some(InFlight { nt: 0, col: 0, val: Fp16::ZERO }));
        assert!(a.flip_pipe_bit(0, 3));
        assert_eq!(a.slots[0].unwrap().val.to_bits(), 1 << 3);
        assert!(a.flip_acc_bit(5, 15));
        assert_eq!(a.acc[5].to_bits(), 0x8000);
        assert!(!a.flip_acc_bit(999, 0));
        // X SEU hits both banks' index space (2*L*H = 8 regs here).
        assert!(a.flip_x_bit(7, 0));
        assert_eq!(a.xbuf[7].to_bits(), 1);
        assert!(!a.flip_x_bit(8, 0));
    }

    #[test]
    fn clear_resets_everything() {
        let mut a = CeArray::new(2, 2, 2);
        step_row(&mut a, 0, Some(InFlight { nt: 0, col: 1, val: Fp16::ONE }));
        a.set_acc(1, 2, Fp16::ONE);
        a.set_x(1, 0, 1, Fp16::ONE);
        a.wbuf_val[0] = Fp16::ONE;
        a.wbuf_valid[0] = true;
        a.clear();
        assert!(a.pipelines_empty());
        assert!(a.acc.iter().all(|v| v.is_zero()));
        assert!(a.xbuf.iter().all(|v| v.is_zero()));
        assert!(a.wbuf_val.iter().all(|v| v.is_zero()));
        assert!(a.wbuf_valid.iter().all(|&v| !v));
    }
}
