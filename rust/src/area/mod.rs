//! Analytic gate-equivalent (GE) area model (§4.1, Figure 2).
//!
//! We have no 12LP+ PDK, so area is modelled *structurally*: every module's
//! GE count is derived from its architectural bit/gate inventory
//! (flip-flops, XOR trees, comparators, FMA datapaths, address generators)
//! times calibrated technology coefficients. The coefficients are fitted
//! once so that the **paper instance** (L=12, H=4, P=3, FP16) reproduces
//! the published totals — 583 kGE baseline, 596 kGE with data protection
//! (+2.3 %), 730 kGE fully protected (+25.2 %) — and the same formulas
//! then *predict* the breakdown for any other configuration, which is how
//! the ablation bench explores the paper's claim that "the relative cost
//! of fault tolerance would considerably decrease in larger
//! configurations".
//!
//! The model also keys the fault-injection site weights: the probability
//! of a uniformly chosen combinational net belonging to module *m* is
//! approximated by *m*'s share of the build's GE total (see
//! [`crate::fault::registry`]).

pub mod floorplan;

use crate::redmule::{Protection, RedMuleConfig};

/// Technology/structure coefficients (GE units, NAND2-equivalent).
/// Calibrated against the paper instance; see module docs.
pub mod coeff {
    /// One flip-flop bit incl. clock gating and mux-in glue.
    pub const GE_PER_FF_BIT: f64 = 6.5;
    /// One 2-input XOR gate.
    pub const GE_PER_XOR: f64 = 2.0;
    /// One bit of equality comparator (XNOR + AND-tree share).
    pub const GE_PER_CMP_BIT: f64 = 2.5;
    /// One bit of a carry-lookahead adder lane (the ABFT checksum
    /// accumulators' add path).
    pub const GE_PER_ADDER_BIT: f64 = 9.0;
    /// FP16 FMA datapath logic (FPnew-like, single precision mode),
    /// excluding pipeline registers.
    pub const GE_FMA16: f64 = 5400.0;
    /// Per-CE pipeline register width: FP16 value + wave tag + valid.
    pub const CE_PIPE_BITS: f64 = 26.0;
    /// One 32-bit address-generation lane: counters, adders, strides,
    /// realignment — the dominant streamer cost in RedMulE.
    pub const GE_ADDRGEN_LANE: f64 = 2750.0;
    /// Per-stream FIFO / realignment buffer depth in bits (256-bit port,
    /// double-buffered).
    pub const STREAM_FIFO_BITS: f64 = 1024.0;
    /// Scheduler FSM base (phase logic + per-counter increment/compare).
    pub const GE_SCHED_BASE: f64 = 9000.0;
    pub const GE_SCHED_PER_COUNTER: f64 = 2400.0;
    /// Top-level control FSM + handshake logic.
    pub const GE_CTRL_FSM: f64 = 9500.0;
    /// Register-file decode/readout glue per context word.
    pub const GE_REGFILE_PER_WORD: f64 = 110.0;
    /// Top-level interconnect glue, clock/reset spine, HWPE wrapper.
    pub const GE_TOP_GLUE: f64 = 26000.0;
    /// Reduced-width replica streamer cost relative to the primary
    /// (control-only: addresses + handshakes, no data FIFOs).
    pub const REPLICA_STREAMER_FRACTION: f64 = 0.51;
    /// Replica FSM cost relative to primary (same logic, no output regs).
    pub const REPLICA_FSM_FRACTION: f64 = 0.9;
    /// SECDED (39,32) encoder / decoder gate cost (XOR trees + syndrome
    /// decode), per instance.
    pub const GE_ECC_ENCODER: f64 = 160.0;
    pub const GE_ECC_DECODER: f64 = 230.0;
    /// FP16 → FP8 narrowing lane (RTNE rounder + saturation/special-case
    /// logic), per cast-unit lane. FPnew's cast slice is small next to an
    /// FMA datapath.
    pub const GE_CAST_NARROW: f64 = 180.0;
    /// FP8 → FP16 widening lane (exact expand, no rounding), per lane.
    pub const GE_CAST_WIDEN: f64 = 60.0;
    /// Per-tile NoC link interface: 64-bit serializer/deserializer,
    /// elastic FIFO, credit logic. One uplink per tile toward the
    /// reduction root.
    pub const GE_NOC_LINK_IF: f64 = 5200.0;
    /// One 5-port wormhole router slice (buffers, allocator, crossbar)
    /// amortized per tile of the mesh.
    pub const GE_NOC_ROUTER: f64 = 14000.0;
    /// Per-tile mesh sequencer: shard descriptor fetch, result push DMA,
    /// doorbell/handshake FSM.
    pub const GE_NOC_TILE_CTRL: f64 = 7500.0;
    /// Per-link CRC-16 generator + checker + seq/ack retransmit buffer
    /// control (FT overhead of the reliable-transport option).
    pub const GE_NOC_CRC: f64 = 1900.0;
    /// Reduction/merge engine at the mesh root (one instance): band
    /// placement address generation + commit FIFO.
    pub const GE_NOC_REDUCE: f64 = 9000.0;
    /// Tile heartbeat watchdog + retirement sequencer (FT overhead of
    /// the graceful-degradation option), per tile.
    pub const GE_NOC_HEARTBEAT: f64 = 1500.0;
}

/// One line of the area breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct AreaItem {
    pub name: &'static str,
    pub kge: f64,
    /// True if this item exists only because of fault-tolerance hardware
    /// (the hatched portions of Figure 2b).
    pub ft_overhead: bool,
}

/// Full area report for one build.
#[derive(Debug, Clone)]
pub struct AreaReport {
    pub cfg: RedMuleConfig,
    pub protection: Protection,
    pub items: Vec<AreaItem>,
}

impl AreaReport {
    pub fn total_kge(&self) -> f64 {
        self.items.iter().map(|i| i.kge).sum()
    }

    pub fn ft_overhead_kge(&self) -> f64 {
        self.items.iter().filter(|i| i.ft_overhead).map(|i| i.kge).sum()
    }

    /// Overhead percentage relative to a baseline report.
    pub fn overhead_vs(&self, baseline: &AreaReport) -> f64 {
        (self.total_kge() / baseline.total_kge() - 1.0) * 100.0
    }

    /// GE share of a named item group (prefix match), for site weighting.
    pub fn share_of(&self, prefix: &str) -> f64 {
        let t = self.total_kge();
        self.items
            .iter()
            .filter(|i| i.name.starts_with(prefix))
            .map(|i| i.kge)
            .sum::<f64>()
            / t
    }

    /// Render a Figure-2b-style text table.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Area breakdown — RedMulE-FT L={} H={} P={} [{}]\n",
            self.cfg.l,
            self.cfg.h,
            self.cfg.p,
            self.protection.name()
        ));
        s.push_str(&format!("{:<28} {:>10}  {}\n", "module", "kGE", "FT-overhead"));
        for i in &self.items {
            s.push_str(&format!(
                "{:<28} {:>10.1}  {}\n",
                i.name,
                i.kge,
                if i.ft_overhead { "hatched" } else { "" }
            ));
        }
        s.push_str(&format!("{:<28} {:>10.1}\n", "TOTAL", self.total_kge()));
        s
    }
}

/// Compute the area report for a build.
pub fn area_report(cfg: RedMuleConfig, protection: Protection) -> AreaReport {
    use coeff::*;
    let l = cfg.l as f64;
    let h = cfg.h as f64;
    let p = cfg.p as f64;
    let d = cfg.d() as f64;
    let n_ce = l * h;

    let mut items = Vec::new();
    let mut push = |name: &'static str, kge: f64, ft: bool| {
        items.push(AreaItem {
            name,
            kge,
            ft_overhead: ft,
        })
    };

    // ------------------------------------------------------ baseline core
    // CE array: FMA datapaths + per-CE pipeline registers.
    let ce_pipe_ge = p * CE_PIPE_BITS * GE_PER_FF_BIT;
    push("ce_array/fma", n_ce * GE_FMA16 / 1000.0, false);
    push("ce_array/pipe_regs", n_ce * ce_pipe_ge / 1000.0, false);
    // Output-stationary accumulators: L × D × 16-bit registers.
    push("accumulator", l * d * 16.0 * GE_PER_FF_BIT / 1000.0, false);
    // X operand registers (double-buffered) + W broadcast registers.
    push("xbuf", 2.0 * n_ce * 16.0 * GE_PER_FF_BIT / 1000.0, false);
    push("wbuf", h * 16.0 * GE_PER_FF_BIT / 1000.0, false);
    // Streamer: 4 streams × (addr-gen lanes + FIFO/realignment).
    let stream_ge = GE_ADDRGEN_LANE * 16.0 + STREAM_FIFO_BITS * GE_PER_FF_BIT;
    push("streamer", 4.0 * stream_ge / 1000.0, false);
    // Scheduler + control FSMs.
    push(
        "sched_fsm",
        (GE_SCHED_BASE + 5.0 * GE_SCHED_PER_COUNTER) / 1000.0,
        false,
    );
    push("ctrl_fsm", GE_CTRL_FSM / 1000.0, false);
    // Register file: 2 contexts × 16 words × 32 bits + decode glue.
    let rf_bits = 2.0 * 16.0 * 32.0;
    push(
        "regfile",
        (rf_bits * GE_PER_FF_BIT + 2.0 * 16.0 * GE_REGFILE_PER_WORD) / 1000.0,
        false,
    );
    push("top_glue", GE_TOP_GLUE / 1000.0, false);

    // ----------------------------------------- FP8 cast units (hybrid mode)
    // Present only when the build's task datatype routes operands through
    // the cast path. They are *datapath* area (`dp/`), not fault-tolerance
    // overhead: an unprotected FP8 build carries them too — which is
    // precisely why they widen the unprotected cross-section.
    if cfg.format.is_fp8() {
        let cast_lane = GE_CAST_NARROW + GE_CAST_WIDEN;
        let code_reg = 8.0 * GE_PER_FF_BIT;
        push("dp/castin_x", (l * cast_lane + code_reg) / 1000.0, false);
        push("dp/castin_w", (h * cast_lane + code_reg) / 1000.0, false);
        push("dp/castin_y", (l * cast_lane + code_reg) / 1000.0, false);
        push("dp/castout_z", (16.0 * cast_lane + code_reg) / 1000.0, false);
    }

    // --------------------------------------------- §3.1 data protection
    if protection.has_data_protection() {
        // ECC decoders: one per consumer row on X/Y responses (duplicated
        // pre-decode, §3.1) + store-path encoders.
        let n_dec = 2.0 * l + 2.0; // per-row X/Y decoders + W/Z path
        push(
            "ft/ecc_codecs",
            (n_dec * GE_ECC_DECODER + 4.0 * GE_ECC_ENCODER) / 1000.0,
            true,
        );
        // Z output checkers: one 16-bit comparator per row pair.
        push(
            "ft/z_checkers",
            (l / 2.0) * 16.0 * GE_PER_CMP_BIT / 1000.0,
            true,
        );
        // TCDM write filter.
        push("ft/write_filter", 0.45, true);
        // W parity: generator at the buffer + checker at every CE.
        let parity_tree = 16.0 * GE_PER_XOR;
        push(
            "ft/w_parity",
            ((h + n_ce) * parity_tree + h * GE_PER_FF_BIT) / 1000.0,
            true,
        );
        // Fault/ECC tracking registers + status CSRs.
        push("ft/fault_tracking", 64.0 * GE_PER_FF_BIT / 1000.0, true);
        // More complex address generators (duplicated row addressing).
        push("ft/addrgen_extra", 4.4, true);
    }

    // ------------------------------------- ABFT writeback checksum unit
    if protection.has_abft_checksums() {
        // L row + D column fixed-point accumulators on the store path:
        // 48-bit registers, one adder lane each, plus the magnitude
        // accumulation share and the tolerance compare logic. An order of
        // magnitude below replication (`Full`): no replica streamers, no
        // duplicated FSMs, no ECC machinery.
        let acc_lanes = l + d;
        let abft_bits = 48.0;
        push(
            "ft/abft_acc_regs",
            acc_lanes * abft_bits * GE_PER_FF_BIT / 1000.0,
            true,
        );
        push(
            "ft/abft_adders",
            acc_lanes * abft_bits * GE_PER_ADDER_BIT / 1000.0,
            true,
        );
        push(
            "ft/abft_compare",
            (acc_lanes * abft_bits * GE_PER_CMP_BIT + 2.0 * abft_bits * GE_PER_XOR) / 1000.0,
            true,
        );
    }

    // --------------------------- online-ABFT residual + correction unit
    if protection.has_online_abft() {
        // A second (L + D)-lane bank of 48-bit residual registers with
        // subtractor lanes for the two planes, plus the locate/correct
        // priority logic. Named `ft/online_abft*` (not `ft/abft*`) so
        // the registry's prefix sums keep the two units' weights apart.
        let acc_lanes = l + d;
        let abft_bits = 48.0;
        push(
            "ft/online_abft_res_regs",
            acc_lanes * abft_bits * GE_PER_FF_BIT / 1000.0,
            true,
        );
        push(
            "ft/online_abft_adders",
            acc_lanes * abft_bits * GE_PER_ADDER_BIT / 1000.0,
            true,
        );
        push(
            "ft/online_abft_locate",
            (acc_lanes * GE_PER_CMP_BIT + 16.0 * GE_PER_XOR) / 1000.0,
            true,
        );
    }

    // ----------------------------- [8]-style localized per-CE checkers
    if protection.has_per_ce_checkers() {
        // One reduced recompute FMA + 16-bit comparator per CE. [8]
        // reports substantial area for its checkers; we model the
        // recompute datapath at ~35 % of a full FMA.
        push(
            "ft/perce_checkers",
            n_ce * (0.35 * GE_FMA16 + 16.0 * GE_PER_CMP_BIT) / 1000.0,
            true,
        );
    }

    // ------------------------------------------ §3.2 control protection
    if protection.has_control_protection() {
        // Reduced-width replica streamers: all control, no data.
        push(
            "ft/replica_streamers",
            4.0 * stream_ge * REPLICA_STREAMER_FRACTION / 1000.0,
            true,
        );
        // Replica scheduler + control FSMs and their comparators.
        let sched_ge = GE_SCHED_BASE + 5.0 * GE_SCHED_PER_COUNTER;
        push(
            "ft/replica_fsms",
            (sched_ge + GE_CTRL_FSM) * REPLICA_FSM_FRACTION / 1000.0,
            true,
        );
        push(
            "ft/fsm_comparators",
            (96.0 * GE_PER_CMP_BIT + 4.0 * 32.0 * GE_PER_CMP_BIT) / 1000.0,
            true,
        );
        // Register-file parity storage + duplicated hardware checker.
        push(
            "ft/regfile_parity",
            (2.0 * 16.0 * GE_PER_FF_BIT + 2.0 * 16.0 * 32.0 * GE_PER_XOR) / 1000.0,
            true,
        );
        // Interrupt double-assert + abort sequencing logic.
        push("ft/irq_logic", 0.35, true);
    }

    AreaReport {
        cfg,
        protection,
        items,
    }
}

/// Area report for an N-tile RedMulE mesh: `tiles` copies of the
/// per-tile build plus the interconnect (`mesh/noc*` items). The three
/// recovery options (per-link CRC + retransmit, reduction-tree ABFT,
/// tile retirement) are the mesh's FT hardware and are marked
/// `ft_overhead` when enabled; the bare links/routers/sequencers are
/// plumbing every mesh carries. The same `mesh/noc*` GE coefficients
/// weight the interconnect fault-site sampling in
/// [`crate::mesh::NocRegistry`], mirroring how the single-tile registry
/// keys site weights off [`area_report`].
pub fn mesh_area_report(
    cfg: RedMuleConfig,
    protection: Protection,
    tiles: usize,
    link_crc: bool,
    reduction_abft: bool,
    tile_retirement: bool,
) -> AreaReport {
    use coeff::*;
    let tile = area_report(cfg, protection);
    let t = tiles as f64;
    let mut items = Vec::new();
    let tile_ft = tile.ft_overhead_kge();
    items.push(AreaItem {
        name: "mesh/tiles_base",
        kge: (tile.total_kge() - tile_ft) * t,
        ft_overhead: false,
    });
    if tile_ft > 0.0 {
        items.push(AreaItem {
            name: "mesh/tiles_ft",
            kge: tile_ft * t,
            ft_overhead: true,
        });
    }
    items.push(AreaItem {
        name: "mesh/noc-link-if",
        kge: GE_NOC_LINK_IF * t / 1000.0,
        ft_overhead: false,
    });
    items.push(AreaItem {
        name: "mesh/noc-router",
        kge: GE_NOC_ROUTER * t / 1000.0,
        ft_overhead: false,
    });
    items.push(AreaItem {
        name: "mesh/noc-tile-ctrl",
        kge: GE_NOC_TILE_CTRL * t / 1000.0,
        ft_overhead: false,
    });
    items.push(AreaItem {
        name: "mesh/noc-reduce",
        kge: GE_NOC_REDUCE / 1000.0,
        ft_overhead: false,
    });
    if link_crc {
        items.push(AreaItem {
            name: "mesh/noc-crc",
            kge: GE_NOC_CRC * t / 1000.0,
            ft_overhead: true,
        });
    }
    if reduction_abft {
        // 16 column lanes × 48-bit fixed-point accumulate/compare at the
        // reduction root (same bit inventory style as `ft/abft_*`).
        let abft_ge = 16.0 * 48.0 * (GE_PER_FF_BIT + GE_PER_ADDER_BIT + GE_PER_CMP_BIT);
        items.push(AreaItem {
            name: "mesh/noc-abft",
            kge: abft_ge / 1000.0,
            ft_overhead: true,
        });
    }
    if tile_retirement {
        items.push(AreaItem {
            name: "mesh/noc-heartbeat",
            kge: GE_NOC_HEARTBEAT * t / 1000.0,
            ft_overhead: true,
        });
    }
    AreaReport {
        cfg,
        protection,
        items,
    }
}

/// Published totals for the paper instance (kGE), used by tests and the
/// Fig. 2b bench to report model-vs-paper.
pub mod published {
    pub const BASELINE_KGE: f64 = 583.0;
    pub const DATA_KGE: f64 = 596.0;
    pub const FULL_KGE: f64 = 730.0;
    pub const DATA_OVERHEAD_PCT: f64 = 2.3;
    pub const FULL_OVERHEAD_PCT: f64 = 25.2;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper(p: Protection) -> AreaReport {
        area_report(RedMuleConfig::paper(), p)
    }

    #[test]
    fn baseline_total_matches_published_within_2pct() {
        let r = paper(Protection::Baseline);
        let err = (r.total_kge() - published::BASELINE_KGE).abs() / published::BASELINE_KGE;
        assert!(err < 0.02, "baseline {:.1} kGE vs 583 published", r.total_kge());
        assert_eq!(r.ft_overhead_kge(), 0.0);
    }

    #[test]
    fn data_protection_overhead_near_2_3_pct() {
        let b = paper(Protection::Baseline);
        let d = paper(Protection::Data);
        let ovh = d.overhead_vs(&b);
        assert!(
            (1.8..=2.8).contains(&ovh),
            "data-protection overhead {ovh:.2}% should be ≈2.3%"
        );
    }

    #[test]
    fn full_protection_overhead_near_25_2_pct() {
        let b = paper(Protection::Baseline);
        let f = paper(Protection::Full);
        let ovh = f.overhead_vs(&b);
        assert!(
            (23.0..=27.5).contains(&ovh),
            "full-protection overhead {ovh:.2}% should be ≈25.2%"
        );
    }

    #[test]
    fn ft_items_are_exactly_the_hatched_ones() {
        for p in [Protection::Full, Protection::Abft] {
            for i in &paper(p).items {
                assert_eq!(i.ft_overhead, i.name.starts_with("ft/"), "{}", i.name);
            }
        }
    }

    #[test]
    fn abft_overhead_sits_between_data_and_full() {
        // The Table-1 trade: ABFT costs more than the §3.1 parity/ECC
        // sprinkle but far less than full replication.
        let b = paper(Protection::Baseline);
        let a = paper(Protection::Abft);
        let d = paper(Protection::Data);
        let f = paper(Protection::Full);
        let ovh = a.overhead_vs(&b);
        assert!(ovh > d.overhead_vs(&b), "abft {ovh:.2}% vs data");
        assert!(ovh < 0.5 * f.overhead_vs(&b), "abft {ovh:.2}% vs full");
        assert!((1.0..=8.0).contains(&ovh), "abft overhead {ovh:.2}% out of band");
        assert!(a.ft_overhead_kge() > 0.0);
    }

    #[test]
    fn relative_ft_cost_shrinks_for_larger_arrays() {
        // §4.1: "The relative cost of fault tolerance would considerably
        // decrease in larger configurations with more FMA units."
        let small_b = area_report(RedMuleConfig::paper(), Protection::Baseline);
        let small_f = area_report(RedMuleConfig::paper(), Protection::Full);
        let big_cfg = RedMuleConfig::new(24, 8, 3);
        let big_b = area_report(big_cfg, Protection::Baseline);
        let big_f = area_report(big_cfg, Protection::Full);
        assert!(big_f.overhead_vs(&big_b) < 0.6 * small_f.overhead_vs(&small_b));
    }

    #[test]
    fn shares_sum_to_one() {
        let f = paper(Protection::Full);
        let total: f64 = f.items.iter().map(|i| i.kge).sum();
        assert!((f.items.iter().map(|i| i.kge / total).sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cast_units_appear_only_on_fp8_builds_and_are_not_ft_overhead() {
        use crate::fp::{Fp8Format, GemmFormat};
        let fp16 = paper(Protection::Baseline);
        assert!(
            !fp16.items.iter().any(|i| i.name.starts_with("dp/cast")),
            "FP16 build must not carry cast units"
        );
        let cfg8 = RedMuleConfig::paper().with_format(GemmFormat::Fp8(Fp8Format::E4M3));
        for p in [Protection::Baseline, Protection::Full, Protection::Abft] {
            let r8 = area_report(cfg8, p);
            for name in ["dp/castin_x", "dp/castin_w", "dp/castin_y", "dp/castout_z"] {
                let item = r8
                    .items
                    .iter()
                    .find(|i| i.name == name)
                    .unwrap_or_else(|| panic!("{name} missing on fp8 {p:?} build"));
                assert!(!item.ft_overhead, "{name} is datapath, not FT overhead");
                assert!(item.kge > 0.0);
            }
            // The hatched-items invariant holds on FP8 builds too.
            for i in &r8.items {
                assert_eq!(i.ft_overhead, i.name.starts_with("ft/"), "{}", i.name);
            }
        }
        // Cast units are a small share of the build, and byte-identical
        // totals on the default path.
        let base8 = area_report(cfg8, Protection::Baseline);
        let share = base8.share_of("dp/cast");
        assert!(share > 0.0 && share < 0.05, "cast share {share:.4}");
        assert_eq!(fp16.total_kge(), paper(Protection::Baseline).total_kge());
    }

    #[test]
    fn render_contains_all_modules() {
        let r = paper(Protection::Full);
        let text = r.render();
        assert!(text.contains("streamer"));
        assert!(text.contains("ft/replica_fsms"));
        assert!(text.contains("TOTAL"));
    }

    #[test]
    fn mesh_report_scales_with_tiles_and_marks_ft_options() {
        let cfg = RedMuleConfig::paper();
        let m4 = mesh_area_report(cfg, Protection::Full, 4, true, true, true);
        let m8 = mesh_area_report(cfg, Protection::Full, 8, true, true, true);
        assert!(m8.total_kge() > m4.total_kge());
        // Every recovery option contributes hatched (FT) area; the bare
        // interconnect does not.
        for name in ["mesh/noc-crc", "mesh/noc-abft", "mesh/noc-heartbeat", "mesh/tiles_ft"] {
            let i = m4.items.iter().find(|i| i.name == name).expect(name);
            assert!(i.ft_overhead, "{name}");
        }
        for name in ["mesh/noc-link-if", "mesh/noc-router", "mesh/noc-tile-ctrl", "mesh/noc-reduce"]
        {
            let i = m4.items.iter().find(|i| i.name == name).expect(name);
            assert!(!i.ft_overhead, "{name}");
        }
        // Unprotected mesh carries no FT items beyond the tiles' own.
        let bare = mesh_area_report(cfg, Protection::Baseline, 4, false, false, false);
        assert_eq!(bare.ft_overhead_kge(), 0.0);
        // Tile compute dominates; the NoC is a modest share.
        let noc_share = m4.share_of("mesh/noc");
        assert!(noc_share > 0.0 && noc_share < 0.2, "noc share {noc_share:.4}");
    }
}
