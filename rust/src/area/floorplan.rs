//! Textual floorplan of the PULP cluster with RedMulE-FT (Figure 2a).
//!
//! The paper implements the whole cluster in a placed-and-routed
//! 1400 µm × 850 µm block in GlobalFoundries 12LP+. We reproduce the
//! *structure* of that figure: each cluster block gets an area from the GE
//! model (logic) or macro estimates (SRAM), blocks are packed into the
//! published die outline, and the result is rendered as ASCII art with a
//! per-block legend — the closest textual equivalent of the paper's
//! rendered floorplan.

use super::{area_report, AreaReport};
use crate::redmule::{Protection, RedMuleConfig};

/// Published block outline (µm).
pub const DIE_W_UM: f64 = 1400.0;
pub const DIE_H_UM: f64 = 850.0;

/// Approximate logic density for GF 12LP+ at ~70 % placement utilization
/// (µm² per GE). Calibrated so the cluster inventory fills the published
/// outline.
pub const UM2_PER_KGE: f64 = 205.0;

/// SRAM macro density (µm² per KiB), denser than random logic.
pub const UM2_PER_KIB_SRAM: f64 = 1450.0;

/// One placed block.
#[derive(Debug, Clone)]
pub struct Block {
    pub name: &'static str,
    pub tag: char,
    pub area_um2: f64,
    /// Filled by `place`: (x, y, w, h) in µm.
    pub rect: (f64, f64, f64, f64),
}

/// The cluster inventory (§2.2 + §3): 8 RV32 cores, shared instruction
/// cache, 256 KiB ECC TCDM in 16 banks, logarithmic interconnect, DMA,
/// event unit / peripherals, AXI boundary, and RedMulE-FT itself.
pub fn cluster_blocks(cfg: RedMuleConfig, protection: Protection) -> (Vec<Block>, AreaReport) {
    let redmule = area_report(cfg, protection);
    let logic = |kge: f64| kge * UM2_PER_KGE;
    let sram = |kib: f64| kib * UM2_PER_KIB_SRAM;

    let blocks = vec![
        Block {
            name: "8x RV32 cores",
            tag: 'C',
            area_um2: logic(8.0 * 45.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "I$ + prefetch",
            tag: 'I',
            area_um2: logic(60.0) + sram(16.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "TCDM banks (256 KiB, SECDED)",
            tag: 'M',
            // 39/32 storage expansion for the ECC bits.
            area_um2: sram(256.0 * 39.0 / 32.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "log. interconnect + ECC",
            tag: 'X',
            area_um2: logic(95.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "DMA engine",
            tag: 'D',
            area_um2: logic(70.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "event unit + peripherals",
            tag: 'E',
            area_um2: logic(55.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "AXI plugs + cluster bus",
            tag: 'A',
            area_um2: logic(75.0),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
        Block {
            name: "RedMulE-FT",
            tag: 'R',
            area_um2: logic(redmule.total_kge()),
            rect: (0.0, 0.0, 0.0, 0.0),
        },
    ];
    (blocks, redmule)
}

/// Slice-and-dice treemap placement into the die outline: recursively
/// split the block list into two area-balanced halves and the rectangle
/// along its longer side, proportionally. Always exactly tiles the die —
/// the same visual structure as the published placed-and-routed figure.
pub fn place(blocks: &mut [Block]) {
    blocks.sort_by(|a, b| b.area_um2.partial_cmp(&a.area_um2).unwrap());
    slice_dice(blocks, (0.0, 0.0, DIE_W_UM, DIE_H_UM));
}

fn slice_dice(blocks: &mut [Block], rect: (f64, f64, f64, f64)) {
    let (x, y, w, h) = rect;
    match blocks.len() {
        0 => {}
        1 => blocks[0].rect = rect,
        n => {
            let total: f64 = blocks.iter().map(|b| b.area_um2).sum();
            // Split point: first prefix reaching half the area.
            let mut acc = 0.0;
            let mut split = 1;
            for (i, b) in blocks.iter().enumerate() {
                acc += b.area_um2;
                if acc >= total / 2.0 || i == n - 2 {
                    split = i + 1;
                    break;
                }
            }
            let frac = blocks[..split].iter().map(|b| b.area_um2).sum::<f64>() / total;
            let (ra, rb) = if w >= h {
                let wa = w * frac;
                ((x, y, wa, h), (x + wa, y, w - wa, h))
            } else {
                let ha = h * frac;
                ((x, y, w, ha), (x, y + ha, w, h - ha))
            };
            let (left, right) = blocks.split_at_mut(split);
            slice_dice(left, ra);
            slice_dice(right, rb);
        }
    }
}

/// Render the placed floorplan as ASCII (1 cell ≈ 20 µm × 20 µm).
pub fn render(blocks: &[Block]) -> String {
    const CELL: f64 = 20.0;
    let cols = (DIE_W_UM / CELL) as usize;
    let rows = (DIE_H_UM / CELL / 2.0) as usize; // chars are ~2:1 tall
    let mut grid = vec![vec!['.'; cols]; rows];
    for b in blocks {
        let (x, y, w, h) = b.rect;
        let c0 = (x / CELL) as usize;
        let c1 = (((x + w) / CELL) as usize).min(cols);
        let r0 = (y / CELL / 2.0) as usize;
        let r1 = (((y + h) / CELL / 2.0) as usize).min(rows);
        for r in r0..r1 {
            for c in c0..c1 {
                grid[r][c] = b.tag;
            }
        }
    }
    let mut s = String::new();
    s.push_str(&format!(
        "PULP cluster floorplan — {:.0} µm × {:.0} µm (GF 12LP+, 500 MHz)\n",
        DIE_W_UM, DIE_H_UM
    ));
    s.push('+');
    s.push_str(&"-".repeat(cols));
    s.push_str("+\n");
    for row in &grid {
        s.push('|');
        s.extend(row.iter());
        s.push_str("|\n");
    }
    s.push('+');
    s.push_str(&"-".repeat(cols));
    s.push_str("+\n");
    s.push_str("legend:\n");
    let mut sorted: Vec<&Block> = blocks.iter().collect();
    sorted.sort_by(|a, b| b.area_um2.partial_cmp(&a.area_um2).unwrap());
    for b in sorted {
        s.push_str(&format!(
            "  {} {:<34} {:>9.0} µm²  ({:>5.1} %)\n",
            b.tag,
            b.name,
            b.area_um2,
            100.0 * b.area_um2 / (DIE_W_UM * DIE_H_UM)
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_roughly_fills_the_published_outline() {
        let (blocks, _) = cluster_blocks(RedMuleConfig::paper(), Protection::Full);
        let total: f64 = blocks.iter().map(|b| b.area_um2).sum();
        let die = DIE_W_UM * DIE_H_UM;
        let fill = total / die;
        assert!(
            (0.6..=1.4).contains(&fill),
            "inventory fills {:.0} % of the die",
            fill * 100.0
        );
    }

    #[test]
    fn placement_stays_inside_the_die() {
        let (mut blocks, _) = cluster_blocks(RedMuleConfig::paper(), Protection::Full);
        place(&mut blocks);
        for b in &blocks {
            let (x, y, w, h) = b.rect;
            assert!(x >= -1e-6 && y >= -1e-6);
            assert!(x + w <= DIE_W_UM + 1e-6, "{} sticks out in x", b.name);
            assert!(y + h <= DIE_H_UM + 1e-6, "{} sticks out in y", b.name);
            assert!(w > 0.0 && h > 0.0);
        }
    }

    #[test]
    fn redmule_grows_with_protection() {
        let a = |p| {
            let (b, _) = cluster_blocks(RedMuleConfig::paper(), p);
            b.iter().find(|x| x.tag == 'R').unwrap().area_um2
        };
        assert!(a(Protection::Data) > a(Protection::Baseline));
        assert!(a(Protection::Full) > 1.2 * a(Protection::Baseline));
    }

    #[test]
    fn render_contains_outline_and_legend() {
        let (mut blocks, _) = cluster_blocks(RedMuleConfig::paper(), Protection::Full);
        place(&mut blocks);
        let s = render(&blocks);
        assert!(s.contains("RedMulE-FT"));
        assert!(s.contains("TCDM"));
        assert!(s.starts_with("PULP cluster floorplan"));
    }
}
