//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the Rust request path.
//!
//! `make artifacts` runs `python/compile/aot.py` **once** at build time; it
//! lowers the Layer-2 JAX graphs (which call the Layer-1 Pallas kernels)
//! to **HLO text** under `artifacts/`, together with a plain-text manifest.
//! This module is everything needed at run time: a PJRT CPU client, the
//! text → `HloModuleProto` → compile pipeline, and typed `execute` helpers.
//! Python never runs on this path.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! All artifact I/O is `f32` at the boundary: FP16 values convert to f32
//! exactly, the graphs cast to f16 internally and compute with the same
//! per-step rounding as the hardware, and the f16 results cast back to
//! f32 exactly — so bit-exact comparison against the simulator/golden is
//! done by converting both sides to f16 bit patterns.

use crate::golden::Mat;
use crate::{Error, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest entry: a named computation with its I/O contract.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    /// Artifact kind tag (`gemm`, `gemm_redundant`, `mlp_train`, ...).
    pub kind: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Kind-specific integer parameters (e.g. `m n k` for `gemm`).
    pub params: Vec<usize>,
}

/// Parse `manifest.txt`: one entry per line,
/// `name kind file param*` (whitespace separated, `#` comments).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactEntry>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(name), Some(kind), Some(file)) = (it.next(), it.next(), it.next()) else {
            return Err(Error::Runtime(format!(
                "manifest line {}: expected `name kind file param*`",
                lineno + 1
            )));
        };
        let params = it
            .map(|p| {
                p.parse::<usize>().map_err(|_| {
                    Error::Runtime(format!("manifest line {}: bad param {p:?}", lineno + 1))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        out.push(ArtifactEntry {
            name: name.to_string(),
            kind: kind.to_string(),
            file: file.to_string(),
            params,
        });
    }
    Ok(out)
}

/// Locate the artifact directory: `$REDMULE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("REDMULE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// The runtime: PJRT CPU client plus compiled executables, keyed by
    /// manifest name. Compilation happens once at load; execution is
    /// reusable and cheap.
    pub struct GoldenRuntime {
        client: xla::PjRtClient,
        entries: HashMap<String, ArtifactEntry>,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        dir: PathBuf,
    }

    impl GoldenRuntime {
        /// Load every artifact listed in `<dir>/manifest.txt`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest_path)
                .map_err(|_| Error::ArtifactMissing(manifest_path.display().to_string()))?;
            let entries = parse_manifest(&text)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
            let mut executables = HashMap::new();
            let mut by_name = HashMap::new();
            for e in entries {
                let path = dir.join(&e.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|err| Error::Runtime(format!("parse {}: {err}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|err| Error::Runtime(format!("compile {}: {err}", e.name)))?;
                executables.insert(e.name.clone(), exe);
                by_name.insert(e.name.clone(), e);
            }
            Ok(Self {
                client,
                entries: by_name,
                executables,
                dir,
            })
        }

        /// Load from the default directory (`$REDMULE_ARTIFACTS` or
        /// `./artifacts`).
        pub fn load_default() -> Result<Self> {
            Self::load(default_artifact_dir())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn dir(&self) -> &Path {
            &self.dir
        }

        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
            v.sort();
            v
        }

        pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
            self.entries.get(name)
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.executables
                .get(name)
                .ok_or_else(|| Error::ArtifactMissing(name.to_string()))
        }

        /// Execute a computation on f32 tensors; returns the flat f32
        /// outputs of the (tupled) result.
        pub fn execute_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self.exe(name)?;
            let literals = inputs
                .iter()
                .map(|(data, dims)| {
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| Error::Runtime(format!("reshape: {e}")))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
            let literal = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
            let parts = literal
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
            parts
                .into_iter()
                .map(|l| {
                    l.to_vec::<f32>()
                        .map_err(|e| Error::Runtime(format!("to_vec {name}: {e}")))
                })
                .collect()
        }

        /// Execute a `gemm` artifact on FP16 matrices (exact f32 carry).
        pub fn execute_gemm(&self, name: &str, x: &Mat, w: &Mat, y: &Mat) -> Result<Mat> {
            let e = self
                .entry(name)
                .ok_or_else(|| Error::ArtifactMissing(name.to_string()))?;
            if e.params.len() != 3 {
                return Err(Error::Runtime(format!("{name} is not a gemm artifact")));
            }
            let (m, n, k) = (e.params[0], e.params[1], e.params[2]);
            if (x.rows, x.cols) != (m, n) || (w.rows, w.cols) != (n, k) || (y.rows, y.cols) != (m, k)
            {
                return Err(Error::Config(format!(
                    "{name} expects ({m},{n},{k}); got X {}x{} W {}x{} Y {}x{}",
                    x.rows, x.cols, w.rows, w.cols, y.rows, y.cols
                )));
            }
            let xf: Vec<f32> = x.data.iter().map(|v| v.to_f32()).collect();
            let wf: Vec<f32> = w.data.iter().map(|v| v.to_f32()).collect();
            let yf: Vec<f32> = y.data.iter().map(|v| v.to_f32()).collect();
            let outs = self.execute_f32(
                name,
                &[
                    (&xf, &[m as i64, n as i64]),
                    (&wf, &[n as i64, k as i64]),
                    (&yf, &[m as i64, k as i64]),
                ],
            )?;
            let z = &outs[0];
            if z.len() != m * k {
                return Err(Error::Runtime(format!(
                    "{name}: output len {} != {}",
                    z.len(),
                    m * k
                )));
            }
            Ok(Mat {
                rows: m,
                cols: k,
                data: z.iter().map(|&v| crate::fp::Fp16::from_f32(v)).collect(),
            })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::GoldenRuntime;

/// Stub when built without the `pjrt` feature: loading always fails with
/// a descriptive error so pure-simulator builds keep working.
#[cfg(not(feature = "pjrt"))]
pub struct GoldenRuntime;

#[cfg(not(feature = "pjrt"))]
impl GoldenRuntime {
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Runtime(
            "built without the `pjrt` feature; rebuild with --features pjrt".into(),
        ))
    }

    pub fn load_default() -> Result<Self> {
        Self::load("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_names_kinds_and_params() {
        let text = "\
# artifacts
gemm_12x16x16 gemm gemm_12x16x16.hlo.txt 12 16 16

mlp_train mlp mlp_train.hlo.txt 32 16 32 4
";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "gemm_12x16x16");
        assert_eq!(entries[0].kind, "gemm");
        assert_eq!(entries[0].params, vec![12, 16, 16]);
        assert_eq!(entries[1].params, vec![32, 16, 32, 4]);
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        assert!(parse_manifest("just_a_name").is_err());
        assert!(parse_manifest("a gemm f.hlo.txt twelve").is_err());
    }

    #[test]
    fn manifest_skips_comments_and_blanks() {
        let entries = parse_manifest("# nothing\n\n   \n").unwrap();
        assert!(entries.is_empty());
    }

    #[test]
    fn default_dir_honours_env() {
        // NB: do not mutate the environment here (tests run in parallel);
        // just verify the fallback.
        if std::env::var_os("REDMULE_ARTIFACTS").is_none() {
            assert_eq!(default_artifact_dir(), PathBuf::from("artifacts"));
        }
    }
}
