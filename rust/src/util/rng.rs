//! Deterministic pseudo-random number generators.
//!
//! The fault-injection campaign must be exactly reproducible from a seed
//! (the paper reports 1 M injections per configuration; we re-derive every
//! injection from `(campaign_seed, injection_index)`), and the offline
//! build environment has no `rand` crate — so we carry our own SplitMix64
//! (seeding) and xoshiro256** (bulk generation), both from the public
//! domain reference implementations by Blackman & Vigna.

/// SplitMix64 — used to expand a single `u64` seed into a full generator
/// state and for cheap one-shot hashing of `(seed, index)` pairs.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot stateless mix of two words; used to derive per-injection seeds.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = SplitMix64::new(a ^ b.rotate_left(32).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    s.next_u64()
}

/// xoshiro256** — the campaign and workload generator PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors (never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform float in `[0, 1)` with 53 bits of randomness.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// A random IEEE binary16 bit pattern representing a finite value in
    /// roughly `[-max_mag, max_mag]`; used by workload generators.
    pub fn next_fp16_in(&mut self, max_mag: f64) -> crate::fp::Fp16 {
        let v = (self.next_f64() * 2.0 - 1.0) * max_mag;
        crate::fp::Fp16::from_f64(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..1000 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        let same = (0..1000).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 5, "different seeds should diverge");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn mix64_differs_by_index() {
        let a = mix64(1, 0);
        let b = mix64(1, 1);
        let c = mix64(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
