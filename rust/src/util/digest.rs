//! Order-stable 64-bit state digests (FNV-1a).
//!
//! The campaign's fast-forward engine compares a rolling digest of the
//! full architectural state against the fault-free reference trace to
//! detect that an injected fault has been masked or absorbed — at which
//! point the remainder of the run is bit-identical to the reference and
//! can be skipped. The hash therefore only needs to be *deterministic and
//! order-stable across runs and platforms*; it is not cryptographic. A
//! 64-bit FNV-1a keeps the collision probability of a false convergence
//! far below the 1M-injection campaign scale (and the A/B equivalence
//! tests in `tests/fastforward.rs` pin the engine against the direct
//! path end to end).

/// Incremental FNV-1a 64-bit hasher.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Self(FNV_OFFSET)
    }

    #[inline]
    pub fn write_u8(&mut self, v: u8) {
        self.0 = (self.0 ^ v as u64).wrapping_mul(FNV_PRIME);
    }

    #[inline]
    pub fn write_u16(&mut self, v: u16) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    pub fn write_i64(&mut self, v: i64) {
        self.write_u64(v as u64);
    }

    #[inline]
    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(v as u8);
    }

    /// Fold a byte slice into the digest, in order — equivalent to
    /// writing each byte with [`Fnv64::write_u8`]. Content-identity
    /// hashing (e.g. the campaign's shared-trace cache key digests the
    /// workload matrices) goes through this.
    #[inline]
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.write_u32(1);
        a.write_u32(2);
        let mut b = Fnv64::new();
        b.write_u32(1);
        b.write_u32(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_u32(2);
        c.write_u32(1);
        assert_ne!(a.finish(), c.finish(), "order must matter");
    }

    #[test]
    fn width_is_part_of_the_stream() {
        // Writing the same numeric value at different widths must digest
        // differently (the byte stream differs), so accidental width
        // changes in a component digest cannot silently collide.
        let mut a = Fnv64::new();
        a.write_u16(7);
        let mut b = Fnv64::new();
        b.write_u32(7);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn empty_digest_is_the_offset_basis() {
        assert_eq!(Fnv64::new().finish(), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn write_bytes_equals_per_byte_writes() {
        let bytes = [0x01u8, 0xFF, 0x00, 0x7A, 0xC3];
        let mut a = Fnv64::new();
        a.write_bytes(&bytes);
        let mut b = Fnv64::new();
        for &v in &bytes {
            b.write_u8(v);
        }
        assert_eq!(a.finish(), b.finish());
        // And agrees with the multi-word writers on their LE byte streams.
        let mut c = Fnv64::new();
        c.write_u32(0xDEAD_BEEF);
        let mut d = Fnv64::new();
        d.write_bytes(&0xDEAD_BEEFu32.to_le_bytes());
        assert_eq!(c.finish(), d.finish());
    }
}
