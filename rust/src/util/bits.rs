//! Bit-twiddling helpers shared by the ECC and fault-injection code.

/// XOR-reduce (parity) of a word: returns 1 iff an odd number of bits set.
#[inline]
pub fn parity_u32(x: u32) -> u32 {
    (x.count_ones() & 1) as u32
}

/// XOR-reduce (parity) of a 64-bit word.
#[inline]
pub fn parity_u64(x: u64) -> u32 {
    (x.count_ones() & 1) as u32
}

/// Flip bit `b` of `x`.
#[inline]
pub fn flip_bit_u16(x: u16, b: u32) -> u16 {
    x ^ (1u16 << (b & 15))
}

/// Flip bit `b` of `x`.
#[inline]
pub fn flip_bit_u32(x: u32, b: u32) -> u32 {
    x ^ (1u32 << (b & 31))
}

/// Flip bit `b` of `x`.
#[inline]
pub fn flip_bit_u64(x: u64, b: u32) -> u64 {
    x ^ (1u64 << (b & 63))
}

/// Extract bits `[lo, lo+len)` of `x`.
#[inline]
pub fn field_u32(x: u32, lo: u32, len: u32) -> u32 {
    (x >> lo) & ((1u32 << len) - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parity_basics() {
        assert_eq!(parity_u32(0), 0);
        assert_eq!(parity_u32(1), 1);
        assert_eq!(parity_u32(0b11), 0);
        assert_eq!(parity_u32(u32::MAX), 0);
        assert_eq!(parity_u64(u64::MAX), 0);
        assert_eq!(parity_u64(1 << 63), 1);
    }

    #[test]
    fn flip_round_trips() {
        for b in 0..16 {
            assert_eq!(flip_bit_u16(flip_bit_u16(0xABCD, b), b), 0xABCD);
        }
        for b in 0..32 {
            assert_eq!(flip_bit_u32(flip_bit_u32(0xDEAD_BEEF, b), b), 0xDEAD_BEEF);
        }
        for b in 0..64 {
            assert_eq!(
                flip_bit_u64(flip_bit_u64(0x0123_4567_89AB_CDEF, b), b),
                0x0123_4567_89AB_CDEF
            );
        }
    }

    #[test]
    fn field_extraction() {
        assert_eq!(field_u32(0xABCD_1234, 0, 4), 0x4);
        assert_eq!(field_u32(0xABCD_1234, 16, 16), 0xABCD);
        assert_eq!(field_u32(0xFFFF_FFFF, 31, 1), 1);
    }
}
