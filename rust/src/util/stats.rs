//! Statistics helpers for the fault-injection campaign.
//!
//! The paper reports rates with a Poisson 95 % confidence interval and, for
//! the zero-observed-error case of the fully protected configuration,
//! derives the `< 0.0003 %` bound by "conservatively assuming one
//! additional observed error". We reproduce both conventions here.

/// Two-sided 95 % Poisson confidence interval for an observed count `k`.
///
/// Uses the exact (Garwood) interval expressed through the chi-squared
/// distribution:  lower = chi2(0.025, 2k)/2, upper = chi2(0.975, 2k+2)/2.
/// The chi-squared quantiles are computed with the Wilson–Hilferty
/// approximation, which is accurate to well below the digit the paper
/// quotes for k ≥ 0.
pub fn poisson_ci95(k: u64) -> (f64, f64) {
    let lower = if k == 0 {
        0.0
    } else {
        0.5 * chi2_quantile(0.025, 2.0 * k as f64)
    };
    let upper = 0.5 * chi2_quantile(0.975, 2.0 * k as f64 + 2.0);
    (lower, upper)
}

/// The paper's conservative convention: upper bound for a rate with zero
/// observed events in `n` trials, "assuming one additional observed error"
/// (i.e. treat the count as 1) — quoted as `< 0.0003 %` for n = 1e6.
pub fn conservative_upper_rate(observed: u64, n: u64) -> f64 {
    let (_, up) = poisson_ci95(observed + 1);
    up / n as f64
}

/// Wilson–Hilferty approximation of the chi-squared quantile function.
fn chi2_quantile(p: f64, df: f64) -> f64 {
    if df <= 0.0 {
        return 0.0;
    }
    let z = normal_quantile(p);
    let a = 2.0 / (9.0 * df);
    let c = 1.0 - a + z * a.sqrt();
    df * c * c * c
}

/// Acklam's rational approximation of the standard normal quantile.
/// Relative error < 1.15e-9 over the full open interval.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p must be in (0,1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Binomial-style rate with its Poisson 95 % CI half-widths, formatted the
/// way Table 1 quotes it (e.g. `7.08 ± 0.05 %`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rate {
    pub count: u64,
    pub total: u64,
}

impl Rate {
    pub fn new(count: u64, total: u64) -> Self {
        Self { count, total }
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.count as f64 / self.total as f64
        }
    }

    pub fn percent(&self) -> f64 {
        self.value() * 100.0
    }

    /// 95 % CI on the rate (Poisson on the count).
    pub fn ci95(&self) -> (f64, f64) {
        let (lo, hi) = poisson_ci95(self.count);
        (lo / self.total.max(1) as f64, hi / self.total.max(1) as f64)
    }

    /// Render like Table 1: `xx.xx ± y.yy %`, or `< bound %` for zero counts
    /// (paper footnote a: bound via Poisson, one additional assumed error).
    pub fn table1_cell(&self) -> String {
        if self.count == 0 {
            let ub = conservative_upper_rate(0, self.total.max(1)) * 100.0;
            format!("<{ub:.4} %")
        } else {
            let (lo, hi) = self.ci95();
            let half = (hi - lo) / 2.0 * 100.0;
            format!("{:.2} ± {:.2} %", self.percent(), half)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_quantile_symmetry_and_known_values() {
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.025) + 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.5)).abs() < 1e-9);
    }

    #[test]
    fn poisson_ci_zero_and_small_counts() {
        let (lo, hi) = poisson_ci95(0);
        assert_eq!(lo, 0.0);
        // Exact value is 3.6889; Wilson-Hilferty is within a few percent.
        assert!((hi - 3.6889).abs() < 0.15, "hi = {hi}");

        let (lo1, hi1) = poisson_ci95(1);
        assert!(lo1 > 0.0 && lo1 < 0.1, "lo1 = {lo1}");
        assert!((hi1 - 5.5716).abs() < 0.2, "hi1 = {hi1}");
    }

    #[test]
    fn paper_upper_bound_convention() {
        // Table 1 footnote: zero observed errors in 1e6 injections, assume
        // one additional error -> "< 0.0003 %".
        let ub = conservative_upper_rate(0, 1_000_000);
        let pct = ub * 100.0;
        assert!(pct < 0.0006 && pct > 0.0002, "pct = {pct}");
    }

    #[test]
    fn poisson_ci_large_count_matches_normal_approx() {
        // For large k the Poisson CI approaches k ± 1.96 sqrt(k).
        let k = 70_800u64; // baseline functional errors out of 1M ≈ 7.08 %
        let (lo, hi) = poisson_ci95(k);
        let half = (hi - lo) / 2.0;
        let expect = 1.96 * (k as f64).sqrt();
        assert!((half - expect).abs() / expect < 0.01, "half = {half}");
        // Scaled by 1M this is the paper's ±0.05 %.
        let pct_half = half / 1_000_000.0 * 100.0;
        assert!((pct_half - 0.052).abs() < 0.005, "pct_half = {pct_half}");
    }

    #[test]
    fn rate_formatting() {
        let r = Rate::new(0, 1_000_000);
        assert!(r.table1_cell().starts_with('<'));
        let r2 = Rate::new(70_800, 1_000_000);
        let cell = r2.table1_cell();
        assert!(cell.starts_with("7.08"), "cell = {cell}");
    }
}
