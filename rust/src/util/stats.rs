//! Statistics helpers for the fault-injection campaign.
//!
//! The paper reports rates with a Poisson 95 % confidence interval and, for
//! the zero-observed-error case of the fully protected configuration,
//! derives the `< 0.0003 %` bound by "conservatively assuming one
//! additional observed error". We reproduce both conventions here.
//!
//! On top of the paper's conventions, the adaptive campaign engine needs
//! proper **binomial interval estimation** and **stratified allocation**:
//!
//! * [`wilson_ci95`] — the Wilson score interval, the campaign's working
//!   interval (well-behaved near 0/1, cheap, and its half-width is the
//!   early-stopping precision criterion);
//! * [`clopper_pearson_ci95`] — the exact (conservative) interval via the
//!   regularized incomplete beta function, quoted alongside Wilson in
//!   reports and JSON; its one-sided zero-count form [`exact_upper95`] is
//!   how "0 functional errors in N injections" becomes "< p at 95 %"
//!   (the rule-of-three `3/N` to within a few percent);
//! * [`OutcomeEstimate`] — one outcome rate with both intervals, pooled
//!   ([`OutcomeEstimate::pooled`]) or area-weight stratified
//!   ([`OutcomeEstimate::stratified`], the textbook
//!   `Var = Σ W_h² p̃_h(1−p̃_h)/n_h` with a Laplace-smoothed variance so
//!   zero-count strata never report false certainty);
//! * [`neyman_allocation`] — deterministic largest-remainder split of a
//!   batch over strata proportional to `W_h · s_h`, with a floor so rare
//!   strata are never starved.

/// Two-sided 95 % Poisson confidence interval for an observed count `k`.
///
/// Uses the exact (Garwood) interval expressed through the chi-squared
/// distribution:  lower = chi2(0.025, 2k)/2, upper = chi2(0.975, 2k+2)/2.
/// The chi-squared quantiles are computed with the Wilson–Hilferty
/// approximation, which is accurate to well below the digit the paper
/// quotes for k ≥ 0.
pub fn poisson_ci95(k: u64) -> (f64, f64) {
    let lower = if k == 0 {
        0.0
    } else {
        0.5 * chi2_quantile(0.025, 2.0 * k as f64)
    };
    let upper = 0.5 * chi2_quantile(0.975, 2.0 * k as f64 + 2.0);
    (lower, upper)
}

/// The paper's conservative convention: upper bound for a rate with zero
/// observed events in `n` trials, "assuming one additional observed error"
/// (i.e. treat the count as 1) — quoted as `< 0.0003 %` for n = 1e6.
pub fn conservative_upper_rate(observed: u64, n: u64) -> f64 {
    let (_, up) = poisson_ci95(observed + 1);
    up / n as f64
}

/// Wilson–Hilferty approximation of the chi-squared quantile function.
fn chi2_quantile(p: f64, df: f64) -> f64 {
    if df <= 0.0 {
        return 0.0;
    }
    let z = normal_quantile(p);
    let a = 2.0 / (9.0 * df);
    let c = 1.0 - a + z * a.sqrt();
    df * c * c * c
}

/// Acklam's rational approximation of the standard normal quantile.
/// Relative error < 1.15e-9 over the full open interval.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p must be in (0,1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Binomial-style rate with its Poisson 95 % CI half-widths, formatted the
/// way Table 1 quotes it (e.g. `7.08 ± 0.05 %`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rate {
    pub count: u64,
    pub total: u64,
}

impl Rate {
    pub fn new(count: u64, total: u64) -> Self {
        Self { count, total }
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.count as f64 / self.total as f64
        }
    }

    pub fn percent(&self) -> f64 {
        self.value() * 100.0
    }

    /// 95 % CI on the rate (Poisson on the count).
    pub fn ci95(&self) -> (f64, f64) {
        let (lo, hi) = poisson_ci95(self.count);
        (lo / self.total.max(1) as f64, hi / self.total.max(1) as f64)
    }

    /// Render like Table 1: `xx.xx ± y.yy %`, or `< bound %` for zero counts
    /// (paper footnote a: bound via Poisson, one additional assumed error).
    pub fn table1_cell(&self) -> String {
        if self.count == 0 {
            let ub = conservative_upper_rate(0, self.total.max(1)) * 100.0;
            format!("<{ub:.4} %")
        } else {
            let (lo, hi) = self.ci95();
            let half = (hi - lo) / 2.0 * 100.0;
            format!("{:.2} ± {:.2} %", self.percent(), half)
        }
    }
}

// ------------------------------------------------- binomial intervals

/// z for a two-sided 95 % normal interval.
pub const Z95: f64 = 1.959963984540054;

/// z for a one-sided 95 % normal bound.
pub const Z95_ONE_SIDED: f64 = 1.6448536269514722;

/// Critical value of a two-sided normal interval at confidence `conf`.
/// The default 95 % level returns the exact [`Z95`] constant (not the
/// rational approximation), so `--confidence 0.95` is bit-identical to
/// the historical hardwired interval math.
pub fn z_two_sided(conf: f64) -> f64 {
    if conf == 0.95 {
        Z95
    } else {
        normal_quantile(0.5 + conf / 2.0)
    }
}

/// Critical value of a one-sided normal bound at confidence `conf` (the
/// same exact-constant pinning at 95 % as [`z_two_sided`]).
pub fn z_one_sided(conf: f64) -> f64 {
    if conf == 0.95 {
        Z95_ONE_SIDED
    } else {
        normal_quantile(conf)
    }
}

/// Natural log of the gamma function (Lanczos, g = 7, 9 coefficients —
/// absolute error well below 1e-10 over the positive reals).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    const G: f64 = 7.0;
    use std::f64::consts::PI;
    if x < 0.5 {
        // Reflection formula keeps the series in its accurate range.
        PI.ln() - (PI * x).sin().abs().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        let t = x + G + 0.5;
        0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Continued-fraction kernel of the incomplete beta (Lentz's algorithm).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_IT: usize = 300;
    const EPS: f64 = 3.0e-14;
    const FPMIN: f64 = 1.0e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_IT {
        let mf = m as f64;
        let m2 = 2.0 * mf;
        let aa = mf * (b - mf) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + mf) * (qab + mf) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta function `I_x(a, b)`.
pub fn beta_inc_reg(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_bt = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let bt = ln_bt.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Quantile of the Beta(a, b) distribution by bisection on
/// [`beta_inc_reg`]: monotone, fully deterministic, and accurate to the
/// bisection limit (~1e-18 after 80 halvings), which is far below any
/// digit a campaign report quotes.
pub fn beta_quantile(p: f64, a: f64, b: f64) -> f64 {
    if p <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return 1.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if beta_inc_reg(a, b, mid) < p {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Wilson score interval for `k` successes in `n` trials at critical
/// value `z` (two-sided). The degenerate endpoints are pinned exactly —
/// at `k = 0` the Wilson lower bound is 0 and at `k = n` the upper is 1
/// analytically, but `center ± half` only reaches them up to rounding.
pub fn wilson_ci(k: u64, n: u64, z: f64) -> (f64, f64) {
    let n = n.max(1);
    let nf = n as f64;
    let p = k as f64 / nf;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = z * (p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt() / denom;
    let lo = if k == 0 { 0.0 } else { (center - half).max(0.0) };
    let hi = if k >= n { 1.0 } else { (center + half).min(1.0) };
    (lo, hi)
}

/// Wilson score interval at 95 %.
pub fn wilson_ci95(k: u64, n: u64) -> (f64, f64) {
    wilson_ci(k, n, Z95)
}

/// Wilson score interval at confidence `conf` (two-sided).
pub fn wilson_ci_at(k: u64, n: u64, conf: f64) -> (f64, f64) {
    wilson_ci(k, n, z_two_sided(conf))
}

/// Clopper–Pearson exact two-sided interval at confidence `conf`:
/// `lo = BetaInv(α/2; k, n−k+1)`, `hi = BetaInv(1−α/2; k+1, n−k)`, with
/// the closed-form endpoints at k = 0 and k = n.
pub fn clopper_pearson_ci(k: u64, n: u64, conf: f64) -> (f64, f64) {
    let n = n.max(1);
    let k = k.min(n);
    let alpha = 1.0 - conf;
    let (kf, nf) = (k as f64, n as f64);
    let lo = if k == 0 {
        0.0
    } else {
        beta_quantile(alpha / 2.0, kf, nf - kf + 1.0)
    };
    let hi = if k == n {
        1.0
    } else if k == 0 {
        1.0 - (alpha / 2.0).powf(1.0 / nf)
    } else {
        beta_quantile(1.0 - alpha / 2.0, kf + 1.0, nf - kf)
    };
    (lo, hi)
}

/// Clopper–Pearson exact interval at 95 %.
pub fn clopper_pearson_ci95(k: u64, n: u64) -> (f64, f64) {
    clopper_pearson_ci(k, n, 0.95)
}

/// One-sided exact upper bound at confidence `conf`. For `k = 0` this is
/// the closed form `1 − (1−conf)^{1/n}` — the rule-of-three `≈ 3/n` at
/// 95 % — which is how a zero-error campaign cell prints "< p at 95 %"
/// (1 M injections ⇒ < 3.0e-6; with the paper's "one additional assumed
/// error" Poisson convention the same order: < 3.7e-6).
pub fn exact_upper(k: u64, n: u64, conf: f64) -> f64 {
    let n = n.max(1);
    if k >= n {
        return 1.0;
    }
    if k == 0 {
        return 1.0 - (1.0 - conf).powf(1.0 / n as f64);
    }
    beta_quantile(conf, k as f64 + 1.0, (n - k) as f64)
}

/// One-sided exact upper bound at 95 %.
pub fn exact_upper95(k: u64, n: u64) -> f64 {
    exact_upper(k, n, 0.95)
}

/// One stratum's sample of a binomial outcome: the stratum's sampling
/// weight (need not be normalized), the outcome count and the number of
/// injections allocated to the stratum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StratumSample {
    pub weight: f64,
    pub count: u64,
    pub n: u64,
}

/// One outcome-rate estimate with its 95 % intervals.
///
/// `ci_lo / ci_hi` is the working interval — Wilson on pooled counts, or
/// the stratified normal interval when built by
/// [`OutcomeEstimate::stratified`] — and its half-width is what the
/// adaptive engine compares against the precision target.
/// `exact_lo / exact_hi` is the Clopper–Pearson interval on the pooled
/// counts (reported alongside; for stratified estimates it ignores the
/// weighting and is quoted as the conservative raw-count interval).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutcomeEstimate {
    pub count: u64,
    pub n: u64,
    /// Point estimate of the rate (area-weighted when stratified).
    pub rate: f64,
    pub ci_lo: f64,
    pub ci_hi: f64,
    pub exact_lo: f64,
    pub exact_hi: f64,
    /// One-sided upper bound consistent with the point estimate, at the
    /// construction confidence (95 % unless built through the `_at`
    /// constructors — the field keeps its historical name for JSON
    /// compatibility): Clopper–Pearson exact for pooled estimates (the
    /// zero-count "< p at 95 %" convention), the one-sided normal bound
    /// on the weighted rate for stratified ones (a pooled-count bound
    /// could sit *below* an area-weighted rate and read as a
    /// contradiction).
    upper95: f64,
}

impl OutcomeEstimate {
    /// Half-width of the working 95 % interval — the early-stopping
    /// precision measure.
    pub fn half_width(&self) -> f64 {
        0.5 * (self.ci_hi - self.ci_lo)
    }

    /// One-sided upper bound on the rate at the construction confidence
    /// (95 % by default; see the field docs; always at or above `rate`).
    pub fn upper95(&self) -> f64 {
        self.upper95
    }

    /// Pooled binomial estimate: Wilson working interval, Clopper–Pearson
    /// exact interval, both at 95 %.
    pub fn pooled(count: u64, n: u64) -> Self {
        Self::pooled_at(count, n, 0.95)
    }

    /// [`OutcomeEstimate::pooled`] at an arbitrary confidence level (the
    /// campaign's `--confidence` knob): Wilson and Clopper–Pearson
    /// two-sided intervals plus the one-sided exact upper bound, all at
    /// `conf`. `conf = 0.95` is bit-identical to
    /// [`OutcomeEstimate::pooled`].
    pub fn pooled_at(count: u64, n: u64, conf: f64) -> Self {
        let n1 = n.max(1);
        let (ci_lo, ci_hi) = wilson_ci_at(count, n1, conf);
        let (exact_lo, exact_hi) = clopper_pearson_ci(count, n1, conf);
        Self {
            count,
            n,
            rate: count as f64 / n1 as f64,
            ci_lo,
            ci_hi,
            exact_lo,
            exact_hi,
            upper95: exact_upper(count, n1, conf),
        }
    }

    /// Stratified estimate over area-weighted strata:
    /// `p̂ = Σ W_h k_h/n_h` with
    /// `Var = Σ W_h² p̃_h(1−p̃_h)/n_h`, where `p̃_h = (k_h+1)/(n_h+2)` is
    /// Laplace-smoothed so a zero-count stratum still contributes
    /// variance (no false certainty), and a *never-sampled* stratum with
    /// positive weight contributes the maximal single-draw variance so
    /// the half-width cannot meet any meaningful target until every
    /// populated stratum has been sampled. The exact interval is
    /// Clopper–Pearson on the pooled counts.
    pub fn stratified(strata: &[StratumSample]) -> Self {
        Self::stratified_at(strata, 0.95)
    }

    /// [`OutcomeEstimate::stratified`] at an arbitrary confidence level
    /// (same exact-constant pinning at 95 % as the pooled path).
    pub fn stratified_at(strata: &[StratumSample], conf: f64) -> Self {
        let wsum: f64 = strata
            .iter()
            .filter(|s| s.weight > 0.0 && s.weight.is_finite())
            .map(|s| s.weight)
            .sum();
        let (mut count, mut n) = (0u64, 0u64);
        for s in strata {
            count += s.count;
            n += s.n;
        }
        if wsum <= 0.0 {
            return Self::pooled_at(count, n, conf);
        }
        let mut rate = 0.0;
        let mut var = 0.0;
        for s in strata {
            if s.weight <= 0.0 || !s.weight.is_finite() {
                continue;
            }
            let w = s.weight / wsum;
            if s.n > 0 {
                let nf = s.n as f64;
                rate += w * s.count as f64 / nf;
                let pt = (s.count as f64 + 1.0) / (nf + 2.0);
                var += w * w * pt * (1.0 - pt) / nf;
            } else {
                var += w * w * 0.25;
            }
        }
        let sd = var.sqrt();
        let half = z_two_sided(conf) * sd;
        let (exact_lo, exact_hi) = clopper_pearson_ci(count, n.max(1), conf);
        Self {
            count,
            n,
            rate,
            ci_lo: (rate - half).max(0.0),
            ci_hi: (rate + half).min(1.0),
            exact_lo,
            exact_hi,
            upper95: (rate + z_one_sided(conf) * sd).min(1.0),
        }
    }
}

/// Deterministic largest-remainder apportionment of `batch` draws over
/// strata with Neyman scores `W_h · s_h` (passed pre-multiplied in
/// `scores`). Strata with non-positive or non-finite scores get nothing;
/// every active stratum gets at least `floor` draws (capped so the floors
/// fit in the batch); ties break toward the lower index so the result is
/// a pure function of its inputs.
pub fn neyman_allocation(scores: &[f64], batch: u64, floor: u64) -> Vec<u64> {
    let mut out = vec![0u64; scores.len()];
    let active: Vec<usize> = (0..scores.len())
        .filter(|&i| scores[i].is_finite() && scores[i] > 0.0)
        .collect();
    if active.is_empty() || batch == 0 {
        return out;
    }
    let a = active.len() as u64;
    let per_floor = floor.min(batch / a);
    for &i in &active {
        out[i] = per_floor;
    }
    let rem = batch - per_floor * a;
    if rem == 0 {
        return out;
    }
    let total: f64 = active.iter().map(|&i| scores[i]).sum();
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(active.len());
    let mut assigned = 0u64;
    for &i in &active {
        let quota = rem as f64 * scores[i] / total;
        let fl = quota.floor() as u64;
        out[i] += fl;
        assigned += fl;
        fracs.push((i, quota - fl as f64));
    }
    let mut left = rem - assigned;
    fracs.sort_by(|x, y| {
        y.1.partial_cmp(&x.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(x.0.cmp(&y.0))
    });
    for (i, _) in fracs {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_quantile_symmetry_and_known_values() {
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.025) + 1.959964).abs() < 1e-4);
        assert!((normal_quantile(0.5)).abs() < 1e-9);
    }

    #[test]
    fn poisson_ci_zero_and_small_counts() {
        let (lo, hi) = poisson_ci95(0);
        assert_eq!(lo, 0.0);
        // Exact value is 3.6889; Wilson-Hilferty is within a few percent.
        assert!((hi - 3.6889).abs() < 0.15, "hi = {hi}");

        let (lo1, hi1) = poisson_ci95(1);
        assert!(lo1 > 0.0 && lo1 < 0.1, "lo1 = {lo1}");
        assert!((hi1 - 5.5716).abs() < 0.2, "hi1 = {hi1}");
    }

    #[test]
    fn paper_upper_bound_convention() {
        // Table 1 footnote: zero observed errors in 1e6 injections, assume
        // one additional error -> "< 0.0003 %".
        let ub = conservative_upper_rate(0, 1_000_000);
        let pct = ub * 100.0;
        assert!(pct < 0.0006 && pct > 0.0002, "pct = {pct}");
    }

    #[test]
    fn poisson_ci_large_count_matches_normal_approx() {
        // For large k the Poisson CI approaches k ± 1.96 sqrt(k).
        let k = 70_800u64; // baseline functional errors out of 1M ≈ 7.08 %
        let (lo, hi) = poisson_ci95(k);
        let half = (hi - lo) / 2.0;
        let expect = 1.96 * (k as f64).sqrt();
        assert!((half - expect).abs() / expect < 0.01, "half = {half}");
        // Scaled by 1M this is the paper's ±0.05 %.
        let pct_half = half / 1_000_000.0 * 100.0;
        assert!((pct_half - 0.052).abs() < 0.005, "pct_half = {pct_half}");
    }

    #[test]
    fn rate_formatting() {
        let r = Rate::new(0, 1_000_000);
        assert!(r.table1_cell().starts_with('<'));
        let r2 = Rate::new(70_800, 1_000_000);
        let cell = r2.table1_cell();
        assert!(cell.starts_with("7.08"), "cell = {cell}");
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        // Γ(n+1) = n! — ln Γ at small integers must hit the exact values.
        let facts = [1.0f64, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, f) in facts.iter().enumerate() {
            let got = ln_gamma(n as f64 + 1.0);
            assert!((got - f.ln()).abs() < 1e-9, "ln_gamma({}) = {got}", n + 1);
        }
        // Γ(1/2) = sqrt(π).
        let half = ln_gamma(0.5);
        assert!((half - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
    }

    #[test]
    fn beta_quantile_inverts_beta_inc() {
        for &(a, b) in &[(1.0, 10.0), (3.0, 7.0), (20.0, 400.0), (0.5, 0.5)] {
            for &p in &[0.025, 0.1, 0.5, 0.9, 0.975] {
                let x = beta_quantile(p, a, b);
                let back = beta_inc_reg(a, b, x);
                assert!(
                    (back - p).abs() < 1e-8,
                    "I_x inverse mismatch: a={a} b={b} p={p} back={back}"
                );
            }
        }
    }

    #[test]
    fn wilson_known_value_and_bounds() {
        // k=10, n=100: Wilson 95% ≈ [0.0552, 0.1744] (textbook value).
        let (lo, hi) = wilson_ci95(10, 100);
        assert!((lo - 0.0552).abs() < 0.002, "lo = {lo}");
        assert!((hi - 0.1744).abs() < 0.002, "hi = {hi}");
        // Degenerate corners stay in [0, 1] and contain the point estimate.
        let (lo0, hi0) = wilson_ci95(0, 50);
        assert_eq!(lo0, 0.0);
        assert!(hi0 > 0.0 && hi0 < 0.15);
        let (lon, hin) = wilson_ci95(50, 50);
        assert!(lon > 0.85);
        assert_eq!(hin, 1.0);
    }

    #[test]
    fn clopper_pearson_known_values() {
        // k=10, n=100: exact 95% ≈ [0.0490, 0.1762].
        let (lo, hi) = clopper_pearson_ci95(10, 100);
        assert!((lo - 0.0490).abs() < 0.002, "lo = {lo}");
        assert!((hi - 0.1762).abs() < 0.002, "hi = {hi}");
        // Zero count: closed form 1 - 0.025^(1/n).
        let (lo0, hi0) = clopper_pearson_ci95(0, 1000);
        assert_eq!(lo0, 0.0);
        assert!((hi0 - (1.0 - 0.025f64.powf(1.0 / 1000.0))).abs() < 1e-12);
        // Full count mirrors.
        let (_, hin) = clopper_pearson_ci95(30, 30);
        assert_eq!(hin, 1.0);
    }

    #[test]
    fn zero_count_upper_is_rule_of_three() {
        for &n in &[100u64, 1_000, 100_000, 1_000_000] {
            let ub = exact_upper95(0, n);
            let rot = 3.0 / n as f64;
            assert!(
                ((ub - rot) / rot).abs() < 0.05,
                "n={n}: upper {ub:.3e} vs 3/n {rot:.3e}"
            );
        }
        // The paper-scale bound: 0 errors in 1M injections ⇒ < 3.0e-6.
        let ub = exact_upper95(0, 1_000_000);
        assert!(ub < 3.1e-6 && ub > 2.9e-6, "ub = {ub:.4e}");
    }

    #[test]
    fn pooled_estimate_is_consistent() {
        let e = OutcomeEstimate::pooled(7, 200);
        assert_eq!(e.count, 7);
        assert!((e.rate - 0.035).abs() < 1e-12);
        assert!(e.ci_lo <= e.rate && e.rate <= e.ci_hi);
        assert!(e.exact_lo <= e.rate && e.rate <= e.exact_hi);
        assert!(e.half_width() > 0.0 && e.half_width() < 0.05);
        // upper95 sits above the point estimate.
        assert!(e.upper95() > e.rate);
    }

    #[test]
    fn stratified_estimate_weights_the_strata() {
        // Two strata, one rare but error-dense: the weighted rate must sit
        // between the per-stratum rates, pulled toward the heavy stratum.
        let strata = [
            StratumSample { weight: 0.9, count: 0, n: 900 },
            StratumSample { weight: 0.1, count: 50, n: 100 },
        ];
        let e = OutcomeEstimate::stratified(&strata);
        assert_eq!(e.count, 50);
        assert_eq!(e.n, 1000);
        assert!((e.rate - 0.05).abs() < 1e-12, "0.9*0 + 0.1*0.5 = 0.05");
        assert!(e.ci_lo <= e.rate && e.rate <= e.ci_hi);
        assert!(e.half_width() > 0.0 && e.half_width() < 0.05);
        // An unsampled populated stratum blocks tight half-widths.
        let open = [
            StratumSample { weight: 0.9, count: 0, n: 900 },
            StratumSample { weight: 0.1, count: 0, n: 0 },
        ];
        let e2 = OutcomeEstimate::stratified(&open);
        assert!(e2.half_width() > 0.04, "hw = {}", e2.half_width());
        // Zero total weight degrades to the pooled estimate.
        let degenerate = [StratumSample { weight: 0.0, count: 3, n: 30 }];
        assert_eq!(
            OutcomeEstimate::stratified(&degenerate),
            OutcomeEstimate::pooled(3, 30)
        );
    }

    #[test]
    fn neyman_allocation_is_deterministic_and_exact() {
        let scores = [0.5, 0.25, 0.0, 0.25];
        let a = neyman_allocation(&scores, 100, 5);
        assert_eq!(a.iter().sum::<u64>(), 100);
        assert_eq!(a[2], 0, "zero-score stratum gets nothing");
        assert!(a[0] >= 5 && a[1] >= 5 && a[3] >= 5, "floors hold: {a:?}");
        assert!(a[0] > a[1], "allocation follows the scores: {a:?}");
        assert_eq!(a, neyman_allocation(&scores, 100, 5), "pure function");
        // Batch smaller than the floors: evenly split, never overflows.
        let tight = neyman_allocation(&scores, 4, 10);
        assert_eq!(tight.iter().sum::<u64>(), 4);
        // Degenerate inputs.
        assert_eq!(neyman_allocation(&[0.0, f64::NAN], 10, 1), vec![0, 0]);
        assert_eq!(neyman_allocation(&[1.0], 0, 1), vec![0]);
    }
}
