//! Small shared utilities: deterministic PRNGs, bit helpers, statistics,
//! state digests.

pub mod bits;
pub mod digest;
pub mod rng;
pub mod stats;
