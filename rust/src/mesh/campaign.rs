//! Fault-injection campaigns over the mesh interconnect domain.
//!
//! Same statistical machine as the single-tile campaign — `(seed,
//! index)`-pure injection streams, chunked worker threads merged in
//! canonical chunk order — but the sampled population is the NoC
//! ([`NocRegistry`]) and the unit under test is a whole sharded mesh
//! run. Outcomes reuse the Table-1 classes ([`Outcome`]); detected /
//! corrected events are attributed to the three `mesh/noc*` strata.
//!
//! Stream domains are distinct from every existing campaign/sweep
//! domain, so mesh campaigns perturb no previously sampled stream (the
//! mini-Table-1 pins and all A/B baselines stay valid).

use super::noc::{MeshFaultProfile, NocRegistry, NOC_STRATUM_NAMES, N_NOC_STRATA};
use super::{Mesh, MeshConfig, MeshEvents, MeshReport, TilePool};
use crate::campaign::{stream_seed, CampaignConfig, CampaignResult, Outcome, OUTCOMES};
use crate::golden::{GemmProblem, GemmSpec, Mat};
use crate::util::digest::Fnv64;
use crate::util::rng::Xoshiro256;
use crate::{Error, Result};

/// Stream domain for the mesh campaign workload. ("REDMMSPR")
pub const DOMAIN_MESH_PROBLEM: u64 = 0x5245_444D_4D53_5052;
/// Stream domain for mesh injection plans. ("REDMMSIN")
pub const DOMAIN_MESH_INJECT: u64 = 0x5245_444D_4D53_494E;

/// Configuration of one mesh campaign.
#[derive(Debug, Clone)]
pub struct MeshCampaignConfig {
    pub mesh: MeshConfig,
    /// The full (pre-sharding) GEMM shape.
    pub spec: GemmSpec,
    pub injections: u64,
    /// Faults sampled per injection (class profiles; `chaos` always
    /// builds its composed 5-fault plan).
    pub faults_per_run: usize,
    pub profile: MeshFaultProfile,
    pub seed: u64,
    pub threads: usize,
}

impl MeshCampaignConfig {
    pub fn new(tiles: usize, injections: u64, seed: u64) -> Self {
        Self {
            mesh: MeshConfig::new(tiles),
            spec: GemmSpec::new(48, 16, 16),
            injections,
            faults_per_run: 2,
            profile: MeshFaultProfile::Chaos,
            seed,
            threads: 1,
        }
    }
}

/// Per-stratum attribution of one mesh campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct NocStratumStats {
    pub name: &'static str,
    /// Gate-equivalent area share of the stratum (the `mixed` sampling
    /// weight), from [`NocRegistry::stratum_shares`].
    pub share: f64,
    pub applied: u64,
    pub detected: u64,
    pub corrected: u64,
    /// Injections ending in a functional error that had at least one
    /// applied fault in this stratum.
    pub functional_errors: u64,
}

/// Summary the sweep engine carries per mesh cell (`"mesh"` object of
/// sweep-v2 JSON). Kept separate from [`CampaignResult::strata`]: the
/// single-tile stratified estimators must never see mesh strata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshCellInfo {
    pub tiles: usize,
    pub shards: usize,
    pub retired_tiles: u64,
    pub reassigned_shards: u64,
    pub noc_applied: u64,
    pub noc_detected: u64,
    pub noc_corrected: u64,
}

/// Result of one mesh campaign.
#[derive(Debug, Clone)]
pub struct MeshCampaignResult {
    pub config: MeshCampaignConfig,
    pub total: u64,
    pub correct_no_retry: u64,
    pub correct_with_retry: u64,
    pub incorrect: u64,
    pub timeout: u64,
    /// Injections where at least one interconnect fault actually struck
    /// (crash points past a tile's workload, or fates on never-sent
    /// messages, are architecturally masked).
    pub applied_runs: u64,
    pub events: MeshEvents,
    pub strata: Vec<NocStratumStats>,
    /// FNV-64 digest of the golden result (workload identity check).
    pub golden_digest: u64,
}

impl MeshCampaignResult {
    pub fn correct(&self) -> u64 {
        self.correct_no_retry + self.correct_with_retry
    }

    pub fn functional_errors(&self) -> u64 {
        self.incorrect + self.timeout
    }

    pub fn cell_info(&self) -> MeshCellInfo {
        MeshCellInfo {
            tiles: self.config.mesh.tiles,
            shards: self.config.mesh.shard_count(self.config.spec.m),
            retired_tiles: self.events.tiles_retired,
            reassigned_shards: self.events.shards_reassigned,
            noc_applied: self.events.applied(),
            noc_detected: self.events.detected(),
            noc_corrected: self.events.corrected(),
        }
    }

    /// Repackage the outcome counts as a [`CampaignResult`] so mesh
    /// cells flow through the sweep's existing JSON/aggregation
    /// machinery. `strata` stays EMPTY on purpose: the stratified
    /// estimators are defined over the single-tile site population, and
    /// mesh attribution travels in [`MeshCellInfo`] instead.
    pub fn to_campaign_result(&self, config: CampaignConfig, wall_seconds: f64) -> CampaignResult {
        CampaignResult {
            config,
            total: self.total,
            correct_no_retry: self.correct_no_retry,
            correct_with_retry: self.correct_with_retry,
            incorrect: self.incorrect,
            timeout: self.timeout,
            applied: self.applied_runs,
            faults_applied: self.events.applied(),
            corrections: self.events.abft_localized,
            band_recomputes: self.events.shard_recomputes,
            wall_seconds,
            batches: 1,
            stopped_early: false,
            strata: Vec::new(),
        }
    }

    /// Text report in the campaign `--report` style.
    pub fn render(&self) -> String {
        let c = &self.config;
        let mut s = String::new();
        s.push_str(&format!(
            "Mesh campaign — {} tiles, {} shards, {}x{}x{}, engine {}, tile protection {}, profile {}\n",
            c.mesh.tiles,
            c.mesh.shard_count(c.spec.m),
            c.spec.m,
            c.spec.n,
            c.spec.k,
            c.mesh.engine.name(),
            c.mesh.protection.name(),
            c.profile.name(),
        ));
        s.push_str(&format!(
            "mesh recovery: link-crc={} reduction-abft={} tile-retirement={}\n",
            c.mesh.link_crc, c.mesh.reduction_abft, c.mesh.tile_retirement
        ));
        let counts = [
            self.correct_no_retry,
            self.correct_with_retry,
            self.incorrect,
            self.timeout,
        ];
        for (o, n) in OUTCOMES.iter().zip(counts) {
            let pct = 100.0 * n as f64 / self.total.max(1) as f64;
            s.push_str(&format!("{:<22} {:>8}  {:>6.2}%\n", o.name(), n, pct));
        }
        let e = &self.events;
        s.push_str(&format!(
            "interconnect events: crc_detected={} retransmits={} drops_recovered={} dups_discarded={} reorders_fixed={} abft_localized={} shard_recomputes={} tiles_retired={} shards_reassigned={}\n",
            e.crc_detected,
            e.retransmits,
            e.drops_recovered,
            e.dups_discarded,
            e.reorders_fixed,
            e.abft_localized,
            e.shard_recomputes,
            e.tiles_retired,
            e.shards_reassigned,
        ));
        s.push_str(&format!(
            "{:<18} {:>6} {:>8} {:>9} {:>10} {:>12}\n",
            "stratum", "share", "applied", "detected", "corrected", "func-errors"
        ));
        for st in &self.strata {
            s.push_str(&format!(
                "{:<18} {:>6.3} {:>8} {:>9} {:>10} {:>12}\n",
                st.name, st.share, st.applied, st.detected, st.corrected, st.functional_errors
            ));
        }
        s
    }

    /// Deterministic JSON (no wall-clock fields): byte-identical across
    /// thread counts and tile schedules, which the CI mesh sweep-smoke
    /// diffs directly.
    pub fn to_json(&self) -> String {
        let c = &self.config;
        let mut s = String::new();
        s.push_str("{\"schema\": \"redmule-ft/mesh-campaign-v1\", ");
        s.push_str(&format!(
            "\"tiles\": {}, \"shards\": {}, \"shape\": \"{}x{}x{}\", ",
            c.mesh.tiles,
            c.mesh.shard_count(c.spec.m),
            c.spec.m,
            c.spec.n,
            c.spec.k
        ));
        s.push_str(&format!(
            "\"engine\": \"{}\", \"protection\": \"{}\", \"profile\": \"{}\", ",
            c.mesh.engine.name(),
            c.mesh.protection.name(),
            c.profile.name()
        ));
        s.push_str(&format!(
            "\"link_crc\": {}, \"reduction_abft\": {}, \"tile_retirement\": {}, ",
            c.mesh.link_crc, c.mesh.reduction_abft, c.mesh.tile_retirement
        ));
        s.push_str(&format!(
            "\"injections\": {}, \"applied_runs\": {}, \"seed\": {}, \"golden_digest\": \"{:#018x}\", ",
            self.total, self.applied_runs, c.seed, self.golden_digest
        ));
        s.push_str(&format!(
            "\"outcomes\": {{\"correct_no_retry\": {}, \"correct_with_retry\": {}, \"incorrect\": {}, \"timeout\": {}}}, ",
            self.correct_no_retry, self.correct_with_retry, self.incorrect, self.timeout
        ));
        let e = &self.events;
        s.push_str(&format!(
            "\"events\": {{\"crc_detected\": {}, \"retransmits\": {}, \"drops_recovered\": {}, \"dups_discarded\": {}, \"reorders_fixed\": {}, \"abft_localized\": {}, \"shard_recomputes\": {}, \"tiles_retired\": {}, \"shards_reassigned\": {}, \"staging_repairs\": {}}}, ",
            e.crc_detected,
            e.retransmits,
            e.drops_recovered,
            e.dups_discarded,
            e.reorders_fixed,
            e.abft_localized,
            e.shard_recomputes,
            e.tiles_retired,
            e.shards_reassigned,
            e.staging_repairs,
        ));
        s.push_str("\"strata\": [");
        for (i, st) in self.strata.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"name\": \"{}\", \"share\": {:.6}, \"applied\": {}, \"detected\": {}, \"corrected\": {}, \"functional_errors\": {}}}",
                st.name, st.share, st.applied, st.detected, st.corrected, st.functional_errors
            ));
        }
        s.push_str("]}");
        s
    }
}

/// Classify one mesh run against the golden result, mirroring the
/// single-tile [`crate::campaign::classify`] semantics.
pub fn classify_mesh(report: &MeshReport, golden: &Mat) -> Outcome {
    if !report.completed {
        Outcome::Timeout
    } else if report.z != *golden {
        Outcome::Incorrect
    } else if report.events.recovered() {
        Outcome::CorrectWithRetry
    } else {
        Outcome::CorrectNoRetry
    }
}

#[derive(Default)]
struct Partial {
    outcomes: [u64; 4],
    applied_runs: u64,
    events: MeshEvents,
    strata_fe: [u64; N_NOC_STRATA],
}

/// The mesh campaign engine.
pub struct MeshCampaign;

impl MeshCampaign {
    /// Run on the canonical seeded workload for this config.
    pub fn run(config: &MeshCampaignConfig) -> Result<MeshCampaignResult> {
        let problem = GemmProblem::random(
            &config.spec,
            stream_seed(config.seed, DOMAIN_MESH_PROBLEM, 0),
        );
        Self::run_with_problem(config, &problem)
    }

    /// Run against a caller-provided workload (the sweep engine shares
    /// one problem per shape across cells).
    pub fn run_with_problem(
        config: &MeshCampaignConfig,
        problem: &GemmProblem,
    ) -> Result<MeshCampaignResult> {
        if problem.spec != config.spec {
            return Err(Error::Config(
                "mesh campaign problem shape does not match config.spec".into(),
            ));
        }
        let golden = problem.golden_z_for(config.mesh.cfg.format, config.mesh.cfg.op);
        let tiles = config.mesh.tiles;
        let shards = config.mesh.shard_count(config.spec.m);
        let mut shards_of = vec![0u64; tiles];
        for s in 0..shards {
            shards_of[s % tiles] += 1;
        }
        let registry = NocRegistry::new(tiles, shards_of);

        let n = config.injections;
        let threads = config.threads.max(1).min(n.max(1) as usize);
        let chunk = n.div_ceil(threads as u64);
        let mut partials: Vec<Partial> = Vec::new();
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for w in 0..threads {
                let lo = w as u64 * chunk;
                let hi = ((w as u64 + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                let golden = &golden;
                let registry = &registry;
                handles.push(
                    scope.spawn(move || Self::run_range(config, problem, golden, registry, lo, hi)),
                );
            }
            // Joined (and merged) in spawn order: thread-count invariant.
            for h in handles {
                let p = h
                    .join()
                    .map_err(|_| Error::Sim("mesh campaign worker panicked".into()))??;
                partials.push(p);
            }
            Ok(())
        })?;

        let mut outcomes = [0u64; 4];
        let mut applied_runs = 0u64;
        let mut events = MeshEvents::default();
        let mut strata_fe = [0u64; N_NOC_STRATA];
        for p in &partials {
            for i in 0..4 {
                outcomes[i] += p.outcomes[i];
            }
            applied_runs += p.applied_runs;
            for s in 0..N_NOC_STRATA {
                strata_fe[s] += p.strata_fe[s];
            }
            events.merge(&p.events);
        }
        let shares = NocRegistry::stratum_shares();
        let strata = (0..N_NOC_STRATA)
            .map(|s| NocStratumStats {
                name: NOC_STRATUM_NAMES[s],
                share: shares[s],
                applied: events.strata[s][0],
                detected: events.strata[s][1],
                corrected: events.strata[s][2],
                functional_errors: strata_fe[s],
            })
            .collect();
        let mut h = Fnv64::new();
        for &b in &golden.bits() {
            h.write_u16(b);
        }
        Ok(MeshCampaignResult {
            config: config.clone(),
            total: n,
            correct_no_retry: outcomes[0],
            correct_with_retry: outcomes[1],
            incorrect: outcomes[2],
            timeout: outcomes[3],
            applied_runs,
            events,
            strata,
            golden_digest: h.finish(),
        })
    }

    fn run_range(
        config: &MeshCampaignConfig,
        problem: &GemmProblem,
        golden: &Mat,
        registry: &NocRegistry,
        lo: u64,
        hi: u64,
    ) -> Result<Partial> {
        let mut pool = TilePool::new(config.mesh.cfg, config.mesh.protection, config.mesh.tiles);
        let mut p = Partial::default();
        for i in lo..hi {
            let mut rng = Xoshiro256::new(stream_seed(config.seed, DOMAIN_MESH_INJECT, i));
            let plan = registry.sample(&mut rng, config.faults_per_run, config.profile);
            let report = Mesh::run_with_pool(&config.mesh, problem, &plan, &mut pool)?;
            let outcome = classify_mesh(&report, golden);
            p.outcomes[outcome.index()] += 1;
            if report.faults_applied > 0 {
                p.applied_runs += 1;
            }
            if outcome.is_functional_error() {
                for s in 0..N_NOC_STRATA {
                    if report.events.strata[s][0] > 0 {
                        p.strata_fe[s] += 1;
                    }
                }
            }
            p.events.merge(&report.events);
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TileEngine;

    fn tiny(tiles: usize, profile: MeshFaultProfile) -> MeshCampaignConfig {
        let mut c = MeshCampaignConfig::new(tiles, 12, 0xC0FFEE);
        c.spec = GemmSpec::new(16, 6, 5);
        c.mesh.engine = TileEngine::FastForward;
        c.profile = profile;
        c
    }

    #[test]
    fn full_protection_chaos_has_zero_functional_errors() {
        let c = tiny(4, MeshFaultProfile::Chaos);
        let r = MeshCampaign::run(&c).unwrap();
        assert_eq!(r.total, 12);
        assert_eq!(r.functional_errors(), 0, "\n{}", r.render());
        // Chaos applies all five faults every injection; recovery fired.
        assert!(r.events.applied() > 0);
        assert!(r.events.detected() > 0);
        assert!(r.correct_with_retry > 0);
    }

    #[test]
    fn thread_count_does_not_change_the_result() {
        let mut a = tiny(3, MeshFaultProfile::Mixed);
        let mut b = tiny(3, MeshFaultProfile::Mixed);
        a.threads = 1;
        b.threads = 8;
        let ra = MeshCampaign::run(&a).unwrap();
        let rb = MeshCampaign::run(&b).unwrap();
        assert_eq!(ra.to_json(), rb.to_json());
    }

    #[test]
    fn unprotected_mesh_fails_under_each_transport_fault_class() {
        for profile in [
            MeshFaultProfile::Drop,
            MeshFaultProfile::Dup,
            MeshFaultProfile::Crash,
        ] {
            let mut c = tiny(3, profile);
            c.mesh = MeshConfig::unprotected(3);
            c.mesh.engine = TileEngine::FastForward;
            c.faults_per_run = 1;
            let r = MeshCampaign::run(&c).unwrap();
            assert!(
                r.functional_errors() > 0,
                "profile {} should break an unprotected mesh\n{}",
                profile.name(),
                r.render()
            );
        }
    }

    #[test]
    fn stratum_attribution_lands_in_the_right_stratum() {
        let mut c = tiny(4, MeshFaultProfile::Flip);
        c.faults_per_run = 1;
        let r = MeshCampaign::run(&c).unwrap();
        assert!(r.strata[0].applied > 0, "\n{}", r.render());
        assert_eq!(r.strata[1].applied, 0);
        assert_eq!(r.strata[2].applied, 0);
        assert_eq!(r.strata[0].name, "mesh/noc-link");
        // CRC detects and retransmission corrects every flip.
        assert_eq!(r.strata[0].detected, r.strata[0].applied);
        assert_eq!(r.functional_errors(), 0);
    }

    #[test]
    fn campaign_result_conversion_keeps_strata_empty() {
        let c = tiny(2, MeshFaultProfile::Chaos);
        let r = MeshCampaign::run(&c).unwrap();
        let cc = CampaignConfig::table1(c.mesh.protection, r.total, c.seed);
        let conv = r.to_campaign_result(cc, 0.0);
        assert!(conv.strata.is_empty());
        assert_eq!(conv.total, r.total);
        assert_eq!(conv.functional_errors(), r.functional_errors());
        let info = r.cell_info();
        assert_eq!(info.tiles, 2);
        assert_eq!(info.noc_applied, r.events.applied());
    }
}
