//! RedMulE Mesh: a deterministic multi-tile sharded-GEMM simulation.
//!
//! One large `Z = X·W + Y` is sharded into contiguous **row bands** and
//! distributed round-robin over N [`System`] tiles (the sweep engine's
//! worker-arena + `reconfigure` machinery, promoted to a tile pool).
//! Row-band sharding keeps every per-element FMA chain intact, so the
//! gathered result is **bit-identical** to the single-`System` run for
//! any tile count — the property the mesh determinism tests pin.
//!
//! Tiles push their finished bands to a reduction root over a modeled
//! NoC. That transfer-and-reduction layer is a first-class fault domain
//! ([`noc`]): link SETs on in-flight results, lost / duplicated /
//! reordered messages, and tile crashes mid-shard, each attributed to
//! its own `mesh/noc*` stratum. Three composable recovery options
//! defend it:
//!
//! * **Per-link CRC + bounded retransmit** (`link_crc`) — CRC-16 +
//!   sequence numbers + ACK/NACK: corrupted messages are retransmitted
//!   (clean, up to [`MAX_RETRANSMITS`]), duplicates are discarded,
//!   placement trusts the CRC-protected header, and a lost message is
//!   re-sent after [`RETRANSMIT_TIMEOUT`]. Without it the root gathers
//!   by physical ingress: per-link arrival index → assigned shard, so
//!   a drop shifts every later band on that link, a duplicate shifts
//!   them the other way, and a reorder swaps bands — real, distinct
//!   failure modes per fault class.
//! * **Reduction-tree ABFT** (`reduction_abft`) — every message carries
//!   exact fixed-point column sums of its band
//!   ([`crate::golden::fp16_to_fixed`]; exact integer addition is
//!   associative, so the check is reduction-order invariant). The root
//!   verifies a binary tree over the gathered bands, descends into the
//!   mismatching half, and recomputes only the corrupted shard on its
//!   owning tile. A misplaced-but-intact band carries its own matching
//!   checksums, so misplacement is CRC's job, not ABFT's — the classic
//!   division of labor between transport and algorithmic checks.
//! * **Tile retirement** (`tile_retirement`) — a heartbeat watchdog
//!   detects a wedged tile; its unfinished shards are reassigned
//!   round-robin over the survivors and pulled by the host over a
//!   supervised channel (recovery traffic is never struck by sampled
//!   plans: fate ordinals only cover attempt-0 traffic).
//!
//! Determinism contract: fault fates are keyed by canonical message
//! identity, message delivery is a total order on
//! `(arrival, tile, ordinal, attempt, copy)`, and per-tile virtual
//! clocks advance independently of host scheduling — so a mesh run is
//! byte-identical across thread counts and tile-stepping orders.

pub mod campaign;
pub mod noc;

pub use campaign::{MeshCampaign, MeshCampaignConfig, MeshCampaignResult, MeshCellInfo, NocStratumStats};
pub use noc::{crc16, MeshFaultProfile, NocFault, NocFaultKind, NocRegistry, NOC_STRATUM_NAMES, N_NOC_STRATA};

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::cluster::{HostOutcome, System, TileEngine};
use crate::fp::Fp16;
use crate::golden::{fp16_to_fixed, GemmProblem, GemmSpec, Mat};
use crate::perf::PhaseSchedule;
use crate::redmule::{ExecMode, Protection, RedMuleConfig};
use crate::util::digest::Fnv64;
use crate::{Error, Result};

/// NoC cycles from a tile's result push to root ingress (serialization
/// + hops), identical per link — tiles are one hop from the root.
pub const LINK_LATENCY: u64 = 32;
/// Sender-side ACK timeout before a lost message is retransmitted.
pub const RETRANSMIT_TIMEOUT: u64 = 64;
/// Retransmission budget per message (per-link seq/ack window).
pub const MAX_RETRANSMITS: u32 = 3;
/// Root merge-engine occupancy per committed message.
pub const MERGE_CYCLES_PER_MSG: u64 = 4;
/// Heartbeat watchdog latency before a wedged tile is declared dead.
pub const HEARTBEAT_TIMEOUT: u64 = 128;

/// Configuration of one mesh run.
#[derive(Debug, Clone)]
pub struct MeshConfig {
    pub tiles: usize,
    /// Row-band shard count; 0 = auto (`min(2·tiles, m)` — two waves
    /// per tile so a crash always strands reassignable work).
    pub shards: usize,
    /// Per-tile hardware build.
    pub cfg: RedMuleConfig,
    /// Per-tile protection mode (composes with the mesh options below).
    pub protection: Protection,
    /// Which execution backend each tile runs.
    pub engine: TileEngine,
    /// Per-link CRC-16 + seq/ack + bounded retransmit.
    pub link_crc: bool,
    /// Fixed-point column checksums verified over the reduction tree.
    pub reduction_abft: bool,
    /// Heartbeat watchdog + crashed-tile shard reassignment.
    pub tile_retirement: bool,
    /// Tile *stepping* order for the compute pass (empty = identity).
    /// A pure scheduling choice: results are byte-identical under any
    /// permutation, which `tests/mesh.rs` pins.
    pub tile_order: Vec<usize>,
    /// Verify staged X/W images at rest in TCDM before each tile run
    /// (direct engine only; see `System::verify_staged_inputs`).
    pub verify_staging: bool,
}

impl MeshConfig {
    /// Fully protected mesh on the paper build.
    pub fn new(tiles: usize) -> Self {
        Self {
            tiles,
            shards: 0,
            cfg: RedMuleConfig::paper(),
            protection: Protection::Full,
            engine: TileEngine::Direct,
            link_crc: true,
            reduction_abft: true,
            tile_retirement: true,
            tile_order: Vec::new(),
            verify_staging: false,
        }
    }

    /// Same build with every mesh recovery option off.
    pub fn unprotected(tiles: usize) -> Self {
        Self {
            link_crc: false,
            reduction_abft: false,
            tile_retirement: false,
            ..Self::new(tiles)
        }
    }

    /// Runtime execution mode per tile, derived exactly like the
    /// single-tile campaign default: fault-tolerant iff the build has
    /// the §3.1 data-path machinery.
    pub fn mode(&self) -> ExecMode {
        if self.protection.has_data_protection() {
            ExecMode::FaultTolerant
        } else {
            ExecMode::Performance
        }
    }

    /// Effective shard count for an `m`-row problem.
    pub fn shard_count(&self, m: usize) -> usize {
        let want = if self.shards == 0 {
            (2 * self.tiles).min(m)
        } else {
            self.shards.min(m)
        };
        want.max(1)
    }

    fn validate(&self) -> Result<()> {
        if self.tiles == 0 {
            return Err(Error::Config("mesh needs at least 1 tile".into()));
        }
        if !self.tile_order.is_empty() {
            let mut seen = vec![false; self.tiles];
            let mut ok = self.tile_order.len() == self.tiles;
            if ok {
                for &t in &self.tile_order {
                    if t >= self.tiles || seen[t] {
                        ok = false;
                        break;
                    }
                    seen[t] = true;
                }
            }
            if !ok {
                return Err(Error::Config(format!(
                    "tile_order must be a permutation of 0..{}",
                    self.tiles
                )));
            }
        }
        Ok(())
    }
}

/// Split `m` rows into `shards` contiguous bands, sizes differing by at
/// most one row, returned as `(row0, row1)` half-open ranges.
pub fn shard_rows(m: usize, shards: usize) -> Vec<(usize, usize)> {
    let base = m / shards;
    let rem = m % shards;
    let mut out = Vec::with_capacity(shards);
    let mut r0 = 0;
    for s in 0..shards {
        let rows = base + usize::from(s < rem);
        out.push((r0, r0 + rows));
        r0 += rows;
    }
    out
}

/// Slice the row band `[r0, r1)` of a problem into a standalone
/// sub-problem (X and Y bands, full W).
pub fn sub_problem(p: &GemmProblem, r0: usize, r1: usize) -> GemmProblem {
    let rows = r1 - r0;
    let n = p.spec.n;
    let k = p.spec.k;
    GemmProblem {
        spec: GemmSpec::new(rows, n, k),
        x: Mat {
            rows,
            cols: n,
            data: p.x.data[r0 * n..r1 * n].to_vec(),
        },
        w: p.w.clone(),
        y: Mat {
            rows,
            cols: k,
            data: p.y.data[r0 * k..r1 * k].to_vec(),
        },
    }
}

/// Interconnect event counters of one mesh run, plus per-stratum
/// `[applied, detected, corrected]` attribution (indexed by
/// [`noc::NOC_STRATUM_NAMES`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MeshEvents {
    pub crc_detected: u64,
    pub retransmits: u64,
    pub drops_recovered: u64,
    pub dups_discarded: u64,
    pub reorders_fixed: u64,
    pub abft_localized: u64,
    pub shard_recomputes: u64,
    pub tiles_retired: u64,
    pub shards_reassigned: u64,
    pub staging_repairs: u64,
    pub strata: [[u64; 3]; noc::N_NOC_STRATA],
}

impl MeshEvents {
    pub fn applied(&self) -> u64 {
        self.strata.iter().map(|s| s[0]).sum()
    }

    pub fn detected(&self) -> u64 {
        self.strata.iter().map(|s| s[1]).sum()
    }

    pub fn corrected(&self) -> u64 {
        self.strata.iter().map(|s| s[2]).sum()
    }

    /// Did any recovery machinery fire?
    pub fn recovered(&self) -> bool {
        self.detected() > 0 || self.staging_repairs > 0
    }

    pub fn merge(&mut self, o: &MeshEvents) {
        self.crc_detected += o.crc_detected;
        self.retransmits += o.retransmits;
        self.drops_recovered += o.drops_recovered;
        self.dups_discarded += o.dups_discarded;
        self.reorders_fixed += o.reorders_fixed;
        self.abft_localized += o.abft_localized;
        self.shard_recomputes += o.shard_recomputes;
        self.tiles_retired += o.tiles_retired;
        self.shards_reassigned += o.shards_reassigned;
        self.staging_repairs += o.staging_repairs;
        for s in 0..noc::N_NOC_STRATA {
            for j in 0..3 {
                self.strata[s][j] += o.strata[s][j];
            }
        }
    }
}

/// Result of one mesh run.
#[derive(Debug, Clone)]
pub struct MeshReport {
    /// The gathered result (missing bands zero when `!completed`).
    pub z: Mat,
    /// Every band slot received a result.
    pub completed: bool,
    pub events: MeshEvents,
    /// Virtual cycles: max over tile clocks and the root merge clock.
    pub cycles: u64,
    /// Final shard → tile ownership after any reassignment.
    pub shard_map: Vec<usize>,
    pub retired_tiles: Vec<usize>,
    pub faults_applied: u32,
}

impl MeshReport {
    /// FNV-64 digest of the result bits — what the determinism tests
    /// and the CI sweep-smoke compare across schedules.
    pub fn z_digest(&self) -> u64 {
        let mut h = Fnv64::new();
        for &b in &self.z.bits() {
            h.write_u16(b);
        }
        h.write_bool(self.completed);
        h.finish()
    }
}

/// Tile pool: lazily constructed `System` instances, one per tile,
/// reused across shards (and across injections when the caller holds
/// the pool) exactly like the sweep's worker arenas.
pub struct TilePool {
    cfg: RedMuleConfig,
    protection: Protection,
    systems: Vec<Option<System>>,
}

impl TilePool {
    pub fn new(cfg: RedMuleConfig, protection: Protection, tiles: usize) -> Self {
        Self {
            cfg,
            protection,
            systems: (0..tiles).map(|_| None).collect(),
        }
    }

    pub fn get(&mut self, tile: usize) -> &mut System {
        let slot = &mut self.systems[tile];
        if slot.is_none() {
            *slot = Some(System::new(self.cfg, self.protection));
        }
        slot.as_mut().unwrap()
    }
}

/// One in-flight (or retransmitted) result message at the root.
#[derive(Clone)]
struct Msg {
    words: Vec<u16>,
    crc: u16,
    /// Simulation bookkeeping (NOT read by the unprotected gather —
    /// the CRC path reads the shard id from the protected header).
    shard: usize,
    delayed: bool,
}

/// Serialize a band result: CRC-protected header (shard id), band Z
/// bits, then the exact fixed-point column sums as 4×16-bit limbs.
fn encode_msg(shard: usize, data: &[Fp16], k: usize) -> Vec<u16> {
    let mut words = Vec::with_capacity(2 + data.len() + 4 * k);
    words.push((shard & 0xFFFF) as u16);
    words.push(((shard >> 16) & 0xFFFF) as u16);
    for v in data {
        words.push(v.to_bits());
    }
    for c in 0..k {
        let rows = data.len() / k;
        let mut s: i64 = 0;
        for r in 0..rows {
            s += fp16_to_fixed(data[r * k + c]);
        }
        let u = s as u64;
        words.push((u & 0xFFFF) as u16);
        words.push(((u >> 16) & 0xFFFF) as u16);
        words.push(((u >> 32) & 0xFFFF) as u16);
        words.push(((u >> 48) & 0xFFFF) as u16);
    }
    words
}

/// Inverse of [`encode_msg`]. Message length is flip-invariant, so the
/// band row count is recovered from the length, never from (possibly
/// corrupted) header fields.
fn decode_msg(words: &[u16], k: usize) -> (usize, Vec<Fp16>, Vec<i64>) {
    let shard = (words[0] as usize) | ((words[1] as usize) << 16);
    let body = words.len() - 2 - 4 * k;
    let rows = body / k;
    let data: Vec<Fp16> = words[2..2 + rows * k]
        .iter()
        .map(|&b| Fp16::from_bits(b))
        .collect();
    let base = 2 + rows * k;
    let mut csum = Vec::with_capacity(k);
    for c in 0..k {
        let u = (words[base + 4 * c] as u64)
            | ((words[base + 4 * c + 1] as u64) << 16)
            | ((words[base + 4 * c + 2] as u64) << 32)
            | ((words[base + 4 * c + 3] as u64) << 48);
        csum.push(u as i64);
    }
    (shard, data, csum)
}

fn fixed_col_sums(data: &[Fp16], k: usize) -> Vec<i64> {
    let rows = data.len() / k;
    (0..k)
        .map(|c| (0..rows).map(|r| fp16_to_fixed(data[r * k + c])).sum())
        .collect()
}

/// Run one clean tile attempt for a band sub-problem on the configured
/// engine backend. The direct engine steps the cycle-accurate `System`;
/// the fast-forward and two-level engines use the functional level —
/// valid because clean runs are bit-identical to the golden model on
/// every engine (the crate's clean-run contract, pinned by
/// `tests/precision.rs`) — and price cycles with the closed-form
/// [`PhaseSchedule`].
fn tile_compute(
    config: &MeshConfig,
    sys: &mut System,
    sub: &GemmProblem,
    events: &mut MeshEvents,
) -> Result<(Mat, u64)> {
    match config.engine {
        TileEngine::Direct => {
            sys.redmule.reset();
            let layout = sys.stage(sub)?;
            if config.verify_staging && !sys.verify_staged_inputs(sub, &layout) {
                sys.restage_inputs(sub, &layout)?;
                events.staging_repairs += 1;
            }
            let r = sys.run_staged_with_fault(&layout, config.mode(), None)?;
            if r.outcome != HostOutcome::Completed {
                return Err(Error::Sim(format!(
                    "clean tile run ended {:?} on a {} build",
                    r.outcome,
                    config.protection.name()
                )));
            }
            Ok((r.z, r.cycles))
        }
        TileEngine::FastForward | TileEngine::TwoLevel => {
            let z = sub.golden_z_for(config.cfg.format, config.cfg.op);
            let cycles =
                PhaseSchedule::hosted(config.cfg, config.protection, sub.spec, config.mode())
                    .host_cycles();
            Ok((z, cycles))
        }
    }
}

/// Per-message sampled fate, folded from the plan (pure function of the
/// plan — independent of scheduling).
#[derive(Default, Clone)]
struct Fate {
    flips: Vec<u32>,
    drop: bool,
    dup: bool,
    delay: u64,
}

/// The mesh simulator.
pub struct Mesh;

impl Mesh {
    /// Run with no interconnect faults.
    pub fn run_clean(config: &MeshConfig, problem: &GemmProblem) -> Result<MeshReport> {
        Self::run(config, problem, &[])
    }

    /// Run one sharded GEMM under an interconnect fault plan.
    pub fn run(config: &MeshConfig, problem: &GemmProblem, plan: &[NocFault]) -> Result<MeshReport> {
        let mut pool = TilePool::new(config.cfg, config.protection, config.tiles);
        Self::run_with_pool(config, problem, plan, &mut pool)
    }

    /// [`Mesh::run`] with a caller-owned tile pool (the campaign hot
    /// loop reuses one pool across injections, like the sweep arenas).
    pub fn run_with_pool(
        config: &MeshConfig,
        problem: &GemmProblem,
        plan: &[NocFault],
        pool: &mut TilePool,
    ) -> Result<MeshReport> {
        config.validate()?;
        let m = problem.spec.m;
        let k = problem.spec.k;
        let tiles = config.tiles;
        let shards = config.shard_count(m);
        let bands = shard_rows(m, shards);
        let band_len: Vec<usize> = bands.iter().map(|&(r0, r1)| (r1 - r0) * k).collect();

        // Canonical round-robin shard → tile assignment; `assigned[t]`
        // ascending defines each uplink's attempt-0 message ordinals.
        let assign: Vec<usize> = (0..shards).map(|s| s % tiles).collect();
        let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); tiles];
        for (s, &t) in assign.iter().enumerate() {
            assigned[t].push(s);
        }

        let mut events = MeshEvents::default();

        // Fold the plan into per-message fates and per-tile crash points.
        let mut crash_after: Vec<Option<u64>> = vec![None; tiles];
        let mut fates: HashMap<(usize, u64), Fate> = HashMap::new();
        for f in plan {
            if f.tile >= tiles {
                continue;
            }
            match f.kind {
                NocFaultKind::TileCrash { after_shards } => {
                    crash_after[f.tile] =
                        Some(crash_after[f.tile].map_or(after_shards, |c| c.min(after_shards)));
                }
                kind => {
                    let n_msgs = assigned[f.tile].len() as u64;
                    if n_msgs == 0 {
                        continue;
                    }
                    let e = fates.entry((f.tile, f.msg_ordinal % n_msgs)).or_default();
                    match kind {
                        NocFaultKind::LinkFlip { bit } => e.flips.push(bit),
                        NocFaultKind::Drop => e.drop = true,
                        NocFaultKind::Dup => e.dup = true,
                        NocFaultKind::Delay { cycles } => e.delay = e.delay.max(cycles),
                        NocFaultKind::TileCrash { .. } => unreachable!(),
                    }
                }
            }
        }
        let crashed: Vec<bool> = (0..tiles)
            .map(|t| crash_after[t].is_some_and(|a| (a as usize) < assigned[t].len()))
            .collect();
        for t in 0..tiles {
            if crashed[t] {
                events.strata[2][0] += 1;
            }
        }

        // ------------------------------------------------- compute pass
        let order: Vec<usize> = if config.tile_order.is_empty() {
            (0..tiles).collect()
        } else {
            config.tile_order.clone()
        };
        let mut shard_z: Vec<Option<Mat>> = vec![None; shards];
        let mut done_at: Vec<u64> = vec![0; shards];
        let mut tile_clock: Vec<u64> = vec![0; tiles];
        for &t in &order {
            for (ord, &s) in assigned[t].iter().enumerate() {
                if crash_after[t].is_some_and(|a| ord as u64 >= a) {
                    break;
                }
                let (r0, r1) = bands[s];
                let sub = sub_problem(problem, r0, r1);
                let (z, cycles) = tile_compute(config, pool.get(t), &sub, &mut events)?;
                tile_clock[t] += cycles;
                done_at[s] = tile_clock[t];
                shard_z[s] = Some(z);
            }
        }

        // ------------------------------------------------- transit pass
        // Clean encodings are kept per shard so NACK-triggered
        // retransmissions resend uncorrupted store-and-forward copies.
        let mut enc: Vec<Option<(Vec<u16>, u16)>> = vec![None; shards];
        // Delivery is a total order on (arrival, tile, ordinal, attempt,
        // copy): unique per message instance, independent of scheduling.
        type Key = (u64, usize, u64, u32, u32);
        let mut heap: BinaryHeap<Reverse<(Key, usize)>> = BinaryHeap::new();
        let mut msgs: Vec<Msg> = Vec::new();
        for s in 0..shards {
            let Some(z) = &shard_z[s] else { continue };
            let t = assign[s];
            let ord = assigned[t].iter().position(|&x| x == s).unwrap() as u64;
            let clean = encode_msg(s, &z.data, k);
            let crc = crc16(&clean);
            enc[s] = Some((clean.clone(), crc));
            let mut words = clean;
            let mut arrival = done_at[s] + LINK_LATENCY;
            let mut delayed = false;
            let mut dropped = false;
            let mut dup = false;
            if let Some(f) = fates.get(&(t, ord)) {
                for &bit in &f.flips {
                    let nbits = (words.len() * 16) as u32;
                    let b = bit % nbits;
                    words[(b / 16) as usize] ^= 1 << (b % 16);
                    events.strata[0][0] += 1;
                }
                if f.delay > 0 {
                    arrival += f.delay;
                    delayed = true;
                    events.strata[1][0] += 1;
                }
                if f.dup {
                    dup = true;
                    events.strata[1][0] += 1;
                }
                if f.drop {
                    dropped = true;
                    events.strata[1][0] += 1;
                }
            }
            if dropped {
                if config.link_crc {
                    // No ACK within the window: the sender retransmits
                    // its buffered clean copy once.
                    let (cw, cc) = enc[s].clone().unwrap();
                    events.retransmits += 1;
                    events.drops_recovered += 1;
                    events.strata[1][1] += 1;
                    events.strata[1][2] += 1;
                    let idx = msgs.len();
                    msgs.push(Msg {
                        words: cw,
                        crc: cc,
                        shard: s,
                        delayed: false,
                    });
                    heap.push(Reverse((
                        (done_at[s] + RETRANSMIT_TIMEOUT + LINK_LATENCY, t, ord, 1, 0),
                        idx,
                    )));
                }
                continue;
            }
            let idx = msgs.len();
            msgs.push(Msg {
                words: words.clone(),
                crc,
                shard: s,
                delayed,
            });
            heap.push(Reverse(((arrival, t, ord, 0, 0), idx)));
            if dup {
                // The duplicated grant forwards the same (possibly
                // corrupted) flits one slot later.
                let idx = msgs.len();
                msgs.push(Msg {
                    words,
                    crc,
                    shard: s,
                    delayed,
                });
                heap.push(Reverse(((arrival + 1, t, ord, 0, 1), idx)));
            }
        }

        // ------------------------------------------------ delivery pass
        let mut slots: Vec<Option<Vec<Fp16>>> = vec![None; shards];
        let mut slot_csum: Vec<Option<Vec<i64>>> = vec![None; shards];
        // Unprotected gather state: per-link arrival index → shard via
        // the static assignment (each uplink is believed FIFO).
        let mut link_idx: Vec<usize> = vec![0; tiles];
        let mut retrans: HashMap<(usize, u64), u32> = HashMap::new();
        let mut agg_clock: u64 = 0;
        while let Some(Reverse((key, idx))) = heap.pop() {
            let (arrival, t, ord, _attempt, _copy) = key;
            agg_clock = agg_clock.max(arrival) + MERGE_CYCLES_PER_MSG;
            let msg = msgs[idx].clone();
            if config.link_crc {
                if crc16(&msg.words) != msg.crc {
                    events.crc_detected += 1;
                    events.strata[0][1] += 1;
                    let cnt = retrans.entry((t, ord)).or_insert(0);
                    if *cnt < MAX_RETRANSMITS {
                        *cnt += 1;
                        let attempt = *cnt;
                        events.retransmits += 1;
                        events.strata[0][2] += 1;
                        let (cw, cc) = enc[msg.shard].clone().unwrap();
                        let nidx = msgs.len();
                        msgs.push(Msg {
                            words: cw,
                            crc: cc,
                            shard: msg.shard,
                            delayed: false,
                        });
                        heap.push(Reverse((
                            (arrival + RETRANSMIT_TIMEOUT, t, ord, attempt, 0),
                            nidx,
                        )));
                    }
                    continue;
                }
                let (shard, data, csum) = decode_msg(&msg.words, k);
                if shard >= shards || slots[shard].is_some() {
                    // Sequence-number dedup (duplicate grant, or a
                    // retransmission racing a late original).
                    if shard < shards {
                        events.dups_discarded += 1;
                        events.strata[1][1] += 1;
                        events.strata[1][2] += 1;
                    }
                    continue;
                }
                if msg.delayed {
                    events.reorders_fixed += 1;
                    events.strata[1][1] += 1;
                    events.strata[1][2] += 1;
                }
                slots[shard] = Some(data);
                slot_csum[shard] = Some(csum);
            } else {
                // Dumb gather: commit to `assigned[t][arrival index]`.
                // Correct for any cross-tile timing when links really
                // are FIFO and lossless; a drop shifts every later band
                // on the link, a dup shifts them back, a reorder swaps.
                let li = link_idx[t];
                link_idx[t] += 1;
                if li >= assigned[t].len() {
                    continue;
                }
                let slot = assigned[t][li];
                let (_shard, data, csum) = decode_msg(&msg.words, k);
                let want = band_len[slot];
                let mut fill = vec![Fp16::ZERO; want];
                let n = want.min(data.len());
                fill[..n].copy_from_slice(&data[..n]);
                slots[slot] = Some(fill);
                slot_csum[slot] = Some(csum);
            }
        }

        // -------------------------------------------- retirement pass
        let mut shard_map = assign.clone();
        let mut retired: Vec<usize> = Vec::new();
        if config.tile_retirement && crashed.iter().any(|&c| c) {
            let survivors: Vec<usize> = (0..tiles).filter(|&t| !crashed[t]).collect();
            for t in 0..tiles {
                if crashed[t] {
                    // Heartbeat watchdog: detection always fires.
                    events.strata[2][1] += 1;
                    retired.push(t);
                }
            }
            events.tiles_retired = retired.len() as u64;
            agg_clock += HEARTBEAT_TIMEOUT;
            if !survivors.is_empty() {
                let missing: Vec<usize> =
                    (0..shards).filter(|&s| shard_z[s].is_none()).collect();
                for (i, &s) in missing.iter().enumerate() {
                    let t = survivors[i % survivors.len()];
                    let (r0, r1) = bands[s];
                    let sub = sub_problem(problem, r0, r1);
                    let (z, cycles) = tile_compute(config, pool.get(t), &sub, &mut events)?;
                    tile_clock[t] += cycles;
                    // Host-supervised pull: placed by shard id on both
                    // transports, and never struck by sampled fates
                    // (recovery ordinals sit past attempt-0 traffic).
                    slot_csum[s] = Some(fixed_col_sums(&z.data, k));
                    slots[s] = Some(z.data);
                    shard_map[s] = t;
                    events.shards_reassigned += 1;
                }
                for t in 0..tiles {
                    if crashed[t] {
                        events.strata[2][2] += 1;
                    }
                }
            }
        }

        let completed = slots.iter().all(|s| s.is_some());

        // --------------------------------------- reduction-tree verify
        if completed && config.reduction_abft {
            Self::verify_node(
                config, problem, &bands, &shard_map, pool, &mut slots, &mut slot_csum,
                &mut tile_clock, &mut events, 0, shards, k,
            )?;
        }

        // ----------------------------------------------------- gather
        let mut z = Mat::zeros(m, k);
        for s in 0..shards {
            if let Some(data) = &slots[s] {
                let (r0, _) = bands[s];
                let n = band_len[s].min(data.len());
                z.data[r0 * k..r0 * k + n].copy_from_slice(&data[..n]);
            }
        }

        let compute_max = tile_clock.iter().copied().max().unwrap_or(0);
        Ok(MeshReport {
            z,
            completed,
            cycles: agg_clock.max(compute_max),
            shard_map,
            retired_tiles: retired,
            faults_applied: events.applied() as u32,
            events,
        })
    }

    /// Verify the carried fixed-point column checksums over the binary
    /// reduction tree for shard range `[l, r)`. Exact integer sums are
    /// associative, so every interior node's check is reduction-order
    /// invariant; a mismatch descends into the failing half and the
    /// corrupted leaf is recomputed on its owning tile.
    #[allow(clippy::too_many_arguments)]
    fn verify_node(
        config: &MeshConfig,
        problem: &GemmProblem,
        bands: &[(usize, usize)],
        shard_map: &[usize],
        pool: &mut TilePool,
        slots: &mut [Option<Vec<Fp16>>],
        slot_csum: &mut [Option<Vec<i64>>],
        tile_clock: &mut [u64],
        events: &mut MeshEvents,
        l: usize,
        r: usize,
        k: usize,
    ) -> Result<()> {
        let mut ok = true;
        'cols: for c in 0..k {
            let mut carried = 0i64;
            let mut observed = 0i64;
            for s in l..r {
                carried += slot_csum[s].as_ref().unwrap()[c];
                let data = slots[s].as_ref().unwrap();
                let rows = data.len() / k;
                for row in 0..rows {
                    observed += fp16_to_fixed(data[row * k + c]);
                }
            }
            if carried != observed {
                ok = false;
                break 'cols;
            }
        }
        if ok {
            return Ok(());
        }
        if r - l == 1 {
            let s = l;
            events.abft_localized += 1;
            events.strata[0][1] += 1;
            let t = shard_map[s];
            let (r0, r1) = bands[s];
            let sub = sub_problem(problem, r0, r1);
            let (z, cycles) = tile_compute(config, pool.get(t), &sub, events)?;
            tile_clock[t] += cycles;
            slot_csum[s] = Some(fixed_col_sums(&z.data, k));
            slots[s] = Some(z.data);
            events.shard_recomputes += 1;
            events.strata[0][2] += 1;
            return Ok(());
        }
        let mid = l + (r - l) / 2;
        Self::verify_node(
            config, problem, bands, shard_map, pool, slots, slot_csum, tile_clock, events, l, mid,
            k,
        )?;
        Self::verify_node(
            config, problem, bands, shard_map, pool, slots, slot_csum, tile_clock, events, mid, r,
            k,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_rows_partitions_exactly() {
        for m in [1, 5, 12, 16, 37] {
            for shards in 1..=m.min(9) {
                let bands = shard_rows(m, shards);
                assert_eq!(bands.len(), shards);
                assert_eq!(bands[0].0, 0);
                assert_eq!(bands[shards - 1].1, m);
                for w in bands.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
                let max = bands.iter().map(|&(a, b)| b - a).max().unwrap();
                let min = bands.iter().map(|&(a, b)| b - a).min().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn msg_codec_round_trips() {
        let p = GemmProblem::random(&GemmSpec::new(4, 3, 5), 99);
        let words = encode_msg(7, &p.y.data, 5);
        let crc = crc16(&words);
        let (shard, data, csum) = decode_msg(&words, 5);
        assert_eq!(shard, 7);
        assert_eq!(data, p.y.data);
        assert_eq!(csum, fixed_col_sums(&p.y.data, 5));
        assert_eq!(crc, crc16(&words));
    }

    #[test]
    fn sub_problem_bands_recompose_the_golden() {
        let p = GemmProblem::random(&GemmSpec::new(10, 6, 7), 5);
        let golden = p.golden_z();
        let bands = shard_rows(10, 4);
        let mut z = Mat::zeros(10, 7);
        for &(r0, r1) in &bands {
            let sub = sub_problem(&p, r0, r1);
            let zb = sub.golden_z();
            for (i, &v) in zb.data.iter().enumerate() {
                z.data[r0 * 7 + i] = v;
            }
        }
        assert_eq!(z, golden);
    }

    #[test]
    fn clean_mesh_matches_golden_for_any_tile_count() {
        let p = GemmProblem::random(&GemmSpec::new(12, 8, 6), 11);
        let golden = p.golden_z();
        for tiles in [1, 2, 3, 5] {
            let mut cfg = MeshConfig::new(tiles);
            cfg.engine = TileEngine::FastForward;
            let r = Mesh::run_clean(&cfg, &p).unwrap();
            assert!(r.completed);
            assert_eq!(r.z, golden, "tiles={tiles}");
            assert_eq!(r.faults_applied, 0);
            assert_eq!(r.events, MeshEvents::default());
        }
    }

    #[test]
    fn unprotected_clean_mesh_is_also_correct() {
        // The ingress-indexed gather must be exact when nothing fails,
        // even with unequal band sizes racing across links.
        let p = GemmProblem::random(&GemmSpec::new(11, 4, 3), 3);
        let golden = p.golden_z();
        let mut cfg = MeshConfig::unprotected(3);
        cfg.engine = TileEngine::FastForward;
        let r = Mesh::run_clean(&cfg, &p).unwrap();
        assert!(r.completed);
        assert_eq!(r.z, golden);
    }
}
