//! The mesh interconnect (NoC/DMA) fault domain.
//!
//! The mesh's transfer-and-reduction layer is a first-class fault-site
//! population, parallel to the per-tile [`crate::fault::FaultRegistry`]:
//! three strata (`mesh/noc-link`, `mesh/noc-router`, `mesh/noc-tile`)
//! weighted by the same gate-equivalent coefficients the mesh area model
//! charges for them ([`crate::area::coeff`]), sampled from
//! `(seed, index)`-pure streams exactly like datapath faults.
//!
//! Fault *fates* are keyed by the canonical identity of the struck
//! message — `(tile, msg_ordinal)`, the ordinal counting the tile's
//! attempt-0 result pushes in its canonical (ascending shard) order —
//! never by wall-clock or scheduling order. A plan therefore lands on
//! the same message no matter how many worker threads run the campaign
//! or in which order tiles are stepped, which is what keeps mesh
//! results byte-identical across thread counts and tile schedules.

use crate::area::coeff::{GE_NOC_LINK_IF, GE_NOC_ROUTER, GE_NOC_TILE_CTRL};
use crate::util::rng::Xoshiro256;

/// Number of interconnect strata.
pub const N_NOC_STRATA: usize = 3;

/// Stratum display names. The `mesh/noc` prefix keeps campaign reports
/// unambiguous next to the per-tile strata (`dp/…`, `ft/…`).
pub const NOC_STRATUM_NAMES: [&str; N_NOC_STRATA] =
    ["mesh/noc-link", "mesh/noc-router", "mesh/noc-tile"];

/// Upper bound on a sampled router-delay fate, in NoC cycles. Large
/// enough to reorder a message behind everything a busy tile sends
/// later; small next to a shard's compute time.
pub const MAX_DELAY_CYCLES: u64 = 96;

/// One interconnect fault class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NocFaultKind {
    /// SET on a link wire: one bit of the in-flight serialized result
    /// message flips. `bit` is a raw draw, reduced modulo the message's
    /// payload width at strike time.
    LinkFlip { bit: u32 },
    /// Router buffer overrun / misroute: the message never arrives.
    Drop,
    /// Duplicated switch grant: the message is delivered twice.
    Dup,
    /// Stalled virtual channel: delivery is delayed by `cycles`,
    /// reordering the message behind later traffic.
    Delay { cycles: u64 },
    /// The tile's mesh sequencer wedges after completing `after_shards`
    /// of its assigned shards; nothing more is computed or sent.
    TileCrash { after_shards: u64 },
}

impl NocFaultKind {
    /// Index into [`NOC_STRATUM_NAMES`].
    pub fn stratum(self) -> usize {
        match self {
            NocFaultKind::LinkFlip { .. } => 0,
            NocFaultKind::Drop | NocFaultKind::Dup | NocFaultKind::Delay { .. } => 1,
            NocFaultKind::TileCrash { .. } => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NocFaultKind::LinkFlip { .. } => "link-flip",
            NocFaultKind::Drop => "drop",
            NocFaultKind::Dup => "dup",
            NocFaultKind::Delay { .. } => "delay",
            NocFaultKind::TileCrash { .. } => "tile-crash",
        }
    }
}

/// One planned interconnect fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NocFault {
    /// Tile whose uplink / router ingress / sequencer is struck.
    pub tile: usize,
    /// Canonical ordinal of the struck message on that tile's uplink
    /// (ignored by [`NocFaultKind::TileCrash`]). Reassigned-shard
    /// pushes get ordinals past every tile's attempt-0 count, so a plan
    /// can never strike recovery traffic — fates stay a pure function
    /// of the sampled plan.
    pub msg_ordinal: u64,
    pub kind: NocFaultKind,
}

/// Which interconnect fault classes an injection samples from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MeshFaultProfile {
    /// No interconnect faults (clean mesh).
    None,
    /// Link SETs only.
    Flip,
    /// Lost messages only.
    Drop,
    /// Duplicated messages only.
    Dup,
    /// Delayed (reordered) messages only.
    Reorder,
    /// Tile crashes only.
    Crash,
    /// Area-weighted mix across all three strata.
    Mixed,
    /// The composed worst case: one flip + one drop + one dup + one
    /// reorder on distinct messages plus one tile crash, per injection.
    #[default]
    Chaos,
}

impl MeshFaultProfile {
    pub fn name(self) -> &'static str {
        match self {
            MeshFaultProfile::None => "none",
            MeshFaultProfile::Flip => "flip",
            MeshFaultProfile::Drop => "drop",
            MeshFaultProfile::Dup => "dup",
            MeshFaultProfile::Reorder => "reorder",
            MeshFaultProfile::Crash => "crash",
            MeshFaultProfile::Mixed => "mixed",
            MeshFaultProfile::Chaos => "chaos",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "none" => MeshFaultProfile::None,
            "flip" => MeshFaultProfile::Flip,
            "drop" => MeshFaultProfile::Drop,
            "dup" => MeshFaultProfile::Dup,
            "reorder" => MeshFaultProfile::Reorder,
            "crash" => MeshFaultProfile::Crash,
            "mixed" => MeshFaultProfile::Mixed,
            "chaos" => MeshFaultProfile::Chaos,
            _ => return None,
        })
    }
}

/// The interconnect fault-site population of one mesh run: which tiles
/// exist and how many attempt-0 result messages each uplink carries.
/// Strata are weighted by their gate-equivalent area, mirroring the
/// per-tile registry's area-keyed site weights.
#[derive(Debug, Clone)]
pub struct NocRegistry {
    pub tiles: usize,
    /// Attempt-0 message count per tile (its originally assigned shards).
    pub shards_of: Vec<u64>,
}

impl NocRegistry {
    pub fn new(tiles: usize, shards_of: Vec<u64>) -> Self {
        assert!(tiles > 0 && shards_of.len() == tiles);
        Self { tiles, shards_of }
    }

    /// Normalized area share of each stratum — the weights
    /// [`NocRegistry::sample`] draws with under the `mixed` profile,
    /// and what campaign reports print as the stratum `share`.
    pub fn stratum_shares() -> [f64; N_NOC_STRATA] {
        let total = GE_NOC_LINK_IF + GE_NOC_ROUTER + GE_NOC_TILE_CTRL;
        [
            GE_NOC_LINK_IF / total,
            GE_NOC_ROUTER / total,
            GE_NOC_TILE_CTRL / total,
        ]
    }

    fn victim(&self, rng: &mut Xoshiro256) -> (usize, u64) {
        // Tiles are identical hardware, so the struck tile is uniform;
        // the ordinal is uniform over that uplink's attempt-0 traffic.
        let tile = rng.below(self.tiles as u64) as usize;
        let ordinal = rng.below(self.shards_of[tile].max(1));
        (tile, ordinal)
    }

    fn sample_one(&self, rng: &mut Xoshiro256, profile: MeshFaultProfile) -> NocFault {
        let class = match profile {
            MeshFaultProfile::Mixed => {
                let shares = Self::stratum_shares();
                let u = rng.next_f64();
                if u < shares[0] {
                    0
                } else if u < shares[0] + shares[1] {
                    1
                } else {
                    2
                }
            }
            MeshFaultProfile::Flip => 0,
            MeshFaultProfile::Drop | MeshFaultProfile::Dup | MeshFaultProfile::Reorder => 1,
            MeshFaultProfile::Crash => 2,
            MeshFaultProfile::None | MeshFaultProfile::Chaos => unreachable!(),
        };
        let (tile, msg_ordinal) = self.victim(rng);
        let kind = match class {
            0 => NocFaultKind::LinkFlip {
                bit: rng.next_u32(),
            },
            1 => match profile {
                MeshFaultProfile::Drop => NocFaultKind::Drop,
                MeshFaultProfile::Dup => NocFaultKind::Dup,
                MeshFaultProfile::Reorder => NocFaultKind::Delay {
                    cycles: 1 + rng.below(MAX_DELAY_CYCLES),
                },
                // Mixed: the three router failure modes are equally
                // likely within the router stratum.
                _ => match rng.below(3) {
                    0 => NocFaultKind::Drop,
                    1 => NocFaultKind::Dup,
                    _ => NocFaultKind::Delay {
                        cycles: 1 + rng.below(MAX_DELAY_CYCLES),
                    },
                },
            },
            _ => NocFaultKind::TileCrash {
                after_shards: rng.below(self.shards_of[tile].max(1)),
            },
        };
        NocFault {
            tile,
            msg_ordinal,
            kind,
        }
    }

    /// Sample one injection's interconnect plan. Class profiles draw `n`
    /// independent faults of that class; `chaos` builds the composed
    /// acceptance scenario regardless of `n`.
    pub fn sample(&self, rng: &mut Xoshiro256, n: usize, profile: MeshFaultProfile) -> Vec<NocFault> {
        match profile {
            MeshFaultProfile::None => Vec::new(),
            MeshFaultProfile::Chaos => self.chaos_plan(rng),
            _ => (0..n).map(|_| self.sample_one(rng, profile)).collect(),
        }
    }

    /// One flip + one drop + one dup + one reorder on (preferably)
    /// distinct messages, plus one tile crash mid-shard.
    pub fn chaos_plan(&self, rng: &mut Xoshiro256) -> Vec<NocFault> {
        let mut used: Vec<(usize, u64)> = Vec::with_capacity(4);
        let mut pick = |rng: &mut Xoshiro256| {
            // Bounded rejection keeps the draw deterministic even on
            // meshes too small for four distinct victims.
            for _ in 0..16 {
                let v = self.victim(rng);
                if !used.contains(&v) {
                    used.push(v);
                    return v;
                }
            }
            let v = self.victim(rng);
            used.push(v);
            v
        };
        let (ft, fo) = pick(rng);
        let flip_bit = rng.next_u32();
        let (dt, do_) = pick(rng);
        let (ut, uo) = pick(rng);
        let (rt, ro) = pick(rng);
        let delay = 1 + rng.below(MAX_DELAY_CYCLES);
        let crash_tile = rng.below(self.tiles as u64) as usize;
        let crash_after = rng.below(self.shards_of[crash_tile].max(1));
        vec![
            NocFault {
                tile: ft,
                msg_ordinal: fo,
                kind: NocFaultKind::LinkFlip { bit: flip_bit },
            },
            NocFault {
                tile: dt,
                msg_ordinal: do_,
                kind: NocFaultKind::Drop,
            },
            NocFault {
                tile: ut,
                msg_ordinal: uo,
                kind: NocFaultKind::Dup,
            },
            NocFault {
                tile: rt,
                msg_ordinal: ro,
                kind: NocFaultKind::Delay { cycles: delay },
            },
            NocFault {
                tile: crash_tile,
                msg_ordinal: 0,
                kind: NocFaultKind::TileCrash {
                    after_shards: crash_after,
                },
            },
        ]
    }
}

/// CRC-16/CCITT-FALSE over the message words, little-endian byte order.
/// This is the per-link integrity check of the reliable transport: a
/// corrupted payload (or header) fails the check at the reduction root
/// and triggers a NACK + bounded retransmit.
pub fn crc16(words: &[u16]) -> u16 {
    let mut crc: u16 = 0xFFFF;
    for &w in words {
        for byte in w.to_le_bytes() {
            crc ^= (byte as u16) << 8;
            for _ in 0..8 {
                crc = if crc & 0x8000 != 0 {
                    (crc << 1) ^ 0x1021
                } else {
                    crc << 1
                };
            }
        }
    }
    crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc16_matches_known_vector() {
        // CRC-16/CCITT-FALSE("123456789") = 0x29B1; "123456789" as
        // little-endian u16 words is [0x3231, 0x3433, ...] plus a
        // trailing odd byte — use an even-length ASCII vector instead.
        let bytes = b"12345678";
        let words: Vec<u16> = bytes
            .chunks(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect();
        let c = crc16(&words);
        // Self-consistency: deterministic, sensitive to any bit flip.
        assert_eq!(c, crc16(&words));
        for w in 0..words.len() {
            for b in 0..16 {
                let mut f = words.clone();
                f[w] ^= 1 << b;
                assert_ne!(crc16(&f), c, "flip at word {w} bit {b} undetected");
            }
        }
    }

    #[test]
    fn stratum_shares_are_normalized() {
        let s = NocRegistry::stratum_shares();
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(s.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn chaos_plan_covers_all_classes() {
        let reg = NocRegistry::new(4, vec![2, 2, 2, 2]);
        let mut rng = Xoshiro256::new(7);
        let plan = reg.chaos_plan(&mut rng);
        assert_eq!(plan.len(), 5);
        let mut strata = [0u32; N_NOC_STRATA];
        for f in &plan {
            strata[f.kind.stratum()] += 1;
            assert!(f.tile < 4);
        }
        assert_eq!(strata, [1, 3, 1]);
    }

    #[test]
    fn sampling_is_seed_pure() {
        let reg = NocRegistry::new(3, vec![3, 3, 2]);
        for profile in [
            MeshFaultProfile::Flip,
            MeshFaultProfile::Mixed,
            MeshFaultProfile::Chaos,
        ] {
            let a = reg.sample(&mut Xoshiro256::new(42), 4, profile);
            let b = reg.sample(&mut Xoshiro256::new(42), 4, profile);
            assert_eq!(a, b);
        }
        assert!(reg
            .sample(&mut Xoshiro256::new(1), 8, MeshFaultProfile::None)
            .is_empty());
    }

    #[test]
    fn profile_names_round_trip() {
        for p in [
            MeshFaultProfile::None,
            MeshFaultProfile::Flip,
            MeshFaultProfile::Drop,
            MeshFaultProfile::Dup,
            MeshFaultProfile::Reorder,
            MeshFaultProfile::Crash,
            MeshFaultProfile::Mixed,
            MeshFaultProfile::Chaos,
        ] {
            assert_eq!(MeshFaultProfile::parse(p.name()), Some(p));
        }
        assert_eq!(MeshFaultProfile::parse("bogus"), None);
    }
}
