//! # RedMulE-FT — reproduction library
//!
//! A cycle-level, fault-injectable model of the RedMulE-FT reconfigurable
//! fault-tolerant matrix-multiplication engine (Wiese et al., CF Companion
//! '25), together with the substrates it depends on (FP16 soft-float, ECC,
//! TCDM, DMA, a PULP-cluster host driver), a statistical fault-injection
//! campaign engine, an analytic gate-equivalent area model, and a PJRT
//! runtime that executes the AOT-compiled JAX/Pallas golden model from Rust.
//!
//! ## Layering
//!
//! * **Layer 1/2 (build time)** — `python/compile/` holds the Pallas GEMM
//!   kernel and the JAX graphs (golden GEMM, MLP train step). `make
//!   artifacts` lowers them once to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — everything at simulation/request time:
//!   the accelerator model ([`redmule`]), the fault campaign
//!   ([`fault`], [`campaign`]), the cluster substrate ([`tcdm`], [`dma`],
//!   [`cluster`]), the mixed-criticality [`coordinator`], and the
//!   [`runtime`] that loads the HLO artifacts via PJRT.
//!
//! See `ARCHITECTURE.md` at the repository root for the module graph, the
//! three execution engines, and the determinism contract that ties them
//! together.
//!
//! ## Precision and op family
//!
//! The datapath is parameterised on a numeric format
//! ([`fp::GemmFormat`]: FP16, or FP8 E4M3 / E5M2 carried on FP16 rails
//! through cast-in/cast-out stages that are themselves fault sites) and a
//! GEMM op family ([`fp::GemmOp`]: `mul` plus the `addmax` / `addmin` /
//! `mulmax` / `mulmin` max-/min-plus variants). Both are plumbed from
//! [`redmule::RedMuleConfig`] through the golden model, the fault-site
//! registry, the area model and the sweep grid; the defaults (`fp16`,
//! `mul`) reproduce the paper configuration bit-for-bit.
//!
//! ## Quick start
//!
//! ```text
//! use redmule_ft::prelude::*;
//!
//! // Build a cluster with a fully protected RedMulE-FT instance.
//! let cfg = RedMuleConfig::paper(); // L=12, H=4, P=3, FP16
//! let mut sys = System::new(cfg, Protection::Full);
//! let gemm = GemmSpec::new(12, 16, 16);
//! let problem = GemmProblem::random(&gemm, 42);
//! let report = sys.run_gemm(&problem, ExecMode::FaultTolerant).unwrap();
//! assert!(report.z_matches(&problem.golden_z()));
//!
//! // Or trade replication for ABFT checksums: full performance-mode
//! // throughput, ~3.6 % area, detection + row-band recovery at
//! // writeback (coverage bounded by the FP16 rounding tolerance).
//! let mut sys = System::new(cfg, Protection::Abft)
//!     .with_recovery(RecoveryPolicy::TileLevel);
//! let report = sys.run_gemm(&problem, ExecMode::Performance).unwrap();
//! assert!(report.z_matches(&problem.golden_z()) && report.retries == 0);
//! ```

// Module roster (see DESIGN.md §2 for the inventory).
pub mod area;
pub mod campaign;
pub mod cluster;
pub mod coordinator;
pub mod dma;
pub mod ecc;
pub mod fault;
pub mod fp;
pub mod golden;
pub mod mesh;
pub mod perf;
pub mod redmule;
pub mod runtime;
pub mod service;
pub mod tcdm;
pub mod util;

/// Commonly used types, re-exported for examples and binaries.
pub mod prelude {
    pub use crate::campaign::{
        Campaign, CampaignConfig, Outcome, Sweep, SweepConfig, Table1, TraceCache,
    };
    pub use crate::cluster::{HostOutcome, RecoveryPolicy, RunReport, System, TileEngine};
    pub use crate::coordinator::{Coordinator, Criticality, TaskRequest};
    pub use crate::fault::{FaultKind, FaultModel, FaultPlan, FaultRegistry};
    pub use crate::fp::{Fp16, Fp8, Fp8Format, GemmFormat, GemmOp};
    pub use crate::golden::{GemmProblem, GemmSpec, Mat};
    pub use crate::mesh::{
        Mesh, MeshCampaign, MeshCampaignConfig, MeshConfig, MeshFaultProfile, MeshReport,
    };
    pub use crate::redmule::{ExecMode, Protection, RedMuleConfig};
    pub use crate::service::{
        BackoffPolicy, CampaignService, JobOutcome, JobSpec, ServiceConfig, ServiceFaultPlan,
        ServiceReport,
    };
    pub use crate::util::rng::Xoshiro256;
}

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("configuration error: {0}")]
    Config(String),
    #[error("simulation error: {0}")]
    Sim(String),
    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),
    #[error("artifact not found: {0} (run `make artifacts`)")]
    ArtifactMissing(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;
